"""Deterministic fallback for the ``hypothesis`` property-test API.

The property suites (tests/test_kernels.py, test_ternary.py, test_optim.py,
test_core_attention.py, test_substrate.py) used to ``importorskip``
hypothesis, which silently skipped them wholesale on machines without the
package — and let them rot (undefined ``st`` references shipped unnoticed).
They now fall back to this module instead, so the properties *always
execute*:

  * with hypothesis installed (CI installs it from requirements-dev.txt)
    the real library runs — shrinking, edge-case heuristics, the works;
  * without it, this shim drives each ``@given`` test with a deterministic,
    seeded sweep: the strategy bounds' endpoints first, then reproducible
    pseudo-random draws up to ``settings(max_examples=...)``.

Only the API surface the repo's tests use is implemented (``given``,
``settings``, ``assume``, ``strategies.integers/floats/booleans/
sampled_from/lists``). The draws are keyed by the test's qualified name, so
a failure reproduces by just re-running the test — no seed database needed.
This is intentionally NOT a hypothesis replacement: no shrinking, no
adaptive generation. It exists so "no hypothesis" degrades to "fewer, fixed
examples" rather than "zero coverage".
"""
from __future__ import annotations

import functools
import hashlib
import inspect
import random
from typing import Any, Callable, List, Optional, Sequence


class _Unsatisfied(Exception):
    """Raised by assume(False): the drawn example is discarded."""


def assume(condition: bool) -> bool:
    if not condition:
        raise _Unsatisfied()
    return True


class _Strategy:
    def example(self, rng: random.Random) -> Any:
        raise NotImplementedError

    def edges(self) -> List[Any]:
        """Deterministic boundary examples tried before random draws."""
        return []


class _Integers(_Strategy):
    def __init__(self, min_value: int, max_value: int):
        assert min_value <= max_value
        self.lo, self.hi = int(min_value), int(max_value)

    def example(self, rng):
        return rng.randint(self.lo, self.hi)

    def edges(self):
        mid = (self.lo + self.hi) // 2
        return list(dict.fromkeys([self.lo, self.hi, mid]))


class _Floats(_Strategy):
    def __init__(self, min_value: float, max_value: float):
        assert min_value <= max_value
        self.lo, self.hi = float(min_value), float(max_value)

    def example(self, rng):
        return rng.uniform(self.lo, self.hi)

    def edges(self):
        mid = 0.5 * (self.lo + self.hi)
        return list(dict.fromkeys([self.lo, self.hi, mid]))


class _Booleans(_Strategy):
    def example(self, rng):
        return rng.random() < 0.5

    def edges(self):
        return [False, True]


class _SampledFrom(_Strategy):
    def __init__(self, elements: Sequence[Any]):
        self.elements = list(elements)
        assert self.elements

    def example(self, rng):
        return rng.choice(self.elements)

    def edges(self):
        return self.elements[:2]


class _Lists(_Strategy):
    def __init__(self, elements: _Strategy, min_size: int = 0,
                 max_size: Optional[int] = None):
        self.elements = elements
        self.min_size = min_size
        self.max_size = max_size if max_size is not None else min_size + 8

    def example(self, rng):
        n = rng.randint(self.min_size, self.max_size)
        return [self.elements.example(rng) for _ in range(n)]

    def edges(self):
        out = [[e] * max(self.min_size, 1) for e in self.elements.edges()[:1]]
        if self.min_size == 0:
            out.insert(0, [])
        return out


class strategies:
    """Namespace mirroring ``hypothesis.strategies`` (import as ``st``)."""

    @staticmethod
    def integers(min_value: int = 0, max_value: int = 2 ** 31 - 1):
        return _Integers(min_value, max_value)

    @staticmethod
    def floats(min_value: float = 0.0, max_value: float = 1.0, **_ignored):
        return _Floats(min_value, max_value)

    @staticmethod
    def booleans():
        return _Booleans()

    @staticmethod
    def sampled_from(elements: Sequence[Any]):
        return _SampledFrom(elements)

    @staticmethod
    def lists(elements: _Strategy, min_size: int = 0,
              max_size: Optional[int] = None):
        return _Lists(elements, min_size, max_size)


st = strategies  # the conventional alias


class _Settings:
    def __init__(self, max_examples: int = 20, deadline=None, **_ignored):
        self.max_examples = max_examples
        self.deadline = deadline


def settings(**kwargs) -> Callable:
    """Attach example-count settings to a test (either decorator order
    relative to ``@given`` works, as with real hypothesis)."""

    def deco(fn):
        fn._compat_settings = _Settings(**kwargs)
        return fn

    return deco


def _seed_for(qualname: str) -> int:
    return int.from_bytes(
        hashlib.sha256(qualname.encode()).digest()[:8], "big")


def given(**strats: _Strategy) -> Callable:
    """Run the wrapped test over edge examples + seeded random draws.

    Examples are deterministic per test (seeded by the test's qualname), so
    a red run reproduces exactly; the failing example's arguments ride along
    on the raised error's message.
    """
    names = sorted(strats)

    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            cfg = (getattr(wrapper, "_compat_settings", None)
                   or getattr(fn, "_compat_settings", None) or _Settings())
            rng = random.Random(_seed_for(fn.__qualname__))
            examples: List[dict] = []
            edge_lists = {k: strats[k].edges() for k in names}
            for i in range(max(len(v) for v in edge_lists.values()) if names
                           else 0):
                examples.append({
                    k: (edge_lists[k][i] if i < len(edge_lists[k])
                        else strats[k].example(rng)) for k in names})
            while len(examples) < cfg.max_examples:
                examples.append({k: strats[k].example(rng) for k in names})
            for drawn in examples[: cfg.max_examples]:
                try:
                    fn(*args, **kwargs, **drawn)
                except _Unsatisfied:
                    continue
                except Exception as e:
                    raise AssertionError(
                        f"property falsified with {drawn!r} "
                        f"(hypothesis_compat deterministic sweep): {e}"
                    ) from e

        # hide the strategy-bound parameters from pytest: without this,
        # inspect.signature follows __wrapped__ into ``fn`` and pytest tries
        # to resolve ``seed=``/``scale=``... as fixtures (collection error)
        sig = inspect.signature(fn)
        wrapper.__signature__ = sig.replace(parameters=[
            p for name, p in sig.parameters.items() if name not in strats])
        wrapper.hypothesis_compat = True
        return wrapper

    return deco
