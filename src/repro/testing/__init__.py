"""Test-support utilities (importable via the same ``PYTHONPATH=src`` the
test suite already uses). Not part of the serving/runtime surface."""
