"""pixtral-12b [vlm] — Pixtral ViT frontend (stub) + Mistral-NeMo-style backbone.

40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072
[hf:mistralai/Pixtral-12B-2409; unverified]
"""
from repro.configs.base import LoRAConfig, ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b",
    family="vlm",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=131072,
    ffn_kind="swiglu",
    rope_theta=1_000_000.0,
    # Vision frontend is a STUB per the assignment: input_specs() provides
    # pre-computed patch embeddings at d_model for the image prefix tokens.
    frontend_stub_dim=5120,
    lora=LoRAConfig(rank=16, targets=("q", "v")),
)
