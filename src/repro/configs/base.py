"""Config system for the TOM reproduction framework.

Every assigned architecture is expressed as a :class:`ModelConfig`. Configs are
pure dataclasses — no jax import at module scope — so that ``launch/dryrun.py``
can set XLA flags before any device initialisation.
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass, field
from typing import Optional, Sequence, Tuple

# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0
    num_experts_per_tok: int = 0
    num_shared_experts: int = 0
    expert_d_ff: int = 0
    # Arctic-style dense MLP residual branch running in parallel with the MoE.
    dense_residual_d_ff: int = 0
    # DeepSeek-style: first k layers use a dense FFN instead of MoE.
    first_k_dense: int = 0
    dense_d_ff: int = 0
    # Router options
    router_aux_free_bias: bool = True  # DeepSeek-V3-style aux-loss-free balancing term
    capacity_factor: float = 1.25      # used by the dropping (EP) path


@dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (DeepSeek-V2)."""

    kv_lora_rank: int = 512
    q_lora_rank: int = 1536
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 / SSD block configuration."""

    state_size: int = 128
    head_dim: int = 64
    expand: int = 2
    num_groups: int = 1
    conv_width: int = 4
    chunk_size: int = 256


@dataclass(frozen=True)
class LoRAConfig:
    """Ternary QLoRA adapters (paper §IV-D.3, LoTA-QAF-style)."""

    rank: int = 16
    targets: Tuple[str, ...] = ("q", "v")  # which projections carry adapters
    ternary_adapters: bool = True
    alpha: float = 32.0


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128
    # --- attention options -------------------------------------------------
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    attention_kind: str = "gqa"  # gqa | mla | none
    mla: Optional[MLAConfig] = None
    # --- ffn ----------------------------------------------------------------
    ffn_kind: str = "swiglu"  # swiglu | gelu | relu2
    moe: Optional[MoEConfig] = None
    # --- ssm / hybrid --------------------------------------------------------
    ssm: Optional[SSMConfig] = None
    # hybrid pattern: for every layer index, 'a' (attention block) or 'm'
    # (mamba2 block). Empty → homogeneous per `family`.
    block_pattern: str = ""
    # zamba2: attention blocks share a single set of weights
    shared_attention: bool = False
    # --- embedding / head ----------------------------------------------------
    tie_embeddings: bool = False
    # modality frontend stub: if set, input_specs() provides pre-computed
    # frame/patch embeddings of this dimension instead of token ids.
    frontend_stub_dim: int = 0
    # --- quantisation (the paper's technique) --------------------------------
    ternary_weights: bool = True   # C1: pack every linear as 2-bit ternary
    fp8_activations: bool = True   # activations/KV in e4m3 with scales
    fp8_kv_cache: bool = True
    # --- adapters -------------------------------------------------------------
    lora: Optional[LoRAConfig] = None
    # --- misc -----------------------------------------------------------------
    max_seq_len: int = 32_768
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"

    # ------------------------------------------------------------------
    @property
    def vocab_padded(self) -> int:
        """Embedding-table vocab padded to a multiple of 128 so the
        vocab-sharded embedding/head divide evenly across 16 lanes (only
        mamba2-1.3b pads: 50280 → 50304). Logits at the pad positions are
        masked to −inf; ``vocab_size`` stays the logical vocabulary."""
        return ((self.vocab_size + 127) // 128) * 128

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # Rough parameter count (embedding + blocks), used by roofline MODEL_FLOPS.
    def param_count(self, active_only: bool = False) -> int:
        d = self.d_model
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        n_attn, n_mamba = self._block_counts()
        # attention params
        if self.attention_kind == "gqa":
            attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        elif self.attention_kind == "mla":
            m = self.mla
            qh = self.num_heads * (m.qk_nope_head_dim + m.qk_rope_head_dim)
            attn = (
                d * m.q_lora_rank
                + m.q_lora_rank * qh
                + d * (m.kv_lora_rank + m.qk_rope_head_dim)
                + m.kv_lora_rank * self.num_heads * (m.qk_nope_head_dim + m.v_head_dim)
                + self.num_heads * m.v_head_dim * d
            )
        else:
            attn = 0
        # ffn params
        if self.moe is not None:
            e = self.moe
            expert = self._ffn_params(d, e.expert_d_ff)
            k_active = e.num_experts_per_tok + e.num_shared_experts
            if active_only:
                ffn = k_active * expert
            else:
                ffn = (e.num_experts + e.num_shared_experts) * expert
            ffn += d * e.num_experts  # router
            if e.dense_residual_d_ff:
                ffn += self._ffn_params(d, e.dense_residual_d_ff)
        else:
            ffn = self._ffn_params(d, self.d_ff)
        # mamba2 params
        if self.ssm is not None:
            s = self.ssm
            d_in = s.expand * d
            nheads = d_in // s.head_dim
            conv_dim = d_in + 2 * s.num_groups * s.state_size
            mamba = (
                d * (2 * d_in + 2 * s.num_groups * s.state_size + nheads)  # in_proj
                + conv_dim * s.conv_width
                + d_in * d  # out_proj
                + 2 * nheads  # A_log, D
            )
        else:
            mamba = 0

        total = emb
        total += n_attn * (attn + ffn)
        total += n_mamba * mamba
        # deepseek first-k-dense correction
        if self.moe is not None and self.moe.first_k_dense:
            moe_ffn_full = ffn
            dense_ffn = self._ffn_params(d, self.moe.dense_d_ff)
            total -= self.moe.first_k_dense * (moe_ffn_full - dense_ffn)
        return total

    def _ffn_params(self, d: int, dff: int) -> int:
        if self.ffn_kind == "swiglu":
            return 3 * d * dff
        return 2 * d * dff

    def _block_counts(self) -> Tuple[int, int]:
        """(# attention blocks incl. their FFN, # mamba blocks)."""
        if self.block_pattern:
            n_a = self.block_pattern.count("a")
            n_m = self.block_pattern.count("m")
            return n_a, n_m
        if self.family == "ssm":
            return 0, self.num_layers
        return self.num_layers, 0


# ---------------------------------------------------------------------------
# Input-shape cells (assigned per architecture)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}

ARCH_IDS = (
    "pixtral-12b",
    "musicgen-large",
    "qwen3-1.7b",
    "mistral-large-123b",
    "yi-34b",
    "starcoder2-7b",
    "arctic-480b",
    "deepseek-v2-236b",
    "mamba2-1.3b",
    "zamba2-7b",
)

# Paper's own model is additionally available but not part of the assigned grid.
EXTRA_ARCH_IDS = ("bitnet-2b",)

_MODULE_FOR_ARCH = {a: a.replace("-", "_").replace(".", "_") for a in ARCH_IDS + EXTRA_ARCH_IDS}


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULE_FOR_ARCH:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULE_FOR_ARCH)}")
    mod = importlib.import_module(f"repro.configs.{_MODULE_FOR_ARCH[arch]}")
    return mod.CONFIG


def shapes_for_arch(cfg: ModelConfig) -> Sequence[ShapeConfig]:
    """The assigned shape cells for an architecture.

    ``long_500k`` needs sub-quadratic context handling: run it for SSM/hybrid
    families only, skip for pure full-attention archs (noted in DESIGN.md §4).
    """
    names = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.family in ("ssm", "hybrid"):
        names.append("long_500k")
    return [SHAPES[n] for n in names]
