"""musicgen-large [audio] — decoder-only over EnCodec tokens.

48L d_model=2048 32H (GQA kv=32 == MHA) d_ff=8192 vocab=2048
[arXiv:2306.05284; hf]
"""
from repro.configs.base import LoRAConfig, ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    family="audio",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=2048,
    ffn_kind="gelu",
    # EnCodec frontend is a STUB: input_specs() provides pre-computed frame
    # embeddings; the 4 codebooks are modelled as the flat vocab above.
    frontend_stub_dim=2048,
    lora=LoRAConfig(rank=16, targets=("q", "v")),
)
