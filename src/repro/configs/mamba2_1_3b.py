"""mamba2-1.3b [ssm] — SSD (state-space duality), attention-free.

48L d_model=2048 d_ff=0 vocab=50280, ssm_state=128
[arXiv:2405.21060; unverified]

TOM applicability (DESIGN.md §4): the paper's two-phase decode attention (C3)
is inapplicable — there is no attention. Ternary packing (C1), lane-tiled
projections with tree reduction (C2) and ternary QLoRA (C4) apply unchanged.
"""
from repro.configs.base import LoRAConfig, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=0,
    num_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=50280,
    attention_kind="none",
    ssm=SSMConfig(state_size=128, head_dim=64, expand=2, num_groups=1, conv_width=4),
    tie_embeddings=True,
    max_seq_len=1_048_576,
    lora=LoRAConfig(rank=16, targets=("in_proj", "out_proj")),
)
