"""arctic-480b [moe] — 128 experts top-2 + dense residual MLP branch.

35L d_model=7168 56H (GQA kv=8) expert d_ff=4864 vocab=32000, MoE 128e top-2
[hf:Snowflake/snowflake-arctic-base; hf]
"""
from repro.configs.base import LoRAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    num_layers=35,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    head_dim=128,
    d_ff=4864,
    vocab_size=32000,
    ffn_kind="swiglu",
    moe=MoEConfig(
        num_experts=128,
        num_experts_per_tok=2,
        expert_d_ff=4864,
        # Arctic runs a dense residual MLP in parallel with the MoE branch.
        dense_residual_d_ff=4864,
    ),
    lora=LoRAConfig(rank=16, targets=("q", "v")),
)
