"""deepseek-v2-236b [moe] — MLA kv_lora=512, 2 shared + 160 routed top-6.

60L d_model=5120 128H (MLA) expert d_ff=1536 vocab=102400, MoE 160e top-6
[arXiv:2405.04434; hf]
"""
from repro.configs.base import LoRAConfig, MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    num_layers=60,
    d_model=5120,
    num_heads=128,
    num_kv_heads=128,  # MLA: per-head keys are reconstructed from the latent
    head_dim=128,
    d_ff=1536,
    vocab_size=102400,
    attention_kind="mla",
    mla=MLAConfig(
        kv_lora_rank=512,
        q_lora_rank=1536,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
    ),
    ffn_kind="swiglu",
    moe=MoEConfig(
        num_experts=160,
        num_experts_per_tok=6,
        num_shared_experts=2,
        expert_d_ff=1536,
        first_k_dense=1,
        dense_d_ff=12288,
    ),
    lora=LoRAConfig(rank=16, targets=("q", "v")),
)
