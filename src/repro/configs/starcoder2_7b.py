"""starcoder2-7b [dense] — GQA, RoPE, non-gated GELU FFN.

32L d_model=4608 36H (GQA kv=4) d_ff=18432 vocab=49152
[arXiv:2402.19173; hf]
"""
from repro.configs.base import LoRAConfig, ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-7b",
    family="dense",
    num_layers=32,
    d_model=4608,
    num_heads=36,
    num_kv_heads=4,
    head_dim=128,
    d_ff=18432,
    vocab_size=49152,
    ffn_kind="gelu",
    rope_theta=100_000.0,
    lora=LoRAConfig(rank=16, targets=("q", "v")),
)
