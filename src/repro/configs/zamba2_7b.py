"""zamba2-7b [hybrid] — Mamba2 backbone + shared attention blocks.

81L d_model=3584 32H (GQA kv=32) d_ff=14336 vocab=32000, ssm_state=64
[arXiv:2411.15242; unverified]

Block pattern: every 6th block is the SHARED attention+MLP block (one set of
weights reused at every attention position, Zamba2-style); the rest are
Mamba2 blocks.
"""
from repro.configs.base import LoRAConfig, ModelConfig, SSMConfig


def _pattern(n_layers: int, period: int = 6) -> str:
    # m m m m m a | m m m m m a | ...
    return "".join("a" if (i % period) == (period - 1) else "m" for i in range(n_layers))


CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    num_layers=81,
    d_model=3584,
    num_heads=32,
    num_kv_heads=32,
    head_dim=112,
    d_ff=14336,
    vocab_size=32000,
    ffn_kind="gelu",
    ssm=SSMConfig(state_size=64, head_dim=64, expand=2, num_groups=2, conv_width=4),
    block_pattern=_pattern(81),
    shared_attention=True,
    max_seq_len=1_048_576,
    lora=LoRAConfig(rank=16, targets=("q", "v")),
)
