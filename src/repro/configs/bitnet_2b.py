"""bitnet-2b — the paper's own evaluation model (BitNet b1.58 2B4T).

30L d_model=2560 20H (GQA kv=5) d_ff=6912 vocab=128256, ReLU² FFN, ternary
weights trained from scratch. [arXiv:2504.12285]
"""
from repro.configs.base import LoRAConfig, ModelConfig

CONFIG = ModelConfig(
    name="bitnet-2b",
    family="dense",
    num_layers=30,
    d_model=2560,
    num_heads=20,
    num_kv_heads=5,
    head_dim=128,
    d_ff=6912,
    vocab_size=128256,
    ffn_kind="relu2",
    rope_theta=500_000.0,
    tie_embeddings=True,
    lora=LoRAConfig(rank=16, targets=("q", "v")),
)
