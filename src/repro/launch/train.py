"""Training driver: QAT-from-scratch (the way BitNet-2B was made) or QLoRA
on-device tuning on the immutable packed base (C4).

Production posture: sharded params/optimizer over the mesh, fault-tolerant
step execution (runtime/), atomic async checkpoints with exact resume
(data cursor + RNG + step), straggler watchdog, optional cross-pod int8
gradient compression.

CPU-scale usage (the end-to-end example path):

    PYTHONPATH=src python -m repro.launch.train \
        --arch bitnet-2b --preset tiny --steps 200 --batch 8 --seq 256

Cluster usage: same entry point with --mesh data,model extents per pod; the
dry-run (dryrun.py) proves the production mesh compiles for every arch.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
import time
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import checkpoint as ckpt_mod
from repro.configs.base import ModelConfig, ShapeConfig, get_config
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.launch import mesh as mesh_mod
from repro.launch import specs as specs_mod
from repro.launch import steps as steps_mod
from repro.models import sharding as shard_rules
from repro.models.transformer import Model
from repro.optim import AdamW, trainable_mask, warmup_cosine
from repro.runtime.fault import RetryPolicy, StepRunner


# ---------------------------------------------------------------------------
# Presets: reduced configs for CPU end-to-end runs
# ---------------------------------------------------------------------------


def reduce_config(cfg: ModelConfig, preset: str) -> ModelConfig:
    """Shrink an assigned architecture to a CPU-runnable size while keeping
    its family/topology (used by examples and smoke tests)."""
    if preset == "full":
        return cfg
    scale = {"tiny": 8, "small": 4}[preset]
    kw: Dict[str, Any] = dict(
        num_layers=max(2, cfg.num_layers // scale),
        d_model=max(128, cfg.d_model // scale),
        d_ff=max(256, cfg.d_ff // scale) if cfg.d_ff else 0,
        vocab_size=min(cfg.vocab_size, 2048),
        max_seq_len=min(cfg.max_seq_len, 4096),
    )
    if cfg.num_heads:
        kw["num_heads"] = max(2, cfg.num_heads // scale)
        # GQA requires Hq % Hkv == 0: pick the largest divisor of the reduced
        # head count that doesn't exceed the original kv-head count
        kv_cap = max(1, min(cfg.num_kv_heads, kw["num_heads"]))
        kw["num_kv_heads"] = max(d for d in range(1, kv_cap + 1)
                                 if kw["num_heads"] % d == 0)
        kw["head_dim"] = max(32, min(cfg.head_dim, kw["d_model"] // kw["num_heads"]))
    if cfg.moe is not None:
        kw["moe"] = dataclasses.replace(
            cfg.moe,
            num_experts=max(4, cfg.moe.num_experts // scale),
            expert_d_ff=max(64, cfg.moe.expert_d_ff // scale),
            dense_d_ff=max(128, cfg.moe.dense_d_ff // scale) if cfg.moe.dense_d_ff else 0,
            dense_residual_d_ff=max(128, cfg.moe.dense_residual_d_ff // scale)
            if cfg.moe.dense_residual_d_ff else 0,
        )
    if cfg.mla is not None:
        kw["mla"] = dataclasses.replace(
            cfg.mla,
            kv_lora_rank=max(32, cfg.mla.kv_lora_rank // scale),
            q_lora_rank=max(48, cfg.mla.q_lora_rank // scale),
            qk_nope_head_dim=max(16, cfg.mla.qk_nope_head_dim // scale),
            qk_rope_head_dim=max(16, cfg.mla.qk_rope_head_dim // scale),
            v_head_dim=max(16, cfg.mla.v_head_dim // scale),
        )
    if cfg.ssm is not None:
        kw["ssm"] = dataclasses.replace(
            cfg.ssm,
            state_size=max(16, cfg.ssm.state_size // scale),
            head_dim=max(16, cfg.ssm.head_dim // scale),
        )
    if cfg.block_pattern:
        n = kw["num_layers"]
        period = 3
        kw["block_pattern"] = "".join(
            "a" if (i % period) == period - 1 else "m" for i in range(n))
    return cfg.replace(**kw)


# ---------------------------------------------------------------------------
# Trainer
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class TrainConfig:
    arch: str = "bitnet-2b"
    preset: str = "tiny"             # tiny | small | full
    mode: str = "qat"                # qat | qlora
    steps: int = 100                 # TOTAL schedule horizon (cosine anchor)
    stop_after: Optional[int] = None  # preemption point: stop (+ckpt) early
    batch: int = 8
    seq: int = 256
    lr: float = 3e-4
    warmup: int = 20
    seed: int = 0
    mesh_model: int = 1              # model-axis extent on the host mesh
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    resume: bool = True
    log_every: int = 10
    grad_compression: str = "none"   # none | int8  (cross-pod axis)
    data_path: Optional[str] = None  # mmap token file; None → synthetic


class Trainer:
    """Owns the mesh, sharded state, data pipeline, fault handling and the
    checkpoint lifecycle. One class serves the CPU examples and the cluster
    entry point — only the mesh differs."""

    def __init__(self, tc: TrainConfig):
        self.tc = tc
        base = get_config(tc.arch)
        self.cfg = reduce_config(base, tc.preset)
        self.mesh = mesh_mod.make_host_mesh(model=tc.mesh_model)
        shape = ShapeConfig("train", tc.seq, tc.batch, "train")

        self.model = Model(self.cfg, mode=tc.mode,
                           act_shard=steps_mod.act_sharding_for(self.mesh, shape))
        self.opt = AdamW(schedule=warmup_cosine(tc.lr, tc.warmup, tc.steps))

        pspecs = self.model.param_specs()
        self.p_shard = specs_mod.named(
            self.mesh,
            shard_rules.param_spec_tree(pspecs, self.mesh, mode=tc.mode, fsdp=True))

        if tc.mode == "qlora":
            # optimizer state exists only for the adapter leaves — the packed
            # ROM base is frozen (C4) and carries no moments at all.
            from repro.optim import partition
            self.mask = trainable_mask(pspecs, "qlora")
            train_specs, _ = partition(pspecs, self.mask)
            train_shard, _ = partition(self.p_shard, self.mask)
            _, self.o_shard = steps_mod._moment_shardings(
                train_specs, train_shard, self.opt, self.mesh)
            step = steps_mod.make_qlora_step(self.model, self.opt, self.mask)
        else:
            self.mask = None
            _, self.o_shard = steps_mod._moment_shardings(pspecs, self.p_shard,
                                                          self.opt, self.mesh)
            step = steps_mod.make_train_step(self.model, self.opt)
        batch_tree = specs_mod.train_inputs(self.cfg, shape)
        b_shard = specs_mod.batch_shardings(self.cfg, shape, self.mesh, batch_tree)
        self.step_fn = jax.jit(step,
                               in_shardings=(self.p_shard, self.o_shard, b_shard),
                               out_shardings=(self.p_shard, self.o_shard, None),
                               donate_argnums=(0, 1))

        self.data = TokenPipeline(DataConfig(
            vocab_size=self.cfg.vocab_size, batch=tc.batch, seq=tc.seq,
            seed=tc.seed, path=tc.data_path))
        self.runner = StepRunner(RetryPolicy())
        self.step = 0
        self._init_state()

    # -- state ---------------------------------------------------------------
    def _init_state(self):
        tc = self.tc
        with self.mesh:
            init = jax.jit(self.model.init, out_shardings=self.p_shard)
            self.params = init(jax.random.PRNGKey(tc.seed))
            if self.mask is not None:
                from repro.optim import partition
                opt_over, _ = partition(self.params, self.mask)
            else:
                opt_over = self.params
            self.opt_state = jax.jit(self.opt.init,
                                     out_shardings=self.o_shard)(opt_over)
        if tc.ckpt_dir and tc.resume:
            latest = ckpt_mod.latest_step(tc.ckpt_dir)
            if latest is not None:
                self.restore(latest)

    # -- checkpoint ------------------------------------------------------------
    def save(self, block: bool = False):
        if not self.tc.ckpt_dir:
            return
        state = {"params": self.params, "opt_state": self.opt_state}
        meta = {"step": self.step, "data_cursor": self.data.cursor,
                "arch": self.tc.arch, "preset": self.tc.preset}
        ckpt_mod.save(self.tc.ckpt_dir, self.step, state, meta, async_=not block)

    def restore(self, step: int):
        state = {"params": self.params, "opt_state": self.opt_state}
        state, meta = ckpt_mod.restore(self.tc.ckpt_dir, step, state,
                                       mesh=self.mesh)
        self.params, self.opt_state = state["params"], state["opt_state"]
        self.step = meta["step"]
        self.data.seek(meta["data_cursor"])
        print(f"[train] resumed from step {self.step}")

    # -- loop -------------------------------------------------------------------
    def run(self) -> Dict[str, float]:
        tc = self.tc
        last = {}
        t0 = time.time()
        stop_at = min(tc.steps, tc.stop_after or tc.steps)
        while self.step < stop_at:
            if self.runner.preemption.should_stop:
                print(f"[train] preemption at step {self.step}; checkpointing")
                break
            batch = self.data.next()

            def do_step():
                return self.step_fn(self.params, self.opt_state, batch)

            self.params, self.opt_state, metrics = self.runner.run(do_step)
            self.step += 1
            if self.step % tc.log_every == 0 or self.step == tc.steps:
                last = {k: float(v) for k, v in metrics.items()}
                dt = time.time() - t0
                tok_s = tc.batch * tc.seq * tc.log_every / max(dt, 1e-9)
                print(f"[train] step {self.step:5d} "
                      f"loss {last.get('ce_loss', last.get('loss', 0)):.4f} "
                      f"gnorm {last.get('grad_norm', 0):.3f} "
                      f"lr {last.get('lr', 0):.2e} "
                      f"| {tok_s:,.0f} tok/s")
                sys.stdout.flush()
                t0 = time.time()
            if tc.ckpt_dir and self.step % tc.ckpt_every == 0:
                self.save()
        self.save(block=True)
        ckpt_mod.wait_pending()
        return last


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="bitnet-2b")
    ap.add_argument("--preset", default="tiny", choices=("tiny", "small", "full"))
    ap.add_argument("--mode", default="qat", choices=("qat", "qlora"))
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--mesh-model", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--no-resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--data", default=None)
    args = ap.parse_args(argv)

    tc = TrainConfig(arch=args.arch, preset=args.preset, mode=args.mode,
                     steps=args.steps, batch=args.batch, seq=args.seq,
                     lr=args.lr, mesh_model=args.mesh_model,
                     ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                     resume=not args.no_resume, seed=args.seed,
                     data_path=args.data)
    trainer = Trainer(tc)
    final = trainer.run()
    print("[train] done:", json.dumps(final))
    return 0


if __name__ == "__main__":
    sys.exit(main())
