"""Launch layer: production mesh, dry-run, train/serve drivers.

NOTE: dryrun must be executed as `python -m repro.launch.dryrun` so its
XLA_FLAGS line runs before jax initializes; do not import it from here.
"""
