"""Production mesh construction.

The target machine is a TPU v5e pod: 256 chips arranged (data=16, model=16),
multi-pod = 2 pods = 512 chips with a leading "pod" axis. ``model`` is the
paper's Processing-Lane axis (16 lanes, Table I); ``data``(×``pod``) is
batch parallelism; the cross-pod axis composes with ``data`` for the
hierarchical gradient reduction.

Functions, not module constants — importing this module must never touch
jax device state (the dry-run sets XLA_FLAGS before first jax init).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import Mesh

# Hardware constants (TPU v5e; used by the roofline analysis)
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link (~per-direction)
HBM_BYTES = 16 * 1024 ** 3      # 16 GiB per chip
SINGLE_POD = (16, 16)
MULTI_POD = (2, 16, 16)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = MULTI_POD if multi_pod else SINGLE_POD
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]) -> Mesh:
    """Arbitrary mesh (elastic re-mesh / tests use small shapes)."""
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: Optional[int] = None) -> Mesh:
    """Whatever devices exist right now, as (data, model) — used by tests,
    examples and the CPU end-to-end drivers."""
    n = len(jax.devices())
    model = model or 1
    assert n % model == 0, (n, model)
    return jax.make_mesh((n // model, model), ("data", "model"))


def mesh_chips(mesh: Mesh) -> int:
    return mesh.devices.size


def dp_size(mesh: Mesh) -> int:
    s = 1
    for name in ("pod", "data", "replica"):
        if name in mesh.axis_names:
            s *= mesh.shape[name]
    return s


def tp_size(mesh: Mesh) -> int:
    return mesh.shape.get("model", 1)
