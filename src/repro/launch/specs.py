"""ShapeDtypeStruct input stand-ins + sharding trees per (arch × shape) cell.

This is the shannon/kernels pattern: every model input is described as a
``jax.ShapeDtypeStruct`` (weak-type-correct, shardable, zero allocation) so
``dryrun.py`` can ``.lower().compile()`` the full production configuration on
placeholder devices.

Cell kinds (configs/base.SHAPES):
  * ``train``   → ``train_step``  inputs: params, opt_state, batch
  * ``prefill`` → ``prefill_step`` inputs: params, batch (tokens/embeds)
  * ``decode``  → ``serve_step``  inputs: params, cache, token, pos
    (one new token against a seq_len-deep KV cache — NOT a full forward)

``[vlm]``/``[audio]`` archs take precomputed patch/frame embeddings from the
stub frontend (``embeds`` instead of ``tokens``), per the assignment.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import sharding as shard_rules
from repro.models.transformer import Model

Tree = Any


# ---------------------------------------------------------------------------
# batch-axis helper: shard batch over dp only when it divides evenly
# (long_500k has global_batch=1 → replicated)
# ---------------------------------------------------------------------------


def batch_axis(mesh: Mesh, global_batch: int):
    dp = shard_rules.logical_to_mesh_axes(mesh)["dp"]
    if dp is None:
        return None
    names = dp if isinstance(dp, tuple) else (dp,)
    size = 1
    for n in names:
        size *= mesh.shape[n]
    return dp if global_batch % size == 0 and global_batch >= size else None


# ---------------------------------------------------------------------------
# Input specs
# ---------------------------------------------------------------------------


def _uses_stub_frontend(cfg: ModelConfig) -> bool:
    return cfg.family in ("vlm", "audio")


def train_inputs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, jax.ShapeDtypeStruct]:
    b, s = shape.global_batch, shape.seq_len
    batch: Dict[str, jax.ShapeDtypeStruct] = {
        "labels": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    if _uses_stub_frontend(cfg):
        batch["embeds"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.bfloat16)
    else:
        batch["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
    return batch


def prefill_inputs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, jax.ShapeDtypeStruct]:
    b, s = shape.global_batch, shape.seq_len
    if _uses_stub_frontend(cfg):
        return {"embeds": jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.bfloat16)}
    return {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}


def decode_inputs(cfg: ModelConfig, shape: ShapeConfig
                  ) -> Tuple[jax.ShapeDtypeStruct, jax.ShapeDtypeStruct]:
    """(token, pos) for one serve step; the cache spec comes from the model."""
    b = shape.global_batch
    if _uses_stub_frontend(cfg):
        tok = jax.ShapeDtypeStruct((b, cfg.d_model), jnp.bfloat16)
    else:
        tok = jax.ShapeDtypeStruct((b,), jnp.int32)
    return tok, jax.ShapeDtypeStruct((), jnp.int32)


def batch_shardings(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                    batch_tree: Tree) -> Tree:
    dp = batch_axis(mesh, shape.global_batch)

    def spec(path_leaf):
        nd = len(path_leaf.shape)
        return NamedSharding(mesh, P(dp, *([None] * (nd - 1))))

    return jax.tree.map(spec, batch_tree)


# ---------------------------------------------------------------------------
# Cell assembly: everything dryrun/train/serve need for one (arch, shape)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Cell:
    cfg: ModelConfig
    shape: ShapeConfig
    mesh: Mesh
    model: Model
    fn: Any                  # the jit-able step function
    args: Tuple[Any, ...]    # ShapeDtypeStructs (or spec trees)
    in_shardings: Tuple[Any, ...]
    out_shardings: Any
    donate_argnums: Tuple[int, ...] = ()
    static_argnums: Tuple[int, ...] = ()

    def lower(self):
        jitted = jax.jit(self.fn,
                         in_shardings=self.in_shardings,
                         out_shardings=self.out_shardings,
                         donate_argnums=self.donate_argnums,
                         static_argnums=self.static_argnums)
        return jitted.lower(*self.args)


def replicated(mesh: Mesh, tree: Tree) -> Tree:
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)


def named(mesh: Mesh, spec_tree: Tree) -> Tree:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
