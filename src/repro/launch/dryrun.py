import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ^ MUST precede every other import (jax locks device count on first init).
"""Multi-pod dry-run: lower + compile every (arch × shape) cell on the
production mesh, record memory/cost/collective artifacts for §Roofline.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-1.7b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all            # 32 cells, 1 pod
    PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod
    PYTHONPATH=src python -m repro.launch.dryrun --all --both     # 64 compile checks

Artifacts land in artifacts/dryrun/<arch>__<shape>__<mesh>[__<strategy>].json
with per-device bytes, HLO FLOPs/bytes, and per-collective byte counts — the
roofline analysis (benchmarks/roofline.py) and EXPERIMENTS.md read them.
"""
import argparse
import json
import sys
import time
import traceback
from pathlib import Path

import jax

from repro.configs.base import ARCH_IDS, SHAPES, get_config, shapes_for_arch
from repro.launch import hlo_analysis
from repro.launch import mesh as mesh_mod
from repro.launch import steps as steps_mod

ARTIFACT_DIR = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"


# ---------------------------------------------------------------------------
# One cell
# ---------------------------------------------------------------------------


def run_cell(arch: str, shape_name: str, multi_pod: bool, *,
             strategy: str = "paper_tree", moe_sharding: str = "tp",
             seq_shard: bool = True, head_shard: bool = False,
             fuse_proj: bool = False, kv_widen: str = "f32",
             save: bool = True, verbose: bool = True, tag: str = "") -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = mesh_mod.make_production_mesh(multi_pod=multi_pod)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"

    t0 = time.time()
    cell = steps_mod.build_cell(cfg, shape, mesh, strategy=strategy,
                                moe_sharding=moe_sharding, seq_shard=seq_shard,
                                head_shard=head_shard, fuse_proj=fuse_proj,
                                kv_widen=kv_widen)
    lowered = cell.lower()
    t_lower = time.time() - t0

    t1 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t1

    # Collectives only exist after SPMD partitioning, and scan bodies must be
    # weighted by their trip counts → structural analysis of the compiled HLO
    # (hlo_analysis.py), not raw cost_analysis() (which counts loops once).
    hstats = hlo_analysis.analyze(compiled.as_text())

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    n_dev = mesh.devices.size

    result = {
        "arch": arch,
        "shape": shape_name,
        "kind": shape.kind,
        "mesh": mesh_name,
        "n_devices": n_dev,
        "strategy": strategy,
        "moe_sharding": moe_sharding,
        "seq_shard": seq_shard,
        # per-device totals (loop-weighted structural analysis)
        "flops": hstats["flops"],
        "bytes_accessed": hstats["bytes"],
        "collectives": hstats["collectives"],
        "collective_payload_bytes": hstats["collective_payload_bytes"],
        "collective_wire_bytes": hstats["collective_wire_bytes"],
        # raw XLA numbers (loop bodies counted once — cross-check only)
        "xla_flops_once": float(cost.get("flops", 0.0)),
        "xla_bytes_once": float(cost.get("bytes accessed", 0.0)),
        "hbm_bytes_per_device": {
            "argument": getattr(mem, "argument_size_in_bytes", 0),
            "output": getattr(mem, "output_size_in_bytes", 0),
            "temp": getattr(mem, "temp_size_in_bytes", 0),
            "peak": (getattr(mem, "argument_size_in_bytes", 0)
                     + getattr(mem, "temp_size_in_bytes", 0)),
        },
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "params": cfg.param_count(),
        "params_active": cfg.param_count(active_only=True),
    }

    if save:
        ARTIFACT_DIR.mkdir(parents=True, exist_ok=True)
        suffix = f"__{tag}" if tag else ""
        path = ARTIFACT_DIR / f"{arch}__{shape_name}__{mesh_name}{suffix}.json"
        path.write_text(json.dumps(result, indent=1))
        result["artifact"] = str(path)

    if verbose:
        arg_gb = result["hbm_bytes_per_device"]["argument"] / 2 ** 30
        tmp_gb = result["hbm_bytes_per_device"]["temp"] / 2 ** 30
        print(f"[dryrun] {arch} × {shape_name} × {mesh_name} ({strategy}) OK "
              f"| lower {t_lower:.1f}s compile {t_compile:.1f}s "
              f"| args {arg_gb:.2f} GiB + temp {tmp_gb:.2f} GiB /device "
              f"| {result['flops']:.3e} FLOPs "
              f"| coll wire {result['collective_wire_bytes'] / 2 ** 30:.3f} GiB")
        sys.stdout.flush()
    return result


def iter_cells():
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in shapes_for_arch(cfg):
            yield arch, shape.name


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both", action="store_true", help="run 1-pod AND 2-pod")
    ap.add_argument("--strategy", default="paper_tree",
                    choices=("paper_tree", "megatron"))
    ap.add_argument("--moe-sharding", default="tp",
                    choices=("tp", "ep", "megatron"))
    ap.add_argument("--head-shard", action="store_true")
    ap.add_argument("--fuse-proj", action="store_true")
    ap.add_argument("--kv-widen", default="f32", choices=("f32", "bf16"))
    ap.add_argument("--no-seq-shard", action="store_true")
    ap.add_argument("--tag", default="", help="artifact filename suffix")
    ap.add_argument("--continue-on-error", action="store_true")
    args = ap.parse_args()

    cells = list(iter_cells()) if args.all else [(args.arch, args.shape)]
    pods = [False, True] if args.both else [args.multi_pod]

    failures = []
    for arch, shape in cells:
        for mp in pods:
            try:
                run_cell(arch, shape, mp, strategy=args.strategy,
                         moe_sharding=args.moe_sharding,
                         seq_shard=not args.no_seq_shard,
                         head_shard=args.head_shard, fuse_proj=args.fuse_proj,
                         kv_widen=args.kv_widen, tag=args.tag)
            except Exception as e:  # noqa: BLE001 — report, continue
                failures.append((arch, shape, mp, repr(e)))
                print(f"[dryrun] {arch} × {shape} × multi_pod={mp} FAILED: {e}")
                if not args.continue_on_error:
                    traceback.print_exc()
                    return 1
    if failures:
        print(f"[dryrun] {len(failures)} failures:")
        for f in failures:
            print("   ", *f)
        return 1
    print(f"[dryrun] all {len(cells) * len(pods)} cells compiled clean.")
    return 0


if __name__ == "__main__":
    sys.exit(main())
