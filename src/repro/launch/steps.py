"""Step functions (train / prefill / serve-decode) + their Cell assembly.

These are the exact functions the dry-run lowers against the production mesh
and the train/serve drivers execute on real devices. One definition serves
both paths so the dry-run proves precisely what would run.

Paper mapping: ``strategy="paper_tree"`` lays every linear out per Fig 7(a)
(K over lanes, reduction-tree psum); the serve decode step's context-sharded
KV + stable softmax lowers to the paper's two-phase tree dataflow (C3).
``strategy="megatron"`` is the beyond-paper §Perf variant.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.launch import specs as specs_mod
from repro.launch.specs import Cell
from repro.models import sharding as shard_rules
from repro.models.transformer import Model
from repro.optim import AdamW, warmup_cosine

Tree = Any


# ---------------------------------------------------------------------------
# Step factories
# ---------------------------------------------------------------------------


def make_train_step(model: Model, opt: AdamW):
    """(params, opt_state, batch) → (params, opt_state, metrics).

    qat mode: every leaf is float and trainable (BitNet training-from-scratch
    with STE fake-quant inside the layers)."""

    def train_step(params, opt_state, batch):
        (loss, aux), grads = jax.value_and_grad(model.loss_fn, has_aux=True)(
            params, batch)
        params, opt_state, opt_metrics = opt.update(grads, opt_state, params)
        metrics = {"loss": loss, **aux, **opt_metrics}
        return params, opt_state, metrics

    return train_step


def make_qlora_step(model: Model, opt: AdamW, mask: Tree):
    """QLoRA on-device tuning step (C4): the packed ROM base is frozen —
    autodiff runs over the adapter (+norm) leaves only."""
    from repro.optim import combine, partition

    def qlora_step(params, opt_state, batch):
        train_p, frozen_p = partition(params, mask)

        def loss_fn(tp):
            return model.loss_fn(combine(tp, frozen_p), batch)

        (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(train_p)
        train_p, opt_state, opt_metrics = opt.update(grads, opt_state, train_p,
                                                     mask=None)
        params = combine(train_p, frozen_p)
        metrics = {"loss": loss, **aux, **opt_metrics}
        return params, opt_state, metrics

    return qlora_step


def make_prefill_step(model: Model, max_len: int):
    def prefill_step(params, batch):
        return model.prefill(params, batch, max_len)

    return prefill_step


def make_decode_step(model: Model):
    """One new token for the whole batch against the existing KV cache —
    what ``decode_*`` / ``long_*`` cells lower (serve_step, not train_step)."""

    def decode_step(params, cache, token, pos):
        logits, cache = model.decode_step(params, cache, token, pos)
        return logits, cache

    return decode_step


def make_greedy_decode_step(model: Model):
    def step(params, cache, token, pos):
        logits, cache = model.decode_step(params, cache, token, pos)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache

    return step


# ---------------------------------------------------------------------------
# Sharding assembly per cell
# ---------------------------------------------------------------------------


def act_sharding_for(mesh: Mesh, shape: ShapeConfig, seq_shard: bool = True):
    """Residual-stream sharding: batch over dp, sequence over model (SP)."""
    dp = specs_mod.batch_axis(mesh, shape.global_batch)
    sp = "model" if (seq_shard and shape.seq_len % mesh.shape.get("model", 1) == 0) \
        else None
    return NamedSharding(mesh, P(dp, sp, None))


def head_sharding_for(mesh: Mesh, shape: ShapeConfig):
    """(B, S, H, D) attention-tensor sharding: heads over lanes (§Perf A).
    act_sharding.constrain() skips it per-tensor when H % lanes != 0."""
    dp = specs_mod.batch_axis(mesh, shape.global_batch)
    return NamedSharding(mesh, P(dp, None, "model", None))


def _moment_shardings(params_specs, params_shardings, opt, mesh):
    """Optimizer moments inherit the parameter sharding (ZeRO-for-free with
    2-D sharded weights); scalar placeholders for frozen leaves replicate."""
    state_specs = opt.state_specs(params_specs)

    def fix(mspec, pshard):
        if mspec.shape == ():
            return NamedSharding(mesh, P())
        return pshard

    m = jax.tree.map(fix, state_specs.m, params_shardings)
    v = jax.tree.map(fix, state_specs.v, params_shardings)
    return state_specs, type(state_specs)(step=NamedSharding(mesh, P()), m=m, v=v)


# ---------------------------------------------------------------------------
# Cell builders (used by dryrun.py and by the real drivers)
# ---------------------------------------------------------------------------


def build_cell(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh, *,
               strategy: str = "paper_tree",
               moe_sharding: str = "tp",
               seq_shard: bool = True,
               head_shard: bool = False,
               fuse_proj: bool = False,
               kv_widen: str = "f32",
               remat: bool = True) -> Cell:
    if shape.kind == "train":
        return build_train_cell(cfg, shape, mesh, strategy=strategy,
                                moe_sharding=moe_sharding, seq_shard=seq_shard,
                                head_shard=head_shard, remat=remat)
    if shape.kind == "prefill":
        return build_prefill_cell(cfg, shape, mesh, strategy=strategy,
                                  moe_sharding=moe_sharding, seq_shard=seq_shard,
                                  head_shard=head_shard, remat=remat)
    return build_decode_cell(cfg, shape, mesh, strategy=strategy,
                             moe_sharding=moe_sharding, fuse_proj=fuse_proj,
                             kv_widen=kv_widen)


def build_train_cell(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh, *,
                     strategy: str = "paper_tree", moe_sharding: str = "tp",
                     seq_shard: bool = True, head_shard: bool = False,
                     remat: bool = True) -> Cell:
    model = Model(cfg, mode="qat", remat=remat,
                  act_shard=act_sharding_for(mesh, shape, seq_shard),
                  head_shard=head_sharding_for(mesh, shape) if head_shard else None)
    opt = AdamW(schedule=warmup_cosine(3e-4, 1000, 100_000))

    params_specs = model.param_specs()
    p_shard = specs_mod.named(
        mesh, shard_rules.param_spec_tree(params_specs, mesh, strategy=strategy,
                                          mode="qat", fsdp=True,
                                          moe_sharding=moe_sharding))
    opt_specs, opt_shard = _moment_shardings(params_specs, p_shard, opt, mesh)

    batch = specs_mod.train_inputs(cfg, shape)
    b_shard = specs_mod.batch_shardings(cfg, shape, mesh, batch)

    metrics_shard = None  # replicated scalars; let jit infer
    step = make_train_step(model, opt)
    return Cell(
        cfg=cfg, shape=shape, mesh=mesh, model=model, fn=step,
        args=(params_specs, opt_specs, batch),
        in_shardings=(p_shard, opt_shard, b_shard),
        out_shardings=(p_shard, opt_shard, metrics_shard),
        donate_argnums=(0, 1),
    )


def build_prefill_cell(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh, *,
                       strategy: str = "paper_tree", moe_sharding: str = "tp",
                       seq_shard: bool = True, head_shard: bool = False,
                       remat: bool = False) -> Cell:
    model = Model(cfg, mode="serve", remat=remat,
                  act_shard=act_sharding_for(mesh, shape, seq_shard),
                  head_shard=head_sharding_for(mesh, shape) if head_shard else None)
    params_specs = model.param_specs()
    p_shard = specs_mod.named(
        mesh, shard_rules.param_spec_tree(params_specs, mesh, strategy=strategy,
                                          mode="serve", fsdp=False,
                                          moe_sharding=moe_sharding))
    batch = specs_mod.prefill_inputs(cfg, shape)
    b_shard = specs_mod.batch_shardings(cfg, shape, mesh, batch)

    cache_specs = model.cache_specs(shape.global_batch, shape.seq_len)
    c_shard = _cache_shardings(cache_specs, mesh, shape)
    dp = specs_mod.batch_axis(mesh, shape.global_batch)
    logits_shard = NamedSharding(mesh, P(dp, None))

    step = make_prefill_step(model, shape.seq_len)
    return Cell(
        cfg=cfg, shape=shape, mesh=mesh, model=model, fn=step,
        args=(params_specs, batch),
        in_shardings=(p_shard, b_shard),
        out_shardings=(logits_shard, c_shard),
    )


def build_decode_cell(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh, *,
                      strategy: str = "paper_tree", moe_sharding: str = "tp",
                      fuse_proj: bool = False, kv_widen: str = "f32",
                      ) -> Cell:
    model = Model(cfg, mode="serve", remat=False, fuse_proj=fuse_proj,
                  kv_widen=kv_widen)
    params_specs = model.param_specs()
    p_shard = specs_mod.named(
        mesh, shard_rules.param_spec_tree(params_specs, mesh, strategy=strategy,
                                          mode="serve", fsdp=False,
                                          moe_sharding=moe_sharding))
    cache_specs = model.cache_specs(shape.global_batch, shape.seq_len)
    c_shard = _cache_shardings(cache_specs, mesh, shape)

    token, pos = specs_mod.decode_inputs(cfg, shape)
    dp = specs_mod.batch_axis(mesh, shape.global_batch)
    tok_shard = NamedSharding(mesh, P(dp, *([None] * (len(token.shape) - 1))))
    pos_shard = NamedSharding(mesh, P())
    logits_shard = NamedSharding(mesh, P(dp, None))

    step = make_decode_step(model)
    return Cell(
        cfg=cfg, shape=shape, mesh=mesh, model=model, fn=step,
        args=(params_specs, cache_specs, token, pos),
        in_shardings=(p_shard, c_shard, tok_shard, pos_shard),
        out_shardings=(logits_shard, c_shard),
        donate_argnums=(1,),
    )


def _is_dp_part(p) -> bool:
    names = p if isinstance(p, tuple) else (p,)
    return all(n in ("pod", "data", "replica") for n in names)


def _cache_shardings(cache_specs, mesh: Mesh, shape: ShapeConfig):
    tree = shard_rules.kv_cache_spec_tree(cache_specs, mesh)
    dp = specs_mod.batch_axis(mesh, shape.global_batch)

    # kv_cache_spec_tree puts dp on the batch dim unconditionally; strip it
    # when the cell's batch doesn't divide the dp extent (long_500k, B=1).
    def fix(spec):
        if dp is None:
            parts = tuple(None if (p is not None and _is_dp_part(p)) else p
                          for p in spec)
            return NamedSharding(mesh, P(*parts))
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(fix, tree, is_leaf=lambda x: isinstance(x, P))
