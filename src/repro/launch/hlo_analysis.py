"""Structural cost analysis of post-partitioning HLO text.

Why this exists: ``compiled.cost_analysis()`` visits every computation ONCE —
a ``lax.scan`` over 88 layers reports 1/88th of the real FLOPs/bytes, and
collectives inside the loop body are similarly undercounted. Since the whole
framework leans on scan-over-layers (compact HLO, weight prefetch overlap),
the roofline instrument must multiply loop bodies by their trip counts.

The parser builds the computation call graph from the HLO text
(`body=`/`condition=` for whiles — with ``known_trip_count`` from the backend
config —, `calls=` for fusions, `to_apply=` for reduces, branch lists for
conditionals), assigns each computation an execution multiplier, and sums:

  * ``flops``            — dot ops: 2 · numel(out) · contract_size; plus
                           1 flop/output element for elementwise/reduce ops.
  * ``bytes``            — HBM traffic proxy: Σ over *top-level* ops (entry +
                           while bodies, × multiplier) of operand + output
                           bytes. Fusion internals are excluded — a fusion
                           reads its operands and writes its output once,
                           which is XLA's own fusion bytes_accessed model.
  * ``collectives``      — per-kind payload bytes & op records (× multiplier)
                           with replica-group extents for wire-byte modeling.

Validated against unrolled references in tests/test_hlo_analysis.py.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "c64": 8, "f32": 4, "bf16": 2, "f16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "s4": 0.5, "u4": 0.5, "token": 0,
}

COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                    "collective-permute", "collective-broadcast")

# ops that move no data / are metadata-only
_FREE_OPS = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
             "after-all", "partition-id", "replica-id", "iota", "custom-call"}

_SHAPE_TOK = re.compile(r"(\w+)\[([0-9,]*)\](?:\{[^}]*\})?")
_NAME_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*")
_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->")


def _split_def(line: str):
    """'%name = <shape> <op>(<args>)…' → (name, shape_text, op_kind) or None.

    Tuple shapes contain nested parens and '/*index=N*/' comments, so the
    shape prefix is taken by balanced-paren scan, not regex."""
    m = _NAME_RE.match(line)
    if not m:
        return None
    name = m.group(1)
    rhs = line[m.end():]
    if rhs.startswith("("):
        depth = 0
        end = -1
        for i, ch in enumerate(rhs):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        if end < 0:
            return None
        shape, rest = rhs[:end + 1], rhs[end + 1:].lstrip()
    else:
        sp = rhs.find(" ")
        if sp < 0:
            return None
        shape, rest = rhs[:sp], rhs[sp + 1:].lstrip()
    om = re.match(r"([\w\-]+)\(", rest)
    if not om:
        return None
    return name, shape, om.group(1)
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_TRIP_RE = re.compile(r'known_trip_count["\\]*:\s*\{["\\]*n["\\]*:["\\]*(\d+)')
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")


def _numel(dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


def _shape_bytes(shape_str: str) -> float:
    """Bytes of possibly-tuple shape text like '(f32[8,64], u8[4])' or
    'bf16[128,512]{1,0}'."""
    total = 0.0
    for m in _SHAPE_TOK.finditer(shape_str):
        dt = m.group(1)
        if dt in _DTYPE_BYTES:
            total += _numel(m.group(2)) * _DTYPE_BYTES[dt]
    return total


def _first_shape(shape_str: str) -> Optional[Tuple[str, List[int]]]:
    m = _SHAPE_TOK.search(shape_str)
    if not m:
        return None
    dims = [int(d) for d in m.group(2).split(",") if d]
    return m.group(1), dims


@dataclasses.dataclass
class Op:
    name: str
    kind: str
    out_shape: str          # raw text before the op name
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    is_entry: bool
    ops: List[Op]


def parse_computations(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in hlo.splitlines():
        if not raw.strip():
            continue
        if not raw.startswith(" ") and (hdr := _COMP_HDR.match(raw)):
            cur = Computation(hdr.group(2), bool(hdr.group(1)), [])
            comps[cur.name] = cur
            continue
        if raw.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        d = _split_def(raw)
        if d:
            cur.ops.append(Op(d[0], d[2], d[1], raw.strip()))
    return comps


def _call_edges(op: Op) -> List[Tuple[str, float]]:
    """(callee, per-call multiplicity) pairs for one op."""
    edges: List[Tuple[str, float]] = []
    s = op.line
    if op.kind == "while":
        trip = 1.0
        if (t := _TRIP_RE.search(s)):
            trip = float(t.group(1))
        if (b := re.search(r"body=%?([\w.\-]+)", s)):
            edges.append((b.group(1), trip))
        if (c := re.search(r"condition=%?([\w.\-]+)", s)):
            edges.append((c.group(1), trip + 1))
    elif op.kind in ("fusion", "call", "map", "reduce", "reduce-window",
                     "scatter", "sort", "select-and-scatter", "all-reduce",
                     "reduce-scatter"):
        for attr in ("calls", "to_apply"):
            if (m := re.search(attr + r"=%?([\w.\-]+)", s)):
                edges.append((m.group(1), 1.0))
    elif op.kind == "conditional":
        if (m := re.search(r"branch_computations=\{([^}]*)\}", s)):
            for name in _OPERAND_RE.findall(m.group(1)):
                edges.append((name, 1.0))
        for attr in ("true_computation", "false_computation"):
            if (m := re.search(attr + r"=%?([\w.\-]+)", s)):
                edges.append((m.group(1), 1.0))
    return edges


def compute_multipliers(comps: Dict[str, Computation]) -> Dict[str, float]:
    entry = next((c.name for c in comps.values() if c.is_entry), None)
    if entry is None:  # single unnamed computation
        entry = next(iter(comps))
    mult: Dict[str, float] = {name: 0.0 for name in comps}
    mult[entry] = 1.0
    # topological-ish fixed point (call graph is a DAG in HLO)
    changed = True
    guard = 0
    while changed and guard < 100:
        changed = False
        guard += 1
        for name, comp in comps.items():
            m = mult.get(name, 0.0)
            if m == 0.0:
                continue
            for op in comp.ops:
                for callee, k in _call_edges(op):
                    if callee in mult:
                        want = m * k
                        if mult[callee] < want:
                            mult[callee] = want
                            changed = True
    return mult


# ---------------------------------------------------------------------------
# FLOPs
# ---------------------------------------------------------------------------

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "and", "or", "xor", "negate", "abs", "exponential", "exponential-minus-one",
    "log", "log-plus-one", "rsqrt", "sqrt", "tanh", "logistic", "sine",
    "cosine", "select", "clamp", "compare", "floor", "ceil", "round-nearest-afz",
    "round-nearest-even", "sign", "remainder", "atan2", "cbrt", "erf",
}
_REDUCTION = {"reduce", "reduce-window"}


def _dot_flops(op: Op, shapes: Dict[str, str]) -> float:
    out = _first_shape(op.out_shape)
    if out is None:
        return 0.0
    out_numel = 1
    for d in out[1]:
        out_numel *= d
    # contract size from lhs operand shape + lhs_contracting_dims
    mdims = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.line)
    inner = re.search(r"\(([^)]*)\)", op.line)
    contract = 1
    if mdims and inner:
        lhs_tok = inner.group(1).split(",")[0]
        lhs_shape = _first_shape(lhs_tok)
        if lhs_shape is None:  # operand printed as %name only
            ops_in = _OPERAND_RE.findall(inner.group(1))
            if ops_in and ops_in[0] in shapes:
                lhs_shape = _first_shape(shapes[ops_in[0]])
        if lhs_shape:
            for i in (int(x) for x in mdims.group(1).split(",") if x):
                if i < len(lhs_shape[1]):
                    contract *= lhs_shape[1][i]
    return 2.0 * out_numel * contract


def _op_flops(op: Op, shapes: Dict[str, str]) -> float:
    if op.kind == "dot":
        return _dot_flops(op, shapes)
    if op.kind == "convolution":
        # not used by these models; approximate via output numel only
        out = _first_shape(op.out_shape)
        return float(0 if out is None else _numel(",".join(map(str, out[1]))))
    if op.kind in _ELEMENTWISE or op.kind in _REDUCTION:
        out = _first_shape(op.out_shape)
        if out is None:
            return 0.0
        n = 1
        for d in out[1]:
            n *= d
        return float(n)
    return 0.0


# ---------------------------------------------------------------------------
# Bytes (HBM traffic proxy)
# ---------------------------------------------------------------------------


def _shape_bytes_scan_aware(shape_str: str, trip: int) -> float:
    """Like _shape_bytes, but inside a while body with known trip count,
    arrays whose LEADING dim equals the trip count are the scan-stacked
    operands (layer-stacked weights / caches): each iteration touches one
    slice, so charge 1/trip of the full shape. This is the dynamic-slice /
    dynamic-update-slice in-place traffic model for scan-over-layers."""
    total = 0.0
    for m in _SHAPE_TOK.finditer(shape_str):
        dt = m.group(1)
        if dt not in _DTYPE_BYTES:
            continue
        dims = [int(d) for d in m.group(2).split(",") if d]
        n = 1
        for d in dims:
            n *= d
        b = n * _DTYPE_BYTES[dt]
        if trip > 1 and dims and dims[0] == trip:
            b /= trip
        total += b
    return total


def _op_bytes(op: Op, shapes: Dict[str, str], trip: int = 0) -> float:
    if op.kind in _FREE_OPS or op.kind in ("while", "conditional", "call"):
        # loop/branch bodies are counted separately; the op itself is a
        # carry pass-through, not HBM traffic
        return 0.0
    total = _shape_bytes_scan_aware(op.out_shape, trip)
    inner = re.search(r"\((.*?)\)(,|$| )", op.line)
    if inner:
        seen = set()
        for name in _OPERAND_RE.findall(inner.group(1)):
            if name in shapes and name not in seen:
                seen.add(name)
                total += _shape_bytes_scan_aware(shapes[name], trip)
    return total


# ---------------------------------------------------------------------------
# Public API
# ---------------------------------------------------------------------------


def body_trip_counts(comps: Dict[str, Computation]) -> Dict[str, int]:
    """while-body computation name → its trip count."""
    trips: Dict[str, int] = {}
    for comp in comps.values():
        for op in comp.ops:
            if op.kind == "while":
                t = 1
                if (m := _TRIP_RE.search(op.line)):
                    t = int(m.group(1))
                if (b := re.search(r"body=%?([\w.\-]+)", op.line)):
                    trips[b.group(1)] = max(trips.get(b.group(1), 0), t)
    return trips


def analyze(hlo: str) -> dict:
    comps = parse_computations(hlo)
    mult = compute_multipliers(comps)
    trips = body_trip_counts(comps)

    # name → raw output-shape text, per computation (names are unique/comp;
    # collisions across computations are fine for shape purposes)
    flops = 0.0
    bytes_ = 0.0
    coll = {k: {"count": 0.0, "bytes": 0.0, "ops": []} for k in COLLECTIVE_KINDS}

    # computations whose ops count as "top-level" for the bytes proxy:
    # entry + while bodies/conditions + conditional branches + called comps —
    # i.e. everything EXCEPT fusion bodies and reduce/scatter appliers.
    fusion_callees = set()
    for comp in comps.values():
        for op in comp.ops:
            if op.kind in ("fusion", "reduce", "reduce-window", "scatter",
                           "sort", "select-and-scatter", "map", "all-reduce",
                           "reduce-scatter"):
                for callee, _ in _call_edges(op):
                    fusion_callees.add(callee)

    for comp in comps.values():
        m = mult.get(comp.name, 0.0)
        if m == 0.0:
            continue
        trip = trips.get(comp.name, 0)
        shapes = {op.name: op.out_shape for op in comp.ops}
        for op in comp.ops:
            flops += m * _op_flops(op, shapes)
            if comp.name not in fusion_callees:
                bytes_ += m * _op_bytes(op, shapes, trip)
            kind = op.kind
            base = kind[:-6] if kind.endswith("-start") else kind
            if base in COLLECTIVE_KINDS and not kind.endswith("-done"):
                b = _shape_bytes(op.out_shape)
                if base == "all-gather" and kind.endswith("-start"):
                    # -start output is (operand, result); count result only
                    b = b / 2 if b else b
                g = 0
                if (gm := _GROUPS_IOTA_RE.search(op.line)):
                    g = int(gm.group(2))
                elif (gm := _GROUPS_LIST_RE.search(op.line)):
                    g = len(gm.group(1).split(","))
                coll[base]["count"] += m
                coll[base]["bytes"] += m * b
                coll[base]["ops"].append({"bytes": b, "group": g, "mult": m})

    coll_total = sum(v["bytes"] for v in coll.values())
    # wire-byte model: ring algorithms on a (g)-wide axis
    wire = 0.0
    for kind, v in coll.items():
        for rec in v["ops"]:
            g = max(rec["group"], 1)
            b, m = rec["bytes"], rec["mult"]
            if kind == "all-reduce":
                wire += m * 2 * b * (g - 1) / g
            elif kind in ("all-gather", "reduce-scatter", "all-to-all"):
                wire += m * b * (g - 1) / g
            else:  # permute / broadcast
                wire += m * b
    return {
        "flops": flops,
        "bytes": bytes_,
        "collectives": {k: {"count": v["count"], "bytes": v["bytes"]}
                        for k, v in coll.items()},
        "collective_payload_bytes": coll_total,
        "collective_wire_bytes": wire,
        "n_computations": len(comps),
    }


def analyze_compiled(compiled) -> dict:
    """Full cost picture of one compiled executable: the loop-weighted
    structural pass over its HLO text, cross-checked against XLA's own
    once-per-computation ``cost_analysis()`` (``xla_flops`` / ``xla_bytes``)
    and ``memory_analysis()`` footprint. Every backend introspection call is
    best-effort — missing APIs (CPU plugins, older jax) degrade to zeros so
    the live profiler never takes the serving loop down with it."""
    out = analyze(compiled.as_text())
    cost: dict = {}
    try:
        c = compiled.cost_analysis()
        if isinstance(c, (list, tuple)):        # older jax: one dict/device
            c = c[0] if c else {}
        cost = dict(c or {})
    except Exception:
        pass
    out["xla_flops"] = float(cost.get("flops", 0.0))
    out["xla_bytes"] = float(cost.get("bytes accessed", 0.0))
    mem = {}
    try:
        m = compiled.memory_analysis()
        for key in ("argument_size_in_bytes", "output_size_in_bytes",
                    "temp_size_in_bytes", "generated_code_size_in_bytes"):
            mem[key] = int(getattr(m, key, 0) or 0)
    except Exception:
        pass
    out["memory"] = mem
    return out
