"""Serving driver: load (or init) a packed-ternary model and run a batched
request stream through the gateway (scheduler → engine → metrics).

CPU-scale usage (end-to-end example path):

    PYTHONPATH=src python -m repro.launch.serve \
        --arch bitnet-2b --preset tiny --requests 16 --slots 4 --max-new 16 \
        --kv paged --page 32 --prefix-cache

Chunked prefill (SLO isolation — long prompts stream in chunks while other
slots keep decoding):

    PYTHONPATH=src python -m repro.launch.serve \
        --arch bitnet-2b --preset tiny --requests 16 --slots 4 \
        --prefill batched --prefill-chunk 32 --prompt-len 200 --kv paged

Multi-tenant adapters (one ternary base, many QLoRA fine-tunes):

    PYTHONPATH=src python -m repro.launch.serve \
        --arch bitnet-2b --preset tiny --requests 16 --slots 4 \
        --adapters 4 --adapter-rank 8 --adapter-budget-kb 64 --adapter-rate 0.8

Prints one JSON blob: request-level latency stats plus the gateway metrics
registry (TTFT/TBT histograms, queue depth, pool occupancy, preemptions).

Cluster posture: the same engine runs with the model jit-sharded over the
production mesh (the decode_32k dry-run cells prove those graphs compile);
slots become the global batch and the KV cache shards over (data, model) —
batch over data, context over model, exactly Table I's distributed SRAM.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from typing import Optional

import jax
import numpy as np

from repro.ckpt import checkpoint as ckpt_mod
from repro.configs.base import get_config
from repro.launch.train import reduce_config
from repro.models.transformer import Model
from repro.serving import (DenseKV, PagedKV, ReplicaRouter, RequestSpec,
                           SamplingParams, ServeEngine, replica_meshes,
                           shard_engine)
from repro.serving.gateway import Gateway


def build_engine(arch: str, preset: str, *, slots: int, max_len: int,
                 prefill: str, prefill_chunk: Optional[int] = None,
                 ckpt_dir: Optional[str] = None,
                 seed: int = 0, kv: str = "dense", page: int = 64,
                 n_pages: Optional[int] = None,
                 prefix_cache: bool = False, spec_k: int = 0,
                 spec_adaptive: bool = False,
                 n_adapters: int = 0, adapter_rank: int = 8,
                 adapter_budget_kb: Optional[float] = None,
                 host_cache_mb: float = 0.0,
                 disk_cache_dir: Optional[str] = None,
                 disk_cache_mb: float = 256.0, prefetch: bool = False,
                 tracer=None, profiler=None) -> ServeEngine:
    cfg = reduce_config(get_config(arch), preset)
    model = Model(cfg, mode="serve")
    params = model.init(jax.random.PRNGKey(seed))
    if ckpt_dir:
        step = ckpt_mod.latest_step(ckpt_dir)
        if step is not None:
            state, _ = ckpt_mod.restore(ckpt_dir, step, {"params": params})
            params = state["params"]
            print(f"[serve] restored packed weights from step {step}")
    adapters = None
    if n_adapters > 0:
        from repro.serving.adapters import (AdapterRegistry, AdapterServing,
                                            AdapterSpec,
                                            synthetic_adapter_stacks)
        spec = AdapterSpec(rank=adapter_rank, alpha=2.0 * adapter_rank,
                           targets=("q", "v"))
        registry = AdapterRegistry(spec)
        rng = np.random.default_rng(seed + 1)
        for i in range(n_adapters):
            registry.register(
                f"tenant-{i}",
                synthetic_adapter_stacks(rng, cfg, spec, cfg.num_layers))
        per_adapter = registry.get("tenant-0").nbytes
        budget = (int(adapter_budget_kb * 1024) if adapter_budget_kb
                  else per_adapter * max(2, n_adapters // 2))
        adapters = AdapterServing(model, registry, budget_bytes=budget,
                                  max_resident=max(2, min(n_adapters, slots * 2)))
        print(f"[serve] {n_adapters} tenants registered "
              f"({per_adapter}B each, SRAM budget {budget}B)")
    backend = (PagedKV(page=page, n_pages=n_pages) if kv == "paged"
               else DenseKV())
    tiered = None
    if host_cache_mb > 0 or disk_cache_dir:
        from repro.serving import TieredStore
        tiered = TieredStore(
            host_budget_bytes=int(host_cache_mb * (1 << 20)),
            disk_budget_bytes=int(disk_cache_mb * (1 << 20)),
            disk_dir=disk_cache_dir)
        print(f"[serve] tiered memory: host {host_cache_mb}MB"
              + (f", disk {disk_cache_mb}MB at {disk_cache_dir}"
                 if disk_cache_dir else "")
              + (", prefetch on" if prefetch else ""))
    return ServeEngine(model, params, max_slots=slots, max_len=max_len,
                       prefill=prefill, prefill_chunk=prefill_chunk,
                       seed=seed, kv=backend, spec_decode=spec_k > 0,
                       spec_adaptive=spec_adaptive,
                       prefix_cache=prefix_cache, adapters=adapters,
                       tiered=tiered, prefetch=prefetch,
                       tracer=tracer, profiler=profiler)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="bitnet-2b")
    ap.add_argument("--preset", default="tiny", choices=("tiny", "small", "full"))
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--prefill", default="token", choices=("token", "batched"))
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="split batched prefill into this many tokens per "
                         "tick (SLO isolation: decode slots keep emitting "
                         "during a long prompt's prefill; requires "
                         "--prefill batched)")
    ap.add_argument("--spec-k", type=int, default=0,
                    help="speculative decoding: draft up to this many tokens "
                         "per tick by n-gram prompt lookup and verify them "
                         "in one multi-token step (0 = off; greedy/seeded "
                         "requests only, outputs token-identical either way)")
    ap.add_argument("--spec-adaptive", action="store_true",
                    help="adapt each slot's draft width to its live accept "
                         "rate (EWMA, clamped to --spec-k; requires --spec-k)")
    ap.add_argument("--async", dest="async_runtime", action="store_true",
                    help="drive the engine through the asynchronous "
                         "dispatch/backlog runtime (device kept >= 1 tick "
                         "ahead; outputs token-identical to the sync loop)")
    ap.add_argument("--async-depth", type=int, default=1,
                    help="device-ahead pipeline depth for --async")
    ap.add_argument("--replicas", type=int, default=1,
                    help="serve this many engine replicas behind the "
                         "prefix-cache-aware router (each replica gets its "
                         "own (data=1, model=--tp) submesh, KV pool and "
                         "dispatch thread; implies the async runtime)")
    ap.add_argument("--tp", type=int, default=1,
                    help="model-parallel lanes per replica (devices must "
                         "divide; 1 on a single-device host)")
    ap.add_argument("--aot-warmup", action="store_true",
                    help="AOT-compile the prefill length buckets "
                         "(lower().compile() per pow2 bucket) and pre-trace "
                         "decode/sample/verify before serving — steady-state "
                         "jit_compiles stays 0 (asserted by the CI smoke)")
    ap.add_argument("--http-port", type=int, default=None,
                    help="serve an HTTP/SSE front on this port instead of "
                         "the synthetic request stream (implies --async; "
                         "0 = ephemeral; POST /v1/shutdown stops the "
                         "process gracefully)")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-p", type=float, default=1.0,
                    help="nucleus sampling mass (1.0 = disabled)")
    ap.add_argument("--kv", "--kv-backend", dest="kv", default="dense",
                    choices=("dense", "paged"))
    ap.add_argument("--page", type=int, default=64)
    ap.add_argument("--n-pages", type=int, default=None,
                    help="pool capacity (default: slots * max_len / page)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="share identical prompt prefixes via the page trie "
                         "(requires --kv paged)")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="prepend this many identical system-prompt tokens "
                         "to every request (exercises the prefix cache)")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="per-request SLO deadline (EDF scheduling)")
    ap.add_argument("--adapters", type=int, default=0,
                    help="register this many synthetic QLoRA tenants and "
                         "serve them multi-tenant (0 = single personality)")
    ap.add_argument("--adapter-rank", type=int, default=8)
    ap.add_argument("--adapter-budget-kb", type=float, default=None,
                    help="adapter SRAM budget (default: half the tenants fit)")
    ap.add_argument("--adapter-rate", type=float, default=1.0,
                    help="fraction of requests that carry an adapter_id")
    ap.add_argument("--host-cache-mb", type=float, default=0.0,
                    help="host-RAM tier budget for the tiered memory "
                         "hierarchy: evicted adapter packs and prefix-cache "
                         "KV pages demote here instead of being dropped, "
                         "and re-admit bit-identical (0 = tiering off "
                         "unless --disk-cache-dir is set)")
    ap.add_argument("--disk-cache-dir", default=None,
                    help="directory for the disk tier (mmapped CRC-checked "
                         "files); entries cascade host → disk under "
                         "host-budget pressure")
    ap.add_argument("--disk-cache-mb", type=float, default=256.0,
                    help="disk tier budget (only with --disk-cache-dir)")
    ap.add_argument("--prefetch", action="store_true",
                    help="scheduler prefetch hook: walk the pending queue "
                         "each tick and warm upcoming adapters / spilled "
                         "prefixes up the hierarchy before their turn")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace-out", default=None,
                    help="capture a Chrome trace_event trace of the tick "
                         "loop: *.jsonl → strict JSONL, anything else → "
                         "{'traceEvents': [...]} JSON; both open at "
                         "ui.perfetto.dev")
    ap.add_argument("--trace-ring", type=int, default=None,
                    help="keep only the newest N trace events (bounded "
                         "memory on long runs; default unbounded)")
    ap.add_argument("--prom-out", default=None,
                    help="write the metrics registry in Prometheus text "
                         "exposition format to this path (atomic rewrite "
                         "every --prom-every ticks and once at exit)")
    ap.add_argument("--prom-every", type=int, default=50,
                    help="tick window between --prom-out rewrites")
    ap.add_argument("--profile-out", default=None,
                    help="write the merged performance-attribution report "
                         "(per-compiled-function roofline placement, "
                         "per-phase SLO breakdown, recompile offenders, "
                         "%%-of-tick host overhead) as JSON to this path; "
                         "dispatches run blocked while profiling")
    args = ap.parse_args(argv)

    tracer = None
    if args.trace_out:
        from repro.serving.obs import Tracer
        tracer = Tracer(ring=args.trace_ring)
    profiler = None
    if args.profile_out:
        from repro.serving.obs import ProfileRegistry
        profiler = ProfileRegistry()
    n_rep = max(1, args.replicas)
    sharded = n_rep > 1 or args.tp > 1
    meshes = replica_meshes(n_rep, tp=args.tp) if sharded else [None]
    engines, warmups = [], []
    for mesh in meshes:
        e = build_engine(args.arch, args.preset, slots=args.slots,
                         max_len=args.max_len, prefill=args.prefill,
                         prefill_chunk=args.prefill_chunk,
                         ckpt_dir=args.ckpt_dir, seed=args.seed, kv=args.kv,
                         page=args.page, n_pages=args.n_pages,
                         prefix_cache=args.prefix_cache, spec_k=args.spec_k,
                         spec_adaptive=args.spec_adaptive,
                         n_adapters=args.adapters,
                         adapter_rank=args.adapter_rank,
                         adapter_budget_kb=args.adapter_budget_kb,
                         host_cache_mb=args.host_cache_mb,
                         disk_cache_dir=args.disk_cache_dir,
                         disk_cache_mb=args.disk_cache_mb,
                         prefetch=args.prefetch,
                         tracer=tracer if not engines else None,
                         profiler=profiler if not engines else None)
        if mesh is not None:
            shard_engine(e, mesh)
        if args.aot_warmup:
            info = e.warmup_aot(
                max_prompt_len=args.shared_prefix + args.prompt_len)
            warmups.append(info)
            print(f"[serve] replica {len(engines)}: AOT warmup — "
                  f"{info['aot_executables']} prefill executables, "
                  f"{info['jit_warmed']} jit traces in {info['wall_s']:.2f}s",
                  flush=True)
        engines.append(e)
    eng = engines[0]
    gws = [Gateway(e) for e in engines]
    gw = gws[0]
    if args.prom_out:
        gw.prom_out = args.prom_out
        gw.prom_every = args.prom_every

    def warmup_blob():
        return {
            "aot_executables": sum(w["aot_executables"] for w in warmups),
            "jit_warmed": sum(w["jit_warmed"] for w in warmups),
            "compiles": sum(w["compiles"] for w in warmups),
            "wall_s": round(sum(w["wall_s"] for w in warmups), 3),
        }

    if args.http_port is not None:
        # front-door mode: no synthetic stream — serve HTTP/SSE until a
        # client POSTs /v1/shutdown (the CI smoke's graceful-stop path)
        from repro.serving.runtime import AsyncServeRuntime, ServingHTTPFront
        rts = [AsyncServeRuntime(g, depth=args.async_depth) for g in gws]
        if n_rep > 1:
            runtime = ReplicaRouter(rts).start()
            metrics_blob = runtime.gw.metrics.to_dict
        else:
            runtime = rts[0].start()
            metrics_blob = gw.metrics_dict
        front = ServingHTTPFront(runtime, port=args.http_port).start()
        print(f"[serve] http/sse front on 127.0.0.1:{front.port} "
              f"({n_rep} replica(s), async depth {args.async_depth})",
              flush=True)
        try:
            front.serve_until_shutdown()
        finally:
            front.close()
            for rt in rts:
                rt.close(raise_on_poison=False)
        out = {"replicas": n_rep,
               "completed": sum(e.stats.completed for e in engines),
               "tokens_out": sum(e.stats.tokens_out for e in engines),
               "jit_compiles": sum(e.stats.jit_compiles for e in engines),
               "poisoned": runtime.poisoned,
               "tick_host_overhead_frac": round(
                   eng.stats.host_overhead_frac, 4),
               "energy": gw.energy.gauges(),
               "metrics": metrics_blob()}
        if args.aot_warmup:
            out["warmup"] = warmup_blob()
            out["warmup_compiles"] = sum(
                e.stats.warmup_compiles for e in engines)
        print("[serve]", json.dumps(out))
        return 1 if runtime.poisoned else 0

    rng = np.random.default_rng(args.seed)
    vocab = eng.cfg.vocab_size
    system = list(rng.integers(0, min(vocab, 1000), size=args.shared_prefix))
    workload = []
    for i in range(args.requests):
        plen = int(rng.integers(max(2, args.prompt_len // 2), args.prompt_len + 1))
        prompt = system + list(rng.integers(0, min(vocab, 1000), size=plen))
        adapter_id = None
        if args.adapters > 0 and rng.random() < args.adapter_rate:
            adapter_id = f"tenant-{i % args.adapters}"
        workload.append((
            prompt,
            RequestSpec(max_new_tokens=args.max_new,
                        priority=i % 2,            # mixed SLO classes
                        deadline_ms=args.deadline_ms,
                        adapter_id=adapter_id),
            SamplingParams(temperature=args.temperature, top_p=args.top_p,
                           spec_k=args.spec_k)))

    router = None
    if n_rep > 1:
        from repro.serving.runtime import AsyncServeRuntime
        t0 = time.time()
        with ReplicaRouter([AsyncServeRuntime(g, depth=args.async_depth)
                            for g in gws]) as router:
            tickets = [router.submit(p, spec=s, sampling=sp)
                       for p, s, sp in workload]
            router.drain()
            reqs = [t.req for t in tickets]
        wall = time.time() - t0
        stats = eng.stats
    elif args.async_runtime:
        from repro.serving.runtime import AsyncServeRuntime
        t0 = time.time()
        with AsyncServeRuntime(gw, depth=args.async_depth) as rt:
            tickets = [rt.submit(p, spec=s, sampling=sp)
                       for p, s, sp in workload]
            rt.drain()
            reqs = [t.req for t in tickets]
        wall = time.time() - t0
        stats = eng.stats
    else:
        reqs = [gw.submit(p, s, sp) for p, s, sp in workload]
        t0 = time.time()
        stats = gw.run_until_drained()
        wall = time.time() - t0

    done = [r for r in reqs if r.state == "done"]
    ttfts = [r.ttft_s for r in done] or [0.0]
    lats = [r.latency_s for r in done] or [0.0]
    out = {
        "requests": len(reqs),
        "completed": stats.completed,
        "tokens_out": stats.tokens_out,
        "wall_s": round(wall, 3),
        "throughput_tps": round(stats.tokens_out / wall, 1),
        "ttft_p50_ms": round(float(np.median(ttfts)) * 1e3, 1),
        "ttft_p99_ms": round(float(np.quantile(ttfts, 0.99)) * 1e3, 1),
        "latency_p50_ms": round(float(np.median(lats)) * 1e3, 1),
        "phase_breakdown_ms": stats.phase_breakdown_ms(),
        "tick_gap_ms_mean": round(stats.tick_gap_ms_mean, 4),
        "tick_host_overhead_frac": round(stats.host_overhead_frac, 4),
        "jit_compiles": stats.jit_compiles,
        "energy": gw.energy.gauges(),
        "metrics": gw.metrics_dict(),
    }
    if args.aot_warmup:
        out["warmup"] = warmup_blob()
        out["warmup_compiles"] = sum(e.stats.warmup_compiles
                                     for e in engines)
        out["aot_fallbacks"] = sum(e.stats.aot_fallbacks for e in engines)
    if router is not None:
        out["replicas"] = n_rep
        out["completed"] = sum(e.stats.completed for e in engines)
        out["tokens_out"] = sum(e.stats.tokens_out for e in engines)
        out["throughput_tps"] = round(out["tokens_out"] / wall, 1)
        out["jit_compiles"] = sum(e.stats.jit_compiles for e in engines)
        out["routing"] = router.gw.metrics.to_dict()["fleet"]["counters"]
    if args.spec_k:
        out["spec"] = {"drafted": stats.spec_drafted,
                       "accepted": stats.spec_accepted,
                       "accept_rate": round(stats.spec_accept_rate, 4),
                       "verify_ticks": stats.spec_ticks}
    if eng.adapters is not None:
        out["adapters"] = eng.adapters.stats()
    if eng.tiered is not None:
        out["tiered"] = dict(eng.tiered.stats(),
                             prefix_readmits=stats.prefix_readmits,
                             prefix_readmit_tokens=stats.prefix_readmit_tokens,
                             prefetch_hits=stats.prefetch_hits,
                             kv_spilled_pages=stats.kv_spilled_pages)
    if args.trace_out:
        eng.trace.dump(args.trace_out)
        print(f"[serve] trace → {args.trace_out} "
              f"({len(eng.trace.events)} events; open at ui.perfetto.dev)",
              file=sys.stderr)
    if args.prom_out:
        from repro.serving.obs.prom import write_prom
        write_prom(args.prom_out, gw.metrics.to_prom_text())
    if args.profile_out:
        from repro.serving.obs import attribution_report
        report = attribution_report(gw, profiler)
        with open(args.profile_out, "w") as f:
            json.dump(report, f, indent=2)
        n_fns = len(report.get("functions", ()))
        print(f"[serve] attribution → {args.profile_out} "
              f"({n_fns} compiled functions, host overhead "
              f"{report['host_overhead']['frac_of_tick']:.1%} of tick)",
              file=sys.stderr)
    print("[serve]", json.dumps(out))
    return 0


if __name__ == "__main__":
    sys.exit(main())
