"""Checkpointing: atomic sharded save/restore with manifest + CRC,
async save thread, restore-with-resharding (elastic re-mesh)."""
from repro.ckpt import checkpoint

__all__ = ["checkpoint"]
