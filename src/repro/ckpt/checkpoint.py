"""Fault-tolerant checkpointing: atomic, CRC-verified, async, re-shardable.

Layout (one directory per step)::

    <dir>/step_00001000/
        manifest.json       # tree structure, shapes, dtypes, per-file CRC32
        leaf_00000.npy ...  # one file per pytree leaf

Properties:
  * **atomic** — written to ``step_X.tmp`` then ``os.rename``'d; a crash
    mid-save never corrupts the latest checkpoint, restart picks the newest
    *complete* directory.
  * **verified** — every leaf carries a CRC32; restore fails loudly on
    corruption (flaky storage on large fleets is a when, not an if).
  * **async** — serialization runs on a background thread against a
    snapshotted host copy; the train loop keeps stepping. ``wait_pending()``
    joins before exit.
  * **re-shardable** — leaves are stored as full logical arrays; restore
    ``device_put``s them against the *target* sharding, so a checkpoint
    taken on (data=4, model=2) restores onto (data=2, model=4) or a
    different pod count unchanged (the elastic re-mesh path, runtime/).
  * **bounded** — ``keep`` most-recent checkpoints are retained.

Multi-host note: on a real fleet each process would save only
``arr.addressable_shards`` and restore via per-shard assembly; the manifest
format carries ``shard_of`` for that extension. Single-controller CPU runs
(this container) always see fully-addressable arrays.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
import zlib
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import ml_dtypes
import numpy as np

Tree = Any

#: dtypes numpy can't serialize natively → (wire view dtype, logical dtype)
_EXOTIC = {
    "bfloat16": (np.uint16, ml_dtypes.bfloat16),
    "float8_e4m3fn": (np.uint8, ml_dtypes.float8_e4m3fn),
    "float8_e5m2": (np.uint8, ml_dtypes.float8_e5m2),
    "float8_e4m3": (np.uint8, getattr(ml_dtypes, "float8_e4m3", ml_dtypes.float8_e4m3fn)),
}

_PENDING: list = []
_LOCK = threading.Lock()


# ---------------------------------------------------------------------------
# Tree ↔ flat path map
# ---------------------------------------------------------------------------


def _flatten(tree: Tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = jax.tree_util.keystr(path)
        flat[key] = leaf
    return flat


def _unflatten_like(tree: Tree, flat: Dict[str, Any]) -> Tree:
    paths, treedef = jax.tree_util.tree_flatten_with_path(tree)
    leaves = []
    for path, old in paths:
        key = jax.tree_util.keystr(path)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key}")
        leaves.append(flat[key])
    return jax.tree_util.tree_unflatten(treedef, leaves)


# ---------------------------------------------------------------------------
# Save
# ---------------------------------------------------------------------------


def _step_dir(base: str, step: int) -> Path:
    return Path(base) / f"step_{step:08d}"


def _snapshot(tree: Tree) -> Dict[str, np.ndarray]:
    """Device → host copy (consistent point-in-time snapshot)."""
    flat = _flatten(tree)
    out = {}
    for k, v in flat.items():
        out[k] = np.asarray(jax.device_get(v))
    return out


def _write(base: str, step: int, host_flat: Dict[str, np.ndarray],
           meta: Dict[str, Any], keep: int) -> Path:
    final = _step_dir(base, step)
    if final.exists():  # this step already checkpointed (save/save race)
        return final
    # unique tmp per writer — concurrent saves of the same step can't collide
    tmp = final.with_suffix(f".tmp{os.getpid()}.{threading.get_ident()}")
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    manifest = {"step": step, "meta": meta, "time": time.time(), "leaves": {}}
    for i, (key, arr) in enumerate(sorted(host_flat.items())):
        fname = f"leaf_{i:05d}.npy"
        fpath = tmp / fname
        logical = str(arr.dtype)
        if logical in _EXOTIC:  # numpy can't np.save bf16/fp8 — wire as uint
            arr = arr.view(_EXOTIC[logical][0])
        np.save(fpath, arr, allow_pickle=False)
        crc = zlib.crc32(fpath.read_bytes()) & 0xFFFFFFFF
        manifest["leaves"][key] = {
            "file": fname, "shape": list(arr.shape), "dtype": logical,
            "crc32": crc,
        }
    mpath = tmp / "manifest.json"
    mpath.write_text(json.dumps(manifest, indent=1))
    with open(mpath) as f:  # fsync the manifest before the atomic rename
        os.fsync(f.fileno())
    try:
        os.rename(tmp, final)
    except OSError:
        if final.exists():  # lost the race to an identical save — fine
            shutil.rmtree(tmp, ignore_errors=True)
        else:
            raise
    _gc(base, keep)
    return final


def _gc(base: str, keep: int) -> None:
    steps = sorted(all_steps(base))
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(_step_dir(base, s), ignore_errors=True)


def save(base: str, step: int, state: Tree, meta: Optional[Dict[str, Any]] = None,
         *, async_: bool = True, keep: int = 3) -> None:
    """Checkpoint ``state`` (any pytree of arrays) at ``step``."""
    meta = dict(meta or {})
    meta.setdefault("step", step)
    host_flat = _snapshot(state)  # main thread: consistent snapshot
    if async_:
        t = threading.Thread(target=_write, args=(base, step, host_flat, meta, keep),
                             daemon=True)
        with _LOCK:
            _PENDING.append(t)
        t.start()
    else:
        _write(base, step, host_flat, meta, keep)


def wait_pending() -> None:
    with _LOCK:
        pending = list(_PENDING)
        _PENDING.clear()
    for t in pending:
        t.join()


# ---------------------------------------------------------------------------
# Restore
# ---------------------------------------------------------------------------


def all_steps(base: str) -> list:
    p = Path(base)
    if not p.exists():
        return []
    out = []
    for d in p.iterdir():
        if d.is_dir() and d.name.startswith("step_") and ".tmp" not in d.name:
            if (d / "manifest.json").exists():
                out.append(int(d.name.split("_")[1]))
    return sorted(out)


def latest_step(base: str) -> Optional[int]:
    steps = all_steps(base)
    return steps[-1] if steps else None


def load_manifest(base: str, step: int) -> Dict[str, Any]:
    return json.loads((_step_dir(base, step) / "manifest.json").read_text())


def restore(base: str, step: int, target: Tree, *,
            mesh=None, shardings: Optional[Tree] = None,
            strict_crc: bool = True) -> Tuple[Tree, Dict[str, Any]]:
    """Load a checkpoint into the structure of ``target``.

    Each leaf is ``device_put`` against either the matching leaf of
    ``shardings`` or the sharding the target leaf already has — which is how
    a checkpoint re-shards onto a different mesh (elastic scaling)."""
    d = _step_dir(base, step)
    manifest = json.loads((d / "manifest.json").read_text())
    target_flat = _flatten(target)
    shard_flat = _flatten(shardings) if shardings is not None else None

    loaded: Dict[str, Any] = {}
    for key, info in manifest["leaves"].items():
        fpath = d / info["file"]
        raw = fpath.read_bytes()
        crc = zlib.crc32(raw) & 0xFFFFFFFF
        if strict_crc and crc != info["crc32"]:
            raise IOError(f"CRC mismatch for {key} in {d} "
                          f"(expected {info['crc32']:#x}, got {crc:#x})")
        arr = np.load(fpath, allow_pickle=False)
        if info["dtype"] in _EXOTIC:
            arr = arr.view(_EXOTIC[info["dtype"]][1])
        if key in target_flat:
            ref = target_flat[key]
            if tuple(arr.shape) != tuple(ref.shape):
                raise ValueError(f"shape mismatch for {key}: ckpt {arr.shape} "
                                 f"vs target {ref.shape}")
            if shard_flat is not None:
                sharding = shard_flat[key]
            else:
                sharding = getattr(ref, "sharding", None)
            loaded[key] = (jax.device_put(arr, sharding) if sharding is not None
                           else jax.device_put(arr))
        else:
            loaded[key] = arr
    state = _unflatten_like(target, loaded)
    return state, manifest["meta"]
