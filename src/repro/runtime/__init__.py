"""Distributed runtime: fault tolerance (retry/preemption/straggler) and
elastic re-mesh."""
from repro.runtime.fault import (
    PreemptionHandler,
    RetryPolicy,
    StepRunner,
    StragglerWatchdog,
)

__all__ = ["PreemptionHandler", "RetryPolicy", "StepRunner", "StragglerWatchdog"]
