"""Elastic re-mesh: restart training/serving on a different device count.

The scenario: a pod loses nodes (or gains them back) and the job must resume
on a new mesh shape without invalidating the checkpoint. Checkpoints store
full logical arrays (ckpt/), so re-meshing is:

    1. build the new mesh,
    2. rebuild the model/optimizer spec trees (pure shape metadata),
    3. derive the new PartitionSpec trees from models/sharding.py,
    4. restore: each leaf is device_put against its *new* sharding.

The batch size / steps bookkeeping is the trainer's job (global batch is
kept constant — per-device batch grows when devices shrink, as long as
divisibility holds; otherwise the caller picks a new global batch).

``plan_remesh`` validates divisibility up front so a bad elastic event
fails before any state is touched.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import Mesh

from repro.ckpt import checkpoint as ckpt_mod
from repro.configs.base import ModelConfig
from repro.launch import mesh as mesh_mod
from repro.launch import specs as specs_mod
from repro.models import sharding as shard_rules


@dataclasses.dataclass
class RemeshPlan:
    old_shape: Tuple[int, ...]
    new_shape: Tuple[int, ...]
    new_mesh: Mesh
    notes: Dict[str, Any]


def plan_remesh(cfg: ModelConfig, new_shape: Tuple[int, ...],
                axes: Tuple[str, ...] = ("data", "model"),
                global_batch: Optional[int] = None,
                old_shape: Tuple[int, ...] = ()) -> RemeshPlan:
    """Validate that the architecture shards onto the new mesh."""
    notes: Dict[str, Any] = {}
    tp = dict(zip(axes, new_shape)).get("model", 1)
    dp = 1
    for name, extent in zip(axes, new_shape):
        if name in ("pod", "data", "replica"):
            dp *= extent
    for dim, label in ((cfg.d_model, "d_model"), (cfg.d_ff or tp, "d_ff")):
        if dim % tp:
            raise ValueError(f"{label}={dim} not divisible by model axis {tp}")
    if cfg.vocab_padded % tp:
        raise ValueError(f"vocab_padded={cfg.vocab_padded} not divisible by {tp}")
    if global_batch is not None and global_batch % dp:
        notes["batch"] = (f"global_batch={global_batch} not divisible by dp={dp};"
                          " batch will be replicated or must be re-chosen")
    mesh = mesh_mod.make_mesh(new_shape, axes)
    return RemeshPlan(old_shape=old_shape, new_shape=new_shape, new_mesh=mesh,
                      notes=notes)


def restore_on_mesh(ckpt_dir: str, step: int, target_specs: Any, plan: RemeshPlan,
                    *, strategy: str = "paper_tree", mode: str = "qat",
                    fsdp: bool = True) -> Tuple[Any, Dict[str, Any]]:
    """Restore a checkpoint onto the new mesh with freshly derived shardings.

    ``target_specs`` is the {params, opt_state} spec tree (eval_shape'd);
    parameter leaves get param rules, everything else inherits the matching
    parameter leaf's sharding where shapes allow, else replicates."""
    mesh = plan.new_mesh
    p_specs = target_specs["params"]
    p_shard = specs_mod.named(
        mesh, shard_rules.param_spec_tree(p_specs, mesh, strategy=strategy,
                                          mode=mode, fsdp=fsdp))
    shardings = {"params": p_shard}
    if "opt_state" in target_specs:
        o = target_specs["opt_state"]
        from jax.sharding import NamedSharding, PartitionSpec as P

        def fix(mspec, pshard):
            if getattr(mspec, "shape", ()) == ():
                return NamedSharding(mesh, P())
            return pshard

        shardings["opt_state"] = type(o)(
            step=NamedSharding(mesh, P()),
            m=jax.tree.map(fix, o.m, p_shard),
            v=jax.tree.map(fix, o.v, p_shard))
    state, meta = ckpt_mod.restore(ckpt_dir, step, target_specs,
                                   shardings=shardings)
    return state, meta
