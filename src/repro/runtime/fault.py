"""Fault-tolerant step execution: retries, preemption, stragglers.

At 1000+ nodes something is always failing. The failure taxonomy and the
response implemented here:

  * **transient step failure** (flaky interconnect, XLA internal retryable,
    host OOM-kill of a data worker) → retry with exponential backoff +
    jitter, up to ``max_retries``; the step function must be pure w.r.t.
    (params, opt_state, batch), so a retry is safe by construction.
  * **preemption notice** (SIGTERM from the scheduler / maintenance event)
    → set a flag; the train loop checkpoints at the next step boundary and
    exits cleanly for the scheduler to restart elsewhere.
  * **stragglers** — a watchdog thread measures per-step wall time against a
    rolling median; a step exceeding ``straggler_factor ×`` median raises a
    report (on a real fleet this feeds the controller's node-replacement
    logic; here it logs and counts).
  * **hard failure** (unrecoverable) → raises after retries exhausted;
    process restart + checkpoint restore (ckpt/) is the recovery path, and
    the elastic re-mesh helper (below) covers coming back on a *different*
    device count.
"""
from __future__ import annotations

import dataclasses
import random
import signal
import statistics
import threading
import time
from typing import Any, Callable, Dict, List, Optional

import jax


# ---------------------------------------------------------------------------
# Retry policy
# ---------------------------------------------------------------------------


RETRYABLE = (jax.errors.JaxRuntimeError, OSError, RuntimeError)


@dataclasses.dataclass
class RetryPolicy:
    max_retries: int = 3
    base_delay_s: float = 0.5
    max_delay_s: float = 30.0
    jitter: float = 0.25

    def delay(self, attempt: int) -> float:
        d = min(self.base_delay_s * (2 ** attempt), self.max_delay_s)
        return d * (1.0 + random.uniform(-self.jitter, self.jitter))


class PreemptionHandler:
    """Installs SIGTERM/SIGINT hooks; the loop polls ``should_stop``."""

    def __init__(self, signals=(signal.SIGTERM,)):
        self._stop = threading.Event()
        self._installed = False
        self._signals = signals

    def install(self) -> "PreemptionHandler":
        if not self._installed and threading.current_thread() is threading.main_thread():
            for s in self._signals:
                try:
                    signal.signal(s, self._on_signal)
                except ValueError:
                    pass
            self._installed = True
        return self

    def _on_signal(self, signum, frame):
        self._stop.set()

    def request_stop(self) -> None:  # testable path
        self._stop.set()

    @property
    def should_stop(self) -> bool:
        return self._stop.is_set()


class StragglerWatchdog:
    """Rolling-median step timing; flags slow steps."""

    def __init__(self, factor: float = 3.0, window: int = 32, min_samples: int = 5):
        self.factor = factor
        self.window = window
        self.min_samples = min_samples
        self.samples: List[float] = []
        self.flagged: List[Dict[str, float]] = []

    def observe(self, step: int, seconds: float) -> Optional[Dict[str, float]]:
        report = None
        if len(self.samples) >= self.min_samples:
            med = statistics.median(self.samples)
            if seconds > self.factor * med:
                report = {"step": step, "seconds": seconds, "median": med,
                          "factor": seconds / med}
                self.flagged.append(report)
        self.samples.append(seconds)
        if len(self.samples) > self.window:
            self.samples.pop(0)
        return report


class StepRunner:
    """Wraps one training/serving step with retry + timing + straggler
    detection. The wrapped callable must be repeatable (pure in its args)."""

    def __init__(self, policy: Optional[RetryPolicy] = None,
                 watchdog: Optional[StragglerWatchdog] = None,
                 preemption: Optional[PreemptionHandler] = None,
                 on_report: Callable[[str, Dict], None] = None):
        self.policy = policy or RetryPolicy()
        self.watchdog = watchdog or StragglerWatchdog()
        self.preemption = (preemption or PreemptionHandler()).install()
        self.on_report = on_report or (lambda kind, payload: print(
            f"[runtime] {kind}: {payload}"))
        self.step_count = 0
        self.retry_count = 0

    def run(self, fn: Callable[[], Any]) -> Any:
        attempt = 0
        while True:
            t0 = time.time()
            try:
                out = fn()
                out = jax.block_until_ready(out)
                dt = time.time() - t0
                self.step_count += 1
                rep = self.watchdog.observe(self.step_count, dt)
                if rep:
                    self.on_report("straggler", rep)
                return out
            except RETRYABLE as e:  # noqa: PERF203
                attempt += 1
                self.retry_count += 1
                if attempt > self.policy.max_retries:
                    self.on_report("fatal", {"error": repr(e), "attempt": attempt})
                    raise
                delay = self.policy.delay(attempt - 1)
                self.on_report("retry", {"error": repr(e)[:200],
                                         "attempt": attempt, "delay_s": delay})
                time.sleep(delay)
