"""Optimizer substrate: AdamW, schedules, clipping, QLoRA masking,
gradient compression (distributed-optimization trick for the `pod` axis)."""
from repro.optim.adamw import (
    AdamW,
    AdamWState,
    clip_by_global_norm,
    combine,
    constant,
    global_norm,
    linear_decay,
    partition,
    trainable_mask,
    warmup_cosine,
)
from repro.optim.compression import (
    compress_int8,
    decompress_int8,
    error_feedback_update,
)

__all__ = [
    "AdamW", "AdamWState", "clip_by_global_norm", "combine", "constant",
    "global_norm", "linear_decay", "partition", "trainable_mask",
    "warmup_cosine", "compress_int8", "decompress_int8",
    "error_feedback_update",
]
