"""In-repo AdamW with distributed-training accoutrements.

optax is not available offline, so the optimizer substrate is implemented
here: decoupled weight decay AdamW, global-norm clipping, cosine/linear
schedules, and the ZeRO-friendly state layout (moments live with the same
sharding as the parameters — the 2-D (tp, dp) weight sharding therefore
shards optimizer state 256-way on the production mesh for free).

Memory posture at 100B+ (arctic-480b train_4k is the stress cell): moments
are stored in ``bfloat16`` by default (``moment_dtype``), halving optimizer
HBM vs f32 at negligible quality cost for QAT (the master weights stay in
the param dtype). With 2-D sharded weights on 256 chips:

    480e9 params x (2 master + 2 m + 2 v) bytes / 256  ~=  11.3 GB/chip

which fits v5e HBM with remat'd activations; the dry-run memory_analysis is
the authoritative check.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Any
PyTree = Any


# ---------------------------------------------------------------------------
# Schedules
# ---------------------------------------------------------------------------


def warmup_cosine(peak_lr: float, warmup_steps: int, total_steps: int,
                  final_frac: float = 0.1) -> Callable[[jax.Array], jax.Array]:
    """Linear warmup then cosine decay to ``final_frac * peak_lr``."""

    def schedule(step: jax.Array) -> jax.Array:
        step = step.astype(jnp.float32)
        warm = peak_lr * step / max(warmup_steps, 1)
        prog = jnp.clip((step - warmup_steps) / max(total_steps - warmup_steps, 1),
                        0.0, 1.0)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup_steps, warm, peak_lr * cos)

    return schedule


def constant(lr: float) -> Callable[[jax.Array], jax.Array]:
    return lambda step: jnp.asarray(lr, jnp.float32)


def linear_decay(peak_lr: float, warmup_steps: int, total_steps: int
                 ) -> Callable[[jax.Array], jax.Array]:
    def schedule(step: jax.Array) -> jax.Array:
        step = step.astype(jnp.float32)
        warm = peak_lr * step / max(warmup_steps, 1)
        prog = jnp.clip((step - warmup_steps) / max(total_steps - warmup_steps, 1),
                        0.0, 1.0)
        return jnp.where(step < warmup_steps, warm, peak_lr * (1 - prog))

    return schedule


# ---------------------------------------------------------------------------
# Gradient clipping
# ---------------------------------------------------------------------------


def global_norm(tree: PyTree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree_util.tree_leaves(tree)
              if hasattr(x, "dtype") and jnp.issubdtype(x.dtype, jnp.floating)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads: PyTree, max_norm: float) -> Tuple[PyTree, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), norm


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------


class AdamWState(NamedTuple):
    step: jax.Array      # () int32
    m: PyTree            # first moment, moment_dtype
    v: PyTree            # second moment, moment_dtype


@dataclasses.dataclass(frozen=True)
class AdamW:
    """Decoupled-weight-decay Adam (Loshchilov & Hutter).

    ``mask`` (same treedef as params, bool leaves) selects which leaves are
    trainable — QLoRA mode freezes the packed ROM base by masking everything
    except adapters. Frozen leaves carry no moments (zeros are still stored
    structurally but XLA DCEs untouched zero arrays when donated).
    """

    schedule: Callable[[jax.Array], jax.Array]
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    max_grad_norm: float = 1.0
    moment_dtype: Any = jnp.bfloat16
    # First moment can drop to fp8 (e4m3) — m is a smoothed gradient whose
    # per-step contribution is divided by sqrt(v), so coarse mantissa is
    # tolerable; v stays ≥ bf16 (its sqrt gates the step size). At 480B/256
    # chips this is the difference between fitting 16 GiB HBM and not
    # (EXPERIMENTS.md §Dry-run residency).
    m_dtype: Any = None  # None → moment_dtype

    @property
    def _m_dtype(self):
        return self.m_dtype or self.moment_dtype

    # -- init ----------------------------------------------------------------
    def init(self, params: Params) -> AdamWState:
        def zero(p, dtype):
            return (jnp.zeros(p.shape, dtype) if self._is_float(p)
                    else jnp.zeros((), jnp.int8))

        return AdamWState(
            step=jnp.zeros((), jnp.int32),
            m=jax.tree.map(lambda p: zero(p, self._m_dtype), params),
            v=jax.tree.map(lambda p: zero(p, self.moment_dtype), params))

    def state_specs(self, params: Params) -> AdamWState:
        return jax.eval_shape(self.init, params)

    @staticmethod
    def _is_float(x) -> bool:
        return hasattr(x, "dtype") and jnp.issubdtype(x.dtype, jnp.floating)

    # -- update ----------------------------------------------------------------
    def update(self, grads: PyTree, state: AdamWState, params: Params,
               mask: Optional[PyTree] = None
               ) -> Tuple[Params, AdamWState, Dict[str, jax.Array]]:
        grads, gnorm = clip_by_global_norm(grads, self.max_grad_norm)
        step = state.step + 1
        lr = self.schedule(step)
        b1, b2 = self.b1, self.b2
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(p, g, m, v, trainable=True):
            if not self._is_float(p) or not trainable:
                return p, m, v
            gf = g.astype(jnp.float32)
            mf = b1 * m.astype(jnp.float32) + (1 - b1) * gf
            vf = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
            mh = mf / bc1
            vh = vf / bc2
            delta = mh / (jnp.sqrt(vh) + self.eps) + self.weight_decay * p.astype(jnp.float32)
            p2 = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
            return p2, mf.astype(self._m_dtype), vf.astype(self.moment_dtype)

        if mask is None:
            out = jax.tree.map(upd, params, grads, state.m, state.v)
        else:
            out = jax.tree.map(upd, params, grads, state.m, state.v, mask)
        p2 = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
        m2 = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
        v2 = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
        metrics = {"grad_norm": gnorm, "lr": lr}
        return p2, AdamWState(step=step, m=m2, v=v2), metrics


# ---------------------------------------------------------------------------
# Trainability masks
# ---------------------------------------------------------------------------


def trainable_mask(params: Params, mode: str) -> PyTree:
    """qat: everything float trains. qlora: only /lora/ leaves train (the ROM
    base is immutable — C4's 'base weights in ROM are immutable')."""

    paths = jax.tree_util.tree_flatten_with_path(params)[0]

    def leaf_mask(path_entries, leaf):
        path = "/".join(str(getattr(e, "key", getattr(e, "idx", e))) for e in path_entries)
        if mode == "qlora":
            return "lora" in path
        return True

    flat = [leaf_mask(p, l) for p, l in paths]
    treedef = jax.tree_util.tree_structure(params)
    return jax.tree_util.tree_unflatten(treedef, flat)


def partition(params: Params, mask: PyTree) -> Tuple[PyTree, PyTree]:
    """Split params into (trainable, frozen) trees with ``None`` holes, so
    ``jax.grad`` can differentiate the trainable tree only (the frozen tree —
    e.g. packed uint8 ROM weights in qlora mode — never enters autodiff)."""
    train = jax.tree.map(lambda p, m: p if m else None, params, mask)
    frozen = jax.tree.map(lambda p, m: None if m else p, params, mask)
    return train, frozen


def combine(train: PyTree, frozen: PyTree) -> Params:
    return jax.tree.map(lambda a, b: a if b is None else b, train, frozen,
                        is_leaf=lambda x: x is None)
