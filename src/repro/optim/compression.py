"""Gradient compression for the cross-pod all-reduce (distributed trick).

At 1000+ nodes the cross-pod (DCI) gradient all-reduce dominates step time;
in-pod ICI reduce-scatter is cheap by comparison. The standard mitigation is
hierarchical reduction (reduce-scatter in-pod → compressed all-reduce across
pods → all-gather in-pod) with int8 quantisation + error feedback so the
compression error is re-injected next step instead of lost (1-bit Adam /
PowerSGD lineage, here the simpler int8+EF variant).

These are pure jittable functions; `launch/train.py` wires them into the
`pod`-axis psum when `--grad-compression int8` is set. Tests check the
error-feedback invariant: sum of applied updates converges to the true sum.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


def compress_int8(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8 quantisation: g ≈ q * scale."""
    gf = g.astype(jnp.float32)
    scale = jnp.max(jnp.abs(gf)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q: jax.Array, scale: jax.Array, dtype=jnp.float32) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def error_feedback_update(g: jax.Array, residual: jax.Array
                          ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Quantise (g + residual); return (q, scale, new_residual)."""
    corrected = g.astype(jnp.float32) + residual.astype(jnp.float32)
    q, scale = compress_int8(corrected)
    new_residual = corrected - decompress_int8(q, scale)
    return q, scale, new_residual


def compressed_psum_tree(grads: PyTree, residuals: PyTree, axis_name: Optional[str]
                         ) -> Tuple[PyTree, PyTree]:
    """int8+EF all-reduce of a gradient tree over ``axis_name``.

    The int8 payload is what crosses the (slow) axis; scales are psum'd in
    f32 (scalar — negligible). Reduction of quantised values is exact in
    int32 accumulation, so the only loss is the per-shard quantisation error,
    which error feedback re-injects next step. With ``axis_name=None``
    degrades to identity (still applying EF, for testability).
    """

    def one(g, r):
        q, scale, new_r = error_feedback_update(g, r)
        if axis_name is None:
            total = decompress_int8(q, scale, jnp.float32)
            n = 1.0
        else:
            # each pod contributes q*scale; sum in f32 after widening — the
            # wire format is int8, the psum math is exact per-term.
            total = jax.lax.psum(q.astype(jnp.float32) * scale, axis_name)
            n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
        return (total / n).astype(g.dtype), new_r

    out = jax.tree.map(one, grads, residuals)
    summed = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_res = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    return summed, new_res


def init_residuals(grads_spec: PyTree) -> PyTree:
    return jax.tree.map(lambda s: jnp.zeros(s.shape, jnp.float32), grads_spec)
