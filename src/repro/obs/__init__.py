"""Cross-cutting observability primitives shared by benchmarks and serving.

``repro.serving.obs`` holds the serving-loop instrumentation (tracer, Prom,
energy, profiler); this package holds the pieces that are *not* tied to the
serving loop — currently the hardware peak specs that roofline math is
computed against.
"""
from repro.obs.hardware import CPU_HOST, TPU_V5E, HardwareSpec, detect

__all__ = ["CPU_HOST", "TPU_V5E", "HardwareSpec", "detect"]
