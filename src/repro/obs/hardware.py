"""Hardware peak specs — the single source of truth for roofline math.

Factored out of ``benchmarks/roofline.py`` (which previously hardcoded the
TPU v5e peaks inline) so the offline roofline report, the live serving
profiler (``repro.serving.obs.profile``) and the analytic memory model
(``benchmarks/analytic_model``) all read the same numbers.

Two specs ship:

* ``TPU_V5E`` — the paper's deployment target: 197 TFLOP/s bf16, 819 GB/s
  HBM, ~50 GB/s per ICI link (conservative single-link figure), 16 GiB HBM.
* ``CPU_HOST`` — an order-of-magnitude host fallback so the profiler
  degrades gracefully when serving runs under ``JAX_PLATFORMS=cpu`` (CI,
  dev boxes). Absolute efficiencies against it are directional only; the
  memory-vs-compute *classification* is still meaningful because it depends
  on operational intensity relative to the ridge point.

``detect()`` picks by the active jax backend and never raises — off-TPU it
always lands on ``CPU_HOST``.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    """Peak rates for one chip (or one host, for the CPU fallback)."""

    name: str
    peak_flops: float       # FLOP/s (bf16 on TPU)
    hbm_bw: float           # bytes/s main-memory bandwidth
    ici_link_bw: float      # bytes/s per interconnect link
    hbm_bytes: int          # main-memory capacity, bytes

    @property
    def ridge_intensity(self) -> float:
        """FLOP/byte at the roofline ridge: below it a kernel is
        bandwidth-limited, above it compute-limited."""
        return self.peak_flops / self.hbm_bw

    def roof_flops(self, intensity: float) -> float:
        """Attainable FLOP/s at a given operational intensity."""
        if intensity <= 0.0:
            return self.hbm_bw  # degenerate: pure-memory op, 1 flop/byte roof
        return min(self.peak_flops, intensity * self.hbm_bw)

    def to_dict(self) -> dict:
        return {
            "name": self.name,
            "peak_flops": self.peak_flops,
            "hbm_bw": self.hbm_bw,
            "ici_link_bw": self.ici_link_bw,
            "hbm_bytes": self.hbm_bytes,
            "ridge_intensity": self.ridge_intensity,
        }


#: TPU v5e, per chip. The numbers roofline.py shipped with since PR 0.
TPU_V5E = HardwareSpec(
    name="tpu-v5e",
    peak_flops=197e12,
    hbm_bw=819e9,
    ici_link_bw=50e9,
    hbm_bytes=16 * 1024 ** 3,
)

#: Rough single-socket host: ~100 GFLOP/s sustained f32, ~20 GB/s DRAM.
#: Deliberately conservative round numbers — a fallback, not a claim.
CPU_HOST = HardwareSpec(
    name="cpu-host",
    peak_flops=100e9,
    hbm_bw=20e9,
    ici_link_bw=1e9,
    hbm_bytes=8 * 1024 ** 3,
)


def detect() -> HardwareSpec:
    """Spec for the active jax backend; CPU_HOST whenever not on TPU."""
    try:
        import jax
        backend = jax.default_backend()
    except Exception:
        backend = "cpu"
    return TPU_V5E if backend == "tpu" else CPU_HOST
