"""Token data pipeline: deterministic, sharded, resumable.

Two sources behind one interface:

  * **synthetic** — a structured pseudo-corpus generated on the fly
    (Zipf-distributed unigrams + a Markov bigram backbone + copy spans, so a
    model can actually reduce loss on it — pure uniform noise gives a flat
    loss and makes end-to-end examples look broken).
  * **mmap** — a flat binary token file (np.uint16/uint32) read with
    ``np.memmap``; the production path for real corpora.

Determinism & resume: batches are a pure function of ``(seed, cursor)``.
The trainer checkpoints ``cursor`` and calls :meth:`seek` on restore — exact
resume, no tail re-reads. In a multi-host deployment each host reads only
its ``(host_id, num_hosts)`` interleave of batches (``host_batch_slice``).
"""
from __future__ import annotations

import dataclasses
from pathlib import Path
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass
class DataConfig:
    vocab_size: int
    batch: int
    seq: int
    seed: int = 0
    path: Optional[str] = None          # mmap token file; None → synthetic
    dtype: str = "uint16"
    host_id: int = 0
    num_hosts: int = 1
    # synthetic-corpus knobs
    zipf_a: float = 1.2
    markov_states: int = 64
    copy_prob: float = 0.15


class SyntheticCorpus:
    """Deterministic learnable pseudo-language over ``vocab_size`` tokens."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed ^ 0x5EED)
        v = cfg.vocab_size
        m = min(cfg.markov_states, v)
        # Markov backbone: each state strongly prefers a few successors
        self.trans = rng.integers(0, m, size=(m, 4))
        # Zipf unigram table for the emission mixture
        ranks = np.arange(1, v + 1, dtype=np.float64)
        p = ranks ** (-cfg.zipf_a)
        self.unigram = p / p.sum()
        self.m = m

    def batch_at(self, cursor: int) -> np.ndarray:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed << 20) ^ cursor)
        b, s = cfg.batch, cfg.seq
        state = rng.integers(0, self.m, size=b)
        out = np.empty((b, s + 1), np.int64)
        emit_uni = rng.random((b, s + 1)) < 0.3
        uni = rng.choice(cfg.vocab_size, size=(b, s + 1), p=self.unigram)
        pick = rng.integers(0, 4, size=(b, s + 1))
        for t in range(s + 1):
            state = self.trans[state, pick[:, t]]
            out[:, t] = np.where(emit_uni[:, t], uni[:, t], state)
        # copy spans: repeat an earlier window (gives in-context signal)
        n_copy = int(b * cfg.copy_prob)
        if n_copy and s >= 64:
            rows = rng.choice(b, n_copy, replace=False)
            for r in rows:
                src = rng.integers(0, s // 2)
                ln = rng.integers(16, min(64, s // 4) + 1)
                dst = rng.integers(s // 2, s + 1 - ln)
                out[r, dst:dst + ln] = out[r, src:src + ln]
        return out


class MmapCorpus:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        dt = np.uint16 if cfg.dtype == "uint16" else np.uint32
        self.tokens = np.memmap(cfg.path, dtype=dt, mode="r")
        self.n = len(self.tokens)

    def batch_at(self, cursor: int) -> np.ndarray:
        cfg = self.cfg
        b, s = cfg.batch, cfg.seq
        need = b * (s + 1)
        start = (cursor * need) % max(self.n - need, 1)
        flat = np.asarray(self.tokens[start:start + need], np.int64)
        if len(flat) < need:  # wrap
            flat = np.concatenate([flat, np.asarray(self.tokens[:need - len(flat)],
                                                    np.int64)])
        return (flat % cfg.vocab_size).reshape(b, s + 1)


class TokenPipeline:
    """next() → {"tokens": (B, S) int32, "labels": (B, S) int32}."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self.source = MmapCorpus(cfg) if cfg.path else SyntheticCorpus(cfg)
        self.cursor = 0

    def seek(self, cursor: int) -> None:
        self.cursor = int(cursor)

    def batch_at(self, cursor: int) -> Dict[str, np.ndarray]:
        # host interleave: batch index space is strided across hosts
        global_cursor = cursor * self.cfg.num_hosts + self.cfg.host_id
        chunk = self.source.batch_at(global_cursor)
        return {"tokens": chunk[:, :-1].astype(np.int32),
                "labels": chunk[:, 1:].astype(np.int32)}

    def next(self) -> Dict[str, np.ndarray]:
        out = self.batch_at(self.cursor)
        self.cursor += 1
        return out

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.next()


def write_token_file(path: str, tokens: np.ndarray, dtype=np.uint16) -> None:
    """Helper for tests/examples: persist a flat token array."""
    Path(path).parent.mkdir(parents=True, exist_ok=True)
    tokens.astype(dtype).tofile(path)
