"""Data substrate: deterministic sharded token pipeline with exact resume."""
from repro.data.pipeline import DataConfig, MmapCorpus, SyntheticCorpus, TokenPipeline, write_token_file

__all__ = ["DataConfig", "MmapCorpus", "SyntheticCorpus", "TokenPipeline", "write_token_file"]
