"""TOM's two-phase distributed decode attention (paper C3, §IV-D.2 / Fig 7b).

The paper adapts flash-decoding to its reduction-tree hardware: instead of
each context tile maintaining rescaled partial outputs (the stock
flash-decoding combine), TOM first establishes the *global* softmax max with
one tree ``max`` round, then every lane rescales once and a single tree
``sum`` round produces the output:

    step 0: local scores sᵢ = q·Kᵢᵀ, local max mᵢ         (per lane)
    step 1: m = tree_max(mᵢ)                               (reduction tree)
    step 2: pᵢ = exp(sᵢ − m); dᵢ = Σ pᵢ                    (per lane)
    step 3: oᵢ = pᵢ · Vᵢ                                   (per lane)
    step 4: out = tree_sum(oᵢ) / tree_sum(dᵢ)              (reduction tree)

Stock flash-decoding (the baseline we compare against) avoids the early max
round by carrying (m, d, o) triples and combining with rescaling — optimal
when the combine is expensive (GPU kernel launches), while TOM's variant is
optimal when the tree is fast (on TPU: a pmax on a 16-wide ICI axis).

All three variants below are mathematically identical (tests assert
equivalence to the dense reference); KV may be fp8 (e4m3 + per-layer scale),
which is the paper's Act./KV format.

These functions run *inside* ``shard_map`` with the KV cache sharded along
the context dimension over the ``model`` axis (the paper's "KV cache is
distributed across the on-chip SRAMs, tiled across the context dimension").
Outside shard_map (axis_name=None) they degenerate to single-device
flash-decoding over one tile.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.lanes import tree_max, tree_sum

NEG_INF = -1e30


def _widen(x: jax.Array) -> jax.Array:
    return x.astype(jnp.float32)


# ---------------------------------------------------------------------------
# Dense reference (oracle)
# ---------------------------------------------------------------------------


def dense_decode_attention(
    q: jax.Array,          # (B, H, D)
    k: jax.Array,          # (B, H, S, D)
    v: jax.Array,          # (B, H, S, D)
    mask: Optional[jax.Array] = None,  # (B, S) True = attend
    scale: Optional[float] = None,
) -> jax.Array:
    """Single-token decode attention, materialized softmax. Ground truth."""
    d = q.shape[-1]
    scale = scale if scale is not None else d ** -0.5
    s = jnp.einsum("bhd,bhsd->bhs", _widen(q), _widen(k)) * scale
    if mask is not None:
        s = jnp.where(mask[:, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhs,bhsd->bhd", p, _widen(v))


# ---------------------------------------------------------------------------
# TOM two-phase flash decode (paper-faithful, C3)
# ---------------------------------------------------------------------------


def tom_flash_decode(
    q: jax.Array,               # (B, H, D)            replicated across lanes
    k_local: jax.Array,         # (B, H, S_local, D)   this lane's context tile
    v_local: jax.Array,         # (B, H, S_local, D)
    *,
    axis_name: Optional[str],
    mask_local: Optional[jax.Array] = None,  # (B, S_local)
    scale: Optional[float] = None,
    kv_scale: Optional[jax.Array] = None,    # fp8 KV dequant scale
) -> jax.Array:
    """Fig 7(b) dataflow: global-max round first, single rescale, tree-sum."""
    d = q.shape[-1]
    scale = scale if scale is not None else d ** -0.5
    kf = _widen(k_local)
    vf = _widen(v_local)
    if kv_scale is not None:
        kf = kf * kv_scale
        vf = vf * kv_scale

    # step 0: local scores + local max
    s = jnp.einsum("bhd,bhsd->bhs", _widen(q), kf) * scale
    if mask_local is not None:
        s = jnp.where(mask_local[:, None, :], s, NEG_INF)
    m_local = jnp.max(s, axis=-1)                      # (B, H)

    # step 1: global max via the reduction tree
    m = tree_max(m_local, axis_name)

    # step 2: rescale once, local denominator
    p = jnp.exp(s - m[..., None])                      # (B, H, S_local)
    d_local = jnp.sum(p, axis=-1)                      # (B, H)

    # step 3: local weighted values
    o_local = jnp.einsum("bhs,bhsd->bhd", p, vf)       # (B, H, D)

    # step 4: single tree-sum round for numerator and denominator
    o = tree_sum(o_local, axis_name)
    den = tree_sum(d_local, axis_name)
    return o / jnp.maximum(den[..., None], 1e-30)


# ---------------------------------------------------------------------------
# Stock flash-decoding baseline (per-tile rescale + (m, d, o) combine)
# ---------------------------------------------------------------------------


def stock_flash_decode(
    q: jax.Array,
    k_local: jax.Array,
    v_local: jax.Array,
    *,
    axis_name: Optional[str],
    mask_local: Optional[jax.Array] = None,
    scale: Optional[float] = None,
    kv_scale: Optional[jax.Array] = None,
) -> jax.Array:
    """Flash-decoding as on GPUs: each tile produces (m, d, o·d) with its own
    max; the cross-tile combine rescales by exp(mᵢ − m). On the tree hardware
    this costs the same collectives but extra lane-local exp/mul work — the
    trade the paper calls out. Kept as the comparison baseline."""
    d = q.shape[-1]
    scale = scale if scale is not None else d ** -0.5
    kf = _widen(k_local)
    vf = _widen(v_local)
    if kv_scale is not None:
        kf = kf * kv_scale
        vf = vf * kv_scale

    s = jnp.einsum("bhd,bhsd->bhs", _widen(q), kf) * scale
    if mask_local is not None:
        s = jnp.where(mask_local[:, None, :], s, NEG_INF)
    m_local = jnp.max(s, axis=-1)
    p = jnp.exp(s - m_local[..., None])
    d_local = jnp.sum(p, axis=-1)
    o_local = jnp.einsum("bhs,bhsd->bhd", p, vf)

    # combine: global max, rescale each tile's (d, o) by exp(m_local − m)
    m = tree_max(m_local, axis_name)
    corr = jnp.exp(m_local - m)
    o = tree_sum(o_local * corr[..., None], axis_name)
    den = tree_sum(d_local * corr, axis_name)
    return o / jnp.maximum(den[..., None], 1e-30)


# ---------------------------------------------------------------------------
# Chunked single-device flash decode (used when context is lane-local, and by
# the long-context path to bound VMEM)
# ---------------------------------------------------------------------------


def chunked_flash_decode(
    q: jax.Array,               # (B, H, D)
    k: jax.Array,               # (B, H, S, D)
    v: jax.Array,
    *,
    chunk: int = 2048,
    mask: Optional[jax.Array] = None,
    scale: Optional[float] = None,
) -> jax.Array:
    """Online-softmax decode over context chunks with lax.scan (O(chunk) live
    scores). Mirrors what the Pallas flash_decode kernel does in VMEM."""
    b, h, s_len, d = k.shape
    scale = scale if scale is not None else d ** -0.5
    n_chunks = -(-s_len // chunk)
    pad = n_chunks * chunk - s_len
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        pad_mask = jnp.arange(n_chunks * chunk) < s_len
        mask = pad_mask[None, :] & (mask if mask is not None else True)
    kc = k.reshape(b, h, n_chunks, chunk, d).transpose(2, 0, 1, 3, 4)
    vc = v.reshape(b, h, n_chunks, chunk, d).transpose(2, 0, 1, 3, 4)
    if mask is not None:
        mc = jnp.broadcast_to(mask, (b, n_chunks * chunk)).reshape(b, n_chunks, chunk)
        mc = mc.transpose(1, 0, 2)
    else:
        mc = jnp.ones((n_chunks, b, chunk), bool)

    qf = _widen(q)

    def step(carry, inp):
        m_run, d_run, o_run = carry
        k_i, v_i, msk = inp
        s = jnp.einsum("bhd,bhsd->bhs", qf, _widen(k_i)) * scale
        s = jnp.where(msk[:, None, :], s, NEG_INF)
        m_new = jnp.maximum(m_run, jnp.max(s, axis=-1))
        corr = jnp.exp(m_run - m_new)
        p = jnp.exp(s - m_new[..., None])
        d_new = d_run * corr + jnp.sum(p, axis=-1)
        o_new = o_run * corr[..., None] + jnp.einsum("bhs,bhsd->bhd", p, _widen(v_i))
        return (m_new, d_new, o_new), None

    init = (
        jnp.full((b, h), NEG_INF, jnp.float32),
        jnp.zeros((b, h), jnp.float32),
        jnp.zeros((b, h, d), jnp.float32),
    )
    (m_f, d_f, o_f), _ = jax.lax.scan(step, init, (kc, vc, mc))
    return o_f / jnp.maximum(d_f[..., None], 1e-30)


# ---------------------------------------------------------------------------
# GQA wrapper: expand KV heads to query heads lazily via reshape-free einsum
# ---------------------------------------------------------------------------


def gqa_decode(
    q: jax.Array,             # (B, Hq, D)
    k_local: jax.Array,       # (B, Hkv, S_local, D)
    v_local: jax.Array,
    *,
    axis_name: Optional[str],
    variant: str = "tom",     # tom | stock
    mask_local: Optional[jax.Array] = None,
    scale: Optional[float] = None,
    kv_scale: Optional[jax.Array] = None,
) -> jax.Array:
    """Grouped-query decode: Hq queries share Hkv KV heads (Hq % Hkv == 0).

    Internally reshapes queries to (B, Hkv, G, D) and folds the group dim into
    the score einsum so KV is never materialized per-query-head.
    """
    b, hq, d = q.shape
    hkv = k_local.shape[1]
    assert hq % hkv == 0, (hq, hkv)
    g = hq // hkv
    scale = scale if scale is not None else d ** -0.5
    qg = q.reshape(b, hkv, g, d)
    kf = _widen(k_local)
    vf = _widen(v_local)
    if kv_scale is not None:
        kf = kf * kv_scale
        vf = vf * kv_scale

    s = jnp.einsum("bhgd,bhsd->bhgs", _widen(qg), kf) * scale
    if mask_local is not None:
        s = jnp.where(mask_local[:, None, None, :], s, NEG_INF)
    m_local = jnp.max(s, axis=-1)

    if variant == "tom":
        m = tree_max(m_local, axis_name)
        p = jnp.exp(s - m[..., None])
        d_local = jnp.sum(p, axis=-1)
        o_local = jnp.einsum("bhgs,bhsd->bhgd", p, vf)
        o = tree_sum(o_local, axis_name)
        den = tree_sum(d_local, axis_name)
    else:
        p = jnp.exp(s - m_local[..., None])
        d_local = jnp.sum(p, axis=-1)
        o_local = jnp.einsum("bhgs,bhsd->bhgd", p, vf)
        m = tree_max(m_local, axis_name)
        corr = jnp.exp(m_local - m)
        o = tree_sum(o_local * corr[..., None], axis_name)
        den = tree_sum(d_local * corr, axis_name)
    out = o / jnp.maximum(den[..., None], 1e-30)
    return out.reshape(b, hq, d)
