"""FP8 (e4m3) activation / KV-cache quantisation.

TOM's heterogeneous-precision scheme (§IV-C.c): linears run Ternary×FP8 and
attention runs FP8×FP8. On TPU we keep values in ``float8_e4m3fn`` with
per-tensor (or per-head) power-of-two-friendly scales, and widen to bf16 at
the MXU boundary (fp8 dot is emulated on CPU; on TPU v5e+ the MXU consumes
bf16 — fp8 here buys *bytes* in HBM/VMEM for the KV cache, which is the
memory-roofline lever, mirroring the paper's SRAM-capacity argument).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

E4M3_MAX = 448.0
EPS = 1e-12


def quantize(x: jax.Array, axis=None) -> Tuple[jax.Array, jax.Array]:
    """Symmetric absmax quantisation to e4m3. Returns (x8, scale_f32)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=axis, keepdims=axis is not None)
    scale = jnp.maximum(amax, EPS) / E4M3_MAX
    x8 = (x.astype(jnp.float32) / scale).astype(jnp.float8_e4m3fn)
    return x8, scale.astype(jnp.float32)


def dequantize(x8: jax.Array, scale: jax.Array, dtype=jnp.bfloat16) -> jax.Array:
    return (x8.astype(jnp.float32) * scale).astype(dtype)


def fake_quantize(x: jax.Array, axis=None) -> jax.Array:
    """Round-trip through e4m3 (QAT / accuracy studies). Differentiable via STE."""
    x8, s = quantize(x, axis=axis)
    xq = dequantize(x8, s, dtype=x.dtype)
    return x + jax.lax.stop_gradient(xq - x)
