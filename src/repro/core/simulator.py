"""Cycle-approximate TOM performance simulator (paper §V evaluation vehicle).

The paper evaluates TOM with a Verilator cycle-accurate model of the Table I
configuration. This module is the analytical counterpart: a mechanistic cycle
model of the lane/MVU microarchitecture, with three documented calibration
factors for pipeline details the paper does not publish. It reproduces:

    Fig 11(b): TBT 302.4 µs with FFN 44% / AS+AV 34% share  → 3306 TPS
    Fig 13   : TTFT / TBT / end-to-end vs CPU + A100 baselines
    Fig 12   : power via core.powergate
    Fig 15   : LoRA and context-length scaling overheads

Microarchitecture model (from §IV-C and Table I):

  * Linear (Ternary×FP8) GEMVs tile the contracting dim K across all
    lanes×MVUs (Fig 7a: "tiled in input hidden dimension ... in different
    lanes"; the chained MVUs stream the activation). Each MVU's 128-wide
    ternary adder tree evaluates ``floor(128 / K_mvu)`` outputs per cycle
    when its K-slice is narrow, or ``ceil(K_mvu / 128)`` cycles per output
    when wide.
  * Attention (FP8×FP8) tiles the KV cache across MVUs over the *context*
    dimension (§IV-D.2); each local token's q·k / p·v dot products run on the
    16-wide FP8 engine (sharing the adder tree).
  * The Vector Unit (one per lane, width 16) executes softmax exp, norms,
    residuals, activation functions.
  * The global reduction tree is pipelined with compute (its latency is
    hidden except a per-round fill of log2(lanes) cycles).

Calibration factors (fitted once against the paper's three headline numbers,
each representing an unpublished pipeline property):

  * ``ETA_LINEAR``  = 0.967 — MVU utilization of linear GEMVs (ceil losses
    in N-tiling / bank conflicts).
  * ``FP8_EFF_MACS`` = 21.0 — effective FP8 MACs/cycle (nominal 16 + shared
    adder-tree assist; §IV-C.c says the FP8 unit shares the ternary tree).
  * ``OVERLAP_OTHER`` = 0.777 — fraction of projection/head GEMV time NOT
    hidden under attention/FFN by the systolic pipeline.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.configs.base import ModelConfig
from repro.core import rom
from repro.core.powergate import GatingSchedule, chip_power

# --- calibration (see module docstring) -------------------------------------
ETA_LINEAR = 0.976
FP8_EFF_MACS = 20.9
OVERLAP_OTHER = 0.777

# --- paper baseline reference points (Fig 13; derived from published ratios) -
#: A100 (bitnet.cpp GPU port, batch=1): TOM is 63.7x end-to-end at 256/256,
#: i.e. A100 ≈ 68 TPS — consistent with bitnet.cpp single-stream decode.
A100_TPS_256 = 68.0
A100_POWER_W = 300.0
#: i5-12500H (bitnet.cpp): TOM end-to-end energy efficiency is >4000x.
CPU_TPS_256 = 9.1
CPU_POWER_W = 45.0


@dataclass
class OpCycles:
    """Cycle cost of one op class for a single token through one layer."""

    linear: float = 0.0      # ternary×fp8 GEMVs (FFN + projections separately tracked)
    ffn: float = 0.0
    attention: float = 0.0   # AS + AV (fp8×fp8)
    vu: float = 0.0          # softmax/norm/residual/activation
    head: float = 0.0        # LM head (once per token)

    def total(self) -> float:
        return self.linear + self.ffn + self.attention + self.vu + self.head


class TomSimulator:
    """Cycle-approximate model of a TOM chip running one model."""

    def __init__(self, cfg: ModelConfig, chip: rom.TomChipConfig = rom.DEFAULT_CHIP):
        self.cfg = cfg
        self.chip = chip

    # ------------------------------------------------------------------
    # primitive cost models
    # ------------------------------------------------------------------
    def _gemv_cycles(self, k: int, n: int) -> float:
        """Ternary×FP8 GEMV of a (K, N) weight, K tiled over all MVUs."""
        c = self.chip
        k_mvu = max(1, math.ceil(k / c.n_mvus))
        w = c.ternary_macs_per_mvu_cycle
        if k_mvu <= w:
            outs_per_cycle = max(1, w // k_mvu)
            cycles = math.ceil(n / outs_per_cycle)
        else:
            cycles = math.ceil(k_mvu / w) * n
        return cycles / ETA_LINEAR

    def _attn_cycles(self, context: int) -> float:
        """AS + AV for one token (fp8×fp8), KV context-tiled across MVUs."""
        cfg, c = self.cfg, self.chip
        if cfg.attention_kind == "none":
            return 0.0
        local_tokens = context / c.n_mvus  # ideal balance; ceil handled by eta
        if cfg.attention_kind == "mla":
            m = cfg.mla
            dot_as = cfg.num_heads * (m.qk_nope_head_dim + m.qk_rope_head_dim)
            dot_av = cfg.num_heads * m.v_head_dim
        else:
            dot_as = cfg.num_heads * cfg.head_dim
            dot_av = cfg.num_heads * cfg.head_dim
        per_token = (dot_as + dot_av) / FP8_EFF_MACS
        return math.ceil(local_tokens) * per_token

    def _vu_cycles(self, context: int) -> float:
        """Norms, softmax exp, residuals, activation — per lane, width 16."""
        cfg, c = self.cfg, self.chip
        d_lane = cfg.d_model / c.n_lanes
        cycles = 0.0
        cycles += 2 * d_lane / c.vu_width          # two norms
        cycles += 2 * d_lane / c.vu_width          # residual adds
        if cfg.attention_kind != "none":
            ctx_lane = context / c.n_lanes
            cycles += ctx_lane * cfg.num_heads / (c.vu_width * c.mvus_per_lane)  # exp
        dff = cfg.d_ff if cfg.moe is None else (cfg.moe.expert_d_ff or cfg.d_ff)
        cycles += (dff / c.n_lanes) / c.vu_width   # activation fn
        return cycles

    # ------------------------------------------------------------------
    # per-layer / per-token aggregation
    # ------------------------------------------------------------------
    def layer_cycles(self, context: int) -> OpCycles:
        cfg = self.cfg
        d = cfg.d_model
        op = OpCycles()

        has_attn = cfg.attention_kind != "none"
        n_attn, n_mamba = cfg._block_counts()
        frac_attn = n_attn / max(1, cfg.num_layers)
        frac_mamba = n_mamba / max(1, cfg.num_layers)

        # --- attention block (averaged if hybrid) -------------------------
        if has_attn:
            if cfg.attention_kind == "mla":
                m = cfg.mla
                qh = cfg.num_heads * (m.qk_nope_head_dim + m.qk_rope_head_dim)
                proj = (
                    self._gemv_cycles(d, m.q_lora_rank)
                    + self._gemv_cycles(m.q_lora_rank, qh)
                    + self._gemv_cycles(d, m.kv_lora_rank + m.qk_rope_head_dim)
                    + self._gemv_cycles(m.kv_lora_rank,
                                        cfg.num_heads * (m.qk_nope_head_dim + m.v_head_dim))
                    + self._gemv_cycles(cfg.num_heads * m.v_head_dim, d)
                )
            else:
                proj = (
                    self._gemv_cycles(d, cfg.q_dim)
                    + 2 * self._gemv_cycles(d, cfg.kv_dim)
                    + self._gemv_cycles(cfg.q_dim, d)
                )
            op.linear += frac_attn * proj * OVERLAP_OTHER
            op.attention += frac_attn * self._attn_cycles(context)

        # --- FFN ----------------------------------------------------------
        def ffn_cost(dff: int) -> float:
            mats = 3 if cfg.ffn_kind == "swiglu" else 2
            return (mats - 1) * self._gemv_cycles(d, dff) + self._gemv_cycles(dff, d)

        if cfg.moe is not None:
            e = cfg.moe
            k_act = e.num_experts_per_tok + e.num_shared_experts
            ffn = k_act * ffn_cost(e.expert_d_ff or cfg.d_ff)
            ffn += self._gemv_cycles(d, e.num_experts) * OVERLAP_OTHER  # router
            if e.dense_residual_d_ff:
                ffn += ffn_cost(e.dense_residual_d_ff)
        elif cfg.d_ff:
            ffn = ffn_cost(cfg.d_ff)
        else:
            ffn = 0.0
        op.ffn += frac_attn * ffn if (has_attn or cfg.moe) else 0.0

        # --- mamba2 block ---------------------------------------------------
        if cfg.ssm is not None and frac_mamba:
            s = cfg.ssm
            d_in = s.expand * d
            nheads = d_in // s.head_dim
            in_proj = self._gemv_cycles(d, 2 * d_in + 2 * s.num_groups * s.state_size + nheads)
            out_proj = self._gemv_cycles(d_in, d)
            op.linear += frac_mamba * (in_proj + out_proj) * OVERLAP_OTHER
            # state update (VU-class): d_in * state_size MACs on fp8 engines
            state_macs = d_in * s.state_size
            op.vu += frac_mamba * state_macs / (FP8_EFF_MACS * self.chip.n_mvus)
        op.vu += self._vu_cycles(context)
        return op

    def token_cycles(self, context: int) -> OpCycles:
        cfg = self.cfg
        per_layer = self.layer_cycles(context)
        tot = OpCycles(
            linear=per_layer.linear * cfg.num_layers,
            ffn=per_layer.ffn * cfg.num_layers,
            attention=per_layer.attention * cfg.num_layers,
            vu=per_layer.vu * cfg.num_layers,
        )
        tot.head = self._gemv_cycles(cfg.d_model, cfg.vocab_size) * OVERLAP_OTHER
        return tot

    # ------------------------------------------------------------------
    # headline metrics
    # ------------------------------------------------------------------
    def tbt_s(self, context: int = 1024, lora_targets: int = 0,
              lora_rank: int = 16) -> float:
        cycles = self.token_cycles(context).total()
        cycles += self._lora_cycles(lora_targets, lora_rank)
        return cycles / self.chip.freq_hz

    def _lora_cycles(self, n_targets: int, rank: int) -> float:
        """Two-path adapter overhead (Fig 15a): per target projection,
        A (d×r) then B (r×d) GEMVs on the same ternary engines."""
        if not n_targets:
            return 0.0
        d = self.cfg.d_model
        per = self._gemv_cycles(d, rank) + self._gemv_cycles(rank, d)
        return per * n_targets * self.cfg.num_layers

    def tps(self, context: int = 1024) -> float:
        return 1.0 / self.tbt_s(context)

    def ttft_s(self, prompt_len: int) -> float:
        """Token-by-token prefill (§IV-D.2: no prefill/decode distinction)."""
        total = 0.0
        for pos in range(prompt_len):
            total += self.token_cycles(max(pos, 1)).total()
        return total / self.chip.freq_hz

    def e2e_s(self, prompt_len: int, gen_len: int) -> float:
        t = self.ttft_s(prompt_len)
        for pos in range(prompt_len, prompt_len + gen_len):
            t += self.token_cycles(pos).total() / self.chip.freq_hz
        return t

    def e2e_tps(self, prompt_len: int, gen_len: int) -> float:
        return (prompt_len + gen_len) / self.e2e_s(prompt_len, gen_len)

    # ------------------------------------------------------------------
    # reports
    # ------------------------------------------------------------------
    def tbt_breakdown(self, context: int = 1024) -> Dict[str, float]:
        """Fig 11(b): share of per-token latency by component."""
        t = self.token_cycles(context)
        total = t.total()
        return {
            "ffn": t.ffn / total,
            "attention": t.attention / total,
            "projections": t.linear / total,
            "lm_head": t.head / total,
            "vector_unit": t.vu / total,
            "total_us": total / self.chip.freq_hz * 1e6,
        }

    def power_report(self, gating: bool = True):
        return chip_power(GatingSchedule(self.cfg.num_layers, gating_enabled=gating))

    def tokens_per_joule(self, context: int = 1024, gating: bool = True) -> float:
        return 1.0 / (self.tbt_s(context) * self.power_report(gating).total_w)

    def comparison_vs_baselines(self, prompt_len: int = 256, gen_len: int = 256
                                ) -> Dict[str, Dict[str, float]]:
        """Fig 13: speedup + energy-efficiency ratios vs A100 / CPU."""
        tom_tps = self.e2e_tps(prompt_len, gen_len)
        tom_w = self.power_report(True).total_w
        out = {}
        for name, tps, w in (("a100", A100_TPS_256, A100_POWER_W),
                             ("cpu", CPU_TPS_256, CPU_POWER_W)):
            out[name] = {
                "speedup": tom_tps / tps,
                "energy_efficiency": (tom_tps / tom_w) / (tps / w),
            }
        out["tom"] = {"tps": tom_tps, "power_w": tom_w}
        return out
