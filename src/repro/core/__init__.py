"""The paper's primary contribution as composable JAX modules.

C1 ternary+ROM  -> `ternary` (quant/pack/STE), `rom` (density/area/power model)
C2 lanes+tree   -> `lanes` (shard_map lane linears, tree_sum/tree_max)
C3 attention    -> `attention` (two-phase flash-decode vs stock vs dense)
C4 QLoRA        -> `qlora` (two-path execution, ternary adapters)
C5 power gating -> `powergate` (schedule + Fig 12 model), `simulator` (SecV)
plus `fp8` (heterogeneous-precision activations / KV cache).
"""
