"""Workload-aware dynamic power gating (paper C5, §IV-E / Fig 8 / Fig 12).

The silicon mechanism — logic-based ROM banks wake instantly, so the Global
Controller powers only the active layer's banks (pre-waking layer N+1 while
N executes) — has no direct JAX semantics. Per DESIGN.md §2.5 it is:

  1. *modeled* here: a gating schedule over the per-layer execution timeline
     (from `core.simulator`) integrates ROM power → reproduces Fig 12's
     25.813 W → 5.33 W and gives per-token energy for the efficiency figures;
  2. *adapted* at runtime: "power up layer N+1 while N executes" is exactly
     double-buffered weight prefetch, which the scan-over-layers serving path
     gets from XLA's operand prefetching (models/transformer.py).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Sequence

from repro.core import rom


@dataclass(frozen=True)
class GatingSchedule:
    """Which ROM banks are powered when (Fig 8)."""

    n_layers: int
    prewake_fraction: float = rom.PREWAKE_FRACTION  # of a layer's exec time
    gating_enabled: bool = True

    def powered_layer_fraction(self) -> float:
        """Time-averaged fraction of ROM banks powered."""
        if not self.gating_enabled or self.n_layers <= 1:
            return 1.0
        return min(1.0, (1.0 + self.prewake_fraction) / self.n_layers)


@dataclass(frozen=True)
class PowerReport:
    rom_w: float
    sram_w: float
    compute_w: float
    other_w: float

    @property
    def total_w(self) -> float:
        return self.rom_w + self.sram_w + self.compute_w + self.other_w

    def breakdown(self) -> Dict[str, float]:
        return {
            "rom": self.rom_w,
            "sram": self.sram_w,
            "compute": self.compute_w,
            "other": self.other_w,
            "total": self.total_w,
        }


# Fig 12's non-ROM 4.507 W split across SRAM/compute/other in proportion to
# their Fig 11a areas (SRAM 13.66 mm², compute 10.24 mm²) with a small fixed
# 'other' (clocking, IO, controller).
_SRAM_W = 2.20
_COMPUTE_W = 1.90
_OTHER_W = rom.POWER_NON_ROM_W - _SRAM_W - _COMPUTE_W


def chip_power(schedule: GatingSchedule,
               rom_ungated_w: float = rom.POWER_ROM_UNGATED_W) -> PowerReport:
    """Fig 12 reproduction: gating drops total from 25.813 W to 5.33 W."""
    frac = schedule.powered_layer_fraction()
    return PowerReport(
        rom_w=rom_ungated_w * frac,
        sram_w=_SRAM_W,
        compute_w=_COMPUTE_W,
        other_w=_OTHER_W,
    )


def energy_per_token_j(schedule: GatingSchedule, tbt_s: float) -> float:
    return chip_power(schedule).total_w * tbt_s


#: Fraction of SRAM power that is leakage/retention (drawn even when the
#: arrays are idle); the rest scales with how much of the SRAM budget is
#: actually resident (KV pages + pinned adapters).
SRAM_STATIC_FRACTION = 0.2


def live_power(schedule: GatingSchedule, *, exec_fraction: float,
               sram_utilization: float = 1.0) -> PowerReport:
    """Fig-12 power model driven by *live* engine state over a wall-clock
    window (the measurement half of workload-aware gating).

    ``exec_fraction`` — fraction of the window the device actually spent
    executing layers (decode / verify / prefill dispatches). While
    executing, gating keeps only the active layer (+ pre-wake) powered —
    `powered_layer_fraction`; while the host stalls between dispatches
    every ROM bank is gated, so ROM and compute power scale with
    ``exec_fraction``. ``sram_utilization`` — occupancy of the SRAM budget
    (KV page-pool occupancy / resident-adapter bytes): SRAM retention is
    charged on the resident fraction plus a static floor, because unlike
    ROM banks the KV arrays must hold state across the idle gaps. The
    ``other`` rail (clock/IO/controller) is always on.
    """
    exec_fraction = min(max(exec_fraction, 0.0), 1.0)
    sram_utilization = min(max(sram_utilization, 0.0), 1.0)
    return PowerReport(
        rom_w=rom.POWER_ROM_UNGATED_W
        * schedule.powered_layer_fraction() * exec_fraction,
        sram_w=_SRAM_W * (SRAM_STATIC_FRACTION
                          + (1.0 - SRAM_STATIC_FRACTION) * sram_utilization),
        compute_w=_COMPUTE_W * exec_fraction,
        other_w=_OTHER_W,
    )


def gating_timeline(n_layers: int, layer_cycles: Sequence[int],
                    prewake_fraction: float = rom.PREWAKE_FRACTION
                    ) -> List[Dict[str, float]]:
    """Cycle-resolved schedule (Fig 8): for each layer interval, which banks
    are on. Returned as a list of {layer, start, end, powered_layers} events —
    consumed by benchmarks/bench_power.py to plot the gating waveform."""
    events = []
    t = 0
    for i, c in enumerate(layer_cycles):
        wake_at = t + (1.0 - prewake_fraction) * c
        events.append({
            "layer": i,
            "start": float(t),
            "prewake_next_at": float(wake_at) if i + 1 < n_layers else None,
            "end": float(t + c),
            "powered": [i] if i + 1 >= n_layers else [i, i + 1],
        })
        t += c
    return events
