"""Explicit-lanes decode: the paper's dataflow written as `shard_map`.

The GSPMD serve path (models/transformer.py) lets XLA's partitioner derive
TOM's collectives from shardings. This module is the ground truth the other
direction: every lane's program is written out exactly as §IV-C/D describes —

    per layer:
      1. q/k/v/o GEMVs: each lane multiplies its K-slice of the packed
         ternary ROM against its activation slice; partial sums cross the
         reduction tree (ONE psum per GEMV — Fig 7a)
      2. decode attention: KV tiled across lanes over the context dim;
         two-phase softmax = pmax round, rescale, psum round (Fig 7b)
      3. FFN: same lane-tiled ternary GEMVs
    lanes never exchange data except via tree_sum/tree_max.

Dense GQA architectures (the paper's BitNet-2B class). Tests assert
equivalence with the GSPMD decode on a multi-device host mesh, which is the
claim in DESIGN.md §2.2: the partitioner's lowering and the hand-written
lane program compute the same function.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core import attention as core_attn
from repro.core import ternary
from repro.core.lanes import tree_sum
from repro.models.layers import KV_CACHE_SCALE, Params

AXIS = "model"


# ---------------------------------------------------------------------------
# lane-local primitives
# ---------------------------------------------------------------------------


def _lane_linear_packed(x_local: jax.Array, packed_local: jax.Array,
                        scale: jax.Array, *, reduce: bool = True) -> jax.Array:
    """x (B, K/L) @ ROM-slice (K/L / 4, N) ×scale, tree-reduced (Fig 7a)."""
    w = ternary.unpack2(packed_local).astype(jnp.bfloat16)
    y = jnp.einsum("bk,kn->bn", x_local.astype(jnp.bfloat16), w,
                   preferred_element_type=jnp.float32) * scale
    return tree_sum(y, AXIS) if reduce else y


def _split_x(x: jax.Array) -> jax.Array:
    """Take this lane's K-slice of a replicated activation."""
    lanes = jax.lax.psum(1, AXIS)
    idx = jax.lax.axis_index(AXIS)
    k_local = x.shape[-1] // lanes
    return jax.lax.dynamic_slice_in_dim(x, idx * k_local, k_local, axis=-1)


def _rms_norm(x, w, eps):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * w).astype(x.dtype)


def _rope(x, pos, theta):
    d = x.shape[-1]
    freqs = 1.0 / (theta ** (jnp.arange(0, d, 2, dtype=jnp.float32) / d))
    ang = pos.astype(jnp.float32)[..., None, None] * freqs  # (B,1,D/2)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * jnp.cos(ang) - x2 * jnp.sin(ang),
                           x1 * jnp.sin(ang) + x2 * jnp.cos(ang)], -1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# one decoder layer, lane-resident
# ---------------------------------------------------------------------------


def _lane_layer(lp: Params, x: jax.Array, kc: jax.Array, vc: jax.Array,
                pos: jax.Array, cfg: ModelConfig):
    """x: (B, D) replicated; kc/vc: (B, Hkv, S/L, D) lane-local context tile;
    pos: scalar (single-stream decode — the paper's regime).

    Returns (x', kc', vc'). Every GEMV = local partial + tree_sum; attention
    = Fig 7b two-phase over the lane-tiled cache."""
    eps = cfg.norm_eps
    h = _rms_norm(x, lp["norm1"]["w"], eps)
    hl = _split_x(h)

    q = _lane_linear_packed(hl, lp["attn"]["q"]["packed"], lp["attn"]["q"]["scale"])
    k = _lane_linear_packed(hl, lp["attn"]["k"]["packed"], lp["attn"]["k"]["scale"])
    v = _lane_linear_packed(hl, lp["attn"]["v"]["packed"], lp["attn"]["v"]["scale"])
    b = x.shape[0]
    q = q.reshape(b, cfg.num_heads, cfg.head_dim)
    k = k.reshape(b, cfg.num_kv_heads, cfg.head_dim)
    v = v.reshape(b, cfg.num_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = _rms_norm(q, lp["attn"]["q_norm"]["w"], eps)
        k = _rms_norm(k, lp["attn"]["k_norm"]["w"], eps)
    posb = jnp.broadcast_to(pos[None], (b,))
    q = _rope(q, posb, cfg.rope_theta)
    k = _rope(k, posb, cfg.rope_theta)

    # --- cache insert: pos lands in exactly one lane's context tile --------
    lanes = jax.lax.psum(1, AXIS)
    lane = jax.lax.axis_index(AXIS)
    s_local = kc.shape[2]
    owner = pos // s_local                    # which lane owns this position
    local_pos = pos % s_local
    k_q = (k / KV_CACHE_SCALE).astype(kc.dtype)
    v_q = (v / KV_CACHE_SCALE).astype(vc.dtype)
    kc_new = jax.lax.dynamic_update_slice(kc, k_q[:, :, None], (0, 0, local_pos, 0))
    vc_new = jax.lax.dynamic_update_slice(vc, v_q[:, :, None], (0, 0, local_pos, 0))
    is_owner = (owner == lane)  # scalar pos → scalar predicate
    kc = jnp.where(is_owner, kc_new, kc)
    vc = jnp.where(is_owner, vc_new, vc)

    # --- two-phase attention over lane tiles (Fig 7b) ----------------------
    base = lane * s_local
    mask_local = (base + jnp.arange(s_local)) <= pos          # (S/L,)
    mask_local = jnp.broadcast_to(mask_local[None], (b, s_local))
    kf = kc.astype(jnp.float32) * KV_CACHE_SCALE
    vf = vc.astype(jnp.float32) * KV_CACHE_SCALE
    attn = core_attn.gqa_decode(q, kf, vf, axis_name=AXIS, variant="tom",
                                mask_local=mask_local)
    attn = attn.reshape(b, cfg.q_dim).astype(x.dtype)

    o = _lane_linear_packed(_split_x(attn), lp["attn"]["o"]["packed"],
                            lp["attn"]["o"]["scale"]).astype(x.dtype)
    x = x + o

    h2 = _rms_norm(x, lp["norm2"]["w"], eps)
    h2l = _split_x(h2)
    up = _lane_linear_packed(h2l, lp["ffn"]["up"]["packed"],
                             lp["ffn"]["up"]["scale"])
    if cfg.ffn_kind == "swiglu":
        gate = _lane_linear_packed(h2l, lp["ffn"]["gate"]["packed"],
                                   lp["ffn"]["gate"]["scale"])
        act = jax.nn.silu(gate) * up
    elif cfg.ffn_kind == "relu2":
        act = jnp.square(jax.nn.relu(up))
    else:
        act = jax.nn.gelu(up)
    act = act.astype(x.dtype)
    down = _lane_linear_packed(_split_x(act), lp["ffn"]["down"]["packed"],
                               lp["ffn"]["down"]["scale"]).astype(x.dtype)
    return x + down, kc, vc


# ---------------------------------------------------------------------------
# whole-model decode step under shard_map
# ---------------------------------------------------------------------------


def make_lane_decode_step(cfg: ModelConfig, mesh: Mesh):
    """Explicit-lane decode step for dense GQA serve-mode params.

    Signature matches Model.decode_step: (params, cache, token (B,), pos ())
    → (logits (B, V), cache). Only the 'model' axis participates; batch
    stays replicated (the paper's single-stream regime)."""
    assert cfg.attention_kind == "gqa" and cfg.moe is None and cfg.ssm is None

    def body(params, k_cache, v_cache, token, pos):
        # embedding rows are replicated (packed_rows gather is local)
        emb = params["embed"]
        from repro.models.layers import unpack_rows
        x = (unpack_rows(emb["packed_rows"][token]).astype(jnp.float32)
             * emb["scale"]).astype(jnp.bfloat16)

        def layer(carry, inp):
            xc, = carry
            lp, kc, vc = inp
            xc, kc, vc = _lane_layer(lp, xc, kc, vc, pos, cfg)
            return (xc,), (kc, vc)

        (x,), (k_new, v_new) = jax.lax.scan(
            layer, (x,), (params["layers"], k_cache, v_cache))
        x = _rms_norm(x, params["final_norm"]["w"], cfg.norm_eps)
        if cfg.tie_embeddings:
            w = (unpack_rows(emb["packed_rows"]).astype(jnp.float32)
                 * emb["scale"])
            logits = jnp.einsum("bd,vd->bv", x.astype(jnp.float32), w)
        else:
            logits = _lane_linear_packed(_split_x(x), params["head"]["packed"],
                                         params["head"]["scale"])
        if cfg.vocab_padded != cfg.vocab_size:
            pad_mask = jnp.arange(cfg.vocab_padded) < cfg.vocab_size
            logits = jnp.where(pad_mask, logits, -1e30)
        return logits, k_new, v_new

    # shardings: weights K-sharded over lanes (packed K/4 rows), caches
    # context-sharded, activations/token/logits replicated.
    def build_param_specs(params):
        def spec_for(path, leaf):
            joined = "/".join(str(getattr(e, "key", e)) for e in path)
            if "packed_rows" in joined or "norm" in joined or "scale" in joined:
                return P()
            if joined.endswith("packed"):
                return P(*([None] * (leaf.ndim - 2)), AXIS, None)
            return P()
        return jax.tree_util.tree_map_with_path(spec_for, params)

    def step(params, cache, token, pos):
        in_specs = (build_param_specs(params),
                    P(None, None, None, AXIS, None),   # k (L,B,H,S,D): S over lanes
                    P(None, None, None, AXIS, None),
                    P(), P())
        out_specs = (P(), P(None, None, None, AXIS, None),
                     P(None, None, None, AXIS, None))
        fn = shard_map(body, mesh=mesh, in_specs=in_specs,
                       out_specs=out_specs, check_rep=False)
        logits, k_new, v_new = fn(params, cache["k"], cache["v"], token, pos)
        return logits, {"k": k_new, "v": v_new}

    return step
