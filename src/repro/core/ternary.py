"""Ternary quantisation core (paper C1).

Implements BitNet-style absmean ternary quantisation, the paper's 2-bit
encoding (``+1='01'``, ``-1='10'``, ``0='00'`` — chosen over ``'11'`` for −1
specifically to maximise the zero-*bit* ratio, §III-C / Fig 4), dense 2-bit
packing (4 weights/byte — the HBM analogue of the sparsity-aware ROM), and a
straight-through estimator for QAT.

Layout note (TPU co-design): packing is along the *contracting* (input/K)
dimension so that the Pallas matmul kernel can stream packed K-tiles
HBM→VMEM and decode in-registers before hitting the MXU. Two layouts:

- ``interleaved``: byte ``k`` of a column packs rows ``4k..4k+3``
  (bits 0-1 = row 4k). Simple, reference layout.
- ``strided``  : within each K-tile of ``tile`` rows, byte ``j`` packs rows
  ``j, j+t/4, j+t/2, j+3t/4`` of the tile. Decoding is then a plain
  concatenate along sublanes — no interleaving reshape — which lowers to
  cheaper Mosaic ops. Used by the optimized kernel path.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

EPS = 1e-8

# ---------------------------------------------------------------------------
# absmean quantisation (BitNet b1.58)
# ---------------------------------------------------------------------------


def absmean_scale(w: jax.Array, axis=None) -> jax.Array:
    """BitNet b1.58 scale: mean of |w| (per-tensor by default)."""
    return jnp.mean(jnp.abs(w).astype(jnp.float32), axis=axis, keepdims=axis is not None)


def quantize(w: jax.Array, axis=None) -> Tuple[jax.Array, jax.Array]:
    """absmean ternary quantisation.

    Returns ``(t, scale)`` with ``t`` int8 in {-1, 0, +1} and ``w ≈ t*scale``.
    """
    s = absmean_scale(w, axis=axis)
    t = jnp.clip(jnp.round(w.astype(jnp.float32) / (s + EPS)), -1, 1).astype(jnp.int8)
    return t, s.astype(jnp.float32)


def dequantize(t: jax.Array, scale: jax.Array, dtype=jnp.bfloat16) -> jax.Array:
    return (t.astype(jnp.float32) * scale).astype(dtype)


def ste_quantize(w: jax.Array, axis=None) -> jax.Array:
    """Straight-through-estimator fake-quant: forward = t*scale, grad = id.

    This is the QAT path (BitNet training / LoTA-QAF ternary adapters).
    """
    t, s = quantize(w, axis=axis)
    wq = dequantize(t, s, dtype=w.dtype)
    return w + jax.lax.stop_gradient(wq - w)


# ---------------------------------------------------------------------------
# 2-bit encoding & bit statistics (paper Fig 4)
# ---------------------------------------------------------------------------


def encode2(t: jax.Array) -> jax.Array:
    """Ternary {-1,0,+1} → 2-bit code {2,0,1} (uint8): +1→'01', -1→'10', 0→'00'."""
    ti = t.astype(jnp.int8)
    return jnp.where(ti == 1, jnp.uint8(1), jnp.where(ti == -1, jnp.uint8(2), jnp.uint8(0)))


def decode2(c: jax.Array) -> jax.Array:
    """2-bit code → ternary int8: the paper's conditional-negation decode."""
    ci = c.astype(jnp.int8)
    return ((ci & 1) - ((ci >> 1) & 1)).astype(jnp.int8)


def zero_value_ratio(t: jax.Array) -> jax.Array:
    """Fraction of zero-valued weights."""
    return jnp.mean((t == 0).astype(jnp.float32))


def zero_bit_ratio(t: jax.Array) -> jax.Array:
    """Fraction of zero BITS under the paper's encoding.

    Each zero weight contributes 2 zero-bits; each ±1 weight exactly one
    (this is why '10' encodes −1 instead of '11'). So
    ``zbr = 1 − (1 − zvr)/2``; e.g. BitNet's ~40% zero weights → ~70%
    zero-bits (paper §V-B.b).
    """
    zvr = zero_value_ratio(t)
    return 1.0 - (1.0 - zvr) / 2.0


# ---------------------------------------------------------------------------
# Dense 2-bit packing (4 weights / byte) along the K (contracting) axis
# ---------------------------------------------------------------------------


def pack2(t: jax.Array, layout: str = "interleaved", tile: int = 512) -> jax.Array:
    """Pack ternary int8 ``(..., K, N)`` → uint8 ``(..., K//4, N)``.

    ``K`` (second-to-last axis) must be divisible by 4 (and by ``tile`` for the
    strided layout).
    """
    k = t.shape[-2]
    if k % 4:
        raise ValueError(f"K={k} not divisible by 4")
    c = encode2(t)
    if layout == "interleaved":
        g = c.reshape(*c.shape[:-2], k // 4, 4, c.shape[-1])
        return (
            g[..., 0, :]
            | (g[..., 1, :] << 2)
            | (g[..., 2, :] << 4)
            | (g[..., 3, :] << 6)
        ).astype(jnp.uint8)
    elif layout == "strided":
        if k % tile:
            raise ValueError(f"K={k} not divisible by tile={tile}")
        q = tile // 4
        # (.., n_tiles, 4, q, N): slot s of byte j in tile covers row s*q + j
        g = c.reshape(*c.shape[:-2], k // tile, 4, q, c.shape[-1])
        packed = (
            g[..., 0, :, :]
            | (g[..., 1, :, :] << 2)
            | (g[..., 2, :, :] << 4)
            | (g[..., 3, :, :] << 6)
        )
        return packed.reshape(*c.shape[:-2], k // 4, c.shape[-1]).astype(jnp.uint8)
    raise ValueError(f"unknown layout {layout!r}")


def unpack2(p: jax.Array, layout: str = "interleaved", tile: int = 512) -> jax.Array:
    """Inverse of :func:`pack2`: uint8 ``(..., K//4, N)`` → int8 ``(..., K, N)``."""
    kq = p.shape[-2]
    if layout == "interleaved":
        slots = [decode2((p >> (2 * i)) & 3) for i in range(4)]
        st = jnp.stack(slots, axis=-2)  # (..., K//4, 4, N)
        return st.reshape(*p.shape[:-2], kq * 4, p.shape[-1])
    elif layout == "strided":
        q = tile // 4
        if kq % q:
            raise ValueError(f"packed K={kq} not divisible by tile//4={q}")
        pt = p.reshape(*p.shape[:-2], kq // q, q, p.shape[-1])
        slots = [decode2((pt >> (2 * i)) & 3) for i in range(4)]
        st = jnp.concatenate(slots, axis=-2)  # (..., n_tiles, tile, N)
        return st.reshape(*p.shape[:-2], kq * 4, p.shape[-1])
    raise ValueError(f"unknown layout {layout!r}")


# ---------------------------------------------------------------------------
# Packed-weight container used by the model layers
# ---------------------------------------------------------------------------


@jax.tree_util.register_pytree_node_class
class TernaryTensor:
    """A ternary weight in its 'ROM' (packed) form.

    ``packed``: uint8 (K//4, N); ``scale``: f32 scalar (absmean);
    ``shape`` = logical (K, N). The optimizer never touches this — it is the
    immutable 'knowledge foundation'; tunability goes through QLoRA adapters.
    """

    __slots__ = ("packed", "scale", "k", "layout", "tile")

    def __init__(self, packed: jax.Array, scale: jax.Array, k: int,
                 layout: str = "interleaved", tile: int = 512):
        self.packed = packed
        self.scale = scale
        self.k = int(k)
        self.layout = layout
        self.tile = int(tile)

    @property
    def shape(self) -> Tuple[int, int]:
        return (self.k, self.packed.shape[-1])

    @classmethod
    def from_dense(cls, w: jax.Array, layout: str = "interleaved", tile: int = 512
                   ) -> "TernaryTensor":
        t, s = quantize(w)
        return cls(pack2(t, layout=layout, tile=tile), s, w.shape[-2], layout, tile)

    def to_dense(self, dtype=jnp.bfloat16) -> jax.Array:
        t = unpack2(self.packed, layout=self.layout, tile=self.tile)
        return dequantize(t, self.scale, dtype=dtype)

    # -- pytree protocol ----------------------------------------------------
    def tree_flatten(self):
        return (self.packed, self.scale), (self.k, self.layout, self.tile)

    @classmethod
    def tree_unflatten(cls, aux, children):
        packed, scale = children
        k, layout, tile = aux
        return cls(packed, scale, k, layout, tile)

    def __repr__(self):
        return f"TernaryTensor(shape={self.shape}, layout={self.layout!r})"


def nbytes_packed(shape: Tuple[int, int]) -> int:
    k, n = shape
    return (k // 4) * n + 4  # + scale


def compression_ratio_vs(dtype_bytes: float, shape: Tuple[int, int]) -> float:
    k, n = shape
    return (k * n * dtype_bytes) / nbytes_packed(shape)
