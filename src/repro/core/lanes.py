"""Distributed Processing Lanes + global reduction tree (paper C2, §IV-C).

TOM's lane architecture maps 1:1 onto JAX SPMD over the ``model`` mesh axis
(DESIGN.md §2.2):

    Processing Lane      ≙ one device along the ``model`` axis
    local ROM            ≙ the lane's shard of every packed-ternary weight
    local SRAM           ≙ the lane's shard of the KV cache / adapters
    global reduction tree≙ ``psum`` / ``pmax`` over the ``model`` axis
    "no direct cross-lane communication"
                         ≙ the paper-faithful path uses ONLY tree collectives
                           (no all_to_all / ppermute on the model axis)

Linear layers follow Fig 7(a): the weight is tiled along the *input hidden*
(K, contracting) dimension; every lane computes a partial GEMV against its
activation slice; the reduction tree sums the partials. The functions here
are written to run *inside* ``shard_map`` (they take the axis name) with pure
single-device reference versions alongside.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import ternary


@dataclass(frozen=True)
class LaneConfig:
    """The paper's Table I lane geometry (informational at JAX level; the mesh
    decides real lane count — 16 on the production mesh, matching the paper)."""

    n_lanes: int = 16
    mvus_per_lane: int = 10


# ---------------------------------------------------------------------------
# Reduction tree
# ---------------------------------------------------------------------------


def tree_sum(x: jax.Array, axis_name: Optional[str]) -> jax.Array:
    """Global reduction tree, sum port. Identity outside shard_map."""
    return jax.lax.psum(x, axis_name) if axis_name else x


def tree_max(x: jax.Array, axis_name: Optional[str]) -> jax.Array:
    """Global reduction tree, max port (used by two-phase attention, C3)."""
    return jax.lax.pmax(x, axis_name) if axis_name else x


# ---------------------------------------------------------------------------
# Lane-tiled linear layers (Fig 7a)
# ---------------------------------------------------------------------------


def lane_linear(
    x_local: jax.Array,
    w_local: jax.Array,
    *,
    axis_name: Optional[str],
    scale: Optional[jax.Array] = None,
    reduce: bool = True,
) -> jax.Array:
    """Input-dim-sharded linear: ``x_local (…, K/L) @ w_local (K/L, N)``.

    Each lane holds a K-slice of the weight ("its ROM banks") and the matching
    activation slice; partials are aggregated on the reduction tree. With
    ``reduce=False`` the caller is responsible for the psum (used to fuse the
    tree reduction of several projections into one collective).
    """
    y = jnp.einsum("...k,kn->...n", x_local, w_local.astype(x_local.dtype),
                   preferred_element_type=jnp.float32)
    if scale is not None:
        y = y * scale
    y = y.astype(x_local.dtype)
    return tree_sum(y, axis_name) if reduce else y


def lane_linear_ternary(
    x_local: jax.Array,
    packed_local: jax.Array,
    scale: jax.Array,
    *,
    axis_name: Optional[str],
    reduce: bool = True,
    layout: str = "interleaved",
    tile: int = 512,
) -> jax.Array:
    """Lane-tiled linear with the weight slice in packed 2-bit 'ROM' form.

    The decode (2-bit → ±1/0) happens lane-locally — the analogue of each
    MVU's combinational ROM logic feeding its own adder tree. This is the
    XLA path; the Pallas kernel (`kernels/ternary_matmul`) is the fused path
    selected by `ops.py` when shapes allow.
    """
    w = ternary.unpack2(packed_local, layout=layout, tile=tile)
    y = jnp.einsum("...k,kn->...n", x_local.astype(jnp.float32),
                   w.astype(jnp.float32), preferred_element_type=jnp.float32)
    y = (y * scale).astype(x_local.dtype)
    return tree_sum(y, axis_name) if reduce else y


def lane_linear_out_sharded(
    x_repl: jax.Array,
    w_local: jax.Array,
    *,
    scale: Optional[jax.Array] = None,
) -> jax.Array:
    """Output-dim-sharded linear: ``x (…, K) @ w_local (K, N/L)`` — no
    collective (each lane produces its own N-slice).

    The paper tiles K (Fig 7a) so the tree does one reduction per layer; an
    N-tiled layout instead leaves the *activation* sharded, which composes as
    reduce-scatter → the beyond-paper §Perf variant pairs K-tiled and N-tiled
    layers back-to-back so only boundary reductions remain.
    """
    y = jnp.einsum("...k,kn->...n", x_repl, w_local.astype(x_repl.dtype),
                   preferred_element_type=jnp.float32)
    if scale is not None:
        y = y * scale
    return y.astype(x_repl.dtype)


# ---------------------------------------------------------------------------
# Sharding helpers: how each weight kind is laid out over (data, model[, pod])
# ---------------------------------------------------------------------------


def shard_weight_k(k: int, n: int, n_lanes: int) -> Tuple[int, int]:
    """Fig 7a layout: K tiled across lanes."""
    assert k % n_lanes == 0, (k, n_lanes)
    return k // n_lanes, n


def pad_to_multiple(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m
