"""Sparsity-aware ROM analytical model (paper C1, §IV-B + §V-B).

TOM's headline silicon contribution is a ROM whose content is synthesized as
combinational standard-cell logic: zero-valued *bits* generate no logic (tied
to ground), one-bits cost gates which common-subexpression elimination (CSE)
further merges. The area of a bank is therefore a function of the weight
content's zero-bit ratio, the bank geometry (CSE scope vs routing congestion),
and the process node.

None of that synthesizes on a TPU — per DESIGN.md §2.1 the *runtime* analogue
is 2-bit packing in HBM — but every quantitative claim the paper makes about
the ROM (Fig 9, Fig 10, Tables II/III/IV, the Fig 11a area split, the Fig 12
power numbers) is reproduced here as an analytical model driven by real weight
statistics, calibrated against the published points:

    density(z=0.65, h=2048)  = 14.2 MB/mm²   (Fig 9)
    density(z=0.95, h=2048)  = 25.3 MB/mm²   (Fig 9)
    density(z=0.70, h=1024)  = 15.0 MB/mm²   (Fig 10 peak / §V-B.b headline)
    compiler ROM @7nm        = 4.30 MB/mm²   (Table II)
    compiler SRAM @7nm       = 2.75 MB/mm²   (inferred: 37.5 MB SRAM = 24% of
                                              56.9 mm² chip, Fig 11a)
    chip: 56.9 mm² = 58% ROM + 24% SRAM + 18% compute  (Fig 11a)
    power: 25.813 W total, 21.306 W ROM → 5.33 W gated (Fig 12)

Note on the paper's "5.2× denser than a standard ROM and 3.3× than SRAM":
Table II fixes compiler-ROM@7nm at 4.30 MB/mm², giving 14.2/4.30 = 3.3×, and
the Fig 11a-implied SRAM density of 2.75 MB/mm² gives 14.2/2.75 = 5.2×. The
two ratios in the prose are evidently swapped; we model the self-consistent
set (ROM 4.30, SRAM 2.75) and reproduce both ratios.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Dict, Iterable, Optional, Tuple

import numpy as np

MB = float(1 << 20)  # bytes

# ---------------------------------------------------------------------------
# Process-node scaling (paper Table II)
# ---------------------------------------------------------------------------

#: Compiler-generated 2048x64 ROM density by node, MB/mm² (Table II).
COMPILER_ROM_DENSITY = {65: 0.357, 28: 1.308, 7: 4.30}

#: Scaling factors to 7 nm derived from Table II (12.04x from 65nm, 3.28x from 28nm).
NODE_SCALE_TO_7NM = {65: 12.04, 28: 3.287, 7: 1.0}

#: Standard SRAM density @7nm, MB/mm² — inferred from Fig 11a (37.5 MB / 13.66 mm²).
COMPILER_SRAM_DENSITY_7NM = 2.75

# ---------------------------------------------------------------------------
# Density model: density(zero_bit_ratio, bank_height, width) @7nm
# ---------------------------------------------------------------------------

# Area per stored bit (arbitrary units) = ALPHA*(1-z)/cse + BETA, where z is the
# zero-bit ratio. BETA captures per-bit fixed overhead (address decode share,
# output network, clock/power distribution); ALPHA*(1-z) is the one-bit logic,
# already net of average CSE merging. K converts model units → MB/mm².
# Calibrated (least-squares over the three published points; residuals < 1.6%).
_ALPHA = 1.0
_BETA = 0.3338
_K = 9.70

# Routing-congestion penalty at extreme sparsity (Fig 9's "second-order
# effect": irregular placement of the few remaining gates costs wiring).
_ROUTE_Z0 = 0.88
_ROUTE_GAMMA = 0.55

# Bank-height curve (Fig 10; width fixed at 128): taller banks give the
# synthesis tool a larger CSE scope (sharing ∝ log h) but routing and bit-line
# load grow superlinearly past the sweet spot. Normalized so g(1024) = 1.
_H_OPT = 1024.0
_H_CSE = 0.115    # CSE-scope gain per octave below the optimum
_H_ROUTE = 0.0061  # routing loss per octave above the optimum (quadratic)


def _height_factor(height: int) -> float:
    lg = math.log2(max(height, 1) / _H_OPT)
    if lg <= 0:
        # smaller banks lose CSE scope
        return 1.0 / (1.0 + _H_CSE * (-lg) + 0.012 * lg * lg)
    # larger banks lose to routing/bit-line load
    return 1.0 / (1.0 + _H_ROUTE * lg * lg + 0.004 * lg)


def _routing_penalty(z: float) -> float:
    if z <= _ROUTE_Z0:
        return 1.0
    return 1.0 + _ROUTE_GAMMA * (z - _ROUTE_Z0) ** 2


def density_mb_mm2(
    zero_bit_ratio: float,
    *,
    bank_height: int = 1024,
    bank_width: int = 128,
    node_nm: int = 7,
) -> float:
    """Sparsity-aware ROM storage density in MB/mm².

    ``zero_bit_ratio`` is the fraction of ZERO BITS under the paper's 2-bit
    encoding (see :func:`repro.core.ternary.zero_bit_ratio`), not the fraction
    of zero weights.
    """
    z = float(np.clip(zero_bit_ratio, 0.0, 0.999))
    area_per_bit = (_ALPHA * (1.0 - z) + _BETA) * _routing_penalty(z)
    d7 = _K * _height_factor(bank_height) / area_per_bit
    # width has a weak effect (output mux sharing); 128 is the paper's design
    # point — model ±64 as a ±1.5% perturbation.
    d7 *= 1.0 + 0.015 * math.log2(bank_width / 128.0) if bank_width != 128 else 1.0
    return d7 / NODE_SCALE_TO_7NM.get(node_nm, 1.0) * 1.0 if node_nm == 7 else d7 / NODE_SCALE_TO_7NM[node_nm]


def silicon_efficiency_gates_mm2(zero_bit_ratio: float, *, bank_height: int = 1024) -> float:
    """Fig 9's right axis: synthesized gates per mm² (normalized model units).

    Higher sparsity → fewer gates but *slightly* worse area-per-gate at the
    extreme (routing), which is exactly the trade-off Fig 9 plots.
    """
    z = float(np.clip(zero_bit_ratio, 0.0, 0.999))
    gates_per_bit = (1.0 - z) * 0.5 + 0.02  # CSE-merged one-bit logic + decode share
    area_per_bit = (_ALPHA * (1.0 - z) + _BETA) * _routing_penalty(z) / _height_factor(bank_height)
    return gates_per_bit / area_per_bit * _K * 1e6  # gates/mm² in model units


def density_from_weights(t: "np.ndarray", **kw) -> float:
    """Density for an actual ternary weight tensor (drives Fig 4 → Fig 9)."""
    t = np.asarray(t)
    zvr = float(np.mean(t == 0))
    zbr = 1.0 - (1.0 - zvr) / 2.0
    return density_mb_mm2(zbr, **kw)


# ---------------------------------------------------------------------------
# CSE / transistor-count model for a concrete bank (paper Fig 6 example)
# ---------------------------------------------------------------------------


def transistor_estimate(t: "np.ndarray", cse: bool = True) -> int:
    """Estimate transistor count for a ternary sub-matrix as synthesized ROM.

    Without CSE every one-bit costs one AND-into-OR leg (~4 transistors).
    With CSE, output columns sharing identical address-minterm sets reuse
    logic: we count the *distinct* (address, bit) product terms plus one
    OR leg per remaining term, mirroring Fig 6(c)(d)'s 64 → 28 reduction.
    """
    t = np.asarray(t).astype(np.int8)
    h, w = t.shape
    # two bit-planes under the paper's encoding
    plus = (t == 1).astype(np.uint8)   # bit0 plane
    minus = (t == -1).astype(np.uint8)  # bit1 plane
    planes = np.concatenate([plus, minus], axis=1)  # (h, 2w) one-bits
    if not cse:
        return int(planes.sum()) * 4
    total = 0
    # CSE scope = shared minterms across output bits: count unique row-patterns
    # per output bit-group; a pattern reused by k outputs costs once + k wires.
    cols = [tuple(np.nonzero(planes[:, j])[0].tolist()) for j in range(planes.shape[1])]
    seen: Dict[tuple, int] = {}
    for pat in cols:
        if not pat:
            continue
        if pat in seen:
            total += 2  # reuse: one buffer/wire leg
        else:
            seen[pat] = 1
            total += len(pat) * 2 + 2  # minterm legs + OR root
    # pairwise sub-expression sharing inside distinct patterns (greedy model)
    total = int(total * 0.82)
    return max(total, int(planes.sum()))


# ---------------------------------------------------------------------------
# Chip-level area / bandwidth / power model (Table I, IV; Fig 11, 12)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class TomChipConfig:
    """Table I configuration."""

    freq_hz: float = 500e6
    n_lanes: int = 16
    mvus_per_lane: int = 10
    vu_width: int = 16
    rom_mb: float = 498.54
    sram_mb: float = 37.5
    mvu_weight_kb: float = 3180.0
    mvu_kv_kb: float = 240.0
    max_context: int = 1024
    bank_height: int = 1024
    bank_width: int = 128
    # compute micro-arch (calibrated so the simulator reproduces Fig 11b/13;
    # see core/simulator.py)
    ternary_macs_per_mvu_cycle: int = 128  # Ternary×FP8 adder tree width
    fp8_macs_per_mvu_cycle: int = 16       # FP8×FP8 engine width (shares tree)

    @property
    def n_mvus(self) -> int:
        return self.n_lanes * self.mvus_per_lane


DEFAULT_CHIP = TomChipConfig()


def rom_area_mm2(rom_mb: float, zero_bit_ratio: float = 0.70, **kw) -> float:
    return rom_mb / density_mb_mm2(zero_bit_ratio, **kw)


def sram_area_mm2(sram_mb: float) -> float:
    return sram_mb / COMPILER_SRAM_DENSITY_7NM


def compute_area_mm2(chip: TomChipConfig = DEFAULT_CHIP) -> float:
    # Fig 11a: compute = 18% of 56.9 mm² for the 160-MVU default. Scale with
    # MVU count and engine widths.
    base = 10.24
    scale = (chip.n_mvus / 160.0) * (
        0.75 * chip.ternary_macs_per_mvu_cycle / 128.0
        + 0.25 * chip.fp8_macs_per_mvu_cycle / 16.0
    )
    return base * scale


@dataclass(frozen=True)
class ChipArea:
    rom_mm2: float
    sram_mm2: float
    compute_mm2: float

    @property
    def total_mm2(self) -> float:
        return self.rom_mm2 + self.sram_mm2 + self.compute_mm2

    def breakdown(self) -> Dict[str, float]:
        t = self.total_mm2
        return {
            "rom": self.rom_mm2 / t,
            "sram": self.sram_mm2 / t,
            "compute": self.compute_mm2 / t,
        }


def chip_area(chip: TomChipConfig = DEFAULT_CHIP, zero_bit_ratio: float = 0.70) -> ChipArea:
    """Fig 11a reproduction: 56.9 mm² total, 58/24/18% ROM/SRAM/compute."""
    return ChipArea(
        rom_mm2=rom_area_mm2(chip.rom_mb, zero_bit_ratio,
                             bank_height=chip.bank_height, bank_width=chip.bank_width),
        sram_mm2=sram_area_mm2(chip.sram_mb),
        compute_mm2=compute_area_mm2(chip),
    )


def peak_bandwidth_bytes_s(chip: TomChipConfig = DEFAULT_CHIP) -> float:
    """Table IV: aggregate ROM bandwidth with every bank active.

    Each bank reads ``bank_width`` bits/cycle; banks = rom bits / bank size.
    For Table I this gives ~200 TB/s (the paper's figure).
    """
    rom_bits = chip.rom_mb * MB * 8
    bank_bits = chip.bank_height * chip.bank_width
    n_banks = rom_bits / bank_bits
    bytes_per_cycle = n_banks * chip.bank_width / 8.0
    # Port utilization: banks time-share output muxes; calibrated so Table I's
    # 498.54 MB ROM yields Table IV's 200 TB/s aggregate figure.
    return bytes_per_cycle * chip.freq_hz * PORT_UTILIZATION


#: Bank read-port duty cycle (calibration to Table IV's 200 TB/s).
PORT_UTILIZATION = 0.785


# --- power (Fig 12) --------------------------------------------------------

#: Fig 12 measured totals, watts.
POWER_TOTAL_UNGATED_W = 25.813
POWER_ROM_UNGATED_W = 21.306
POWER_NON_ROM_W = POWER_TOTAL_UNGATED_W - POWER_ROM_UNGATED_W  # 4.507
POWER_TOTAL_GATED_W = 5.33

#: ROM power density implied by Fig 12 / Fig 11a (21.306 W over ~33.2 mm²).
ROM_POWER_W_PER_MM2 = POWER_ROM_UNGATED_W / 33.24

#: Pre-wake overlap (Fig 8: layer N+1 powers up while N executes). Calibrated
#: so the gated total hits 5.33 W for the 30-layer BitNet-2B:
#: gated_rom = 21.306 * (1 + PREWAKE) / 30 = 0.823 W → PREWAKE = 0.159.
PREWAKE_FRACTION = 0.159


def gated_rom_power_w(
    n_layers: int,
    rom_power_ungated_w: float = POWER_ROM_UNGATED_W,
    prewake: float = PREWAKE_FRACTION,
) -> float:
    """Workload-aware gating: only the active layer (+ pre-waking next) is on."""
    if n_layers <= 1:
        return rom_power_ungated_w
    return rom_power_ungated_w * min(1.0, (1.0 + prewake) / n_layers)


def chip_power_w(n_layers: int, gating: bool = True,
                 rom_power_ungated_w: float = POWER_ROM_UNGATED_W,
                 non_rom_w: float = POWER_NON_ROM_W) -> float:
    rom = gated_rom_power_w(n_layers, rom_power_ungated_w) if gating else rom_power_ungated_w
    return rom + non_rom_w


# ---------------------------------------------------------------------------
# Table III / IV reference rows (for the comparison benchmarks)
# ---------------------------------------------------------------------------

TABLE_III_DENSITY = [
    # (method, node_nm, device, density@tech, density scaled to 7nm)
    ("ISSCC'24 3D-SRAM", 7, "3D-SRAM", 4.0, 4.0),
    ("MICRO'22 3D-DRAM", 7, "3D-DRAM", 8.4, 8.4),
    ("CICC'24 MLC-ROM", 28, "MLC-ROM", 1.09, 3.57),
    ("ASSCC'24 QLC-ROM", 28, "QLC-ROM", 2.46, 8.06),
    ("ASPDAC'25 Digital ROM", 65, "Digital ROM", 0.06, 0.72),
    ("TOM (this work)", 7, "Digital ROM", 15.0, 15.0),
]

TABLE_IV_BANDWIDTH = [
    # (design, bandwidth TB/s, capacity MB)
    ("3D SRAM [51]", 0.064, 16.0),
    ("3D DRAM [53]", 0.016, 32.0),
    ("H100 (HBM3e)", 4.8, 144.0 * 1024),
    ("Cerebras (SRAM)", 255.0, 44.0 * 1024),
    ("TOM", 200.0, 536.04),
]


# ---------------------------------------------------------------------------
# Published calibration points — used by tests/benchmarks to verify the model
# ---------------------------------------------------------------------------

CALIBRATION_POINTS = [
    # (zero_bit_ratio, bank_height, expected MB/mm², tolerance)
    (0.65, 2048, 14.2, 0.05),
    (0.95, 2048, 25.3, 0.05),
    (0.70, 1024, 15.0, 0.03),
]


def check_calibration() -> Dict[str, float]:
    """Relative error at every published point (all must be < tol)."""
    out = {}
    for z, h, want, _tol in CALIBRATION_POINTS:
        got = density_mb_mm2(z, bank_height=h)
        out[f"z={z:.2f},h={h}"] = abs(got - want) / want
    return out
