"""QLoRA two-path execution with ternary adapters (paper C4, §IV-D.3).

TOM's hybrid ROM-SRAM split: the ternary base weight is *immutable* (ROM —
here a packed uint8 `TernaryTensor` the optimizer never touches), while small
LoRA adapters live in "SRAM" (ordinary trainable arrays) and are themselves
ternary (LoTA-QAF-style), so the adapter path reuses the same Ternary×FP8
compute as the base path. Because W cannot be merged with AB (ROM is
read-only), execution is two-path:

    base path   : h_base = (W_packed ⊛ x) · s_w          (ternary matmul)
    adapter path: h_lora = B ⊛ (A ⊛ x) · (α / r)         (two small ternary matmuls)
    VU sum      : h = h_base + h_lora

Fine-tuning ("on-device adaptation") trains float master copies of A/B with a
straight-through estimator so the *deployed* adapters are exactly ternary;
`freeze()` packs them to 2-bit for serving. Gradients never reach the base.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import ternary
from repro.core.lanes import tree_sum


@dataclass(frozen=True)
class LoRASpec:
    rank: int = 16
    alpha: float = 32.0
    ternary: bool = True

    @property
    def scaling(self) -> float:
        return self.alpha / self.rank


def init_adapter(key: jax.Array, k: int, n: int, spec: LoRASpec,
                 dtype=jnp.float32) -> Dict[str, jax.Array]:
    """LoRA init: A ~ N(0, 1/r) (kaiming-ish), B = 0 ⇒ ΔW = 0 at start."""
    ka, _ = jax.random.split(key)
    a = jax.random.normal(ka, (k, spec.rank), dtype) * (1.0 / jnp.sqrt(spec.rank))
    b = jnp.zeros((spec.rank, n), dtype)
    return {"a": a, "b": b}


def adapter_path(
    x: jax.Array,
    adapter: Dict[str, jax.Array],
    spec: LoRASpec,
    *,
    train: bool = False,
) -> jax.Array:
    """h_lora = B·(A·x) · (α/r), with A/B fake-quantized to ternary when the
    spec demands it (train=True keeps the STE path differentiable)."""
    a, b = adapter["a"], adapter["b"]
    if spec.ternary:
        if train:
            a = ternary.ste_quantize(a)
            b = ternary.ste_quantize(b)
        else:
            ta, sa = ternary.quantize(a)
            tb, sb = ternary.quantize(b)
            a = ternary.dequantize(ta, sa, x.dtype)
            b = ternary.dequantize(tb, sb, x.dtype)
    z = jnp.einsum("...k,kr->...r", x, a.astype(x.dtype))
    # rank from the adapter's own shape so a default spec scales correctly
    scaling = spec.alpha / a.shape[-1]
    return jnp.einsum("...r,rn->...n", z, b.astype(x.dtype)) * scaling


def two_path_linear(
    x: jax.Array,
    base: ternary.TernaryTensor,
    adapter: Optional[Dict[str, jax.Array]],
    spec: Optional[LoRASpec] = None,
    *,
    train: bool = False,
) -> jax.Array:
    """The full §IV-D.3 dataflow on one device: ROM base + SRAM adapter + sum."""
    w = jax.lax.stop_gradient(base.to_dense(x.dtype))  # ROM: no grads into W
    h = jnp.einsum("...k,kn->...n", x, w)
    if adapter is not None:
        h = h + adapter_path(x, adapter, spec or LoRASpec(), train=train)
    return h


def lane_two_path_linear(
    x_local: jax.Array,
    packed_local: jax.Array,
    w_scale: jax.Array,
    adapter_local: Optional[Dict[str, jax.Array]],
    spec: Optional[LoRASpec] = None,
    *,
    axis_name: Optional[str],
    train: bool = False,
) -> jax.Array:
    """Distributed two-path: both paths are K-sharded across lanes (the
    adapter's A matrix tiles its K dim alongside the base weight — 'sharing
    SRAM with the KV cache' per lane), and ONE tree round sums base+adapter
    partials together — the collective is fused, mirroring the single VU add."""
    w = ternary.unpack2(packed_local)
    h = jnp.einsum("...k,kn->...n", x_local.astype(jnp.float32),
                   w.astype(jnp.float32)) * jax.lax.stop_gradient(w_scale)
    h = h.astype(x_local.dtype)
    if adapter_local is not None:
        h = h + adapter_path(x_local, adapter_local, spec or LoRASpec(), train=train).astype(h.dtype)
    return tree_sum(h, axis_name)


def freeze_adapter(adapter: Dict[str, jax.Array]) -> Dict[str, ternary.TernaryTensor]:
    """Pack trained adapters to 2-bit for deployment (they join the 'SRAM'
    image next to the KV cache)."""
    out = {}
    for name, w in adapter.items():
        k = w.shape[0]
        pad = (-k) % 4
        if pad:
            w = jnp.pad(w, ((0, pad), (0, 0)))
        out[name] = ternary.TernaryTensor.from_dense(w)
    return out


def adapter_bytes(k: int, n: int, spec: LoRASpec) -> int:
    """SRAM footprint of one frozen adapter pair (drives Fig 15a overhead)."""
    a_bytes = ternary.nbytes_packed((((k + 3) // 4) * 4, spec.rank))
    b_bytes = ternary.nbytes_packed((((spec.rank + 3) // 4) * 4, n))
    return a_bytes + b_bytes
