"""Speculative decoding support: n-gram proposer + accept/commit planning.

The engine decodes one token per tick; the paper's 3,306-TPS headline comes
from keeping the ternary datapath saturated, so tick-bound decode leaves
bandwidth on the table. Per-slot speculative decoding closes the gap without
a draft model: a **prompt-lookup / n-gram proposer** drafts up to ``k``
continuation tokens from the slot's own emitted history (prompt + output),
and one jitted **multi-token verify step** scores all ``k+1`` positions in a
single forward pass (``Model.verify_step``). Accepted drafts commit in bulk
through the KV backends' span writes (``PagePool.write_span`` / sliced dense
writes); rejected drafts are never committed, so outputs stay
token-identical to the non-speculative engine under greedy (and for seeded
sampling, whose draws depend only on ``(seed, step)``).

Everything here is host-side planning — pure functions over python lists, so
the accept/reject contract is unit-testable without a model.
"""
from __future__ import annotations

from typing import List, Optional, Sequence


def quantize_width(k: int) -> int:
    """Largest draft width of the form 2^t - 1 that is <= k (0 if k <= 0).

    The verify step scores ``1 + width`` positions padded to a power-of-two
    bucket, and a sequential-scan verify pays for every padded step — a k=4
    draft would ride an 8-wide bucket with 3 steps of pure waste. Quantizing
    widths to 1, 3, 7, 15 keeps every bucket exactly full."""
    if k <= 0:
        return 0
    t = (k + 1).bit_length()
    if (1 << t) - 1 > k:
        t -= 1
    return (1 << t) - 1


class AdaptiveSpecK:
    """Per-slot adaptive draft width from the live accept rate.

    Every rejected draft token is a wasted verify-scan step, so a slot whose
    stream stopped being repetitive should stop paying for wide buckets —
    and re-widen the moment acceptance recovers. The controller keeps an
    EWMA of the per-tick accept fraction (``accepted / drafted``) and maps
    it onto the request's ``spec_k`` ceiling:

        suggest(k_max) = quantize_width(clamp(round(ewma * k_max)))

    The floor of 1 keeps a probe draft in flight even after a run of full
    rejections — without it the width would latch at 0 and never observe
    acceptance again. Widths only gate how many drafts are *risked*; the
    accept/commit contract already guarantees rejected drafts never reach
    storage, so adapting the width cannot change emitted tokens.

    Host-side pure state — unit-testable without a model (the adaptation
    curve is pinned in tests/test_spec_decode.py).
    """
    __slots__ = ("alpha", "floor", "rate", "drafted", "accepted")

    def __init__(self, alpha: float = 0.3, floor: int = 1,
                 init_rate: float = 1.0):
        self.alpha = alpha
        self.floor = floor
        self.rate = init_rate     # optimistic start: first tick drafts full
        self.drafted = 0
        self.accepted = 0

    def observe(self, drafted: int, accepted: int) -> None:
        """Fold one verify tick's outcome into the EWMA."""
        if drafted <= 0:
            return
        self.drafted += drafted
        self.accepted += accepted
        self.rate += self.alpha * (accepted / drafted - self.rate)

    def suggest(self, k_max: int) -> int:
        """Draft width to risk next tick, quantized like every other width
        (1, 3, 7, 15) and clamped to [floor, k_max]."""
        if k_max <= 0:
            return 0
        k = int(round(self.rate * k_max))
        return quantize_width(max(min(k, k_max), self.floor))


def cycle_propose(history: Sequence[int], k: int, max_period: int = 3,
                  min_reps: int = 3) -> List[int]:
    """Draft ``k`` tokens by extrapolating a short cycle in the tail.

    If the last ``min_reps`` periods of some period ``p <= max_period``
    repeat exactly (constant runs are the p=1 case), continuing the cycle is
    the highest-confidence draft available — and it is exactly the regime
    greedy decode of a fixed model falls into. Checked before the n-gram
    lookup because the lookup needs a full ``max_n``-gram recurrence plus a
    full-width continuation in history before it drafts wide, which costs
    several one-token ramp ticks at every new cycle."""
    h = list(history)
    for p in range(1, max_period + 1):
        if len(h) < p * min_reps:
            break
        if all(h[-i] == h[-i - p] for i in range(1, p * (min_reps - 1) + 1)):
            return [h[-p + (j % p)] for j in range(k)]
    return []


def ngram_propose(history: Sequence[int], k: int, max_n: int = 3,
                  min_n: int = 2) -> List[int]:
    """Draft up to ``k`` tokens by prompt-lookup: find the most recent
    earlier occurrence of the longest matching tail n-gram (n = ``max_n``
    down to ``min_n``) and propose the tokens that followed it.

    Greedy decode of a fixed model is locally repetitive (and real prompts
    quote their own context), so the continuation after a repeated n-gram is
    a strong cheap draft — no draft model, no extra weights. Draft width
    scales with match confidence: a full ``max_n``-gram match proposes up to
    ``k`` tokens, a shorter match only 1 (measured on greedy tiny-model
    streams this lifts accept from ~0.45 to ~0.65 — every rejected token is
    a wasted verify step, so precision beats volume). Single-token
    (``n < min_n``) coincidences draft nothing: the slot falls back to one
    token per tick for that tick.
    """
    h = list(history)
    if k <= 0 or len(h) < 2:
        return []
    for n in range(min(max_n, len(h) - 1), min_n - 1, -1):
        tail = h[-n:]
        width = k if n >= max_n else 1
        # scan right-to-left (most recent match tracks the current
        # cycle/phrase, not a stale early one) — but prefer the most recent
        # occurrence with a *full-width* continuation: on a tight cycle the
        # nearest match sits one step back and offers a 1-token continuation
        # before history runs out, which would cap every draft at 1
        best = None
        for i in range(len(h) - n - 1, -1, -1):
            if h[i:i + n] == tail:
                if best is None:
                    best = i
                if i + n + width <= len(h):
                    best = i
                    break
        if best is not None:
            cont = h[best + n:best + n + width]
            if cont:
                return cont
    return []


def propose(history: Sequence[int], k: int, max_n: int = 3) -> List[int]:
    """The engine's draft source: cycle extrapolation first (full width,
    fires within ~3 tokens of a cycle forming), n-gram prompt lookup as the
    general fallback."""
    draft = cycle_propose(history, k)
    if draft:
        return draft
    return ngram_propose(history, k, max_n)


def accepted_prefix(draft: Sequence[int], choices: Sequence[int]) -> int:
    """Length of the accepted draft prefix.

    ``choices[j]`` is the model's own token for output step j (argmax under
    greedy, the seeded draw otherwise); draft token ``draft[j]`` was the
    *input* at verify position j+1, so it is valid iff the model would have
    emitted it at step j. The first mismatch invalidates everything after it
    (later positions attended a wrong token).
    """
    a = 0
    while a < len(draft) and draft[a] == choices[a]:
        a += 1
    return a


def plan_emit(accepted: int, choices: Sequence[int], *, budget: int,
              room: int, eos_id: Optional[int]) -> List[int]:
    """Tokens actually emitted this tick: the accepted drafts plus the
    model's bonus/corrected token, truncated exactly where the sequential
    engine would have stopped.

    ``budget`` is the remaining ``max_new_tokens`` allowance, ``room`` the
    remaining cache positions (``max_len - pos``). The emitted list also
    equals the number of input-token KVs to commit (the sequential engine
    writes input t_i's KV when emitting e_i), so callers commit
    ``len(result)`` span positions — rejected drafts never reach storage.
    """
    n = min(accepted + 1, budget, room)
    out = list(choices[:n])
    if eos_id is not None:
        for j, tok in enumerate(out):
            if tok == eos_id:
                return out[:j + 1]
    return out
