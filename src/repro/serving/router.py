"""Replica router: one front door over N async serving replicas.

The tentpole of the scale-out layer: each replica is a sharded
`ServeEngine` (see `repro.serving.sharded`) wrapped in its own `Gateway` +
`AsyncServeRuntime` — its own dispatch/backlog thread pair, its own KV
page pool, its own prefix cache. The router grows the Gateway role to the
fleet: it duck-types the exact surface `ServingHTTPFront` binds to
(``submit`` / ``cancel`` / ``admission_check`` / ``poisoned`` /
``_tickets`` / ``gw.metrics``), so the HTTP/SSE front serves a fleet with
zero changes.

Placement is prefix-cache-aware: a request routes to the replica whose
`PrefixCache` scores the longest token-prefix hit (those pages are
reattached instead of re-prefilled — the paper's shared-context ROM-bank
reuse, now a *placement* signal); with no hit anywhere it falls back to
least-loaded (queue depth + active slots). Poisoned replicas are skipped,
so a single crashed engine degrades capacity instead of the service — the
fleet is only down when *every* replica is (``poisoned``), which is what
``serve_until_shutdown`` polls.

Uid namespacing: each replica's engine allocates uids from a disjoint
``UID_STRIDE`` block, so fleet-wide uids never collide and the router can
find a ticket's owner without a reverse map scan. ``replace_replica``
swaps a crashed replica for a fresh runtime under a *new* block — surviving
tickets keep their uids, replayed requests get unambiguous new ones (the
crash-recovery fuzz lane drives this).
"""
from __future__ import annotations

import threading
import types
from typing import Dict, List, Optional, Tuple

from repro.serving.gateway.metrics import Metrics
from repro.serving.runtime.runtime import (AsyncServeRuntime, RuntimePoisoned,
                                           Ticket)

#: uid block size per replica lifetime — far above any bench/test request
#: count, so uids stay unique across replicas *and* across replacements.
UID_STRIDE = 1_000_000


def _suffix(name: str, i: int) -> str:
    """Tag a replica-local metric name with its replica index, keeping the
    ``base__label`` convention's label part last so the prom renderer still
    folds it into a label."""
    if "__" in name:
        base, label = name.split("__", 1)
        if base and label:
            return f"{base}_r{i}__{label}"
    return f"{name}_r{i}"


class _FleetMetrics:
    """Router-level registry + merged exposition over every replica.

    ``inc``/``set_gauge``/``observe`` land in the router's own `Metrics`
    (routing decisions, fleet admission rejects); ``to_prom_text`` renders
    that registry merged with every replica's, replica names suffixed
    ``_r{i}`` — one scrape shows the whole fleet."""

    def __init__(self, router: "ReplicaRouter"):
        self._router = router
        self._own = Metrics()

    def inc(self, name: str, n: float = 1) -> None:
        self._own.inc(name, n)

    def set_gauge(self, name: str, value: float) -> None:
        self._own.set_gauge(name, value)

    def observe(self, name: str, value: float, buckets=None) -> None:
        self._own.observe(name, value, buckets)

    def counter(self, name: str) -> float:
        return self._own.counter(name)

    def _merged(self):
        self._router._refresh_gauges()
        counters = dict(self._own.counters)
        gauges = dict(self._own.gauges)
        hists = dict(self._own.histograms)
        for i, rt in enumerate(self._router.runtimes):
            m = rt.gw.metrics
            with m._lock:
                for name, v in m.counters.items():
                    counters[_suffix(name, i)] = v
                for name, v in m.gauges.items():
                    gauges[_suffix(name, i)] = v
                for name, h in m.histograms.items():
                    hists[_suffix(name, i)] = h
        return types.SimpleNamespace(counters=counters, gauges=gauges,
                                     histograms=hists)

    def to_prom_text(self) -> str:
        from repro.serving.obs.prom import render_text
        return render_text(self._merged())

    def to_dict(self) -> Dict:
        return {
            "fleet": self._own.to_dict(),
            "replicas": [rt.gw.metrics.to_dict()
                         for rt in self._router.runtimes],
        }


class _FleetView:
    """The router's ``gw`` attribute — just enough Gateway for the HTTP
    front (``rt.gw.metrics``)."""

    def __init__(self, router: "ReplicaRouter"):
        self.metrics = _FleetMetrics(router)


class ReplicaRouter:
    """Route requests over N `AsyncServeRuntime` replicas.

    Presents the runtime surface `ServingHTTPFront` needs, so
    ``ServingHTTPFront(ReplicaRouter([...]))`` is a sharded fleet behind
    one port. Thread-safe: routing reads replica load cross-thread
    (point-in-time, like `admission_check` — each engine's own admission
    stays the hard gate)."""

    def __init__(self, runtimes: List[AsyncServeRuntime]):
        assert runtimes, "router needs at least one replica"
        self.runtimes: List[AsyncServeRuntime] = list(runtimes)
        self._next_block = 0
        for rt in self.runtimes:
            self._assign_uid_block(rt)
        self._tickets: Dict[int, Ticket] = {}
        self._tickets_lock = threading.Lock()
        self._owner: Dict[int, int] = {}
        self.gw = _FleetView(self)

    def _assign_uid_block(self, rt: AsyncServeRuntime) -> None:
        assert rt.eng._uid == 0 or rt.eng._uid % UID_STRIDE == 0, \
            "replica engine already issued uids outside router blocks"
        rt.eng._uid = self._next_block * UID_STRIDE
        self._next_block += 1

    # -- lifecycle -----------------------------------------------------------
    def start(self) -> "ReplicaRouter":
        for rt in self.runtimes:
            rt.start()
        return self

    def __enter__(self) -> "ReplicaRouter":
        return self.start()

    def __exit__(self, exc_type, exc, tb):
        self.close(raise_on_poison=exc_type is None)
        return False

    def close(self, timeout: float = 30.0,
              raise_on_poison: bool = True) -> None:
        for rt in self.runtimes:
            rt.close(timeout=timeout, raise_on_poison=False)
        if raise_on_poison and self.poisoned:
            raise RuntimePoisoned(self.exception)

    def quiesce(self, timeout: float = 60.0) -> None:
        for rt in self.runtimes:
            if not rt.poisoned:
                rt.quiesce(timeout=timeout)

    def drain(self, timeout: float = 300.0) -> None:
        for rt in self.runtimes:
            if not rt.poisoned:
                rt.drain(timeout=timeout)

    # -- health --------------------------------------------------------------
    @property
    def poisoned(self) -> bool:
        """Fleet-down: every replica crashed. A partial outage is
        ``degraded`` — the router keeps serving on the survivors."""
        return all(rt.poisoned for rt in self.runtimes)

    @property
    def degraded(self) -> bool:
        return any(rt.poisoned for rt in self.runtimes)

    @property
    def exception(self) -> Optional[BaseException]:
        for rt in self.runtimes:
            if rt.exception is not None:
                return rt.exception
        return None

    def _healthy(self) -> List[Tuple[int, AsyncServeRuntime]]:
        alive = [(i, rt) for i, rt in enumerate(self.runtimes)
                 if not rt.poisoned]
        if not alive:
            raise RuntimePoisoned(self.exception
                                  or RuntimeError("no healthy replicas"))
        return alive

    def _refresh_gauges(self) -> None:
        m = self.gw.metrics
        m.set_gauge("replicas", len(self.runtimes))
        m.set_gauge("replicas_healthy",
                    sum(1 for rt in self.runtimes if not rt.poisoned))

    # -- placement -----------------------------------------------------------
    def route(self, prompt: List[int],
              adapter_id: Optional[str] = None) -> Tuple[int, str]:
        """Pick a replica for ``prompt``: longest prefix-cache hit wins
        (reattached pages beat a cold prefill), ties/misses go least-loaded
        (adapter residency breaks load ties). Returns (index, reason)."""
        toks = list(prompt)
        best, best_key, best_reason = None, None, "least_loaded"
        for i, rt in self._healthy():
            eng = rt.eng
            hit_toks = 0
            if eng.prefix is not None:
                hit_toks = eng.prefix.lookup(toks) * eng.pool.cfg.page
            load = len(eng.scheduler) + sum(
                1 for r in eng.slot_req if r is not None)
            resident = (adapter_id is not None and eng.adapters is not None
                        and eng.adapters.is_resident(adapter_id))
            key = (-hit_toks, load, 0 if resident else 1, i)
            if best_key is None or key < best_key:
                best, best_key = i, key
                best_reason = "prefix_hit" if hit_toks else (
                    "adapter_affinity" if resident else "least_loaded")
        return best, best_reason

    # -- client API (the ServingHTTPFront runtime surface) -------------------
    def submit(self, prompt: List[int], spec=None, sampling=None,
               timeout: float = 30.0) -> Ticket:
        idx, reason = self.route(
            prompt, getattr(spec, "adapter_id", None))
        ticket = self.runtimes[idx].submit(prompt, spec=spec,
                                           sampling=sampling, timeout=timeout)
        with self._tickets_lock:
            self._tickets[ticket.uid] = ticket
            self._owner[ticket.uid] = idx
        m = self.gw.metrics
        m.inc("requests_routed")
        m.inc(f"routed_{reason}")
        m.inc(f"routed__r{idx}")
        return ticket

    def cancel(self, uid: int, timeout: float = 30.0) -> bool:
        with self._tickets_lock:
            idx = self._owner.get(uid)
        if idx is None:
            return False
        rt = self.runtimes[idx]
        if rt.poisoned:
            return False      # poison cleanup already errored the ticket
        return rt.cancel(uid, timeout=timeout)

    def admission_check(self, prompt_len: int, max_new_tokens: int,
                        adapter_id: Optional[str] = None,
                        max_queue: int = 256) -> Optional[str]:
        """Admit if *any* healthy replica would: per-replica queues mean one
        full replica shouldn't bounce a request another can take."""
        reason = "runtime poisoned"
        for _, rt in ((i, r) for i, r in enumerate(self.runtimes)
                      if not r.poisoned):
            reason = rt.admission_check(prompt_len, max_new_tokens,
                                        adapter_id=adapter_id,
                                        max_queue=max_queue)
            if reason is None:
                return None
        return reason

    # -- recovery ------------------------------------------------------------
    def replace_replica(self, idx: int,
                        runtime: AsyncServeRuntime) -> AsyncServeRuntime:
        """Swap in a rebuilt replica (crash recovery): the new runtime gets
        a fresh uid block — uids of dead in-flight requests stay unique so
        their (already errored) tickets remain queryable, and replayed
        requests bind new uids. Returns the replaced runtime (caller closes
        it)."""
        old = self.runtimes[idx]
        self._assign_uid_block(runtime)
        self.runtimes[idx] = runtime
        self.gw.metrics.inc("replicas_replaced")
        return old

    def in_flight(self) -> List[Ticket]:
        with self._tickets_lock:
            return [t for t in self._tickets.values() if not t.terminal]
