"""KV backends: the engine's cache contract behind one protocol.

The engine used to carry a ``kv="dense"|"paged"`` string switch with two
parallel jitted decode paths (a contiguous cache vs a per-tick host gather
of the page pool). Both are gone: a :class:`KVBackend` owns cache
**init / alloc / commit / free** plus the admission accounting, and hands
the jitted decode an opaque *state* pytree that ``Model.decode_step``
understands —

  * :class:`DenseKV` — the model's contiguous dict cache (GQA / MLA / SSM /
    hybrid): state *is* the cache, capacity is unbounded (every slot already
    reserved ``max_len``).
  * :class:`PagedKV` — the shared fp8 :class:`PagePool`: state is a
    :class:`~repro.models.attention.PagedKVState` (pool + block tables +
    this tick's write targets), so decode attention consumes pages directly
    — the Pallas ``paged_flash_decode`` kernel on TPU (scalar-prefetch block
    tables, no contiguous gather), the XLA gather reference on CPU.

The engine talks only to this protocol; ``kv="paged"`` strings are accepted
by :func:`as_backend` behind a ``DeprecationWarning``.
"""
from __future__ import annotations

import math
import warnings
from typing import Any, List, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.attention import PagedKVState
from repro.serving.paged_kv import PagePool, PagedConfig

Params = Any


def _splice_cache(cache, sub_cache, slot: int):
    """Insert a (batch=1) cache into the batch cache at ``slot`` (batch is
    always axis 1 across all cache layouts: k/v, latent, ssm, conv)."""

    def one(full, sub):
        idx = [0] * full.ndim
        idx[1] = slot
        return jax.lax.dynamic_update_slice(full, sub.astype(full.dtype),
                                            tuple(idx))

    return jax.tree.map(one, cache, sub_cache)


class KVBackend:
    """Owns KV storage for the engine's decode slots.

    Page-accounting methods default to the dense answers (zero cost,
    unbounded capacity) so the engine's admission / capacity logic is
    backend-generic — no string branches.
    """

    name = "?"
    supports_paging = False
    pool: Optional[PagePool] = None

    def bind(self, model, max_slots: int, max_len: int) -> None:
        """Allocate storage for ``max_slots`` sequences of ``max_len``."""
        raise NotImplementedError

    # -- admission / capacity accounting --------------------------------------
    def pages_for(self, tokens: int) -> int:
        return 0

    @property
    def pages_free(self) -> float:
        return math.inf

    @property
    def capacity_pages(self) -> float:
        return math.inf

    def slot_pages(self, slot: int) -> int:
        return 0

    # -- alloc / free ---------------------------------------------------------
    def reserve(self, slot: int, upto_tokens: int) -> None:
        pass

    def release(self, slot: int, keep: int = 0) -> None:
        pass

    def free_pages(self, page_ids: List[int]) -> None:
        pass

    # -- the decode-tick contract --------------------------------------------
    def decode_state(self, active: Sequence[int], pos: np.ndarray):
        """Build the state pytree ``Model.decode_step`` consumes this tick."""
        raise NotImplementedError

    def commit(self, new_state, active: Sequence[int], pos: np.ndarray) -> None:
        """Store the decode step's updated state."""
        raise NotImplementedError

    def write_prefill(self, slot: int, start: int, sub_cache, n: int) -> None:
        """Store a batched-prefill result (a batch-1 cache covering
        positions ``start .. start+n``) into the slot's storage."""
        raise NotImplementedError

    # -- speculative decode ----------------------------------------------------
    def verify_state(self, active: Sequence[int], pos: np.ndarray,
                     n_tokens: np.ndarray, s_bucket: int):
        """State pytree for ``Model.verify_step`` scoring up to ``s_bucket``
        positions per slot this tick (``n_tokens`` (B,) = each slot's
        planned fed+draft count). Must cover the committed context plus room
        for the drafted span (paged: pages reserved through
        ``pos + n_tokens[i]``); nothing is written device-side here."""
        raise NotImplementedError

    def commit_span(self, slot: int, start: int, spans, n: int) -> None:
        """Commit the first ``n`` verified positions of a slot's span from a
        verify step's ``{"k","v"}: (L, B, Hkv, S, D)`` output — the
        multi-token analogue of :meth:`commit`. Callers pass ``n`` = tokens
        the sequential engine would have written, so rejected drafts
        (positions >= n) are never stored."""
        raise NotImplementedError

    def prefix_kv(self, slot: int, upto_tokens: int):
        """Materialize the slot's first ``upto_tokens`` committed k/v
        positions (fp8 cache encoding, ``{"k","v"}: (L, 1, Hkv, T, D)``) for
        a mid-sequence prefill resume — a prefix-cache hit or the next chunk
        of a chunked prefill. Token-granular: chunk boundaries need not be
        page-aligned."""
        raise NotImplementedError

    # -- tiered spill / re-admit ----------------------------------------------
    # Host-side round trips for the tiered memory hierarchy. Exports hand
    # back the *raw cache encoding* (fp8 for GQA caches) as host numpy
    # arrays and imports write those same bytes back, so a spilled-then-
    # re-admitted prefix is bit-identical to freshly prefilled KV.
    def export_page(self, page_id: int):
        """Host copy of one committed pool page's k/v
        (``{"k","v"}: (L, Hkv, page, D)``, cache dtype). Paged only."""
        raise NotImplementedError(f"{self.name} KV does not export pages")

    def import_page(self, page_id: int, payload) -> None:
        """Write an `export_page` payload back into pool page ``page_id``
        (a freshly allocated page — committed pages are immutable)."""
        raise NotImplementedError(f"{self.name} KV does not import pages")

    def export_prefix(self, slot: int, upto_tokens: int):
        """Host copy of a slot's first ``upto_tokens`` committed positions
        (``{"k","v"}: (L, Hkv, T, D)``, cache dtype). Dense only."""
        raise NotImplementedError(f"{self.name} KV does not export prefixes")

    def import_prefix(self, slot: int, payload) -> None:
        """Write an `export_prefix` payload into a slot's positions
        ``0 .. T`` (the slot is freshly placed; nothing committed yet)."""
        raise NotImplementedError(f"{self.name} KV does not import prefixes")

    # -- AOT warmup -------------------------------------------------------------
    def warmup_decode_states(self):
        """Throwaway decode-state pytrees covering every state shape the
        tick loop can produce (one per block-table view bucket for paged,
        one for dense). Used by ``ServeEngine.warmup_aot`` to populate the
        decode jit's dispatch cache up front; the states alias **no live
        storage** — outputs are discarded and a donated decode may consume
        them without invalidating the real cache/pool."""
        return ()

    def warmup_verify_states(self, s_bucket: int):
        """Same contract as :meth:`warmup_decode_states` for the multi-token
        verify's state shapes at draft-width bucket ``s_bucket``."""
        return ()


class DenseKV(KVBackend):
    """Contiguous per-slot cache — the paper's fixed on-chip SRAM budget.
    Works for every cache family (GQA, MLA, SSM, hybrid)."""

    name = "dense"

    def bind(self, model, max_slots: int, max_len: int) -> None:
        assert not hasattr(self, "cache"), \
            "KVBackend instances are engine-owned: build a fresh one per engine"
        self.cache = model.init_cache(max_slots, max_len)

    def decode_state(self, active, pos):
        return self.cache

    def commit(self, new_state, active, pos) -> None:
        self.cache = new_state

    def write_prefill(self, slot, start, sub_cache, n) -> None:
        if start == 0:
            self.cache = _splice_cache(self.cache, sub_cache, slot)
            return
        # chunked-prefill resume: only [start, start+n) is fresh — splicing
        # the whole row would clobber the committed prefix with the chunk
        # cache's zeros. GQA layout only (k/v: (L, B, Hkv, S, D)), which is
        # the only family the mid-sequence prefill path supports.
        new = dict(self.cache)
        for key in ("k", "v"):
            span = sub_cache[key][:, :, :, start:start + n]
            new[key] = jax.lax.dynamic_update_slice(
                self.cache[key], span.astype(self.cache[key].dtype),
                (0, slot, 0, start, 0))
        self.cache = new

    def prefix_kv(self, slot, upto_tokens):
        return {"k": self.cache["k"][:, slot:slot + 1, :, :upto_tokens],
                "v": self.cache["v"][:, slot:slot + 1, :, :upto_tokens]}

    # -- tiered spill / re-admit ----------------------------------------------
    # GQA layout only ((L, B, Hkv, S, D)) — the same restriction as the
    # mid-sequence prefill path that consumes re-admitted prefixes.
    def export_prefix(self, slot, upto_tokens):
        return {"k": np.asarray(self.cache["k"][:, slot, :, :upto_tokens]),
                "v": np.asarray(self.cache["v"][:, slot, :, :upto_tokens])}

    def import_prefix(self, slot, payload) -> None:
        new = dict(self.cache)
        for key in ("k", "v"):
            span = jnp.asarray(payload[key])[:, None]   # restore batch axis
            new[key] = jax.lax.dynamic_update_slice(
                self.cache[key], span.astype(self.cache[key].dtype),
                (0, slot, 0, 0, 0))
        self.cache = new

    # -- speculative decode ----------------------------------------------------
    def verify_state(self, active, pos, n_tokens, s_bucket):
        # the contiguous cache is already the full context view; stale rows
        # at/beyond each slot's pos are masked by position inside the model
        return self.cache

    def commit_span(self, slot, start, spans, n) -> None:
        # sliced dense writes: only [start, start+n) of the slot's row moves
        # — a whole-cache splice would resurrect rejected draft positions
        new = dict(self.cache)
        for key in ("k", "v"):
            span = spans[key][:, slot:slot + 1, :, :n]
            new[key] = jax.lax.dynamic_update_slice(
                self.cache[key], span.astype(self.cache[key].dtype),
                (0, slot, 0, start, 0))
        self.cache = new

    # -- AOT warmup -------------------------------------------------------------
    def warmup_decode_states(self):
        # dense state is the cache itself: one shape, one entry. zeros_like
        # preserves dtype *and* placement/sharding, so the warmup dispatch
        # lands in the same executable-cache entry as live ticks.
        yield jax.tree.map(jnp.zeros_like, self.cache)

    def warmup_verify_states(self, s_bucket):
        yield jax.tree.map(jnp.zeros_like, self.cache)


class PagedKV(KVBackend):
    """vLLM-style paging over the shared fp8 pool: slots own block tables,
    decode attention reads pages through them (no per-slot max_len
    reservation). Unlocks admission control, preemption and the prefix
    cache."""

    name = "paged"
    supports_paging = True

    def __init__(self, page: int = 64, n_pages: Optional[int] = None):
        self.page = page
        self.n_pages = n_pages
        self.pool = None

    def bind(self, model, max_slots: int, max_len: int) -> None:
        assert self.pool is None, \
            "KVBackend instances are engine-owned: build a fresh one per engine"
        cfg = model.cfg
        assert cfg.family not in ("ssm", "hybrid"), \
            "paged KV needs an attention KV cache (use DenseKV)"
        assert cfg.attention_kind != "mla", \
            "paged KV supports GQA caches only (use DenseKV)"
        spec = model.cache_specs(1, 1)
        pcfg = PagedConfig(
            n_layers=spec["k"].shape[0],
            n_kv_heads=cfg.num_kv_heads,
            head_dim=cfg.head_dim,
            page=self.page,
            n_pages=self.n_pages or max_slots * (-(-max_len // self.page)),
            dtype=spec["k"].dtype,
        )
        self.pool = PagePool(pcfg, max_slots)
        self.max_slots = max_slots
        self.max_len = max_len

    # -- accounting -----------------------------------------------------------
    def pages_for(self, tokens: int) -> int:
        return self.pool.pages_for(tokens)

    @property
    def pages_free(self) -> int:
        return self.pool.pages_free

    @property
    def capacity_pages(self) -> int:
        return self.pool.cfg.n_pages

    def slot_pages(self, slot: int) -> int:
        return len(self.pool.tables[slot])

    # -- alloc / free ---------------------------------------------------------
    def reserve(self, slot: int, upto_tokens: int) -> None:
        self.pool.reserve(slot, upto_tokens)

    def release(self, slot: int, keep: int = 0) -> None:
        self.pool.release(slot, keep=keep)

    def free_pages(self, page_ids: List[int]) -> None:
        self.pool.free_pages(page_ids)

    def _table_view(self, active) -> np.ndarray:
        """Bucketed (B, P) block-table matrix: next power of two over the
        longest active table, capped at the max_len footprint, so jit
        recompiles only on bucket growth; inactive rows point at the pool's
        scratch page."""
        pool = self.pool
        max_pages = max(len(pool.tables[i]) for i in active)
        view = 1 << max(0, (max_pages - 1).bit_length())
        view = min(view, pool.pages_for(self.max_len))
        view = max(view, max_pages)
        return pool.batch_tables(active, view, self.max_slots)

    # -- decode tick ----------------------------------------------------------
    def decode_state(self, active, pos) -> PagedKVState:
        """Block tables + write targets for this tick (see `_table_view` for
        the bucketing that bounds recompiles)."""
        pool = self.pool
        for i in active:
            pool.reserve(i, int(pos[i]) + 1)
        tables = self._table_view(active)
        page_ids = np.full((self.max_slots,), pool.scratch_page, np.int32)
        offsets = np.zeros((self.max_slots,), np.int32)
        lengths = np.zeros((self.max_slots,), np.int32)
        for i in active:
            p = int(pos[i])
            page_ids[i] = pool.tables[i][p // pool.cfg.page]
            offsets[i] = p % pool.cfg.page
            lengths[i] = p + 1
        return PagedKVState(
            k_pool=pool.k, v_pool=pool.v,
            tables=jnp.asarray(tables),
            write_page=jnp.asarray(page_ids),
            write_off=jnp.asarray(offsets),
            lengths=jnp.asarray(lengths))

    def commit(self, new_state: PagedKVState, active, pos) -> None:
        self.pool.k = new_state.k_pool
        self.pool.v = new_state.v_pool
        for i in active:
            self.pool.lengths[i] = max(int(self.pool.lengths[i]),
                                       int(pos[i]) + 1)

    def write_prefill(self, slot, start, sub_cache, n) -> None:
        self.pool.write_span(slot, start,
                             sub_cache["k"][:, 0, :, start:start + n],
                             sub_cache["v"][:, 0, :, start:start + n])

    def prefix_kv(self, slot, upto_tokens):
        n_pages = self.pool.pages_for(upto_tokens)
        gk, gv = self.pool.gather_slot(slot, n_pages)
        # the final page may be partially filled (chunk boundaries are
        # token-granular) — hand back exactly the committed span
        return {"k": gk[:, :, :, :upto_tokens], "v": gv[:, :, :, :upto_tokens]}

    # -- tiered spill / re-admit ----------------------------------------------
    def export_page(self, page_id):
        return {"k": np.asarray(self.pool.k[:, page_id]),
                "v": np.asarray(self.pool.v[:, page_id])}

    def import_page(self, page_id, payload) -> None:
        pool = self.pool
        pool.k = pool.k.at[:, page_id].set(
            jnp.asarray(payload["k"], pool.k.dtype))
        pool.v = pool.v.at[:, page_id].set(
            jnp.asarray(payload["v"], pool.v.dtype))

    # -- speculative decode ----------------------------------------------------
    def verify_state(self, active, pos, n_tokens, s_bucket) -> PagedKVState:
        """Verify-tick view: tables cover the committed context *plus* each
        slot's drafted span (pages reserved through ``pos + n_tokens[i]`` —
        the engine budgets draft lengths against ``pages_free`` first, so
        this never raises mid-tick). ``write_page``/``write_off`` are
        **(B, s_bucket)** per-position targets, consumed only by the Pallas
        kernel path's functional in-jit scatter (padding rows beyond a
        slot's planned span target the scratch page); the gather path
        ignores them. Nothing is written to the real pool here —
        `commit_span` is the only writer."""
        pool = self.pool
        for i in active:
            pool.reserve(i, int(pos[i]) + int(n_tokens[i]))
        tables = self._table_view(active)
        page_ids = np.full((self.max_slots, s_bucket), pool.scratch_page,
                           np.int32)
        offsets = np.zeros((self.max_slots, s_bucket), np.int32)
        lengths = np.zeros((self.max_slots,), np.int32)
        for i in active:
            for j in range(int(n_tokens[i])):
                pj = int(pos[i]) + j
                page_ids[i, j] = pool.tables[i][pj // pool.cfg.page]
                offsets[i, j] = pj % pool.cfg.page
            lengths[i] = int(pos[i])
        return PagedKVState(
            k_pool=pool.k, v_pool=pool.v,
            tables=jnp.asarray(tables),
            write_page=jnp.asarray(page_ids),
            write_off=jnp.asarray(offsets),
            lengths=jnp.asarray(lengths))

    def commit_span(self, slot, start, spans, n) -> None:
        self.pool.write_span(slot, start, spans["k"][:, slot, :, :n],
                             spans["v"][:, slot, :, :n])

    # -- AOT warmup -------------------------------------------------------------
    def _view_buckets(self) -> List[int]:
        """Every (B, P) block-table width `_table_view` can emit: powers of
        two capped at the max_len footprint."""
        cap = self.pool.pages_for(self.max_len)
        views, b = [], 1
        while True:
            views.append(min(b, cap))
            if b >= cap:
                break
            b <<= 1
        return sorted(set(views))

    def warmup_decode_states(self):
        pool = self.pool
        for view in self._view_buckets():
            yield PagedKVState(
                k_pool=jnp.zeros_like(pool.k),
                v_pool=jnp.zeros_like(pool.v),
                tables=jnp.full((self.max_slots, view), pool.scratch_page,
                                jnp.int32),
                write_page=jnp.full((self.max_slots,), pool.scratch_page,
                                    jnp.int32),
                write_off=jnp.zeros((self.max_slots,), jnp.int32),
                lengths=jnp.zeros((self.max_slots,), jnp.int32))

    def warmup_verify_states(self, s_bucket):
        pool = self.pool
        for view in self._view_buckets():
            yield PagedKVState(
                k_pool=jnp.zeros_like(pool.k),
                v_pool=jnp.zeros_like(pool.v),
                tables=jnp.full((self.max_slots, view), pool.scratch_page,
                                jnp.int32),
                write_page=jnp.full((self.max_slots, s_bucket),
                                    pool.scratch_page, jnp.int32),
                write_off=jnp.zeros((self.max_slots, s_bucket), jnp.int32),
                lengths=jnp.zeros((self.max_slots,), jnp.int32))


def as_backend(kv: Union[str, KVBackend, None], *, page: int = 64,
               n_pages: Optional[int] = None) -> KVBackend:
    """Normalize the engine's ``kv`` argument to a backend instance.
    Strings are the legacy interface → ``DeprecationWarning``."""
    if kv is None:
        return DenseKV()
    if isinstance(kv, KVBackend):
        return kv
    if kv in ("dense", "paged"):
        warnings.warn(
            f"kv={kv!r} strings are deprecated: pass kv=DenseKV() or "
            "kv=PagedKV(page=..., n_pages=...) (repro.serving.kv)",
            DeprecationWarning, stacklevel=3)
        return PagedKV(page=page, n_pages=n_pages) if kv == "paged" \
            else DenseKV()
    raise ValueError(f"unknown kv backend: {kv!r}")
