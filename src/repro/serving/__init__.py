"""Serving: continuous-batching decode engine over the paper's
context-sharded fp8 KV cache."""
from repro.serving.engine import EngineStats, Request, ServeEngine

__all__ = ["EngineStats", "Request", "ServeEngine"]
