"""Serving: continuous-batching decode engine over the paper's
context-sharded fp8 KV cache, plus the gateway layer (scheduler, prefix
cache, streaming frontend, metrics) in `repro.serving.gateway` and the
multi-tenant QLoRA adapter subsystem in `repro.serving.adapters`."""
from repro.serving.engine import EngineStats, Request, ServeEngine
from repro.serving.paged_kv import PagePool, PagedConfig

__all__ = ["EngineStats", "PagePool", "PagedConfig", "Request", "ServeEngine"]
