"""Serving: continuous-batching decode engine over the paper's
context-sharded fp8 KV cache, the unified request API
(`repro.serving.api`: SamplingParams / RequestSpec), pluggable KV backends
(`repro.serving.kv`: DenseKV / PagedKV behind the KVBackend protocol), plus
the gateway layer (scheduler, prefix cache, streaming frontend, metrics) in
`repro.serving.gateway`, the multi-tenant QLoRA adapter subsystem in
`repro.serving.adapters`, the device→host→disk tiered memory hierarchy in
`repro.serving.memory`, the asynchronous dispatch/backlog runtime with
its HTTP/SSE front in `repro.serving.runtime`, and the scale-out layer:
mesh-sharded replica construction (`repro.serving.sharded`) behind the
prefix-cache-aware fleet router (`repro.serving.router`)."""
from repro.serving.api import RequestSpec, SamplingParams
from repro.serving.engine import EngineStats, Request, ServeEngine
from repro.serving.kv import DenseKV, KVBackend, PagedKV
from repro.serving.memory import TieredStore
from repro.serving.paged_kv import PagePool, PagedConfig
from repro.serving.router import ReplicaRouter
from repro.serving.runtime import (AsyncServeRuntime, RuntimePoisoned,
                                   ServingHTTPFront, Ticket)
from repro.serving.sharded import (fleet_mesh, replica_meshes, shard_engine,
                                   shard_params)

__all__ = ["AsyncServeRuntime", "DenseKV", "EngineStats", "KVBackend",
           "PagePool", "PagedConfig", "PagedKV", "ReplicaRouter", "Request",
           "RequestSpec", "RuntimePoisoned", "SamplingParams", "ServeEngine",
           "ServingHTTPFront", "Ticket", "TieredStore", "fleet_mesh",
           "replica_meshes", "shard_engine", "shard_params"]
