"""Serving metrics registry: counters, gauges, histograms → JSON blob +
Prometheus text exposition.

Prometheus-shaped (monotonic counters, point-in-time gauges, bucketed
histograms with cumulative export) and dependency-free: the gateway
observes TTFT / time-between-tokens / queue depth / pool occupancy here;
`launch/serve.py` + `benchmarks/bench_serving.py` dump `to_dict()` as JSON
and `to_prom_text()` renders the standard text format (``--prom-out``).
Exact percentiles come from retained samples (serving runs here are
bench-scale; a reservoir cap bounds memory for long soaks).
"""
from __future__ import annotations

import bisect
import random
import threading
from typing import Dict, List, Optional, Sequence, Tuple

DEFAULT_MS_BUCKETS = (1.0, 2.0, 5.0, 10.0, 20.0, 50.0, 100.0, 200.0,
                      500.0, 1000.0, 2000.0, 5000.0, 10000.0)


class Histogram:
    def __init__(self, buckets: Sequence[float] = DEFAULT_MS_BUCKETS,
                 sample_cap: int = 65536):
        self.buckets = tuple(sorted(buckets))
        self.bucket_counts = [0] * (len(self.buckets) + 1)  # +inf tail
        self.count = 0
        self.sum = 0.0
        self._samples: List[float] = []
        self._cap = sample_cap
        self._rng = random.Random(0)
        self._max = float("-inf")

    def observe(self, value: float) -> None:
        self.count += 1
        self.sum += value
        self._max = max(self._max, value)
        self.bucket_counts[bisect.bisect_left(self.buckets, value)] += 1
        # uniform reservoir: percentiles stay representative of the whole
        # stream on long soaks, not frozen on the first cap observations
        if len(self._samples) < self._cap:
            self._samples.append(value)
        else:
            j = self._rng.randrange(self.count)
            if j < self._cap:
                self._samples[j] = value

    def percentile(self, p: float) -> float:
        """Percentile over retained samples (p in [0, 100]), with linear
        interpolation between adjacent order statistics — nearest-rank
        rounding made p50 of [1, 2] arbitrarily 1 or 2 depending on the
        rounding direction; interpolation gives 1.5."""
        if not self._samples:
            return 0.0
        s = sorted(self._samples)
        rank = max(0.0, min(1.0, p / 100.0)) * (len(s) - 1)
        lo = int(rank)
        hi = min(lo + 1, len(s) - 1)
        frac = rank - lo
        return s[lo] + (s[hi] - s[lo]) * frac

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def cumulative_buckets(self) -> List[Tuple[float, int]]:
        """Prometheus-style cumulative (upper_edge, count<=edge) pairs,
        ending with the (+Inf, total) tail."""
        out: List[Tuple[float, int]] = []
        cum = 0
        for edge, n in zip(self.buckets, self.bucket_counts):
            cum += n
            out.append((edge, cum))
        out.append((float("inf"), self.count))
        return out

    def to_dict(self) -> Dict:
        return {
            "count": self.count,
            "mean": round(self.mean, 3),
            "p50": round(self.percentile(50), 3),
            "p90": round(self.percentile(90), 3),
            "p99": round(self.percentile(99), 3),
            "max": round(self._max, 3) if self.count else 0.0,
            # cumulative buckets were silently dropped before this fix —
            # the registry was "Prometheus-shaped" with no buckets exported
            "buckets": {("+Inf" if edge == float("inf") else f"{edge:g}"): n
                        for edge, n in self.cumulative_buckets()},
        }


class Metrics:
    """Flat named registry. Every conventional metric name the gateway
    publishes (counters, gauges, histograms — including the observability
    layer's tick/energy/jit gauges) is documented in one table in
    README.md § "Observability"; this class is name-agnostic plumbing.

    Two export surfaces: ``to_dict()`` (the JSON blob benches and
    `launch/serve.py` dump) and ``to_prom_text()`` (standard Prometheus
    text exposition incl. cumulative histogram buckets, rendered by
    `repro.serving.obs.prom`).

    Thread-safe: the async runtime's backlog thread, HTTP front handler
    threads (admission counters, /metrics scrapes) and the caller's thread
    all touch one registry, so every read-modify-write rides a lock —
    counter ``+=`` and histogram reservoir updates are not atomic in
    CPython."""

    def __init__(self):
        self.counters: Dict[str, float] = {}
        self.gauges: Dict[str, float] = {}
        self.histograms: Dict[str, Histogram] = {}
        self._lock = threading.Lock()

    def inc(self, name: str, n: float = 1) -> None:
        with self._lock:
            self.counters[name] = self.counters.get(name, 0) + n

    def set_gauge(self, name: str, value: float) -> None:
        with self._lock:
            self.gauges[name] = value

    def observe(self, name: str, value: float,
                buckets: Optional[Sequence[float]] = None) -> None:
        with self._lock:
            h = self.histograms.get(name)
            if h is None:
                h = self.histograms[name] = Histogram(
                    buckets or DEFAULT_MS_BUCKETS)
            h.observe(value)

    def counter(self, name: str) -> float:
        return self.counters.get(name, 0)

    def to_dict(self) -> Dict:
        with self._lock:
            return {
                "counters": dict(self.counters),
                "gauges": dict(self.gauges),
                "histograms": {k: h.to_dict()
                               for k, h in self.histograms.items()},
            }

    def to_prom_text(self) -> str:
        """The registry in Prometheus text exposition format (# TYPE
        headers, cumulative buckets + +Inf, _sum/_count) — see
        `repro.serving.obs.prom` for the format rules."""
        from repro.serving.obs.prom import render_text
        return render_text(self)
