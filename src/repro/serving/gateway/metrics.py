"""Serving metrics registry: counters, gauges, histograms → one JSON blob.

Prometheus-shaped (monotonic counters, point-in-time gauges, bucketed
histograms) but in-process and dependency-free: the gateway observes
TTFT / time-between-tokens / queue depth / pool occupancy here and
`launch/serve.py` + `benchmarks/bench_serving.py` dump `to_dict()` as JSON.
Exact percentiles come from retained samples (serving runs here are
bench-scale; a reservoir cap bounds memory for long soaks).
"""
from __future__ import annotations

import bisect
import random
from typing import Dict, List, Optional, Sequence

DEFAULT_MS_BUCKETS = (1.0, 2.0, 5.0, 10.0, 20.0, 50.0, 100.0, 200.0,
                      500.0, 1000.0, 2000.0, 5000.0, 10000.0)


class Histogram:
    def __init__(self, buckets: Sequence[float] = DEFAULT_MS_BUCKETS,
                 sample_cap: int = 65536):
        self.buckets = tuple(sorted(buckets))
        self.bucket_counts = [0] * (len(self.buckets) + 1)  # +inf tail
        self.count = 0
        self.sum = 0.0
        self._samples: List[float] = []
        self._cap = sample_cap
        self._rng = random.Random(0)
        self._max = float("-inf")

    def observe(self, value: float) -> None:
        self.count += 1
        self.sum += value
        self._max = max(self._max, value)
        self.bucket_counts[bisect.bisect_left(self.buckets, value)] += 1
        # uniform reservoir: percentiles stay representative of the whole
        # stream on long soaks, not frozen on the first cap observations
        if len(self._samples) < self._cap:
            self._samples.append(value)
        else:
            j = self._rng.randrange(self.count)
            if j < self._cap:
                self._samples[j] = value

    def percentile(self, p: float) -> float:
        """Exact percentile over retained samples (p in [0, 100])."""
        if not self._samples:
            return 0.0
        s = sorted(self._samples)
        idx = min(len(s) - 1, max(0, int(round(p / 100.0 * (len(s) - 1)))))
        return s[idx]

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def to_dict(self) -> Dict:
        return {
            "count": self.count,
            "mean": round(self.mean, 3),
            "p50": round(self.percentile(50), 3),
            "p90": round(self.percentile(90), 3),
            "p99": round(self.percentile(99), 3),
            "max": round(self._max, 3) if self.count else 0.0,
        }


class Metrics:
    """Flat named registry. Conventional names used by the gateway:

    counters:  requests_submitted / rejected / expired / cancelled /
               completed / preempted, tokens_out, prefix_hit_tokens,
               prefill_ticks_saved
    gauges:    queue_depth, active_slots, prefilling_slots, prefill_chunks,
               decode_stall_s, pool_pages_free, pool_occupancy,
               spec_drafted_tokens, spec_accepted_tokens, spec_accept_rate
    histograms (ms): ttft_ms, tbt_ms, e2e_ms, queue_wait_ms
    """

    def __init__(self):
        self.counters: Dict[str, float] = {}
        self.gauges: Dict[str, float] = {}
        self.histograms: Dict[str, Histogram] = {}

    def inc(self, name: str, n: float = 1) -> None:
        self.counters[name] = self.counters.get(name, 0) + n

    def set_gauge(self, name: str, value: float) -> None:
        self.gauges[name] = value

    def observe(self, name: str, value: float,
                buckets: Optional[Sequence[float]] = None) -> None:
        h = self.histograms.get(name)
        if h is None:
            h = self.histograms[name] = Histogram(buckets or DEFAULT_MS_BUCKETS)
        h.observe(value)

    def counter(self, name: str) -> float:
        return self.counters.get(name, 0)

    def to_dict(self) -> Dict:
        return {
            "counters": dict(self.counters),
            "gauges": dict(self.gauges),
            "histograms": {k: h.to_dict() for k, h in self.histograms.items()},
        }
