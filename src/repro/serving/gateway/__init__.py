"""Serving gateway: SLO scheduler, prefix cache, frontend + metrics over the
continuous-batching engine (see gateway.py for the dataflow diagram)."""
from repro.serving.gateway.gateway import Gateway
from repro.serving.gateway.metrics import Histogram, Metrics
from repro.serving.gateway.prefix_cache import PrefixCache
from repro.serving.gateway.scheduler import Scheduler

__all__ = ["Gateway", "Histogram", "Metrics", "PrefixCache", "Scheduler"]
