"""Prefix cache: a token trie over committed KV pages with refcounts.

Shared system prompts dominate multi-user serving traffic; their KV is
identical across requests, so re-running prefill for them wastes both ticks
(TTFT) and pool pages. This cache maps *full pages* of prompt tokens to the
page ids that already hold their k/v:

  * keys are exact token prefixes (tuple of the first ``i*page`` tokens) —
    a trie flattened into a dict, collision-free by construction;
  * ``match`` walks the longest cached prefix and hands the pages to a new
    slot **copy-on-write**: shared pages are always full, so the slot's own
    writes land in freshly allocated pages after the shared span and the
    shared pages are never mutated;
  * ``commit`` adopts a slot's prompt pages into the cache once its prefill
    finishes (ownership transfers; the pool must not free them on release);
  * refcounts track live slot users; nodes with no users are *resident* and
    evictable LRU, leaf-first, when the pool runs dry.

Only full pages are cacheable, and at least one trailing prompt token is
always left un-matched so the decode path has a token to feed (its logits
produce the first output token).
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional, Sequence, Tuple

Key = Tuple[int, ...]


@dataclasses.dataclass
class _Node:
    page_id: int
    active: int = 0            # live slot users
    children: int = 0
    last_use: int = 0


class PrefixCache:
    def __init__(self, page: int):
        self.page = page
        self.nodes: Dict[Key, _Node] = {}
        self._clock = itertools.count(1)
        self.hits = 0
        self.misses = 0

    # -- internals ------------------------------------------------------------
    def _key(self, tokens: Sequence[int], n_pages: int) -> Key:
        return tuple(tokens[: n_pages * self.page])

    def _walk(self, tokens: Sequence[int]) -> int:
        """Longest cached page span, capped so ≥1 token stays for decode."""
        limit = max(0, (len(tokens) - 1) // self.page)
        n = 0
        while n < limit and self._key(tokens, n + 1) in self.nodes:
            n += 1
        return n

    # -- read side ------------------------------------------------------------
    def lookup(self, tokens: Sequence[int]) -> int:
        """Matched page count without taking references (admission peek)."""
        return self._walk(tokens)

    def match(self, tokens: Sequence[int]) -> Tuple[List[int], List[Key]]:
        """Longest-prefix hit: increfs every matched node and returns
        (page_ids, keys). The caller attaches the pages to its slot table
        and must ``decref(keys)`` when the slot ends."""
        n = self._walk(tokens)
        ids, keys = [], []
        now = next(self._clock)
        for i in range(1, n + 1):
            node = self.nodes[self._key(tokens, i)]
            node.active += 1
            node.last_use = now
            ids.append(node.page_id)
            keys.append(self._key(tokens, i))
        if n:
            self.hits += 1
        else:
            self.misses += 1
        return ids, keys

    # -- write side -----------------------------------------------------------
    def commit(self, tokens: Sequence[int], table: Sequence[int],
               start_pages: int) -> List[Key]:
        """Adopt a slot's freshly-prefilled prompt pages, from page index
        ``start_pages`` (the slot's shared-prefix span) up to the last full
        page. Stops at the first already-cached key (a concurrent request
        committed the same prefix first; the slot keeps its duplicate page).
        Returns the committed keys — the slot holds a reference to each."""
        n_full = len(tokens) // self.page
        committed: List[Key] = []
        now = next(self._clock)
        for i in range(start_pages, n_full):
            key = self._key(tokens, i + 1)
            if key in self.nodes:
                break
            self.nodes[key] = _Node(page_id=table[i], active=1, last_use=now)
            if i > 0:
                parent = self.nodes.get(self._key(tokens, i))
                if parent is not None:
                    parent.children += 1
            committed.append(key)
        return committed

    def decref(self, keys: Sequence[Key]) -> None:
        for key in keys:
            node = self.nodes.get(key)
            if node is not None and node.active > 0:
                node.active -= 1

    # -- eviction -------------------------------------------------------------
    def evict_detailed(self, n_pages: int) -> List[Tuple[Key, int]]:
        """Free up to ``n_pages`` resident pages, LRU leaf-first. Returns
        ``(key, page_id)`` pairs so a tiered caller can spill each page's KV
        to the host tier (keyed by its token prefix) before the pool reuses
        the page."""
        freed: List[Tuple[Key, int]] = []
        while len(freed) < n_pages:
            leaves = [(k, nd) for k, nd in self.nodes.items()
                      if nd.active == 0 and nd.children == 0]
            if not leaves:
                break
            key, node = min(leaves, key=lambda kn: kn[1].last_use)
            del self.nodes[key]
            parent_key = key[: len(key) - self.page]
            parent = self.nodes.get(parent_key)
            if parent is not None:
                parent.children -= 1
            freed.append((key, node.page_id))
        return freed

    def evict(self, n_pages: int) -> List[int]:
        """Free up to ``n_pages`` resident pages, LRU leaf-first. Returns the
        freed page ids (caller returns them to the PagePool)."""
        return [pid for _, pid in self.evict_detailed(n_pages)]

    # -- tiered re-admission ---------------------------------------------------
    def readmit(self, key: Key, page_id: int) -> None:
        """Re-insert an evicted-then-spilled prefix page whose KV has just
        been re-imported into pool page ``page_id``. The node starts with no
        users (a following ``match`` increfs it like any resident node); the
        parent link is rewired when the parent is cached. The caller walks
        prefixes shortest-first, so parents re-admit before children."""
        assert key not in self.nodes, key
        assert len(key) % self.page == 0 and key, key
        self.nodes[key] = _Node(page_id=page_id, active=0,
                                last_use=next(self._clock))
        if len(key) > self.page:
            parent = self.nodes.get(key[: len(key) - self.page])
            if parent is not None:
                parent.children += 1

    # -- stats ----------------------------------------------------------------
    @property
    def n_pages(self) -> int:
        return len(self.nodes)

    def stats(self) -> Dict[str, int]:
        return {"pages": self.n_pages, "hits": self.hits,
                "misses": self.misses,
                "resident": sum(1 for n in self.nodes.values()
                                if n.active == 0)}
