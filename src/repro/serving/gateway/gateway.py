"""Serving gateway frontend: submit / stream / cancel + metrics.

The gateway is the request-facing layer above `ServeEngine`:

    client ──submit/stream/cancel──▶ Gateway ──schedules──▶ ServeEngine
                                       │                        │
                                       ├── Scheduler (SLO)      ├── decode_step
                                       ├── PrefixCache          └── PagePool
                                       └── Metrics (JSON)

It wires the engine's event hooks (`on_token` …) to per-request streaming
callbacks and a metrics registry (TTFT / time-between-tokens histograms,
queue depth, pool occupancy, preemption counters), and drives the tick loop.
Synchronous by design — the engine is one jitted decode per tick — but the
callback surface is what an async transport (HTTP/SSE) would attach to.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, Iterator, List, Optional

from repro.serving.api import RequestSpec, SamplingParams, coerce_submit
from repro.serving.engine import Request, ServeEngine
from repro.serving.gateway.metrics import Metrics
from repro.serving.obs.energy import EnergyMonitor
from repro.serving.obs.slo import PHASES as SLO_PHASES
from repro.serving.obs.slo import SLOAttribution

TokenCallback = Callable[[Request, int], None]

#: tick_gap histogram buckets: sub-ms host bubbles up to multi-second stalls
_GAP_BUCKETS = (0.05, 0.1, 0.2, 0.5, 1.0, 2.0, 5.0, 10.0, 20.0, 50.0,
                100.0, 500.0)


class Gateway:
    def __init__(self, engine: ServeEngine, metrics: Optional[Metrics] = None,
                 energy: Optional[EnergyMonitor] = None):
        self.engine = engine
        self.metrics = metrics if metrics is not None else Metrics()
        # SLO attribution: a per-request wall-time decomposition (queue /
        # prefill / decode / stall / preempted) driven by the same hooks;
        # on close the components feed per-phase histograms and, for
        # deadline violators, a slo_violation__<phase> counter naming the
        # dominant phase
        self.slo = SLOAttribution()
        # energy observability: per-tick summaries drive the Fig-12 power
        # model from live engine state (device-busy fraction + SRAM
        # residency) → chip_power_w / gated_bank_fraction / energy_per_token_j
        self.energy = energy if energy is not None else EnergyMonitor(
            n_layers=engine.cfg.num_layers)
        # optional Prometheus text sidecar: when set (launch/serve.py
        # --prom-out), the registry is atomically rewritten every
        # ``prom_every`` ticks
        self.prom_out: Optional[str] = None
        self.prom_every: int = 50
        self._prom_tick = 0
        self._stream_cbs: Dict[int, TokenCallback] = {}
        engine.on_token = self._on_token
        engine.on_done = self._on_done
        engine.on_admit = self._on_admit
        engine.on_preempt = self._on_preempt
        engine.on_expire = self._on_expire
        engine.on_tick = self._on_tick

    # -- frontend API ---------------------------------------------------------
    def submit(self, prompt: List[int], spec: Optional[RequestSpec] = None,
               sampling: Optional[SamplingParams] = None,
               **legacy) -> Request:
        """Enqueue a request described by a `RequestSpec` (+ optional
        `SamplingParams`) — the same dataclasses the engine consumes, so the
        gateway adds no kwarg list of its own. ``spec.deadline_ms`` is the
        SLO relative to now; ``spec.adapter_id`` selects a registered tenant
        fine-tune; ``spec.stream_cb(req, token)`` fires for every generated
        token. Old keyword calls still work behind a DeprecationWarning."""
        spec, sampling, deadline_s = coerce_submit(spec, sampling, legacy)
        if deadline_s is not None:      # legacy absolute deadline → relative
            spec = dataclasses.replace(
                spec, deadline_ms=(deadline_s - time.time()) * 1e3)
        req = self.engine.submit(prompt, spec, sampling)
        self._note_submit(req)
        return req

    def _note_submit(self, req: Request) -> None:
        """Gateway-side submit bookkeeping (metrics + SLO track + stream-cb
        registration), split from the engine-side enqueue so the async
        runtime can run the enqueue on its dispatch thread and replay this
        half on the backlog thread."""
        self.metrics.inc("requests_submitted")
        if req.state == "rejected":
            self.metrics.inc("requests_rejected")
        else:
            self.slo.observe_submit(req)
            if req.adapter_id is not None:
                # accepted ⇒ adapter_id is registered: per-tenant counter
                # cardinality stays bounded by the registry, not by clients
                self.metrics.inc("adapter_requests_total")
                self.metrics.inc(f"adapter_requests__{req.adapter_id}")
            if req.spec.stream_cb is not None:
                self._stream_cbs[req.uid] = req.spec.stream_cb

    def cancel(self, uid: int) -> bool:
        req = self._find_req(uid)
        ok = self.engine.cancel(uid)
        if ok and req is not None:
            self._note_cancel(req)
        elif ok:
            self.metrics.inc("requests_cancelled")
        return ok

    def _note_cancel(self, req: Request, now: Optional[float] = None) -> None:
        """Cancel bookkeeping (counter + SLO close + stream-cb drop) —
        replayed on the backlog thread by the async runtime with the
        dispatch-time timestamp."""
        self.metrics.inc("requests_cancelled")
        self._stream_cbs.pop(req.uid, None)
        self._slo_close(req, violated=False, now=now)

    def _find_req(self, uid: int) -> Optional[Request]:
        """The live Request for ``uid`` (queue or slot), before cancel
        detaches it from both."""
        for r in self.engine.slot_req:
            if r is not None and r.uid == uid:
                return r
        peek = getattr(self.engine.scheduler, "peek", None)
        if peek is not None:
            return peek(lambda r: r.uid == uid)
        return None

    def stream(self, req: Request, max_ticks: int = 100_000
               ) -> Iterator[int]:
        """Generator of ``req``'s tokens, driving the engine as needed —
        co-scheduled requests keep decoding in the same ticks."""
        emitted = 0
        ticks = 0
        while req.state not in ("done", "cancelled", "expired", "rejected") \
                or emitted < len(req.output):
            while emitted < len(req.output):
                yield req.output[emitted]
                emitted += 1
            if req.state in ("done", "cancelled", "expired", "rejected"):
                return
            if ticks >= max_ticks:
                return
            self.step()
            ticks += 1

    def step(self) -> None:
        """One engine tick + gauge refresh."""
        self.engine.tick()
        self._sample_gauges()

    def run_until_drained(self, max_ticks: int = 100_000):
        stats = self.engine.run_until_drained(max_ticks)
        self._sample_gauges()
        return stats

    # -- engine event hooks ----------------------------------------------------
    def _on_token(self, req: Request, tok: int, now: float,
                  idx: Optional[int] = None,
                  t_prev: Optional[float] = None) -> None:
        # ``idx``/``t_prev`` are emit-time snapshots (1-based output index,
        # previous token's timestamp) passed by the async runtime's backlog
        # replay: by replay time the engine may have appended further tokens
        # and advanced ``req.t_last``, so the live reads the sync path uses
        # would misclassify TTFT and compute negative inter-token gaps.
        self.slo.observe_token(req, now)
        self.metrics.inc("tokens_out")
        n = len(req.output) if idx is None else idx
        if n == 1:
            self.metrics.observe("ttft_ms", (now - req.t_submit) * 1e3)
            self.metrics.observe("queue_wait_ms",
                                 (req.t_admit - req.t_submit) * 1e3)
        else:
            prev = req.t_last if t_prev is None else t_prev
            self.metrics.observe("tbt_ms", (now - prev) * 1e3)
        cb = self._stream_cbs.get(req.uid)
        if cb is not None:
            cb(req, tok)

    def _on_done(self, req: Request) -> None:
        self.metrics.inc("requests_completed")
        self.metrics.observe("e2e_ms", req.latency_s * 1e3)
        violated = (req.deadline_s is not None
                    and req.t_done > req.deadline_s)
        if violated:
            self.metrics.inc("slo_misses")
        self._slo_close(req, violated=violated)
        if req.prefix_hit_tokens:
            self.metrics.inc("prefix_hit_tokens", req.prefix_hit_tokens)
            self.metrics.inc("prefill_ticks_saved", req.prefix_hit_tokens)
        self._stream_cbs.pop(req.uid, None)

    def _on_admit(self, req: Request, slot: int) -> None:
        self.slo.observe_admit(req)
        self.metrics.inc("admissions")

    def _on_preempt(self, req: Request, now: Optional[float] = None) -> None:
        # ``now`` is the dispatch-time timestamp when the event is replayed
        # from the async runtime's backlog thread — without it, backlog
        # processing delay would be charged to the preempted phase
        self.slo.observe_preempt(req, now)
        self.metrics.inc("preemptions")

    def _on_expire(self, req: Request, now: Optional[float] = None) -> None:
        self.metrics.inc("requests_expired")
        # an expiry IS an SLO violation — the deadline passed while queued
        self._slo_close(req, violated=True, now=now)
        self._stream_cbs.pop(req.uid, None)

    def _slo_close(self, req: Request, violated: bool,
                   now: Optional[float] = None) -> None:
        """Freeze the request's attribution track, feed the per-phase
        latency histograms and — when the request violated its SLO — blame
        the dominant phase via an attributed counter."""
        comp = self.slo.close(req, now)
        if comp is None:
            return
        for phase in SLO_PHASES:
            self.metrics.observe(f"slo_phase_ms__{phase}",
                                 comp.get(phase, 0.0) * 1e3)
        if violated:
            self.metrics.inc("slo_violations_total")
            worst = max(SLO_PHASES, key=lambda p: comp.get(p, 0.0))
            self.metrics.inc(f"slo_violation__{worst}")
            self.slo.note_violation(worst)

    def _on_tick(self, summary: Dict) -> None:
        """Engine per-tick summary → tick-gap histogram + energy model.
        ``gap_ms`` (the host-side bubble between device dispatches) goes to
        a histogram, not just the running mean — the p50 is the steady-state
        bubble while the max is dominated by compile/admission outliers."""
        if summary.get("gap_ms") is not None:
            self.metrics.observe("tick_gap_ms", summary["gap_ms"],
                                 buckets=_GAP_BUCKETS)
        if summary.get("dispatch_ahead_depth") is not None:
            self.metrics.set_gauge("dispatch_ahead_depth",
                                   summary["dispatch_ahead_depth"])
        # the async runtime snapshots SRAM utilization on the dispatch
        # thread at tick time (engine state is dispatch-thread-owned there);
        # the sync path computes it live
        sram = summary.get("sram_utilization")
        self.energy.observe_tick(
            wall_s=summary["wall_ms"] * 1e-3,
            busy_s=summary["busy_ms"] * 1e-3,
            tokens=summary["tokens"],
            sram_utilization=(self._sram_utilization()
                              if sram is None else sram),
            verify_width=summary.get("verify_width", 1))
        if self.prom_out is not None:
            self._prom_tick += 1
            if self._prom_tick % max(self.prom_every, 1) == 0:
                from repro.serving.obs.prom import write_prom
                self._sample_gauges()
                write_prom(self.prom_out, self.metrics.to_prom_text())

    def _sram_utilization(self) -> float:
        """Resident fraction of the SRAM budget the energy model charges
        retention power on: KV page-pool occupancy when paged (the dominant
        SRAM tenant), active-slot fraction when dense (the whole cache is
        pre-allocated but only active rows hold live state), plus the
        adapter cache's used fraction of its byte budget when present."""
        eng = self.engine
        if eng.pool is not None:
            total = max(eng.pool.cfg.n_pages, 1)
            kv_frac = 1.0 - eng.pool.pages_free / total
        else:
            kv_frac = (sum(1 for r in eng.slot_req if r is not None)
                       / max(eng.max_slots, 1))
        if eng.adapters is not None:
            st = eng.adapters.stats()
            budget = st.get("budget_bytes") or 0
            if budget:
                ad_frac = min(st.get("bytes_used", 0) / budget, 1.0)
                # weight KV:adapters 4:1 — KV pages dwarf adapter stacks in
                # the paper's SRAM budget split
                return min(0.8 * kv_frac + 0.2 * ad_frac, 1.0)
        return min(kv_frac, 1.0)

    # -- observability ---------------------------------------------------------
    def _sample_gauges(self) -> None:
        eng = self.engine
        self.metrics.set_gauge("queue_depth", len(eng.scheduler))
        self.metrics.set_gauge(
            "active_slots",
            sum(1 for r in eng.slot_req if r is not None))
        self.metrics.set_gauge(
            "prefilling_slots",
            sum(1 for todo in eng.slot_prefill_todo if todo))
        # chunked-prefill telemetry: cumulative chunk count plus the
        # decode-starvation gauge (wall seconds decode slots spent stalled
        # behind another request's prefill — the head-of-line signal
        # prefill_chunk exists to shrink)
        self.metrics.set_gauge("prefill_chunks", eng.stats.prefill_chunks)
        self.metrics.set_gauge("decode_stall_s",
                               round(eng.stats.decode_stall_s, 4))
        # speculative decoding: proposer volume, accepted (free) tokens and
        # the draft hit rate — the accept rate is the signal for tuning
        # spec_k (wide drafts only pay off when the history is repetitive)
        self.metrics.set_gauge("spec_drafted_tokens", eng.stats.spec_drafted)
        self.metrics.set_gauge("spec_accepted_tokens",
                               eng.stats.spec_accepted)
        self.metrics.set_gauge("spec_accept_rate",
                               round(eng.stats.spec_accept_rate, 4))
        if eng.pool is not None:
            total = eng.pool.cfg.n_pages
            self.metrics.set_gauge("pool_pages_free", eng.pool.pages_free)
            self.metrics.set_gauge(
                "pool_occupancy",
                round(1.0 - eng.pool.pages_free / max(total, 1), 4))
            if eng.prefix is not None:
                self.metrics.set_gauge("prefix_cache_pages",
                                       eng.prefix.n_pages)
        if eng.adapters is not None:
            # adapter SRAM-cache residency / hit-rate / eviction telemetry
            for name, value in eng.adapters.stats().items():
                self.metrics.set_gauge(f"adapter_cache_{name}", value)
        tiered = getattr(eng, "tiered", None)
        if tiered is not None:
            # tiered memory hierarchy: per-tier residency, where reads were
            # served from, and the promote/demote churn between tiers
            st = tiered.stats()
            for tier in ("device", "host", "disk"):
                self.metrics.set_gauge(f"tier_bytes__{tier}",
                                       st["tier_bytes"][tier])
                self.metrics.set_gauge(f"tier_hits__{tier}",
                                       st["tier_hits"][tier])
            self.metrics.set_gauge("tier_promotes", st["promotes"])
            self.metrics.set_gauge("tier_demotes", st["demotes"])
            # spill/re-admit + scheduler-prefetch effectiveness (engine-side
            # counters so they exist even when the store itself is idle)
            self.metrics.set_gauge("prefix_readmits",
                                   eng.stats.prefix_readmits)
            self.metrics.set_gauge("prefix_readmit_tokens",
                                   eng.stats.prefix_readmit_tokens)
            self.metrics.set_gauge("prefetch_hits", eng.stats.prefetch_hits)
            self.metrics.set_gauge("kv_spilled_pages",
                                   eng.stats.kv_spilled_pages)
        # tick-loop health: host bubble between device dispatches and jit
        # cache growth (recompile stalls), both from the engine's obs layer
        self.metrics.set_gauge("tick_gap_ms_mean",
                               round(eng.stats.tick_gap_ms_mean, 4))
        # the same bubble as a fraction of total tick wall — the %-of-tick
        # host overhead the async-runtime roadmap item must drive to ~0
        self.metrics.set_gauge("tick_host_overhead_frac",
                               round(eng.stats.host_overhead_frac, 4))
        self.metrics.set_gauge("jit_recompiles", eng.stats.jit_compiles)
        hol = getattr(eng.scheduler, "hol_bypasses", None)
        if hol is not None:
            self.metrics.set_gauge("sched_hol_bypasses", hol)
        # energy gauges: the Fig-12 model integrated over live tick state
        for name, value in self.energy.gauges().items():
            self.metrics.set_gauge(name, value)

    def metrics_dict(self) -> Dict:
        self._sample_gauges()
        return self.metrics.to_dict()

    def slo_report(self) -> Dict:
        """Per-phase SLO breakdown: closed-request latency percentiles per
        attribution phase plus the attributed violation counters — the
        "why did requests miss" half of the bench attribution block."""
        phases: Dict[str, Dict] = {}
        for phase in SLO_PHASES:
            h = self.metrics.histograms.get(f"slo_phase_ms__{phase}")
            if h is None:
                continue
            phases[phase] = {"p50_ms": round(h.percentile(50), 4),
                             "p95_ms": round(h.percentile(95), 4),
                             "mean_ms": round(h.mean, 4)}
        violations = {
            name.split("__", 1)[1]: int(v)
            for name, v in self.metrics.counters.items()
            if name.startswith("slo_violation__")}
        return {
            "phases": phases,
            "violations": violations,
            "violations_total": int(self.metrics.counter(
                "slo_violations_total")),
            "requests_closed": self.slo.closed,
        }
