"""SLO-aware request scheduler: priority classes, deadlines, preemption.

Policy (documented, deliberately simple — the engine is tick-synchronous):

  * **priority classes**: lower number = more urgent. Class 0 is "interactive",
    higher classes are batch/background. Strict priority across classes.
  * **EDF within a class**: entries order by (deadline, arrival) on the
    absolute ``Request.deadline_s`` the engine derives once from
    ``RequestSpec.deadline_ms`` (serving/api.py — the single deadline
    representation). Requests without a deadline sort after all deadlined
    ones.
  * **admission control**: ``pop_next(can_admit)`` hands out the best entry
    whose KV footprint fits the page pool *right now* (the engine passes a
    ``PagePool.can_admit``-backed predicate). A blocked head does not wedge
    the queue: later/lower entries may bypass it, so small requests flow
    while a huge one waits for pages.
  * **expiry**: a queued request whose deadline already passed is dropped
    (counted by the gateway) rather than admitted to miss its SLO.
  * **preemption**: when the pool runs dry mid-decode, ``pick_victim``
    names the youngest request of the lowest-priority class; the engine
    releases its pages and ``requeue``s it (generated tokens re-enter as
    prompt, so no work is lost beyond the re-prefill).
  * **chunked-prefill budget**: ``plan_prefill`` names the prefilling slots
    that advance one chunk this tick — at most one (the most urgent, same
    (priority, deadline, arrival) order) while anything decodes, all of them
    when the decode batch is empty. Decode cadence is protected and chunk
    scheduling inherits the EDF/priority invariants.
  * **adapter affinity**: ``pop_next(prefer=...)`` lets the engine prefer
    requests whose QLoRA adapter is already resident in the SRAM-budget
    cache — but only among entries with identical (priority, deadline), so
    affinity batching can never starve a more urgent cold-adapter request.
"""
from __future__ import annotations

import bisect
import itertools
import math
from typing import Callable, List, Optional, Sequence, Tuple

from repro.serving.engine import Request


class Scheduler:
    def __init__(self, max_queue: int = 4096):
        self.max_queue = max_queue
        # kept sorted by _key (keys are immutable per request), so pop/peek
        # are in-order scans rather than per-call sorts
        self._entries: List[Request] = []
        self._seq = itertools.count()
        # observability: admissions that bypassed a pool-blocked head —
        # sustained growth means a large request is parked at the front of
        # the queue while smaller ones flow around it (gateway gauge
        # ``sched_hol_bypasses``)
        self.hol_bypasses = 0

    # -- queue ----------------------------------------------------------------
    def __len__(self) -> int:
        return len(self._entries)

    def _key(self, req: Request) -> Tuple:
        deadline = req.deadline_s if req.deadline_s is not None else math.inf
        return (req.priority, deadline, req._seq)

    def push(self, req: Request) -> bool:
        """Enqueue; False (rejected) when the queue is at capacity."""
        if len(self._entries) >= self.max_queue:
            return False
        req._seq = next(self._seq)
        bisect.insort(self._entries, req, key=self._key)
        return True

    def requeue(self, req: Request) -> None:
        """Re-admit a preempted request ahead of its class (keeps its
        original arrival order via the old _seq)."""
        bisect.insort(self._entries, req, key=self._key)

    def remove(self, uid: int) -> Optional[Request]:
        for i, r in enumerate(self._entries):
            if r.uid == uid:
                return self._entries.pop(i)
        return None

    # -- scheduling decisions -------------------------------------------------
    def drop_expired(self, now: float) -> List[Request]:
        """Remove queued requests whose deadline already passed."""
        dead = [r for r in self._entries
                if r.deadline_s is not None and now > r.deadline_s]
        if dead:
            gone = {id(r) for r in dead}
            self._entries = [r for r in self._entries if id(r) not in gone]
        return dead

    def peek(self, pred: Optional[Callable[[Request], bool]] = None
             ) -> Optional[Request]:
        """Best entry (optionally the best one satisfying ``pred``)."""
        for req in self._entries:
            if pred is None or pred(req):
                return req
        return None

    def upcoming(self, n: int) -> List[Request]:
        """Read-only peek at the next ``n`` queued requests in scheduling
        order — the engine's tiered-memory prefetch hook walks these to
        warm adapters and spilled prefix KV before their admission tick."""
        return list(self._entries[:n])

    def pop_next(self, can_admit: Callable[[Request], bool] = lambda r: True,
                 prefer: Optional[Callable[[Request], bool]] = None
                 ) -> Optional[Request]:
        """Best admissible entry in (priority, deadline, arrival) order.

        ``prefer`` enables adapter-affinity batching: among admissible
        entries with the SAME (priority, deadline) key, one satisfying
        ``prefer`` (e.g. "its adapter is already resident") is handed out
        ahead of earlier arrivals. Entries of a more urgent class or an
        earlier deadline are never bypassed — affinity only breaks arrival
        ties, so priority/EDF invariants hold and a high-priority request
        with a cold adapter cannot be starved by warm low-priority traffic.
        """
        best_i: Optional[int] = None
        blocked_ahead = 0
        for i, req in enumerate(self._entries):
            if best_i is None:
                if can_admit(req):
                    best_i = i
                    if prefer is None or prefer(req):
                        break
                else:
                    blocked_ahead += 1
                continue
            head = self._entries[best_i]
            head_dl = head.deadline_s if head.deadline_s is not None else math.inf
            req_dl = req.deadline_s if req.deadline_s is not None else math.inf
            if req.priority != head.priority or req_dl != head_dl:
                break            # a different key can never be preferred
            if can_admit(req) and prefer(req):
                best_i = i
                break
        if best_i is None:
            return None
        if blocked_ahead:
            self.hol_bypasses += 1
        return self._entries.pop(best_i)

    def plan_prefill(self, prefilling: Sequence[Tuple[int, Request]],
                     n_decoding: int) -> List[int]:
        """Chunked-prefill budget for this tick: which prefilling slots
        advance one chunk. While any slot is decoding, only the most urgent
        prefill advances — one chunk per tick bounds the inter-token gap
        decode slots see to a single chunk's compute. With nothing decoding
        there is no cadence to protect, so every prefilling slot advances
        (lowest TTFT). Urgency is the same (priority, deadline, arrival)
        order the queue uses, so EDF/priority hold across chunk scheduling
        too: a background prompt can never stall an interactive one's
        chunks."""
        order = sorted(prefilling, key=lambda sr: (
            sr[1].priority,
            sr[1].deadline_s if sr[1].deadline_s is not None else math.inf,
            sr[1]._seq))
        slots = [slot for slot, _ in order]
        return slots[:1] if n_decoding > 0 else slots

    def pick_victim(self, active: Sequence[Tuple[int, Request]],
                    below_priority: Optional[int] = None) -> Optional[int]:
        """Slot to preempt: youngest request of the lowest-priority class.
        ``below_priority`` restricts victims to classes strictly less urgent
        than the given one (admission-time preemption); None allows any
        (mid-decode pool pressure — somebody must yield)."""
        candidates = [(slot, r) for slot, r in active
                      if below_priority is None or r.priority > below_priority]
        if not candidates:
            return None
        slot, _ = max(candidates, key=lambda sr: (sr[1].priority, sr[1].t_admit))
        return slot
