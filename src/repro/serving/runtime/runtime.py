"""Async disaggregated serving runtime: dispatch-ahead + backlog threads.

The synchronous gateway drives ``ServeEngine.tick()`` inline: host
bookkeeping (sampling vectors, admission, emit, metrics, SLO, callbacks)
serializes with device compute every tick — the serialization point the
paper's distributed ROM-bank architecture exists to avoid, quantified by
``EngineStats.host_overhead_frac``. This runtime splits the loop:

  dispatch thread   owns the engine + scheduler. Drains a thread-safe
                    inbox (submit / cancel / barrier), then calls
                    ``tick_begin()`` — which enqueues tick N+1's jitted
                    decode+sample *before* tick N's results are read — and
                    trims the engine's pending deque to ``depth``
                    (``tick_finish()`` materializes + emits). The device
                    queue therefore always holds the next tick's work
                    while the host loops.

  backlog thread    owns every gateway-side consumer: per-request token
                    buffers (Tickets), ``on_token`` stream callbacks,
                    metrics/SLO/energy bookkeeping, gauge sampling. The
                    dispatch thread never runs a user callback; events
                    carry their dispatch-time timestamps so SLO components
                    still telescope to wall regardless of backlog delay.

  supervisor        crash propagation in the JetThread style: any
                    exception on either worker poisons the runtime —
                    in-flight requests are cancelled into a terminal error
                    state, engine pages/pins are released, and the
                    original exception re-raises from every caller-facing
                    API (submit / cancel / drain / quiesce / close). A
                    poisoned runtime never hangs a waiter.

Token identity: the engine's split-tick pipeline feeds in-flight slots
their unmaterialized token via a device-side overlay and offsets seeded
sampling steps by the in-flight count, so seeded/greedy async output is
bit-identical to the sync path (pinned by tests/test_async_runtime.py).
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

TERMINAL_STATES = ("done", "cancelled", "expired", "rejected", "error")

_STOP = object()      # backlog sentinel


class RuntimePoisoned(RuntimeError):
    """The runtime crashed: a worker thread raised, all in-flight requests
    were cancelled with a terminal error state, and the original exception
    is re-raised (chained) in every caller-facing API."""

    def __init__(self, cause: BaseException):
        super().__init__(f"serving runtime poisoned by worker exception: "
                         f"{cause!r}")
        self.cause = cause


class Ticket:
    """Thread-safe client handle for one async request.

    The dispatch thread binds the engine ``Request``; the backlog thread
    pushes tokens and the terminal state; any client thread may block in
    ``result()`` / iterate ``stream()``. All state rides one condition
    variable — no polling."""

    def __init__(self):
        self._cond = threading.Condition()
        self._tokens: List[int] = []
        self._done_cbs: List = []
        self.state = "pending"          # pending → queued → <terminal>
        self.error: Optional[BaseException] = None
        self.req = None                 # engine Request, set at bind
        self.uid: Optional[int] = None

    # -- worker-side ---------------------------------------------------------
    def _bind(self, req) -> None:
        with self._cond:
            self.req = req
            self.uid = req.uid
            if req.state == "rejected":
                self.state = "rejected"
            elif self.state == "pending":
                self.state = "queued"
            self._cond.notify_all()
        if req.state == "rejected":
            self._fire_done_cbs()

    def _push(self, tok: int) -> None:
        with self._cond:
            self._tokens.append(tok)
            self._cond.notify_all()

    def _finish(self, state: str, error: Optional[BaseException] = None
                ) -> None:
        with self._cond:
            if self.state in TERMINAL_STATES:
                return
            self.state = state
            self.error = error
            self._cond.notify_all()
        self._fire_done_cbs()

    def _fire_done_cbs(self) -> None:
        cbs, self._done_cbs = self._done_cbs, []
        for cb in cbs:
            try:
                cb(self)
            except Exception:
                pass    # client callback failures never poison the runtime

    # -- client-side ---------------------------------------------------------
    @property
    def terminal(self) -> bool:
        return self.state in TERMINAL_STATES

    def add_done_callback(self, cb) -> None:
        """``cb(ticket)`` once the ticket reaches a terminal state (fires
        immediately if it already has) — the HTTP front's per-tenant
        in-flight accounting hangs off this."""
        fire = False
        with self._cond:
            if self.terminal:
                fire = True
            else:
                self._done_cbs.append(cb)
        if fire:
            try:
                cb(self)
            except Exception:
                pass

    def wait_bound(self, timeout: Optional[float] = None) -> None:
        with self._cond:
            if not self._cond.wait_for(
                    lambda: self.req is not None or self.terminal, timeout):
                raise TimeoutError("runtime did not bind the request")

    def tokens(self) -> List[int]:
        with self._cond:
            return list(self._tokens)

    def stream(self, timeout: float = 60.0):
        """Yield tokens as the backlog thread lands them; returns after the
        terminal state (raises RuntimePoisoned if that state is an error).
        ``timeout`` bounds each *wait between tokens*, not the stream."""
        i = 0
        while True:
            with self._cond:
                if not self._cond.wait_for(
                        lambda: len(self._tokens) > i or self.terminal,
                        timeout):
                    raise TimeoutError("token stream stalled")
                batch = self._tokens[i:]
                i = len(self._tokens)
                state = self.state if (self.terminal
                                       and i >= len(self._tokens)) else None
                err = self.error
            for tok in batch:
                yield tok
            if state is not None:
                if state == "error":
                    raise RuntimePoisoned(err) if err is not None \
                        else RuntimeError("request errored")
                return

    def result(self, timeout: Optional[float] = None) -> List[int]:
        """Block until terminal; return the full token list. Raises
        RuntimePoisoned when the runtime crashed under this request."""
        with self._cond:
            if not self._cond.wait_for(lambda: self.terminal, timeout):
                raise TimeoutError("request did not finish")
            if self.state == "error":
                raise RuntimePoisoned(self.error) if self.error is not None \
                    else RuntimeError("request errored")
            return list(self._tokens)


class AsyncServeRuntime:
    """Wrap a `Gateway` in the dispatch/backlog/supervisor thread trio.

    Use as a context manager or call ``start()`` / ``close()`` explicitly.
    ``submit`` / ``cancel`` are thread-safe (multiple client threads may
    call them concurrently); ``quiesce()`` is the barrier fuzz/tests use
    to observe a consistent engine + metrics state."""

    def __init__(self, gateway, *, depth: int = 1, inbox_limit: int = 1024,
                 gauge_every: int = 20):
        assert depth >= 0
        self.gw = gateway
        self.eng = gateway.engine
        self.depth = depth
        self.gauge_every = max(gauge_every, 1)
        self._inbox: "queue.Queue" = queue.Queue(maxsize=inbox_limit)
        self._events: "queue.Queue" = queue.Queue()
        self._tickets: Dict[int, Ticket] = {}
        self._tickets_lock = threading.Lock()
        self._poison: Optional[BaseException] = None
        self._poison_lock = threading.Lock()
        self._stop = threading.Event()
        self._started = False
        self._closed = False
        self._tick_events = 0
        self._hooks0: Dict[str, Any] = {}
        self._dispatch_thread = threading.Thread(
            target=self._dispatch_loop, name="serve-dispatch", daemon=True)
        self._backlog_thread = threading.Thread(
            target=self._backlog_loop, name="serve-backlog", daemon=True)

    # -- lifecycle -----------------------------------------------------------
    def start(self) -> "AsyncServeRuntime":
        if self._started:
            return self
        self._wire_hooks()
        self._started = True
        self._dispatch_thread.start()
        self._backlog_thread.start()
        return self

    def __enter__(self) -> "AsyncServeRuntime":
        return self.start()

    def __exit__(self, exc_type, exc, tb):
        # don't mask a client exception with the poison re-raise
        self.close(raise_on_poison=exc_type is None)
        return False

    def close(self, timeout: float = 30.0,
              raise_on_poison: bool = True) -> None:
        """Graceful shutdown: stop the dispatch loop (settling any pending
        tick), drain the backlog, join both threads; re-raise the poison
        exception if the runtime crashed."""
        if self._started and not self._closed:
            self._stop.set()
            self._dispatch_thread.join(timeout)
            self._events.put(_STOP)
            self._backlog_thread.join(timeout)
            self._unwire_hooks()
            self._closed = True
        if raise_on_poison and self._poison is not None:
            raise RuntimePoisoned(self._poison)

    @property
    def poisoned(self) -> bool:
        return self._poison is not None

    @property
    def exception(self) -> Optional[BaseException]:
        return self._poison

    def _check_poison(self) -> None:
        if self._poison is not None:
            raise RuntimePoisoned(self._poison)

    # -- client API ----------------------------------------------------------
    def submit(self, prompt: List[int], spec=None, sampling=None,
               timeout: float = 30.0) -> Ticket:
        """Thread-safe submit: enqueue for the dispatch thread, block until
        the engine Request is bound (so ``ticket.uid`` and rejection are
        known), return the Ticket."""
        self._check_poison()
        if not self._started:
            raise RuntimeError("runtime not started")
        ticket = Ticket()
        self._inbox.put(("submit", (list(prompt), spec, sampling), ticket),
                        timeout=timeout)
        try:
            ticket.wait_bound(timeout)
        except TimeoutError:
            self._check_poison()
            raise
        self._check_poison()
        return ticket

    def cancel(self, uid: int, timeout: float = 30.0) -> bool:
        """Thread-safe cancel by uid; blocks for the dispatch thread's
        verdict (False = unknown/already finished)."""
        self._check_poison()
        box: Dict[str, bool] = {"ok": False}
        done = threading.Event()
        self._inbox.put(("cancel", uid, box, done), timeout=timeout)
        if not done.wait(timeout):
            self._check_poison()
            raise TimeoutError("cancel did not complete")
        self._check_poison()
        return box["ok"]

    def quiesce(self, timeout: float = 60.0) -> None:
        """Barrier: returns once the dispatch thread has settled every
        pending tick AND the backlog thread has processed every event
        enqueued before that point — engine state, tickets and the metrics
        registry are mutually consistent afterwards."""
        self._check_poison()
        done = threading.Event()
        self._inbox.put(("barrier", done), timeout=timeout)
        if not done.wait(timeout):
            self._check_poison()
            raise TimeoutError("quiesce barrier did not complete")
        self._check_poison()

    def drain(self, timeout: float = 300.0) -> None:
        """Block until every submitted request reached a terminal state and
        the engine is empty (then quiesce). Raises on poison/timeout."""
        deadline = time.monotonic() + timeout
        while True:
            self._check_poison()
            with self._tickets_lock:
                pending = [t for t in self._tickets.values()
                           if not t.terminal]
            busy = (len(self.eng.scheduler)
                    or any(r is not None for r in self.eng.slot_req)
                    or len(self.eng._pending))
            if not pending and not busy:
                self.quiesce(timeout=max(deadline - time.monotonic(), 1.0))
                return
            if time.monotonic() > deadline:
                raise TimeoutError(
                    f"drain timed out with {len(pending)} live requests")
            time.sleep(0.002)

    # -- admission (the HTTP front's budget checks) --------------------------
    def admission_check(self, prompt_len: int, max_new_tokens: int,
                        adapter_id: Optional[str] = None,
                        max_queue: int = 256) -> Optional[str]:
        """Front-door admission control against pool + adapter budgets.
        Returns a human-readable rejection reason, or None to admit. Reads
        engine ints cross-thread (point-in-time admission is inherently
        approximate; the engine's own admission is the hard gate)."""
        eng = self.eng
        if self._poison is not None:
            return "runtime poisoned"
        if len(eng.scheduler) >= max_queue:
            return "queue full"
        if adapter_id is not None:
            if eng.adapters is None or not eng.adapters.servable(adapter_id):
                return f"adapter {adapter_id!r} not servable"
        if eng.kv.supports_paging:
            need = eng.kv.pages_for(
                min(prompt_len + max_new_tokens, eng.max_len))
            if need > eng.kv.capacity_pages:
                return "context exceeds page-pool capacity"
        return None

    # -- hook wiring ---------------------------------------------------------
    def _wire_hooks(self) -> None:
        """Replace the gateway's inline engine hooks with event enqueuers:
        the dispatch thread only captures (event, timestamp); the backlog
        thread replays the gateway bookkeeping."""
        eng, ev = self.eng, self._events
        self._hooks0 = {k: getattr(eng, k) for k in
                        ("on_token", "on_done", "on_admit", "on_preempt",
                         "on_expire", "on_tick")}
        # snapshot the 1-based output index and the previous token's
        # timestamp at emit time: by backlog-replay time the engine has
        # moved on, and the gateway's live reads would misclassify
        # TTFT/TBT (see Gateway._on_token)
        eng.on_token = lambda req, tok, now: ev.put(
            ("token", req, tok, now, len(req.output), req.t_last))
        eng.on_done = lambda req: ev.put(("done", req))
        eng.on_admit = lambda req, slot: ev.put(("admit", req, slot))
        eng.on_preempt = lambda req: ev.put(("preempt", req, time.time()))
        eng.on_expire = lambda req: ev.put(("expire", req, time.time()))
        eng.on_tick = self._on_tick_dispatch

    def _unwire_hooks(self) -> None:
        for k, v in self._hooks0.items():
            setattr(self.eng, k, v)

    def _on_tick_dispatch(self, summary: Dict) -> None:
        # engine state is dispatch-thread-owned: snapshot what the energy
        # model needs here instead of letting the backlog read it racily
        summary["sram_utilization"] = self.gw._sram_utilization()
        self._events.put(("tick", summary))

    # -- dispatch thread -----------------------------------------------------
    def _dispatch_loop(self) -> None:
        eng = self.eng
        try:
            while not self._stop.is_set():
                self._drain_inbox()
                if not (len(eng.scheduler)
                        or any(r is not None for r in eng.slot_req)):
                    eng._settle_pipeline()
                    self._drain_inbox(timeout=0.02)
                    continue
                ticks0 = eng.stats.ticks
                t0 = time.perf_counter()
                eng.tick_begin()
                while len(eng._pending) > self.depth:
                    eng.tick_finish()
                eng.stats.wall_s += time.perf_counter() - t0
                if eng.stats.ticks == ticks0:
                    # no progress: settle and re-check — a queued request
                    # nothing can admit must not busy-spin the loop
                    eng._settle_pipeline()
                    if not any(r is not None for r in eng.slot_req):
                        self._drain_inbox(timeout=0.02)
            # graceful stop: flush the pipeline so every sampled token is
            # emitted before the backlog drains
            eng._settle_pipeline()
        except BaseException as exc:      # noqa: BLE001 — supervisor contract
            self._poison_with(exc)
        finally:
            if self._poison is not None:
                self._cleanup_after_poison()

    def _drain_inbox(self, timeout: Optional[float] = None) -> bool:
        try:
            op = (self._inbox.get(timeout=timeout) if timeout
                  else self._inbox.get_nowait())
        except queue.Empty:
            return False
        while True:
            self._handle_op(op)
            try:
                op = self._inbox.get_nowait()
            except queue.Empty:
                return True

    def _handle_op(self, op: Tuple) -> None:
        kind = op[0]
        if kind == "submit":
            _, (prompt, spec, sampling), ticket = op
            req = self.eng.submit(prompt, spec, sampling)
            with self._tickets_lock:
                self._tickets[req.uid] = ticket
            ticket._bind(req)
            self._events.put(("submit", req))
        elif kind == "cancel":
            _, uid, box, done = op
            req = self.gw._find_req(uid)
            ok = self.eng.cancel(uid)
            if ok and req is not None:
                self._events.put(("cancel", req, time.time()))
            elif ok:
                self._events.put(("cancel", None, time.time()))
            box["ok"] = ok
            done.set()
        elif kind == "barrier":
            self.eng._settle_pipeline()
            self._events.put(("barrier", op[1]))

    # -- backlog thread ------------------------------------------------------
    def _backlog_loop(self) -> None:
        try:
            while True:
                evt = self._events.get()
                if evt is _STOP:
                    break
                self._handle_event(evt)
        except BaseException as exc:      # noqa: BLE001 — supervisor contract
            self._poison_with(exc)
            self._cleanup_tickets()

    def _handle_event(self, evt: Tuple) -> None:
        gw = self.gw
        kind = evt[0]
        if kind == "token":
            _, req, tok, now, idx, t_prev = evt
            gw._on_token(req, tok, now, idx=idx, t_prev=t_prev)
            t = self._ticket(req)
            if t is not None:
                t._push(tok)
        elif kind == "done":
            gw._on_done(evt[1])
            self._finish_ticket(evt[1], "done")
        elif kind == "submit":
            gw._note_submit(evt[1])
        elif kind == "admit":
            gw._on_admit(evt[1], evt[2])
        elif kind == "preempt":
            gw._on_preempt(evt[1], now=evt[2])
        elif kind == "expire":
            gw._on_expire(evt[1], now=evt[2])
            self._finish_ticket(evt[1], "expired")
        elif kind == "cancel":
            _, req, now = evt
            if req is not None:
                gw._note_cancel(req, now=now)
                self._finish_ticket(req, "cancelled")
            else:
                gw.metrics.inc("requests_cancelled")
        elif kind == "tick":
            gw._on_tick(evt[1])
            gw.metrics.set_gauge("backlog_len", self._events.qsize())
            self._tick_events += 1
            if self._tick_events % self.gauge_every == 0:
                gw._sample_gauges()
        elif kind == "barrier":
            gw._sample_gauges()
            gw.metrics.set_gauge("backlog_len", 0)
            evt[1].set()

    def _ticket(self, req) -> Optional[Ticket]:
        with self._tickets_lock:
            return self._tickets.get(req.uid)

    def _finish_ticket(self, req, state: str) -> None:
        t = self._ticket(req)
        if t is not None:
            t._finish(state)

    # -- supervisor ----------------------------------------------------------
    def _poison_with(self, exc: BaseException) -> None:
        with self._poison_lock:
            if self._poison is not None:
                return
            self._poison = exc
        self._stop.set()

    def _cleanup_after_poison(self) -> None:
        """Dispatch-thread poison cleanup: drop unmaterialized work, cancel
        every live request, release every slot's pages and adapter pins,
        drain the scheduler, fail pending inbox ops, then error the
        tickets. Zero leaked pages/pins is asserted by the crash-injection
        tests."""
        eng = self.eng
        try:
            eng._pending.clear()
            for slot, req in list(enumerate(eng.slot_req)):
                if req is None:
                    continue
                req.state = "cancelled"
                eng.stats.cancelled += 1
                eng._release_slot(slot)
            while len(eng.scheduler):
                r = eng.scheduler.pop_next(lambda _r: True)
                if r is None:
                    break
                r.state = "cancelled"
                eng.stats.cancelled += 1
        except Exception:
            pass          # best effort — the poison still propagates
        # fail inbox ops that will never be handled
        while True:
            try:
                op = self._inbox.get_nowait()
            except queue.Empty:
                break
            if op[0] == "submit":
                op[2]._finish("error", self._poison)
            elif op[0] == "cancel":
                op[3].set()
            elif op[0] == "barrier":
                op[1].set()
        self._cleanup_tickets()
        self._events.put(_STOP)

    def _cleanup_tickets(self) -> None:
        with self._tickets_lock:
            tickets = list(self._tickets.values())
        for t in tickets:
            t._finish("error", self._poison)
