"""Stdlib-only HTTP/SSE front for the async serving runtime.

Endpoints (JSON in/out unless noted):

  POST /v1/submit          {"prompt": [ints], "max_new_tokens": n, ...}
                           -> 200 {"uid", "state"} | 429 {"error"} (+
                           Retry-After) when admission control or the
                           per-tenant in-flight limit rejects | 503 when
                           the runtime is poisoned.
  GET  /v1/stream/<uid>    text/event-stream: one ``data: {"token": t}``
                           frame per token, then ``data: {"done": true,
                           "state": "...", "tokens": [...]}``.
  POST /v1/cancel/<uid>    -> {"cancelled": bool}
  GET  /v1/result/<uid>    block until terminal -> {"state", "tokens"}
  GET  /metrics            Prometheus exposition (gateway registry)
  GET  /healthz            200 "ok" | 503 "poisoned"
  POST /v1/shutdown        -> 200, then the server stops accepting (used
                           by the CI smoke for graceful shutdown)

Backpressure is two-layered, both answered with 429 + Retry-After so
clients can apply honest backoff:

  * **admission control** — ``AsyncServeRuntime.admission_check`` screens
    against scheduler queue depth, the KV page-pool budget, and adapter
    servability before a request ever reaches the dispatch inbox;
  * **per-tenant bounds** — each tenant (``"tenant"`` field, default
    "anon") gets at most ``tenant_limit`` in-flight requests, counted on
    accept and released via ``Ticket.add_done_callback`` — one hot tenant
    cannot starve the pool for everyone else.

``ThreadingHTTPServer`` gives each connection its own thread, so a slow
SSE consumer only parks its own socket: tokens buffer in the Ticket (the
backlog thread never blocks on a client)."""
from __future__ import annotations

import json
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Dict, Optional

from repro.serving.api import RequestSpec, SamplingParams
from repro.serving.runtime.runtime import AsyncServeRuntime, RuntimePoisoned

_SAMPLING_FIELDS = ("temperature", "top_k", "top_p", "seed", "spec_k")
_SPEC_FIELDS = ("max_new_tokens", "eos_id", "priority", "deadline_ms",
                "adapter_id")


class ServingHTTPFront:
    """Bind the runtime to a host:port; ``start()`` serves on a daemon
    thread, ``close()`` stops it. Port 0 picks an ephemeral port
    (``.port`` reports the bound one — tests and CI use this)."""

    def __init__(self, runtime: AsyncServeRuntime, host: str = "127.0.0.1",
                 port: int = 8080, *, tenant_limit: int = 8,
                 max_queue: int = 256):
        self.runtime = runtime
        self.tenant_limit = tenant_limit
        self.max_queue = max_queue
        self._tenants: Dict[str, int] = {}
        self._tenant_lock = threading.Lock()
        self.shutdown_requested = threading.Event()
        front = self

        class Handler(_Handler):
            pass

        Handler.front = front
        self._server = ThreadingHTTPServer((host, port), Handler)
        self._server.daemon_threads = True
        self._thread = threading.Thread(target=self._server.serve_forever,
                                        name="serve-http", daemon=True)

    @property
    def port(self) -> int:
        return self._server.server_address[1]

    def start(self) -> "ServingHTTPFront":
        self._thread.start()
        return self

    def close(self) -> None:
        self._server.shutdown()
        self._thread.join(timeout=10)
        self._server.server_close()

    def serve_until_shutdown(self, poll_s: float = 0.2) -> None:
        """Block until POST /v1/shutdown (or runtime poison) — the
        ``launch/serve.py --http-port`` foreground loop."""
        while not self.shutdown_requested.wait(poll_s):
            if self.runtime.poisoned:
                break

    # -- per-tenant backpressure --------------------------------------------
    def _tenant_acquire(self, tenant: str) -> bool:
        with self._tenant_lock:
            if self._tenants.get(tenant, 0) >= self.tenant_limit:
                return False
            self._tenants[tenant] = self._tenants.get(tenant, 0) + 1
            return True

    def _tenant_release(self, tenant: str) -> None:
        with self._tenant_lock:
            n = self._tenants.get(tenant, 0) - 1
            if n <= 0:
                self._tenants.pop(tenant, None)
            else:
                self._tenants[tenant] = n

    # -- request handling (runs on connection threads) ----------------------
    def handle_submit(self, body: Dict) -> tuple:
        rt = self.runtime
        prompt = body.get("prompt")
        if not isinstance(prompt, list) or not all(
                isinstance(t, int) for t in prompt):
            return 400, {"error": "prompt must be a list of ints"}, {}
        tenant = str(body.get("tenant", "anon"))
        try:
            sampling = SamplingParams(**{k: body[k] for k in _SAMPLING_FIELDS
                                         if body.get(k) is not None})
            spec = RequestSpec(**{k: body[k] for k in _SPEC_FIELDS
                                  if body.get(k) is not None})
        except (TypeError, ValueError) as exc:
            return 400, {"error": f"bad request options: {exc}"}, {}
        reason = rt.admission_check(len(prompt), spec.max_new_tokens,
                                    adapter_id=spec.adapter_id,
                                    max_queue=self.max_queue)
        if reason is not None:
            rt.gw.metrics.inc("admission_rejects")
            return 429, {"error": reason}, {"Retry-After": "1"}
        if not self._tenant_acquire(tenant):
            rt.gw.metrics.inc("admission_rejects")
            return 429, {"error": f"tenant {tenant!r} at in-flight limit "
                                  f"({self.tenant_limit})"}, {"Retry-After": "1"}
        try:
            ticket = rt.submit(prompt, spec=spec, sampling=sampling)
        except RuntimePoisoned as exc:
            self._tenant_release(tenant)
            return 503, {"error": str(exc)}, {}
        except Exception as exc:
            self._tenant_release(tenant)
            return 400, {"error": str(exc)}, {}
        ticket.add_done_callback(lambda _t: self._tenant_release(tenant))
        if ticket.state == "rejected":
            rt.gw.metrics.inc("admission_rejects")
            return 429, {"error": "engine admission rejected the request",
                         "uid": ticket.uid}, {"Retry-After": "1"}
        return 200, {"uid": ticket.uid, "state": ticket.state}, {}

    def find_ticket(self, uid: int):
        with self.runtime._tickets_lock:
            return self.runtime._tickets.get(uid)


class _Handler(BaseHTTPRequestHandler):
    front: ServingHTTPFront = None     # bound per-front subclass
    protocol_version = "HTTP/1.1"

    # silence default stderr access log — the gateway has real metrics
    def log_message(self, fmt, *args):  # noqa: A003
        pass

    def _json(self, code: int, payload: Dict,
              headers: Optional[Dict] = None) -> None:
        data = json.dumps(payload).encode()
        self.send_response(code)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(data)))
        for k, v in (headers or {}).items():
            self.send_header(k, v)
        self.end_headers()
        self.wfile.write(data)

    def _text(self, code: int, text: str, ctype: str = "text/plain") -> None:
        data = text.encode()
        self.send_response(code)
        self.send_header("Content-Type", ctype)
        self.send_header("Content-Length", str(len(data)))
        self.end_headers()
        self.wfile.write(data)

    def _read_body(self) -> Dict:
        n = int(self.headers.get("Content-Length", 0) or 0)
        if n <= 0:
            return {}
        try:
            return json.loads(self.rfile.read(n) or b"{}")
        except json.JSONDecodeError:
            return {}

    def _uid_from(self, prefix: str) -> Optional[int]:
        tail = self.path[len(prefix):].split("?", 1)[0]
        try:
            return int(tail)
        except ValueError:
            return None

    # -- routes --------------------------------------------------------------
    def do_GET(self):  # noqa: N802 — BaseHTTPRequestHandler contract
        front = self.front
        if self.path == "/healthz":
            if front.runtime.poisoned:
                self._text(503, "poisoned")
            else:
                self._text(200, "ok")
        elif self.path == "/metrics":
            self._text(200, front.runtime.gw.metrics.to_prom_text(),
                       ctype="text/plain; version=0.0.4")
        elif self.path.startswith("/v1/stream/"):
            self._stream(self._uid_from("/v1/stream/"))
        elif self.path.startswith("/v1/result/"):
            uid = self._uid_from("/v1/result/")
            ticket = front.find_ticket(uid) if uid is not None else None
            if ticket is None:
                self._json(404, {"error": f"unknown uid {uid}"})
                return
            try:
                toks = ticket.result(timeout=300.0)
                self._json(200, {"state": ticket.state, "tokens": toks})
            except RuntimePoisoned as exc:
                self._json(503, {"error": str(exc)})
            except TimeoutError:
                self._json(504, {"error": "request did not finish"})
        else:
            self._json(404, {"error": f"no route {self.path}"})

    def do_POST(self):  # noqa: N802
        front = self.front
        if self.path == "/v1/submit":
            code, payload, headers = front.handle_submit(self._read_body())
            self._json(code, payload, headers)
        elif self.path.startswith("/v1/cancel/"):
            uid = self._uid_from("/v1/cancel/")
            if uid is None:
                self._json(400, {"error": "bad uid"})
                return
            try:
                ok = front.runtime.cancel(uid)
                self._json(200, {"cancelled": bool(ok)})
            except RuntimePoisoned as exc:
                self._json(503, {"error": str(exc)})
        elif self.path == "/v1/shutdown":
            self._json(200, {"shutdown": True})
            front.shutdown_requested.set()
        else:
            self._json(404, {"error": f"no route {self.path}"})

    def _stream(self, uid: Optional[int]) -> None:
        front = self.front
        ticket = front.find_ticket(uid) if uid is not None else None
        if ticket is None:
            self._json(404, {"error": f"unknown uid {uid}"})
            return
        self.send_response(200)
        self.send_header("Content-Type", "text/event-stream")
        self.send_header("Cache-Control", "no-cache")
        # SSE is open-ended: no Content-Length; close delimits the stream
        self.send_header("Connection", "close")
        self.end_headers()
        try:
            for tok in ticket.stream(timeout=120.0):
                self.wfile.write(
                    f"data: {json.dumps({'token': tok})}\n\n".encode())
                self.wfile.flush()
            final = {"done": True, "state": ticket.state,
                     "tokens": ticket.tokens()}
        except RuntimePoisoned as exc:
            final = {"done": True, "state": "error", "error": str(exc)}
        except (TimeoutError, BrokenPipeError, ConnectionError):
            return
        try:
            self.wfile.write(f"data: {json.dumps(final)}\n\n".encode())
            self.wfile.flush()
        except (BrokenPipeError, ConnectionError):
            pass
