"""Asynchronous disaggregated serving runtime.

Disaggregates the synchronous tick loop into three roles (runtime.py):

  * a **dispatch thread** that owns the engine + scheduler and keeps the
    device >= 1 tick ahead via the engine's split-tick pipeline
    (``tick_begin`` / ``tick_finish``),
  * a **detokenize/stream backlog thread** that drains device results into
    per-request token streams, ``on_token`` callbacks, metrics/SLO/energy
    bookkeeping and SSE frames — off the dispatch critical path,
  * a **supervisor** contract: any worker exception poisons the runtime,
    cancels in-flight requests with a terminal error state and re-raises
    in every caller-facing API — no silent hangs.

http.py is the stdlib-only HTTP/SSE front: POST submit / GET SSE stream /
cancel endpoints with admission control against pool+adapter budgets and
per-tenant backpressure (bounded queues, 429 + Retry-After).
"""
from repro.serving.runtime.http import ServingHTTPFront
from repro.serving.runtime.runtime import (AsyncServeRuntime, RuntimePoisoned,
                                           Ticket)

__all__ = ["AsyncServeRuntime", "RuntimePoisoned", "ServingHTTPFront",
           "Ticket"]
