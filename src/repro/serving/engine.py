"""Batched serving engine: continuous batching over fixed decode slots.

The paper's deployment is single-stream edge decode (batch = 1, token by
token, weights in ROM). This engine generalizes it to the production mesh:

  * ``max_slots`` concurrent sequences share one jitted ``decode_step`` whose
    KV cache is the paper's "distributed SRAM" — context-sharded over the
    ``model`` axis, fp8 payload (C2/C3). Every tick decodes one token for
    every active slot (B = max_slots, static shapes — no recompiles).
  * **continuous batching**: slots free as sequences finish and are refilled
    from the queue mid-flight; per-slot positions drive the cache scatter and
    attention masks.
  * **prefill** is either ``token`` mode — feed the prompt through
    decode_step one token at a time (the paper's own prefill: "executes all
    operations token-by-token, eliminating the prefill/decoding
    distinction") — or ``batched`` mode, a bucketed full-sequence prefill
    per request that splices the resulting cache rows into the live batch
    (beyond-paper; amortizes long prompts).
  * sampling: greedy or temperature/top-k, jitted with a per-engine PRNG.

SSM/hybrid archs serve through the same interface (their "cache" is the
recurrent state; positions only gate the attention blocks, if any).
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Callable, Deque, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.transformer import Model

Params = Any


@dataclasses.dataclass
class Request:
    uid: int
    prompt: List[int]
    max_new_tokens: int = 32
    temperature: float = 0.0        # 0 → greedy
    top_k: int = 0                  # 0 → full softmax
    eos_id: Optional[int] = None
    # filled by the engine
    output: List[int] = dataclasses.field(default_factory=list)
    t_submit: float = 0.0
    t_first: float = 0.0
    t_done: float = 0.0

    @property
    def ttft_s(self) -> float:
        return self.t_first - self.t_submit

    @property
    def latency_s(self) -> float:
        return self.t_done - self.t_submit


@dataclasses.dataclass
class EngineStats:
    ticks: int = 0
    tokens_out: int = 0
    completed: int = 0
    wall_s: float = 0.0

    @property
    def tps(self) -> float:
        return self.tokens_out / self.wall_s if self.wall_s else 0.0


class ServeEngine:
    def __init__(self, model: Model, params: Params, *, max_slots: int = 8,
                 max_len: int = 1024, prefill: str = "token", seed: int = 0):
        assert model.mode in ("serve", "qlora")
        self.model = model
        self.cfg = model.cfg
        self.params = params
        self.max_slots = max_slots
        self.max_len = max_len
        self.prefill_mode = prefill
        self.key = jax.random.PRNGKey(seed)

        self.cache = model.init_cache(max_slots, max_len)
        self.pos = np.zeros((max_slots,), np.int32)       # next write position
        self.slot_req: List[Optional[Request]] = [None] * max_slots
        self.pending_prompt: List[List[int]] = [[] for _ in range(max_slots)]
        self.queue: Deque[Request] = deque()
        self.stats = EngineStats()
        self._uid = 0

        self._decode = jax.jit(self._decode_fn)
        self._sample = jax.jit(self._sample_fn, static_argnums=(3,))

    # -- jitted kernels --------------------------------------------------------
    def _decode_fn(self, params, cache, tokens, pos):
        logits, cache = self.model.decode_step(params, cache, tokens, pos)
        return logits, cache

    def _sample_fn(self, logits, key, temperature, top_k: int):
        greedy = jnp.argmax(logits, axis=-1)
        if top_k:
            vals, idx = jax.lax.top_k(logits, top_k)
            masked = jnp.full_like(logits, -1e30).at[
                jnp.arange(logits.shape[0])[:, None], idx].set(vals)
        else:
            masked = logits
        scaled = masked / jnp.maximum(temperature[:, None], 1e-6)
        sampled = jax.random.categorical(key, scaled, axis=-1)
        use_greedy = temperature <= 0.0
        return jnp.where(use_greedy, greedy, sampled).astype(jnp.int32)

    # -- public API ---------------------------------------------------------------
    def submit(self, prompt: List[int], max_new_tokens: int = 32,
               temperature: float = 0.0, top_k: int = 0,
               eos_id: Optional[int] = None) -> Request:
        self._uid += 1
        req = Request(self._uid, list(prompt), max_new_tokens, temperature,
                      top_k, eos_id, t_submit=time.time())
        self.queue.append(req)
        return req

    def run_until_drained(self, max_ticks: int = 100_000) -> EngineStats:
        t0 = time.time()
        while (self.queue or any(r is not None for r in self.slot_req)) \
                and self.stats.ticks < max_ticks:
            self.tick()
        self.stats.wall_s += time.time() - t0
        return self.stats

    # -- engine internals ------------------------------------------------------------
    def _free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.slot_req) if r is None]

    def _admit(self) -> None:
        for slot in self._free_slots():
            if not self.queue:
                break
            req = self.queue.popleft()
            if len(req.prompt) + req.max_new_tokens > self.max_len:
                req.prompt = req.prompt[-(self.max_len - req.max_new_tokens):]
            self.slot_req[slot] = req
            self.pos[slot] = 0
            # SSM/hybrid prefill must thread recurrent state → token mode
            # (model.prefill fills the KV cache only; see models/transformer).
            batched_ok = self.cfg.family not in ("ssm", "hybrid")
            if self.prefill_mode == "batched" and batched_ok and len(req.prompt) > 1:
                self._batched_prefill(slot, req)
                self.pending_prompt[slot] = [req.prompt[-1]]
            else:
                # paper mode: prompt tokens stream through decode_step
                self.pending_prompt[slot] = list(req.prompt)

    def _batched_prefill(self, slot: int, req: Request) -> None:
        """Run full-sequence prefill for one request (bucketed length) and
        splice its cache rows into the live batch cache at ``slot``."""
        n = len(req.prompt) - 1          # last prompt token goes through decode
        if n <= 0:
            return
        bucket = 1 << max(4, (n - 1).bit_length())
        bucket = min(bucket, self.max_len)
        toks = np.zeros((1, bucket), np.int32)
        toks[0, :n] = req.prompt[:n]
        _, sub_cache = self.model.prefill(self.params, {"tokens": jnp.asarray(toks)},
                                          self.max_len)
        self.cache = _splice_cache(self.cache, sub_cache, slot)
        self.pos[slot] = n

    def tick(self) -> None:
        """One decode step for the whole slot batch."""
        self._admit()
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return

        tokens = np.zeros((self.max_slots,), np.int32)
        temps = np.zeros((self.max_slots,), np.float32)
        topk = 0
        for i in active:
            req = self.slot_req[i]
            if self.pending_prompt[i]:
                tokens[i] = self.pending_prompt[i][0]
            else:
                tokens[i] = req.output[-1]
            temps[i] = req.temperature
            topk = max(topk, req.top_k)

        logits, self.cache = self._decode(self.params, self.cache,
                                          jnp.asarray(tokens),
                                          jnp.asarray(self.pos))
        self.key, sub = jax.random.split(self.key)
        nxt = np.asarray(self._sample(logits, sub, jnp.asarray(temps), topk))

        now = time.time()
        self.stats.ticks += 1
        for i in active:
            req = self.slot_req[i]
            self.pos[i] += 1
            if self.pending_prompt[i]:
                self.pending_prompt[i].pop(0)
                if self.pending_prompt[i]:
                    continue  # still consuming the prompt
            # the model has now seen the full prompt → this is an output token
            if not req.output:
                req.t_first = now
            req.output.append(int(nxt[i]))
            self.stats.tokens_out += 1
            done = (len(req.output) >= req.max_new_tokens
                    or (req.eos_id is not None and req.output[-1] == req.eos_id)
                    or self.pos[i] >= self.max_len)
            if done:
                req.t_done = now
                self.stats.completed += 1
                self.slot_req[i] = None


def _splice_cache(cache, sub_cache, slot: int):
    """Insert a (batch=1) cache into the batch cache at ``slot`` (batch is
    always axis 1 across all cache layouts: k/v, latent, ssm, conv)."""

    def one(full, sub):
        idx = [0] * full.ndim
        idx[1] = slot
        return jax.lax.dynamic_update_slice(full, sub.astype(full.dtype),
                                            tuple(idx))

    return jax.tree.map(one, cache, sub_cache)
