"""Batched serving engine: continuous batching over fixed decode slots.

The paper's deployment is single-stream edge decode (batch = 1, token by
token, weights in ROM). This engine generalizes it to the production mesh:

  * ``max_slots`` concurrent sequences share one jitted ``decode_step`` whose
    KV cache is the paper's "distributed SRAM" — context-sharded over the
    ``model`` axis, fp8 payload (C2/C3). Every tick decodes one token for
    every active slot (B = max_slots, static shapes — no recompiles).
  * **continuous batching**: slots free as sequences finish and are refilled
    from the queue mid-flight; per-slot positions drive the cache scatter and
    attention masks.
  * **KV backends** (``kv=`` a `serving.kv.KVBackend`): `DenseKV` reserves a
    contiguous (L, B, H, max_len, D) cache row per slot — the paper's fixed
    on-chip SRAM budget. `PagedKV` replaces it with the shared `PagePool`
    (serving/paged_kv.py): slots own block tables of fp8 pages and the
    backend hands the jitted decode a `PagedKVState`, so ``Model.decode_step``
    reads pages through the block tables directly — the Pallas
    ``paged_flash_decode`` kernel on TPU (scalar-prefetch block tables, pages
    stream HBM→VMEM), the XLA gather reference on CPU (op-for-op the dense
    math → dense and paged produce token-identical greedy outputs). Paged
    mode unlocks admission control, preemption and the prefix cache
    (gateway/). There is ONE tick/decode path; the backend only changes what
    state pytree crosses the jit boundary.
  * **scheduling** is delegated to a pluggable scheduler (default FIFO via
    `gateway.scheduler.Scheduler`): priority classes, per-request deadlines
    (EDF), admission control backed by the backend's page accounting and
    preemption of low-priority slots when the pool runs dry — the preempted
    request re-enters the queue with its generated tokens as prompt, so
    resumed decode replays prefill but loses no tokens.
  * **prefix cache**: with ``prefix_cache=True`` (paged only), committed
    prompt pages are shared copy-on-write across requests via a token trie
    (gateway/prefix_cache.py); shared spans skip prefill ticks entirely.
  * **prefill** is either ``token`` mode — feed the prompt through
    decode_step one token at a time (the paper's own prefill: "executes all
    operations token-by-token, eliminating the prefill/decoding
    distinction") — or ``batched`` mode, a bucketed full-sequence prefill
    per request that splices the resulting cache rows into the live batch
    (beyond-paper; amortizes long prompts).
  * **chunked prefill** (``prefill_chunk=C``, batched GQA only): a long
    prompt's batched prefill is split into ≤C-token segments, at most one
    segment per tick while anything is decoding (the scheduler's
    ``plan_prefill`` budget, most-urgent first), so co-resident decode slots
    keep emitting during another request's prefill — SLO isolation against
    head-of-line blocking. Chunk i resumes at ``pos_offset = i·C`` with the
    previously committed chunks as ``prefix_kv`` (the same resume path a
    prefix-cache hit uses), on both KV backends; outputs are token-identical
    to unchunked prefill.
  * **sampling** comes from each request's frozen `SamplingParams`
    (serving/api.py): greedy, temperature, per-slot top-k, top-p nucleus
    mass and an optional per-request seed whose draws depend only on
    (seed, tokens generated) — reproducible regardless of co-scheduled
    traffic. All vector arguments, so one request's narrow top-k/top-p
    never leaks into its batch neighbours.
  * **speculative decoding** (``spec_decode=True`` + per-request
    ``SamplingParams.spec_k``): eligible slots (greedy or seeded) draft up
    to k tokens per tick from their own history (cycle extrapolation +
    n-gram prompt lookup, serving/spec.py) and one jitted
    ``Model.verify_step`` — a ``lax.scan`` of the exact ``decode_step``
    graph — scores all k+1 positions with bit-identical logits. The engine
    commits only the accepted span (``PagePool.write_span`` / sliced dense
    writes), so rejected drafts never reach storage and outputs are
    token-identical to ``spec_decode=False``. Draft memory is
    opportunistic: widths trim before they would evict a prefix page or
    preempt a neighbour.
  * **events**: ``on_token / on_done / on_admit / on_preempt / on_expire``
    hooks fire inline; the gateway (gateway/gateway.py) wires them to
    streaming callbacks and the metrics registry.
  * **multi-tenant adapters** (``adapters=`` an `serving/adapters/
    AdapterServing`): each request may name an ``adapter_id`` — a frozen
    ternary QLoRA fine-tune from the registry. Resident adapters are stacked
    on device and gathered per slot inside the jitted decode (SGMV), so one
    tick serves slots running different fine-tunes; the scheduler prefers
    co-scheduling warm-adapter requests (never violating priority/EDF) and
    the SRAM-budget cache pins adapters while their requests are in flight.

SSM/hybrid archs serve through the same interface (their "cache" is the
recurrent state; positions only gate the attention blocks, if any). Paged KV
requires a GQA KV cache — ssm/hybrid/MLA families use `DenseKV`.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.transformer import Model
from repro.serving.api import RequestSpec, SamplingParams, coerce_submit
from repro.serving.kv import KVBackend, as_backend
from repro.serving.obs.tracer import NULL_TRACER, CompileWatch, Tracer
from repro.serving.spec import (AdaptiveSpecK, accepted_prefix, plan_emit,
                                propose, quantize_width)

Params = Any
NEG_INF = -1e30


# Jitted prefill entry points, module-level so the compile cache is shared
# across engines of the same model (tests/benches build many). The resume
# variant takes ``off`` as a *traced* scalar and the prefix padded to a
# power-of-two bucket, so every chunk of a chunked prefill with the same
# (token-bucket, prefix-bucket) shape pair reuses one compiled graph —
# without this, each chunk's unique prefix length recompiles the prefill
# and a "chunk" costs more than the monolithic prompt it replaced.
def _fresh_prefill(model, params, toks, max_len, aidx):
    kwargs = {} if aidx is None else {"adapter_idx": aidx}
    return model.prefill(params, {"tokens": toks}, max_len, **kwargs)


def _resume_prefill(model, params, toks, max_len, off, prefix_kv, aidx):
    kwargs = {} if aidx is None else {"adapter_idx": aidx}
    return model.prefill(params, {"tokens": toks}, max_len, pos_offset=off,
                         prefix_kv=prefix_kv, **kwargs)


def _prefill_jits(model):
    """(fresh, resume) jitted wrappers, cached on the model instance (Model
    is an unhashable dataclass, so it can't ride as a jit static arg)."""
    fns = getattr(model, "_serving_prefill_jits", None)
    if fns is None:
        import functools
        fns = (jax.jit(functools.partial(_fresh_prefill, model),
                       static_argnums=(2,)),
               jax.jit(functools.partial(_resume_prefill, model),
                       static_argnums=(2,)))
        model._serving_prefill_jits = fns
    return fns


class _AotCall:
    """An ahead-of-time compiled executable behind the dispatch interface.

    ``name``/``last_compiled`` mirror `CompileWatch`, so `_dispatch`'s
    profiler probe attributes wall time to the same record
    `ProfileRegistry.register_compiled` created at warmup and never flags
    the call as a compile. ``drop`` names argument positions that were
    static at lower time — an AOT executable is called *without* its baked
    statics, while the jit path the caller may fall back to still wants
    them, so both paths share one argument tuple."""
    __slots__ = ("_compiled", "name", "last_compiled", "_drop")

    def __init__(self, compiled, name: str, drop=()):
        self._compiled = compiled
        self.name = name
        self.last_compiled = False
        self._drop = frozenset(drop)

    def __call__(self, *args, **kwargs):
        live = [a for i, a in enumerate(args) if i not in self._drop]
        return self._compiled(*live, **kwargs)


@dataclasses.dataclass
class Request:
    """A submitted request: the immutable `RequestSpec`/`SamplingParams`
    pair plus the engine's mutable bookkeeping. ``deadline_s`` is the
    absolute wall-clock deadline the scheduler orders by, derived once from
    ``spec.deadline_ms`` (relative to submit) — the only place the deadline
    unit conversion happens."""
    uid: int
    prompt: List[int]
    spec: RequestSpec = dataclasses.field(default_factory=RequestSpec)
    sampling: SamplingParams = dataclasses.field(default_factory=SamplingParams)
    deadline_s: Optional[float] = None   # absolute time.time() deadline (SLO)
    # filled by the engine
    max_new_tokens: int = -1             # mutable budget (clamped to max_len)
    state: str = "queued"  # queued|running|preempted|done|cancelled|expired|rejected
    output: List[int] = dataclasses.field(default_factory=list)
    t_submit: float = 0.0
    t_admit: float = 0.0
    t_first: float = 0.0
    t_last: float = 0.0
    t_done: float = 0.0
    n_preempts: int = 0
    stall_s: float = 0.0            # wall time this slot's decode sat blocked
                                    # behind another slot's prefill (SLO
                                    # attribution carves it out of decode)
    prefix_hit_tokens: int = 0      # prompt tokens served from the prefix cache
    prefill_ticks: int = 0          # decode ticks spent consuming the prompt
    prefill_chunks: int = 0         # chunked-prefill segments run for this req
    spec_drafted: int = 0           # draft tokens proposed for this request
    spec_accepted: int = 0          # draft tokens accepted (free extra tokens)
    _seq: int = 0                   # scheduler arrival order

    def __post_init__(self):
        if self.max_new_tokens < 0:
            self.max_new_tokens = self.spec.max_new_tokens
        if (self.deadline_s is None and self.spec.deadline_ms is not None
                and self.t_submit):
            self.deadline_s = self.t_submit + self.spec.deadline_ms / 1e3

    # spec/sampling views (kept as properties so engine internals and the
    # scheduler read one field of truth)
    @property
    def temperature(self) -> float:
        return self.sampling.temperature

    @property
    def top_k(self) -> int:
        return self.sampling.top_k

    @property
    def top_p(self) -> float:
        return self.sampling.top_p

    @property
    def seed(self) -> Optional[int]:
        return self.sampling.seed

    @property
    def eos_id(self) -> Optional[int]:
        return self.spec.eos_id

    @property
    def priority(self) -> int:
        return self.spec.priority

    @property
    def adapter_id(self) -> Optional[str]:
        return self.spec.adapter_id

    @property
    def ttft_s(self) -> float:
        return self.t_first - self.t_submit

    @property
    def latency_s(self) -> float:
        return self.t_done - self.t_submit


@dataclasses.dataclass
class EngineStats:
    ticks: int = 0
    tokens_out: int = 0
    completed: int = 0
    preemptions: int = 0
    cancelled: int = 0
    expired: int = 0
    prefix_hit_tokens: int = 0
    prefill_chunks: int = 0       # chunked-prefill segments run
    decode_stall_s: float = 0.0   # wall time decode slots waited on prefill
    spec_ticks: int = 0           # ticks that ran the multi-token verify
    spec_drafted: int = 0         # draft tokens proposed across all requests
    spec_accepted: int = 0        # draft tokens accepted (extra tokens/tick)
    wall_s: float = 0.0
    # observability: per-phase self-time (ms) accumulated across ticks —
    # schedule / prefill / prefill_chunk / decode / spec_verify / sample /
    # commit / emit; nested phases subtract, so values sum to tick wall
    phase_ms: Dict[str, float] = dataclasses.field(default_factory=dict)
    tick_gap_ms_sum: float = 0.0  # host time between device dispatches
    tick_gaps: int = 0
    # host gaps observed while a previous tick's dispatched work was still
    # unmaterialized (async runtime pipelining): the device queue is
    # non-empty, so this host time is *overlapped* with device compute and
    # excluded from the idle-gap numerator above
    tick_gap_overlap_ms_sum: float = 0.0
    tick_gaps_overlap: int = 0
    tick_wall_ms_sum: float = 0.0  # total tick() wall time (gap denominator)
    jit_compiles: int = 0         # jit cache growth events (CompileWatch)
    warmup_compiles: int = 0      # executables built ahead of traffic by
                                  # warmup_aot (jit_compiles resets to 0 after
                                  # warmup, so serve-time recompiles stand out)
    aot_fallbacks: int = 0        # AOT prefill calls that fell back to the
                                  # jit path on an input-placement mismatch
    # tiered memory hierarchy (ServeEngine(tiered=...)): spilled-then-
    # re-admitted prefix KV and scheduler-prefetch effectiveness
    prefix_readmits: int = 0      # spilled prefix spans pulled back on-device
    prefix_readmit_tokens: int = 0
    prefetch_hits: int = 0        # prefetched adapters/prefixes a placement used
    kv_spilled_pages: int = 0     # prefix KV pages demoted to host instead of dropped

    @property
    def tps(self) -> float:
        return self.tokens_out / self.wall_s if self.wall_s else 0.0

    @property
    def spec_accept_rate(self) -> float:
        """Draft hit rate: accepted / proposed (0.0 when nothing drafted)."""
        return self.spec_accepted / self.spec_drafted if self.spec_drafted \
            else 0.0

    @property
    def tick_gap_ms_mean(self) -> float:
        """Mean host-side bubble between device dispatches — the feedback
        signal the ROADMAP's async disaggregated runtime will shrink."""
        return self.tick_gap_ms_sum / self.tick_gaps if self.tick_gaps \
            else 0.0

    @property
    def host_overhead_frac(self) -> float:
        """Host-side dispatch gaps as a fraction of total tick wall time —
        the %-of-tick the device sits idle on host bookkeeping. This is the
        single number the async disaggregated runtime has to drive to ~0."""
        return self.tick_gap_ms_sum / self.tick_wall_ms_sum \
            if self.tick_wall_ms_sum else 0.0

    def phase_breakdown_ms(self) -> Dict[str, float]:
        """Mean self-time per phase per tick (ms)."""
        n = max(self.ticks, 1)
        return {k: round(v / n, 4) for k, v in sorted(self.phase_ms.items())}


class _Phase:
    """Phase timer + optional trace span. Accumulates *self-time* into
    ``stats.phase_ms`` — a nested phase's time is subtracted from its
    parent (via the engine's running self-time total), so the per-phase
    breakdown sums to tick wall time instead of double-counting."""
    __slots__ = ("eng", "name", "t0", "self0", "span")

    def __init__(self, eng: "ServeEngine", name: str):
        self.eng = eng
        self.name = name

    def __enter__(self):
        self.span = self.eng.trace.span(self.name, pid=self.eng._tpid)
        self.span.__enter__()
        self.self0 = self.eng._phase_self_total
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        dt = (time.perf_counter() - self.t0) * 1e3
        nested = self.eng._phase_self_total - self.self0
        own = max(dt - nested, 0.0)
        pm = self.eng.stats.phase_ms
        pm[self.name] = pm.get(self.name, 0.0) + own
        self.eng._phase_self_total = self.self0 + nested + own
        return self.span.__exit__(*exc)


@dataclasses.dataclass
class PendingTick:
    """One dispatched-but-unmaterialized tick: the device-side sample array
    plus the host bookkeeping deferred until ``tick_finish``. Produced by
    ``tick_begin``; the async runtime holds at most ``depth`` of these so the
    device stays a tick ahead, while the sync ``tick()`` finishes each one
    immediately (the deque is empty between ticks — zero behavior change).

    ``emits`` lists (slot, request, begin-time position) triples whose token
    for this tick lives in ``nxt_dev`` — the position is captured at begin
    because a later pipelined begin advances ``pos`` before this tick's
    finish runs, and the max_len done-check must see this tick's value.
    ``done_slots`` are slots whose request is predictably complete after
    that emission (budget / max_len — eos is only discovered at finish), so
    the next ``tick_begin`` must not decode them again."""
    active: List[int] = dataclasses.field(default_factory=list)
    emits: List[Tuple[int, "Request", int]] = dataclasses.field(
        default_factory=list)
    done_slots: set = dataclasses.field(default_factory=set)
    nxt_dev: Optional[jax.Array] = None
    gap_ms: Optional[float] = None
    verify_width: int = 1
    begin_s: float = 0.0          # host wall spent inside tick_begin
    busy0: float = 0.0
    tokens0: int = 0
    ticks0: int = 0


class ServeEngine:
    def __init__(self, model: Model, params: Params, *, max_slots: int = 8,
                 max_len: int = 1024, prefill: str = "token", seed: int = 0,
                 prefill_chunk: Optional[int] = None,
                 kv: Union[str, KVBackend, None] = None, page: int = 64,
                 n_pages: Optional[int] = None, prefix_cache: bool = False,
                 spec_decode: bool = False, spec_ngram: int = 3,
                 spec_adaptive: bool = False,
                 scheduler=None, adapters=None, tiered=None,
                 prefetch: bool = False,
                 tracer: Optional[Tracer] = None, profiler=None,
                 donate_decode_state: bool = False):
        assert model.mode in ("serve", "qlora")
        assert prefill_chunk is None or prefill_chunk >= 1, \
            "prefill_chunk must be >= 1 tokens (or None for monolithic prefill)"
        self.model = model
        self.cfg = model.cfg
        self.params = params
        self.max_slots = max_slots
        self.max_len = max_len
        self.prefill_mode = prefill
        # chunked prefill (SLO isolation): batched prefill of a long prompt is
        # split into <= prefill_chunk-token segments, one per tick, so decode
        # slots keep emitting while another request's prompt is in flight.
        # Chunk i resumes at pos_offset = i*C with the previous chunks'
        # committed cache as prefix_kv (the prefix-cache resume path). Only
        # meaningful with prefill="batched" on GQA families — token mode is
        # already maximally chunked (one prompt token per tick).
        self.prefill_chunk = prefill_chunk
        # speculative decoding (master switch; per-request width is
        # SamplingParams.spec_k): each eligible slot drafts up to spec_k
        # tokens per tick by n-gram prompt lookup over its own history and a
        # single jitted multi-token verify scores all of them — accepted
        # drafts commit in bulk (PagePool.write_span / sliced dense writes),
        # rejected ones never touch the cache, so greedy outputs are
        # token-identical to spec_decode=False. GQA families only (the
        # verify shares the mid-sequence prefill's attention restriction).
        self.spec_decode = spec_decode
        self.spec_ngram = spec_ngram
        # adaptive draft width (spec_adaptive=True): a per-slot EWMA of the
        # live accept rate shrinks/grows the next tick's draft width within
        # [0, SamplingParams.spec_k] — width never changes *which* tokens
        # are emitted (rejected drafts are discarded), only how many drafts
        # each verify tick risks, so token identity is preserved.
        self.spec_adaptive = spec_adaptive
        if spec_decode:
            assert model.cfg.attention_kind == "gqa" \
                and model.cfg.family not in ("ssm", "hybrid"), \
                "spec_decode needs a GQA KV cache"
        self.key = jax.random.PRNGKey(seed)
        # multi-tenant adapters (serving/adapters/AdapterServing): per-request
        # adapter_id selects a frozen ternary LoRA; resident adapters ride in
        # the param tree as lora_mt stacks, gathered per slot each tick.
        self.adapters = adapters
        self._mt_params: Optional[Params] = None
        self._mt_version = -1

        if scheduler is None:
            from repro.serving.gateway.scheduler import Scheduler
            scheduler = Scheduler()
        self.scheduler = scheduler

        # the KV backend owns cache init/alloc/commit/free; `page`/`n_pages`
        # only apply to the deprecated kv="paged" string shim
        self.kv = as_backend(kv, page=page, n_pages=n_pages)
        self.kv.bind(model, max_slots, max_len)
        self.pool = self.kv.pool
        self.prefix = None
        if prefix_cache:
            assert self.kv.supports_paging, \
                "prefix_cache requires a paged KV backend (kv=PagedKV(...))"
            from repro.serving.gateway.prefix_cache import PrefixCache
            self.prefix = PrefixCache(self.pool.cfg.page)

        # tiered memory hierarchy (serving/memory/TieredStore): device-tier
        # accounting for resident adapters + committed prefix pages, host/disk
        # spill for evicted ones (a popular prefix re-admits from host instead
        # of re-prefilling), and — with prefetch=True — a scheduler hook that
        # warms upcoming adapter/prefix needs up the hierarchy before their
        # tick. None keeps every legacy eviction path byte-identical.
        self.tiered = tiered
        self.prefetch = prefetch
        self._prefetched: set = set()        # warmed keys awaiting first use
        # feed lengths with a host-spilled dense prefix (DenseKV has no page
        # table to key re-admission off, so placements probe these lengths)
        self._dense_spill_lens: set = set()
        self._dense_spill_ok = (
            tiered is not None and not self.kv.supports_paging
            and self.cfg.attention_kind == "gqa"
            and self.cfg.family not in ("ssm", "hybrid"))
        if tiered is not None and adapters is not None:
            adapters.attach_tiered(tiered)

        self.pos = np.zeros((max_slots,), np.int32)       # next write position
        self.slot_adapter = np.zeros((max_slots,), np.int32)  # device slot (0=none)
        # version-pinned adapter cache key per slot: a hot-swap (re-register)
        # mid-stream must not steal an in-flight request's weights, so the
        # slot releases exactly the version it acquired
        self.slot_adapter_key: List[Optional[str]] = [None] * max_slots
        self.slot_req: List[Optional[Request]] = [None] * max_slots
        self.pending_prompt: List[List[int]] = [[] for _ in range(max_slots)]
        # chunked-prefill state machine: a slot with a non-empty todo list is
        # *prefilling* (admitted, pages reserved, excluded from decode) until
        # the tick loop has prefilled all but its last prompt token
        self.slot_prefill_todo: List[List[int]] = [[] for _ in range(max_slots)]
        self.slot_feed: List[List[int]] = [[] for _ in range(max_slots)]
        self.slot_keys: List[List] = [[] for _ in range(max_slots)]
        self.slot_cached: List[int] = [0] * max_slots     # cache-owned lead pages
        # per-slot adaptive-width controller (spec_adaptive only; created at
        # placement, dropped with the slot so each request starts fresh)
        self.slot_spec_adapt: List[Optional[AdaptiveSpecK]] = \
            [None] * max_slots
        self.stats = EngineStats()
        self._uid = 0

        # split-tick pipeline (async runtime): tick_begin() dispatches the
        # device work for one tick and parks the unmaterialized sample array
        # in a PendingTick; tick_finish() materializes the oldest pending
        # tick and runs its emit/eos/release bookkeeping. The sync tick()
        # finishes immediately, so the deque is empty outside tick() and
        # every legacy behavior is unchanged.
        self._pending: "collections.deque[PendingTick]" = collections.deque()

        # observability: the tracer records per-tick phase spans, request
        # lifecycle tracks and jit-compile instants (disabled by default —
        # a null object that allocates nothing per span); phase self-times
        # and the tick-gap clock accumulate in stats either way.
        self.trace = tracer if tracer is not None else NULL_TRACER
        # roofline profiler (obs/profile.ProfileRegistry, opt-in): every
        # _dispatch is blocked-and-timed per (fn, shape-signature) and each
        # compiled executable's cost/memory analysis is captured once —
        # None keeps dispatches async and adds zero per-call work.
        self.profiler = profiler
        self._tpid = (self.trace.register(f"engine[{self.kv.name}]")
                      if self.trace.enabled else 1)
        self._phase_self_total = 0.0
        self._t_dev_end: Optional[float] = None  # last device-dispatch return
        self._dispatch_tid: Optional[int] = None  # thread of that dispatch
        self._tick_gap_ms: Optional[float] = None  # gap observed this tick
        self._last_verify_width = 1
        self._prefill_watch = None
        # AOT prefill executables by (kind, token-bucket, has-adapter-idx):
        # warmup_aot fills this with `.lower(...).compile()` products (the
        # maxtext offline_inference warmup idiom) and _prefill_span prefers
        # them over the jit path — a served bucket never trips a trace-time
        # compile stall. Empty until warmup runs; always safe to ignore.
        self._cached_pref: Dict[Tuple, _AotCall] = {}
        # sharded serving (serving/sharded.py) stamps the replica's Mesh here
        # after device_put-ing params/pool; None = single-device placement
        self.mesh = None

        def _watch(fn, name):
            return CompileWatch(fn, name, self.trace,
                                on_compile=self._note_compile, pid=self._tpid)

        # ONE decode path: the backend's state pytree picks the model's
        # dense or paged decode inside decode_step — no engine branches.
        # Every jitted entry point rides a CompileWatch: cache growth bumps
        # stats.jit_compiles and emits a jit_compile instant naming the
        # offending shape bucket (recompile stalls become visible in-trace).
        # donate_decode_state buys the decode step its input KV buffers
        # (state is replaced wholesale by commit(), so the engine never
        # reads a donated buffer again) — halves decode's transient KV
        # footprint, the enabler for serving max_len-sized pools per replica.
        decode_jit = (jax.jit(self._decode_fn, donate_argnames=("kv_state",))
                      if donate_decode_state else jax.jit(self._decode_fn))
        self.donate_decode_state = donate_decode_state
        self._decode = _watch(decode_jit, "decode_step")
        self._sample = _watch(jax.jit(self._sample_fn,
                                      static_argnames=("use_topp",
                                                       "use_seeds")),
                              "sample")
        # multi-token verify (speculative decoding): compiled per
        # (draft-width bucket, table-view bucket) pair — widths are padded to
        # powers of two so the compile cache stays small; warm every bucket
        # the workload will hit before timing anything
        self._verify = _watch(jax.jit(self._verify_fn), "verify_step")
        self._verify_sample = _watch(
            jax.jit(self._verify_sample_fn,
                    static_argnames=("use_topp", "use_seeds")),
            "verify_sample")

        # event hooks (wired by the gateway; req-first signatures)
        self.on_token: Optional[Callable[[Request, int, float], None]] = None
        self.on_done: Optional[Callable[[Request], None]] = None
        self.on_admit: Optional[Callable[[Request, int], None]] = None
        self.on_preempt: Optional[Callable[[Request], None]] = None
        self.on_expire: Optional[Callable[[Request], None]] = None
        # per-tick summary hook (gateway → tick_gap histogram + energy
        # monitor): fires after every tick() with wall/busy/token counts
        self.on_tick: Optional[Callable[[Dict[str, Any]], None]] = None

    @property
    def kv_mode(self) -> str:
        """Back-compat view of the backend kind ("dense"/"paged")."""
        return self.kv.name

    @property
    def cache(self):
        """Back-compat view of DenseKV's contiguous cache (None if paged)."""
        return getattr(self.kv, "cache", None)

    # -- observability helpers -------------------------------------------------
    def _phase(self, name: str) -> _Phase:
        """Tick-phase timer (+ trace span when the tracer is enabled)."""
        return _Phase(self, name)

    def _note_compile(self, name: str, shapes: str) -> None:
        self.stats.jit_compiles += 1

    def _dispatch(self, fn, *args, **kwargs):
        """Run one device dispatch, recording the host-side gap since the
        previous dispatch returned (``tick_gap_ms``): sampling, scheduling
        and bookkeeping time during which the device sits idle — the named
        feedback signal for the ROADMAP's async disaggregated runtime.

        Threaded-dispatch semantics: the gap clock is *per dispatch thread*
        — a dispatch issued from a different thread than the previous one
        (warmup on the main thread, then the async runtime's dispatch
        thread) records no gap and just re-arms the clock, so cross-thread
        wall time never pollutes ``host_overhead_frac``. While the split-
        tick pipeline holds an unfinished tick the device queue is
        non-empty, so gaps observed then are *overlapped* host time and
        land in ``tick_gap_overlap_ms_sum`` instead of the idle-gap sum."""
        t = time.perf_counter()
        tid = threading.get_ident()
        if self._t_dev_end is not None and tid == self._dispatch_tid:
            gap = (t - self._t_dev_end) * 1e3
            self._tick_gap_ms = gap
            if self._pending:
                self.stats.tick_gap_overlap_ms_sum += gap
                self.stats.tick_gaps_overlap += 1
            else:
                self.stats.tick_gap_ms_sum += gap
                self.stats.tick_gaps += 1
                self.trace.counter("tick_gap_ms", gap, pid=self._tpid)
        out = fn(*args, **kwargs)
        if self.profiler is not None:
            # profiling blocks the dispatch so the measured wall is real
            # device time per compiled executable, not async enqueue time
            out = jax.block_until_ready(out)
            self.profiler.observe_call(
                getattr(fn, "name", getattr(fn, "__name__", "fn")),
                fn, args, kwargs, time.perf_counter() - t,
                compiled=getattr(fn, "last_compiled", False))
        self._t_dev_end = time.perf_counter()
        self._dispatch_tid = tid
        return out

    #: phases counted as device-execution time for the energy monitor
    _BUSY_PHASES = ("prefill", "prefill_chunk", "decode", "spec_verify",
                    "sample", "commit")

    def _busy_ms(self) -> float:
        pm = self.stats.phase_ms
        return sum(pm.get(k, 0.0) for k in self._BUSY_PHASES)

    # -- jitted kernels --------------------------------------------------------
    def _decode_fn(self, params, kv_state, tokens, pos, adapter_idx=None):
        logits, kv_state = self.model.decode_step(params, kv_state, tokens,
                                                  pos, adapter_idx)
        return logits, kv_state

    def _sample_fn(self, logits, key, temperature, top_k, top_p, seeds,
                   has_seed, steps, *, use_topp=True, use_seeds=True):
        """Per-slot sampling, all array arguments (B,) vectors: temperature
        f32, top_k int32 (0 = full softmax), top_p f32 nucleus mass (1.0 =
        off), plus per-request seeded streams (draws keyed by (seed, step)
        only). ``use_topp``/``use_seeds`` are static: the tick passes False
        when no slot uses the feature, so the common greedy/top-k graph pays
        no nucleus sort or per-row seeded draws. With top_p=1.0 and no seeds
        the output is bit-identical to the historical temperature/top-k
        sampler either way (the masks are exact no-ops)."""
        greedy = jnp.argmax(logits, axis=-1)
        vocab = logits.shape[-1]
        sorted_desc = -jnp.sort(-logits, axis=-1)
        k_idx = jnp.clip(top_k - 1, 0, vocab - 1)
        thresh = jnp.take_along_axis(sorted_desc, k_idx[:, None], axis=-1)
        masked = jnp.where((top_k[:, None] > 0) & (logits < thresh),
                           NEG_INF, logits)
        final = masked / jnp.maximum(temperature[:, None], 1e-6)
        if use_topp:
            # top-p (nucleus): keep the smallest prefix of the sorted
            # distribution whose cumulative probability reaches top_p; ties
            # at the cutoff stay.
            sorted_scaled = -jnp.sort(-final, axis=-1)
            probs = jax.nn.softmax(sorted_scaled, axis=-1)
            csum = jnp.cumsum(probs, axis=-1)
            keep = (csum - probs) < top_p[:, None]     # prefix-exclusive mass
            n_keep = jnp.maximum(jnp.sum(keep, axis=-1), 1)
            cutoff = jnp.take_along_axis(sorted_scaled, n_keep[:, None] - 1,
                                         axis=-1)
            apply_p = (top_p < 1.0)[:, None]
            final = jnp.where(apply_p & (final < cutoff), NEG_INF, final)
        sampled = jax.random.categorical(key, final, axis=-1)
        if use_seeds:
            def seeded_draw(seed, step, row):
                k = jax.random.fold_in(jax.random.PRNGKey(seed), step)
                return jax.random.categorical(k, row)

            seeded = jax.vmap(seeded_draw)(seeds, steps, final)
            sampled = jnp.where(has_seed, seeded, sampled)
        use_greedy = temperature <= 0.0
        return jnp.where(use_greedy, greedy, sampled).astype(jnp.int32)

    def _verify_fn(self, params, kv_state, tokens, pos, adapter_idx=None):
        return self.model.verify_step(params, kv_state, tokens, pos,
                                      adapter_idx)

    def _verify_sample_fn(self, logits, key, temperature, top_k, top_p,
                          seeds, has_seed, steps0, *, use_topp=True,
                          use_seeds=True):
        """Per-position sampling over a verify tick's (B, S, V) logits. Row
        (b, j) runs exactly `_sample_fn`'s math at output step
        ``steps0[b] + j``, so greedy picks and seeded draws match the
        single-token sampler token for token — the accept/reject identity
        contract reduces to "does the draft equal this row's choice"."""
        b, s, v = logits.shape

        def rep(a):
            return jnp.repeat(a, s)

        steps = (steps0[:, None] + jnp.arange(s)[None, :]).reshape(-1)
        flat = self._sample_fn(logits.reshape(b * s, v), key,
                               rep(temperature), rep(top_k), rep(top_p),
                               rep(seeds), rep(has_seed), steps,
                               use_topp=use_topp, use_seeds=use_seeds)
        return flat.reshape(b, s)

    # -- public API ---------------------------------------------------------------
    def submit(self, prompt: List[int], spec: Optional[RequestSpec] = None,
               sampling: Optional[SamplingParams] = None,
               **legacy) -> Request:
        """Enqueue a request described by a `RequestSpec` (+ optional
        `SamplingParams`). Old keyword arguments (max_new_tokens=...,
        temperature=..., deadline_s=<absolute>, ...) are accepted behind a
        DeprecationWarning."""
        spec, sampling, deadline_s = coerce_submit(spec, sampling, legacy)
        self._uid += 1
        req = Request(self._uid, list(prompt), spec=spec, sampling=sampling,
                      deadline_s=deadline_s, t_submit=time.time())
        if req.adapter_id is not None and not self._adapter_servable(req.adapter_id):
            # unknown tenant, no adapter runtime, or an adapter bigger than
            # the whole SRAM budget: it could never be scheduled
            req.state = "rejected"
        elif not self.scheduler.push(req):
            req.state = "rejected"
        self.trace.lifecycle(req.uid, "rejected" if req.state == "rejected"
                             else "queued", pid=self._tpid)
        return req

    def _adapter_servable(self, adapter_id: str) -> bool:
        return self.adapters is not None and self.adapters.servable(adapter_id)

    def _adapter_warm(self, req: Request) -> bool:
        """Affinity predicate: True when serving ``req`` costs no adapter
        load (no adapter, or already resident)."""
        return (self.adapters is None or req.adapter_id is None
                or self.adapters.is_resident(req.adapter_id))

    def _effective_params(self) -> Params:
        """Base params, with the current multi-tenant adapter stacks grafted
        in (rebuilt only when the runtime loads/evicts an adapter)."""
        if self.adapters is None:
            return self.params
        if self._mt_version != self.adapters.version:
            self._mt_params = self.adapters.install(self.params)
            self._mt_version = self.adapters.version
        return self._mt_params

    def cancel(self, uid: int) -> bool:
        """Cancel a queued or running request. Returns False if unknown."""
        # settle any in-flight pipelined tick first: its deferred emissions
        # may finish (or release) the very request being cancelled, and a
        # cancel must never race a pending emit for the same slot
        self._settle_pipeline()
        req = self.scheduler.remove(uid)
        if req is not None:
            req.state = "cancelled"
            self.stats.cancelled += 1
            self.trace.lifecycle(uid, "cancelled", pid=self._tpid)
            return True
        for slot, r in enumerate(self.slot_req):
            if r is not None and r.uid == uid:
                r.state = "cancelled"
                self.stats.cancelled += 1
                self._release_slot(slot)
                self.trace.lifecycle(uid, "cancelled", pid=self._tpid)
                return True
        return False

    def run_until_drained(self, max_ticks: int = 100_000) -> EngineStats:
        t0 = time.time()
        while (len(self.scheduler) or any(r is not None for r in self.slot_req)) \
                and self.stats.ticks < max_ticks:
            before = self.stats.ticks
            self.tick()
            if self.stats.ticks == before \
                    and not any(r is not None for r in self.slot_req):
                # nothing running and nothing admissible (e.g. a queued
                # request larger than the page pool): no tick will ever
                # change that, so bail instead of spinning — callers can
                # inspect the still-queued requests
                break
        self.stats.wall_s += time.time() - t0
        return self.stats

    # -- AOT bucket warmup -----------------------------------------------------
    def warmup_aot(self, *, max_prompt_len: Optional[int] = None,
                   spec_widths: Tuple[int, ...] = (1, 3, 7, 15),
                   resume_starts=(), profiler=None) -> Dict[str, Any]:
        """Compile every executable the serving workload can hit *before*
        traffic arrives (the maxtext ``offline_inference`` warmup idiom), so
        no request ever stalls behind a trace+compile.

        Two mechanisms, matched to how each entry point is dispatched:

          * **fresh prefill** — genuine AOT products: ``fn.lower(...)
            .compile()`` per pow2 token bucket (× adapter-idx variant),
            parked in ``_cached_pref`` and *invoked* by ``_prefill_span``;
            each executable is registered with the profiler so roofline
            attribution keeps working without a live ``.lower`` probe.
          * **decode / sample / verify / resume-prefill** — dummy-executed
            through the engine's CompileWatch-wrapped jits with throwaway
            states from the KV backend (`warmup_decode_states` /
            `warmup_verify_states`; every block-table view bucket, every
            draft-width bucket, all four sampler static combos), populating
            the jit dispatch caches and the watches' seen-shape sets. The
            dummies alias no live storage, so a donated decode may consume
            them freely, and the engine's sampling ``self.key`` is never
            advanced — a warmed engine stays token-identical to a cold one.

        ``max_prompt_len`` bounds the prefill buckets (default: ``max_len``);
        ``resume_starts`` adds explicit ``(n_tokens, start)`` resume shapes
        beyond the chunk/page-boundary enumeration. On return,
        ``stats.warmup_compiles`` records the executables built here and
        ``stats.jit_compiles`` resets to **0**, so any nonzero value after
        serving is a real recompile stall (the zero-recompile contract the
        sharded test lane asserts).

        Must run on an idle engine (no pending pipelined ticks)."""
        assert not self._pending, "warmup_aot needs an idle engine"
        t0 = time.perf_counter()
        prof = profiler if profiler is not None else self.profiler
        compiles0 = self.stats.jit_compiles
        params = self._effective_params()
        B = self.max_slots
        n_max = min(max_prompt_len or self.max_len, self.max_len)
        use_jit = self.cfg.attention_kind == "gqa" \
            and self.cfg.family not in ("ssm", "hybrid")
        aidx_variants: List[Optional[jax.Array]] = [None]
        if self.adapters is not None:
            aidx_variants.append(jnp.zeros((1,), jnp.int32))

        # -- fresh prefill: real AOT executables per bucket ---------------------
        buckets: List[int] = []
        n_aot = 0
        if use_jit and self.prefill_mode == "batched":
            e = 4
            while True:
                b = min(1 << e, self.max_len)
                buckets.append(b)
                if (1 << e) >= n_max or b >= self.max_len:
                    break
                e += 1
            buckets = sorted(set(buckets))
            fresh_jit, _ = _prefill_jits(self.model)
            for b in buckets:
                toks = jnp.asarray(np.zeros((1, b), np.int32))
                for aidx in aidx_variants:
                    args = (params, toks, self.max_len, aidx)
                    compiled = fresh_jit.lower(*args).compile()
                    self._cached_pref[("fresh", b, aidx is not None)] = \
                        _AotCall(compiled, "prefill_fresh", drop=(2,))
                    n_aot += 1
                    if prof is not None:
                        prof.register_compiled("prefill_fresh", args, compiled)

        # -- resume prefill: dummy-exec the (bucket, prefix-bucket) shape set ---
        resume_pairs = set()

        def note_resume(n: int, start: int) -> None:
            if n <= 0 or start <= 0 or start >= self.max_len:
                return
            b = 1 << max(4, (n - 1).bit_length())
            b = min(b, self.max_len - start)
            pb = min(1 << max(4, (start - 1).bit_length()), self.max_len)
            if b > 0:
                resume_pairs.add((b, pb))

        def add_start(start: int, n_cap: int) -> None:
            e = 4
            while True:
                note_resume(min(1 << e, n_cap), start)
                if (1 << e) >= n_cap:
                    break
                e += 1

        if use_jit and self.prefill_mode == "batched":
            if self.prefill_chunk:
                for s in range(self.prefill_chunk, n_max, self.prefill_chunk):
                    add_start(s, min(self.prefill_chunk, max(n_max - s, 1)))
            if self.prefix is not None:
                page = self.pool.cfg.page
                for s in range(page, n_max, page):
                    add_start(s, max(n_max - s - 1, 1))
            for n, s in resume_starts:
                note_resume(int(n), int(s))
            if resume_pairs:
                src = self.pool.k if self.kv.supports_paging \
                    else self.cache["k"]
                L, _, H, _, D = src.shape
                resume_watch = self._prefill_fns()[1]
                for b, pb in sorted(resume_pairs):
                    z = jnp.zeros((L, 1, H, pb, D), src.dtype)
                    pref = {"k": z, "v": z}
                    toks = jnp.asarray(np.zeros((1, b), np.int32))
                    for aidx in aidx_variants:
                        resume_watch(params, toks, self.max_len,
                                     jnp.int32(pb), pref, aidx)

        # -- decode tick + samplers (all static combos) -------------------------
        fed = jnp.asarray(np.zeros((B,), np.int32))
        posv = jnp.asarray(np.zeros((B,), np.int32))
        aidx_dec = self._adapter_idx()
        # throwaway key: warmup must not advance self.key (token identity)
        sub = jax.random.split(jax.random.PRNGKey(0))[1]
        z_f = jnp.asarray(np.zeros((B,), np.float32))
        one_f = jnp.asarray(np.ones((B,), np.float32))
        z_i = jnp.asarray(np.zeros((B,), np.int32))
        z_b = jnp.asarray(np.zeros((B,), bool))
        last = None
        logits = None
        for state in self.kv.warmup_decode_states():
            logits, _ = self._decode(params, state, fed, posv, aidx_dec)
        if logits is not None:
            for use_topp in (False, True):
                for use_seeds in (False, True):
                    last = self._sample(logits, sub, z_f, z_i, one_f, z_i,
                                        z_b, z_i, use_topp=use_topp,
                                        use_seeds=use_seeds)

        # -- multi-token verify (spec decode) per draft-width bucket ------------
        sbs: List[int] = []
        if self.spec_decode:
            sbs = sorted({1 << int(w).bit_length()
                          for w in spec_widths if int(w) >= 1})
            for s in sbs:
                vtok = jnp.asarray(np.zeros((B, s), np.int32))
                vlogits = None
                for vstate in self.kv.warmup_verify_states(s):
                    vlogits, _ = self._verify(params, vstate, vtok, posv,
                                              aidx_dec)
                if vlogits is None:
                    continue
                for use_topp in (False, True):
                    for use_seeds in (False, True):
                        last = self._verify_sample(
                            vlogits, sub, z_f, z_i, one_f, z_i, z_b, z_i,
                            use_topp=use_topp, use_seeds=use_seeds)

        if last is not None:
            jax.block_until_ready(last)
        jit_warmed = self.stats.jit_compiles - compiles0
        self.stats.warmup_compiles += jit_warmed + n_aot
        # post-warmup, the compile counter reports *serve-time* recompiles
        # only — the quantity the zero-recompile sweep asserts is exactly 0
        self.stats.jit_compiles = 0
        return {
            "prefill_buckets": buckets,
            "resume_pairs": sorted(resume_pairs),
            "verify_buckets": sbs,
            "aot_executables": n_aot,
            "jit_warmed": jit_warmed,
            "compiles": jit_warmed + n_aot,
            "wall_s": round(time.perf_counter() - t0, 3),
        }

    # -- engine internals ------------------------------------------------------------
    def _free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.slot_req) if r is None]

    def _is_decoding(self, slot: int) -> bool:
        """True when the slot belongs in the decode batch. A slot whose
        chunked prefill is still in flight is NOT decoding even if the
        request already has output tokens (a preempted-while-decoding
        request replays prompt+output through chunked prefill — feeding it
        to decode mid-prefill would shift its KV positions)."""
        req = self.slot_req[slot]
        return (req is not None and not self.slot_prefill_todo[slot]
                and bool(self.pending_prompt[slot] or req.output
                         or self._inflight_emits(slot)))

    # -- split-tick pipeline helpers -------------------------------------------
    def _inflight_emits(self, slot: int) -> int:
        """Deferred emissions queued for ``slot`` across pending ticks —
        tokens the device has (logically) produced but tick_finish() has not
        yet materialized into ``req.output``. The request-identity guard
        drops stale entries for a slot that was re-assigned underneath a
        pending tick (possible only after an early release)."""
        if not self._pending:
            return 0
        req = self.slot_req[slot]
        return sum(1 for p in self._pending
                   for i, r, _ in p.emits if i == slot and r is req)

    def _slot_done_inflight(self, slot: int) -> bool:
        """True when a pending tick already predicted this slot's request
        will be complete once finished (budget / max_len) — the slot stays
        occupied but must not decode again before tick_finish releases it."""
        return any(slot in p.done_slots for p in self._pending)

    def _settle_pipeline(self) -> None:
        """Finish every pending tick (materialize + emit). State-mutating
        paths that need host-visible history — cancel, preemption, draft
        planning, admission under pressure — call this before acting."""
        while self._pending:
            self.tick_finish()

    def _active_pairs(self) -> List[Tuple[int, Request]]:
        return [(i, r) for i, r in enumerate(self.slot_req) if r is not None]

    def _feed_tokens(self, req: Request) -> List[int]:
        """Token history a (re-)admitted request must replay: the prompt
        plus anything generated before a preemption."""
        return list(req.prompt) + list(req.output)

    def _clamped_feed(self, req: Request) -> Tuple[List[int], int]:
        """(feed, remaining_new) after the max_len truncation clamp — the
        single source of truth shared by admission accounting and _place:
        the generation budget is clamped first (a request can never produce
        more than max_len - 1 new tokens), then the prompt keeps its tail."""
        feed = self._feed_tokens(req)
        remaining_new = max(1, req.max_new_tokens - len(req.output))
        if len(feed) + remaining_new > self.max_len:
            remaining_new = min(remaining_new, self.max_len - 1)
            feed = feed[-(self.max_len - remaining_new):]
        return feed, remaining_new

    def _pages_needed(self, req: Request) -> int:
        """Free pages required to *start* the request (prompt + 1 token)."""
        feed, _ = self._clamped_feed(req)
        hit = self.prefix.lookup(feed) if self.prefix is not None else 0
        return self.kv.pages_for(len(feed) + 1) - hit

    def _pages_lifetime(self, req: Request) -> int:
        """Backend pages the request's slot will hold at its *final* context
        length (prefix hits included — shared pages still occupy the pool).
        Must fit total capacity or the request can never complete."""
        feed, remaining_new = self._clamped_feed(req)
        return self.kv.pages_for(min(len(feed) + remaining_new, self.max_len))

    def _can_admit(self, req: Request) -> bool:
        if (self.adapters is not None and req.adapter_id is not None
                and not self.adapters.can_serve(req.adapter_id)):
            # every budget byte is pinned by in-flight adapters — the request
            # waits until a slot drains and unpins one
            return False
        # a request whose final context exceeds the whole pool would only
        # crash mid-flight — keep it queued instead of admitting it
        # (DenseKV reports zero cost / unbounded capacity: always admissible)
        if self._pages_lifetime(req) > self.kv.capacity_pages:
            return False
        return self.kv.pages_free >= self._pages_needed(req)

    # -- tiered memory hierarchy ----------------------------------------------
    def _kv_key(self, key) -> str:
        """TieredStore key of a prefix-KV span (tuple of prompt tokens)."""
        return "kv:" + ",".join(map(str, key))

    def _dense_key(self, adapter_key, feed) -> str:
        """Dense-spill store key. Unlike the paged trie (which shares
        committed pages across tenants by token identity — the baseline
        semantic), the dense path is new reuse, so it must not hand one
        adapter's KV to another: the slot's version-pinned adapter key
        namespaces the entry."""
        tag = f"{adapter_key}|" if adapter_key else ""
        return "kv:" + tag + ",".join(map(str, feed))

    @property
    def _page_nbytes(self) -> int:
        """Device footprint of one k+v pool page (fp8 cache encoding)."""
        c = self.pool.cfg
        return (2 * c.n_layers * c.n_kv_heads * c.page * c.head_dim
                * np.dtype(self.pool.k.dtype).itemsize)

    def _evict_prefix(self, n: int) -> None:
        """Evict up to ``n`` resident prefix pages. With a tiered store each
        page's KV is exported and demoted to the host tier (keyed by its
        token prefix) before the page returns to the pool — a later request
        for the same prefix re-admits the bytes instead of re-prefilling."""
        if self.tiered is None:
            self.kv.free_pages(self.prefix.evict(n))
            return
        freed = []
        for key, pid in self.prefix.evict_detailed(n):
            self.tiered.demote(self._kv_key(key), self.kv.export_page(pid),
                               remat_cost=float(len(key)))
            self.stats.kv_spilled_pages += 1
            freed.append(pid)
        self.kv.free_pages(freed)

    def _readmit_prefix(self, feed: List[int], keep_free: int = 0,
                        record: bool = False) -> int:
        """Extend ``feed``'s cached prefix span by re-importing spilled
        pages from the tiered store back into freshly allocated pool pages
        and re-inserting their trie nodes (shortest-first, so parents exist
        before children). Returns pages re-admitted. ``keep_free`` leaves
        pool headroom (the prefetch hook must not starve admissions);
        ``record`` marks the keys as prefetched so the placement that uses
        them counts a prefetch hit."""
        if self.tiered is None or self.prefix is None:
            return 0
        page = self.pool.cfg.page
        limit = max(0, (len(feed) - 1) // page)
        n = self.prefix.lookup(feed)
        readmitted = 0
        while n < limit and self.pool.pages_free > keep_free:
            key = tuple(feed[: (n + 1) * page])
            kv_key = self._kv_key(key)
            if self.tiered.tier_of(kv_key) in (None, "device"):
                break
            payload = self.tiered.take(kv_key)
            if payload is None:
                break              # corrupt disk copy degraded to a miss
            pid = self.pool.alloc_page()
            self.kv.import_page(pid, payload)
            self.prefix.readmit(key, pid)
            self.tiered.note_device(kv_key, self._page_nbytes,
                                    remat_cost=float(len(key)))
            self.stats.prefix_readmits += 1
            self.stats.prefix_readmit_tokens += page
            if record:
                self._prefetched.add(kv_key)
            n += 1
            readmitted += 1
        return readmitted

    def _readmit_dense(self, slot: int, feed: List[int]) -> int:
        """DenseKV re-admission: probe spilled feed lengths (longest first)
        for a host copy of ``feed``'s prefix KV and import it into the
        slot's rows. Returns matched token count (≥1 token always left for
        decode). The host entry is read, not consumed — other placements
        can reuse it until the store's budget evicts it."""
        akey = self.slot_adapter_key[slot]
        for n in sorted(self._dense_spill_lens, reverse=True):
            if n > len(feed):
                continue
            key = self._dense_key(akey, feed[:n])
            payload = self.tiered.get(key)
            if payload is None:
                continue
            upto = min(n, len(feed) - 1)
            if upto <= 0:
                continue
            if upto < n:
                payload = {k: v[:, :, :upto] for k, v in payload.items()}
            self.kv.import_prefix(slot, payload)
            self.stats.prefix_readmits += 1
            self.stats.prefix_readmit_tokens += upto
            if key in self._prefetched:
                self._prefetched.discard(key)
                self.stats.prefetch_hits += 1
            return upto
        return 0

    def _prefetch_queue(self) -> None:
        """Scheduler prefetch hook: walk the head of the pending queue and
        warm each request's adapter and prefix KV *up* the hierarchy before
        its admission tick — disk→host staging always, host→device only
        into spare capacity (free adapter slots / pool headroom), so
        prefetch never evicts hotter state."""
        if not self.prefetch or self.tiered is None:
            return
        upcoming = getattr(self.scheduler, "upcoming", None)
        if upcoming is None:
            return          # custom scheduler without a queue peek
        for req in upcoming(2 * self.max_slots):
            if (self.adapters is not None and req.adapter_id is not None
                    and req.adapter_id in self.adapters.registry):
                key = "adapter:" + self.adapters._vkey(req.adapter_id)
                if self.adapters.prefetch(req.adapter_id):
                    self._prefetched.add(key)
            feed, _ = self._clamped_feed(req)
            if self.prefix is not None:
                page = self.pool.cfg.page
                for i in range(1, max(0, (len(feed) - 1) // page) + 1):
                    kk = self._kv_key(tuple(feed[: i * page]))
                    if self.tiered.tier_of(kk) == "disk":
                        self.tiered.promote_host(kk)
                self._readmit_prefix(
                    feed, keep_free=self.kv.pages_for(self.max_len),
                    record=True)
            elif self._dense_spill_ok:
                akey = None
                if (self.adapters is not None and req.adapter_id is not None
                        and req.adapter_id in self.adapters.registry):
                    akey = self.adapters._vkey(req.adapter_id)
                for n in sorted(self._dense_spill_lens, reverse=True):
                    if n > len(feed):
                        continue
                    kk = self._dense_key(akey, feed[:n])
                    if self.tiered.promote_host(kk):
                        self._prefetched.add(kk)
                    break

    def _admit(self) -> None:
        now = time.time()
        for req in self.scheduler.drop_expired(now):
            req.state = "expired"
            self.stats.expired += 1
            self.trace.lifecycle(req.uid, "expired", pid=self._tpid)
            if self.on_expire:
                self.on_expire(req)
        for slot in self._free_slots():
            if not len(self.scheduler):
                break
            req = self.scheduler.pop_next(self._can_admit,
                                          prefer=self._adapter_warm)
            if req is None and self.kv.supports_paging:
                req = self._admit_under_pressure()
            if req is None:
                break
            self._place(slot, req, now)

    def _admit_under_pressure(self) -> Optional[Request]:
        """Nothing fits the pool: evict resident prefix pages, then preempt
        lower-priority active slots for the most urgent queued request —
        but only if the reclaimed pages actually make it admissible.
        Preempting without that check livelocks: the victim is re-admitted
        by the very next pop and zero progress is made every tick."""
        # preemption replays prompt+output — settle pending emissions first
        # so a victim's replay feed includes every token it already earned
        self._settle_pipeline()
        head = self.scheduler.peek(
            lambda r: self._pages_lifetime(r) <= self.kv.capacity_pages
            and (self.adapters is None or r.adapter_id is None
                 or self.adapters.can_serve(r.adapter_id)))
        if head is None:
            return None
        needed = self._pages_needed(head)
        short = needed - self.kv.pages_free
        if short > 0 and self.prefix is not None:
            self._evict_prefix(short)
        if not self._can_admit(head):
            # plan the victim set first: count only pages release() actually
            # frees (owned pages — cache-shared ones stay resident)
            budget = self.kv.pages_free
            pairs = self._active_pairs()
            victims: List[int] = []
            while budget < needed:
                slot = self.scheduler.pick_victim(
                    pairs, below_priority=head.priority)
                if slot is None:
                    return None          # preemption can't help → no thrash
                budget += self.kv.slot_pages(slot) - self.slot_cached[slot]
                victims.append(slot)
                pairs = [(i, r) for i, r in pairs if i != slot]
            for slot in victims:
                self._preempt(slot)
        return self.scheduler.pop_next(self._can_admit,
                                       prefer=self._adapter_warm)

    def _place(self, slot: int, req: Request, now: float) -> None:
        req.state = "running"
        req.t_admit = now
        if self.adapters is not None and req.adapter_id is not None:
            # load (evicting LRU unpinned if needed) + pin for the slot's
            # life. The pin is *version-resolved* at placement: a hot-swap
            # (re-register) while this request streams must not move its
            # weights, so release targets the exact pinned version below.
            dev_slot, key = self.adapters.acquire_versioned(req.adapter_id)
            self.slot_adapter[slot] = dev_slot
            self.slot_adapter_key[slot] = key
            if "adapter:" + key in self._prefetched:
                self._prefetched.discard("adapter:" + key)
                self.stats.prefetch_hits += 1
        feed, remaining_new = self._clamped_feed(req)
        req.max_new_tokens = len(req.output) + remaining_new
        self.slot_req[slot] = req
        self.slot_feed[slot] = feed
        if self.spec_adaptive and req.sampling.spec_k > 0:
            self.slot_spec_adapt[slot] = AdaptiveSpecK()
        self.pos[slot] = 0
        matched = 0
        if self.prefix is not None:
            # pull any spilled pages of this feed's prefix back on-device
            # first, so the trie match below sees the re-admitted span too
            self._readmit_prefix(feed)
            ids, keys = self.prefix.match(feed)
            self.slot_keys[slot] = keys
            self.slot_cached[slot] = len(ids)
            for k in keys:
                kk = self._kv_key(k)
                if kk in self._prefetched:
                    self._prefetched.discard(kk)
                    self.stats.prefetch_hits += 1
            if ids:
                self.pool.append_shared(slot, ids)
                matched = len(ids) * self.pool.cfg.page
                self.pos[slot] = matched
                self.pool.lengths[slot] = matched
                req.prefix_hit_tokens = matched
                self.stats.prefix_hit_tokens += matched
        elif self._dense_spill_ok and self._dense_spill_lens:
            matched = self._readmit_dense(slot, feed)
            if matched:
                self.pos[slot] = matched
                req.prefix_hit_tokens = matched
                self.stats.prefix_hit_tokens += matched
        # eager reservation: claim the prompt's pages (plus the first output
        # token) now, so admission control sees the true footprint of
        # already-placed requests instead of racing lazy allocation.
        # (DenseKV: no-op — the slot's max_len row is always reserved.)
        self.kv.reserve(slot, len(feed) + 1)
        remainder = feed[matched:]
        # SSM/hybrid prefill must thread recurrent state → token mode
        # (model.prefill fills the KV cache only; see models/transformer).
        # After a prefix hit the remainder starts at ``matched``: GQA prefill
        # resumes mid-sequence (position offset + attention over the cached
        # prefix pages); other attention kinds fall back to token mode.
        batched_ok = (self.cfg.family not in ("ssm", "hybrid")
                      and len(remainder) > 1
                      and (matched == 0 or self.cfg.attention_kind == "gqa"))
        chunkable = (self.prefill_chunk is not None
                     and self.cfg.attention_kind == "gqa"
                     and len(remainder) - 1 > self.prefill_chunk)
        if self.prefill_mode == "batched" and batched_ok:
            if chunkable:
                # chunked: defer to the tick loop's chunk planner — the slot
                # holds its reserved pages but stays out of decode until the
                # last chunk commits
                self.slot_prefill_todo[slot] = list(remainder)
                self.pending_prompt[slot] = []
            else:
                self._batched_prefill(slot, remainder, matched)
                self.pending_prompt[slot] = [remainder[-1]]
        else:
            # paper mode: prompt tokens stream through decode_step
            self.pending_prompt[slot] = list(remainder)
        if self.trace.enabled:
            state = ("prefilling" if (self.slot_prefill_todo[slot]
                                      or len(self.pending_prompt[slot]) > 1)
                     else "decoding")
            self.trace.lifecycle(req.uid, state, pid=self._tpid)
        if self.on_admit:
            self.on_admit(req, slot)

    def _batched_prefill(self, slot: int, feed: List[int],
                         matched: int = 0) -> None:
        """Run full-sequence prefill for one request (bucketed length) and
        hand the resulting cache rows to the backend — spliced into the live
        batch cache (dense) or written into the slot's pages (paged).
        ``matched`` > 0 resumes after a prefix-cache hit: positions offset by
        the cached span and the remainder attends the already-committed
        prefix pages."""
        # last prompt token goes through decode
        self._prefill_span(slot, feed[:-1], matched)

    def _prefill_fns(self) -> Tuple[CompileWatch, CompileWatch]:
        """The (fresh, resume) prefill jits behind this engine's compile
        watches (the jits themselves stay shared on the model)."""
        if self._prefill_watch is None:
            fresh, resume = _prefill_jits(self.model)
            self._prefill_watch = (
                CompileWatch(fresh, "prefill_fresh", self.trace,
                             on_compile=self._note_compile, pid=self._tpid),
                CompileWatch(resume, "prefill_resume", self.trace,
                             on_compile=self._note_compile, pid=self._tpid))
        return self._prefill_watch

    def _prefill_span(self, slot: int, tokens: List[int], start: int,
                      phase: str = "prefill") -> None:
        """Prefill ``tokens`` into positions ``start .. start+n`` of the
        slot's cache (bucketed length). ``start`` > 0 resumes mid-sequence:
        positions offset by the committed span (prefix-cache pages and/or
        earlier chunks) and the new tokens attend the committed k/v via
        ``prefix_kv``. Wall time spent here while other slots were mid-decode
        is charged to ``stats.decode_stall_s`` — the decode-starvation signal
        chunking exists to shrink."""
        n = len(tokens)
        if n <= 0:
            return
        t0 = time.time()
        with self._phase(phase):
            bucket = 1 << max(4, (n - 1).bit_length())
            bucket = min(bucket, self.max_len - start)
            toks = np.zeros((1, bucket), np.int32)
            toks[0, :n] = tokens
            aidx = None
            if self.adapters is not None and self.slot_adapter[slot]:
                aidx = jnp.asarray([self.slot_adapter[slot]], jnp.int32)
            use_jit = self.cfg.attention_kind == "gqa" \
                and self.cfg.family not in ("ssm", "hybrid")
            if start:
                # pad the committed prefix to a power-of-two bucket (the
                # padded tail is masked by position inside the model) so
                # consecutive chunks hit the same compiled resume graph
                pref = self.kv.prefix_kv(slot, start)
                pbucket = min(1 << max(4, (start - 1).bit_length()),
                              self.max_len)
                if pbucket > start:
                    pad = [(0, 0)] * 5
                    pad[3] = (0, pbucket - start)
                    pref = {k: jnp.pad(v, pad) for k, v in pref.items()}
                _, sub_cache = self._dispatch(
                    self._prefill_fns()[1], self._effective_params(),
                    jnp.asarray(toks), self.max_len, jnp.int32(start), pref,
                    aidx)
            elif use_jit:
                args = (self._effective_params(), jnp.asarray(toks),
                        self.max_len, aidx)
                aot = self._cached_pref.get(("fresh", bucket, aidx is not None))
                if aot is not None:
                    try:
                        _, sub_cache = self._dispatch(aot, *args)
                    except ValueError:
                        # an input's placement drifted from the shardings the
                        # executable was lowered with (e.g. an adapter upload
                        # re-committed a leaf): the jit path re-canonicalizes
                        # placement, so fall back rather than fail the request
                        self.stats.aot_fallbacks += 1
                        aot = None
                if aot is None:
                    _, sub_cache = self._dispatch(self._prefill_fns()[0],
                                                  *args)
            else:
                kwargs = {} if aidx is None else {"adapter_idx": aidx}
                _, sub_cache = self.model.prefill(
                    self._effective_params(),
                    {"tokens": jnp.asarray(toks)}, self.max_len, **kwargs)
            self.kv.write_prefill(slot, start, sub_cache, n)
            self.pos[slot] = start + n
            stalled = [i for i in range(self.max_slots)
                       if i != slot and self._is_decoding(i)]
            if stalled:
                # charge real prefill compute, not just async dispatch time —
                # without the sync, the stall gauge under-reports on async
                # backends and the monolithic-vs-chunked A/B inverts
                jax.block_until_ready(sub_cache)
                dt = time.time() - t0
                self.stats.decode_stall_s += dt
                # each blocked decode slot experienced the full stall; SLO
                # attribution carves it out of that request's decode time
                for i in stalled:
                    self.slot_req[i].stall_s += dt

    def _advance_prefill(self) -> int:
        """Run the prefill chunks the scheduler planned for this tick.
        Returns the number of chunks advanced (each is one
        ``prefill_chunk``-token ``model.prefill`` segment); a slot whose todo
        list drops to its final prompt token transitions to decoding and
        joins this very tick's batch."""
        prefilling = [(i, self.slot_req[i]) for i in range(self.max_slots)
                      if self.slot_req[i] is not None
                      and self.slot_prefill_todo[i]]
        if not prefilling:
            return 0
        n_decoding = sum(1 for i in range(self.max_slots)
                         if self._is_decoding(i))
        advanced = 0
        for slot in self.scheduler.plan_prefill(prefilling, n_decoding):
            todo = self.slot_prefill_todo[slot]
            n = min(self.prefill_chunk, len(todo) - 1)
            self._prefill_span(slot, todo[:n], int(self.pos[slot]),
                               phase="prefill_chunk")
            req = self.slot_req[slot]
            req.prefill_chunks += 1
            self.stats.prefill_chunks += 1
            todo = todo[n:]
            if len(todo) == 1:
                self.pending_prompt[slot] = [todo[0]]
                self.slot_prefill_todo[slot] = []
            else:
                self.slot_prefill_todo[slot] = todo
            advanced += 1
        return advanced

    # -- capacity / preemption ------------------------------------------------------
    def _ensure_capacity(self, active: List[int]) -> List[int]:
        """Guarantee every active slot can write its next token. Evicts
        resident prefix pages first, then preempts victims (pages released,
        request re-queued with its generated tokens as prompt). DenseKV
        reports zero page cost, so this is a no-op there."""
        while True:
            need = sum(
                max(0, self.kv.pages_for(int(self.pos[i]) + 1)
                    - self.kv.slot_pages(i))
                for i in active)
            short = need - self.kv.pages_free
            if short <= 0:
                return active
            if self._pending:
                # under pressure with a tick in flight: finishing it may
                # release completed slots (freeing pages) and must precede
                # any preemption (the victim's replay needs its tokens)
                self._settle_pipeline()
                active = [i for i in active if self._is_decoding(i)]
                continue
            if self.prefix is not None:
                self._evict_prefix(short)
                if need <= self.kv.pages_free:
                    return active
            # victims may also be mid-chunked-prefill slots (not in the
            # decode ``active`` list) — their reserved pages are reclaimable
            pairs = self._active_pairs()
            victim = self.scheduler.pick_victim(pairs)
            if victim is None or len(pairs) <= 1:
                raise MemoryError(
                    "page pool exhausted: a single request's context exceeds "
                    "pool capacity (grow n_pages)")
            self._preempt(victim)
            active = [i for i in active if i != victim]

    def _preempt(self, slot: int) -> None:
        req = self.slot_req[slot]
        req.state = "preempted"
        req.n_preempts += 1
        self.stats.preemptions += 1
        self.trace.lifecycle(req.uid, "preempt", pid=self._tpid)
        self._release_slot(slot)
        self.scheduler.requeue(req)
        if self.on_preempt:
            self.on_preempt(req)

    def _release_slot(self, slot: int) -> None:
        req = self.slot_req[slot]
        # dense spill: the contiguous backend has no page trie, so a slot
        # whose prompt KV is fully committed parks a host copy in the tiered
        # store at release — the next request with the same prompt prefix
        # imports it instead of re-prefilling (the store's budget, not this
        # engine, decides how long it survives)
        if (self._dense_spill_ok and len(self.slot_feed[slot]) > 1
                and int(self.pos[slot]) >= len(self.slot_feed[slot])):
            feed = self.slot_feed[slot]
            key = self._dense_key(self.slot_adapter_key[slot], feed)
            if self.tiered.tier_of(key) != "host":
                self.tiered.put(key, self.kv.export_prefix(slot, len(feed)),
                                remat_cost=float(len(feed)))
                self.stats.kv_spilled_pages += 1
            self._dense_spill_lens.add(len(feed))
        if self.slot_adapter_key[slot] is not None:
            # unpin the exact version this slot acquired (hot-swap safe)
            self.adapters.release_key(self.slot_adapter_key[slot])
            self.slot_adapter_key[slot] = None
        self.slot_adapter[slot] = 0
        if self.prefix is not None:
            self.prefix.decref(self.slot_keys[slot])
        self.kv.release(slot, keep=self.slot_cached[slot])
        self.slot_req[slot] = None
        self.pending_prompt[slot] = []
        # preemption-safe partial-prefill release: committed chunk pages go
        # back to the pool (prefix-cache-owned lead pages excluded via keep=),
        # and a requeued request replays prefill from scratch on re-admission
        self.slot_prefill_todo[slot] = []
        self.slot_feed[slot] = []
        self.slot_keys[slot] = []
        self.slot_cached[slot] = 0
        self.slot_spec_adapt[slot] = None
        self.pos[slot] = 0

    # -- decode ---------------------------------------------------------------------
    def _adapter_idx(self) -> Optional[jax.Array]:
        """Per-slot device adapter index for the jitted decode (None when the
        engine serves a single personality — keeps the graph byte-identical
        to the pre-adapter path)."""
        if self.adapters is None:
            return None
        # copy: the async pipeline mutates slot_adapter (place/release)
        # while a dispatched tick may still read an aliased host buffer
        return jnp.asarray(self.slot_adapter.copy())

    def _sampling_vectors(self, active):
        """Per-slot sampling parameter vectors for the jitted samplers."""
        temps = np.zeros((self.max_slots,), np.float32)
        topks = np.zeros((self.max_slots,), np.int32)
        topps = np.ones((self.max_slots,), np.float32)
        seeds = np.zeros((self.max_slots,), np.int32)
        has_seed = np.zeros((self.max_slots,), bool)
        steps = np.zeros((self.max_slots,), np.int32)
        for i in active:
            req = self.slot_req[i]
            temps[i] = req.temperature
            topks[i] = req.top_k
            topps[i] = req.top_p
            if req.seed is not None:
                seeds[i] = req.seed
                has_seed[i] = True
            # seeded draws depend on (seed, tokens generated): count tokens
            # still in flight in pending ticks so a pipelined seeded slot
            # samples the exact step index the sequential engine would
            steps[i] = len(req.output) + self._inflight_emits(i)
        return temps, topks, topps, seeds, has_seed, steps

    def _fed_token(self, i: int) -> int:
        """The token decode consumes for slot ``i`` this tick: the next
        pending prompt token, else the last emitted one."""
        if self.pending_prompt[i]:
            return self.pending_prompt[i][0]
        return self.slot_req[i].output[-1]

    def _pop_pending(self, i: int) -> bool:
        """Consume the fed prompt token; True while the prompt is still
        being consumed (no emission this tick). When the prompt empties, its
        full pages are donated to the prefix trie — callers on the verify
        path must have committed the fed token's KV *first*, since a
        page-aligned prompt's last page is donated here."""
        req = self.slot_req[i]
        if not self.pending_prompt[i]:
            return False
        self.pending_prompt[i].pop(0)
        req.prefill_ticks += 1
        if self.pending_prompt[i]:
            return True
        self.trace.lifecycle(req.uid, "decoding", pid=self._tpid)
        if self.prefix is not None:
            keys = self.prefix.commit(self.slot_feed[i],
                                      self.pool.tables[i],
                                      self.slot_cached[i])
            self.slot_keys[i].extend(keys)
            self.slot_cached[i] += len(keys)
            if self.tiered is not None:
                for k in keys:
                    self.tiered.note_device(self._kv_key(k),
                                            self._page_nbytes,
                                            remat_cost=float(len(k)))
        return False

    def _emit_token(self, i: int, req: Request, tok: int, now: float,
                    pos_now: Optional[int] = None) -> bool:
        """Output-token bookkeeping shared by the single-token and verify
        ticks; returns True when the request finished (or vanished — an
        on_token callback may cancel requests mid-tick, so re-check slot
        ownership after it fires rather than double-releasing).
        ``pos_now`` overrides the live slot position for the max_len check —
        a deferred (pipelined) emission must judge completion at the
        position its own tick reached, not one a later begin advanced to."""
        if not req.output:
            req.t_first = now
        req.output.append(tok)
        self.stats.tokens_out += 1
        if self.on_token:
            self.on_token(req, tok, now)
        if self.slot_req[i] is not req:
            return True     # cancelled/released from inside the callback
        req.t_last = now
        pos_i = int(self.pos[i]) if pos_now is None else pos_now
        done = (len(req.output) >= req.max_new_tokens
                or (req.eos_id is not None and req.output[-1] == req.eos_id)
                or pos_i >= self.max_len)
        if done:
            req.t_done = now
            req.state = "done"
            self.stats.completed += 1
            self._release_slot(i)
            self.trace.lifecycle(req.uid, "done", pid=self._tpid)
            if self.on_done:
                self.on_done(req)
        return done

    # -- speculative decoding --------------------------------------------------
    def _spec_eligible(self, i: int) -> bool:
        """Drafting is worthwhile only when acceptance is decidable without
        perturbing the request's sampling contract: greedy (accept iff the
        draft is the argmax) or seeded (draws depend only on (seed, step),
        so the verify row reproduces the exact token the sequential sampler
        would emit). Unseeded stochastic slots keep one token per tick."""
        req = self.slot_req[i]
        s = req.sampling
        return (s.spec_k > 0
                and (s.temperature <= 0.0 or s.seed is not None)
                and len(self.pending_prompt[i]) <= 1)

    def _plan_drafts(self, active: List[int]) -> List[List[int]]:
        """Per-slot draft tokens for this tick (empty = plain decode).
        Width is capped by the request's remaining budget and cache room,
        then drafts are trimmed (longest first) until the worst-case commit
        fits the page pool — speculation is opportunistic and must never
        evict a prefix page or preempt a neighbour to make room."""
        drafts: List[List[int]] = [[] for _ in range(self.max_slots)]
        for i in active:
            req = self.slot_req[i]
            if not self._spec_eligible(i):
                continue
            k = min(req.sampling.spec_k,
                    req.max_new_tokens - len(req.output) - 1,
                    self.max_len - int(self.pos[i]) - 1)
            adapt = self.slot_spec_adapt[i]
            if adapt is not None:
                # adaptive width: the slot's live accept-rate EWMA names how
                # much of the request's spec_k ceiling is worth risking
                k = min(k, adapt.suggest(req.sampling.spec_k))
            # quantize to a pow2-minus-one width (1, 3, 7, 15): the verify
            # scan runs s_bucket sequential steps whatever the true draft
            # length, so a k=4 draft would pay for an 8-wide bucket — 3
            # steps of pure padding waste
            k = quantize_width(k)
            if k <= 0:
                continue
            proposed = propose(self._feed_tokens(req), k, self.spec_ngram)
            drafts[i] = proposed[:quantize_width(len(proposed))]
        if self.kv.supports_paging and any(drafts[i] for i in active):
            # _ensure_capacity already guaranteed the +1 pages; drafts may
            # only spend what is left beyond that baseline
            base_need = sum(
                max(0, self.kv.pages_for(int(self.pos[i]) + 1)
                    - self.kv.slot_pages(i))
                for i in active)
            budget = self.kv.pages_free - base_need

            def extra(i):
                return (self.kv.pages_for(int(self.pos[i]) + 1
                                          + len(drafts[i]))
                        - self.kv.pages_for(int(self.pos[i]) + 1))

            while sum(extra(i) for i in active) > budget:
                victim = max((i for i in active if drafts[i]),
                             key=lambda i: len(drafts[i]), default=None)
                if victim is None:
                    break
                drafts[victim] = []
        return drafts

    def _tick_verify(self, active: List[int],
                     drafts: List[List[int]]) -> None:
        """The speculative tick: one jitted ``verify_step`` scores every
        slot's fed token plus its drafts (width padded to a power of two),
        the per-position sampler names the token the sequential engine would
        have emitted at each step, and each slot commits exactly the
        accepted span — ``plan_emit`` truncates where the sequential engine
        would have stopped (budget / eos / max_len), so rejected drafts
        never reach the KV store and bookkeeping is step-identical."""
        with self._phase("spec_verify"):
            n_in = np.ones((self.max_slots,), np.int32)
            for i in active:
                n_in[i] = 1 + len(drafts[i])
            s_bucket = 1 << int(max(int(n_in[i])
                                    for i in active) - 1).bit_length()
            self._last_verify_width = s_bucket
            tokens = np.zeros((self.max_slots, s_bucket), np.int32)
            for i in active:
                row = [self._fed_token(i)] + drafts[i]
                tokens[i, :len(row)] = row
            temps, topks, topps, seeds, has_seed, steps = \
                self._sampling_vectors(active)

            state = self.kv.verify_state(active, self.pos, n_in, s_bucket)
            logits, spans = self._dispatch(
                self._verify, self._effective_params(), state,
                jnp.asarray(tokens), jnp.asarray(self.pos),
                self._adapter_idx())
        with self._phase("sample"):
            self.key, sub = jax.random.split(self.key)
            choice = np.asarray(self._dispatch(
                self._verify_sample,
                logits, sub, jnp.asarray(temps), jnp.asarray(topks),
                jnp.asarray(topps), jnp.asarray(seeds),
                jnp.asarray(has_seed), jnp.asarray(steps),
                use_topp=bool(np.any(topps < 1.0)),
                use_seeds=bool(np.any(has_seed))))

        now = time.time()
        self.stats.ticks += 1
        self.stats.spec_ticks += 1
        with self._phase("emit"):
            for i in active:
                req = self.slot_req[i]
                if req is None:
                    continue    # released by a callback earlier in the loop
                if len(self.pending_prompt[i]) > 1:
                    # mid-prompt (token-mode prefill): commit the fed token's
                    # KV and keep consuming — drafting was ineligible here
                    with self._phase("commit"):
                        self.kv.commit_span(i, int(self.pos[i]), spans, 1)
                    self.pos[i] += 1
                    self._pop_pending(i)
                    continue
                acc = accepted_prefix(drafts[i], choice[i])
                emit = plan_emit(acc, choice[i],
                                 budget=req.max_new_tokens - len(req.output),
                                 room=self.max_len - int(self.pos[i]),
                                 eos_id=req.eos_id)
                # commit before _pop_pending: trie donation of a page-aligned
                # prompt needs the fed token's KV in its page already
                with self._phase("commit"):
                    self.kv.commit_span(i, int(self.pos[i]), spans,
                                        len(emit))
                self._pop_pending(i)
                adapt = self.slot_spec_adapt[i]
                if adapt is not None and drafts[i]:
                    adapt.observe(len(drafts[i]), acc)
                req.spec_drafted += len(drafts[i])
                self.stats.spec_drafted += len(drafts[i])
                gained = max(0, len(emit) - 1)
                req.spec_accepted += gained
                self.stats.spec_accepted += gained
                for tok in emit:
                    self.pos[i] += 1
                    if self._emit_token(i, req, int(tok), now):
                        break

    def tick(self) -> None:
        """One decode step for the whole slot batch, preceded by the tick's
        chunked-prefill budget. A slot mid-chunked-prefill is excluded from
        the decode batch, so co-resident decode slots keep emitting every
        tick while its prompt streams in chunk by chunk. With
        ``spec_decode=True`` and any drafts on offer, the tick runs the
        multi-token verify instead and commits every accepted token.

        Internally one tick is ``tick_begin()`` (everything up to and
        including the sample dispatch) followed by ``tick_finish()``
        (materialize the sampled tokens + emit/eos/release bookkeeping).
        The sync path runs them back to back; the async runtime interleaves
        begin(N+1) before finish(N) so the device stays a tick ahead."""
        self.tick_begin()
        while self._pending:
            self.tick_finish()

    def tick_begin(self) -> PendingTick:
        """Dispatch one tick's device work without reading its results:
        admission, chunked prefill, decode + sample dispatch, KV commit,
        position advance and prompt-consumption bookkeeping all happen now;
        the sampled-token array stays on device inside the returned
        ``PendingTick`` (also appended to the engine's pending deque).

        Pipelining contract: a next ``tick_begin`` issued before the finish
        feeds in-flight slots their unmaterialized token via a device-side
        overlay (``jnp.where`` against the pending sample array), offsets
        seeded-sampling step indices by the in-flight count, and skips slots
        whose completion is already predictable (budget / max_len). Verify
        (spec) ticks and state-mutating scheduler paths settle the pipeline
        first — they need host-visible history."""
        p = PendingTick()
        t0 = time.perf_counter()
        p.busy0 = self._busy_ms()
        p.tokens0 = self.stats.tokens_out
        p.ticks0 = self.stats.ticks
        self._tick_gap_ms = None
        self._last_verify_width = 1
        with self.trace.span("tick", pid=self._tpid):
            self._tick_begin_impl(p)
        p.gap_ms = self._tick_gap_ms
        p.verify_width = self._last_verify_width
        p.begin_s = time.perf_counter() - t0
        self._pending.append(p)
        return p

    def tick_finish(self) -> None:
        """Materialize the oldest pending tick and run its deferred host
        work: read the sampled tokens, append/emit/eos/release per slot, add
        the tick's wall to the stats ledger and fire ``on_tick``. A slot
        whose request changed since begin (released by an earlier finish
        discovering eos, or cancelled) skips its stale emission."""
        if not self._pending:
            return
        p = self._pending.popleft()
        t0 = time.perf_counter()
        with self.trace.span("tick_finish", pid=self._tpid):
            if p.nxt_dev is not None:
                nxt = np.asarray(p.nxt_dev)
                now = time.time()
                with self._phase("emit"):
                    for i, req, pos_i in p.emits:
                        if self.slot_req[i] is not req:
                            continue    # released/cancelled since begin
                        self._emit_token(i, req, int(nxt[i]), now,
                                         pos_now=pos_i)
        wall_ms = (p.begin_s + time.perf_counter() - t0) * 1e3
        self.stats.tick_wall_ms_sum += wall_ms
        if self.on_tick is not None:
            self.on_tick({
                "wall_ms": wall_ms,
                "busy_ms": self._busy_ms() - p.busy0,
                "gap_ms": p.gap_ms,
                "tokens": self.stats.tokens_out - p.tokens0,
                "ticked": self.stats.ticks > p.ticks0,
                "active": sum(1 for r in self.slot_req if r is not None),
                "prefilling": sum(1 for t in self.slot_prefill_todo if t),
                "verify_width": p.verify_width,
                "dispatch_ahead_depth": len(self._pending),
            })

    def _tick_begin_impl(self, p: PendingTick) -> None:
        with self._phase("schedule"):
            self._prefetch_queue()
            self._admit()
        chunks = self._advance_prefill()
        active = [i for i in range(self.max_slots) if self._is_decoding(i)
                  and not self._slot_done_inflight(i)]
        if active:
            with self._phase("schedule"):
                active = self._ensure_capacity(active)
                active = [i for i in active
                          if not self._slot_done_inflight(i)]
        if not active:
            if chunks:
                self.stats.ticks += 1   # prefill-only tick still progresses
            return

        if self.spec_decode:
            # drafting proposes from host-visible history — settle any
            # pipelined tick so the proposer sees every emitted token
            self._settle_pipeline()
            active = [i for i in active if self._is_decoding(i)]
            if not active:
                if chunks:
                    self.stats.ticks += 1
                return
            with self._phase("schedule"):
                drafts = self._plan_drafts(active)
            if any(drafts[i] for i in active):
                self._tick_verify(active, drafts)
                return

        with self._phase("decode"):
            tokens = np.zeros((self.max_slots,), np.int32)
            overlay: List[int] = []
            for i in active:
                if not self.pending_prompt[i] and self._inflight_emits(i):
                    # fed token is still on device (previous tick's sample)
                    overlay.append(i)
                else:
                    tokens[i] = self._fed_token(i)
            temps, topks, topps, seeds, has_seed, steps = \
                self._sampling_vectors(active)

            fed = jnp.asarray(tokens)
            if overlay:
                # per-slot device overlay: feed each in-flight slot the
                # sample array of the *latest* pending tick that emitted for
                # it (with depth 1 that is simply the newest pending)
                by_src: Dict[int, Tuple[PendingTick, List[int]]] = {}
                for i in overlay:
                    for q in reversed(self._pending):
                        if any(j == i and r is self.slot_req[i]
                               for j, r, _ in q.emits):
                            by_src.setdefault(id(q), (q, []))[1].append(i)
                            break
                for q, slots in by_src.values():
                    mask = np.zeros((self.max_slots,), bool)
                    mask[slots] = True
                    fed = jnp.where(jnp.asarray(mask), q.nxt_dev, fed)
            # snapshot live engine buffers: without the sync path's
            # materialization barrier the dispatch is truly async, and
            # jnp.asarray may alias host numpy memory on CPU — the pos
            # advance below must not race the in-flight compute
            state = self.kv.decode_state(active, self.pos)
            logits, new_state = self._dispatch(
                self._decode, self._effective_params(), state,
                fed, jnp.asarray(self.pos.copy()),
                self._adapter_idx())
        with self._phase("commit"):
            self.kv.commit(new_state, active, self.pos)
        with self._phase("sample"):
            self.key, sub = jax.random.split(self.key)
            p.nxt_dev = self._dispatch(
                self._sample,
                logits, sub, jnp.asarray(temps),
                jnp.asarray(topks), jnp.asarray(topps),
                jnp.asarray(seeds), jnp.asarray(has_seed),
                jnp.asarray(steps),
                use_topp=bool(np.any(topps < 1.0)),
                use_seeds=bool(np.any(has_seed)))

        self.stats.ticks += 1
        p.active = active
        for i in active:
            req = self.slot_req[i]
            if req is None:
                continue
            self.pos[i] += 1
            if self._pop_pending(i):
                continue  # still consuming the prompt — no emission
            p.emits.append((i, req, int(self.pos[i])))
            # predictable completion (budget / max_len): count every token
            # already emitted, in flight in older pending ticks, and this
            # tick's own pending emission
            n_out = (len(req.output) + self._inflight_emits(i)) + 1
            if n_out >= req.max_new_tokens or self.pos[i] >= self.max_len:
                p.done_slots.add(i)
