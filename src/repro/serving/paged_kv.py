"""Paged KV cache (paged-lite): a block-pool allocator for decode slots.

The slot engine (engine.py) reserves ``max_len`` cache per slot — fine for
the paper's fixed on-chip SRAM budget (Table I: 240 KB per MVU), wasteful
when request lengths vary. This module adds vLLM-style paging:

  * one shared page pool per layer group: ``(L, n_pages, Hkv, page, D)`` fp8
  * each slot owns a growable list of page ids (the block table)
  * pages allocate on first write into them and free when the slot ends

Pure-JAX integration path (used here + tests): `gather_slot` materializes a
slot's contiguous (L, 1, H, S_used, D) view for the model's decode step and
`scatter_slot` writes the updated tail page back. On TPU the gather is
skipped entirely — the Pallas `flash_decode` kernel takes the page table and
streams pages HBM→VMEM directly (its context loop is already page-shaped:
block_s == page); that integration point is the kernel's `block_s` grid.

The allocator itself is host-side (numpy int32 tables) — allocation is
control-plane, the pool is device-side.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# jit-friendly functional forms live beside the model's PagedKVState (the
# paged decode path consumes them inside Model.decode_step); re-exported
# here for the pool's own helpers and back-compat.
from repro.models.attention import gather_pages, scatter_tokens


@dataclasses.dataclass
class PagedConfig:
    n_layers: int
    n_kv_heads: int
    head_dim: int
    page: int = 64              # tokens per page
    n_pages: int = 256          # pool capacity (per k and v)
    dtype: object = jnp.float8_e4m3fn


class PagePool:
    """Shared fp8 KV page pool + per-slot block tables.

    One extra *scratch* page (id ``cfg.n_pages``) is allocated past the pool:
    it is never handed out and soaks up the batched decode writes of inactive
    slots, so the engine's jitted scatter needs no mask.
    """

    def __init__(self, cfg: PagedConfig, max_slots: int):
        self.cfg = cfg
        shape = (cfg.n_layers, cfg.n_pages + 1, cfg.n_kv_heads, cfg.page,
                 cfg.head_dim)
        self.k = jnp.zeros(shape, cfg.dtype)
        self.v = jnp.zeros(shape, cfg.dtype)
        self.free: List[int] = list(range(cfg.n_pages))
        self.tables: List[List[int]] = [[] for _ in range(max_slots)]
        self.lengths = np.zeros((max_slots,), np.int32)

    @property
    def scratch_page(self) -> int:
        return self.cfg.n_pages

    # -- allocator (host control plane) --------------------------------------
    @property
    def pages_free(self) -> int:
        return len(self.free)

    def pages_for(self, tokens: int) -> int:
        return -(-tokens // self.cfg.page)

    def can_admit(self, tokens: int) -> bool:
        return self.pages_free >= self.pages_for(tokens)

    def reserve(self, slot: int, upto_tokens: int) -> None:
        """Grow the slot's table to cover ``upto_tokens`` positions."""
        need = self.pages_for(max(upto_tokens, 1)) - len(self.tables[slot])
        for _ in range(max(0, need)):
            if not self.free:
                raise MemoryError("page pool exhausted")
            self.tables[slot].append(self.free.pop())

    def release(self, slot: int, keep: int = 0) -> None:
        """Free the slot's pages and clear its table. ``keep`` leading pages
        are *not* returned to the free list — they belong to the prefix cache
        (which refcounts them and frees them on eviction)."""
        self.free.extend(self.tables[slot][keep:])
        self.tables[slot] = []
        self.lengths[slot] = 0

    def free_pages(self, page_ids: List[int]) -> None:
        """Return cache-owned pages (e.g. evicted prefix pages) to the pool."""
        self.free.extend(page_ids)

    def alloc_page(self) -> int:
        """Allocate one page owned by the caller (prefix re-admission: the
        page goes straight to the prefix cache, never through a slot table)."""
        if not self.free:
            raise MemoryError("page pool exhausted")
        return self.free.pop()

    def append_shared(self, slot: int, page_ids: List[int]) -> None:
        """Attach already-allocated pages (prefix-cache hits) to a slot's
        table. The pages stay owned by the cache; ``release(keep=...)`` must
        skip them."""
        self.tables[slot].extend(page_ids)

    def fragmentation_savings(self, max_len: int, active_lengths) -> float:
        """Bytes saved vs per-slot max_len reservation (the paged-lite win)."""
        flat = sum(self.pages_for(int(l)) for l in active_lengths)
        reserved = len(active_lengths) * self.pages_for(max_len)
        return 1.0 - flat / max(reserved, 1)

    # -- device-side data movement --------------------------------------------
    def table_array(self, slot: int, max_pages: int) -> jnp.ndarray:
        t = self.tables[slot]
        pad = [0] * (max_pages - len(t))
        return jnp.asarray(t + pad, jnp.int32)

    def gather_slot(self, slot: int, n_pages: Optional[int] = None
                    ) -> Tuple[jax.Array, jax.Array]:
        """Materialize the slot's contiguous (L, 1, H, S, D) k/v views."""
        ids = self.tables[slot][: n_pages or len(self.tables[slot])]
        idx = jnp.asarray(ids, jnp.int32)
        c = self.cfg

        def gather(pool):
            pages = pool[:, idx]                      # (L, P, H, page, D)
            return pages.transpose(0, 2, 1, 3, 4).reshape(
                c.n_layers, 1, c.n_kv_heads, len(ids) * c.page, c.head_dim
            ).transpose(0, 1, 2, 3, 4)

        return gather(self.k), gather(self.v)

    def batch_tables(self, slots: List[int], n_pages: int,
                     batch: int) -> np.ndarray:
        """(batch, n_pages) int32 block-table matrix; rows of inactive slots
        (and padding beyond a slot's table) point at the scratch page."""
        out = np.full((batch, n_pages), self.scratch_page, np.int32)
        for s in slots:
            t = self.tables[s][:n_pages]
            out[s, :len(t)] = t
        return out

    def gather_batch(self, tables: np.ndarray) -> Tuple[jax.Array, jax.Array]:
        """Materialize batched contiguous (L, B, H, P*page, D) k/v views from
        a (B, P) block-table matrix (the pure-JAX decode integration path)."""
        return (gather_pages(self.k, jnp.asarray(tables, jnp.int32)),
                gather_pages(self.v, jnp.asarray(tables, jnp.int32)))

    def write_tokens(self, page_ids: np.ndarray, offsets: np.ndarray,
                     k_toks: jax.Array, v_toks: jax.Array) -> None:
        """Batched single-token scatter: write (L, B, H, D) k/v entries at
        (page_ids[b], offsets[b]). Inactive rows should target the scratch
        page. Callers must have reserved the pages already."""
        self.k = scatter_tokens(self.k, jnp.asarray(page_ids, jnp.int32),
                                jnp.asarray(offsets, jnp.int32), k_toks)
        self.v = scatter_tokens(self.v, jnp.asarray(page_ids, jnp.int32),
                                jnp.asarray(offsets, jnp.int32), v_toks)

    def write_token(self, slot: int, pos: int, k_tok: jax.Array,
                    v_tok: jax.Array) -> None:
        """Write one (L, H, D) k/v entry at ``pos`` into the slot's pages."""
        self.reserve(slot, pos + 1)
        page_id = self.tables[slot][pos // self.cfg.page]
        off = pos % self.cfg.page
        self.k = self.k.at[:, page_id, :, off].set(
            k_tok.astype(self.k.dtype))
        self.v = self.v.at[:, page_id, :, off].set(
            v_tok.astype(self.v.dtype))
        self.lengths[slot] = max(self.lengths[slot], pos + 1)

    def write_span(self, slot: int, start: int, k_span: jax.Array,
                   v_span: jax.Array) -> None:
        """Bulk write (L, H, T, D) — prefill fill path, page by page."""
        t = k_span.shape[2]
        self.reserve(slot, start + t)
        done = 0
        while done < t:
            pos = start + done
            page_id = self.tables[slot][pos // self.cfg.page]
            off = pos % self.cfg.page
            n = min(self.cfg.page - off, t - done)
            self.k = jax.lax.dynamic_update_slice(
                self.k, k_span[:, None, :, done:done + n].astype(self.k.dtype),
                (0, page_id, 0, off, 0))
            self.v = jax.lax.dynamic_update_slice(
                self.v, v_span[:, None, :, done:done + n].astype(self.v.dtype),
                (0, page_id, 0, off, 0))
            done += n
        self.lengths[slot] = max(self.lengths[slot], start + t)


