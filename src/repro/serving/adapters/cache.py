"""SRAM-budget adapter cache: byte-accounted residency, LRU, pinning.

Models TOM's finite SRAM: adapters share the on-chip budget with the KV
cache, so only a bounded set can be resident at once. The cache tracks

  * **bytes** — every resident adapter is accounted at its packed 2-bit
    footprint (`qlora.adapter_bytes`); admission never exceeds the budget;
  * **slots** — each resident adapter owns one index in the device-side
    ``[num_adapters, ...]`` stacks (slot 0 is the null adapter and is never
    allocated);
  * **pins** — refcounts of in-flight requests. A pinned adapter is *never*
    evicted: its slot index is baked into running decode state;
  * **LRU** — unpinned residents evict least-recently-used first when a new
    adapter needs bytes or a slot.

Pure host-side control plane (the data plane lives in runtime.py), so it is
unit-testable without a model.
"""
from __future__ import annotations

import itertools
from typing import Dict, List, Optional, Tuple


class AdapterCache:
    def __init__(self, budget_bytes: int, max_entries: int, tiered=None):
        assert max_entries >= 1
        self.budget_bytes = int(budget_bytes)
        self.max_entries = int(max_entries)
        # Optional TieredStore: evicted packs demote to the host tier
        # instead of being dropped, and admissions are accounted in the
        # store's device tier. `demote_payload` (set by AdapterServing)
        # maps an id to its host-side pack payload at eviction time.
        self.tiered = tiered
        self.demote_payload = None
        self._slot: Dict[str, int] = {}        # id → device slot (1-based)
        self._nbytes: Dict[str, int] = {}
        self._pins: Dict[str, int] = {}
        self._last_use: Dict[str, int] = {}
        self._clock = itertools.count(1)
        self._free_slots: List[int] = list(range(max_entries, 0, -1))
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.loads = 0

    # -- introspection --------------------------------------------------------
    @property
    def bytes_used(self) -> int:
        return sum(self._nbytes.values())

    @property
    def n_resident(self) -> int:
        return len(self._slot)

    def is_resident(self, adapter_id: str) -> bool:
        return adapter_id in self._slot

    def slot_of(self, adapter_id: str) -> int:
        return self._slot[adapter_id]

    def pinned(self, adapter_id: str) -> bool:
        return self._pins.get(adapter_id, 0) > 0

    def resident_ids(self) -> List[str]:
        return list(self._slot)

    # -- admission ------------------------------------------------------------
    def _evictable_lru(self) -> List[str]:
        """Unpinned residents, least-recently-used first."""
        ids = [i for i in self._slot if self._pins.get(i, 0) == 0]
        return sorted(ids, key=lambda i: self._last_use.get(i, 0))

    def can_admit(self, adapter_id: str, nbytes: int) -> bool:
        """Could ``adapter_id`` be made resident *right now* (evicting only
        unpinned adapters)? Admission control calls this before scheduling a
        request whose adapter is cold."""
        if adapter_id in self._slot:
            return True
        if nbytes > self.budget_bytes:
            return False
        reclaimable = sum(self._nbytes[i] for i in self._evictable_lru())
        if self.bytes_used - reclaimable + nbytes > self.budget_bytes:
            return False
        if not self._free_slots and not self._evictable_lru():
            return False
        return True

    def lookup(self, adapter_id: str) -> Optional[int]:
        """Slot of a resident adapter (touches LRU + hit/miss counters)."""
        slot = self._slot.get(adapter_id)
        if slot is None:
            self.misses += 1
            return None
        self.hits += 1
        self._last_use[adapter_id] = next(self._clock)
        return slot

    def admit(self, adapter_id: str, nbytes: int) -> Tuple[int, List[str]]:
        """Make ``adapter_id`` resident; returns (slot, evicted ids). Raises
        MemoryError when pinned residents hold too much of the budget."""
        if adapter_id in self._slot:
            return self._slot[adapter_id], []
        evicted: List[str] = []
        while (self.bytes_used + nbytes > self.budget_bytes
               or not self._free_slots):
            lru = self._evictable_lru()
            if not lru:
                raise MemoryError(
                    f"adapter SRAM budget exhausted by pinned adapters "
                    f"({self.bytes_used}B used + {nbytes}B needed > "
                    f"{self.budget_bytes}B budget)")
            evicted.append(self._evict(lru[0]))
        slot = self._free_slots.pop()
        self._slot[adapter_id] = slot
        self._nbytes[adapter_id] = nbytes
        self._last_use[adapter_id] = next(self._clock)
        self.loads += 1
        if self.tiered is not None:
            self.tiered.note_device("adapter:" + adapter_id, nbytes)
        return slot, evicted

    def _evict(self, adapter_id: str) -> str:
        self._free_slots.append(self._slot.pop(adapter_id))
        self._nbytes.pop(adapter_id)
        self._last_use.pop(adapter_id, None)
        self.evictions += 1
        if self.tiered is not None:
            payload = (self.demote_payload(adapter_id)
                       if self.demote_payload is not None else None)
            if payload is not None:
                self.tiered.demote("adapter:" + adapter_id, payload)
            else:
                self.tiered.drop_device("adapter:" + adapter_id)
        return adapter_id

    # -- pinning (in-flight requests) ----------------------------------------
    def pin(self, adapter_id: str) -> None:
        assert adapter_id in self._slot, adapter_id
        self._pins[adapter_id] = self._pins.get(adapter_id, 0) + 1

    def unpin(self, adapter_id: str) -> None:
        n = self._pins.get(adapter_id, 0)
        if n <= 1:
            self._pins.pop(adapter_id, None)
        else:
            self._pins[adapter_id] = n - 1

    # -- stats ----------------------------------------------------------------
    def stats(self) -> Dict[str, float]:
        lookups = self.hits + self.misses
        return {
            "resident": self.n_resident,
            "pinned": sum(1 for i in self._slot if self.pinned(i)),
            "bytes_used": self.bytes_used,
            "budget_bytes": self.budget_bytes,
            "hits": self.hits,
            "misses": self.misses,
            "hit_rate": round(self.hits / lookups, 4) if lookups else 0.0,
            "evictions": self.evictions,
            "loads": self.loads,
        }
