"""Adapter serving runtime: device-side stacks + param-tree injection.

The data plane of the multi-tenant subsystem. Resident adapters live as
packed-ternary stacks shaped for the model's scan-over-layers:

    a: (L, R+1, K//4, r) u8    b: (L, R+1, r//4, N) u8    s: (L, R+1) f32

— leading layer axis so `jax.lax.scan` slices one layer's ``(R+1, ...)``
stack per step; slot 0 is the null adapter (zero codes, zero scale), so
slots without an adapter contribute exactly 0. ``install`` grafts these
stacks into a serve-mode param tree as ``lora_mt`` leaves on the target
projections; the engine passes a per-slot ``adapter_idx`` vector into the
jitted decode and `models/layers.apply_linear` gathers each row's A/B by
index (SGMV — one tick serves many fine-tunes, no per-adapter dispatch).

Loading/evicting an adapter rewrites one slot of each stack (same shapes →
no recompilation) and bumps ``version`` so the engine re-installs the
leaves. The combined per-layer scale ``scale_a · scale_b · α/r`` is folded
into ``s`` at upload, so the kernel applies one multiply.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from repro.serving.adapters.cache import AdapterCache
from repro.serving.adapters.registry import (AdapterRegistry, FrozenAdapter,
                                             TARGET_GROUP, target_dims)


class AdapterServing:
    """Registry + SRAM-budget cache + device stacks for one served model."""

    def __init__(self, model, registry: AdapterRegistry, *,
                 budget_bytes: int, max_resident: int = 8):
        cfg = model.cfg
        assert cfg.family not in ("ssm", "hybrid"), \
            "multi-tenant adapters need scanned attention layers"
        assert cfg.attention_kind == "gqa", \
            "multi-tenant adapters target GQA projections (q/k/v/o)"
        assert cfg.moe is None or not any(
            t in ("up", "gate", "down") for t in registry.spec.targets), \
            "FFN adapter targets need a dense FFN"
        assert cfg.moe is None or cfg.moe.first_k_dense == 0, \
            "unstacked prefix layers are not adapter targets"
        self.model = model
        self.cfg = cfg
        self.registry = registry
        self.spec = registry.spec
        self.cache = AdapterCache(budget_bytes, max_resident)
        self.tiered = None
        self.version = 0
        self.n_layers = cfg.num_layers
        r = self.spec.rank
        n_slots = max_resident + 1              # + null slot 0
        self.pack: Dict[str, Dict[str, jnp.ndarray]] = {}
        for target in self.spec.targets:
            k, n = target_dims(cfg, target)
            assert k % 4 == 0, (target, k)
            self.pack[target] = {
                "a": jnp.zeros((self.n_layers, n_slots, k // 4, r), jnp.uint8),
                "b": jnp.zeros((self.n_layers, n_slots, r // 4, n), jnp.uint8),
                "s": jnp.zeros((self.n_layers, n_slots), jnp.float32),
            }

    # -- tiered memory ---------------------------------------------------------
    def attach_tiered(self, tiered) -> None:
        """Back the SRAM cache with a TieredStore: evicted packs demote to
        the host tier as upload-ready payloads, and a later acquire of the
        same version promotes from host instead of re-freezing from the
        registry (the registry stays the durable source of truth — the host
        tier is the warm path)."""
        self.tiered = tiered
        self.cache.tiered = tiered
        self.cache.demote_payload = self._demote_payload

    def _demote_payload(self, key: str):
        """Upload-ready host payload for a version-resolved cache key
        (``tenant@vN``): packed codes plus the folded per-layer scale."""
        adapter_id, _, v = key.rpartition("@v")
        try:
            entry = self.registry.get(adapter_id, int(v))
        except (KeyError, ValueError):
            return None
        payload = {}
        for target, pk in entry.packs.items():
            combined = (pk["a_scale"] * pk["b_scale"]
                        * np.float32(self.spec.scaling))
            payload[f"{target}.a"] = pk["a_codes"]
            payload[f"{target}.b"] = pk["b_codes"]
            payload[f"{target}.s"] = np.asarray(combined, np.float32)
        return payload

    def _upload_payload(self, payload, slot: int) -> None:
        """Write a host-tier payload (from `_demote_payload`) into device
        slot ``slot`` — same bytes the registry path uploads."""
        for target in self.pack:
            dev = self.pack[target]
            dev["a"] = dev["a"].at[:, slot].set(
                jnp.asarray(payload[f"{target}.a"]))
            dev["b"] = dev["b"].at[:, slot].set(
                jnp.asarray(payload[f"{target}.b"]))
            dev["s"] = dev["s"].at[:, slot].set(
                jnp.asarray(payload[f"{target}.s"]))

    def prefetch(self, adapter_id: str) -> bool:
        """Opportunistically warm the latest version into a *free* slot
        (scheduler prefetch hook). Never evicts and never pins: only loads
        when both a slot and the bytes are spare, so it cannot displace
        in-flight or hotter-by-LRU residents."""
        if adapter_id not in self.registry:
            return False
        entry = self.registry.get(adapter_id)
        key = f"{adapter_id}@v{entry.version}"
        if self.cache.is_resident(key):
            return False
        if not self.cache._free_slots:
            return False
        if self.cache.bytes_used + entry.nbytes > self.cache.budget_bytes:
            return False
        payload = (self.tiered.take("adapter:" + key)
                   if self.tiered is not None else None)
        slot, _ = self.cache.admit(key, entry.nbytes)
        if payload is not None:
            self._upload_payload(payload, slot)
        else:
            self._upload(entry, slot)
        self.version += 1
        return True

    # -- param-tree injection --------------------------------------------------
    def install(self, params: Dict[str, Any]) -> Dict[str, Any]:
        """Copy-on-write graft of the current stacks into ``params`` as
        ``lora_mt`` leaves (original tree untouched)."""
        out = dict(params)
        layers_p = dict(params["layers"])
        for target, pack in self.pack.items():
            group = TARGET_GROUP[target]
            group_p = dict(layers_p[group])
            node = dict(group_p[target])
            node["lora_mt"] = {"a": pack["a"], "b": pack["b"], "s": pack["s"]}
            group_p[target] = node
            layers_p[group] = group_p
        out["layers"] = layers_p
        return out

    # -- residency lifecycle ---------------------------------------------------
    # Cache keys are *version-resolved* ("tenant@v2"): a hot-swap
    # (re-register) creates a distinct cache entry, so requests pinned on the
    # old version keep their weights while new placements load the new one —
    # both versions can be resident at once if the budget allows, and the old
    # entry becomes LRU-evictable the moment its last pin drops.
    def _vkey(self, adapter_id: str) -> str:
        """Cache key of the adapter's *latest* registered version."""
        return f"{adapter_id}@v{self.registry.get(adapter_id).version}"

    def is_resident(self, adapter_id: str) -> bool:
        """Affinity predicate: is the *latest* version already on device?"""
        if adapter_id not in self.registry:
            return False
        return self.cache.is_resident(self._vkey(adapter_id))

    def servable(self, adapter_id: Optional[str]) -> bool:
        """Static half of admission: registered and small enough to *ever*
        fit the SRAM budget (submit-time validation)."""
        if adapter_id is None:
            return True
        if adapter_id not in self.registry:
            return False
        return self.registry.get(adapter_id).nbytes <= self.cache.budget_bytes

    def can_serve(self, adapter_id: Optional[str]) -> bool:
        """Admission predicate: could a request with this adapter start now?"""
        if adapter_id is None:
            return True
        if adapter_id not in self.registry:
            return False
        entry = self.registry.get(adapter_id)
        return self.cache.can_admit(self._vkey(adapter_id), entry.nbytes)

    def acquire_versioned(self, adapter_id: str) -> "tuple[int, str]":
        """Pin the adapter's latest version for an in-flight request, loading
        (and evicting LRU unpinned residents) if cold. Returns the device
        slot index plus the version-resolved cache key — callers release
        exactly that key, so a mid-stream re-register never steals the
        weights out from under a running request."""
        entry = self.registry.get(adapter_id)
        key = f"{adapter_id}@v{entry.version}"
        slot = self.cache.lookup(key)
        if slot is None:
            # Host-tier hit: a previously evicted pack was demoted instead
            # of dropped — promote the ready-made payload rather than
            # re-deriving the upload from the registry entry.
            payload = (self.tiered.take("adapter:" + key)
                       if self.tiered is not None else None)
            slot, _ = self.cache.admit(key, entry.nbytes)
            if payload is not None:
                self._upload_payload(payload, slot)
            else:
                self._upload(entry, slot)
            self.version += 1
        self.cache.pin(key)
        return slot, key

    def acquire(self, adapter_id: str) -> int:
        return self.acquire_versioned(adapter_id)[0]

    def release_key(self, key: str) -> None:
        """Unpin a version-resolved key from `acquire_versioned`."""
        self.cache.unpin(key)

    def pinned(self, adapter_id: str) -> bool:
        """Is *any* version of this adapter pinned by an in-flight request?
        (Invariant checks shouldn't care which version a request rode.)"""
        prefix = f"{adapter_id}@v"
        return any(n > 0 for k, n in self.cache._pins.items()
                   if k.startswith(prefix))

    def release(self, adapter_id: str) -> None:
        """Legacy unpin by bare id (targets the latest version's entry)."""
        self.cache.unpin(self._vkey(adapter_id))

    def _upload(self, entry: FrozenAdapter, slot: int) -> None:
        if entry.n_layers != self.n_layers:
            raise ValueError(
                f"{entry.adapter_id} v{entry.version} has {entry.n_layers} "
                f"layers; model has {self.n_layers}")
        for target, pk in entry.packs.items():
            combined = (pk["a_scale"] * pk["b_scale"]
                        * np.float32(self.spec.scaling))
            dev = self.pack[target]
            dev["a"] = dev["a"].at[:, slot].set(jnp.asarray(pk["a_codes"]))
            dev["b"] = dev["b"].at[:, slot].set(jnp.asarray(pk["b_codes"]))
            dev["s"] = dev["s"].at[:, slot].set(jnp.asarray(combined))

    # -- stats -----------------------------------------------------------------
    def stats(self) -> Dict[str, float]:
        st = self.cache.stats()
        st["registered"] = len(self.registry)
        return st
