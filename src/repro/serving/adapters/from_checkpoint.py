"""train → freeze → register: deploy a QLoRA training run as a tenant.

Closes the loop between the training stack and the multi-tenant serving
stack. A ``mode="qlora"`` model trains float master LoRA leaves inside the
scan-stacked param tree (``params["layers"][group][target]["lora"]`` with
``a: (L, K, r)`` / ``b: (L, r, N)`` — exactly the stack shape
`AdapterRegistry.register` freezes). These helpers extract those stacks
from a live tree or a saved checkpoint and push them through
``freeze_adapter`` into the registry, where the serving runtime's SRAM
cache and the tiered store take over.

The registry's `AdapterSpec` must agree with the training run's
``cfg.lora`` (same rank and targets) — `register` validates rank and
packing divisibility, and `lora_stacks_from_params` fails loudly when a
target has no trained leaves.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import numpy as np

from repro.ckpt import checkpoint as ckpt_mod
from repro.serving.adapters.registry import (AdapterRegistry, AdapterSpec,
                                             FrozenAdapter, TARGET_GROUP)


def lora_stacks_from_params(params: Dict[str, Any], spec: AdapterSpec
                            ) -> Dict[str, Dict[str, np.ndarray]]:
    """Float master LoRA stacks ``{target: {"a": (L, K, r), "b": (L, r, N)}}``
    pulled from a qlora-mode param tree, host-side."""
    stacks: Dict[str, Dict[str, np.ndarray]] = {}
    for target in spec.targets:
        group = TARGET_GROUP[target]
        node = params["layers"].get(group, {}).get(target, {})
        lora = node.get("lora") if isinstance(node, dict) else None
        if not lora:
            raise KeyError(
                f"params carry no trained LoRA leaves for target {target!r} "
                "(expected params['layers'][group][target]['lora']) — was "
                "the checkpoint trained with mode='qlora' and cfg.lora."
                f"targets including {target!r}?")
        stacks[target] = {"a": np.asarray(lora["a"]),
                          "b": np.asarray(lora["b"])}
    return stacks


def register_from_params(registry: AdapterRegistry, params: Dict[str, Any],
                         adapter_id: str) -> FrozenAdapter:
    """Freeze a qlora param tree's LoRA leaves into ``registry`` as the
    next version of ``adapter_id`` (TOM's deployment step: float masters →
    packed 2-bit ternary SRAM pack)."""
    return registry.register(
        adapter_id, lora_stacks_from_params(params, registry.spec))


def register_from_checkpoint(registry: AdapterRegistry, ckpt_dir: str,
                             adapter_id: str, params_like: Dict[str, Any],
                             step: Optional[int] = None) -> FrozenAdapter:
    """Load a qlora training checkpoint (latest step by default) and
    register its adapter. ``params_like`` is a same-structure qlora param
    tree (e.g. a fresh ``model.init``) — the checkpoint layer restores
    leaves by tree position, CRC-checked."""
    if step is None:
        step = ckpt_mod.latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir!r}")
    state, _ = ckpt_mod.restore(ckpt_dir, step, {"params": params_like})
    return register_from_params(registry, state["params"], adapter_id)
