"""Multi-tenant QLoRA adapter serving: registry (versioned frozen ternary
adapters), SRAM-budget cache (byte-accounted LRU with pinning), and the
device runtime that stacks resident adapters for the batched SGMV decode
path (see runtime.py for the dataflow)."""
from repro.serving.adapters.cache import AdapterCache
from repro.serving.adapters.from_checkpoint import (lora_stacks_from_params,
                                                    register_from_checkpoint,
                                                    register_from_params)
from repro.serving.adapters.registry import (AdapterRegistry, AdapterSpec,
                                             FrozenAdapter,
                                             synthetic_adapter_stacks,
                                             target_dims)
from repro.serving.adapters.runtime import AdapterServing

__all__ = ["AdapterCache", "AdapterRegistry", "AdapterServing", "AdapterSpec",
           "FrozenAdapter", "lora_stacks_from_params",
           "register_from_checkpoint", "register_from_params",
           "synthetic_adapter_stacks", "target_dims"]
