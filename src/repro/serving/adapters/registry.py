"""Adapter registry: versioned frozen ternary QLoRA adapters per tenant.

TOM's hybrid ROM-SRAM split amortizes one immutable ternary base (ROM) over
many tenants, each owning a small tunable adapter in SRAM. The registry is
the control plane for those adapters: `register` takes a tenant's *float
master* A/B stacks (one (K, r)/(r, N) pair per scanned layer per target
projection, the shape `core/qlora.init_adapter` trains), freezes them to
2-bit ternary through `qlora.freeze_adapter` — exactly the deployment pack
the paper ships to SRAM — and files them under ``adapter_id`` with a
monotonically growing version (re-registering the same id is a fine-tune
update; old versions stay addressable for rollback).

Byte accounting uses `qlora.adapter_bytes`, which matches the packed array
sizes exactly (codes + one f32 scale per tensor); the SRAM-budget cache
(cache.py) evicts against that number.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import qlora

#: projection name → parameter group inside a scanned layer
TARGET_GROUP = {"q": "attn", "k": "attn", "v": "attn", "o": "attn",
                "up": "ffn", "gate": "ffn", "down": "ffn"}


@dataclasses.dataclass(frozen=True)
class AdapterSpec:
    """Shared shape contract for every adapter served by one runtime (the
    device-side stacks are homogeneous, like TOM's fixed SRAM adapter slots)."""
    rank: int = 16
    alpha: float = 32.0
    targets: Tuple[str, ...] = ("q", "v")

    @property
    def scaling(self) -> float:
        return self.alpha / self.rank

    @property
    def lora_spec(self) -> qlora.LoRASpec:
        return qlora.LoRASpec(rank=self.rank, alpha=self.alpha, ternary=True)


def target_dims(cfg, target: str) -> Tuple[int, int]:
    """(K, N) of projection ``target`` in one layer of ``cfg``."""
    dims = {
        "q": (cfg.d_model, cfg.q_dim),
        "k": (cfg.d_model, cfg.kv_dim),
        "v": (cfg.d_model, cfg.kv_dim),
        "o": (cfg.q_dim, cfg.d_model),
        "up": (cfg.d_model, cfg.d_ff),
        "gate": (cfg.d_model, cfg.d_ff),
        "down": (cfg.d_ff, cfg.d_model),
    }
    if target not in dims:
        raise KeyError(f"unknown adapter target {target!r}")
    return dims[target]


@dataclasses.dataclass
class FrozenAdapter:
    """One tenant fine-tune in its deployable (packed 2-bit) form."""
    adapter_id: str
    version: int
    spec: AdapterSpec
    # target → {a_codes (L,K//4,r) u8, a_scale (L,) f32, b_codes (L,r//4,N), b_scale (L,)}
    packs: Dict[str, Dict[str, np.ndarray]]
    nbytes: int
    n_layers: int


class AdapterRegistry:
    """Register / version / look up frozen adapters by ``adapter_id``."""

    def __init__(self, spec: AdapterSpec):
        if spec.rank % 4:
            raise ValueError(f"rank {spec.rank} must be divisible by 4 "
                             "(2-bit packing along the contracting axis)")
        for t in spec.targets:
            if t not in TARGET_GROUP:
                raise KeyError(f"unknown adapter target {t!r}")
        self.spec = spec
        self._versions: Dict[str, List[FrozenAdapter]] = {}

    # -- write side -----------------------------------------------------------
    def register(self, adapter_id: str,
                 stacks: Dict[str, Dict[str, jnp.ndarray]]) -> FrozenAdapter:
        """Freeze float master stacks ``{target: {"a": (L, K, r), "b":
        (L, r, N)}}`` to packed ternary and file them as the next version."""
        if set(stacks) != set(self.spec.targets):
            raise ValueError(f"stacks targets {sorted(stacks)} != spec "
                             f"targets {sorted(self.spec.targets)}")
        packs: Dict[str, Dict[str, np.ndarray]] = {}
        nbytes = 0
        n_layers = None
        for target, ab in stacks.items():
            a, b = np.asarray(ab["a"]), np.asarray(ab["b"])
            l, k, r = a.shape
            if r != self.spec.rank or b.shape[1] != self.spec.rank:
                raise ValueError(f"{adapter_id}/{target}: rank {r} != spec "
                                 f"rank {self.spec.rank}")
            if k % 4:
                raise ValueError(f"{adapter_id}/{target}: K={k} not "
                                 "divisible by 4")
            if n_layers is None:
                n_layers = l
            elif l != n_layers:
                raise ValueError(f"{adapter_id}: inconsistent layer counts")
            a_codes, a_scale, b_codes, b_scale = [], [], [], []
            for li in range(l):
                frozen = qlora.freeze_adapter({"a": jnp.asarray(a[li]),
                                               "b": jnp.asarray(b[li])})
                a_codes.append(np.asarray(frozen["a"].packed))
                a_scale.append(float(frozen["a"].scale))
                b_codes.append(np.asarray(frozen["b"].packed))
                b_scale.append(float(frozen["b"].scale))
            packs[target] = {
                "a_codes": np.stack(a_codes),
                "a_scale": np.asarray(a_scale, np.float32),
                "b_codes": np.stack(b_codes),
                "b_scale": np.asarray(b_scale, np.float32),
            }
            nbytes += l * qlora.adapter_bytes(k, b.shape[2], self.spec.lora_spec)
        versions = self._versions.setdefault(adapter_id, [])
        entry = FrozenAdapter(adapter_id, len(versions) + 1, self.spec, packs,
                              nbytes, n_layers or 0)
        versions.append(entry)
        return entry

    # -- read side ------------------------------------------------------------
    def __contains__(self, adapter_id: str) -> bool:
        return adapter_id in self._versions

    def __len__(self) -> int:
        return len(self._versions)

    def ids(self) -> List[str]:
        return list(self._versions)

    def get(self, adapter_id: str, version: Optional[int] = None) -> FrozenAdapter:
        """Latest version by default; a specific one for rollback."""
        versions = self._versions.get(adapter_id)
        if not versions:
            raise KeyError(f"unknown adapter {adapter_id!r}")
        if version is None:
            return versions[-1]
        if not 1 <= version <= len(versions):
            raise KeyError(f"{adapter_id!r} has no version {version}")
        return versions[version - 1]


def synthetic_adapter_stacks(rng: np.random.Generator, cfg, spec: AdapterSpec,
                             n_layers: int, scale: float = 0.02
                             ) -> Dict[str, Dict[str, np.ndarray]]:
    """Random float master stacks shaped for ``cfg`` — benches and the serve
    CLI use these as stand-in tenants (B is non-zero, unlike fresh LoRA init,
    so each tenant actually shifts the logits)."""
    out = {}
    for target in spec.targets:
        k, n = target_dims(cfg, target)
        out[target] = {
            "a": rng.normal(size=(n_layers, k, spec.rank)).astype(np.float32)
            * (spec.rank ** -0.5),
            "b": rng.normal(size=(n_layers, spec.rank, n)).astype(np.float32)
            * scale,
        }
    return out
