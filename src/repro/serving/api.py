"""Unified serving API: request/sampling dataclasses + the legacy-kwarg shim.

Before this module, per-request options lived in duplicated (and drifting)
kwarg lists — ``Gateway.submit(deadline_ms=...)`` vs
``ServeEngine.submit(deadline_s=...)`` disagreed on the deadline unit, and
every new option (top-p, seeds, speculative decoding knobs) would have had
to be threaded through three signatures. Now there are exactly two frozen
value objects:

  * :class:`SamplingParams` — how tokens are drawn (temperature, top-k,
    top-p nucleus mass, optional per-request seed). Frozen, hashable,
    shareable across requests.
  * :class:`RequestSpec` — everything else about a request: generation
    budget, eos, SLO (``priority`` class + relative ``deadline_ms``),
    tenant ``adapter_id``, streaming callback.

``Gateway.submit``, ``ServeEngine.submit`` and the engine's ``Request``
consume these directly. The **deadline is defined once**: a relative
millisecond budget from submit time (``RequestSpec.deadline_ms``); the
engine derives the absolute wall-clock ``Request.deadline_s`` the scheduler
orders by. Old keyword calls still work through :func:`coerce_submit` but
raise a ``DeprecationWarning`` (the engine's legacy ``deadline_s`` kwarg is
interpreted as the absolute deadline it always was).
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Callable, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """How output tokens are drawn for one request.

    temperature  0 → greedy (top_k/top_p/seed are then irrelevant).
    top_k        keep the k highest logits (0 = full softmax).
    top_p        nucleus sampling: keep the smallest prefix of the sorted
                 distribution with cumulative probability >= top_p
                 (1.0 = disabled; the sampler is bit-identical to the
                 pre-top-p path in that case).
    seed         per-request RNG stream: draws depend only on
                 (seed, tokens-generated-so-far), so a seeded request
                 reproduces its outputs regardless of co-scheduled traffic.
    spec_k       speculative decoding: draft up to this many tokens per tick
                 from the request's own history (n-gram prompt lookup) and
                 verify them in one multi-token step (0 = off, the default).
                 Only acts when the engine was built with
                 ``spec_decode=True`` and the request is greedy or seeded —
                 outputs are token-identical to spec_k=0 either way; the
                 knob trades verify width for accept rate.
    """
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    seed: Optional[int] = None
    spec_k: int = 0

    def __post_init__(self):
        if not 0.0 < self.top_p <= 1.0:
            raise ValueError(f"top_p must be in (0, 1], got {self.top_p}")
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0, got {self.top_k}")
        if self.seed is not None and not -2**31 <= self.seed < 2**31:
            # the seed rides into the jitted sampler as int32
            raise ValueError(f"seed must fit int32, got {self.seed}")
        if not 0 <= self.spec_k <= 15:
            # verify width is pow2-bucketed; 16-wide drafts are already past
            # any plausible accept horizon for an n-gram proposer
            raise ValueError(f"spec_k must be in [0, 15], got {self.spec_k}")


@dataclasses.dataclass(frozen=True)
class RequestSpec:
    """Per-request serving options (everything that is not sampling).

    deadline_ms is the SLO budget **relative to submit time** in
    milliseconds — the single deadline representation across the stack
    (the old Gateway ``deadline_ms``/engine ``deadline_s`` split is gone).
    """
    max_new_tokens: int = 32
    eos_id: Optional[int] = None
    priority: int = 1                 # lower = more urgent (0: interactive)
    deadline_ms: Optional[float] = None
    adapter_id: Optional[str] = None  # tenant fine-tune (serving/adapters/)
    stream_cb: Optional[Callable] = None   # cb(req, token) per output token


_SAMPLING_KEYS = ("temperature", "top_k", "top_p", "seed")
_SPEC_KEYS = ("max_new_tokens", "eos_id", "priority", "adapter_id",
              "stream_cb", "deadline_ms")
_LEGACY_KEYS = frozenset(_SAMPLING_KEYS + _SPEC_KEYS + ("deadline_s",))


def coerce_submit(spec: Optional[RequestSpec],
                  sampling: Optional[SamplingParams],
                  legacy: dict) -> Tuple[RequestSpec, SamplingParams,
                                         Optional[float]]:
    """Normalize a ``submit()`` call to (spec, sampling, absolute_deadline_s).

    ``legacy`` holds old-style keyword arguments; a non-empty dict raises a
    ``DeprecationWarning`` and is folded into fresh dataclasses. The third
    return is only non-None for the engine's legacy ``deadline_s`` kwarg
    (which was always an absolute ``time.time()`` deadline).
    """
    deadline_s = None
    unknown = set(legacy) - _LEGACY_KEYS
    if unknown:
        raise TypeError(f"unknown submit() arguments: {sorted(unknown)}")
    if spec is not None and not isinstance(spec, RequestSpec):
        raise TypeError(
            f"spec must be a RequestSpec, got {type(spec).__name__} "
            "(the old positional submit(prompt, max_new_tokens, ...) form "
            "is gone — pass RequestSpec(max_new_tokens=...))")
    if sampling is not None and not isinstance(sampling, SamplingParams):
        raise TypeError(
            f"sampling must be SamplingParams, got {type(sampling).__name__}")
    if any(v is not None for v in legacy.values()):
        if spec is not None or sampling is not None:
            raise TypeError(
                "pass RequestSpec/SamplingParams or legacy keywords, not both")
        warnings.warn(
            "submit(**kwargs) is deprecated: pass spec=RequestSpec(...) and "
            "sampling=SamplingParams(...) instead (deadlines are "
            "RequestSpec.deadline_ms, relative to submit)",
            DeprecationWarning, stacklevel=3)
        sampling = SamplingParams(**{k: legacy[k] for k in _SAMPLING_KEYS
                                     if legacy.get(k) is not None})
        spec = RequestSpec(**{k: legacy[k] for k in _SPEC_KEYS
                              if legacy.get(k) is not None})
        if legacy.get("deadline_s") is not None:
            deadline_s = float(legacy["deadline_s"])
    return (spec if spec is not None else RequestSpec(),
            sampling if sampling is not None else SamplingParams(),
            deadline_s)
