"""Sharded replica construction: mesh-placed engines for the fleet router.

TOM's architecture is distributed by construction — ternary ROM banks
co-located with the processing lanes, KV tiles in per-lane SRAM. The jax
mapping: each serving replica owns a ``(data=1, model=tp)`` submesh cut
from the host's device grid, with

  * **base params** placed by `models/sharding.param_spec_tree` (paper-tree
    strategy: contracting dim over the ``model`` lanes — Fig 7a),
  * **paged KV pool** sharded over its *pages* axis — pages play the
    context role, so lanes each hold a slice of the pooled SRAM tiles
    (`kv_cache_spec_tree`'s context rule, transposed to pool layout),
  * **dense caches** placed by `kv_cache_spec_tree` directly,
  * **adapter stacks** replicated (they are SRAM-budget-bounded and
    gathered per slot inside the decode — sharding the stack would turn
    the SGMV gather into cross-lane traffic).

Every spec passes through `fit_spec`, so axes that don't divide a tiny
test shape degrade to replication instead of erroring — a tp=1 replica on
one CPU device is the identity placement, which is exactly what the
sharded↔single-device token-identity lane asserts.

Replicas beyond the device-row count reuse rows round-robin: ``--replicas
2`` on a 1-device host builds two engines time-sharing one chip —
correctness (and the router's behavior) is unchanged, only the parallel
speedup is gone.
"""
from __future__ import annotations

from typing import Any, List

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.launch.mesh import make_host_mesh
from repro.models.sharding import (fit_spec, kv_cache_spec_tree,
                                   param_spec_tree, to_named)

Params = Any


def fleet_mesh(tp: int = 1) -> Mesh:
    """All visible devices as one (data, model) grid — the canvas replica
    submeshes are cut from."""
    return make_host_mesh(model=tp)


def replica_meshes(n_replicas: int, tp: int = 1) -> List[Mesh]:
    """One ``(data=1, model=tp)`` submesh per replica, row-sliced from the
    fleet mesh (round-robin reuse when replicas outnumber rows)."""
    assert n_replicas >= 1
    rows = fleet_mesh(tp).devices.reshape(-1, tp)
    return [Mesh(rows[r % rows.shape[0]][None, :], ("data", "model"))
            for r in range(n_replicas)]


def shard_params(params: Params, mesh: Mesh, *,
                 strategy: str = "paper_tree") -> Params:
    """device_put the param tree onto ``mesh`` under the named spec tree
    (explicit input shardings — jit then compiles against these placements
    instead of inferring them)."""
    specs = param_spec_tree(params, mesh, strategy=strategy, mode="serve")
    return jax.device_put(params, to_named(specs, mesh))


def pool_spec(pool, mesh: Mesh) -> P:
    """PartitionSpec for the paged pool's ``(L, pages, Hkv, page, D)``
    arrays: pages over the ``model`` lanes (the context dim of the paper's
    per-lane SRAM tiling). `fit_spec` drops the axis when the page count
    doesn't divide — tiny test pools simply replicate."""
    tp = "model" if "model" in mesh.axis_names else None
    return fit_spec((None, tp, None, None, None), pool.k.shape, mesh)


def shard_engine(engine, mesh: Mesh):
    """Place one engine's device state onto ``mesh`` with explicit
    shardings: params by the paper-tree spec, KV storage by the cache/pool
    spec, adapter stacks replicated. Stamps ``engine.mesh`` and invalidates
    the engine's installed multi-tenant param tree so the next
    ``_effective_params()`` grafts adapters onto the *sharded* base.
    Returns the engine (mutated in place)."""
    engine.params = shard_params(engine.params, mesh)
    if engine.kv.supports_paging:
        sh = NamedSharding(mesh, pool_spec(engine.pool, mesh))
        engine.pool.k = jax.device_put(engine.pool.k, sh)
        engine.pool.v = jax.device_put(engine.pool.v, sh)
    elif engine.cache is not None:
        cache = engine.kv.cache
        specs = kv_cache_spec_tree(cache, mesh)
        flat_c, treedef = jax.tree.flatten(cache)
        flat_s, _ = jax.tree.flatten(specs,
                                     is_leaf=lambda x: isinstance(x, P))
        shardings = jax.tree.unflatten(treedef, [
            NamedSharding(mesh, fit_spec(tuple(s), leaf.shape, mesh))
            for leaf, s in zip(flat_c, flat_s)])
        engine.kv.cache = jax.device_put(cache, shardings)
    if engine.adapters is not None:
        rep = NamedSharding(mesh, P())
        for pack in engine.adapters.pack.values():
            for k in list(pack):
                pack[k] = jax.device_put(pack[k], rep)
        engine._mt_params = None
        engine._mt_version = -1
    engine.mesh = mesh
    return engine
