"""Live roofline profiler for the serving loop's compiled functions.

A `ProfileRegistry` rides the engine's ``_dispatch`` probe: every device
dispatch of a jitted serving callable (fresh/resume prefill per pow2
bucket, the decode tick, ``verify_step`` per spec width, the samplers) is
timed to completion (``block_until_ready``) and keyed by
``(function, argument-shape signature)`` — one record per compiled
executable. On a record's first dispatch the registry AOT-lowers the same
call (``fn.lower(...).compile()``) and runs the full cost capture:

  * the **loop-weighted structural HLO pass** (`launch.hlo_analysis`) —
    the FLOP/byte source of truth (``cost_analysis()`` counts a
    scan-over-layers body once; the structural pass multiplies by trip
    count);
  * XLA's own ``cost_analysis()`` / ``memory_analysis()`` as the
    cross-check columns (``xla_flops`` / ``xla_bytes`` / peak temp bytes).

Combining captured FLOPs/bytes with measured mean wall time yields achieved
FLOP/s and GB/s and a roofline placement against `repro.obs.hardware`
peaks: operational intensity vs the ridge point classifies each function as
memory- or compute-bound, and ``pct_of_roof`` says how far it sits under
the attainable roof at that intensity. Calls that triggered a jit compile
are excluded from the wall-time mean (tracing+XLA time is not kernel time)
but counted per record — ``report()`` ranks the top recompile offenders.

Everything is opt-in (``ServeEngine(profiler=...)``) and fails soft: on
backends without the introspection APIs a record degrades to measured wall
time with ``bound="unknown"``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

from repro.obs.hardware import HardwareSpec, detect
from repro.serving.obs.tracer import CompileWatch


def shape_sig(args) -> str:
    """Canonical argument-shape signature (shared with CompileWatch)."""
    return CompileWatch._shapes(args)


@dataclasses.dataclass
class FnProfile:
    """One compiled executable: (function name, shape signature)."""
    name: str
    signature: str
    calls: int = 0              # dispatches timed (compile calls excluded)
    compiles: int = 0           # jit cache growth events for this key
    wall_s: float = 0.0         # summed blocked wall time of timed calls
    analyzed: bool = False      # AOT capture attempted (once per key)
    capture_error: Optional[str] = None
    flops: float = 0.0          # loop-weighted structural FLOPs
    bytes: float = 0.0          # loop-weighted structural HBM-traffic proxy
    xla_flops: float = 0.0      # cost_analysis() cross-check (once-counted)
    xla_bytes: float = 0.0
    memory: Dict[str, int] = dataclasses.field(default_factory=dict)

    @property
    def mean_s(self) -> float:
        return self.wall_s / self.calls if self.calls else 0.0

    @property
    def flops_xla_ratio(self) -> float:
        """structural / XLA FLOPs — ≈ the scan trip count for loop-heavy
        graphs, ≈ 1.0 for loop-free ones (the cross-check agreement band)."""
        return self.flops / self.xla_flops if self.xla_flops else 0.0


def classify(flops: float, nbytes: float, mean_s: float,
             hw: HardwareSpec) -> Dict[str, Any]:
    """Roofline placement for one (flops, bytes, measured-time) triple.

    Pure math on synthetic or captured numbers — no jax. ``bound`` is set
    by operational intensity vs the ridge point; ``pct_of_roof`` compares
    achieved FLOP/s against the attainable roof at that intensity (for
    memory-bound kernels that equals achieved-vs-peak bandwidth).
    """
    if flops <= 0.0 and nbytes <= 0.0:
        return {"intensity": 0.0, "bound": "unknown", "pct_of_roof": 0.0,
                "achieved_gflops": 0.0, "achieved_gbs": 0.0,
                "peak_gflops": hw.peak_flops / 1e9, "peak_gbs": hw.hbm_bw / 1e9}
    intensity = flops / nbytes if nbytes else float("inf")
    bound = "memory" if intensity < hw.ridge_intensity else "compute"
    achieved_fs = flops / mean_s if mean_s else 0.0
    achieved_bs = nbytes / mean_s if mean_s else 0.0
    if flops > 0.0:
        roof = hw.roof_flops(intensity)
        pct = achieved_fs / roof if roof else 0.0
    else:   # pure data movement: roof is the bandwidth peak
        pct = achieved_bs / hw.hbm_bw
    return {
        "intensity": intensity if intensity != float("inf") else 0.0,
        "bound": bound,
        "pct_of_roof": pct,
        "achieved_gflops": achieved_fs / 1e9,
        "achieved_gbs": achieved_bs / 1e9,
        "peak_gflops": hw.peak_flops / 1e9,
        "peak_gbs": hw.hbm_bw / 1e9,
    }


class ProfileRegistry:
    """Per-compiled-function cost/time registry fed by ``_dispatch``."""

    def __init__(self, hw: Optional[HardwareSpec] = None,
                 capture: bool = True):
        self.hw = hw if hw is not None else detect()
        self.capture = capture      # False: wall-time only (skip AOT lowers)
        self.records: Dict[Tuple[str, str], FnProfile] = {}

    # -- ingestion (engine hooks) -------------------------------------------
    def observe_call(self, name: str, fn, args, kwargs, dt: float,
                     compiled: bool = False) -> None:
        """One blocked dispatch of ``fn`` (a CompileWatch or jit callable).
        ``compiled=True`` marks a call that grew the jit cache: its wall
        time is compile+trace, so it bumps the offender counter instead of
        the timing mean. First sight of a key runs the AOT cost capture."""
        rec = self._rec(name, shape_sig(args))
        if compiled:
            rec.compiles += 1
        else:
            rec.calls += 1
            rec.wall_s += dt
        if self.capture and not rec.analyzed:
            self._capture(rec, fn, args, kwargs)

    def _rec(self, name: str, sig: str) -> FnProfile:
        key = (name, sig)
        rec = self.records.get(key)
        if rec is None:
            rec = self.records[key] = FnProfile(name=name, signature=sig)
        return rec

    def register_compiled(self, name: str, args, compiled) -> FnProfile:
        """Adopt an executable that was AOT-compiled *outside* the dispatch
        probe (``ServeEngine.warmup_aot``'s ``lower(...).compile()`` bucket
        products). The record is keyed exactly as ``observe_call`` would key
        the live dispatches of that executable, so warmup-built prefill
        buckets keep full roofline attribution — cost stats harvest from the
        compiled object directly (it has no ``.lower`` to re-probe)."""
        rec = self._rec(name, shape_sig(args))
        if self.capture and not rec.analyzed:
            rec.analyzed = True
            self._harvest(rec, compiled)
        return rec

    def _capture(self, rec: FnProfile, fn, args, kwargs) -> None:
        """AOT-lower the call and harvest cost/memory/structural stats.
        Runs once per record; any failure is recorded and never retried."""
        rec.analyzed = True
        try:
            inner = getattr(fn, "_fn", fn)      # unwrap CompileWatch
            compiled = inner.lower(*args, **kwargs).compile()
        except Exception as e:                  # pragma: no cover - backend-dep
            rec.capture_error = repr(e)
            return
        self._harvest(rec, compiled)

    def _harvest(self, rec: FnProfile, compiled) -> None:
        """Fill a record's cost/memory columns from a compiled executable."""
        from repro.launch import hlo_analysis
        try:
            info = hlo_analysis.analyze_compiled(compiled)
        except Exception as e:                  # pragma: no cover - backend-dep
            rec.capture_error = repr(e)
            return
        rec.flops = float(info.get("flops", 0.0))
        rec.bytes = float(info.get("bytes", 0.0))
        rec.xla_flops = float(info.get("xla_flops", 0.0))
        rec.xla_bytes = float(info.get("xla_bytes", 0.0))
        rec.memory = dict(info.get("memory", {}))

    # -- reporting ----------------------------------------------------------
    def function_rows(self) -> List[Dict[str, Any]]:
        """One roofline row per compiled executable, heaviest first."""
        rows = []
        for rec in self.records.values():
            roof = classify(rec.flops, rec.bytes, rec.mean_s, self.hw)
            rows.append({
                "fn": rec.name,
                "signature": rec.signature,
                "calls": rec.calls,
                "compiles": rec.compiles,
                "mean_ms": rec.mean_s * 1e3,
                "total_s": rec.wall_s,
                "flops": rec.flops,
                "bytes": rec.bytes,
                "xla_flops": rec.xla_flops,
                "xla_bytes": rec.xla_bytes,
                "flops_xla_ratio": rec.flops_xla_ratio,
                "memory": rec.memory,
                "capture_error": rec.capture_error,
                **roof,
            })
        rows.sort(key=lambda r: r["total_s"], reverse=True)
        return rows

    def recompile_offenders(self, top: int = 8) -> List[Dict[str, Any]]:
        offenders = [{"fn": r.name, "signature": r.signature,
                      "compiles": r.compiles}
                     for r in self.records.values() if r.compiles]
        offenders.sort(key=lambda r: r["compiles"], reverse=True)
        return offenders[:top]

    def report(self) -> Dict[str, Any]:
        return {
            "hardware": self.hw.to_dict(),
            "functions": self.function_rows(),
            "recompile_offenders": self.recompile_offenders(),
        }


def attribution_report(gateway, profiler: Optional[ProfileRegistry] = None
                       ) -> Dict[str, Any]:
    """The merged performance-attribution report serve.py ``--profile-out``
    and both benches emit: per-compiled-function roofline table + per-phase
    SLO breakdown + top recompile offenders + host-overhead context for
    ``tick_gap_ms`` (the %-of-tick number the async runtime must beat)."""
    stats = gateway.engine.stats
    report: Dict[str, Any] = {
        "slo": gateway.slo_report(),
        "host_overhead": {
            "tick_gap_ms_mean": round(stats.tick_gap_ms_mean, 4),
            "frac_of_tick": round(stats.host_overhead_frac, 4),
        },
    }
    if profiler is None:
        profiler = getattr(gateway.engine, "profiler", None)
    if profiler is not None:
        report.update(profiler.report())
    return report


#: roofline-row keys every report row must carry (CI schema validation)
_ROW_KEYS = ("fn", "signature", "calls", "compiles", "mean_ms", "flops",
             "bytes", "intensity", "bound", "pct_of_roof",
             "achieved_gflops", "peak_gflops", "achieved_gbs", "peak_gbs")


def validate_report(report: Dict[str, Any]) -> Dict[str, int]:
    """Schema check for a ``ProfileRegistry.report()`` (or the merged bench
    attribution block that embeds one). Raises AssertionError on the first
    violation; returns summary counts. Used by tests and the CI smoke."""
    assert isinstance(report, dict), "report must be a dict"
    hw = report.get("hardware")
    assert isinstance(hw, dict) and hw.get("peak_flops", 0) > 0, \
        f"bad hardware spec: {hw!r}"
    fns = report.get("functions")
    assert isinstance(fns, list), "functions must be a list"
    for row in fns:
        for key in _ROW_KEYS:
            assert key in row, f"roofline row missing {key!r}: {row}"
        assert row["bound"] in ("memory", "compute", "unknown"), \
            f"bad bound {row['bound']!r}"
        assert row["pct_of_roof"] >= 0.0
    for off in report.get("recompile_offenders", ()):
        assert off.get("compiles", 0) >= 1, f"non-offender listed: {off}"
    slo = report.get("slo")
    if slo is not None:     # merged attribution block: SLO section schema
        assert isinstance(slo.get("phases"), dict), f"bad slo.phases: {slo}"
        for phase, row in slo["phases"].items():
            assert "p95_ms" in row, f"slo phase {phase} missing p95_ms"
        assert isinstance(slo.get("violations"), dict)
        assert slo.get("violations_total", 0) >= \
            sum(slo["violations"].values()) or not slo["violations"]
    return {"functions": len(fns),
            "offenders": len(report.get("recompile_offenders", ()))}
