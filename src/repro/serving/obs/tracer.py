"""Chrome ``trace_event`` tracer for the serving tick loop.

One `Tracer` records three kinds of activity:

  * **phase spans** (`span`): nested complete ("X") events on a per-engine
    "tick" track — the engine wraps each tick and its phases (schedule /
    prefill_chunk / decode / spec_verify / sample / commit / emit) so a
    captured trace shows exactly where a tick's time goes;
  * **request lifecycle tracks** (`lifecycle`): each request uid gets its
    own track; every state (queued → prefilling → decoding) is one "X"
    span from state entry to exit, terminal states (done / cancelled /
    expired) and preemption edges land as instant ("i") events;
  * **instants and counters** (`instant` / `counter`): one-off markers —
    the engine's jit-recompile events (with the offending shape bucket)
    and the ``tick_gap_ms`` counter series ride here.

Export is the Chrome ``trace_event`` format (ts/dur in microseconds):
``dump(path)`` writes strict JSONL (one event object per line — what the
CI validity check parses) for ``*.jsonl`` paths and a
``{"traceEvents": [...]}`` JSON document (the classic Perfetto /
chrome://tracing container) for anything else. Perfetto's JSON tokenizer
accepts both. Events are sorted by timestamp at dump time, so child spans
(emitted at exit, before their parent) come out ts-monotonic.

``Tracer(ring=N)`` keeps only the newest N events (metadata and still-open
lifecycle state survive eviction), so long soaks stay bounded.
``Tracer(enabled=False)`` — the engine default — is a null object: every
`span()` call returns one shared no-op context manager and nothing is
allocated or recorded.

`CompileWatch` wraps a jitted callable and reports cache growth: every
compile (including the first) bumps a counter and emits an instant event
naming the argument shape bucket that triggered it — the recompile-stall
signal for the AOT-warmup roadmap item.
"""
from __future__ import annotations

import collections
import itertools
import json
import threading
import time
from typing import Any, Callable, Dict, List, Optional

#: Request lifecycle states that end a request's track.
TERMINAL_STATES = ("done", "cancelled", "expired", "rejected")

#: tid of the engine's tick/phase track inside its process group.
TICK_TID = 0


class _NullSpan:
    """Shared no-op context manager handed out by a disabled tracer."""
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class _Span:
    """Open span: records one complete ("X") event when it exits."""
    __slots__ = ("tracer", "name", "pid", "tid", "args", "t0")

    def __init__(self, tracer: "Tracer", name: str, pid: int, tid: int,
                 args: Optional[Dict[str, Any]]):
        self.tracer = tracer
        self.name = name
        self.pid = pid
        self.tid = tid
        self.args = args
        self.t0 = tracer._now_us()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        t1 = self.tracer._now_us()
        self.tracer._push({"ph": "X", "name": self.name, "cat": "phase",
                           "ts": self.t0, "dur": t1 - self.t0,
                           "pid": self.pid, "tid": self.tid,
                           **({"args": self.args} if self.args else {})})
        return False


class Tracer:
    def __init__(self, enabled: bool = True, ring: Optional[int] = None,
                 clock: Callable[[], float] = time.perf_counter):
        self.enabled = enabled
        self.ring = ring
        self._clock = clock
        self._t0 = clock()
        # ring=N keeps the newest N events; metadata (process/thread names)
        # lives separately so Perfetto track names survive eviction
        self.events: "collections.deque" = collections.deque(maxlen=ring)
        self._meta: List[Dict[str, Any]] = []
        self._pids = itertools.count(1)
        self._proc_names: Dict[int, str] = {}
        # per-(pid, uid) open lifecycle state: state name + entry ts
        self._open_life: Dict[tuple, tuple] = {}
        self._named_tids: set = set()

    # -- clock / storage ----------------------------------------------------
    def _now_us(self) -> float:
        return (self._clock() - self._t0) * 1e6

    def _push(self, evt: Dict[str, Any]) -> None:
        self.events.append(evt)

    # -- track registry -----------------------------------------------------
    def register(self, name: str) -> int:
        """Allocate a process group (one per engine) so several traced
        engines in one process don't interleave their tick tracks."""
        pid = next(self._pids)
        self._proc_names[pid] = name
        self._meta.append({"ph": "M", "name": "process_name", "pid": pid,
                           "tid": 0, "ts": 0, "args": {"name": name}})
        self._meta.append({"ph": "M", "name": "thread_name", "pid": pid,
                           "tid": TICK_TID, "ts": 0, "args": {"name": "tick"}})
        return pid

    def _name_tid(self, pid: int, tid: int, name: str) -> None:
        if (pid, tid) not in self._named_tids:
            self._named_tids.add((pid, tid))
            self._meta.append({"ph": "M", "name": "thread_name", "pid": pid,
                               "tid": tid, "ts": 0, "args": {"name": name}})

    # -- recording ----------------------------------------------------------
    def span(self, name: str, pid: int = 1, tid: int = TICK_TID,
             **args):
        """Context manager recording a complete event on exit. Disabled
        tracers return one shared no-op singleton (nothing allocated)."""
        if not self.enabled:
            return _NULL_SPAN
        return _Span(self, name, pid, tid, args or None)

    def instant(self, name: str, pid: int = 1, tid: int = TICK_TID,
                **args) -> None:
        if not self.enabled:
            return
        self._push({"ph": "i", "name": name, "cat": "event", "s": "t",
                    "ts": self._now_us(), "pid": pid, "tid": tid,
                    **({"args": args} if args else {})})

    def counter(self, name: str, value: float, pid: int = 1) -> None:
        if not self.enabled:
            return
        self._push({"ph": "C", "name": name, "cat": "counter",
                    "ts": self._now_us(), "pid": pid, "tid": TICK_TID,
                    "args": {name: round(float(value), 4)}})

    def lifecycle(self, uid: int, state: str, pid: int = 1, **args) -> None:
        """Advance request ``uid``'s lifecycle track: the previous state is
        closed as an "X" span covering its whole duration; terminal states
        and one-off edges (``preempt``) additionally land as instants."""
        if not self.enabled:
            return
        now = self._now_us()
        key = (pid, uid)
        self._name_tid(pid, uid, f"req-{uid}")
        prev = self._open_life.pop(key, None)
        if prev is not None:
            pstate, pt0 = prev
            self._push({"ph": "X", "name": pstate, "cat": "request",
                        "ts": pt0, "dur": max(now - pt0, 0.0),
                        "pid": pid, "tid": uid})
        if state in TERMINAL_STATES or state == "preempt":
            self._push({"ph": "i", "name": state, "cat": "request", "s": "t",
                        "ts": now, "pid": pid, "tid": uid,
                        **({"args": args} if args else {})})
            if state == "preempt":         # preempted → back in the queue
                self._open_life[key] = ("queued", now)
        else:
            self._open_life[key] = (state, now)

    # -- export -------------------------------------------------------------
    def to_events(self) -> List[Dict[str, Any]]:
        """Metadata + recorded events + auto-closed open lifecycle spans,
        sorted by timestamp (metadata first) — a self-contained snapshot."""
        now = self._now_us()
        tail = [{"ph": "X", "name": state, "cat": "request", "ts": t0,
                 "dur": max(now - t0, 0.0), "pid": pid, "tid": uid}
                for (pid, uid), (state, t0) in self._open_life.items()]
        body = sorted(list(self.events) + tail, key=lambda e: e["ts"])
        return list(self._meta) + body

    def dumps_jsonl(self) -> str:
        return "\n".join(json.dumps(e, separators=(",", ":"))
                         for e in self.to_events()) + "\n"

    def dump(self, path) -> None:
        """Write the trace: ``*.jsonl`` → strict JSONL (one event per
        line); anything else → ``{"traceEvents": [...]}`` JSON. Both load
        in Perfetto (ui.perfetto.dev)."""
        import os
        text = (self.dumps_jsonl() if str(path).endswith(".jsonl")
                else json.dumps({"traceEvents": self.to_events()}, indent=1))
        tmp = f"{path}.tmp"
        with open(tmp, "w") as f:
            f.write(text)
        os.replace(tmp, path)


#: Shared disabled tracer — the engine default. Never records anything.
NULL_TRACER = Tracer(enabled=False)


class CompileWatch:
    """Wrap a jitted callable; count compilations and trace their shapes.

    Detection is *shape-signature based and race-free*: each call computes
    its argument shape/dtype signature and atomically tests-and-adds it to
    a lock-protected seen-set — a signature's first caller is the compile,
    every later caller (including a concurrent one on another thread) is a
    cache hit. The earlier implementation compared the underlying jit
    cache size before/after the call, which misattributed compiles under
    threaded dispatch: two threads interleaving calls both observe the
    cache grow by someone else's entry (or neither observes its own). The
    async runtime dispatches from a worker thread while warmup/benches may
    call from the main thread, so the watch must be correct under
    concurrency. On a compile the watch bumps ``compiles``, invokes
    ``on_compile(name, shapes)`` and emits a ``jit_compile`` instant
    naming the shape bucket — shape-bucket churn that stalls a tick shows
    up in the trace exactly where the stall happened.
    """

    def __init__(self, fn: Callable, name: str, tracer: Tracer = NULL_TRACER,
                 on_compile: Optional[Callable[[str, str], None]] = None,
                 pid: int = 1):
        self._fn = fn
        self.name = name
        self.tracer = tracer
        self.on_compile = on_compile
        self.pid = pid
        self.compiles = 0
        #: whether the most recent call grew the jit cache — the engine's
        #: dispatch probe reads this so the profiler can keep compile+trace
        #: wall time out of the per-executable timing mean
        self.last_compiled = False
        self._lock = threading.Lock()
        self._seen_sigs: set = set()

    @staticmethod
    def _shapes(args) -> str:
        try:
            import jax
            leaves = jax.tree_util.tree_leaves(args)
        except Exception:
            leaves = list(args)
        out, seen = [], set()
        for leaf in leaves:
            shape = getattr(leaf, "shape", None)
            if shape is None:
                continue
            sig = "x".join(map(str, shape)) or "scalar"
            if sig not in seen:
                seen.add(sig)
                out.append(sig)
        return ",".join(out[:8]) or "scalar"

    @staticmethod
    def _sig(args, kwargs) -> str:
        """Compile-cache key approximation: arg shapes + dtypes plus the
        static kwargs (e.g. ``use_topp``/``use_seeds`` flip the compiled
        graph at identical array shapes)."""
        try:
            import jax
            leaves = jax.tree_util.tree_leaves(args)
        except Exception:
            leaves = list(args)
        parts = []
        for leaf in leaves:
            shape = getattr(leaf, "shape", None)
            if shape is None:
                # python scalars trace as weak-typed constants: the *type*
                # keys the compile cache, the value does not
                parts.append(type(leaf).__name__)
            else:
                parts.append("x".join(map(str, shape))
                             + ":" + str(getattr(leaf, "dtype", "?")))
        if kwargs:
            parts.append(repr(sorted(kwargs.items())))
        return "|".join(parts)

    def __call__(self, *args, **kwargs):
        sig = self._sig(args, kwargs)
        with self._lock:
            compiled = sig not in self._seen_sigs
            self._seen_sigs.add(sig)
            if compiled:
                self.compiles += 1
        out = self._fn(*args, **kwargs)
        self.last_compiled = compiled
        if compiled:
            shapes = self._shapes(args)
            if self.on_compile is not None:
                self.on_compile(self.name, shapes)
            self.tracer.instant("jit_compile", pid=self.pid, fn=self.name,
                                shapes=shapes)
        return out


# -- trace validation (tests + the CI smoke step) ---------------------------

def load_trace(path) -> List[Dict[str, Any]]:
    """Parse a dumped trace back to its event list (JSONL or JSON array)."""
    with open(path) as f:
        text = f.read()
    stripped = text.lstrip()
    if stripped.startswith("{") and '"traceEvents"' in stripped[:40]:
        return json.loads(stripped)["traceEvents"]
    return [json.loads(line) for line in text.splitlines() if line.strip()]


def validate_trace(path) -> Dict[str, Any]:
    """Structural validity of a dumped trace; raises AssertionError on the
    first violation, returns summary stats otherwise. Checks: every line
    parses (JSONL), required keys per event, "X" events carry a
    non-negative dur, "B"/"E" pairs match per (pid, tid), and non-metadata
    timestamps are monotonic in file order."""
    events = load_trace(path)
    assert events, f"{path}: empty trace"
    last_ts = None
    open_begins: Dict[tuple, int] = {}
    stats = {"events": 0, "tick_spans": 0, "request_spans": 0,
             "instants": 0, "counters": 0}
    for evt in events:
        ph = evt.get("ph")
        assert ph, f"event missing ph: {evt}"
        if ph == "M":
            continue
        stats["events"] += 1
        for key in ("name", "ts", "pid", "tid"):
            assert key in evt, f"event missing {key}: {evt}"
        ts = evt["ts"]
        assert last_ts is None or ts >= last_ts, \
            f"non-monotonic ts: {ts} after {last_ts}"
        last_ts = ts
        track = (evt["pid"], evt["tid"])
        if ph == "X":
            assert evt.get("dur", -1) >= 0, f"X event without dur: {evt}"
            if evt["name"] == "tick":
                stats["tick_spans"] += 1
            if evt.get("cat") == "request":
                stats["request_spans"] += 1
        elif ph == "B":
            open_begins[track] = open_begins.get(track, 0) + 1
        elif ph == "E":
            assert open_begins.get(track, 0) > 0, f"E without B: {evt}"
            open_begins[track] -= 1
        elif ph == "i":
            stats["instants"] += 1
        elif ph == "C":
            stats["counters"] += 1
    assert not any(open_begins.values()), \
        f"unmatched B events: {open_begins}"
    return stats
