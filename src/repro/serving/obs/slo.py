"""Per-request SLO latency attribution: wall time decomposed by phase.

Every request the gateway tracks gets a continuous-time state machine fed
by the engine's lifecycle hooks:

    queue_wait : submit → first admission (scheduler queue time)
    prefill    : admission → first emitted token (prompt consumption:
                 batched/chunked prefill ticks or token-mode streaming)
    decode     : steady-state emission (first token → terminal)
    decode_stall : carved out of ``decode`` — wall time this request's
                 decode batch sat blocked behind another slot's prefill
                 (the engine charges ``Request.stall_s`` per stalled slot)
    preempted  : preemption → the next emitted token after re-admission
                 (requeue wait + the replay prefill both count as
                 preemption cost, not as queue/prefill time)

Transitions telescope — each one closes the previous interval at a single
timestamp — so the components **sum exactly to the request's wall time**
(the fuzz harness asserts this every tick, for live and terminal requests
alike). Closing a request (done/cancelled/expired) freezes the
decomposition; the gateway then feeds per-phase histograms
(``slo_phase_ms__<phase>`` → p95 breakdown in the registry/Prom text) and,
for SLO-violating requests, increments ``slo_violation__<phase>`` against
the dominant phase — "why did this request miss" as a counter.
"""
from __future__ import annotations

import collections
import time
from typing import Any, Dict, Optional, Tuple

#: attribution components, in report order
PHASES = ("queue_wait", "prefill", "decode", "decode_stall", "preempted")

#: request states that freeze a track
_TERMINAL = ("done", "cancelled", "expired", "rejected")


class _Track:
    __slots__ = ("state", "t0", "t_last", "acc", "done")

    def __init__(self, t_submit: float):
        self.state = "queue_wait"
        self.t0 = t_submit
        self.t_last = t_submit
        self.acc = {p: 0.0 for p in PHASES}
        self.done = False


class SLOAttribution:
    """Lifecycle-driven per-request decomposition registry.

    All ``observe_*`` hooks are no-ops for unknown uids (requests submitted
    around the gateway) and for frozen tracks, so the gateway can wire them
    unconditionally. Closed tracks are retained (ring-capped) so terminal
    requests stay queryable for invariant checks and reports.
    """

    def __init__(self, keep: int = 4096):
        self._tracks: "collections.OrderedDict[int, _Track]" = \
            collections.OrderedDict()
        self._keep = keep
        self.closed = 0
        self.violations: Dict[str, int] = {}

    # -- lifecycle hooks ----------------------------------------------------
    def observe_submit(self, req) -> None:
        if req.uid in self._tracks:
            return
        # Request timestamps use 0.0 = "not yet set" (engine convention)
        self._tracks[req.uid] = _Track(req.t_submit or time.time())
        # bound memory on long soaks: evict oldest *frozen* tracks only
        while len(self._tracks) > self._keep:
            uid, tr = next(iter(self._tracks.items()))
            if not tr.done:
                break
            del self._tracks[uid]

    def observe_admit(self, req) -> None:
        tr = self._tracks.get(req.uid)
        if tr is None or tr.done:
            return
        if tr.state == "queue_wait":
            # re-admission after preempt stays in "preempted" (replay
            # prefill is preemption cost); only the first admission ends
            # the queue-wait interval
            self._advance(tr, "prefill", req.t_admit or time.time())

    def observe_token(self, req, now: Optional[float] = None) -> None:
        tr = self._tracks.get(req.uid)
        if tr is None or tr.done:
            return
        if tr.state != "decode":
            self._advance(tr, "decode", now if now is not None else time.time())

    def observe_preempt(self, req, now: Optional[float] = None) -> None:
        tr = self._tracks.get(req.uid)
        if tr is None or tr.done:
            return
        self._advance(tr, "preempted", now if now is not None else time.time())

    def close(self, req, now: Optional[float] = None) -> Optional[Dict[str, float]]:
        """Freeze the track at the request's terminal timestamp and return
        the final components (seconds). Idempotent."""
        tr = self._tracks.get(req.uid)
        if tr is None:
            return None
        if not tr.done:
            if now is None:
                now = req.t_done or time.time()
            self._advance(tr, None, now)
            self._carve_stall(tr.acc, req)
            tr.done = True
            self.closed += 1
        return dict(tr.acc)

    # -- queries ------------------------------------------------------------
    def snapshot(self, req, now: Optional[float] = None
                 ) -> Optional[Tuple[Dict[str, float], float]]:
        """(components, wall_s) — live view for in-flight requests, frozen
        view for terminal ones. Components always sum to wall_s."""
        tr = self._tracks.get(req.uid)
        if tr is None:
            return None
        if not tr.done and req.state in _TERMINAL:
            self.close(req)     # terminal transition the gateway missed
        if tr.done:
            return dict(tr.acc), tr.t_last - tr.t0
        if now is None:
            now = time.time()
        acc = dict(tr.acc)
        dt = max(now - tr.t_last, 0.0)
        acc[tr.state] += dt
        self._carve_stall(acc, req)
        return acc, (tr.t_last - tr.t0) + dt

    def note_violation(self, phase: str) -> None:
        self.violations[phase] = self.violations.get(phase, 0) + 1

    # -- internals ----------------------------------------------------------
    @staticmethod
    def _advance(tr: _Track, new_state: Optional[str], now: float) -> None:
        """Close the open interval at ``now`` (clock-skew clipped) and move
        to ``new_state``. Accumulation telescopes: Σ components is always
        exactly ``t_last - t0``."""
        dt = max(now - tr.t_last, 0.0)
        tr.acc[tr.state] += dt
        tr.t_last += dt
        if new_state is not None:
            tr.state = new_state

    @staticmethod
    def _carve_stall(acc: Dict[str, float], req) -> None:
        """Split the request's measured decode-stall wall time out of its
        decode interval (never out of other phases — the clamp keeps the
        sum-to-wall identity exact even if stall accounting overlaps a
        prefill-state tick)."""
        stall = min(float(getattr(req, "stall_s", 0.0)), acc["decode"])
        acc["decode"] -= stall
        acc["decode_stall"] += stall
