"""Serving observability: per-tick phase tracing, Prometheus text
exposition and live energy/power-gating gauges.

Dependency-free (stdlib + the repo's own analytical power model). Three
pieces, each usable alone:

  * `obs.tracer` — `Tracer`: nested per-tick phase spans (tick → schedule /
    prefill_chunk / decode / spec_verify / sample / commit / emit),
    per-request lifecycle tracks (queued → prefilling → decoding → done,
    with preempt/cancel edges) and jit-recompile instants, exported as
    Chrome ``trace_event`` JSON(L) loadable in Perfetto. A ring-buffer mode
    bounds memory on long soaks; disabled (the default in the engine) it
    allocates nothing per span.
  * `obs.prom` — renders the gateway `Metrics` registry in the standard
    Prometheus text exposition format (``# TYPE`` lines, cumulative
    histogram buckets incl. ``+Inf``) and writes it atomically.
  * `obs.energy` — `EnergyMonitor`: drives `core.powergate.GatingSchedule`
    from live engine state every tick (device-busy fraction, SRAM
    residency) and integrates the paper's Fig-12 power model into
    `energy_per_token_j` / `gated_bank_fraction` / `chip_power_w` gauges —
    the measurement half of the ROADMAP power-gating item.
"""
from repro.serving.obs.energy import EnergyMonitor
from repro.serving.obs.prom import render_text, write_prom
from repro.serving.obs.tracer import (NULL_TRACER, CompileWatch, Tracer,
                                      load_trace, validate_trace)

__all__ = ["CompileWatch", "EnergyMonitor", "NULL_TRACER", "Tracer",
           "load_trace", "render_text", "validate_trace", "write_prom"]
