"""Serving observability: per-tick phase tracing, Prometheus text
exposition, live energy/power-gating gauges and performance attribution.

Dependency-free (stdlib + the repo's own analytical models). Five pieces,
each usable alone:

  * `obs.tracer` — `Tracer`: nested per-tick phase spans (tick → schedule /
    prefill_chunk / decode / spec_verify / sample / commit / emit),
    per-request lifecycle tracks (queued → prefilling → decoding → done,
    with preempt/cancel edges) and jit-recompile instants, exported as
    Chrome ``trace_event`` JSON(L) loadable in Perfetto. A ring-buffer mode
    bounds memory on long soaks; disabled (the default in the engine) it
    allocates nothing per span.
  * `obs.prom` — renders the gateway `Metrics` registry in the standard
    Prometheus text exposition format (``# TYPE`` lines, cumulative
    histogram buckets incl. ``+Inf``) and writes it atomically.
  * `obs.energy` — `EnergyMonitor`: drives `core.powergate.GatingSchedule`
    from live engine state every tick (device-busy fraction, SRAM
    residency) and integrates the paper's Fig-12 power model into
    `energy_per_token_j` / `gated_bank_fraction` / `chip_power_w` gauges —
    the measurement half of the ROADMAP power-gating item.
  * `obs.profile` — `ProfileRegistry`: roofline placement for every
    compiled serving function. Rides the engine's ``_dispatch`` probe;
    captures loop-weighted structural FLOPs/bytes (cross-checked against
    XLA ``cost_analysis``/``memory_analysis``) per (fn, shape signature)
    and combines them with blocked wall times into achieved FLOP/s & GB/s
    vs the `repro.obs.hardware` peaks — memory- vs compute-bound, % of
    roof, top recompile offenders.
  * `obs.slo` — `SLOAttribution`: per-request wall-time decomposition
    (queue_wait / prefill / decode / decode_stall / preempted) whose
    components sum exactly to request wall time; the gateway turns closed
    tracks into per-phase p95 histograms and attributed
    ``slo_violation__<phase>`` counters.
"""
from repro.serving.obs.energy import EnergyMonitor
from repro.serving.obs.profile import (FnProfile, ProfileRegistry,
                                       attribution_report, classify,
                                       validate_report)
from repro.serving.obs.prom import render_text, write_prom
from repro.serving.obs.slo import PHASES as SLO_PHASES
from repro.serving.obs.slo import SLOAttribution
from repro.serving.obs.tracer import (NULL_TRACER, CompileWatch, Tracer,
                                      load_trace, validate_trace)

__all__ = ["CompileWatch", "EnergyMonitor", "FnProfile", "NULL_TRACER",
           "ProfileRegistry", "SLOAttribution", "SLO_PHASES", "Tracer",
           "attribution_report", "classify", "load_trace", "render_text",
           "validate_report", "validate_trace", "write_prom"]
