"""Prometheus text exposition for the gateway metrics registry.

Renders a `repro.serving.gateway.metrics.Metrics` registry (or its
`to_dict()` snapshot) in the standard text format — ``# TYPE`` lines,
monotonic counters, point-in-time gauges and histograms with *cumulative*
buckets including the ``+Inf`` tail plus ``_sum``/``_count`` — without any
prometheus_client dependency.

Name handling: metric names are sanitized to the legal charset
(``[a-zA-Z_:][a-zA-Z0-9_:]*``) and the registry's per-tenant convention
``base__<id>`` (e.g. ``adapter_requests__tenant-3``) is rendered as a
labeled series ``base{id="tenant-3"}`` so tenant cardinality lives in
labels, not metric names.

``write_prom`` writes atomically (temp file + ``os.replace``) so a scraper
tailing the file never sees a half-written window — this is what
``launch/serve.py --prom-out`` calls once per tick window.
"""
from __future__ import annotations

import os
import re
from typing import Dict, List, Tuple

_NAME_OK = re.compile(r"[^a-zA-Z0-9_:]")


def _sanitize(name: str) -> str:
    name = _NAME_OK.sub("_", name)
    if name and name[0].isdigit():
        name = "_" + name
    return name


def _split_label(name: str) -> Tuple[str, str]:
    """``base__value`` → (base, value); everything else → (name, "")."""
    if "__" in name:
        base, value = name.split("__", 1)
        if base and value:
            return base, value
    return name, ""


def _fmt(value: float) -> str:
    f = float(value)
    return str(int(f)) if f == int(f) and abs(f) < 1e15 else repr(f)


def render_text(metrics) -> str:
    """The registry as Prometheus text exposition format (version 0.0.4).

    ``metrics`` is a live `Metrics` registry; histogram buckets come from
    the histogram objects themselves so the cumulative counts are exact
    (the JSON snapshot also carries them since the bucket-export fix).
    """
    lines: List[str] = []

    # counters — group base__label series under one # TYPE header
    grouped: Dict[str, List[Tuple[str, float]]] = {}
    for name in sorted(metrics.counters):
        base, label = _split_label(name)
        grouped.setdefault(_sanitize(base), []).append(
            (label, metrics.counters[name]))
    for base, series in grouped.items():
        lines.append(f"# TYPE {base} counter")
        for label, value in series:
            suffix = f'{{id="{label}"}}' if label else ""
            lines.append(f"{base}{suffix} {_fmt(value)}")

    for name in sorted(metrics.gauges):
        base = _sanitize(name)
        lines.append(f"# TYPE {base} gauge")
        lines.append(f"{base} {_fmt(metrics.gauges[name])}")

    for name in sorted(metrics.histograms):
        h = metrics.histograms[name]
        base = _sanitize(name)
        lines.append(f"# TYPE {base} histogram")
        cum = 0
        for edge, count in zip(h.buckets, h.bucket_counts):
            cum += count
            lines.append(f'{base}_bucket{{le="{edge:g}"}} {cum}')
        lines.append(f'{base}_bucket{{le="+Inf"}} {h.count}')
        lines.append(f"{base}_sum {_fmt(round(h.sum, 6))}")
        lines.append(f"{base}_count {h.count}")

    return "\n".join(lines) + "\n"


def write_prom(path, text: str) -> None:
    """Atomic write: a scraper never observes a torn exposition window."""
    tmp = f"{path}.tmp"
    with open(tmp, "w") as f:
        f.write(text)
    os.replace(tmp, path)


def parse_text(text: str) -> Dict[str, Dict]:
    """Tiny parser for tests/tools: returns
    ``{metric: {"type": t, "samples": {sample_name_with_labels: value}}}``.
    Not a full OpenMetrics parser — just enough to round-trip our own
    renderer and assert counter monotonicity / bucket cumulativity."""
    out: Dict[str, Dict] = {}
    current = None
    for line in text.splitlines():
        line = line.strip()
        if not line:
            continue
        if line.startswith("# TYPE "):
            _, _, name, kind = line.split()
            current = out.setdefault(name, {"type": kind, "samples": {}})
            continue
        if line.startswith("#"):
            continue
        sample, value = line.rsplit(" ", 1)
        base = sample.split("{", 1)[0]
        root = base
        for suffix in ("_bucket", "_sum", "_count"):
            if root.endswith(suffix) and root[: -len(suffix)] in out:
                root = root[: -len(suffix)]
                break
        target = out.get(root) if root in out else current
        if target is None:
            target = out.setdefault(base, {"type": "untyped", "samples": {}})
        target["samples"][sample] = float(value)
    return out
