"""Live energy observability: integrate the paper's power model over the
serving tick stream.

TOM's third headline contribution is workload-aware power gating of the
ROM weight banks (paper §IV-E / Fig 8 / Fig 12); `core/powergate.py` has
modeled it analytically since the seed but nothing drove it from real
serving state. `EnergyMonitor` is that drive: the gateway feeds it one
observation per engine tick (device-busy time, emitted tokens, SRAM
residency, speculative verify width) and it integrates
`powergate.live_power` over wall time into three gauges:

  * ``chip_power_w``         — window-averaged chip power (EMA-smoothed);
  * ``gated_bank_fraction``  — time-averaged fraction of ROM banks gated
    off: 1.0 when idle (everything gated), dropping toward
    ``1 - powered_layer_fraction`` under full device load;
  * ``energy_per_token_j``   — integrated energy / emitted tokens over the
    recent window — the paper's efficiency axis, now measured per tick.

This is the *measurement* half of the ROADMAP power-gating item: it makes
"energy scales down with load at flat p95" observable before any control
policy exists. The model is honest about what it is — the Fig-12 silicon
numbers projected onto the live execution timeline — not a host-CPU power
meter.
"""
from __future__ import annotations

from typing import Dict

from repro.core.powergate import GatingSchedule, live_power


class EnergyMonitor:
    def __init__(self, n_layers: int, *, gating_enabled: bool = True,
                 ema: float = 0.2):
        self.schedule = GatingSchedule(n_layers=n_layers,
                                       gating_enabled=gating_enabled)
        self.ema = ema
        # cumulative integration
        self.energy_j = 0.0
        self.wall_s = 0.0
        self.tokens = 0
        self.ticks = 0
        # EMA'd window state (gauge smoothing over jittery tick walls)
        self._power_w = 0.0
        self._gated_frac = 1.0
        self._j_per_tok = 0.0

    def observe_tick(self, *, wall_s: float, busy_s: float, tokens: int,
                     sram_utilization: float = 1.0,
                     verify_width: int = 1) -> None:
        """One engine tick: ``wall_s`` host wall time since the previous
        observation, ``busy_s`` of it spent in device dispatches (decode /
        verify / prefill phases — a verify tick's S sequential steps are
        naturally S× the busy time, so speculative width feeds the energy
        account through real time, not a fudge factor), ``tokens`` emitted,
        ``sram_utilization`` the resident fraction of the SRAM budget (KV
        pool occupancy / adapter cache bytes)."""
        wall_s = max(wall_s, 1e-9)
        exec_frac = min(busy_s / wall_s, 1.0)
        report = live_power(self.schedule, exec_fraction=exec_frac,
                            sram_utilization=sram_utilization)
        self.energy_j += report.total_w * wall_s
        self.wall_s += wall_s
        self.tokens += int(tokens)
        self.ticks += 1
        powered = self.schedule.powered_layer_fraction() * exec_frac
        a = self.ema if self.ticks > 1 else 1.0
        self._power_w += a * (report.total_w - self._power_w)
        self._gated_frac += a * ((1.0 - powered) - self._gated_frac)
        if tokens > 0:
            j_tok = report.total_w * wall_s / tokens
            self._j_per_tok += a * (j_tok - self._j_per_tok)

    def gauges(self) -> Dict[str, float]:
        """The gauge triple the gateway publishes, plus the cumulative
        integrals (total joules / mean power) for bench summaries."""
        mean_w = self.energy_j / self.wall_s if self.wall_s else 0.0
        per_tok = (self.energy_j / self.tokens if self.tokens
                   else self._j_per_tok)
        return {
            "chip_power_w": round(self._power_w, 4),
            "chip_power_mean_w": round(mean_w, 4),
            "gated_bank_fraction": round(self._gated_frac, 4),
            "energy_per_token_j": round(per_tok, 6),
            "energy_total_j": round(self.energy_j, 4),
        }
