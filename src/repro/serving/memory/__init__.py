"""Unified tiered memory hierarchy (device → host RAM → disk) behind the
serving caches: the adapter SRAM cache demotes evicted packs to host, the
prefix cache spills evicted KV pages and re-admits them bit-identically,
and the scheduler's prefetch hook warms upcoming needs up the hierarchy."""
from repro.serving.memory.tiered import TieredStore

__all__ = ["TieredStore"]
