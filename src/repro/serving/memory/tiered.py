"""Tiered memory store: one device → host → disk hierarchy for cached state.

TOM's memory co-design splits state by mutability and heat — the immutable
bulk in dense ROM, the scarce tunable state in SRAM. The serving stack grew
three ad-hoc device caches in that spirit (the adapter SRAM cache, the
refcounted prefix-page trie, the KV page pool) and each treated eviction as
*loss*: an evicted adapter re-uploads from the registry, an evicted prefix
page re-runs prefill. This module generalizes the split into one explicit
hierarchy, per ROMA's ROM↔SRAM model and H2O-style importance eviction:

  * **device** — accounting-only. The bytes live in the structures that
    already own them (adapter slot stacks, the fp8 page pool); the store
    tracks which keys are device-resident and how big they are, so the
    "every entry lives in exactly one tier" invariant is checkable.
  * **host** — payloads as host numpy buffers (contiguous copies, the
    stand-in for pinned/page-locked allocations on a real accelerator
    host). A demoted device entry parks here instead of being dropped.
  * **disk** — one mmapped file per entry (header + CRC32-checksummed raw
    bytes, written atomically), so cold state survives host-budget pressure
    and a truncated/corrupt file degrades to a *miss*, never bad KV.

Eviction inside host/disk is driven by a cost model — the entry with the
lowest ``re-materialization cost × recency / bytes`` goes first, i.e. big,
stale, cheap-to-rebuild entries — and demotion cascades down the hierarchy
(host → disk → dropped) rather than discarding outright. Per-tier byte
budgets bound each level; hit/miss/promote/demote counters feed the
gateway's ``tier_*`` gauges.

Keys are plain strings; clients namespace them (``adapter:<tenant>@v<N>``,
``kv:<token,token,...>``) so one store can back every subsystem.
"""
from __future__ import annotations

import dataclasses
import hashlib
import itertools
import json
import os
import zlib
from pathlib import Path
from typing import Dict, List, Optional, Tuple

import numpy as np

Payload = Dict[str, np.ndarray]

_MAGIC = b"TMEM1\n"


def _resolve_dtype(name: str) -> np.dtype:
    """Dtype by name, reaching into ml_dtypes for the exotic low-precision
    types numpy can't look up natively (fp8 KV payloads, bf16)."""
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes
        return np.dtype(getattr(ml_dtypes, name))


def _payload_nbytes(payload: Payload) -> int:
    return sum(int(a.nbytes) for a in payload.values())


@dataclasses.dataclass
class _Entry:
    key: str
    nbytes: int
    tier: str                    # "device" | "host" | "disk"
    remat_cost: float            # relative cost to rebuild from nothing
    last_use: int
    payload: Optional[Payload] = None    # host tier only
    path: Optional[Path] = None          # disk tier only


class TieredStore:
    """Byte-budgeted device/host/disk hierarchy behind the serving caches."""

    TIERS = ("device", "host", "disk")

    def __init__(self, *, host_budget_bytes: int = 64 << 20,
                 disk_budget_bytes: int = 0,
                 disk_dir: Optional[str] = None):
        self.host_budget_bytes = int(host_budget_bytes)
        self.disk_dir = Path(disk_dir) if disk_dir else None
        self.disk_budget_bytes = int(disk_budget_bytes) if disk_dir else 0
        if self.disk_dir is not None:
            self.disk_dir.mkdir(parents=True, exist_ok=True)
        self._entries: Dict[str, _Entry] = {}
        self._clock = itertools.count(1)
        self.hits = {t: 0 for t in self.TIERS}
        self.misses = 0
        self.promotes = 0            # disk→host or host/disk→device (take)
        self.demotes = 0             # device→host or host→disk
        self.evictions = 0           # dropped out of the hierarchy entirely
        self.disk_corrupt = 0        # truncated/CRC-failed disk reads → miss

    # -- introspection ---------------------------------------------------------
    def tier_of(self, key: str) -> Optional[str]:
        e = self._entries.get(key)
        return e.tier if e is not None else None

    def __contains__(self, key: str) -> bool:
        return key in self._entries

    def keys(self, tier: Optional[str] = None) -> List[str]:
        return [k for k, e in self._entries.items()
                if tier is None or e.tier == tier]

    def tier_bytes(self, tier: str) -> int:
        return sum(e.nbytes for e in self._entries.values() if e.tier == tier)

    # -- device tier (accounting only) ----------------------------------------
    def note_device(self, key: str, nbytes: int,
                    remat_cost: float = 1.0) -> None:
        """Record that ``key`` is device-resident (the bytes live in the
        client's own device structure). Any host/disk copy is consumed —
        an entry lives in exactly one tier."""
        old = self._entries.pop(key, None)
        if old is not None and old.tier == "disk":
            self._unlink(old)
        self._entries[key] = _Entry(key, int(nbytes), "device",
                                    float(remat_cost), next(self._clock))

    def drop_device(self, key: str) -> None:
        """The device copy is gone and nothing was spilled (no payload)."""
        e = self._entries.get(key)
        if e is not None and e.tier == "device":
            del self._entries[key]
            self.evictions += 1

    def demote(self, key: str, payload: Payload, *,
               remat_cost: Optional[float] = None) -> None:
        """Device → host: the device copy is being dropped and ``payload``
        is its host-side rematerialization (raw bytes — bit-exact). Also
        valid for keys never noted on device (direct host insert)."""
        old = self._entries.pop(key, None)
        cost = remat_cost if remat_cost is not None else \
            (old.remat_cost if old is not None else 1.0)
        if old is not None and old.tier == "disk":
            self._unlink(old)
        if old is not None and old.tier == "device":
            self.demotes += 1
        self._insert_host(_Entry(key, _payload_nbytes(payload), "host",
                                 float(cost), next(self._clock),
                                 payload={k: np.ascontiguousarray(v)
                                          for k, v in payload.items()}))

    def put(self, key: str, payload: Payload, *,
            remat_cost: float = 1.0) -> None:
        """Direct host-tier insert (spill paths with no device accounting)."""
        self.demote(key, payload, remat_cost=remat_cost)

    # -- read side -------------------------------------------------------------
    def get(self, key: str) -> Optional[Payload]:
        """Payload of a host/disk entry (None on miss or corrupt disk file).
        Touches recency; the entry stays in its tier."""
        e = self._entries.get(key)
        if e is None or e.tier == "device":
            if e is not None:
                self.hits["device"] += 1
                e.last_use = next(self._clock)
            else:
                self.misses += 1
            return None
        e.last_use = next(self._clock)
        if e.tier == "host":
            self.hits["host"] += 1
            return e.payload
        payload = self._read_disk(e)
        if payload is None:
            return None
        self.hits["disk"] += 1
        return payload

    def take(self, key: str) -> Optional[Payload]:
        """Consume a host/disk entry for promotion to device: returns the
        payload and removes the entry (the caller re-inserts the device copy
        via ``note_device``). None on miss / corrupt disk copy."""
        payload = self.get(key)
        if payload is None:
            return None
        e = self._entries.pop(key)
        if e.tier == "disk":
            self._unlink(e)
        self.promotes += 1
        return payload

    def promote_host(self, key: str) -> bool:
        """Disk → host (prefetch: stage a cold entry one tier up so a later
        ``take`` is a memory read, not a disk read)."""
        e = self._entries.get(key)
        if e is None or e.tier != "disk":
            return False
        payload = self._read_disk(e)
        if payload is None:
            return False
        del self._entries[key]
        self._unlink(e)
        self.promotes += 1
        self._insert_host(_Entry(key, _payload_nbytes(payload), "host",
                                 e.remat_cost, next(self._clock),
                                 payload=payload))
        return True

    def remove(self, key: str) -> None:
        e = self._entries.pop(key, None)
        if e is not None and e.tier == "disk":
            self._unlink(e)

    # -- cost-model eviction ---------------------------------------------------
    def _score(self, e: _Entry, now: int) -> float:
        """Keep-value density: re-materialization cost × recency / bytes.
        The *lowest* score evicts first — big, stale, cheap-to-rebuild."""
        recency = 1.0 / (1.0 + (now - e.last_use))
        return e.remat_cost * recency / max(e.nbytes, 1)

    def _victim(self, tier: str) -> Optional[_Entry]:
        pool = [e for e in self._entries.values() if e.tier == tier]
        if not pool:
            return None
        now = next(self._clock)
        return min(pool, key=lambda e: (self._score(e, now), e.key))

    def _insert_host(self, entry: _Entry) -> None:
        if entry.nbytes > self.host_budget_bytes:
            self._spill_disk(entry)
            return
        while (self.tier_bytes("host") + entry.nbytes
               > self.host_budget_bytes):
            victim = self._victim("host")
            if victim is None:
                self._spill_disk(entry)
                return
            del self._entries[victim.key]
            self.demotes += 1
            self._spill_disk(victim)
        self._entries[entry.key] = entry

    def _spill_disk(self, entry: _Entry) -> None:
        if self.disk_dir is None or entry.nbytes > self.disk_budget_bytes:
            self.evictions += 1
            return
        while self.tier_bytes("disk") + entry.nbytes > self.disk_budget_bytes:
            victim = self._victim("disk")
            if victim is None:
                self.evictions += 1
                return
            del self._entries[victim.key]
            self._unlink(victim)
            self.evictions += 1
        path = self._write_disk(entry.key, entry.payload)
        self._entries[entry.key] = _Entry(
            entry.key, entry.nbytes, "disk", entry.remat_cost,
            entry.last_use, path=path)

    # -- disk format -----------------------------------------------------------
    # <MAGIC><header-json>\n<raw payload bytes>
    # header: {"key", "arrays": [{"name","dtype","shape","nbytes"}], "crc"}
    # The payload is read back through an mmap and CRC-verified: a torn or
    # truncated file (crash mid-write, disk full) is a *miss*, never data.
    def _disk_path(self, key: str) -> Path:
        digest = hashlib.sha1(key.encode()).hexdigest()
        return self.disk_dir / f"{digest}.tmem"

    def _write_disk(self, key: str, payload: Payload) -> Path:
        arrays, blobs = [], []
        for name, arr in payload.items():
            arr = np.ascontiguousarray(arr)
            blob = arr.view(np.uint8).reshape(-1).tobytes()
            arrays.append({"name": name, "dtype": arr.dtype.name,
                           "shape": list(arr.shape), "nbytes": len(blob)})
            blobs.append(blob)
        data = b"".join(blobs)
        header = json.dumps({"key": key, "arrays": arrays,
                             "crc": zlib.crc32(data) & 0xFFFFFFFF})
        path = self._disk_path(key)
        tmp = path.with_suffix(".tmp")
        with open(tmp, "wb") as f:
            f.write(_MAGIC + header.encode() + b"\n" + data)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
        return path

    def _read_disk(self, e: _Entry) -> Optional[Payload]:
        try:
            with open(e.path, "rb") as f:
                magic = f.read(len(_MAGIC))
                if magic != _MAGIC:
                    raise ValueError("bad magic")
                header = json.loads(f.readline().decode())
                offset = f.tell()
            total = sum(a["nbytes"] for a in header["arrays"])
            raw = np.memmap(e.path, dtype=np.uint8, mode="r",
                            offset=offset, shape=(total,))
            if zlib.crc32(raw.tobytes()) & 0xFFFFFFFF != header["crc"]:
                raise ValueError("payload CRC mismatch")
            payload: Payload = {}
            off = 0
            for a in header["arrays"]:
                chunk = np.array(raw[off:off + a["nbytes"]])  # copy off mmap
                payload[a["name"]] = chunk.view(
                    _resolve_dtype(a["dtype"])).reshape(a["shape"])
                off += a["nbytes"]
            return payload
        except Exception:
            # truncated / torn / unreadable file: degrade to a clean miss
            self.disk_corrupt += 1
            self.misses += 1
            self._entries.pop(e.key, None)
            self._unlink(e)
            return None

    def _unlink(self, e: _Entry) -> None:
        if e.path is not None:
            try:
                e.path.unlink()
            except OSError:
                pass

    # -- lifecycle / invariants ------------------------------------------------
    def drain(self) -> None:
        """Drop every host/disk entry (disk files deleted). Device entries
        stay — their bytes are owned by the client structures."""
        for key in [k for k, e in self._entries.items() if e.tier != "device"]:
            self.remove(key)

    def verify(self) -> List[str]:
        """Structural invariants for the fuzz harness: one tier per entry
        (by construction — cross-checked against payload/path placement),
        per-tier byte accounting matching the stored payloads and within
        budget, and no orphaned or missing disk files."""
        errs = []
        for k, e in self._entries.items():
            if e.tier not in self.TIERS:
                errs.append(f"{k}: unknown tier {e.tier!r}")
            if e.tier == "host":
                if e.payload is None:
                    errs.append(f"{k}: host entry without payload")
                elif _payload_nbytes(e.payload) != e.nbytes:
                    errs.append(f"{k}: host nbytes {e.nbytes} != payload "
                                f"{_payload_nbytes(e.payload)}")
            else:
                if e.payload is not None:
                    errs.append(f"{k}: {e.tier} entry holds a host payload")
            if e.tier == "disk":
                if e.path is None or not e.path.exists():
                    errs.append(f"{k}: disk entry without a backing file")
            elif e.path is not None:
                errs.append(f"{k}: {e.tier} entry holds a disk path")
        if self.tier_bytes("host") > self.host_budget_bytes:
            errs.append(f"host tier over budget: {self.tier_bytes('host')} > "
                        f"{self.host_budget_bytes}")
        if self.disk_dir is not None:
            if self.tier_bytes("disk") > self.disk_budget_bytes:
                errs.append(f"disk tier over budget: "
                            f"{self.tier_bytes('disk')} > "
                            f"{self.disk_budget_bytes}")
            on_disk = {p for p in self.disk_dir.glob("*.tmem")}
            tracked = {e.path for e in self._entries.values()
                       if e.tier == "disk"}
            orphans = on_disk - tracked
            if orphans:
                errs.append(f"orphaned disk files: "
                            f"{sorted(p.name for p in orphans)}")
        return errs

    # -- stats -----------------------------------------------------------------
    def stats(self) -> Dict[str, object]:
        return {
            "tier_bytes": {t: self.tier_bytes(t) for t in self.TIERS},
            "tier_entries": {t: len(self.keys(t)) for t in self.TIERS},
            "tier_hits": dict(self.hits),
            "misses": self.misses,
            "promotes": self.promotes,
            "demotes": self.demotes,
            "evictions": self.evictions,
            "disk_corrupt": self.disk_corrupt,
            "host_budget_bytes": self.host_budget_bytes,
            "disk_budget_bytes": self.disk_budget_bytes,
        }
