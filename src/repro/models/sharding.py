"""Partition rules: how every parameter / activation / cache shards over the
production mesh (data, model[, pod]).

Strategies (selected per run; §Perf records the deltas):

  * ``paper_tree`` — the paper-faithful layout (Fig 7a): EVERY linear weight
    is sharded along its contracting (K) dimension over ``model``; each
    matmul produces partials that the reduction tree (all-reduce) sums. One
    collective per GEMV, no other cross-lane traffic — exactly TOM's
    "lanes synchronize only via the global reduction tree".
  * ``megatron`` — beyond-paper: pair column-sharded (q/k/v/up/gate) with
    row-sharded (o/down) linears so only block boundaries reduce (2
    all-reduces per layer instead of ~7). Decode attention keeps the paper's
    context sharding either way (it is decode-optimal and is the C3 claim).
  * MoE experts: ``tp`` K-shards each expert (paper-faithful, tree-only);
    ``ep`` shards the expert dim (all-to-all dispatch, beyond-paper).

QAT (training) additionally shards the non-contracting weight dim over
``data`` (FSDP/ZeRO-style) so 100B+ masters + optimizer state fit; XLA
all-gathers per layer under the scan.

Rules are expressed as path-regex → PartitionSpec over logical axis names,
resolved against the concrete mesh axes at apply time.
"""
from __future__ import annotations

import re
from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


# Logical axes: "dp" (data ∥, maps to ('pod','data') or ('data',)), "tp"
# (tensor ∥ = the paper's lanes, maps to 'model'), None (replicated).


def logical_to_mesh_axes(mesh: Mesh) -> Dict[str, Any]:
    names = mesh.axis_names
    dp = tuple(n for n in names if n in ("pod", "data", "replica"))
    return {"dp": dp if len(dp) > 1 else (dp[0] if dp else None), "tp": "model"}


def _resolve(spec: Tuple[Optional[str], ...], mesh: Mesh) -> P:
    m = logical_to_mesh_axes(mesh)
    return P(*(m.get(a, None) if a else None for a in spec))


# ---------------------------------------------------------------------------
# Parameter rules (matched against "/"-joined pytree paths)
# ---------------------------------------------------------------------------

# (regex, spec-for-2D-(K,N), spec-for-packed-(K/4,N)) — matched in order.
def param_rules(strategy: str, mode: str, fsdp: bool):
    col = ("tp",) if strategy == "megatron" else ()     # N-shard set
    # In paper_tree, everything K-shards. In megatron, these N-shard:
    col_names = r"(q|k|v|gate|up|q_b|kv_b|in_proj)$" if strategy == "megatron" else r"$^"
    dp = "dp" if fsdp else None
    rules = [
        # MoE stacked experts (E, K, N)
        (r"experts_ep/.*(up|gate|down)/(w|packed)$", ("tp", None, dp)),
        (r".*/(up|gate|down)/(w|packed)$/expert", None),  # placeholder, unused
        # embedding: vocab-sharded rows
        (r".*embed.*/(w|packed_rows)$", ("tp", dp)),
        # lm head (D, V): vocab-sharded output
        (r".*head/(w|packed)$", (dp, "tp") if strategy == "megatron" else ("tp", dp)),
        # column-parallel linears (megatron only)
        (col_names + r"/(w|packed)" if strategy == "megatron" else r"$^", (dp, "tp")),
        # default 2-D linear: K-sharded (paper Fig 7a)
        (r".*/(w|packed)$", ("tp", dp)),
        # everything else (norms, scales, biases, conv, a_log...): replicated
        (r".*", ()),
    ]
    return rules


def _is_expert_leaf(path: str) -> bool:
    return "/moe/" in path and any(s in path for s in ("/up/", "/gate/", "/down/")) \
        and not any(s in path for s in ("shared", "dense_residual", "router"))


def _axis_extent(mesh: Mesh, part) -> int:
    names = part if isinstance(part, tuple) else (part,)
    e = 1
    for n in names:
        e *= mesh.shape[n]
    return e


def fit_spec(parts, shape, mesh: Mesh) -> P:
    """Drop/shrink axes that don't divide their dimension.

    Rule: for each dim, if the assigned axis (or axis tuple) extent does not
    divide the dim, try successively smaller suffixes of the tuple (e.g.
    ('pod','data') → ('data',)), else replicate that dim. Keeps the dry-run
    honest for shapes like zamba2's in_proj N=14704 (divisible by 16, not by
    the 32-wide multi-pod dp)."""
    fitted = []
    for dim, part in zip(shape, parts):
        if part is None:
            fitted.append(None)
            continue
        cand = part if isinstance(part, tuple) else (part,)
        chosen = None
        while cand:
            if dim % _axis_extent(mesh, cand) == 0:
                chosen = cand if len(cand) > 1 else cand[0]
                break
            cand = cand[1:]
        fitted.append(chosen)
    return P(*fitted)


def param_spec_tree(params_or_specs, mesh: Mesh, *, strategy: str = "paper_tree",
                    mode: str = "serve", fsdp: bool = False,
                    moe_sharding: str = "tp"):
    """PartitionSpec tree (same structure as params)."""
    m = logical_to_mesh_axes(mesh)
    dp = m["dp"] if fsdp else None
    tp = m["tp"]

    col_re = re.compile(r"/(q|k|v|gate|up|q_a|q_b|kv_a|kv_b|in_proj)/(w|packed)$")
    embed_re = re.compile(r"embed/(w|packed_rows)$")
    head_re = re.compile(r"head/(w|packed)$")
    lin_re = re.compile(r"/(w|packed)$")
    lora_re = re.compile(r"/lora/(a|b)$")

    def spec_for(path: str, leaf) -> P:
        ndim = len(leaf.shape)
        # strip the stacked-layers leading axis for rule matching
        stacked = path.startswith("layers/") or path.startswith("mamba/") or "/layers/" in path
        wdim = ndim - 1 if stacked else ndim

        if _is_expert_leaf(path) and lin_re.search(path):
            # (…, E, K, N) or (…, E, K/4, N)
            if moe_sharding == "ep":
                e_spec = (tp, None, dp)
            elif moe_sharding == "megatron":
                # column-parallel up/gate + row-parallel down: the silu(gate)·up
                # nonlinearity runs lane-LOCAL on the dff/16 slice and the only
                # reduction is ONE psum of the combined (T, D) output — vs the
                # paper-tree layout's (E, C, dff) f32 reductions (§Perf cell B).
                if "/down/" in path:
                    e_spec = (None, tp, dp)      # row: K=dff over lanes
                else:
                    e_spec = (None, dp, tp)      # col: N=dff over lanes
            else:
                e_spec = (None, tp, dp)
            pad = (None,) * (ndim - 3)
            return P(*pad, *e_spec)
        if lora_re.search(path):
            # adapters: A (K, r) K-sharded, B (r, N) replicated-K
            pad = (None,) * (ndim - 2)
            return P(*pad, tp, None) if path.endswith("/a") else P(*pad, None, None)
        if embed_re.search(path):
            # (V, D): feature dim over lanes (gathers stay device-local),
            # vocab dim over dp (FSDP). Vocab-over-lanes would force an
            # all-gather of the whole table per embed lookup.
            pad = (None,) * (ndim - 2)
            return P(*pad, dp, tp)
        if head_re.search(path):
            pad = (None,) * (ndim - 2)
            return P(*pad, dp, tp) if strategy == "megatron" else P(*pad, tp, dp)
        if wdim >= 2 and lin_re.search(path):
            pad = (None,) * (ndim - 2)
            if strategy == "megatron" and col_re.search(path):
                return P(*pad, dp, tp)
            return P(*pad, tp, dp)   # paper Fig 7a: K over lanes
        return P()

    def build(tree):
        def walk(path, node):
            if isinstance(node, dict):
                return {k: walk(f"{path}/{k}" if path else k, v) for k, v in node.items()}
            if isinstance(node, (list, tuple)):
                t = [walk(f"{path}/{i}", v) for i, v in enumerate(node)]
                return type(node)(t)
            spec = spec_for(path, node)
            return fit_spec(tuple(spec), node.shape, mesh)
        return walk("", tree)

    return build(params_or_specs)


# ---------------------------------------------------------------------------
# Activation / batch / cache specs
# ---------------------------------------------------------------------------


def batch_spec(mesh: Mesh) -> P:
    dp = logical_to_mesh_axes(mesh)["dp"]
    return P(dp)


def tokens_spec(mesh: Mesh) -> P:
    dp = logical_to_mesh_axes(mesh)["dp"]
    return P(dp, None)


def embeds_spec(mesh: Mesh) -> P:
    dp = logical_to_mesh_axes(mesh)["dp"]
    return P(dp, None, None)


def kv_cache_spec_tree(cache_specs, mesh: Mesh) -> Any:
    """KV caches shard over (dp on batch, model on CONTEXT) — the paper's
    SRAM tiling. Works for GQA (L,B,H,S,D), MLA latent (L,B,S,R) and SSM
    states (L,B,H,P,N — heads over model, no context dim)."""
    m = logical_to_mesh_axes(mesh)
    dp, tp = m["dp"], m["tp"]

    def spec_for(path: str, leaf) -> P:
        nd = len(leaf.shape)
        leafname = path.rsplit("/", 1)[-1]
        if leafname in ("k", "v"):                            # (L,B,H,S,D)
            return P(None, dp, None, tp, None)
        if "latent" in path or "k_rope" in path:              # (L,B,S,R)
            return P(None, dp, tp, None)
        if path.endswith("ssm"):                              # (L,B,H,P,N)
            return P(None, dp, tp, None, None)
        if path.endswith("conv"):                             # (L,B,W,C)
            return P(None, dp, None, tp)
        return P(*([None] * nd))

    def walk(path, node):
        if isinstance(node, dict):
            return {k: walk(f"{path}/{k}" if path else k, v) for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            return type(node)([walk(f"{path}/{i}", v) for i, v in enumerate(node)])
        return spec_for(path, node)

    return walk("", cache_specs)


def to_named(spec_tree, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
