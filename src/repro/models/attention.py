"""Attention blocks: GQA (with qk-norm / RoPE) and MLA (DeepSeek-V2).

Two execution regimes per block:

  * **train/prefill** — full-sequence causal attention, computed as a
    block-banded online-softmax scan (`chunked_causal_attention`): flash
    attention expressed in pure JAX so XLA keeps the live score tile at
    (chunk_q × chunk_k) instead of S². Heads are sharded over ``model``
    by the GSPMD layer (models/sharding.py).

  * **decode** — one token against an fp8 KV cache that is sharded over the
    *context* dimension across lanes (the paper's SRAM tiling). The softmax
    is TOM's two-phase tree dataflow (core/attention.py) inside a shard_map
    over the ``model`` axis.

The KV cache layout is ``k/v: (B, Hkv, S, D)`` (GQA) or the compressed
``latent: (B, S, R+rope)`` (MLA — decode uses the absorbed form so the cache
stays compressed end-to-end).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import attention as core_attn
from repro.core.lanes import tree_max, tree_sum
from repro.models import act_sharding, layers
from repro.models.layers import KV_CACHE_SCALE, Params, apply_linear, init_linear, linear_spec

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Paged KV decode state (the opaque KVState a `serving.kv.PagedKV` backend
# hands to Model.decode_step — block tables instead of a contiguous cache)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class PagedKVState:
    """Block-table view of a shared KV page pool for one decode tick.

    ``k_pool``/``v_pool`` are the whole pool ``(L, n_pages+1, Hkv, page, D)``
    (last page = scratch for inactive slots); ``tables`` (B, P) int32 are the
    per-slot block tables (pad → scratch page); ``write_page``/``write_off``
    (B,) name where this tick's token lands; ``lengths`` (B,) is the live
    context length *including* the new token. The struct is a pytree so it
    crosses jit boundaries; Model.decode_step returns it with updated pools.
    """
    k_pool: jax.Array
    v_pool: jax.Array
    tables: jax.Array
    write_page: jax.Array
    write_off: jax.Array
    lengths: jax.Array


jax.tree_util.register_dataclass(
    PagedKVState,
    data_fields=["k_pool", "v_pool", "tables", "write_page", "write_off",
                 "lengths"],
    meta_fields=[])


def gather_pages(pool: jax.Array, tables: jax.Array) -> jax.Array:
    """pool (L, N, H, page, D) × tables (B, P) → contiguous (L, B, H, P*page, D)."""
    l, _, h, page, d = pool.shape
    b, p = tables.shape
    pages = pool[:, tables]                        # (L, B, P, H, page, D)
    return pages.transpose(0, 1, 3, 2, 4, 5).reshape(l, b, h, p * page, d)


def scatter_tokens(pool: jax.Array, page_ids: jax.Array, offsets: jax.Array,
                   toks: jax.Array) -> jax.Array:
    """Write toks (L, B, H, D) at (page_ids[b], offsets[b]) in pool
    (L, N, H, page, D). The separated advanced indices put the broadcast
    batch dim first, so the value is fed as (B, L, H, D)."""
    return pool.at[:, page_ids, :, offsets].set(
        toks.astype(pool.dtype).transpose(1, 0, 2, 3))


def gqa_decode_paged(p: Params, x: jax.Array, k_pool_l: jax.Array,
                     v_pool_l: jax.Array, tables: jax.Array,
                     write_page: jax.Array, write_off: jax.Array,
                     lengths: jax.Array, pos: jax.Array, cfg: ModelConfig,
                     mode: str, *, use_kernel: bool, interpret: bool,
                     **kw) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One-token GQA decode straight off one layer of the paged KV pool.

    Scatters the new token's k/v into its page, then dispatches attention to
    the Pallas ``paged_flash_decode`` kernel (block tables via scalar
    prefetch, pages stream HBM→VMEM — no contiguous gather) or its XLA
    gather reference. x: (B, D); k_pool_l/v_pool_l: (N+1, Hkv, page, D).
    Returns (out (B, D), new k_pool_l, new v_pool_l).
    """
    from repro.kernels.flash_decode.ops import paged_decode_attention
    b, _ = x.shape
    positions = pos[:, None]
    q, k_new, v_new = _project_qkv(p, x[:, None], cfg, mode, positions, **kw)
    q = q[:, 0]                                          # (B, H, D)
    k_new = (k_new[:, 0] / KV_CACHE_SCALE).astype(k_pool_l.dtype)
    v_new = (v_new[:, 0] / KV_CACHE_SCALE).astype(v_pool_l.dtype)
    # (B,) page ids / offsets, slice between them → batch dim leads: (B, H, D)
    k_pool_l = k_pool_l.at[write_page, :, write_off].set(k_new)
    v_pool_l = v_pool_l.at[write_page, :, write_off].set(v_new)
    out = paged_decode_attention(
        q, k_pool_l, v_pool_l, tables, lengths,
        jnp.float32(KV_CACHE_SCALE), use_kernel=use_kernel,
        interpret=interpret, out_dtype=jnp.float32)
    out = out.reshape(b, cfg.q_dim).astype(x.dtype)
    return apply_linear(p["o"], out, mode, **kw), k_pool_l, v_pool_l


# ---------------------------------------------------------------------------
# Block-banded causal flash attention (train / prefill)
# ---------------------------------------------------------------------------


def chunked_causal_attention(
    q: jax.Array,  # (B, S, H, D)
    k: jax.Array,  # (B, S, Hkv, D)
    v: jax.Array,  # (B, S, Hkv, D)
    *,
    chunk_q: int = 512,
    chunk_k: int = 512,
    scale: Optional[float] = None,
    remat_rows: bool = True,
) -> jax.Array:
    """Causal GQA attention with O(chunk_q·chunk_k) live scores.

    Outer scan over query chunks; inner scan over key chunks skips blocks
    strictly above the diagonal (lax.cond → no FLOPs on TPU's sequential
    scan), masking only the diagonal block.

    ``remat_rows`` wraps each q-row in ``jax.checkpoint`` — the flash-
    attention backward policy: the (cq × S) probability row is recomputed
    per q-chunk during the backward instead of being saved for every
    (q-chunk, k-chunk) tile, which would materialize the full S² scores
    (at 123B-scale training that is the difference between ~3 GB and
    ~100+ GB of per-layer backward residuals).
    """
    b, s, h, d = q.shape
    hkv = k.shape[2]
    g = h // hkv
    scale = scale if scale is not None else d ** -0.5
    assert s % chunk_q == 0 and s % chunk_k == 0, (s, chunk_q, chunk_k)
    nq, nk = s // chunk_q, s // chunk_k

    qc = q.reshape(b, nq, chunk_q, hkv, g, d).transpose(1, 0, 3, 4, 2, 5)  # (nq,B,Hkv,G,cq,D)
    kc = k.reshape(b, nk, chunk_k, hkv, d).transpose(1, 0, 3, 2, 4)        # (nk,B,Hkv,ck,D)
    vc = v.reshape(b, nk, chunk_k, hkv, d).transpose(1, 0, 3, 2, 4)

    def q_step(_, iq_qi):
        iq, q_i = iq_qi
        q_i = q_i.astype(jnp.float32)

        def kv_step(carry, ik_kv):
            ik, k_i, v_i = ik_kv
            m_p, d_p, o_p = carry

            def compute(args):
                m_p, d_p, o_p = args
                s_ij = jnp.einsum("bhgqd,bhkd->bhgqk", q_i, k_i.astype(jnp.float32)) * scale
                # mask the diagonal block; earlier blocks are fully visible
                q_pos = iq * chunk_q + jnp.arange(chunk_q)
                k_pos = ik * chunk_k + jnp.arange(chunk_k)
                causal = q_pos[:, None] >= k_pos[None, :]
                s_ij = jnp.where(causal[None, None, None], s_ij, NEG_INF)
                m_n = jnp.maximum(m_p, jnp.max(s_ij, axis=-1))
                corr = jnp.exp(m_p - m_n)
                p_ij = jnp.exp(s_ij - m_n[..., None])
                d_n = d_p * corr + jnp.sum(p_ij, axis=-1)
                o_n = o_p * corr[..., None] + jnp.einsum(
                    "bhgqk,bhkd->bhgqd", p_ij, v_i.astype(jnp.float32))
                return m_n, d_n, o_n

            new = jax.lax.cond(
                ik * chunk_k <= iq * chunk_q + chunk_q - 1,  # block intersects causal band
                compute, lambda a: a, (m_p, d_p, o_p))
            return new, None

        init = (
            jnp.full((b, hkv, g, chunk_q), NEG_INF, jnp.float32),
            jnp.zeros((b, hkv, g, chunk_q), jnp.float32),
            jnp.zeros((b, hkv, g, chunk_q, d), jnp.float32),
        )
        (m_f, d_f, o_f), _ = jax.lax.scan(
            kv_step, init, (jnp.arange(nk), kc, vc))
        out = o_f / jnp.maximum(d_f[..., None], 1e-30)
        return None, out.astype(q.dtype)

    if remat_rows:
        q_step = jax.checkpoint(q_step)
    _, out = jax.lax.scan(q_step, None, (jnp.arange(nq), qc))
    # (nq, B, Hkv, G, cq, D) → (B, S, H, D)
    out = out.transpose(1, 0, 4, 2, 3, 5).reshape(b, s, h, d)
    return out


# ---------------------------------------------------------------------------
# GQA block
# ---------------------------------------------------------------------------


def init_gqa(key: jax.Array, cfg: ModelConfig, mode: str, **kw) -> Params:
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    p = {
        "q": init_linear(ks[0], d, cfg.q_dim, mode,
                         lora=layers.lora_for(cfg, "q", mode), **kw),
        "k": init_linear(ks[1], d, cfg.kv_dim, mode,
                         lora=layers.lora_for(cfg, "k", mode), **kw),
        "v": init_linear(ks[2], d, cfg.kv_dim, mode,
                         lora=layers.lora_for(cfg, "v", mode), **kw),
        "o": init_linear(ks[3], cfg.q_dim, d, mode,
                         lora=layers.lora_for(cfg, "o", mode), **kw),
    }
    if cfg.qk_norm:
        p["q_norm"] = layers.init_rms_norm(cfg.head_dim)
        p["k_norm"] = layers.init_rms_norm(cfg.head_dim)
    return p


def gqa_spec(cfg: ModelConfig, mode: str, **kw) -> Params:
    d = cfg.d_model
    p = {
        "q": linear_spec(d, cfg.q_dim, mode, **kw),
        "k": linear_spec(d, cfg.kv_dim, mode, **kw),
        "v": linear_spec(d, cfg.kv_dim, mode, **kw),
        "o": linear_spec(cfg.q_dim, d, mode, **kw),
    }
    if cfg.qk_norm:
        p["q_norm"] = {"w": jax.ShapeDtypeStruct((cfg.head_dim,), jnp.float32)}
        p["k_norm"] = {"w": jax.ShapeDtypeStruct((cfg.head_dim,), jnp.float32)}
    return p


def _project_qkv(p: Params, x: jax.Array, cfg: ModelConfig, mode: str,
                 positions: jax.Array, **kw):
    b = x.shape[:-1]
    if kw.get("fuse") and mode != "qat":
        sub = {kk: v_ for kk, v_ in kw.items() if kk not in ("fuse", "kv_dtype")}
        q, k, v = layers.apply_linear_fused([p["q"], p["k"], p["v"]], x, mode,
                                            **sub)
        q = q.reshape(*b, cfg.num_heads, cfg.head_dim)
        k = k.reshape(*b, cfg.num_kv_heads, cfg.head_dim)
        v = v.reshape(*b, cfg.num_kv_heads, cfg.head_dim)
    else:
        q = apply_linear(p["q"], x, mode, **kw).reshape(*b, cfg.num_heads, cfg.head_dim)
        k = apply_linear(p["k"], x, mode, **kw).reshape(*b, cfg.num_kv_heads, cfg.head_dim)
        v = apply_linear(p["v"], x, mode, **kw).reshape(*b, cfg.num_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = layers.rms_norm(q, p["q_norm"]["w"], cfg.norm_eps)
        k = layers.rms_norm(k, p["k_norm"]["w"], cfg.norm_eps)
    q = layers.apply_rope(q, positions, cfg.rope_theta)
    k = layers.apply_rope(k, positions, cfg.rope_theta)
    # pin head sharding so chunked-attention tiles stay lane-local (§Perf A);
    # no-op when the head count doesn't divide the lane axis (yi, starcoder)
    q = act_sharding.constrain(q, "heads")
    k = act_sharding.constrain(k, "heads")
    v = act_sharding.constrain(v, "heads")
    return q, k, v


def gqa_train(p: Params, x: jax.Array, cfg: ModelConfig, mode: str,
              chunk: int = 512, **kw) -> jax.Array:
    """Full-sequence causal GQA. x: (B, S, D)."""
    b, s, _ = x.shape
    positions = jnp.arange(s)[None, :]
    q, k, v = _project_qkv(p, x, cfg, mode, positions, **kw)
    cq = min(chunk, s)
    out = chunked_causal_attention(q, k, v, chunk_q=cq, chunk_k=cq)
    out = out.reshape(b, s, cfg.q_dim)
    return apply_linear(p["o"], out, mode, **kw)


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int, n_layers: int,
                  dtype=jnp.float8_e4m3fn) -> Params:
    shape = (n_layers, batch, cfg.num_kv_heads, max_len, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def kv_cache_spec(cfg: ModelConfig, batch: int, max_len: int, n_layers: int,
                  dtype=jnp.float8_e4m3fn) -> Params:
    shape = (n_layers, batch, cfg.num_kv_heads, max_len, cfg.head_dim)
    return {"k": jax.ShapeDtypeStruct(shape, dtype),
            "v": jax.ShapeDtypeStruct(shape, dtype)}


def _update_cache_local(cache_l: jax.Array, new: jax.Array, pos: jax.Array,
                        lane: jax.Array, s_local: int) -> jax.Array:
    """Write (B, Hkv, D) into this lane's (B, Hkv, S_local, D) context shard
    iff `pos` falls in its range — no cross-lane traffic (the token lands in
    exactly one lane's SRAM, Fig 7b)."""
    local = pos - lane * s_local
    in_range = (local >= 0) & (local < s_local)
    idx = jnp.clip(local, 0, s_local - 1)
    updated = jax.lax.dynamic_update_slice(
        cache_l, new[:, :, None].astype(cache_l.dtype), (0, 0, idx, 0))
    return jnp.where(in_range, updated, cache_l)


def gqa_decode(p: Params, x: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
               pos: jax.Array, cfg: ModelConfig, mode: str,
               axis_name: Optional[str], n_lanes: int, **kw
               ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One-token GQA decode against the lane-local KV shard.

    Runs INSIDE shard_map over `axis_name`: k_cache/v_cache are the local
    (B, Hkv, S_local, D) context shards; x (B, D) is replicated. Returns
    (out (B, D), new_k_local, new_v_local).
    """
    b, _ = x.shape
    positions = pos[None, None]  # broadcast to (B, 1)
    q, k_new, v_new = _project_qkv(p, x[:, None], cfg, mode, positions, **kw)
    q = q[:, 0]                     # (B, H, D)
    k_new, v_new = k_new[:, 0], v_new[:, 0]  # (B, Hkv, D)

    s_local = k_cache.shape[2]
    lane = jax.lax.axis_index(axis_name) if axis_name else jnp.int32(0)
    k_cache = _update_cache_local(k_cache, k_new / KV_CACHE_SCALE, pos, lane, s_local)
    v_cache = _update_cache_local(v_cache, v_new / KV_CACHE_SCALE, pos, lane, s_local)

    # local visibility mask: global position index of each local slot
    slot = lane * s_local + jnp.arange(s_local)
    mask = (slot <= pos)[None, :]   # (1, S_local) → broadcast over B

    out = core_attn.gqa_decode(
        q, k_cache.astype(jnp.float32), v_cache.astype(jnp.float32),
        axis_name=axis_name, variant="tom",
        mask_local=jnp.broadcast_to(mask, (b, s_local)),
        kv_scale=jnp.float32(KV_CACHE_SCALE),
    ).astype(x.dtype)
    out = apply_linear(p["o"], out.reshape(b, cfg.q_dim), mode, **kw)
    return out, k_cache, v_cache


# ---------------------------------------------------------------------------
# MLA block (DeepSeek-V2): compressed-latent cache, absorbed decode
# ---------------------------------------------------------------------------


def init_mla(key: jax.Array, cfg: ModelConfig, mode: str, **kw) -> Params:
    m = cfg.mla
    d = cfg.d_model
    h = cfg.num_heads
    ks = jax.random.split(key, 6)
    qh = h * (m.qk_nope_head_dim + m.qk_rope_head_dim)
    return {
        "q_a": init_linear(ks[0], d, m.q_lora_rank, mode, **kw),
        "q_a_norm": layers.init_rms_norm(m.q_lora_rank),
        "q_b": init_linear(ks[1], m.q_lora_rank, qh, mode,
                           lora=layers.lora_for(cfg, "q", mode), **kw),
        "kv_a": init_linear(ks[2], d, m.kv_lora_rank + m.qk_rope_head_dim, mode, **kw),
        "kv_a_norm": layers.init_rms_norm(m.kv_lora_rank),
        "kv_b": init_linear(ks[3], m.kv_lora_rank,
                            h * (m.qk_nope_head_dim + m.v_head_dim), mode,
                            lora=layers.lora_for(cfg, "v", mode), **kw),
        "o": init_linear(ks[4], h * m.v_head_dim, d, mode,
                         lora=layers.lora_for(cfg, "o", mode), **kw),
    }


def mla_spec(cfg: ModelConfig, mode: str, **kw) -> Params:
    m = cfg.mla
    d = cfg.d_model
    h = cfg.num_heads
    qh = h * (m.qk_nope_head_dim + m.qk_rope_head_dim)
    return {
        "q_a": linear_spec(d, m.q_lora_rank, mode, **kw),
        "q_a_norm": {"w": jax.ShapeDtypeStruct((m.q_lora_rank,), jnp.float32)},
        "q_b": linear_spec(m.q_lora_rank, qh, mode, **kw),
        "kv_a": linear_spec(d, m.kv_lora_rank + m.qk_rope_head_dim, mode, **kw),
        "kv_a_norm": {"w": jax.ShapeDtypeStruct((m.kv_lora_rank,), jnp.float32)},
        "kv_b": linear_spec(m.kv_lora_rank, h * (m.qk_nope_head_dim + m.v_head_dim),
                            mode, **kw),
        "o": linear_spec(h * m.v_head_dim, d, mode, **kw),
    }


def _mla_q(p: Params, x: jax.Array, cfg: ModelConfig, mode: str,
           positions: jax.Array, **kw):
    m = cfg.mla
    h = cfg.num_heads
    qa = apply_linear(p["q_a"], x, mode, **kw)
    qa = layers.rms_norm(qa, p["q_a_norm"]["w"], cfg.norm_eps)
    qb = apply_linear(p["q_b"], qa, mode, **kw)
    qb = qb.reshape(*x.shape[:-1], h, m.qk_nope_head_dim + m.qk_rope_head_dim)
    q_nope = qb[..., :m.qk_nope_head_dim]
    q_rope = layers.apply_rope(qb[..., m.qk_nope_head_dim:], positions, cfg.rope_theta)
    return q_nope, q_rope


def _mla_latent(p: Params, x: jax.Array, cfg: ModelConfig, mode: str,
                positions: jax.Array, **kw):
    m = cfg.mla
    kv = apply_linear(p["kv_a"], x, mode, **kw)
    latent = layers.rms_norm(kv[..., :m.kv_lora_rank], p["kv_a_norm"]["w"], cfg.norm_eps)
    k_rope = layers.apply_rope(kv[..., None, m.kv_lora_rank:], positions, cfg.rope_theta)
    return latent, k_rope[..., 0, :]


def mla_train(p: Params, x: jax.Array, cfg: ModelConfig, mode: str,
              chunk: int = 512, **kw) -> jax.Array:
    """Full-seq MLA: reconstruct per-head K/V from the latent (train path)."""
    m = cfg.mla
    b, s, _ = x.shape
    h = cfg.num_heads
    positions = jnp.arange(s)[None, :]
    q_nope, q_rope = _mla_q(p, x, cfg, mode, positions, **kw)
    latent, k_rope = _mla_latent(p, x, cfg, mode, positions, **kw)
    kvb = apply_linear(p["kv_b"], latent, mode, **kw)
    kvb = kvb.reshape(b, s, h, m.qk_nope_head_dim + m.v_head_dim)
    k_nope, v = kvb[..., :m.qk_nope_head_dim], kvb[..., m.qk_nope_head_dim:]
    q = jnp.concatenate([q_nope, q_rope], -1)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(
        k_rope[:, :, None], (b, s, h, m.qk_rope_head_dim))], -1)
    # head-shard the reconstructed q/k/v (128 heads ÷ 16 lanes; §Perf cell A)
    q = act_sharding.constrain(q, "heads")
    k = act_sharding.constrain(k, "heads")
    v = act_sharding.constrain(v, "heads")
    # pad v head dim up to qk dim for the shared kernel, then slice back
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
    if m.v_head_dim != qk_dim:
        v = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, qk_dim - m.v_head_dim)))
    cq = min(chunk, s)
    out = chunked_causal_attention(q, k, v, chunk_q=cq, chunk_k=cq, scale=scale)
    out = out[..., :m.v_head_dim].reshape(b, s, h * m.v_head_dim)
    return apply_linear(p["o"], out, mode, **kw)


def init_mla_cache(cfg: ModelConfig, batch: int, max_len: int, n_layers: int,
                   dtype=jnp.float8_e4m3fn) -> Params:
    m = cfg.mla
    return {"latent": jnp.zeros((n_layers, batch, max_len, m.kv_lora_rank), dtype),
            "k_rope": jnp.zeros((n_layers, batch, max_len, m.qk_rope_head_dim), dtype)}


def mla_cache_spec(cfg: ModelConfig, batch: int, max_len: int, n_layers: int,
                   dtype=jnp.float8_e4m3fn) -> Params:
    m = cfg.mla
    return {
        "latent": jax.ShapeDtypeStruct((n_layers, batch, max_len, m.kv_lora_rank), dtype),
        "k_rope": jax.ShapeDtypeStruct((n_layers, batch, max_len, m.qk_rope_head_dim), dtype),
    }


def mla_decode(p: Params, x: jax.Array, latent_cache: jax.Array,
               rope_cache: jax.Array, pos: jax.Array, cfg: ModelConfig,
               mode: str, axis_name: Optional[str], n_lanes: int, **kw
               ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Absorbed-form MLA decode over the context-sharded compressed cache.

    score_h = q_nopeᵀ·W_kb_kʰ·latent + q_rope·k_rope ; the attention runs in
    latent space so the cache never decompresses — TOM's two-phase softmax
    applies unchanged over the latent context tiles.
    """
    m = cfg.mla
    h = cfg.num_heads
    b, _ = x.shape
    positions = pos[None, None]
    q_nope, q_rope = _mla_q(p, x[:, None], cfg, mode, positions, **kw)
    q_nope, q_rope = q_nope[:, 0], q_rope[:, 0]        # (B, H, dn), (B, H, dr)
    latent_new, k_rope_new = _mla_latent(p, x[:, None], cfg, mode, positions, **kw)
    latent_new, k_rope_new = latent_new[:, 0], k_rope_new[:, 0]

    s_local = latent_cache.shape[1]
    lane = jax.lax.axis_index(axis_name) if axis_name else jnp.int32(0)

    def upd(cache, new):
        local = pos - lane * s_local
        in_r = (local >= 0) & (local < s_local)
        idx = jnp.clip(local, 0, s_local - 1)
        u = jax.lax.dynamic_update_slice(
            cache, (new / KV_CACHE_SCALE)[:, None].astype(cache.dtype), (0, idx, 0))
        return jnp.where(in_r, u, cache)

    latent_cache = upd(latent_cache, latent_new)
    rope_cache = upd(rope_cache, k_rope_new)

    # absorb W_kb into the query / output
    wkb = _dense_weight(p["kv_b"], x.dtype)            # (R, H*(dn+dv))
    wkb = wkb.reshape(m.kv_lora_rank, h, m.qk_nope_head_dim + m.v_head_dim)
    w_k = wkb[..., :m.qk_nope_head_dim]                # (R, H, dn)
    w_v = wkb[..., m.qk_nope_head_dim:]                # (R, H, dv)

    q_lat = jnp.einsum("bhd,rhd->bhr", q_nope.astype(jnp.float32),
                       w_k.astype(jnp.float32))        # (B, H, R)
    lat = latent_cache.astype(jnp.float32) * KV_CACHE_SCALE   # (B, S_l, R)
    rp = rope_cache.astype(jnp.float32) * KV_CACHE_SCALE      # (B, S_l, dr)
    scores = (jnp.einsum("bhr,bsr->bhs", q_lat, lat)
              + jnp.einsum("bhd,bsd->bhs", q_rope.astype(jnp.float32), rp))
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    scores = scores * scale

    slot = lane * s_local + jnp.arange(s_local)
    mask = (slot <= pos)[None, None, :]
    scores = jnp.where(mask, scores, NEG_INF)

    # two-phase tree softmax (C3) over latent context tiles
    m_loc = jnp.max(scores, axis=-1)
    m_glob = tree_max(m_loc, axis_name)
    pexp = jnp.exp(scores - m_glob[..., None])
    d_loc = jnp.sum(pexp, axis=-1)
    o_lat = jnp.einsum("bhs,bsr->bhr", pexp, lat)
    o_lat = tree_sum(o_lat, axis_name)
    den = tree_sum(d_loc, axis_name)
    o_lat = o_lat / jnp.maximum(den[..., None], 1e-30)

    out = jnp.einsum("bhr,rhd->bhd", o_lat, w_v.astype(jnp.float32))  # (B, H, dv)
    out = apply_linear(p["o"], out.reshape(b, h * m.v_head_dim).astype(x.dtype),
                       mode, **kw)
    return out, latent_cache, rope_cache


def _dense_weight(p: Params, dtype) -> jax.Array:
    """Materialize a linear's weight (for the MLA absorb einsums)."""
    if "w" in p:
        from repro.core.ternary import ste_quantize
        return ste_quantize(p["w"].astype(jnp.float32)).astype(dtype)
    from repro.core.ternary import unpack2
    return (unpack2(p["packed"]).astype(jnp.float32) * p["scale"]).astype(dtype)
