"""Activation-sharding context: constraints injected into model internals.

GSPMD propagates shardings from weights/inputs, but inside nested attention
scans it can pick rotating tile shardings that cost an all-to-all per
(q-chunk × k-chunk) tile — measured at ×3776 one-GiB collectives for
deepseek-v2 train (§Perf cell A). Pinning q/k/v to a HEAD-sharded layout
keeps every tile op lane-local (the head axis survives the chunking
reshapes untouched).

Model code cannot thread mesh objects through every call, so the active
shardings live in a contextvar that the Model sets while tracing; constrain()
is a no-op when unset or when a dimension doesn't divide its axis.
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Dict, Optional

import jax

_CTX: contextvars.ContextVar[Dict[str, object]] = contextvars.ContextVar(
    "act_shardings", default={})


@contextlib.contextmanager
def scope(**shardings):
    """Set named shardings for the duration of a trace (None entries skipped)."""
    new = {**_CTX.get(), **{k: v for k, v in shardings.items() if v is not None}}
    token = _CTX.set(new)
    try:
        yield
    finally:
        _CTX.reset(token)


def _divides(x: jax.Array, sharding) -> bool:
    spec = getattr(sharding, "spec", None)
    mesh = getattr(sharding, "mesh", None)
    if spec is None or mesh is None:
        return True
    for dim, part in zip(x.shape, spec):
        if part is None:
            continue
        names = part if isinstance(part, tuple) else (part,)
        extent = 1
        for n in names:
            extent *= mesh.shape[n]
        if dim % extent:
            return False
    return True


def constrain(x: jax.Array, kind: str) -> jax.Array:
    """Apply the context sharding registered under ``kind`` if compatible."""
    s = _CTX.get().get(kind)
    if s is None:
        return x
    spec = getattr(s, "spec", ())
    if len(spec) != x.ndim or not _divides(x, s):
        return x
    return jax.lax.with_sharding_constraint(x, s)


def active(kind: str) -> Optional[object]:
    return _CTX.get().get(kind)
