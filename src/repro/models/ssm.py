"""Mamba2 / SSD block (mamba2-1.3b, zamba2-7b hybrid).

State-space duality form (arXiv:2405.21060): per head, a scalar decay
``a_t = exp(dt_t·A)`` and rank-1 input ``dt_t·x_t⊗B_t`` drive the state
``S ∈ (P, N)``; output ``y_t = S_t·C_t + D·x_t``.

* **train/prefill** — chunked SSD: within a chunk the quadratic
  "attention-like" form (masked (L×L) decay matmul), across chunks a
  lax.scan carries the state. O(S·L) instead of O(S²): this is why the
  ``long_500k`` cell runs for the SSM/hybrid archs only.
* **decode** — O(1) recurrent update of (state, conv window).

TOM applicability (DESIGN.md §4): no attention → C3 inapplicable; the in/out
projections are ternary-packed lane-tiled linears (C1/C2) and the SSD state
update maps to the Vector-Unit class of ops.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, SSMConfig
from repro.models import layers
from repro.models.layers import Params, apply_linear, init_linear, linear_spec


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    nheads = d_in // s.head_dim
    conv_dim = d_in + 2 * s.num_groups * s.state_size
    proj_out = 2 * d_in + 2 * s.num_groups * s.state_size + nheads
    return s, d_in, nheads, conv_dim, proj_out


def init_mamba2(key: jax.Array, cfg: ModelConfig, mode: str, dtype=jnp.bfloat16) -> Params:
    s, d_in, nheads, conv_dim, proj_out = _dims(cfg)
    ks = jax.random.split(key, 4)
    return {
        "in_proj": init_linear(ks[0], cfg.d_model, proj_out, mode, dtype=dtype,
                               lora=layers.lora_for(cfg, "in_proj", mode)),
        "conv_w": jax.random.normal(ks[1], (s.conv_width, conv_dim), jnp.float32) * 0.2,
        "conv_b": jnp.zeros((conv_dim,), jnp.float32),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, nheads, dtype=jnp.float32)),
        "d_skip": jnp.ones((nheads,), jnp.float32),
        "dt_bias": jnp.zeros((nheads,), jnp.float32),
        "gate_norm": layers.init_rms_norm(d_in),
        "out_proj": init_linear(ks[2], d_in, cfg.d_model, mode, dtype=dtype,
                                lora=layers.lora_for(cfg, "out_proj", mode)),
    }


def mamba2_spec(cfg: ModelConfig, mode: str, dtype=jnp.bfloat16) -> Params:
    s, d_in, nheads, conv_dim, proj_out = _dims(cfg)
    f32 = jnp.float32
    return {
        "in_proj": linear_spec(cfg.d_model, proj_out, mode, dtype=dtype),
        "conv_w": jax.ShapeDtypeStruct((s.conv_width, conv_dim), f32),
        "conv_b": jax.ShapeDtypeStruct((conv_dim,), f32),
        "a_log": jax.ShapeDtypeStruct((nheads,), f32),
        "d_skip": jax.ShapeDtypeStruct((nheads,), f32),
        "dt_bias": jax.ShapeDtypeStruct((nheads,), f32),
        "gate_norm": {"w": jax.ShapeDtypeStruct((d_in,), f32)},
        "out_proj": linear_spec(d_in, cfg.d_model, mode, dtype=dtype),
    }


def _split_proj(proj: jax.Array, cfg: ModelConfig):
    s, d_in, nheads, _, _ = _dims(cfg)
    gn = s.num_groups * s.state_size
    z = proj[..., :d_in]
    xbc = proj[..., d_in:d_in + d_in + 2 * gn]
    dt = proj[..., -nheads:]
    return z, xbc, dt


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Per-channel causal conv over time. xbc: (B, S, C); w: (W, C)."""
    width = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (width - 1, 0), (0, 0)))
    out = jnp.zeros_like(xbc, dtype=jnp.float32)
    for i in range(width):  # tiny static loop (W=4)
        out = out + pad[:, i:i + xbc.shape[1]].astype(jnp.float32) * w[width - 1 - i]
    return jax.nn.silu(out + b).astype(xbc.dtype)


def _expand_groups(bc: jax.Array, nheads: int, g: int) -> jax.Array:
    """(B, S, G, N) → (B, S, H, N) by repeating each group over its heads."""
    return jnp.repeat(bc, nheads // g, axis=2)


# ---------------------------------------------------------------------------
# Chunked SSD (train / prefill)
# ---------------------------------------------------------------------------


def ssd_chunked(x: jax.Array, dt: jax.Array, a: jax.Array, b_in: jax.Array,
                c_in: jax.Array, d_skip: jax.Array, chunk: int
                ) -> jax.Array:
    """SSD over a full sequence with chunked state passing.

    x: (B, S, H, P); dt: (B, S, H); a: (H,) negative; b_in/c_in: (B, S, H, N).
    Returns y: (B, S, H, P).
    """
    bsz, s_len, h, p = x.shape
    n = b_in.shape[-1]
    assert s_len % chunk == 0, (s_len, chunk)
    nc = s_len // chunk

    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    bf = b_in.astype(jnp.float32)
    cf = c_in.astype(jnp.float32)

    def to_chunks(t):
        return t.reshape(bsz, nc, chunk, *t.shape[2:]).swapaxes(0, 1)

    xc, dtc, bc, cc = map(to_chunks, (xf, dtf, bf, cf))  # (nc, B, L, ...)

    log_a = dtc * a[None, None, None, :]                 # (nc, B, L, H) ≤ 0
    cum = jnp.cumsum(log_a, axis=2)                      # within-chunk cumulative

    def chunk_step(state, inp):
        x_i, dt_i, b_i, c_i, la_i, cum_i = inp           # (B, L, ...)
        # inter-chunk: y_prev[t] = exp(cum[t]) · C_t · S_prev
        decay_in = jnp.exp(cum_i)                        # (B, L, H)
        y_inter = jnp.einsum("blhn,bhpn->blhp", c_i * decay_in[..., None], state)
        # intra-chunk quadratic form
        scores = jnp.einsum("blhn,bshn->bhls", c_i, b_i)         # (B,H,L,L)
        rel = cum_i.transpose(0, 2, 1)[..., :, None] - cum_i.transpose(0, 2, 1)[..., None, :]
        causal = jnp.tril(jnp.ones((x_i.shape[1], x_i.shape[1]), bool))
        # mask the EXPONENT, not exp's output: above the diagonal rel > 0 can
        # overflow exp to +inf, and where(mask, inf, 0) back-propagates
        # 0·inf = NaN into every gradient. (On the causal side rel ≤ 0 always.)
        rel = jnp.where(causal[None, None], rel, -1e30)
        gamma = jnp.exp(rel)                                      # (B,H,L,L)
        y_intra = jnp.einsum("bhls,bsh,bshp->blhp", scores * gamma, dt_i, x_i)
        # state update: S_new = S·exp(cum_L) + Σ_s exp(cum_L − cum_s)·dt_s·x_s⊗B_s
        tail = jnp.exp(cum_i[:, -1:, :] - cum_i)          # (B, L, H)
        s_new = state * jnp.exp(cum_i[:, -1])[..., None, None]
        s_new = s_new + jnp.einsum("blh,blhp,blhn->bhpn", tail * dt_i, x_i, b_i)
        return s_new, y_inter + y_intra

    init = jnp.zeros((bsz, h, p, n), jnp.float32)
    _, yc = jax.lax.scan(chunk_step, init, (xc, dtc, bc, cc, log_a, cum))
    y = yc.swapaxes(0, 1).reshape(bsz, s_len, h, p)
    y = y + xf * d_skip[None, None, :, None]
    return y.astype(x.dtype)


def ssd_sequential_ref(x, dt, a, b_in, c_in, d_skip):
    """O(S) sequential oracle for tests."""
    bsz, s_len, h, p = x.shape
    n = b_in.shape[-1]

    def step(state, inp):
        x_t, dt_t, b_t, c_t = inp
        decay = jnp.exp(dt_t * a)                        # (B, H)
        state = state * decay[..., None, None] + jnp.einsum(
            "bh,bhp,bhn->bhpn", dt_t, x_t, b_t)
        y = jnp.einsum("bhpn,bhn->bhp", state, c_t)
        return state, y

    xs = (x.astype(jnp.float32).swapaxes(0, 1), dt.astype(jnp.float32).swapaxes(0, 1),
          b_in.astype(jnp.float32).swapaxes(0, 1), c_in.astype(jnp.float32).swapaxes(0, 1))
    init = jnp.zeros((bsz, h, p, n), jnp.float32)
    _, ys = jax.lax.scan(step, init, xs)
    y = ys.swapaxes(0, 1) + x.astype(jnp.float32) * d_skip[None, None, :, None]
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# Block-level apply
# ---------------------------------------------------------------------------


def mamba2_train(p: Params, xin: jax.Array, cfg: ModelConfig, mode: str,
                 **kw) -> jax.Array:
    """Full-sequence Mamba2 block. xin: (B, S, D)."""
    s, d_in, nheads, conv_dim, _ = _dims(cfg)
    bsz, s_len, _ = xin.shape
    proj = apply_linear(p["in_proj"], xin, mode, **kw)
    z, xbc, dt = _split_proj(proj, cfg)
    xbc = _causal_conv(xbc, p["conv_w"], p["conv_b"])
    gn = s.num_groups * s.state_size
    x = xbc[..., :d_in].reshape(bsz, s_len, nheads, s.head_dim)
    b_in = xbc[..., d_in:d_in + gn].reshape(bsz, s_len, s.num_groups, s.state_size)
    c_in = xbc[..., d_in + gn:].reshape(bsz, s_len, s.num_groups, s.state_size)
    b_in = _expand_groups(b_in, nheads, s.num_groups)
    c_in = _expand_groups(c_in, nheads, s.num_groups)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"])
    chunk = min(s.chunk_size, s_len)
    y = ssd_chunked(x, dt, a, b_in, c_in, p["d_skip"], chunk)
    y = y.reshape(bsz, s_len, d_in)
    y = layers.rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                        p["gate_norm"]["w"], cfg.norm_eps)
    return apply_linear(p["out_proj"], y, mode, **kw)


def init_ssm_state(cfg: ModelConfig, batch: int, n_layers: int) -> Params:
    s, d_in, nheads, conv_dim, _ = _dims(cfg)
    return {
        "ssm": jnp.zeros((n_layers, batch, nheads, s.head_dim, s.state_size), jnp.float32),
        "conv": jnp.zeros((n_layers, batch, s.conv_width - 1, conv_dim), jnp.float32),
    }


def ssm_state_spec(cfg: ModelConfig, batch: int, n_layers: int) -> Params:
    s, d_in, nheads, conv_dim, _ = _dims(cfg)
    return {
        "ssm": jax.ShapeDtypeStruct((n_layers, batch, nheads, s.head_dim, s.state_size),
                                    jnp.float32),
        "conv": jax.ShapeDtypeStruct((n_layers, batch, s.conv_width - 1, conv_dim),
                                     jnp.float32),
    }


def mamba2_decode(p: Params, xin: jax.Array, ssm_state: jax.Array,
                  conv_state: jax.Array, cfg: ModelConfig, mode: str, **kw
                  ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One-token recurrent update. xin: (B, D); states are this layer's."""
    s, d_in, nheads, conv_dim, _ = _dims(cfg)
    bsz, _ = xin.shape
    proj = apply_linear(p["in_proj"], xin, mode, **kw)
    z, xbc, dt = _split_proj(proj, cfg)
    window = jnp.concatenate([conv_state, xbc[:, None].astype(jnp.float32)], axis=1)
    conv_out = jnp.einsum("bwc,wc->bc", window, p["conv_w"]) + p["conv_b"]
    xbc = jax.nn.silu(conv_out).astype(xin.dtype)
    new_conv = window[:, 1:]

    gn = s.num_groups * s.state_size
    x = xbc[..., :d_in].reshape(bsz, nheads, s.head_dim)
    b_in = xbc[..., d_in:d_in + gn].reshape(bsz, s.num_groups, s.state_size)
    c_in = xbc[..., d_in + gn:].reshape(bsz, s.num_groups, s.state_size)
    b_in = jnp.repeat(b_in, nheads // s.num_groups, axis=1)
    c_in = jnp.repeat(c_in, nheads // s.num_groups, axis=1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"])

    decay = jnp.exp(dt * a)                              # (B, H)
    new_state = ssm_state * decay[..., None, None] + jnp.einsum(
        "bh,bhp,bhn->bhpn", dt, x.astype(jnp.float32), b_in.astype(jnp.float32))
    y = jnp.einsum("bhpn,bhn->bhp", new_state, c_in.astype(jnp.float32))
    y = y + x.astype(jnp.float32) * p["d_skip"][None, :, None]
    y = y.reshape(bsz, d_in).astype(xin.dtype)
    y = layers.rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                        p["gate_norm"]["w"], cfg.norm_eps)
    out = apply_linear(p["out_proj"], y, mode, **kw)
    return out, new_state, new_conv
