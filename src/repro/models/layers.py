"""Shared model layers: norms, RoPE, the three-mode ternary Linear, FFNs,
embeddings.

Every linear in every architecture runs in one of three modes (DESIGN.md §2):

  * ``qat``   — float master weights, BitNet-style ternary STE fake-quant on
                the forward (+ fp8 fake-quant on activations when enabled).
                Used for training from scratch (the way BitNet-2B was made).
  * ``serve`` — weights are packed 2-bit 'ROM' (uint8 (K/4, N) + f32 scale),
                immutable; the paper's deployment form.
  * ``qlora`` — serve-mode base + trainable float LoRA adapters (C4).

Parameters are plain dict pytrees so they stack cleanly for scan-over-layers
and shard with PartitionSpec trees (models/sharding.py).
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import fp8, qlora, ternary

Params = Dict[str, jax.Array]

#: Static fp8 KV-cache scale (e4m3 is floating — the scale only guards
#: overflow past ±448; post-norm K/V magnitudes are O(1..30)).
KV_CACHE_SCALE = 4.0


# ---------------------------------------------------------------------------
# Norms & activations
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * weight.astype(jnp.float32)).astype(dt)


def init_rms_norm(d: int, dtype=jnp.float32) -> Params:
    return {"w": jnp.ones((d,), dtype)}


ACTIVATIONS = {
    "gelu": jax.nn.gelu,
    "silu": jax.nn.silu,
    "relu2": lambda x: jnp.square(jax.nn.relu(x)),
}


def lora_for(cfg, name: str, mode: str) -> Optional[qlora.LoRASpec]:
    """LoRASpec for projection ``name`` iff qlora mode and it's a target.

    Target names follow LoRA convention: q/k/v/o (attention; MLA's q_b and
    kv_b count as 'q'/'v'), up/gate/down (FFN), in_proj/out_proj (Mamba2)."""
    if mode != "qlora" or cfg.lora is None:
        return None
    targets = cfg.lora.targets
    if targets == ("all",) or name in targets:
        return qlora.LoRASpec(rank=cfg.lora.rank, alpha=cfg.lora.alpha,
                              ternary=cfg.lora.ternary_adapters)
    return None


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, D) or (..., H, D) with positions broadcastable to S."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                     # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    cos = jnp.cos(angles)[..., None, :]              # (..., S, 1, D/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Three-mode Linear
# ---------------------------------------------------------------------------


def init_linear(key: jax.Array, k: int, n: int, mode: str, *,
                dtype=jnp.bfloat16, lora: Optional[qlora.LoRASpec] = None) -> Params:
    if mode == "qat":
        w = jax.random.normal(key, (k, n), jnp.float32) * (k ** -0.5)
        return {"w": w.astype(dtype)}
    # serve / qlora: packed ROM form
    w = jax.random.normal(key, (k, n), jnp.float32) * (k ** -0.5)
    t, s = ternary.quantize(w)
    p: Params = {"packed": ternary.pack2(t), "scale": s}
    if mode == "qlora" and lora is not None:
        p["lora"] = qlora.init_adapter(jax.random.fold_in(key, 1), k, n, lora)
    return p


def linear_spec(k: int, n: int, mode: str, *,
                lora: Optional[qlora.LoRASpec] = None, dtype=jnp.bfloat16) -> Params:
    """ShapeDtypeStruct tree mirroring init_linear (for the dry-run)."""
    if mode == "qat":
        return {"w": jax.ShapeDtypeStruct((k, n), dtype)}
    p: Params = {
        "packed": jax.ShapeDtypeStruct((k // 4, n), jnp.uint8),
        "scale": jax.ShapeDtypeStruct((), jnp.float32),
    }
    if mode == "qlora" and lora is not None:
        p["lora"] = {
            "a": jax.ShapeDtypeStruct((k, lora.rank), jnp.float32),
            "b": jax.ShapeDtypeStruct((lora.rank, n), jnp.float32),
        }
    return p


def apply_linear(p: Params, x: jax.Array, mode: str, *,
                 fp8_acts: bool = False,
                 lora: Optional[qlora.LoRASpec] = None,
                 train: bool = False,
                 fuse: bool = False,
                 kv_dtype: str = "f32",
                 adapter_idx: Optional[jax.Array] = None) -> jax.Array:  # noqa: ARG001
    # ``fuse``/``kv_dtype`` are consumed by fused/attention call sites;
    # accepted (and ignored) here so the flags thread through **kw untouched.
    # ``adapter_idx`` (B,) selects each batch row's resident multi-tenant
    # adapter; it only acts on projections carrying a ``lora_mt`` stack.
    """The mode dispatch. In serve/qlora mode the base is ternary-packed ROM:
    decode-then-matmul (XLA fuses; the Pallas kernel path is selected by the
    serving engine for the hot GEMVs where shapes allow)."""
    if fp8_acts:
        x = fp8.fake_quantize(x)
    if mode == "qat":
        w = ternary.ste_quantize(p["w"].astype(jnp.float32))
        y = jnp.einsum("...k,kn->...n", x.astype(jnp.float32), w,
                       preferred_element_type=jnp.float32)
        return y.astype(x.dtype)
    # §Perf: decode the 2-bit ROM to bf16, not f32 — ternary {−1,0,+1} is
    # exact in bf16 and the dot still accumulates f32; halves the dominant
    # dequant HBM traffic (the Pallas kernel decodes in-VMEM for free).
    w = ternary.unpack2(p["packed"]).astype(jnp.bfloat16)
    # ROM immutability: gradients must not reach the base weight/scale — but
    # MUST keep flowing through x to earlier layers (stop-grad the weight
    # side only, never the matmul output).
    y = jnp.einsum("...k,kn->...n", x.astype(jnp.bfloat16), w,
                   preferred_element_type=jnp.float32)
    y = (y * jax.lax.stop_gradient(p["scale"])).astype(x.dtype)
    if mode == "qlora" and "lora" in p:
        y = y + qlora.adapter_path(x, p["lora"], lora or qlora.LoRASpec(),
                                   train=train).astype(y.dtype)
    if adapter_idx is not None and "lora_mt" in p:
        y = y + _multi_tenant_lora(p["lora_mt"], x, adapter_idx).astype(y.dtype)
    return y


def _multi_tenant_lora(mt: Params, x: jax.Array, adapter_idx: jax.Array) -> jax.Array:
    """Per-row gathered ternary-LoRA contribution (serving/adapters/). Rows
    whose index is 0 hit the null adapter (zero codes, zero scale) and
    contribute exactly 0 — bit-identical to a no-adapter engine."""
    from repro.kernels.batched_lora import ops as blora_ops
    return blora_ops.batched_lora(x, mt["a"], mt["b"], mt["s"], adapter_idx)


def apply_linear_fused(parts, x: jax.Array, mode: str, *,
                       fp8_acts: bool = False, train: bool = False,
                       lora=None, fuse: bool = True,
                       adapter_idx: Optional[jax.Array] = None):
    """Fused multi-projection linear: one matmul over N-concatenated weights.

    With Fig-7a K-sharding every GEMV's partial sum costs one tree reduction;
    q/k/v (and up/gate) share the same input x, so concatenating their packed
    weights along N turns 3 (resp. 2) all-reduces into ONE over the concat
    width — a pure collective-count win (§Perf cell C). Per-tensor scales are
    applied per output slice after the shared matmul. Serve/qlora path only.
    """
    if fp8_acts:
        x = fp8.fake_quantize(x)
    if mode == "qat":
        ws = [ternary.ste_quantize(p["w"].astype(jnp.float32)) for p in parts]
        w = jnp.concatenate(ws, axis=-1)
        y = jnp.einsum("...k,kn->...n", x.astype(jnp.float32), w,
                       preferred_element_type=jnp.float32)
        outs, off = [], 0
        for p in parts:
            n = p["w"].shape[-1]
            outs.append(y[..., off:off + n].astype(x.dtype))
            off += n
        return outs
    packed = jnp.concatenate([p["packed"] for p in parts], axis=-1)
    w = ternary.unpack2(packed).astype(jnp.bfloat16)
    y = jnp.einsum("...k,kn->...n", x.astype(jnp.bfloat16), w,
                   preferred_element_type=jnp.float32)
    outs, off = [], 0
    for p in parts:
        n = p["packed"].shape[-1]
        yi = (y[..., off:off + n]
              * jax.lax.stop_gradient(p["scale"])).astype(x.dtype)
        if mode == "qlora" and "lora" in p:
            yi = yi + qlora.adapter_path(x, p["lora"], lora or qlora.LoRASpec(),
                                         train=train).astype(yi.dtype)
        if adapter_idx is not None and "lora_mt" in p:
            yi = yi + _multi_tenant_lora(p["lora_mt"], x, adapter_idx).astype(yi.dtype)
        outs.append(yi)
        off += n
    return outs


# ---------------------------------------------------------------------------
# FFN (swiglu / gelu / relu2), dense
# ---------------------------------------------------------------------------


def init_ffn(key: jax.Array, d: int, dff: int, kind: str, mode: str, *,
             lora_map: Optional[Dict[str, "qlora.LoRASpec"]] = None,
             **kw) -> Params:
    ks = jax.random.split(key, 3)
    lm = lora_map or {}
    p = {"up": init_linear(ks[0], d, dff, mode, lora=lm.get("up"), **kw),
         "down": init_linear(ks[1], dff, d, mode, lora=lm.get("down"), **kw)}
    if kind == "swiglu":
        p["gate"] = init_linear(ks[2], d, dff, mode, lora=lm.get("gate"), **kw)
    return p


def ffn_spec(d: int, dff: int, kind: str, mode: str, **kw) -> Params:
    p = {"up": linear_spec(d, dff, mode, **kw),
         "down": linear_spec(dff, d, mode, **kw)}
    if kind == "swiglu":
        p["gate"] = linear_spec(d, dff, mode, **kw)
    return p


def apply_ffn(p: Params, x: jax.Array, kind: str, mode: str, **kw) -> jax.Array:
    if kw.get("fuse") and kind == "swiglu" and mode != "qat":
        sub = {k: v for k, v in kw.items() if k not in ("fuse", "kv_dtype")}
        up, gate = apply_linear_fused([p["up"], p["gate"]], x, mode, **sub)
        h = jax.nn.silu(gate.astype(jnp.float32)).astype(up.dtype) * up
    else:
        up = apply_linear(p["up"], x, mode, **kw)
        if kind == "swiglu":
            gate = apply_linear(p["gate"], x, mode, **kw)
            h = jax.nn.silu(gate.astype(jnp.float32)).astype(up.dtype) * up
        else:
            h = ACTIVATIONS[kind if kind in ACTIVATIONS else "gelu"](up)
    return apply_linear(p["down"], h, mode, **kw)


# ---------------------------------------------------------------------------
# Embedding (row-packed ternary in serve mode) + LM head
# ---------------------------------------------------------------------------


def pack_rows(t: jax.Array) -> jax.Array:
    """Ternary (V, D) → uint8 (V, D/4): each row packs its own features, so
    a token gather returns packed rows that unpack locally."""
    v, d = t.shape
    assert d % 4 == 0
    c = ternary.encode2(t.reshape(v, d // 4, 4).swapaxes(-1, -2))  # (V, 4, D/4)
    return (c[:, 0] | (c[:, 1] << 2) | (c[:, 2] << 4) | (c[:, 3] << 6)).astype(jnp.uint8)


def unpack_rows(p: jax.Array) -> jax.Array:
    """uint8 (..., D/4) → int8 (..., D)."""
    slots = [ternary.decode2((p >> (2 * i)) & 3) for i in range(4)]
    st = jnp.stack(slots, axis=-1)  # (..., D/4, 4)
    return st.reshape(*p.shape[:-1], p.shape[-1] * 4)


def init_embedding(key: jax.Array, vocab: int, d: int, mode: str,
                   dtype=jnp.bfloat16) -> Params:
    w = jax.random.normal(key, (vocab, d), jnp.float32) * 0.02
    if mode == "qat":
        return {"w": w.astype(dtype)}
    t, s = ternary.quantize(w)
    return {"packed_rows": pack_rows(t), "scale": s}


def embedding_spec(vocab: int, d: int, mode: str, dtype=jnp.bfloat16) -> Params:
    if mode == "qat":
        return {"w": jax.ShapeDtypeStruct((vocab, d), dtype)}
    return {"packed_rows": jax.ShapeDtypeStruct((vocab, d // 4), jnp.uint8),
            "scale": jax.ShapeDtypeStruct((), jnp.float32)}


def embed_tokens(p: Params, tokens: jax.Array, mode: str, dtype=jnp.bfloat16) -> jax.Array:
    if mode == "qat":
        return p["w"][tokens].astype(dtype)
    rows = p["packed_rows"][tokens]               # (..., D/4) uint8 gather
    return (unpack_rows(rows).astype(jnp.float32) * p["scale"]).astype(dtype)


def lm_head_logits(head_p: Params, x: jax.Array, mode: str) -> jax.Array:
    """x (..., D) → logits (..., V). Head weight layout is (D, V) (or the
    packed column form); tied embeddings pass the embedding params through
    models/transformer.py which transposes appropriately."""
    return apply_linear(head_p, x, mode).astype(jnp.float32)


def tied_logits(embed_p: Params, x: jax.Array, mode: str) -> jax.Array:
    if mode == "qat":
        return jnp.einsum("...d,vd->...v", x.astype(jnp.float32),
                          embed_p["w"].astype(jnp.float32))
    w = unpack_rows(embed_p["packed_rows"]).astype(jnp.float32) * embed_p["scale"]
    return jnp.einsum("...d,vd->...v", x.astype(jnp.float32), w)
