"""Mixture-of-Experts FFN (arctic-480b, deepseek-v2-236b).

Routing: softmax router with top-k selection, optional DeepSeek-V3-style
aux-loss-free bias (added for *selection* only), optional load-balance aux
loss for training, and capacity-based token dropping.

Dispatch is sort-based (MegaBlocks/MaxText-style): token→expert assignments
are argsorted by expert id, written into a static (E, C, D) buffer, processed
by stacked expert FFNs, and combined with the gate weights. No (T, E, C)
one-hot dispatch tensor is ever materialized.

Sharding (models/sharding.py):
  * ``tp`` (paper-faithful): every expert's weight is K-sharded over the
    ``model`` axis like any other linear — lanes synchronize via the
    reduction tree only (psum), exactly the paper's constraint.
  * ``ep`` (beyond-paper §Perf variant): the stacked expert dim is sharded
    over ``model``; XLA turns the dispatch scatter into an all-to-all.
One flag flips the PartitionSpec; the math here is identical.

TOM applicability: every expert weight is ternary-packed ROM (C1) and the
shared/dense branches follow Fig 7a tiling (C2). See DESIGN.md §4.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, MoEConfig
from repro.core import ternary
from repro.models import layers
from repro.models.layers import Params, apply_linear, init_linear, linear_spec


# ---------------------------------------------------------------------------
# Stacked expert linears (E experts as one leading axis)
# ---------------------------------------------------------------------------


def init_expert_linear(key: jax.Array, e: int, k: int, n: int, mode: str,
                       dtype=jnp.bfloat16) -> Params:
    if mode == "qat":
        w = jax.random.normal(key, (e, k, n), jnp.float32) * (k ** -0.5)
        return {"w": w.astype(dtype)}
    w = jax.random.normal(key, (e, k, n), jnp.float32) * (k ** -0.5)
    t, s = jax.vmap(ternary.quantize)(w)
    return {"packed": jax.vmap(ternary.pack2)(t), "scale": s.reshape(e, 1, 1)}


def expert_linear_spec(e: int, k: int, n: int, mode: str, dtype=jnp.bfloat16) -> Params:
    if mode == "qat":
        return {"w": jax.ShapeDtypeStruct((e, k, n), dtype)}
    return {"packed": jax.ShapeDtypeStruct((e, k // 4, n), jnp.uint8),
            "scale": jax.ShapeDtypeStruct((e, 1, 1), jnp.float32)}


def apply_expert_linear(p: Params, x: jax.Array, mode: str) -> jax.Array:
    """x: (E, C, K) → (E, C, N), expert-stacked weights."""
    if mode == "qat":
        w = ternary.ste_quantize(p["w"].astype(jnp.float32))
        return jnp.einsum("eck,ekn->ecn", x.astype(jnp.float32), w).astype(x.dtype)
    # stop-grad the (dequantized) weight only — x-path gradients must survive.
    # bf16 decode: ternary is exact in bf16; scale applied after the f32-accum
    # dot (halves expert-dequant HBM traffic, §Perf B).
    w = jax.lax.stop_gradient(ternary.unpack2(p["packed"]).astype(jnp.bfloat16))
    y = jnp.einsum("eck,ekn->ecn", x.astype(jnp.bfloat16), w,
                   preferred_element_type=jnp.float32)
    y = y * jax.lax.stop_gradient(p["scale"])
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# Router
# ---------------------------------------------------------------------------


def init_moe(key: jax.Array, cfg: ModelConfig, mode: str, dtype=jnp.bfloat16) -> Params:
    e = cfg.moe
    d = cfg.d_model
    dff = e.expert_d_ff or cfg.d_ff
    ks = jax.random.split(key, 8)
    p: Params = {
        "router": {"w": jax.random.normal(ks[0], (d, e.num_experts), jnp.float32) * 0.02},
        "up": init_expert_linear(ks[1], e.num_experts, d, dff, mode, dtype),
        "gate": init_expert_linear(ks[2], e.num_experts, d, dff, mode, dtype),
        "down": init_expert_linear(ks[3], e.num_experts, dff, d, mode, dtype),
    }
    if e.router_aux_free_bias:
        p["router"]["bias"] = jnp.zeros((e.num_experts,), jnp.float32)
    if e.num_shared_experts:
        p["shared"] = layers.init_ffn(ks[4], d, e.num_shared_experts * dff,
                                      "swiglu", mode, dtype=dtype)
    if e.dense_residual_d_ff:
        p["dense_residual"] = layers.init_ffn(ks[5], d, e.dense_residual_d_ff,
                                              "swiglu", mode, dtype=dtype)
    return p


def moe_spec(cfg: ModelConfig, mode: str, dtype=jnp.bfloat16) -> Params:
    e = cfg.moe
    d = cfg.d_model
    dff = e.expert_d_ff or cfg.d_ff
    p: Params = {
        "router": {"w": jax.ShapeDtypeStruct((d, e.num_experts), jnp.float32)},
        "up": expert_linear_spec(e.num_experts, d, dff, mode, dtype),
        "gate": expert_linear_spec(e.num_experts, d, dff, mode, dtype),
        "down": expert_linear_spec(e.num_experts, dff, d, mode, dtype),
    }
    if e.router_aux_free_bias:
        p["router"]["bias"] = jax.ShapeDtypeStruct((e.num_experts,), jnp.float32)
    if e.num_shared_experts:
        p["shared"] = layers.ffn_spec(d, e.num_shared_experts * dff, "swiglu", mode,
                                      dtype=dtype)
    if e.dense_residual_d_ff:
        p["dense_residual"] = layers.ffn_spec(d, e.dense_residual_d_ff, "swiglu", mode,
                                              dtype=dtype)
    return p


def route(p_router: Params, x: jax.Array, e: MoEConfig
          ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (top-k expert ids (T,k), gates (T,k), aux_loss ())."""
    logits = jnp.einsum("td,de->te", x.astype(jnp.float32),
                        p_router["w"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    select = logits + p_router.get("bias", 0.0)  # aux-free bias: selection only
    _, idx = jax.lax.top_k(select, e.num_experts_per_tok)          # (T, k)
    gates = jnp.take_along_axis(probs, idx, axis=-1)               # (T, k)
    gates = gates / jnp.maximum(jnp.sum(gates, -1, keepdims=True), 1e-9)
    # Switch-style load-balance aux loss (reported; weighted by the trainer)
    t = x.shape[0]
    me = jnp.mean(probs, axis=0)
    ce = jnp.zeros((e.num_experts,)).at[idx.reshape(-1)].add(1.0) / (t * e.num_experts_per_tok)
    aux = e.num_experts * jnp.sum(me * ce)
    return idx, gates.astype(x.dtype), aux


def capacity(tokens: int, e: MoEConfig) -> int:
    c = int(tokens * e.num_experts_per_tok * e.capacity_factor / e.num_experts)
    return max(4, -(-c // 4) * 4)  # pad to a lane-friendly multiple


# ---------------------------------------------------------------------------
# Sort-based dispatch / combine
# ---------------------------------------------------------------------------


def moe_ffn(p: Params, x: jax.Array, cfg: ModelConfig, mode: str,
            **kw) -> Tuple[jax.Array, jax.Array]:
    """x: (..., D) → (..., D), plus the load-balance aux loss.

    Flattens tokens, routes, sort-dispatches into the (E, C, D) buffer,
    runs the stacked-expert SwiGLU, combines, and adds shared / dense-residual
    branches (arctic / deepseek variants).
    """
    e = cfg.moe
    d = cfg.d_model
    lead = x.shape[:-1]
    xt = x.reshape(-1, d)
    t = xt.shape[0]
    c = capacity(t, e)

    idx, gates, aux = route(p["router"], xt, e)                    # (T,k)
    k = e.num_experts_per_tok

    te = idx.reshape(-1)                                           # (T*k,)
    tok = jnp.repeat(jnp.arange(t), k)                             # (T*k,)
    gate_flat = gates.reshape(-1)

    order = jnp.argsort(te, stable=True)
    te_s, tok_s, gate_s = te[order], tok[order], gate_flat[order]
    counts = jnp.zeros((e.num_experts,), jnp.int32).at[te].add(1)
    starts = jnp.cumsum(counts) - counts
    pos_in_e = jnp.arange(t * k) - starts[te_s]
    valid = pos_in_e < c
    dest = jnp.where(valid, te_s * c + pos_in_e, e.num_experts * c)  # drop slot

    buf = jnp.zeros((e.num_experts * c + 1, d), xt.dtype)
    buf = buf.at[dest].set(xt[tok_s], mode="drop")
    buf = buf[:-1].reshape(e.num_experts, c, d)

    up = apply_expert_linear(p["up"], buf, mode)
    gate_h = apply_expert_linear(p["gate"], buf, mode)
    h = jax.nn.silu(gate_h.astype(jnp.float32)).astype(up.dtype) * up
    y = apply_expert_linear(p["down"], h, mode)                    # (E, C, D)

    y_flat = y.reshape(e.num_experts * c, d)
    picked = jnp.where(valid[:, None], y_flat[jnp.clip(dest, 0, e.num_experts * c - 1)], 0.0)
    # combine in bf16: the (T·k, D) gate-weighted buffer is what crosses the
    # reduction tree when experts are sharded — f32 here doubled the largest
    # collective payload in the MoE cells (§Perf B).
    out = jnp.zeros((t, d), x.dtype).at[tok_s].add(
        picked * gate_s[:, None].astype(picked.dtype))

    if "shared" in p:
        out = out + layers.apply_ffn(p["shared"], xt, "swiglu", mode, **kw)
    if "dense_residual" in p:
        out = out + layers.apply_ffn(p["dense_residual"], xt, "swiglu", mode, **kw)
    return out.reshape(*lead, d), aux
