"""Model assembly: blocks → scan-over-layers → loss / prefill / decode.

One implementation serves all ten assigned architectures plus bitnet-2b:

  * dense / vlm / audio — homogeneous GQA blocks (vlm/audio take stub
    embeddings instead of token ids; §ARCHITECTURES note).
  * moe — GQA + MoE FFN; deepseek additionally MLA attention and
    ``first_k_dense`` unstacked prefix layers.
  * ssm — homogeneous Mamba2 blocks.
  * hybrid (zamba2) — Mamba2 backbone with a SHARED attention+FFN block
    applied every ``period`` layers (one weight set reused at all positions).

Layers are stacked and scanned (compact HLO at 88 layers, XLA prefetches the
next layer's weights during the current layer — the runtime analogue of the
paper's pre-wake power gating, DESIGN.md §2.5). The LM loss is computed in
sequence chunks so (B,S,V) logits never materialize.

Distribution is GSPMD: `launch/` jits these fns with in/out shardings from
models/sharding.py. With the paper_tree strategy + context-sharded KV cache,
XLA's partitioner lowers the decode softmax to exactly the paper's two-phase
tree dataflow (all-reduce max, then all-reduce sum — verified against the
explicit shard_map implementation in core/attention.py by tests, and in the
dry-run HLO by benchmarks/roofline.py).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_mod
from repro.models import layers, moe as moe_mod, ssm as ssm_mod
from repro.models.layers import KV_CACHE_SCALE, Params

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------


def init_attn_block(key, cfg: ModelConfig, mode: str, dtype, dense_ffn: int = 0) -> Params:
    ks = jax.random.split(key, 3)
    p: Params = {"norm1": layers.init_rms_norm(cfg.d_model),
                 "norm2": layers.init_rms_norm(cfg.d_model)}
    if cfg.attention_kind == "mla":
        p["attn"] = attn_mod.init_mla(ks[0], cfg, mode, dtype=dtype)
    else:
        p["attn"] = attn_mod.init_gqa(ks[0], cfg, mode, dtype=dtype)
    ffn_lora = {n: layers.lora_for(cfg, n, mode) for n in ("up", "gate", "down")}
    if dense_ffn:
        p["ffn"] = layers.init_ffn(ks[1], cfg.d_model, dense_ffn, cfg.ffn_kind,
                                   mode, dtype=dtype, lora_map=ffn_lora)
    elif cfg.moe is not None:
        p["moe"] = moe_mod.init_moe(ks[1], cfg, mode, dtype=dtype)
    else:
        p["ffn"] = layers.init_ffn(ks[1], cfg.d_model, cfg.d_ff, cfg.ffn_kind,
                                   mode, dtype=dtype, lora_map=ffn_lora)
    return p


def attn_block_train(p: Params, x: jax.Array, cfg: ModelConfig, mode: str,
                     chunk: int, **kw) -> Tuple[jax.Array, jax.Array]:
    h = layers.rms_norm(x, p["norm1"]["w"], cfg.norm_eps)
    if cfg.attention_kind == "mla":
        a = attn_mod.mla_train(p["attn"], h, cfg, mode, chunk=chunk, **kw)
    else:
        a = attn_mod.gqa_train(p["attn"], h, cfg, mode, chunk=chunk, **kw)
    x = x + a
    h2 = layers.rms_norm(x, p["norm2"]["w"], cfg.norm_eps)
    aux = jnp.zeros((), jnp.float32)
    if "moe" in p:
        f, aux = moe_mod.moe_ffn(p["moe"], h2, cfg, mode, **kw)
    else:
        f = layers.apply_ffn(p["ffn"], h2, cfg.ffn_kind, mode, **kw)
    return x + f, aux


def attn_block_decode(p: Params, x: jax.Array, cache_slices, pos, cfg: ModelConfig,
                      mode: str, **kw):
    """x: (B, D); cache_slices: per-layer cache arrays (GQA: k,v / MLA:
    latent,k_rope). Returns (x', new_cache_slices, aux)."""
    h = layers.rms_norm(x, p["norm1"]["w"], cfg.norm_eps)
    if cfg.attention_kind == "mla":
        a, c0, c1 = _mla_decode_gspmd(p["attn"], h, cache_slices[0], cache_slices[1],
                                      pos, cfg, mode, **kw)
    else:
        a, c0, c1 = _gqa_decode_gspmd(p["attn"], h, cache_slices[0], cache_slices[1],
                                      pos, cfg, mode, **kw)
    x = x + a
    h2 = layers.rms_norm(x, p["norm2"]["w"], cfg.norm_eps)
    aux = jnp.zeros((), jnp.float32)
    if "moe" in p:
        f, aux = moe_mod.moe_ffn(p["moe"], h2, cfg, mode, **kw)
    else:
        f = layers.apply_ffn(p["ffn"], h2, cfg.ffn_kind, mode, **kw)
    return x + f, (c0, c1), aux


# --- GSPMD decode attention (context-sharded cache; stable two-phase softmax)


def _pos2d(pos: jax.Array) -> jax.Array:
    """pos () or (B,) → (B-or-1, 1) position matrix for RoPE on a 1-token x."""
    return pos[None, None] if pos.ndim == 0 else pos[:, None]


def _update_cache_at(cache: jax.Array, new: jax.Array, pos: jax.Array,
                     seq_axis: int) -> jax.Array:
    """Write one new timestep into the cache at ``pos``.

    Scalar ``pos`` (all sequences aligned — the dry-run decode cells) uses a
    single dynamic_update_slice. Vector ``pos`` (B,) (continuous batching —
    every slot at its own depth) vmaps the update over the batch axis, which
    XLA lowers to a scatter.
    """
    if pos.ndim == 0:
        idx = [jnp.zeros((), jnp.int32)] * cache.ndim
        idx[seq_axis] = pos
        return jax.lax.dynamic_update_slice(cache, new, tuple(idx))

    def one(c, n, p):  # c: cache[b], n: new[b], seq axis shifted left by 1
        idx = [jnp.zeros((), jnp.int32)] * c.ndim
        idx[seq_axis - 1] = p
        return jax.lax.dynamic_update_slice(c, n, tuple(idx))

    return jax.vmap(one)(cache, new, pos)


def _stable_softmax_attend(scores: jax.Array, values: jax.Array,
                           mask: jax.Array) -> jax.Array:
    """scores (B,H,G,S) × values (B,H,S,D) → (B,H,G,D) with the explicit
    max-subtract form. Over a context(S)-sharded mesh axis XLA lowers the max
    and sum reductions to all-reduce max / all-reduce sum — the paper's
    two-phase reduction-tree dataflow (C3)."""
    scores = jnp.where(mask, scores, NEG_INF)
    m = jnp.max(scores, axis=-1, keepdims=True)
    p = jnp.exp(scores - m)
    den = jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bhgs,bhsd->bhgd", p, values)
    return out / jnp.maximum(den, 1e-30)


def _gqa_decode_gspmd(p, x, k_cache, v_cache, pos, cfg, mode, **kw):
    b, _ = x.shape
    positions = _pos2d(pos)
    q, k_new, v_new = attn_mod._project_qkv(p, x[:, None], cfg, mode, positions, **kw)
    q = q[:, 0].reshape(b, cfg.num_kv_heads, -1, cfg.head_dim)     # (B,Hkv,G,D)
    k_new = (k_new[:, 0] / KV_CACHE_SCALE).astype(k_cache.dtype)
    v_new = (v_new[:, 0] / KV_CACHE_SCALE).astype(v_cache.dtype)
    k_cache = _update_cache_at(k_cache, k_new[:, :, None], pos, seq_axis=2)
    v_cache = _update_cache_at(v_cache, v_new[:, :, None], pos, seq_axis=2)
    s_len = k_cache.shape[2]
    # §Perf C: widening the fp8 cache to bf16 instead of f32 halves the
    # dominant decode HBM term; scores still accumulate in f32 via the dot's
    # preferred_element_type.
    wide = jnp.bfloat16 if kw.get("kv_dtype") == "bf16" else jnp.float32
    kf = k_cache.astype(wide) * KV_CACHE_SCALE
    vf = v_cache.astype(wide) * KV_CACHE_SCALE
    scores = jnp.einsum("bhgd,bhsd->bhgs", q.astype(wide), kf,
                        preferred_element_type=jnp.float32)
    scores = scores * (cfg.head_dim ** -0.5)
    if pos.ndim == 0:
        mask = (jnp.arange(s_len) <= pos)[None, None, None, :]
    else:
        mask = (jnp.arange(s_len)[None] <= pos[:, None])[:, None, None, :]
    out = _stable_softmax_attend(scores, vf, mask)
    out = out.reshape(b, cfg.q_dim).astype(x.dtype)
    return layers.apply_linear(p["o"], out, mode, **kw), k_cache, v_cache


def _mla_decode_gspmd(p, x, latent_cache, rope_cache, pos, cfg, mode, **kw):
    m = cfg.mla
    h = cfg.num_heads
    b, _ = x.shape
    positions = _pos2d(pos)
    q_nope, q_rope = attn_mod._mla_q(p, x[:, None], cfg, mode, positions, **kw)
    q_nope, q_rope = q_nope[:, 0], q_rope[:, 0]
    latent_new, k_rope_new = attn_mod._mla_latent(p, x[:, None], cfg, mode,
                                                  positions, **kw)
    latent_new = (latent_new[:, 0] / KV_CACHE_SCALE).astype(latent_cache.dtype)
    k_rope_new = (k_rope_new[:, 0] / KV_CACHE_SCALE).astype(rope_cache.dtype)
    latent_cache = _update_cache_at(latent_cache, latent_new[:, None], pos,
                                    seq_axis=1)
    rope_cache = _update_cache_at(rope_cache, k_rope_new[:, None], pos,
                                  seq_axis=1)
    wkb = attn_mod._dense_weight(p["kv_b"], jnp.float32)
    wkb = wkb.reshape(m.kv_lora_rank, h, m.qk_nope_head_dim + m.v_head_dim)
    w_k, w_v = wkb[..., :m.qk_nope_head_dim], wkb[..., m.qk_nope_head_dim:]
    q_lat = jnp.einsum("bhd,rhd->bhr", q_nope.astype(jnp.float32), w_k)
    lat = latent_cache.astype(jnp.float32) * KV_CACHE_SCALE
    rp = rope_cache.astype(jnp.float32) * KV_CACHE_SCALE
    scores = (jnp.einsum("bhr,bsr->bhs", q_lat, lat)
              + jnp.einsum("bhd,bsd->bhs", q_rope.astype(jnp.float32), rp))
    scores = scores * ((m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5)
    s_len = lat.shape[1]
    if pos.ndim == 0:
        mask = (jnp.arange(s_len) <= pos)[None, None, :]
    else:
        mask = (jnp.arange(s_len)[None] <= pos[:, None])[:, None, :]
    scores = jnp.where(mask, scores, NEG_INF)
    mx = jnp.max(scores, axis=-1, keepdims=True)
    pr = jnp.exp(scores - mx)
    den = jnp.sum(pr, axis=-1, keepdims=True)
    o_lat = jnp.einsum("bhs,bsr->bhr", pr, lat) / jnp.maximum(den, 1e-30)
    out = jnp.einsum("bhr,rhd->bhd", o_lat, w_v)
    out = out.reshape(b, h * m.v_head_dim).astype(x.dtype)
    return layers.apply_linear(p["o"], out, mode, **kw), latent_cache, rope_cache


# ---------------------------------------------------------------------------
# Hybrid pattern helpers (zamba2)
# ---------------------------------------------------------------------------


def hybrid_layout(cfg: ModelConfig) -> Tuple[int, int, int]:
    """(n_groups, mamba_per_group, trailing_mamba) for 'mmmmma...' patterns."""
    pat = cfg.block_pattern
    n_attn = pat.count("a")
    period = pat.index("a") + 1 if "a" in pat else len(pat)
    mpg = period - 1
    trailing = len(pat) - n_attn * period
    assert pat == ("m" * mpg + "a") * n_attn + "m" * trailing, "unsupported pattern"
    return n_attn, mpg, trailing


# ---------------------------------------------------------------------------
# The Model
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Model:
    cfg: ModelConfig
    mode: str = "qat"          # qat | serve | qlora
    remat: bool = True
    attn_chunk: int = 512
    loss_chunk: int = 2048
    # Optional NamedSharding for the (B, S, D) residual stream. Launch sets
    # this to P(dp, model, None) — sequence-parallel activations, so the
    # per-layer remat carry is 1/16th per lane (DESIGN.md §5). None = let
    # XLA's SPMD propagation choose.
    act_shard: Any = None
    # Optional NamedSharding for (B, S, H, D) attention tensors — pins
    # q/k/v to head-sharded so chunked-attention tiles never reshard
    # (§Perf cell A). Applied via models/act_sharding context.
    head_shard: Any = None
    # §Perf cell C levers: fuse q/k/v (and up/gate) into one matmul → one
    # tree reduction instead of 3 (2); widen the fp8 KV cache to bf16 rather
    # than f32 during attention (halves the dominant decode HBM reads).
    fuse_proj: bool = False
    kv_widen: str = "f32"
    # Paged decode attention dispatch when decode_step receives a
    # `PagedKVState` (serving/kv.py PagedKV backend):
    #   "auto"   — Pallas paged_flash_decode on TPU (block tables via scalar
    #              prefetch, pages stream HBM→VMEM), XLA gather reference on
    #              CPU (bit-identical to the dense path);
    #   "kernel" — force the Pallas kernel (interpret-mode on CPU; tests);
    #   "gather" — force the XLA gather reference.
    paged_attn: str = "auto"

    def _c(self, x: jax.Array) -> jax.Array:
        """Constrain the residual stream's sharding (3-D activations only)."""
        if self.act_shard is not None and x.ndim == 3:
            return jax.lax.with_sharding_constraint(x, self.act_shard)
        return x

    def _shard_scope(self):
        from repro.models import act_sharding
        return act_sharding.scope(heads=self.head_shard)

    @property
    def dtype(self):
        return jnp.bfloat16 if self.cfg.dtype == "bfloat16" else jnp.float32

    # -- params ------------------------------------------------------------
    def init(self, key: jax.Array) -> Params:
        cfg, mode, dtype = self.cfg, self.mode, self.dtype
        keys = jax.random.split(key, 8)
        p: Params = {"embed": layers.init_embedding(keys[0], cfg.vocab_padded,
                                                    cfg.d_model, mode, dtype),
                     "final_norm": layers.init_rms_norm(cfg.d_model)}
        if not cfg.tie_embeddings:
            p["head"] = layers.init_linear(keys[1], cfg.d_model, cfg.vocab_padded,
                                           mode, dtype=dtype)
        if cfg.family == "ssm":
            p["mamba"] = jax.vmap(
                lambda k: ssm_mod.init_mamba2(k, cfg, mode, dtype)
            )(jax.random.split(keys[2], cfg.num_layers))
        elif cfg.family == "hybrid":
            n_attn, mpg, trailing = hybrid_layout(cfg)
            n_mamba = n_attn * mpg + trailing
            p["mamba"] = jax.vmap(
                lambda k: ssm_mod.init_mamba2(k, cfg, mode, dtype)
            )(jax.random.split(keys[2], n_mamba))
            p["shared_attn"] = init_attn_block(keys[3], cfg, mode, dtype)
        else:
            n_scan = cfg.num_layers
            k_dense = cfg.moe.first_k_dense if cfg.moe else 0
            if k_dense:
                p["prefix"] = [
                    init_attn_block(jax.random.fold_in(keys[4], i), cfg, mode,
                                    dtype, dense_ffn=cfg.moe.dense_d_ff)
                    for i in range(k_dense)
                ]
                n_scan -= k_dense
            p["layers"] = jax.vmap(
                lambda k: init_attn_block(k, cfg, mode, dtype)
            )(jax.random.split(keys[5], n_scan))
        return p

    def param_specs(self) -> Params:
        return jax.eval_shape(lambda: self.init(jax.random.PRNGKey(0)))

    # -- embedding / head ----------------------------------------------------
    def _embed(self, p: Params, batch: Dict[str, jax.Array]) -> jax.Array:
        if "embeds" in batch:  # vlm/audio frontend stub
            return batch["embeds"].astype(self.dtype)
        return layers.embed_tokens(p["embed"], batch["tokens"], self.mode, self.dtype)

    def _logits(self, p: Params, x: jax.Array) -> jax.Array:
        if self.cfg.tie_embeddings:
            logits = layers.tied_logits(p["embed"], x, self.mode)
        else:
            logits = layers.lm_head_logits(p["head"], x, self.mode)
        if self.cfg.vocab_padded != self.cfg.vocab_size:
            # pad slots exist only to keep the vocab-sharded table divisible
            # across lanes; mask them out of every softmax/argmax.
            pad_mask = jnp.arange(self.cfg.vocab_padded) < self.cfg.vocab_size
            logits = jnp.where(pad_mask, logits, NEG_INF)
        return logits

    # -- backbone (full sequence) -------------------------------------------
    def backbone(self, p: Params, x: jax.Array, **kw) -> Tuple[jax.Array, jax.Array]:
        with self._shard_scope():
            return self._backbone(p, x, **kw)

    def _backbone(self, p: Params, x: jax.Array, **kw) -> Tuple[jax.Array, jax.Array]:
        cfg, mode = self.cfg, self.mode
        aux_total = jnp.zeros((), jnp.float32)
        x = self._c(x)

        def maybe_remat(f):
            return jax.checkpoint(f) if self.remat else f

        if cfg.family == "ssm":
            def body(carry, lp):
                out = ssm_mod.mamba2_train(lp, _pre_norm(carry, cfg), cfg, mode, **kw)
                return self._c(carry + out), None
            x, _ = jax.lax.scan(maybe_remat(body), x, p["mamba"])
        elif cfg.family == "hybrid":
            n_attn, mpg, trailing = hybrid_layout(cfg)
            head_p = jax.tree.map(
                lambda t: t[:n_attn * mpg].reshape(n_attn, mpg, *t.shape[1:]),
                p["mamba"])
            tail_p = jax.tree.map(lambda t: t[n_attn * mpg:], p["mamba"])

            def group(carry, gp):
                h = carry
                for i in range(mpg):
                    lp = jax.tree.map(lambda t, i=i: t[i], gp)
                    h = h + ssm_mod.mamba2_train(lp, _pre_norm(h, cfg), cfg, mode, **kw)
                h, _ = attn_block_train(p["shared_attn"], h, cfg, mode,
                                        self.attn_chunk, **kw)
                return self._c(h), None

            x, _ = jax.lax.scan(maybe_remat(group), x, head_p)
            for i in range(trailing):
                lp = jax.tree.map(lambda t: t[i], tail_p)
                x = x + ssm_mod.mamba2_train(lp, _pre_norm(x, cfg), cfg, mode, **kw)
        else:
            for lp in p.get("prefix", []):
                x, aux = attn_block_train(lp, x, cfg, mode, self.attn_chunk, **kw)
                aux_total += aux

            def body(carry, lp):
                h, aux_sum = carry
                h, aux = attn_block_train(lp, h, cfg, mode, self.attn_chunk, **kw)
                return (self._c(h), aux_sum + aux), None
            (x, aux_total), _ = jax.lax.scan(maybe_remat(body), (x, aux_total),
                                             p["layers"])
        x = layers.rms_norm(x, p["final_norm"]["w"], cfg.norm_eps)
        return x, aux_total

    # -- training loss --------------------------------------------------------
    def loss_fn(self, p: Params, batch: Dict[str, jax.Array]
                ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        cfg = self.cfg
        x = self._embed(p, batch)
        x, aux = self.backbone(p, x, train=(self.mode != "serve"))
        labels = batch["labels"]
        b, s = labels.shape
        chunk = min(self.loss_chunk, s)
        nc = s // chunk

        def chunk_loss(args):
            xc, yc = args
            logits = self._logits(p, xc)                     # (B, c, V) f32
            logz = jax.nn.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(logits, yc[..., None].astype(jnp.int32),
                                       axis=-1)[..., 0]
            valid = (yc >= 0)
            nll = jnp.where(valid, logz - gold, 0.0)
            return jnp.sum(nll), jnp.sum(valid)

        xs = x.reshape(b, nc, chunk, cfg.d_model).swapaxes(0, 1)
        ys = labels.reshape(b, nc, chunk).swapaxes(0, 1)
        totals = jax.lax.map(jax.checkpoint(chunk_loss), (xs, ys))
        loss = jnp.sum(totals[0]) / jnp.maximum(jnp.sum(totals[1]), 1.0)
        aux_w = 0.01 if cfg.moe is not None else 0.0
        total = loss + aux_w * aux
        return total, {"ce_loss": loss, "aux_loss": aux, "tokens": jnp.sum(totals[1])}

    # -- caches ----------------------------------------------------------------
    def init_cache(self, batch: int, max_len: int) -> Params:
        cfg = self.cfg
        cache: Params = {}
        if cfg.family == "ssm":
            cache["states"] = ssm_mod.init_ssm_state(cfg, batch, cfg.num_layers)
        elif cfg.family == "hybrid":
            n_attn, mpg, trailing = hybrid_layout(cfg)
            cache["states"] = ssm_mod.init_ssm_state(cfg, batch, n_attn * mpg + trailing)
            cache.update(attn_mod.init_kv_cache(cfg, batch, max_len, n_attn))
        elif cfg.attention_kind == "mla":
            cache.update(attn_mod.init_mla_cache(cfg, batch, max_len, cfg.num_layers))
        else:
            cache.update(attn_mod.init_kv_cache(cfg, batch, max_len, cfg.num_layers))
        return cache

    def cache_specs(self, batch: int, max_len: int) -> Params:
        return jax.eval_shape(lambda: self.init_cache(batch, max_len))

    # -- decode step ------------------------------------------------------------
    def decode_step(self, p: Params, cache, token_or_embed: jax.Array,
                    pos: jax.Array, adapter_idx: Optional[jax.Array] = None
                    ) -> Tuple[jax.Array, Params]:
        """One token for the whole batch. token: (B,) int32 (or (B, D) stub
        embed). ``adapter_idx`` (B,) selects each slot's resident multi-tenant
        LoRA adapter (serving/adapters/; 0 = none).

        ``cache`` is either the dict cache from :meth:`init_cache` (dense /
        ssm / hybrid / MLA) or an :class:`~repro.models.attention.PagedKVState`
        handed over by a paged KV backend — block tables instead of a
        contiguous cache, attention dispatched per ``self.paged_attn``.
        Returns (logits (B, V) f32, new cache of the same kind)."""
        if isinstance(cache, attn_mod.PagedKVState):
            return self._paged_decode_step(p, cache, token_or_embed, pos,
                                           adapter_idx)
        cfg, mode = self.cfg, self.mode
        kw = {"fuse": self.fuse_proj, "kv_dtype": self.kv_widen}
        if adapter_idx is not None:
            kw["adapter_idx"] = adapter_idx
        if token_or_embed.ndim == 1:
            x = layers.embed_tokens(p["embed"], token_or_embed, mode, self.dtype)
        else:
            x = token_or_embed.astype(self.dtype)

        new_cache = dict(cache)
        if cfg.family == "ssm":
            def body(h, inp):
                lp, st, cv = inp
                h2 = _pre_norm(h, cfg)
                out, st2, cv2 = ssm_mod.mamba2_decode(lp, h2, st, cv, cfg, mode, **kw)
                return h + out, (st2, cv2)
            x, (st, cv) = jax.lax.scan(body, x, (p["mamba"], cache["states"]["ssm"],
                                                 cache["states"]["conv"]))
            new_cache["states"] = {"ssm": st, "conv": cv}
        elif cfg.family == "hybrid":
            x, new_cache = self._hybrid_decode(p, cache, x, pos, **kw)
        else:
            prefix = p.get("prefix", [])
            kd = len(prefix)
            c0, c1 = self._cache_pair(cache)
            for i, lp in enumerate(prefix):
                x, (s0, s1), _ = attn_block_decode(lp, x, (c0[i], c1[i]), pos, cfg,
                                                   mode, **kw)
                c0 = c0.at[i].set(s0)
                c1 = c1.at[i].set(s1)

            def body(h, inp):
                lp, a, b_ = inp
                h, (a2, b2), _ = attn_block_decode(lp, h, (a, b_), pos, cfg, mode, **kw)
                return h, (a2, b2)
            x, (n0, n1) = jax.lax.scan(body, x, (p["layers"], c0[kd:], c1[kd:]))
            c0 = jax.lax.dynamic_update_slice_in_dim(c0, n0, kd, 0)
            c1 = jax.lax.dynamic_update_slice_in_dim(c1, n1, kd, 0)
            new_cache = self._cache_unpair(cache, c0, c1)

        x = layers.rms_norm(x, p["final_norm"]["w"], cfg.norm_eps)
        logits = self._logits(p, x)
        return logits, new_cache

    # -- paged decode (block tables through the attention stack) ---------------
    def _paged_decode_step(self, p: Params, state, token_or_embed: jax.Array,
                           pos: jax.Array,
                           adapter_idx: Optional[jax.Array] = None):
        """decode_step over a PagedKVState: the slot's block table reaches
        decode attention directly. GQA families only (the paged pool layout
        is (L, pages, Hkv, page, D))."""
        cfg = self.cfg
        assert cfg.attention_kind == "gqa" and cfg.family not in ("ssm", "hybrid"), \
            "paged decode needs a GQA KV cache"
        assert pos.ndim == 1, "paged decode is batched (per-slot positions)"
        mode = self.paged_attn
        if mode == "auto":
            mode = "gather" if jax.default_backend() == "cpu" else "kernel"
        if mode == "gather":
            return self._paged_decode_gather(p, state, token_or_embed, pos,
                                             adapter_idx)
        return self._paged_decode_kernel(p, state, token_or_embed, pos,
                                         adapter_idx)

    def _paged_decode_gather(self, p, state, token_or_embed, pos, adapter_idx):
        """XLA reference: gather the contiguous view from the block tables
        *inside* the jitted step, run the exact dense decode body on it, then
        scatter the new token's k/v back into its page. Op-for-op the dense
        math → token-identical dense↔paged greedy outputs."""
        cache = {"k": attn_mod.gather_pages(state.k_pool, state.tables),
                 "v": attn_mod.gather_pages(state.v_pool, state.tables)}
        logits, new_cache = self.decode_step(p, cache, token_or_embed, pos,
                                             adapter_idx)
        # clip, don't fill: inactive slots carry a stale `pos` that can
        # exceed the gathered view (their write lands on the scratch page
        # and is never read), and jnp's OOB fill value is NaN — which would
        # poison the scratch page and leak into live rows via table padding
        idx = pos.reshape(1, -1, 1, 1, 1).astype(jnp.int32)
        k_tok = jnp.take_along_axis(new_cache["k"], idx, axis=3,
                                    mode="clip")[:, :, :, 0]
        v_tok = jnp.take_along_axis(new_cache["v"], idx, axis=3,
                                    mode="clip")[:, :, :, 0]
        k_pool = attn_mod.scatter_tokens(state.k_pool, state.write_page,
                                         state.write_off, k_tok)
        v_pool = attn_mod.scatter_tokens(state.v_pool, state.write_page,
                                         state.write_off, v_tok)
        return logits, dataclasses.replace(state, k_pool=k_pool, v_pool=v_pool)

    def _paged_decode_kernel(self, p, state, token_or_embed, pos, adapter_idx):
        """Pallas path: per layer, scatter the token into its page and run
        `paged_flash_decode` — the block table rides in via scalar prefetch
        and picks which pool page each context step DMAs HBM→VMEM. No
        contiguous view is ever materialized."""
        cfg, mode = self.cfg, self.mode
        interpret = jax.default_backend() == "cpu"
        kw = {"fuse": self.fuse_proj, "kv_dtype": self.kv_widen}
        if adapter_idx is not None:
            kw["adapter_idx"] = adapter_idx
        if token_or_embed.ndim == 1:
            x = layers.embed_tokens(p["embed"], token_or_embed, mode, self.dtype)
        else:
            x = token_or_embed.astype(self.dtype)

        def block(lp, h, kp_l, vp_l):
            hn = layers.rms_norm(h, lp["norm1"]["w"], cfg.norm_eps)
            a, kp_l, vp_l = attn_mod.gqa_decode_paged(
                lp["attn"], hn, kp_l, vp_l, state.tables, state.write_page,
                state.write_off, state.lengths, pos, cfg, mode,
                use_kernel=True, interpret=interpret, **kw)
            h = h + a
            h2 = layers.rms_norm(h, lp["norm2"]["w"], cfg.norm_eps)
            if "moe" in lp:
                f, _ = moe_mod.moe_ffn(lp["moe"], h2, cfg, mode, **kw)
            else:
                f = layers.apply_ffn(lp["ffn"], h2, cfg.ffn_kind, mode, **kw)
            return h + f, kp_l, vp_l

        prefix = p.get("prefix", [])
        kd = len(prefix)
        kp, vp = state.k_pool, state.v_pool
        for i, lp in enumerate(prefix):
            x, k_l, v_l = block(lp, x, kp[i], vp[i])
            kp = kp.at[i].set(k_l)
            vp = vp.at[i].set(v_l)

        def body(h, inp):
            lp, k_l, v_l = inp
            h, k2, v2 = block(lp, h, k_l, v_l)
            return h, (k2, v2)

        x, (n_k, n_v) = jax.lax.scan(body, x, (p["layers"], kp[kd:], vp[kd:]))
        kp = jax.lax.dynamic_update_slice_in_dim(kp, n_k, kd, 0)
        vp = jax.lax.dynamic_update_slice_in_dim(vp, n_v, kd, 0)
        x = layers.rms_norm(x, p["final_norm"]["w"], cfg.norm_eps)
        return self._logits(p, x), dataclasses.replace(state, k_pool=kp,
                                                       v_pool=vp)

    # -- speculative-decode verify ---------------------------------------------
    def verify_step(self, p: Params, cache, tokens: jax.Array,
                    pos: jax.Array, adapter_idx: Optional[jax.Array] = None
                    ) -> Tuple[jax.Array, Params]:
        """Score S = k+1 positions per slot in one jitted call (speculative
        decoding's verify). ``tokens`` (B, S) int32 — position 0 is the
        tick's fed token, positions 1.. the proposer's drafts; ``pos`` (B,)
        is each slot's next cache position. Returns ``(logits (B, S, V) f32,
        spans {"k","v"}: (L, B, Hkv, S, D))`` in the fp8 cache encoding.

        The S positions run as a ``lax.scan`` of :meth:`decode_step` —
        op-for-op the single-token decode on every backend (dense math, the
        XLA gather reference, the Pallas ``paged_flash_decode`` views with
        drafts landing page-by-page), so per-position logits are
        **bit-identical** to what sequential decode would produce. That is
        the accept/reject contract: greedy and seeded choices match the
        non-speculative engine exactly, never just approximately. One jit
        dispatch replaces k+1 tick round-trips (the tick-bound overhead
        speculation exists to amortize), and XLA hoists the loop-invariant
        ternary weight decode out of the scan, so drafted positions reuse
        the ROM stream a sequential host loop would re-read.

        Cache/pool mutations stay inside the trace: the dense carry and the
        paged pool copy are discarded by the engine, which commits only the
        accepted span from the returned ``spans`` through the KV backend
        (sliced dense writes / ``PagePool.write_span``) — rejected drafts
        never reach storage. For a paged ``cache``, ``write_page`` /
        ``write_off`` must be the **(B, S)** per-position targets from
        ``PagedKV.verify_state``. GQA families only (same restriction as
        the mid-sequence prefill)."""
        cfg = self.cfg
        assert cfg.attention_kind == "gqa" and cfg.family not in ("ssm", "hybrid"), \
            "speculative verify needs a GQA KV cache"
        assert pos.ndim == 1, "verify is batched (per-slot positions)"
        s = tokens.shape[1]
        if isinstance(cache, attn_mod.PagedKVState):
            def body(state, inp):
                t_j, j, wp_j, wo_j = inp
                st_j = dataclasses.replace(state, write_page=wp_j,
                                           write_off=wo_j,
                                           lengths=pos + j + 1)
                lg, st_new = self.decode_step(p, st_j, t_j, pos + j,
                                              adapter_idx)
                state = dataclasses.replace(state, k_pool=st_new.k_pool,
                                            v_pool=st_new.v_pool)
                return state, lg

            state, lgs = jax.lax.scan(
                body, cache, (tokens.T, jnp.arange(s),
                              jnp.moveaxis(cache.write_page, 1, 0),
                              jnp.moveaxis(cache.write_off, 1, 0)))
            # pull the drafted span back out of the (functional) pool copy:
            # advanced (B, S) page/offset indices land the batch dims first
            wp, wo = cache.write_page, cache.write_off
            k_span = state.k_pool[:, wp, :, wo].transpose(2, 0, 3, 1, 4)
            v_span = state.v_pool[:, wp, :, wo].transpose(2, 0, 3, 1, 4)
            return jnp.moveaxis(lgs, 0, 1), {"k": k_span, "v": v_span}

        def body(c, inp):
            t_j, j = inp
            lg, c = self.decode_step(p, c, t_j, pos + j, adapter_idx)
            return c, lg

        c, lgs = jax.lax.scan(body, cache, (tokens.T, jnp.arange(s)))
        # mode="clip" for the same reason as the paged decode path: stale
        # positions on inactive rows must not pull in jnp's NaN OOB fill
        idx = (pos[:, None] + jnp.arange(s))[None, :, None, :, None]
        k_span = jnp.take_along_axis(c["k"], idx, axis=3, mode="clip")
        v_span = jnp.take_along_axis(c["v"], idx, axis=3, mode="clip")
        return jnp.moveaxis(lgs, 0, 1), {"k": k_span, "v": v_span}


    def _cache_pair(self, cache):
        if self.cfg.attention_kind == "mla":
            return cache["latent"], cache["k_rope"]
        return cache["k"], cache["v"]

    def _cache_unpair(self, cache, c0, c1):
        out = dict(cache)
        if self.cfg.attention_kind == "mla":
            out["latent"], out["k_rope"] = c0, c1
        else:
            out["k"], out["v"] = c0, c1
        return out

    def _hybrid_decode(self, p, cache, x, pos, **kw):
        cfg, mode = self.cfg, self.mode
        n_attn, mpg, trailing = hybrid_layout(cfg)
        st, cv = cache["states"]["ssm"], cache["states"]["conv"]
        kc, vc = cache["k"], cache["v"]
        mam = p["mamba"]
        head_idx = n_attn * mpg
        gp = jax.tree.map(lambda t: t[:head_idx].reshape(n_attn, mpg, *t.shape[1:]), mam)
        st_g = st[:head_idx].reshape(n_attn, mpg, *st.shape[1:])
        cv_g = cv[:head_idx].reshape(n_attn, mpg, *cv.shape[1:])

        def group(h, inp):
            g, s_g, c_g, k_l, v_l = inp
            new_s, new_c = [], []
            for i in range(mpg):
                lp = jax.tree.map(lambda t: t[i], g)
                out, s2, c2 = ssm_mod.mamba2_decode(lp, _pre_norm(h, cfg), s_g[i],
                                                    c_g[i], cfg, mode, **kw)
                h = h + out
                new_s.append(s2)
                new_c.append(c2)
            h, (k2, v2), _ = attn_block_decode(p["shared_attn"], h, (k_l, v_l), pos,
                                               cfg, mode, **kw)
            return h, (jnp.stack(new_s), jnp.stack(new_c), k2, v2)

        x, (s_new, c_new, k_new, v_new) = jax.lax.scan(
            group, x, (gp, st_g, cv_g, kc, vc))
        st = st.at[:head_idx].set(s_new.reshape(head_idx, *st.shape[1:]))
        cv = cv.at[:head_idx].set(c_new.reshape(head_idx, *cv.shape[1:]))
        for i in range(trailing):
            lp = jax.tree.map(lambda t: t[head_idx + i], mam)
            out, s2, c2 = ssm_mod.mamba2_decode(lp, _pre_norm(x, cfg),
                                                st[head_idx + i], cv[head_idx + i],
                                                cfg, mode, **kw)
            x = x + out
            st = st.at[head_idx + i].set(s2)
            cv = cv.at[head_idx + i].set(c2)
        new_cache = dict(cache)
        new_cache["states"] = {"ssm": st, "conv": cv}
        new_cache["k"], new_cache["v"] = k_new, v_new
        return x, new_cache

    # -- prefill ------------------------------------------------------------------
    def prefill(self, p: Params, batch: Dict[str, jax.Array], max_len: int, *,
                pos_offset: int = 0, prefix_kv: Optional[Params] = None,
                adapter_idx: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, Params]:
        """Process the whole prompt, fill the cache, return last-token logits.

        Batched prefill (beyond-paper default; the paper's token-by-token
        prefill is available in the simulator + serving engine).

        ``pos_offset``/``prefix_kv`` resume prefill mid-sequence: positions
        start at ``pos_offset``, the cache fills from there, and the prompt
        remainder attends to the already-committed prefix k/v (``{"k","v"}:
        (L, B, Hkv, P, D)`` in the fp8 cache encoding). The prefix is either
        a prefix-cache hit's shared pages or — for chunked prefill — the
        earlier chunks of the same prompt, so chunk i of a long prompt
        resumes at ``pos_offset = i·C`` through the exact same path on both
        KV backends (serving/kv.py materializes ``prefix_kv`` token-granular,
        so chunk boundaries need not be page-aligned). GQA attention families
        only. ``adapter_idx`` threads the multi-tenant LoRA selection (one
        entry per batch row)."""
        with self._shard_scope():
            return self._prefill(p, batch, max_len, pos_offset=pos_offset,
                                 prefix_kv=prefix_kv, adapter_idx=adapter_idx)

    def _prefill(self, p: Params, batch: Dict[str, jax.Array], max_len: int, *,
                 pos_offset: int = 0, prefix_kv: Optional[Params] = None,
                 adapter_idx: Optional[jax.Array] = None
                 ) -> Tuple[jax.Array, Params]:
        cfg, mode = self.cfg, self.mode
        x = self._embed(p, batch)
        b, s, _ = x.shape
        cache = self.init_cache(b, max_len)
        kw: Dict[str, Any] = {}
        if adapter_idx is not None:
            kw["adapter_idx"] = adapter_idx
        # (order matters: a resume always carries prefix_kv, and pos_offset
        # may then be a traced scalar — never force bool() on it)
        if prefix_kv is not None or pos_offset:
            assert cfg.attention_kind == "gqa" and cfg.family not in ("ssm", "hybrid"), \
                "mid-sequence prefill (prefix-cache resume) is GQA-only"

        if cfg.family in ("ssm", "hybrid"):
            # run full-seq backbone while extracting final states: recompute
            # states via a decode sweep would be O(S); instead prefill for SSM
            # families processes the sequence chunk-wise through train path and
            # rebuilds states with a final decode of the last token. For the
            # dry-run cells, prefill shapes are only assigned to attention
            # archs' KV path; SSM prefill fills KV (hybrid) + states.
            x_full, _ = self.backbone(p, x, train=False)
            logits = self._logits(p, x_full[:, -1])
            return logits, cache

        prefix = p.get("prefix", [])
        kd = len(prefix)
        c0, c1 = self._cache_pair(cache)
        pk = pv = None
        if prefix_kv is not None:
            pk, pv = prefix_kv["k"], prefix_kv["v"]     # (L, B, Hkv, P, D)

        def fill_block(lp, h, c0_l, c1_l, pk_l=None, pv_l=None):
            hn = layers.rms_norm(h, lp["norm1"]["w"], cfg.norm_eps)
            if cfg.attention_kind == "mla":
                a, c0_l, c1_l = _mla_prefill_fill(lp["attn"], hn, c0_l, c1_l, cfg,
                                                  mode, self.attn_chunk, **kw)
            else:
                a, c0_l, c1_l = _gqa_prefill_fill(lp["attn"], hn, c0_l, c1_l, cfg,
                                                  mode, self.attn_chunk,
                                                  pos_offset=pos_offset,
                                                  prefix_k=pk_l, prefix_v=pv_l,
                                                  **kw)
            h = h + a
            h2 = layers.rms_norm(h, lp["norm2"]["w"], cfg.norm_eps)
            if "moe" in lp:
                f, _ = moe_mod.moe_ffn(lp["moe"], h2, cfg, mode, **kw)
            else:
                f = layers.apply_ffn(lp["ffn"], h2, cfg.ffn_kind, mode, **kw)
            return h + f, c0_l, c1_l

        for i, lp in enumerate(prefix):
            x, s0, s1 = fill_block(lp, x, c0[i], c1[i],
                                   None if pk is None else pk[i],
                                   None if pv is None else pv[i])
            c0 = c0.at[i].set(s0)
            c1 = c1.at[i].set(s1)

        if pk is None:
            def body(h, inp):
                lp, a, b_ = inp
                h, a2, b2 = fill_block(lp, h, a, b_)
                return self._c(h), (a2, b2)

            body = jax.checkpoint(body) if self.remat else body
            x, (n0, n1) = jax.lax.scan(body, x, (p["layers"], c0[kd:], c1[kd:]))
        else:
            def body(h, inp):
                lp, a, b_, pk_l, pv_l = inp
                h, a2, b2 = fill_block(lp, h, a, b_, pk_l, pv_l)
                return self._c(h), (a2, b2)

            body = jax.checkpoint(body) if self.remat else body
            x, (n0, n1) = jax.lax.scan(
                body, x, (p["layers"], c0[kd:], c1[kd:], pk[kd:], pv[kd:]))
        c0 = jax.lax.dynamic_update_slice_in_dim(c0, n0, kd, 0)
        c1 = jax.lax.dynamic_update_slice_in_dim(c1, n1, kd, 0)
        cache = self._cache_unpair(cache, c0, c1)
        x = layers.rms_norm(x, p["final_norm"]["w"], cfg.norm_eps)
        return self._logits(p, x[:, -1]), cache


def _pre_norm(x, cfg):
    # mamba blocks norm with a unit-weight RMS (their own gate_norm carries the
    # learnable scale)
    return layers.rms_norm(x, jnp.ones((cfg.d_model,), jnp.float32), cfg.norm_eps)


def _attend_with_prefix(q, k_new, v_new, k_pref, v_pref, pos_offset):
    """Causal attention for a prompt remainder that starts mid-sequence: the
    queries (global positions ``pos_offset + s``) attend the already-cached
    prefix k/v (fp8 cache encoding, positions ``0..pos_offset``) plus the
    remainder's own keys. q/k/v: (B, S, H*, D); k_pref/v_pref: (B, Hkv, P, D).
    The prefix may be *padded* past the true length (P >= pos_offset — the
    serving engine buckets it to a power of two so chunked-prefill resumes
    reuse compiled graphs) and ``pos_offset`` may be a traced scalar: padded
    prefix keys are masked out by position. Plain masked softmax — the
    serving prefill path is batch-1 and bounded by max_len, so no
    chunking/remat is needed."""
    b, s, h, d = q.shape
    hkv = k_new.shape[2]
    g = h // hkv
    p_len = k_pref.shape[2]          # padded prefix length (>= pos_offset)
    kp = (k_pref.astype(jnp.float32) * KV_CACHE_SCALE).transpose(0, 2, 1, 3)
    vp = (v_pref.astype(jnp.float32) * KV_CACHE_SCALE).transpose(0, 2, 1, 3)
    k_all = jnp.concatenate([kp, k_new.astype(jnp.float32)], axis=1)  # (B,T,Hkv,D)
    v_all = jnp.concatenate([vp, v_new.astype(jnp.float32)], axis=1)
    t = k_all.shape[1]
    qg = q.reshape(b, s, hkv, g, d).astype(jnp.float32)
    scores = jnp.einsum("bshgd,bthd->bshgt", qg, k_all) * (d ** -0.5)
    # key index j: a prefix slot (j < p_len) is real iff j < pos_offset; a
    # remainder key (j - p_len) is causally visible to query i iff <= i
    tidx = jnp.arange(t)[None, :]
    qidx = jnp.arange(s)[:, None]
    visible = jnp.where(tidx < p_len, tidx < pos_offset,
                        (tidx - p_len) <= qidx)
    scores = jnp.where(visible[None, :, None, None, :], scores, NEG_INF)
    m = jnp.max(scores, axis=-1, keepdims=True)
    pr = jnp.exp(scores - m)
    den = jnp.sum(pr, axis=-1, keepdims=True)
    out = jnp.einsum("bshgt,bthd->bshgd", pr / jnp.maximum(den, 1e-30), v_all)
    return out.reshape(b, s, h, d).astype(q.dtype)


def _gqa_prefill_fill(p, h, k_cache, v_cache, cfg, mode, chunk, *,
                      pos_offset=0, prefix_k=None, prefix_v=None, **kw):
    b, s, _ = h.shape
    positions = jnp.arange(s)[None, :] + pos_offset
    q, k, v = attn_mod._project_qkv(p, h, cfg, mode, positions, **kw)
    if prefix_k is None:
        out = attn_mod.chunked_causal_attention(q, k, v, chunk_q=min(chunk, s),
                                                chunk_k=min(chunk, s))
    else:
        out = _attend_with_prefix(q, k, v, prefix_k, prefix_v, pos_offset)
    out = layers.apply_linear(p["o"], out.reshape(b, s, cfg.q_dim), mode, **kw)
    k_c = (k / KV_CACHE_SCALE).transpose(0, 2, 1, 3).astype(k_cache.dtype)
    v_c = (v / KV_CACHE_SCALE).transpose(0, 2, 1, 3).astype(v_cache.dtype)
    k_cache = jax.lax.dynamic_update_slice(k_cache, k_c, (0, 0, pos_offset, 0))
    v_cache = jax.lax.dynamic_update_slice(v_cache, v_c, (0, 0, pos_offset, 0))
    return out, k_cache, v_cache


def _mla_prefill_fill(p, h, latent_cache, rope_cache, cfg, mode, chunk, **kw):
    b, s, _ = h.shape
    positions = jnp.arange(s)[None, :]
    out = attn_mod.mla_train(p, h, cfg, mode, chunk=chunk, **kw)
    latent, k_rope = attn_mod._mla_latent(p, h, cfg, mode, positions, **kw)
    latent_cache = jax.lax.dynamic_update_slice(
        latent_cache, (latent / KV_CACHE_SCALE).astype(latent_cache.dtype), (0, 0, 0))
    rope_cache = jax.lax.dynamic_update_slice(
        rope_cache, (k_rope / KV_CACHE_SCALE).astype(rope_cache.dtype), (0, 0, 0))
    return out, latent_cache, rope_cache
