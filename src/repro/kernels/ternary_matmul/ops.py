"""Public jit'd wrapper for the packed-ternary matmul kernel.

Handles padding to block multiples, batched inputs, backend dispatch (Pallas
on TPU; interpret-mode Pallas or the XLA decode path on CPU), and block-size
selection tuned for v5e VMEM (128 KB per buffer budget; see §Perf log).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import ternary
from repro.kernels.ternary_matmul.ref import ternary_matmul_ref
from repro.kernels.ternary_matmul.ternary_matmul import ternary_matmul as _pallas_matmul


def _pick_blocks(m: int, k: int, n: int):
    """VMEM-aware block selection. Working set per grid step:
    x(bm·bk·2B) + packed(bk/4·bn) + acc(bm·bn·4B) ≤ ~4 MB with double buffer.
    MXU wants multiples of 128 on bm/bn and the packed decode wants bk % 512 == 0.
    """
    bm = min(128, max(8, m))
    bk = 512 if k >= 512 else max(4, k)
    bn = 256 if n >= 256 else max(128, n)
    return bm, bk, bn


def _pad_axis(x: jax.Array, axis: int, mult: int) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % mult
    if not pad:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("layout", "use_kernel", "interpret", "out_dtype"))
def ternary_matmul(
    x: jax.Array,
    packed: jax.Array,
    scale: jax.Array,
    *,
    layout: str = "interleaved",
    use_kernel: bool = True,
    interpret: bool | None = None,
    out_dtype=jnp.float32,
) -> jax.Array:
    """``x (..., K) @ unpack(packed) (K, N) * scale`` → ``(..., N)``.

    The fused Pallas path streams 2-bit tiles and decodes in-kernel; the
    fallback decodes via XLA ops (still packed in HBM — the bandwidth win is
    identical, the decode is just unfused).
    """
    *lead, k = x.shape
    kq, n = packed.shape
    assert kq * 4 == k, (kq, k)
    m = 1
    for s in lead:
        m *= s
    x2 = x.reshape(m, k)

    if interpret is None:
        interpret = jax.default_backend() == "cpu"

    bm, bk, bn = _pick_blocks(m, k, n)
    shapes_ok = (k % bk == 0) and (bk % 4 == 0)
    if use_kernel and shapes_ok:
        xp = _pad_axis(x2, 0, bm)
        pp = _pad_axis(packed, 1, bn)
        out = _pallas_matmul(
            xp, pp, scale,
            layout=layout, block_m=bm, block_n=bn, block_k=bk,
            out_dtype=out_dtype, interpret=interpret,
        )[:m, :n]
    else:
        out = ternary_matmul_ref(x2, packed, scale, layout=layout, out_dtype=out_dtype)
    return out.reshape(*lead, n)


def linear(x: jax.Array, w: ternary.TernaryTensor, *, out_dtype=None) -> jax.Array:
    """Model-layer entry point: activation × TernaryTensor."""
    out_dtype = out_dtype or x.dtype
    return ternary_matmul(
        x, w.packed, w.scale, layout=w.layout, out_dtype=out_dtype,
    )
