"""Pallas TPU kernel: packed-ternary × activation matmul (paper C1→TPU).

The TPU adaptation of TOM's sparsity-aware ROM: weights live in HBM as 2-bit
codes (4/byte); each grid step streams a packed K-tile into VMEM, decodes it
with bitwise ops ("the combinational logic"), widens to the activation dtype
and feeds the MXU. Weight bytes moved are 8× less than bf16 / 2× less than
int4 — in the memory-bound decode regime this moves the memory-roofline term
by the same factor, which is precisely the paper's density argument.

Two decode layouts (see core/ternary.py):
 - interleaved: stack(4 slots, axis=-2) + reshape — a sublane interleave.
 - strided: concatenate(4 slots, axis=-2) — no interleave; cheaper lowering.

Grid: (M/bm, N/bn, K/bk) with K innermost ('arbitrary'), f32 VMEM accumulator,
scale applied once on the final K step from SMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax 0.4.x names this TPUCompilerParams; newer releases renamed it
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def _decode_tile(codes: jax.Array, layout: str, bk: int, bn: int, dtype) -> jax.Array:
    """uint8 (bk//4, bn) 2-bit codes → (bk, bn) ±1/0 in `dtype`."""
    slots = []
    for s in range(4):
        c = (codes >> (2 * s)) & 3
        # '01'→+1, '10'→−1, '00'→0: conditional negation, no multiplier.
        slots.append(((c & 1).astype(jnp.int8) - ((c >> 1) & 1).astype(jnp.int8)))
    if layout == "interleaved":
        w = jnp.stack(slots, axis=1).reshape(bk, bn)
    else:  # strided: slot s covers rows [s*bk/4, (s+1)*bk/4) of the tile
        w = jnp.concatenate(slots, axis=0)
    return w.astype(dtype)


def _kernel(x_ref, p_ref, scale_ref, o_ref, acc_ref, *, layout: str, bk: int, bn: int,
            n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]
    w = _decode_tile(p_ref[...], layout, bk, bn, x.dtype)
    acc_ref[...] += jnp.dot(x, w, preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _done():
        o_ref[...] = (acc_ref[...] * scale_ref[0]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("layout", "block_m", "block_n", "block_k", "out_dtype", "interpret"),
)
def ternary_matmul(
    x: jax.Array,
    packed: jax.Array,
    scale: jax.Array,
    *,
    layout: str = "interleaved",
    block_m: int = 128,
    block_n: int = 256,
    block_k: int = 512,
    out_dtype=jnp.float32,
    interpret: bool = False,
) -> jax.Array:
    """``x (M,K) · W (K,N) * scale`` with W given as packed 2-bit ternary (K//4, N).

    Shapes must be divisible by the block sizes (ops.py pads). For the strided
    layout the pack tile must equal ``block_k``.
    """
    m, kdim = x.shape
    kq, n = packed.shape
    assert kq * 4 == kdim, (kq, kdim)
    n_k = kdim // block_k
    scale = jnp.asarray(scale, jnp.float32).reshape(1)

    grid = (m // block_m, n // block_n, n_k)
    kernel = functools.partial(_kernel, layout=layout, bk=block_k, bn=block_n, n_k=n_k)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_k // 4, block_n), lambda i, j, k: (k, j)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x, packed, scale)
