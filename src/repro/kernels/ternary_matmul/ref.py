"""Pure-jnp oracle for the packed-ternary matmul kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import ternary


def ternary_matmul_ref(
    x: jax.Array,
    packed: jax.Array,
    scale: jax.Array,
    *,
    layout: str = "interleaved",
    tile: int = 512,
    out_dtype=jnp.float32,
) -> jax.Array:
    """``x (M,K) @ unpack(packed) (K,N) * scale`` in f32.

    The oracle decodes the 2-bit 'ROM' to a dense ternary matrix and runs a
    plain matmul — the ground truth the Pallas kernel must match exactly
    (ternary values are exact in every float dtype; accumulation is f32 in
    both paths).
    """
    w = ternary.unpack2(packed, layout=layout, tile=tile).astype(jnp.float32)
    out = jnp.dot(x.astype(jnp.float32), w, preferred_element_type=jnp.float32)
    return (out * scale).astype(out_dtype)
