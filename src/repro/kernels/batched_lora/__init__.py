"""Batched multi-adapter ternary LoRA (SGMV-style segmented matmul).

One decode tick serves slots running *different* fine-tunes: resident frozen
adapters are stacked `[num_adapters, ...]` and each batch row gathers its own
packed-ternary A/B by index — no per-adapter dispatch, no recompiles.
"""
from repro.kernels.batched_lora.ops import batched_lora
from repro.kernels.batched_lora.ref import batched_lora_ref

__all__ = ["batched_lora", "batched_lora_ref"]
