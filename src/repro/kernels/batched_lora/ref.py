"""Reference batched ternary-LoRA matmul (the SGMV oracle).

Adapters are frozen to 2-bit ternary (`qlora.freeze_adapter`) and stacked
along a leading *adapter* axis; every batch row selects its adapter by index:

    z[b] = x[b] @ unpack(a_codes[idx[b]])            # (…, K) → (…, r)
    y[b] = z[b] @ unpack(b_codes[idx[b]]) * s[idx[b]]  # (…, r) → (…, N)

``s`` is the per-adapter combined scale ``scale_a · scale_b · α/r``; index 0
is reserved for the null adapter (all-zero codes, zero scale), so
``adapter_id=None`` slots contribute exactly 0 and stay token-identical to a
no-adapter engine. Pure XLA (gather + two einsums) — this IS the serving
fallback path on CPU; the Pallas kernel (batched_lora.py) fuses the decode
for TPU.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import ternary


def batched_lora_ref(
    x: jax.Array,          # (B, ..., K) activations, adapter-homogeneous per row
    a_codes: jax.Array,    # (R, K//4, r) uint8 packed ternary A stacks
    b_codes: jax.Array,    # (R, r//4, N) uint8 packed ternary B stacks
    scales: jax.Array,     # (R,) f32 combined per-adapter scale
    idx: jax.Array,        # (B,) int32 adapter slot per batch row
    out_dtype=jnp.float32,
) -> jax.Array:
    """Per-row gathered two-matmul LoRA path → (B, ..., N)."""
    a = ternary.unpack2(a_codes[idx]).astype(jnp.float32)    # (B, K, r)
    b = ternary.unpack2(b_codes[idx]).astype(jnp.float32)    # (B, r, N)
    z = jnp.einsum("b...k,bkr->b...r", x.astype(jnp.float32), a)
    y = jnp.einsum("b...r,brn->b...n", z, b)
    s = scales[idx].reshape(idx.shape[0], *([1] * (x.ndim - 1)))
    return (y * s).astype(out_dtype)
