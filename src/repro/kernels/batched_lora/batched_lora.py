"""Pallas TPU kernel: batched multi-adapter ternary-LoRA matmul.

The SGMV analogue for TOM's SRAM adapters: the decode batch mixes slots that
run *different* frozen fine-tunes, so each grid step resolves its row's
adapter through **scalar prefetch** (the same indirection idiom as
`flash_decode/paged.py`'s block tables) — the A/B BlockSpec index maps pick
which adapter's packed 2-bit tile to DMA HBM→VMEM before the body runs. The
tile is decoded in-registers ("the combinational logic") and hits the MXU at
the activation dtype, so adapter weight bytes moved stay at the 2-bit ROM
density even with many tenants resident.

Grid: (B,) — one step per decode slot; both LoRA matmuls are rank-narrow
(r ≤ 64), so one step fuses decode(A) → x·A → decode(B) → z·B → ·s entirely
in VMEM. Per-adapter combined scales ride in SMEM via the second scalar-
prefetch operand.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ternary_matmul.ternary_matmul import _decode_tile

# jax 0.4.x names this TPUCompilerParams; newer releases renamed it
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def _kernel(idx_ref, s_ref, x_ref, a_ref, b_ref, o_ref, *, k: int, r: int, n: int):
    bi = pl.program_id(0)
    x = x_ref[...].astype(jnp.float32)                       # (1, K)
    a = _decode_tile(a_ref[0], "interleaved", k, r, jnp.float32)   # (K, r)
    z = jnp.dot(x, a, preferred_element_type=jnp.float32)    # (1, r)
    b = _decode_tile(b_ref[0], "interleaved", r, n, jnp.float32)   # (r, N)
    y = jnp.dot(z, b, preferred_element_type=jnp.float32)    # (1, N)
    o_ref[...] = (y * s_ref[idx_ref[bi]]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("out_dtype", "interpret"))
def batched_lora_matmul(
    x: jax.Array,          # (B, K) one activation row per decode slot
    a_codes: jax.Array,    # (R, K//4, r) uint8 packed ternary A stacks
    b_codes: jax.Array,    # (R, r//4, N) uint8 packed ternary B stacks
    scales: jax.Array,     # (R,) f32 combined per-adapter scale
    idx: jax.Array,        # (B,) int32 adapter slot per row (0 = null adapter)
    *,
    out_dtype=jnp.float32,
    interpret: bool = False,
) -> jax.Array:
    bsz, k = x.shape
    n_adapters, kq, r = a_codes.shape
    rq, n = b_codes.shape[-2:]
    assert kq * 4 == k, (kq, k)
    assert rq * 4 == r, (rq, r)

    idx = jnp.asarray(idx, jnp.int32).reshape(bsz)
    scales = jnp.asarray(scales, jnp.float32).reshape(n_adapters)

    kernel = functools.partial(_kernel, k=k, r=r, n=n)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                 # idx, scales
        grid=(bsz,),
        in_specs=[
            pl.BlockSpec((1, k), lambda b, i, s: (b, 0)),
            # the multi-tenant indirection: this row's adapter tile, not a
            # contiguous adapter axis
            pl.BlockSpec((1, kq, r), lambda b, i, s: (i[b], 0, 0)),
            pl.BlockSpec((1, rq, n), lambda b, i, s: (i[b], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, n), lambda b, i, s: (b, 0)),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((bsz, n), out_dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary",),
        ),
        interpret=interpret,
    )(idx, scales, x, a_codes, b_codes)
