"""Public wrapper for the batched ternary-LoRA matmul.

Backend dispatch mirrors `ternary_matmul/ops.py`: the fused Pallas kernel
runs on TPU where shapes allow (2-D decode activations, lane-aligned N); the
XLA reference (gather + two einsums — still packed 2-bit in HBM, so the
bandwidth win is identical) covers CPU and the batched-prefill 3-D case.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.batched_lora.batched_lora import batched_lora_matmul
from repro.kernels.batched_lora.ref import batched_lora_ref


@functools.partial(jax.jit, static_argnames=("use_kernel", "interpret", "out_dtype"))
def batched_lora(
    x: jax.Array,          # (B, ..., K)
    a_codes: jax.Array,    # (R, K//4, r)
    b_codes: jax.Array,    # (R, r//4, N)
    scales: jax.Array,     # (R,)
    idx: jax.Array,        # (B,)
    *,
    use_kernel: bool = True,
    interpret: bool | None = None,
    out_dtype=jnp.float32,
) -> jax.Array:
    """Per-slot LoRA contribution ``y[b] = (x[b]·A[idx[b]])·B[idx[b]]·s[idx[b]]``."""
    if x.ndim == 3 and x.shape[1] == 1:
        # the decode hot path carries a singleton seq axis ((B, 1, K) from
        # x[:, None] in the attention projections) — squeeze so it can take
        # the fused kernel instead of the 3-D prefill fallback
        y = batched_lora(x[:, 0], a_codes, b_codes, scales, idx,
                         use_kernel=use_kernel, interpret=interpret,
                         out_dtype=out_dtype)
        return y[:, None]
    n = b_codes.shape[-1]
    kernel_ok = x.ndim == 2 and n % 128 == 0
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    # the interpreter pays per-step Python dispatch — the fused XLA reference
    # is the fast CPU path; the kernel is for real TPU lowering (and tests).
    if use_kernel and kernel_ok and not interpret:
        return batched_lora_matmul(x, a_codes, b_codes, scales, idx,
                                   out_dtype=out_dtype)
    return batched_lora_ref(x, a_codes, b_codes, scales, idx, out_dtype=out_dtype)
