"""Public jit'd wrapper for the flash-decode kernel: padding, GQA folding,
fp8 KV handling and backend dispatch."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_decode.flash_decode import flash_decode as _pallas_decode
from repro.kernels.flash_decode.paged import (paged_flash_decode,
                                              paged_flash_decode_ref)
from repro.kernels.flash_decode.ref import flash_decode_ref


@functools.partial(jax.jit, static_argnames=("block_s", "use_kernel", "interpret", "out_dtype"))
def decode_attention(
    q: jax.Array,          # (B, Hq, D)
    k: jax.Array,          # (B, Hkv, S, D)  (fp8 or bf16/f32)
    v: jax.Array,
    length: jax.Array,     # int32 ()
    kv_scale: jax.Array = 1.0,
    *,
    block_s: int = 512,
    use_kernel: bool = True,
    interpret: bool | None = None,
    out_dtype=jnp.float32,
) -> jax.Array:
    """Single-token GQA decode attention over a (padded) KV cache.

    Pads S to a block multiple (masked via `length`), folds query groups so
    the kernel's score matmul has M=G, and widens fp8 KV inside the kernel.
    """
    b, hq, d = q.shape
    _, hkv, s_len, _ = k.shape
    assert hq % hkv == 0, (hq, hkv)
    g = hq // hkv
    qg = q.reshape(b, hkv, g, d)

    if interpret is None:
        interpret = jax.default_backend() == "cpu"

    bs = min(block_s, max(128, s_len))
    pad = (-s_len) % bs
    if pad:
        widths = ((0, 0), (0, 0), (0, pad), (0, 0))
        k = jnp.pad(k.astype(jnp.float32) if k.dtype == jnp.float8_e4m3fn else k, widths)
        v = jnp.pad(v.astype(jnp.float32) if v.dtype == jnp.float8_e4m3fn else v, widths)

    if use_kernel:
        out = _pallas_decode(
            qg, k, v, length, kv_scale,
            block_s=bs, out_dtype=out_dtype, interpret=interpret,
        )
    else:
        out = flash_decode_ref(qg, k, v, length, kv_scale, out_dtype=out_dtype)
    return out.reshape(b, hq, d)


@functools.partial(jax.jit, static_argnames=("use_kernel", "interpret", "out_dtype"))
def paged_decode_attention(
    q: jax.Array,          # (B, Hq, D)
    k_pool: jax.Array,     # (n_pages, Hkv, page, D)  shared PagePool layer
    v_pool: jax.Array,
    tables: jax.Array,     # (B, n_p) int32 block tables (pad → scratch page)
    lengths: jax.Array,    # (B,) int32 live context length per sequence
    kv_scale: jax.Array = 1.0,
    *,
    use_kernel: bool = True,
    interpret: bool | None = None,
    out_dtype=jnp.float32,
) -> jax.Array:
    """Single-token GQA decode attention straight off the paged KV pool.

    The serving engine's block tables (`PagePool.batch_tables`) drive the
    kernel's page-shaped context loop via scalar prefetch — no contiguous
    gather. fp8 pools are widened per-tile inside the kernel."""
    b, hq, d = q.shape
    _, hkv, _, _ = k_pool.shape
    assert hq % hkv == 0, (hq, hkv)
    qg = q.reshape(b, hkv, hq // hkv, d)

    if interpret is None:
        interpret = jax.default_backend() == "cpu"

    if k_pool.dtype == jnp.float8_e4m3fn:
        # interpret-mode dot_generals reject fp8 inputs; widen outside
        if interpret:
            k_pool = k_pool.astype(jnp.float32)
            v_pool = v_pool.astype(jnp.float32)

    if use_kernel:
        out = paged_flash_decode(qg, k_pool, v_pool, tables, lengths, kv_scale,
                                 out_dtype=out_dtype, interpret=interpret)
    else:
        out = paged_flash_decode_ref(qg, k_pool, v_pool, tables, lengths,
                                     kv_scale, out_dtype=out_dtype)
    return out.reshape(b, hq, d)
