"""Pallas TPU kernel: paged flash-decode over a block-table-indexed KV pool.

The page-shaped twin of `flash_decode.py`: instead of a contiguous
(B, Hkv, S, D) cache, each sequence owns a *block table* of page ids into a
shared pool (serving/paged_kv.py — vLLM-style paging over the paper's
distributed-SRAM KV). The context grid axis walks the table; the block-table
entry is resolved through **scalar prefetch** (`PrefetchScalarGridSpec`), so
the k/v BlockSpec index maps pick which pool page to DMA HBM→VMEM *before*
the kernel body runs — no host-side gather ever materializes the contiguous
view. `block_s == page`: the kernel's context loop is already page-shaped,
which is exactly the integration point the pool was designed for.

Per-sequence live lengths ride in as the second scalar-prefetch operand and
mask the table's padded tail (pad slots may point at any page — commonly the
pool's scratch page — their scores are masked to -inf, contributing exactly
0 after the online softmax).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30

# jax 0.4.x names this TPUCompilerParams; newer releases renamed it
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def _kernel(tables_ref, len_ref, q_ref, k_ref, v_ref, kvs_ref, o_ref,
            m_ref, d_ref, acc_ref, *, page: int, n_p: int, scale: float):
    b = pl.program_id(0)
    p = pl.program_id(2)

    @pl.when(p == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        d_ref[...] = jnp.zeros_like(d_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)                    # (G, D)
    k = k_ref[0, 0].astype(jnp.float32) * kvs_ref[0]       # (page, D)
    v = v_ref[0, 0].astype(jnp.float32) * kvs_ref[0]       # (page, D)

    scores = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) * scale                                              # (G, page)

    # mask positions beyond this sequence's live length
    pos = p * page + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
    scores = jnp.where(pos < len_ref[b], scores, NEG_INF)

    m_prev = m_ref[...]                                    # (G, 128) lane-replicated
    m_cur = jnp.max(scores, axis=-1, keepdims=True)        # (G, 1)
    m_new = jnp.maximum(m_prev, jnp.broadcast_to(m_cur, m_prev.shape))
    corr = jnp.exp(m_prev[:, :1] - m_new[:, :1])           # (G, 1)
    pr = jnp.exp(scores - m_new[:, :1])                    # (G, page)

    d_ref[...] = d_ref[...] * corr + jnp.sum(pr, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        pr, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(p == n_p - 1)
    def _done():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(d_ref[:, :1], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("scale", "out_dtype", "interpret"),
)
def paged_flash_decode(
    q: jax.Array,         # (B, Hkv, G, D)
    k_pool: jax.Array,    # (n_pages, Hkv, page, D)  shared pool (fp8 or wider)
    v_pool: jax.Array,
    tables: jax.Array,    # (B, n_p) int32 — per-sequence block tables (padded)
    lengths: jax.Array,   # (B,) int32 — live context length per sequence
    kv_scale: jax.Array,  # f32 () — fp8 dequant scale (1.0 when KV is bf16)
    *,
    scale: float | None = None,
    out_dtype=jnp.float32,
    interpret: bool = False,
) -> jax.Array:
    b, hkv, g, d = q.shape
    _, _, page, _ = k_pool.shape
    n_p = tables.shape[1]
    scale = scale if scale is not None else d ** -0.5

    tables = jnp.asarray(tables, jnp.int32)
    lengths = jnp.asarray(lengths, jnp.int32).reshape(b)
    kv_scale = jnp.asarray(kv_scale, jnp.float32).reshape(1)

    kernel = functools.partial(_kernel, page=page, n_p=n_p, scale=scale)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                 # tables, lengths
        grid=(b, hkv, n_p),
        in_specs=[
            pl.BlockSpec((1, 1, g, d), lambda b, h, p, t, l: (b, h, 0, 0)),
            # the paged indirection: the context step's block comes from the
            # sequence's block table, not from a contiguous S axis
            pl.BlockSpec((1, 1, page, d), lambda b, h, p, t, l: (t[b, p], h, 0, 0)),
            pl.BlockSpec((1, 1, page, d), lambda b, h, p, t, l: (t[b, p], h, 0, 0)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d), lambda b, h, p, t, l: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, 128), jnp.float32),  # running max (lane-replicated)
            pltpu.VMEM((g, 128), jnp.float32),  # running denom
            pltpu.VMEM((g, d), jnp.float32),    # running output accumulator
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, d), out_dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(tables, lengths, q, k_pool, v_pool, kv_scale)


def paged_flash_decode_ref(q, k_pool, v_pool, tables, lengths, kv_scale=1.0,
                           *, scale=None, out_dtype=jnp.float32):
    """Oracle: gather the contiguous view per sequence, then dense softmax."""
    b, hkv, g, d = q.shape
    _, _, page, _ = k_pool.shape
    n_p = tables.shape[1]
    scale = scale if scale is not None else d ** -0.5
    # (B, P, H, page, D) → (B, H, P*page, D)
    kf = (k_pool[tables].astype(jnp.float32) * kv_scale
          ).transpose(0, 2, 1, 3, 4).reshape(b, hkv, n_p * page, d)
    vf = (v_pool[tables].astype(jnp.float32) * kv_scale
          ).transpose(0, 2, 1, 3, 4).reshape(b, hkv, n_p * page, d)
    s = jnp.einsum("bhgd,bhsd->bhgs", q.astype(jnp.float32), kf) * scale
    mask = jnp.arange(n_p * page)[None, :] < lengths[:, None]
    s = jnp.where(mask[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhgs,bhsd->bhgd", p, vf).astype(out_dtype)
