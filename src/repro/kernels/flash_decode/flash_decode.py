"""Pallas TPU kernel: tiled online-softmax decode attention (paper C3→TPU).

The lane-local half of TOM's attention dataflow (Fig 7b steps 0 & 3): one new
query token attends over a (possibly fp8) KV cache tile-by-tile with an
online softmax, entirely in VMEM. The cross-lane half (steps 1/2/4 — global
max and the tree reductions) lives in `core/attention.py` as shard_map
collectives; this kernel is what each lane runs on its local context shard.

Layout: queries are grouped GQA-style — ``q (B, Hkv, G, D)`` where G =
Hq/Hkv query heads share one KV head — so the score matmul `(G,D)x(D,bs)`
hits the MXU with a non-trivial M dim even for decode. KV tiles stream
HBM→VMEM along the context grid axis; running (m, d, o) state lives in VMEM
scratch across grid steps.

KV may be fp8 (e4m3): the kernel widens tiles to f32 after load — VMEM/HBM
traffic is halved, which is the paper's "Act./KV Cache Format: FP8" applied
to the memory-roofline term.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
# jax 0.4.x names this TPUCompilerParams; newer releases renamed it
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams



def _kernel(len_ref, q_ref, k_ref, v_ref, kvs_ref, o_ref,
            m_ref, d_ref, acc_ref, *, block_s: int, n_s: int, scale: float):
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        d_ref[...] = jnp.zeros_like(d_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)                    # (G, D)
    k = k_ref[0, 0].astype(jnp.float32) * kvs_ref[0]       # (bs, D)
    v = v_ref[0, 0].astype(jnp.float32) * kvs_ref[0]       # (bs, D)

    scores = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) * scale                                              # (G, bs)

    # mask positions beyond the live context length
    pos = s * block_s + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
    scores = jnp.where(pos < len_ref[0], scores, NEG_INF)

    m_prev = m_ref[...]                                    # (G, 128) lane-replicated
    m_cur = jnp.max(scores, axis=-1, keepdims=True)        # (G, 1)
    m_new = jnp.maximum(m_prev, jnp.broadcast_to(m_cur, m_prev.shape))
    corr = jnp.exp(m_prev[:, :1] - m_new[:, :1])           # (G, 1)
    p = jnp.exp(scores - m_new[:, :1])                     # (G, bs)

    d_ref[...] = d_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(s == n_s - 1)
    def _done():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(d_ref[:, :1], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("block_s", "scale", "out_dtype", "interpret"),
)
def flash_decode(
    q: jax.Array,        # (B, Hkv, G, D)
    k: jax.Array,        # (B, Hkv, S, D)   S % block_s == 0 (ops.py pads)
    v: jax.Array,        # (B, Hkv, S, D)
    length: jax.Array,   # int32 () — live context length (masks the padding)
    kv_scale: jax.Array, # f32 () — fp8 dequant scale (1.0 when KV is bf16)
    *,
    block_s: int = 512,
    scale: float | None = None,
    out_dtype=jnp.float32,
    interpret: bool = False,
) -> jax.Array:
    b, hkv, g, d = q.shape
    _, _, s_len, _ = k.shape
    assert s_len % block_s == 0, (s_len, block_s)
    n_s = s_len // block_s
    scale = scale if scale is not None else d ** -0.5

    length = jnp.asarray(length, jnp.int32).reshape(1)
    kv_scale = jnp.asarray(kv_scale, jnp.float32).reshape(1)

    kernel = functools.partial(_kernel, block_s=block_s, n_s=n_s, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=(b, hkv, n_s),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, g, d), lambda b, h, s: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, block_s, d), lambda b, h, s: (b, h, s, 0)),
            pl.BlockSpec((1, 1, block_s, d), lambda b, h, s: (b, h, s, 0)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d), lambda b, h, s: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, d), out_dtype),
        scratch_shapes=[
            pltpu.VMEM((g, 128), jnp.float32),  # running max (lane-replicated)
            pltpu.VMEM((g, 128), jnp.float32),  # running denom
            pltpu.VMEM((g, d), jnp.float32),    # running output accumulator
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(length, q, k, v, kv_scale)
