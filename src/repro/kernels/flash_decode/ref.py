"""Pure-jnp oracle for the flash-decode kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_decode_ref(
    q: jax.Array,        # (B, Hkv, G, D)
    k: jax.Array,        # (B, Hkv, S, D)
    v: jax.Array,
    length,              # int — live context length
    kv_scale=1.0,
    *,
    scale: float | None = None,
    out_dtype=jnp.float32,
) -> jax.Array:
    """Materialized-softmax GQA decode attention with length masking."""
    b, hkv, g, d = q.shape
    s_len = k.shape[2]
    scale = scale if scale is not None else d ** -0.5
    kf = k.astype(jnp.float32) * kv_scale
    vf = v.astype(jnp.float32) * kv_scale
    s = jnp.einsum("bhgd,bhsd->bhgs", q.astype(jnp.float32), kf) * scale
    mask = jnp.arange(s_len) < length
    s = jnp.where(mask[None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhgs,bhsd->bhgd", p, vf).astype(out_dtype)
