"""Optimizer substrate tests: AdamW semantics, masking, schedules, clipping,
gradient compression (error-feedback invariant)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:                      # real hypothesis when installed (CI does)
    from hypothesis import given, settings
    import hypothesis.strategies as st
except ImportError:       # deterministic fallback — properties never skip
    from repro.testing.hypothesis_compat import given, settings, st  # noqa: E402

from repro.optim import (AdamW, clip_by_global_norm, combine, constant,
                         global_norm, linear_decay, partition, trainable_mask,
                         warmup_cosine)
from repro.optim.compression import (compressed_psum_tree, compress_int8,
                                     decompress_int8, error_feedback_update,
                                     init_residuals)

jax.config.update("jax_enable_x64", False)


class TestSchedules:
    def test_warmup_cosine_shape(self):
        s = warmup_cosine(1e-3, 100, 1000, final_frac=0.1)
        assert float(s(jnp.asarray(0))) == 0.0
        np.testing.assert_allclose(float(s(jnp.asarray(100))), 1e-3, rtol=1e-5)
        assert float(s(jnp.asarray(50))) == pytest.approx(5e-4, rel=1e-5)
        np.testing.assert_allclose(float(s(jnp.asarray(1000))), 1e-4, rtol=1e-4)

    def test_linear_decay_endpoint(self):
        s = linear_decay(1e-3, 10, 100)
        assert float(s(jnp.asarray(100))) == pytest.approx(0.0, abs=1e-9)


class TestAdamW:
    def test_quadratic_convergence(self):
        """AdamW minimizes ||x - c||²."""
        c = jnp.asarray([1.0, -2.0, 3.0])
        params = {"x": jnp.zeros(3)}
        opt = AdamW(schedule=constant(0.1), weight_decay=0.0)
        st_ = opt.init(params)

        @jax.jit
        def step(p, s):
            g = jax.grad(lambda q: jnp.sum((q["x"] - c) ** 2))(p)
            return opt.update(g, s, p)[:2]

        for _ in range(300):
            params, st_ = step(params, st_)
        np.testing.assert_allclose(np.asarray(params["x"]), np.asarray(c),
                                   atol=1e-2)

    def test_fp8_first_moment_converges(self):
        """fp8-e4m3 m (the 480B-at-256-chips residency lever) still
        minimizes the quadratic; v stays bf16."""
        c = jnp.asarray([1.0, -2.0, 3.0])
        params = {"x": jnp.zeros(3)}
        opt = AdamW(schedule=constant(0.1), weight_decay=0.0,
                    m_dtype=jnp.float8_e4m3fn)
        st_ = opt.init(params)
        assert st_.m["x"].dtype == jnp.float8_e4m3fn
        assert st_.v["x"].dtype == jnp.bfloat16

        @jax.jit
        def step(p, s):
            g = jax.grad(lambda q: jnp.sum((q["x"] - c) ** 2))(p)
            return opt.update(g, s, p)[:2]

        for _ in range(400):
            params, st_ = step(params, st_)
        np.testing.assert_allclose(np.asarray(params["x"]), np.asarray(c),
                                   atol=0.05)

    def test_weight_decay_decoupled(self):
        """With zero gradient, decay shrinks params multiplicatively."""
        params = {"x": jnp.ones(4) * 10.0}
        opt = AdamW(schedule=constant(0.1), weight_decay=0.5)
        st_ = opt.init(params)
        g = {"x": jnp.zeros(4)}
        p2, _, _ = opt.update(g, st_, params)
        assert float(p2["x"][0]) < 10.0

    def test_frozen_uint8_leaves_pass_through(self):
        params = {"w": jnp.ones((4, 4)), "packed": jnp.ones((2, 2), jnp.uint8)}
        opt = AdamW(schedule=constant(0.1))
        st_ = opt.init(params)
        g = {"w": jnp.ones((4, 4)), "packed": jnp.zeros((), jnp.int8)}
        p2, _, _ = opt.update(g, st_, params)
        assert p2["packed"].dtype == jnp.uint8
        np.testing.assert_array_equal(np.asarray(p2["packed"]),
                                      np.asarray(params["packed"]))

    def test_step_counts(self):
        params = {"x": jnp.ones(2)}
        opt = AdamW(schedule=constant(0.1))
        st_ = opt.init(params)
        _, st_, _ = opt.update({"x": jnp.ones(2)}, st_, params)
        assert int(st_.step) == 1


class TestMaskPartition:
    def test_qlora_mask_selects_lora_only(self):
        params = {"attn": {"q": {"packed": jnp.zeros((2, 2), jnp.uint8),
                                 "lora": {"a": jnp.ones((4, 2)),
                                          "b": jnp.zeros((2, 4))}}},
                  "norm": {"w": jnp.ones(4)}}
        mask = trainable_mask(params, "qlora")
        flat = {jax.tree_util.keystr(p): m
                for p, m in jax.tree_util.tree_flatten_with_path(mask)[0]}
        assert all(("lora" in k) == v for k, v in flat.items())

    def test_partition_combine_roundtrip(self):
        params = {"a": jnp.ones(3), "b": jnp.zeros(2), "c": {"d": jnp.ones(1)}}
        mask = {"a": True, "b": False, "c": {"d": True}}
        tp, fp = partition(params, mask)
        back = combine(tp, fp)
        for k in ("a", "b"):
            np.testing.assert_array_equal(np.asarray(back[k]),
                                          np.asarray(params[k]))


class TestClipping:
    @settings(deadline=None, max_examples=20)
    @given(scale=st.floats(0.1, 100.0))
    def test_clipped_norm_never_exceeds(self, scale):
        g = {"x": jnp.ones(16) * scale}
        clipped, norm = clip_by_global_norm(g, 1.0)
        assert float(global_norm(clipped)) <= 1.0 + 1e-4


class TestCompression:
    def test_int8_roundtrip_error_bounded(self):
        r = np.random.default_rng(0)
        g = jnp.asarray(r.normal(size=(256,)), jnp.float32)
        q, s = compress_int8(g)
        back = decompress_int8(q, s)
        assert float(jnp.max(jnp.abs(back - g))) <= float(s) * 0.5 + 1e-7

    def test_error_feedback_accumulates_truth(self):
        """Sum of transmitted updates + final residual == sum of true grads
        exactly (the EF invariant that makes compression unbiased over time)."""
        r = np.random.default_rng(1)
        grads = [jnp.asarray(r.normal(size=(64,)), jnp.float32) for _ in range(20)]
        residual = jnp.zeros((64,))
        sent_total = jnp.zeros((64,))
        for g in grads:
            q, s, residual = error_feedback_update(g, residual)
            sent_total = sent_total + decompress_int8(q, s)
        true_total = sum(grads)
        np.testing.assert_allclose(np.asarray(sent_total + residual),
                                   np.asarray(true_total), rtol=1e-4, atol=1e-4)

    def test_compressed_psum_tree_local(self):
        g = {"w": jnp.linspace(-1, 1, 32)}
        res = init_residuals(jax.eval_shape(lambda: g))
        out, res2 = compressed_psum_tree(g, res, axis_name=None)
        np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(g["w"]),
                                   atol=2e-2)
