"""Unit + property tests for the ternary quantisation core (paper C1)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:                      # real hypothesis when installed (CI does)
    from hypothesis import given, settings
    import hypothesis.strategies as st
except ImportError:       # deterministic fallback — properties never skip
    from repro.testing.hypothesis_compat import given, settings, st  # noqa: E402

from repro.core import fp8, ternary

jax.config.update("jax_enable_x64", False)


def rng(seed=0):
    return np.random.default_rng(seed)


class TestQuantize:
    def test_values_are_ternary(self):
        w = jnp.asarray(rng().normal(size=(64, 32)), jnp.float32)
        t, s = ternary.quantize(w)
        assert t.dtype == jnp.int8
        assert set(np.unique(np.asarray(t))) <= {-1, 0, 1}
        assert s.shape == ()

    def test_reconstruction_error_bounded(self):
        w = jnp.asarray(rng(1).normal(size=(256, 128)), jnp.float32)
        t, s = ternary.quantize(w)
        wq = ternary.dequantize(t, s, jnp.float32)
        # absmean ternary error is bounded by ~max|w| but should be well below
        # the raw magnitude on Gaussian weights.
        assert float(jnp.mean((w - wq) ** 2)) < float(jnp.mean(w**2))

    def test_scale_is_absmean(self):
        w = jnp.asarray(rng(2).normal(size=(32, 32)), jnp.float32)
        _, s = ternary.quantize(w)
        np.testing.assert_allclose(float(s), float(jnp.mean(jnp.abs(w))), rtol=1e-6)

    def test_ste_gradient_is_identity(self):
        w = jnp.asarray(rng(3).normal(size=(16, 16)), jnp.float32)
        g = jax.grad(lambda w: jnp.sum(ternary.ste_quantize(w) * 2.0))(w)
        np.testing.assert_allclose(np.asarray(g), 2.0 * np.ones_like(g), rtol=1e-6)


class TestEncoding:
    def test_encode_decode_roundtrip(self):
        t = jnp.asarray(rng(4).integers(-1, 2, size=(128, 64)), jnp.int8)
        np.testing.assert_array_equal(np.asarray(ternary.decode2(ternary.encode2(t))), np.asarray(t))

    def test_paper_encoding_values(self):
        # +1→'01'(1), -1→'10'(2), 0→'00'(0)  (paper §IV-B)
        t = jnp.asarray([[1], [-1], [0], [1]], jnp.int8)
        np.testing.assert_array_equal(np.asarray(ternary.encode2(t)).ravel(), [1, 2, 0, 1])

    def test_zero_bit_ratio_bitnet_claim(self):
        # paper §V-B.b: ~40% zero weights ⇒ ~70% zero bits.
        t = jnp.asarray(rng(5).choice([-1, 0, 1], p=[0.3, 0.4, 0.3], size=(1000, 100)), jnp.int8)
        zbr = float(ternary.zero_bit_ratio(t))
        assert abs(zbr - 0.7) < 0.01

    @given(zvr=st.floats(0.0, 1.0))
    @settings(max_examples=20, deadline=None)
    def test_zero_bit_ratio_formula(self, zvr):
        n = 4000
        nz = int(round(n * zvr))
        t = np.zeros(n, np.int8)
        t[nz:] = np.where(np.arange(n - nz) % 2 == 0, 1, -1)
        got = float(ternary.zero_bit_ratio(jnp.asarray(t.reshape(-1, 1))))
        want = 1.0 - (1.0 - (nz / n)) / 2.0
        assert abs(got - want) < 1e-6


class TestPacking:
    @pytest.mark.parametrize("layout", ["interleaved", "strided"])
    @pytest.mark.parametrize("k,n", [(512, 64), (1024, 8), (2048, 256)])
    def test_pack_unpack_roundtrip(self, layout, k, n):
        t = jnp.asarray(rng(k + n).integers(-1, 2, size=(k, n)), jnp.int8)
        p = ternary.pack2(t, layout=layout)
        assert p.dtype == jnp.uint8 and p.shape == (k // 4, n)
        np.testing.assert_array_equal(np.asarray(ternary.unpack2(p, layout=layout)), np.asarray(t))

    def test_pack_batched(self):
        t = jnp.asarray(rng(9).integers(-1, 2, size=(3, 512, 16)), jnp.int8)
        p = ternary.pack2(t)
        assert p.shape == (3, 128, 16)
        np.testing.assert_array_equal(np.asarray(ternary.unpack2(p)), np.asarray(t))

    @given(seed=st.integers(0, 2**16))
    @settings(max_examples=15, deadline=None)
    def test_roundtrip_property(self, seed):
        t = jnp.asarray(rng(seed).integers(-1, 2, size=(512, 32)), jnp.int8)
        for layout in ("interleaved", "strided"):
            got = ternary.unpack2(ternary.pack2(t, layout=layout), layout=layout)
            np.testing.assert_array_equal(np.asarray(got), np.asarray(t))

    def test_ternary_tensor_container(self):
        w = jnp.asarray(rng(11).normal(size=(1024, 128)), jnp.float32)
        tt = ternary.TernaryTensor.from_dense(w)
        assert tt.shape == (1024, 128)
        t, s = ternary.quantize(w)
        np.testing.assert_allclose(
            np.asarray(tt.to_dense(jnp.float32)),
            np.asarray(ternary.dequantize(t, s, jnp.float32)),
            rtol=1e-6,
        )
        # pytree round-trip (must survive jit boundaries)
        leaves, treedef = jax.tree_util.tree_flatten(tt)
        tt2 = jax.tree_util.tree_unflatten(treedef, leaves)
        assert tt2.shape == tt.shape

    def test_compression_ratio(self):
        # 8x vs bf16, 2x vs int4
        assert abs(ternary.compression_ratio_vs(2.0, (4096, 4096)) - 8.0) < 0.01


class TestFP8:
    def test_roundtrip_accuracy(self):
        x = jnp.asarray(rng(12).normal(size=(64, 64)), jnp.float32)
        x8, s = fp8.quantize(x)
        assert x8.dtype == jnp.float8_e4m3fn
        xr = fp8.dequantize(x8, s, jnp.float32)
        err = float(jnp.max(jnp.abs(x - xr)) / jnp.max(jnp.abs(x)))
        assert err < 0.07  # e4m3 has ~2^-3 relative step at worst

    def test_scale_saturates_at_emax(self):
        x = jnp.asarray([[1000.0, -2000.0]], jnp.float32)
        x8, s = fp8.quantize(x)
        assert float(jnp.max(jnp.abs(x8.astype(jnp.float32)))) <= fp8.E4M3_MAX

    @given(seed=st.integers(0, 2**16), scale=st.floats(1e-3, 1e3))
    @settings(max_examples=15, deadline=None)
    def test_relative_error_property(self, seed, scale):
        x = jnp.asarray(rng(seed).normal(size=(32, 32)) * scale, jnp.float32)
        xr = fp8.dequantize(*fp8.quantize(x), jnp.float32)
        denom = float(jnp.max(jnp.abs(x))) + 1e-9
        assert float(jnp.max(jnp.abs(x - xr))) / denom < 0.07
