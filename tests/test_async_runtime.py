"""Async serving runtime acceptance tests.

The contract under test (ISSUE: async disaggregated serving runtime):

  * **token identity** — the async dispatch/backlog runtime produces
    bit-identical outputs to the synchronous ``ServeEngine.tick()`` loop
    for greedy and seeded sampling, across {DenseKV, PagedKV} ×
    {adapters, none} × {speculative decoding on/off}. The sync loop stays
    the correctness oracle; the async path must never trade tokens for
    overlap.
  * **crash propagation** — a worker-thread exception poisons the runtime:
    every in-flight request lands in a terminal error state, engine pages
    and slots are released (zero leaks), and the original exception
    re-raises from every caller-facing API.
  * **admission + backpressure** — the HTTP/SSE front answers budget
    violations and per-tenant overload with 429 + Retry-After before work
    reaches the dispatch inbox.
"""
import json
import threading
import time
import urllib.error
import urllib.request

import jax
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.launch.train import reduce_config
from repro.models.transformer import Model
from repro.serving import (AsyncServeRuntime, DenseKV, PagedKV, RequestSpec,
                           RuntimePoisoned, SamplingParams, ServeEngine,
                           ServingHTTPFront, Ticket)
from repro.serving.adapters import (AdapterRegistry, AdapterServing,
                                    AdapterSpec, synthetic_adapter_stacks)
from repro.serving.gateway import Gateway

jax.config.update("jax_enable_x64", False)

SPEC = AdapterSpec(rank=4, alpha=8.0, targets=("q", "v"))


@pytest.fixture(scope="module")
def model_params():
    cfg = reduce_config(get_config("bitnet-2b"), "tiny")
    model = Model(cfg, mode="serve")
    return model, model.init(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def registry(model_params):
    model, _ = model_params
    reg = AdapterRegistry(SPEC)
    rng = np.random.default_rng(7)
    for i in range(2):
        reg.register(f"tenant-{i}",
                     synthetic_adapter_stacks(rng, model.cfg, SPEC,
                                              model.cfg.num_layers,
                                              scale=0.05))
    return reg


def _engine(model_params, registry, kv_name, with_adapters, spec_k):
    model, params = model_params
    kv = PagedKV(page=8) if kv_name == "paged" else DenseKV()
    adapters = None
    if with_adapters:
        nbytes = registry.get("tenant-0").nbytes
        adapters = AdapterServing(model, registry, budget_bytes=nbytes * 2,
                                  max_resident=2)
    return ServeEngine(model, params, max_slots=2, max_len=64, kv=kv,
                       spec_decode=spec_k > 0, adapters=adapters)


def _workload(with_adapters, spec_k, n=4):
    """Mixed greedy/seeded requests (adapter on every other one)."""
    rng = np.random.default_rng(11)
    work = []
    for i in range(n):
        prompt = list(rng.integers(0, 100, size=int(rng.integers(3, 10))))
        adapter_id = (f"tenant-{i % 2}" if with_adapters and i % 2 == 0
                      else None)
        sampling = (SamplingParams(spec_k=spec_k) if i % 2 == 0 else
                    SamplingParams(temperature=0.8, top_k=16, seed=100 + i,
                                   spec_k=spec_k))
        work.append((prompt,
                     RequestSpec(max_new_tokens=6, adapter_id=adapter_id),
                     sampling))
    return work


class TestTokenIdentity:
    """Seeded/greedy async output == sync output, across the full matrix."""

    @pytest.mark.parametrize("kv_name", ["dense", "paged"])
    @pytest.mark.parametrize("with_adapters", [False, True],
                             ids=["plain", "adapters"])
    @pytest.mark.parametrize("spec_k", [0, 4], ids=["spec0", "spec4"])
    def test_async_matches_sync(self, model_params, registry, kv_name,
                                with_adapters, spec_k):
        work = _workload(with_adapters, spec_k)

        eng = _engine(model_params, registry, kv_name, with_adapters, spec_k)
        reqs = [eng.submit(p, s, sp) for p, s, sp in work]
        stats = eng.run_until_drained()
        assert stats.completed == len(work)
        ref = [r.output for r in reqs]

        eng2 = _engine(model_params, registry, kv_name, with_adapters, spec_k)
        with AsyncServeRuntime(Gateway(eng2), depth=2) as rt:
            tickets = [rt.submit(p, spec=s, sampling=sp)
                       for p, s, sp in work]
            rt.drain(timeout=300)
            out = [t.result() for t in tickets]
        assert out == ref
        assert all(t.state == "done" for t in tickets)

    def test_interleaved_submit_matches_sync(self, model_params, registry):
        """Submissions arriving mid-flight (not batched up-front) must not
        perturb seeded outputs — per-request streams depend only on
        (seed, step)."""
        work = _workload(False, 0, n=5)
        eng = _engine(model_params, registry, "paged", False, 0)
        reqs = [eng.submit(p, s, sp) for p, s, sp in work]
        eng.run_until_drained()
        ref = [r.output for r in reqs]

        eng2 = _engine(model_params, registry, "paged", False, 0)
        with AsyncServeRuntime(Gateway(eng2), depth=1) as rt:
            tickets = []
            for p, s, sp in work:
                tickets.append(rt.submit(p, spec=s, sampling=sp))
                time.sleep(0.05)       # land mid-tick, not as one batch
            rt.drain(timeout=300)
            out = [t.result() for t in tickets]
        assert out == ref


class TestObservabilityUnderThreads:
    """PR 6-7 observability must stay coherent when emit/metrics move to
    the backlog thread."""

    def test_slo_components_telescope_and_ttft_counts(self, model_params,
                                                      registry):
        eng = _engine(model_params, registry, "paged", False, 0)
        gw = Gateway(eng)
        with AsyncServeRuntime(gw, depth=2) as rt:
            tickets = [rt.submit(p, spec=s, sampling=sp)
                       for p, s, sp in _workload(False, 0)]
            rt.drain(timeout=300)
        m = gw.metrics.to_dict()
        n = len(tickets)
        toks = sum(len(t.tokens()) for t in tickets)
        assert m["histograms"]["ttft_ms"]["count"] == n
        assert m["histograms"]["tbt_ms"]["count"] == toks - n
        # every inter-token gap must be non-negative: the backlog replay
        # carries emit-time timestamps, so a stale live read would show up
        # here as a negative/zero-heavy distribution
        assert m["histograms"]["tbt_ms"]["mean"] > 0
        # per-phase SLO components telescope to the closed e2e wall
        e2e = m["histograms"]["e2e_ms"]
        phases = [m["histograms"][f"slo_phase_ms__{p}"]["mean"]
                  for p in ("queue_wait", "prefill", "decode",
                            "decode_stall", "preempted")]
        assert sum(phases) == pytest.approx(e2e["mean"], rel=0.05)

    def test_quiesce_gauges_consistent(self, model_params, registry):
        eng = _engine(model_params, registry, "paged", False, 0)
        gw = Gateway(eng)
        with AsyncServeRuntime(gw, depth=2) as rt:
            for p, s, sp in _workload(False, 0, n=3):
                rt.submit(p, spec=s, sampling=sp)
            rt.drain(timeout=300)
            rt.quiesce()
            m = gw.metrics.to_dict()["gauges"]
            assert m["pool_pages_free"] == eng.pool.pages_free
            assert m["active_slots"] == 0
            assert m["backlog_len"] == 0
            assert m["dispatch_ahead_depth"] == 0

    def test_overlap_gaps_attributed(self, model_params, registry):
        """With the pipeline primed, host gaps between dispatches overlap
        device work and must land in the overlap ledger, not the idle one
        (the bursty bench's <= 0.5x overhead acceptance rides on this)."""
        eng = _engine(model_params, registry, "dense", False, 0)
        with AsyncServeRuntime(Gateway(eng), depth=2) as rt:
            for p, s, sp in _workload(False, 0):
                rt.submit(p, spec=s, sampling=sp)
            rt.drain(timeout=300)
        assert eng.stats.tick_gaps_overlap > eng.stats.tick_gaps


class TestCrashPropagation:
    """JetThread-style supervisor: worker exception → poison → cancel all,
    release everything, re-raise everywhere."""

    def _poisoned_runtime(self, model_params, registry):
        eng = _engine(model_params, registry, "paged", False, 0)
        rt = AsyncServeRuntime(Gateway(eng), depth=2).start()
        tickets = [rt.submit(p, spec=RequestSpec(max_new_tokens=64),
                             sampling=sp)
                   for p, _s, sp in _workload(False, 0, n=3)]
        # let at least one token land so requests are mid-flight
        deadline = time.monotonic() + 60
        while (not any(t.tokens() for t in tickets)
               and time.monotonic() < deadline):
            time.sleep(0.01)
        fault = RuntimeError("injected device fault")
        orig = eng._sampling_vectors

        def boom(*a, **kw):
            raise fault
        eng._sampling_vectors = boom
        deadline = time.monotonic() + 60
        while not rt.poisoned and time.monotonic() < deadline:
            time.sleep(0.01)
        eng._sampling_vectors = orig
        assert rt.poisoned
        rt._dispatch_thread.join(timeout=30)
        rt._backlog_thread.join(timeout=30)
        return eng, rt, tickets, fault

    def test_poison_cancels_releases_and_reraises(self, model_params,
                                                  registry):
        eng, rt, tickets, fault = self._poisoned_runtime(model_params,
                                                         registry)
        # every live request reached a terminal error state
        for t in tickets:
            assert t.terminal
            assert t.state == "error"
            with pytest.raises(RuntimePoisoned):
                t.result(timeout=5)
        # zero leaked pages / slots / queue entries
        assert eng.pool.pages_free == eng.pool.cfg.n_pages
        assert all(r is None for r in eng.slot_req)
        assert len(eng.scheduler) == 0
        assert len(eng._pending) == 0
        # the original exception re-raises (chained) in every client API
        with pytest.raises(RuntimePoisoned) as ei:
            rt.submit([1, 2, 3])
        assert ei.value.cause is fault
        with pytest.raises(RuntimePoisoned):
            rt.cancel(0)
        with pytest.raises(RuntimePoisoned):
            rt.drain(timeout=5)
        with pytest.raises(RuntimePoisoned):
            rt.quiesce(timeout=5)
        with pytest.raises(RuntimePoisoned):
            rt.close()
        rt.close(raise_on_poison=False)   # idempotent non-raising shutdown

    def test_backlog_crash_also_poisons(self, model_params, registry):
        eng = _engine(model_params, registry, "dense", False, 0)
        gw = Gateway(eng)
        rt = AsyncServeRuntime(gw, depth=1).start()
        fault = RuntimeError("injected backlog fault")

        def boom(*a, **kw):
            raise fault
        gw._on_token = boom
        t = rt.submit([1, 2, 3, 4], spec=RequestSpec(max_new_tokens=8))
        deadline = time.monotonic() + 60
        while not rt.poisoned and time.monotonic() < deadline:
            time.sleep(0.01)
        assert rt.poisoned and rt.exception is fault
        with pytest.raises(RuntimePoisoned):
            t.result(timeout=10)
        rt.close(raise_on_poison=False)


class TestTicket:
    """Pure-threading Ticket contract (no model)."""

    def test_stream_sees_tokens_then_terminal(self):
        t = Ticket()
        got = []

        def consume():
            got.extend(t.stream(timeout=10))
        th = threading.Thread(target=consume)
        th.start()
        for tok in (5, 6, 7):
            t._push(tok)
        t._finish("done")
        th.join(timeout=10)
        assert got == [5, 6, 7] and t.state == "done"

    def test_result_raises_on_error(self):
        t = Ticket()
        t._push(1)
        t._finish("error", RuntimeError("x"))
        with pytest.raises(RuntimePoisoned):
            t.result(timeout=1)

    def test_done_callback_fires_once_even_if_late(self):
        t = Ticket()
        calls = []
        t.add_done_callback(lambda tk: calls.append(tk.state))
        t._finish("cancelled")
        t._finish("done")          # terminal state must not be overwritten
        t.add_done_callback(lambda tk: calls.append("late"))
        assert calls == ["cancelled", "late"]
        assert t.state == "cancelled"

    def test_result_timeout(self):
        with pytest.raises(TimeoutError):
            Ticket().result(timeout=0.05)


class TestHTTPFront:
    """Endpoint + backpressure contract over a real engine."""

    @pytest.fixture()
    def front(self, model_params, registry):
        eng = _engine(model_params, registry, "paged", False, 0)
        gw = Gateway(eng)
        rt = AsyncServeRuntime(gw, depth=1).start()
        fr = ServingHTTPFront(rt, port=0, tenant_limit=2, max_queue=8).start()
        yield fr, rt, gw
        fr.close()
        rt.close(raise_on_poison=False)

    def _post(self, port, path, body=None):
        data = json.dumps(body or {}).encode()
        req = urllib.request.Request(
            f"http://127.0.0.1:{port}{path}", data=data,
            headers={"Content-Type": "application/json"})
        try:
            resp = urllib.request.urlopen(req, timeout=30)
            return resp.status, json.loads(resp.read()), resp.headers
        except urllib.error.HTTPError as e:
            return e.code, json.loads(e.read()), e.headers

    def test_submit_stream_cancel_roundtrip(self, front):
        fr, rt, gw = front
        code, sub, _ = self._post(fr.port, "/v1/submit",
                                  {"prompt": [1, 2, 3, 4],
                                   "max_new_tokens": 5, "seed": 3})
        assert code == 200 and sub["state"] in ("queued", "pending")
        stream = urllib.request.urlopen(
            f"http://127.0.0.1:{fr.port}/v1/stream/{sub['uid']}", timeout=60)
        toks, final = [], None
        for line in stream:
            line = line.decode().strip()
            if not line.startswith("data: "):
                continue
            d = json.loads(line[6:])
            if d.get("done"):
                final = d
                break
            toks.append(d["token"])
        assert final["state"] == "done" and toks == final["tokens"]
        assert len(toks) == 5
        # cancel after completion reports not-cancelled
        code, out, _ = self._post(fr.port, f"/v1/cancel/{sub['uid']}")
        assert code == 200 and out["cancelled"] is False
        # /metrics exposition includes the async gauges
        met = urllib.request.urlopen(
            f"http://127.0.0.1:{fr.port}/metrics", timeout=10).read().decode()
        assert "dispatch_ahead_depth" in met and "tokens_out" in met

    def test_tenant_backpressure_429(self, front):
        fr, rt, gw = front
        body = {"prompt": list(range(4)), "max_new_tokens": 32,
                "tenant": "hot"}
        codes = [self._post(fr.port, "/v1/submit", body) for _ in range(3)]
        oks = [c for c, _, _ in codes if c == 200]
        rejects = [(c, h) for c, _, h in codes if c == 429]
        assert len(oks) == 2 and len(rejects) == 1
        assert rejects[0][1].get("Retry-After") is not None
        assert gw.metrics.counter("admission_rejects") >= 1
        # another tenant is not starved by the hot one
        code, _, _ = self._post(fr.port, "/v1/submit",
                                {"prompt": [5, 6, 7], "max_new_tokens": 2,
                                 "tenant": "cold"})
        assert code == 200
        rt.drain(timeout=300)

    def test_admission_rejects_unservable(self, front):
        fr, rt, gw = front
        # unknown adapter → 429 before the dispatch inbox
        code, out, _ = self._post(fr.port, "/v1/submit",
                                  {"prompt": [1, 2], "adapter_id": "ghost"})
        assert code == 429 and "adapter" in out["error"]
        # invalid sampling params → 400 (SamplingParams validation)
        code, out, _ = self._post(fr.port, "/v1/submit",
                                  {"prompt": list(range(4)), "top_p": 0.0})
        assert code == 400 and "top_p" in out["error"]
        # malformed prompt → 400
        code, out, _ = self._post(fr.port, "/v1/submit", {"prompt": "hi"})
        assert code == 400
        assert urllib.request.urlopen(
            f"http://127.0.0.1:{fr.port}/healthz", timeout=10).status == 200
