"""Explicit-lanes decode (core/lane_serve.py) == GSPMD decode.

The paper's dataflow is hand-written with shard_map (every lane's program:
K-sliced ternary GEMVs + tree reductions + the Fig 7b two-phase attention);
this must compute the same function XLA's partitioner derives from
shardings. Runs in a subprocess (8 placeholder devices)."""
import pathlib
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs.base import get_config
    from repro.launch.train import reduce_config
    from repro.models.transformer import Model
    from repro.core.lane_serve import make_lane_decode_step

    for arch in ("bitnet-2b", "qwen3-1.7b"):   # relu2+tied / swiglu+qk_norm
        cfg = reduce_config(get_config(arch), "tiny")
        mesh = jax.make_mesh((8,), ("model",))
        model = Model(cfg, mode="serve")
        params = model.init(jax.random.PRNGKey(0))
        cache_g = model.init_cache(2, 16)
        step_g = jax.jit(model.decode_step)
        step_l = jax.jit(make_lane_decode_step(cfg, mesh))
        c0 = model.init_cache(2, 16)
        cache_l = {"k": c0["k"], "v": c0["v"]}
        tok = jnp.asarray([3, 7], jnp.int32)
        for pos in range(4):
            lg, cache_g = step_g(params, cache_g, tok, jnp.asarray(pos, jnp.int32))
            ll, cache_l = step_l(params, cache_l, tok, jnp.asarray(pos, jnp.int32))
            corr = np.corrcoef(np.asarray(lg).ravel(), np.asarray(ll).ravel())[0, 1]
            assert corr > 0.99, (arch, pos, corr)
            assert (jnp.argmax(lg, -1) == jnp.argmax(ll, -1)).all(), (arch, pos)
            tok = jnp.argmax(lg, -1).astype(jnp.int32)
        print(arch, "OK")
""")


@pytest.mark.slow
def test_lane_serve_matches_gspmd():
    res = subprocess.run(
        [sys.executable, "-c", _SCRIPT], capture_output=True, text=True,
        timeout=900,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root"},
        cwd=str(pathlib.Path(__file__).resolve().parents[1]))
    assert res.returncode == 0, res.stderr[-2000:]
    assert "bitnet-2b OK" in res.stdout and "qwen3-1.7b OK" in res.stdout
