"""Serving gateway tests: SLO scheduler, admission/preemption under pool
exhaustion, prefix-cache reuse (identical outputs vs cold path), paged-vs-
dense engine equivalence, per-slot sampling, truncation regression."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.launch.train import reduce_config
from repro.models.transformer import Model
from repro.serving import (DenseKV, PagedKV, RequestSpec, SamplingParams,
                           ServeEngine)
from repro.serving.engine import Request
from repro.serving.gateway import Gateway, Metrics, PrefixCache, Scheduler

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="module")
def model_params():
    cfg = reduce_config(get_config("bitnet-2b"), "tiny")
    model = Model(cfg, mode="serve")
    return model, model.init(jax.random.PRNGKey(0))


def _req(uid, prompt_len=4, deadline_s=None, **spec_kw):
    return Request(uid, list(range(prompt_len)), spec=RequestSpec(**spec_kw),
                   deadline_s=deadline_s, t_submit=time.time())


class TestScheduler:
    def test_priority_classes_strict_order(self):
        s = Scheduler()
        s.push(_req(1, priority=2))
        s.push(_req(2, priority=0))
        s.push(_req(3, priority=1))
        assert [s.pop_next().uid for _ in range(3)] == [2, 3, 1]

    def test_edf_within_class(self):
        s = Scheduler()
        now = time.time()
        s.push(_req(1, priority=1, deadline_s=now + 9.0))
        s.push(_req(2, priority=1, deadline_s=now + 1.0))
        s.push(_req(3, priority=1))                       # no deadline → last
        assert [s.pop_next().uid for _ in range(3)] == [2, 1, 3]

    def test_admission_bypasses_blocked_head(self):
        """A huge head must not wedge the queue: smaller entries flow."""
        s = Scheduler()
        s.push(_req(1, prompt_len=100, priority=0))
        s.push(_req(2, prompt_len=2, priority=1))
        got = s.pop_next(lambda r: len(r.prompt) < 10)
        assert got.uid == 2 and len(s) == 1

    def test_queue_cap_rejects(self):
        s = Scheduler(max_queue=1)
        assert s.push(_req(1))
        assert not s.push(_req(2))

    def test_drop_expired(self):
        s = Scheduler()
        now = time.time()
        s.push(_req(1, deadline_s=now - 1.0))
        s.push(_req(2, deadline_s=now + 60.0))
        dead = s.drop_expired(now)
        assert [r.uid for r in dead] == [1] and len(s) == 1

    def test_pick_victim_youngest_lowest_priority(self):
        a = _req(1, priority=0); a.t_admit = 1.0
        b = _req(2, priority=2); b.t_admit = 2.0
        c = _req(3, priority=2); c.t_admit = 3.0
        s = Scheduler()
        assert s.pick_victim([(0, a), (1, b), (2, c)]) == 2
        # admission-time preemption: only classes below the demander's
        assert s.pick_victim([(0, a)], below_priority=0) is None


class TestPrefixCacheUnit:
    def test_match_commit_refcount_evict(self):
        pc = PrefixCache(page=4)
        toks = list(range(12))                    # 3 full pages
        assert pc.lookup(toks) == 0
        keys = pc.commit(toks, table=[7, 8, 9], start_pages=0)
        assert len(keys) == 3
        ids, mkeys = pc.match(toks + [99])
        assert ids == [7, 8, 9]
        # active refs pin pages: nothing evictable
        assert pc.evict(10) == []
        pc.decref(mkeys)
        pc.decref(keys)
        # now resident-only → LRU leaf-first cascade frees all three
        freed = pc.evict(10)
        assert sorted(freed) == [7, 8, 9] and pc.n_pages == 0

    def test_match_leaves_one_token_for_decode(self):
        pc = PrefixCache(page=4)
        pc.commit(list(range(8)), table=[1, 2], start_pages=0)
        # prompt exactly == cached span: must not match the last page
        ids, _ = pc.match(list(range(8)))
        assert ids == [1]


class TestPagedVsDense:
    def test_token_identical_greedy(self, model_params):
        """Acceptance: ServeEngine(kv='paged') == kv='dense' greedy outputs."""
        model, params = model_params
        rng = np.random.default_rng(0)
        prompts = [list(rng.integers(0, 100, size=int(rng.integers(2, 14))))
                   for _ in range(7)]
        outs = {}
        for kv_name, kv in (("dense", DenseKV()), ("paged", PagedKV(page=8))):
            eng = ServeEngine(model, params, max_slots=3, max_len=64, kv=kv)
            reqs = [eng.submit(p, RequestSpec(max_new_tokens=6))
                    for p in prompts]
            stats = eng.run_until_drained()
            assert stats.completed == len(prompts)
            outs[kv_name] = [r.output for r in reqs]
        assert outs["dense"] == outs["paged"]

    def test_paged_batched_prefill_matches_token(self, model_params):
        model, params = model_params
        prompt = list(range(5, 30))
        outs = []
        for mode in ("token", "batched"):
            eng = ServeEngine(model, params, max_slots=2, max_len=64,
                              kv=PagedKV(page=8), prefill=mode)
            r = eng.submit(prompt, RequestSpec(max_new_tokens=5))
            eng.run_until_drained()
            outs.append(r.output)
        assert outs[0] == outs[1]


class TestPrefixCacheReuse:
    def test_warm_hit_identical_outputs_and_skipped_prefill(self, model_params):
        model, params = model_params
        shared = list(range(10, 26))              # 2 full pages of 8
        tails = [[3, 4, 5], [6, 7], [8, 9, 1]]

        cold = ServeEngine(model, params, max_slots=2, max_len=64,
                           kv=PagedKV(page=8))
        cold_reqs = [cold.submit(shared + t, RequestSpec(max_new_tokens=5))
                     for t in tails]
        cold.run_until_drained()

        warm = ServeEngine(model, params, max_slots=2, max_len=64,
                           kv=PagedKV(page=8), prefix_cache=True)
        r0 = warm.submit(shared + tails[0], RequestSpec(max_new_tokens=5))
        warm.run_until_drained()                  # commits the shared pages
        r1 = warm.submit(shared + tails[1], RequestSpec(max_new_tokens=5))
        r2 = warm.submit(shared + tails[2], RequestSpec(max_new_tokens=5))
        warm.run_until_drained()

        assert [r.output for r in cold_reqs] == [r.output for r in (r0, r1, r2)]
        assert r0.prefix_hit_tokens == 0
        assert r1.prefix_hit_tokens == 16 and r2.prefix_hit_tokens == 16
        # the shared span costs zero prefill ticks on the warm path
        assert r1.prefill_ticks == cold_reqs[1].prefill_ticks - 16
        assert warm.stats.prefix_hit_tokens == 32

    def test_shared_pages_not_freed_while_resident(self, model_params):
        model, params = model_params
        warm = ServeEngine(model, params, max_slots=1, max_len=64,
                           kv=PagedKV(page=4), prefix_cache=True)
        r = warm.submit(list(range(9)), RequestSpec(max_new_tokens=3))
        warm.run_until_drained()
        # 2 full pages committed → resident in the trie, off the free list
        assert warm.prefix.n_pages == 2
        assert warm.pool.pages_free == warm.pool.cfg.n_pages - 2


class TestAdmissionPreemption:
    def test_preemption_under_pool_exhaustion(self, model_params):
        """Two long requests can't fit a 6-page pool together: the
        low-priority one is preempted, re-queued with its generated tokens,
        and both still complete with full outputs."""
        model, params = model_params
        eng = ServeEngine(model, params, max_slots=2, max_len=64,
                          kv=PagedKV(page=8, n_pages=6))
        hi = eng.submit(list(range(1, 20)),
                        RequestSpec(max_new_tokens=10, priority=0))
        lo = eng.submit(list(range(30, 49)),
                        RequestSpec(max_new_tokens=10, priority=2))
        stats = eng.run_until_drained()
        assert stats.completed == 2
        assert stats.preemptions >= 1 and lo.n_preempts >= 1
        assert hi.n_preempts == 0
        assert len(hi.output) == 10 and len(lo.output) == 10

    def test_preempted_output_matches_unpreempted(self, model_params):
        """Preemption must not corrupt the resumed request's tokens."""
        model, params = model_params
        base = ServeEngine(model, params, max_slots=1, max_len=64,
                           kv=PagedKV(page=8))
        ref = base.submit(list(range(30, 49)), RequestSpec(max_new_tokens=10))
        base.run_until_drained()

        eng = ServeEngine(model, params, max_slots=2, max_len=64,
                          kv=PagedKV(page=8, n_pages=6))
        eng.submit(list(range(1, 20)),
                   RequestSpec(max_new_tokens=10, priority=0))
        lo = eng.submit(list(range(30, 49)),
                        RequestSpec(max_new_tokens=10, priority=2))
        eng.run_until_drained()
        assert lo.n_preempts >= 1
        assert lo.output == ref.output

    def test_oversized_request_never_thrashes(self, model_params):
        """A request bigger than the whole pool stays queued (bypassed by
        smaller ones) instead of triggering preemption churn."""
        model, params = model_params
        eng = ServeEngine(model, params, max_slots=2, max_len=64,
                          kv=PagedKV(page=8, n_pages=2))   # 16-token pool
        giant = eng.submit(list(range(30)),
                           RequestSpec(max_new_tokens=8, priority=0))
        small = eng.submit([1, 2, 3], RequestSpec(max_new_tokens=4, priority=1))
        eng.run_until_drained(max_ticks=200)   # must bail, not spin forever
        assert small.state == "done"
        assert giant.state == "queued"
        assert eng.stats.preemptions == 0

    def test_lifetime_footprint_gates_admission(self, model_params):
        """Regression: a short-prompt request whose *final* context exceeds
        the pool used to be admitted (admission only counted prompt + 1)
        and then crashed the whole run with MemoryError mid-generation."""
        model, params = model_params
        eng = ServeEngine(model, params, max_slots=2, max_len=64,
                          kv=PagedKV(page=8, n_pages=2))   # 16-token pool
        doomed = eng.submit([1, 2, 3], RequestSpec(max_new_tokens=20))  # 23 toks
        small = eng.submit([4, 5], RequestSpec(max_new_tokens=4))
        eng.run_until_drained(max_ticks=200)               # must not raise
        assert small.state == "done"
        assert doomed.state == "queued"

    def test_no_preemption_when_it_cannot_help(self, model_params):
        """Regression: preempting a victim whose pages still don't make the
        head admissible livelocked (victim re-admitted every tick, head
        starved, preemption counter unbounded)."""
        model, params = model_params
        eng = ServeEngine(model, params, max_slots=3, max_len=64,
                          kv=PagedKV(page=8, n_pages=6))
        a = eng.submit(list(range(28)),
                       RequestSpec(max_new_tokens=12, priority=0))  # 4 pages now, 5 lifetime
        v = eng.submit([1, 2, 3, 4],
                       RequestSpec(max_new_tokens=3, priority=2))   # 1 page
        eng.tick()
        # head needs 3 pages; free=1, victim v owns 1 → preemption can't help
        h = eng.submit(list(range(40, 57)),
                       RequestSpec(max_new_tokens=6, priority=1))
        for _ in range(4):
            eng.tick()
        assert eng.stats.preemptions == 0
        assert v.state in ("running", "done")   # not thrashed
        stats = eng.run_until_drained(max_ticks=500)
        assert stats.completed == 3             # h admitted once pages free
        assert len(h.output) == 6

    def test_pool_admission_control_queues_when_full(self, model_params):
        """A request whose KV can't fit free pages waits in the queue even
        while a slot is free."""
        model, params = model_params
        eng = ServeEngine(model, params, max_slots=2, max_len=64,
                          kv=PagedKV(page=8, n_pages=3))
        big = eng.submit(list(range(1, 18)), RequestSpec(max_new_tokens=4))   # 3 pages
        small = eng.submit([1, 2, 3], RequestSpec(max_new_tokens=4))          # 1 page
        eng.tick()   # big admitted (3 pages), small must wait
        assert big.state == "running"
        assert small.state == "queued"
        eng.run_until_drained()
        assert big.state == "done" and small.state == "done"


class TestGatewayFrontend:
    def test_stream_yields_all_tokens(self, model_params):
        model, params = model_params
        gw = Gateway(ServeEngine(model, params, max_slots=2, max_len=64))
        r = gw.submit([3, 4, 5], RequestSpec(max_new_tokens=6))
        assert list(gw.stream(r)) == r.output
        assert len(r.output) == 6

    def test_stream_callback_and_metrics(self, model_params):
        model, params = model_params
        gw = Gateway(ServeEngine(model, params, max_slots=2, max_len=64,
                                 kv=PagedKV(page=8)))
        seen = []
        r = gw.submit([3, 4, 5],
                      RequestSpec(max_new_tokens=5,
                                  stream_cb=lambda req, tok: seen.append(tok)))
        gw.run_until_drained()
        assert seen == r.output
        m = gw.metrics_dict()
        assert m["counters"]["requests_completed"] == 1
        assert m["counters"]["tokens_out"] == 5
        assert m["histograms"]["ttft_ms"]["count"] == 1
        assert m["histograms"]["tbt_ms"]["count"] == 4
        assert 0.0 <= m["gauges"]["pool_occupancy"] <= 1.0

    def test_cancel_queued_and_running(self, model_params):
        model, params = model_params
        gw = Gateway(ServeEngine(model, params, max_slots=1, max_len=64))
        a = gw.submit([1, 2, 3], RequestSpec(max_new_tokens=8))
        b = gw.submit([4, 5, 6], RequestSpec(max_new_tokens=8))
        gw.step()                         # a running, b queued
        assert gw.cancel(b.uid) and b.state == "cancelled"
        assert gw.cancel(a.uid) and a.state == "cancelled"
        assert not gw.cancel(999)
        gw.run_until_drained()
        assert gw.metrics.counter("requests_cancelled") == 2

    def test_deadline_expiry(self, model_params):
        model, params = model_params
        gw = Gateway(ServeEngine(model, params, max_slots=1, max_len=64))
        gw.submit([1, 2], RequestSpec(max_new_tokens=4))       # occupies slot
        late = gw.submit([3, 4],
                         RequestSpec(max_new_tokens=4, deadline_ms=-1.0))
        gw.run_until_drained()
        assert late.state == "expired"
        assert gw.metrics.counter("requests_expired") == 1


class TestSamplingAndTruncation:
    def test_top_k_is_per_slot(self, model_params):
        """Regression: one slot's top_k=1 must not collapse a co-scheduled
        full-softmax slot to greedy (the old code applied
        max(top_k over batch) to everyone)."""
        model, params = model_params
        eng = ServeEngine(model, params, max_slots=2, max_len=64, seed=7)
        logits = jnp.asarray(
            np.tile(np.linspace(0.0, 3.0, 32, dtype=np.float32), (2, 1)))
        temps = jnp.asarray([5.0, 5.0], jnp.float32)
        topks = jnp.asarray([0, 1], jnp.int32)
        key = jax.random.PRNGKey(0)
        topps = jnp.ones((2,), jnp.float32)
        seeds = jnp.zeros((2,), jnp.int32)
        has_seed = jnp.zeros((2,), bool)
        steps = jnp.zeros((2,), jnp.int32)
        toks0, toks1 = set(), set()
        for i in range(50):
            key, sub = jax.random.split(key)
            t = np.asarray(eng._sample(logits, sub, temps, topks, topps,
                                       seeds, has_seed, steps))
            toks0.add(int(t[0]))
            toks1.add(int(t[1]))
        assert toks1 == {31}, "top_k=1 slot must always emit the argmax"
        assert len(toks0) > 1, "top_k=0 slot must sample the full softmax"

    def test_truncation_keeps_prompt_tail(self, model_params):
        """Regression: max_new_tokens >= max_len used to keep the prompt
        *head* (or everything); it must clamp the budget and keep the tail."""
        model, params = model_params
        prompt = list(range(30))
        eng = ServeEngine(model, params, max_slots=1, max_len=16)
        r = eng.submit(prompt, RequestSpec(max_new_tokens=20))
        eng.run_until_drained()
        assert r.max_new_tokens == 15           # clamped to max_len - 1
        assert len(r.output) == 15
        # equivalent direct submission of the kept tail
        eng2 = ServeEngine(model, params, max_slots=1, max_len=16)
        r2 = eng2.submit([prompt[-1]], RequestSpec(max_new_tokens=15))
        eng2.run_until_drained()
        assert r.output == r2.output

    def test_truncation_exact_fit_unchanged(self, model_params):
        model, params = model_params
        eng = ServeEngine(model, params, max_slots=1, max_len=32)
        r = eng.submit(list(range(8)), RequestSpec(max_new_tokens=24))  # 8+24=32
        eng.run_until_drained()
        assert len(r.output) == 24 and r.max_new_tokens == 24
