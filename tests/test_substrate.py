"""Substrate tests: data pipeline, checkpointing, fault runtime, elastic
re-mesh, HLO structural analysis."""
import os
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:                      # real hypothesis when installed (CI does)
    from hypothesis import given, settings
    import hypothesis.strategies as st
except ImportError:       # deterministic fallback — properties never skip
    from repro.testing.hypothesis_compat import given, settings, st  # noqa: E402

from repro.ckpt import checkpoint as C
from repro.data import DataConfig, TokenPipeline, write_token_file
from repro.launch import hlo_analysis as H
from repro.runtime import (PreemptionHandler, RetryPolicy, StepRunner,
                           StragglerWatchdog)

jax.config.update("jax_enable_x64", False)


class TestDataPipeline:
    def test_determinism_across_instances(self):
        cfg = DataConfig(vocab_size=512, batch=4, seq=64, seed=7)
        a = TokenPipeline(cfg)
        b = TokenPipeline(cfg)
        for _ in range(3):
            ba, bb = a.next(), b.next()
            np.testing.assert_array_equal(ba["tokens"], bb["tokens"])

    def test_seek_resume_exact(self):
        cfg = DataConfig(vocab_size=512, batch=4, seq=64, seed=7)
        a = TokenPipeline(cfg)
        batches = [a.next() for _ in range(5)]
        b = TokenPipeline(cfg)
        b.seek(3)
        np.testing.assert_array_equal(b.next()["tokens"], batches[3]["tokens"])

    def test_labels_are_shifted_tokens(self):
        p = TokenPipeline(DataConfig(vocab_size=128, batch=2, seq=32))
        b = p.next()
        np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])

    def test_host_interleave_disjoint(self):
        k = dict(vocab_size=128, batch=2, seq=16, seed=3)
        h0 = TokenPipeline(DataConfig(**k, host_id=0, num_hosts=2))
        h1 = TokenPipeline(DataConfig(**k, host_id=1, num_hosts=2))
        b0, b1 = h0.next(), h1.next()
        assert not np.array_equal(b0["tokens"], b1["tokens"])

    def test_vocab_bounds(self):
        p = TokenPipeline(DataConfig(vocab_size=100, batch=2, seq=64))
        for _ in range(3):
            b = p.next()
            assert b["tokens"].min() >= 0 and b["tokens"].max() < 100

    def test_mmap_corpus_roundtrip(self):
        with tempfile.TemporaryDirectory() as d:
            path = os.path.join(d, "toks.bin")
            toks = np.arange(10_000, dtype=np.uint16) % 1000
            write_token_file(path, toks)
            p = TokenPipeline(DataConfig(vocab_size=1000, batch=2, seq=32,
                                         path=path))
            b = p.next()
            assert b["tokens"].shape == (2, 32)
            np.testing.assert_array_equal(
                b["tokens"][0], (np.arange(32) % 1000).astype(np.int32))

    def test_synthetic_corpus_is_learnable_structured(self):
        """The Markov backbone must make next-token entropy < log(V)."""
        p = TokenPipeline(DataConfig(vocab_size=512, batch=16, seq=256, seed=0))
        b = p.next()
        toks = b["tokens"].ravel()
        hist = np.bincount(toks, minlength=512).astype(np.float64)
        probs = hist / hist.sum()
        ent = -(probs[probs > 0] * np.log(probs[probs > 0])).sum()
        assert ent < np.log(512) * 0.9  # unigram already non-uniform


class TestCheckpoint:
    def _state(self):
        return {"w": jnp.arange(12., dtype=jnp.float32).reshape(3, 4),
                "bf": jnp.ones((4,), jnp.bfloat16) * 1.5,
                "packed": jnp.asarray([[1, 2], [3, 4]], jnp.uint8),
                "fp8": jnp.ones((2,), jnp.float8_e4m3fn)}

    def test_roundtrip_all_dtypes(self):
        with tempfile.TemporaryDirectory() as d:
            st_ = self._state()
            C.save(d, 5, st_, {"cursor": 2}, async_=False)
            out, meta = C.restore(d, 5, jax.tree.map(jnp.zeros_like, st_))
            assert meta["cursor"] == 2
            for k in st_:
                np.testing.assert_array_equal(
                    np.asarray(out[k]).view(np.uint8),
                    np.asarray(st_[k]).view(np.uint8))

    def test_crc_detects_corruption(self):
        with tempfile.TemporaryDirectory() as d:
            C.save(d, 1, self._state(), async_=False)
            # flip a byte in one leaf file
            step_dir = C._step_dir(d, 1)
            f = sorted(step_dir.glob("leaf_*.npy"))[0]
            raw = bytearray(f.read_bytes())
            raw[-1] ^= 0xFF
            f.write_bytes(bytes(raw))
            with pytest.raises(IOError, match="CRC"):
                C.restore(d, 1, self._state())

    def test_atomicity_no_partial_dirs_visible(self):
        with tempfile.TemporaryDirectory() as d:
            C.save(d, 1, self._state(), async_=False)
            C.save(d, 2, self._state(), async_=False)
            assert C.all_steps(d) == [1, 2]
            # a stale tmp dir must not be listed
            (C._step_dir(d, 3).with_suffix(".tmp99.1")).mkdir()
            assert C.all_steps(d) == [1, 2]

    def test_gc_keeps_last_k(self):
        with tempfile.TemporaryDirectory() as d:
            for s in (1, 2, 3, 4, 5):
                C.save(d, s, self._state(), async_=False, keep=2)
            assert C.all_steps(d) == [4, 5]

    def test_async_save_and_same_step_race(self):
        with tempfile.TemporaryDirectory() as d:
            st_ = self._state()
            C.save(d, 7, st_, async_=True)
            C.save(d, 7, st_, async_=False)   # blocking save of same step
            C.wait_pending()
            assert C.latest_step(d) == 7
            C.restore(d, 7, st_)

    def test_shape_mismatch_raises(self):
        with tempfile.TemporaryDirectory() as d:
            C.save(d, 1, {"w": jnp.ones((2, 2))}, async_=False)
            with pytest.raises(ValueError, match="shape"):
                C.restore(d, 1, {"w": jnp.ones((3, 3))})


class TestRuntime:
    def test_retry_then_success(self):
        calls = {"n": 0}

        def flaky():
            calls["n"] += 1
            if calls["n"] < 3:
                raise RuntimeError("transient")
            return jnp.ones(2)

        r = StepRunner(RetryPolicy(base_delay_s=0.001))
        out = r.run(flaky)
        assert calls["n"] == 3 and r.retry_count == 2

    def test_retries_exhausted_raises(self):
        r = StepRunner(RetryPolicy(max_retries=2, base_delay_s=0.001))
        with pytest.raises(RuntimeError):
            r.run(lambda: (_ for _ in ()).throw(RuntimeError("always")))

    def test_straggler_flagging(self):
        w = StragglerWatchdog(factor=3.0, min_samples=3)
        for _ in range(5):
            assert w.observe(0, 0.01) is None
        rep = w.observe(6, 0.5)
        assert rep is not None and rep["factor"] > 3

    def test_preemption_flag(self):
        h = PreemptionHandler()
        assert not h.should_stop
        h.request_stop()
        assert h.should_stop


class TestElasticRemesh:
    def test_restore_on_different_mesh(self):
        """Save on a 1-device layout, restore re-sharded onto (1,1) mesh —
        the sharding changes, the values don't."""
        from repro.launch.mesh import make_mesh
        from repro.runtime.elastic import plan_remesh, restore_on_mesh
        from repro.configs.base import get_config
        from repro.launch.train import reduce_config

        cfg = reduce_config(get_config("qwen3-1.7b"), "tiny")
        from repro.models.transformer import Model
        model = Model(cfg, mode="qat")
        params = model.init(jax.random.PRNGKey(0))
        with tempfile.TemporaryDirectory() as d:
            C.save(d, 3, {"params": params}, {"step": 3}, async_=False)
            plan = plan_remesh(cfg, (1, 1), ("data", "model"), global_batch=8)
            specs = {"params": jax.eval_shape(lambda: model.init(
                jax.random.PRNGKey(0)))}
            state, meta = restore_on_mesh(d, 3, specs, plan, mode="qat")
            assert meta["step"] == 3
            a = jax.tree.leaves(params)[0]
            b = jax.tree.leaves(state["params"])[0]
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_plan_rejects_indivisible(self):
        from repro.runtime.elastic import plan_remesh
        from repro.configs.base import get_config
        with pytest.raises(ValueError):
            plan_remesh(get_config("qwen3-1.7b"), (1, 3), ("data", "model"))


class TestHLOAnalysis:
    def _flops(self, n_layers, unroll):
        w = jnp.ones((n_layers, 32, 32), jnp.float32)

        def f(x, w):
            if unroll:
                for i in range(n_layers):
                    x = jnp.tanh(x @ w[i])
                return x
            x, _ = jax.lax.scan(lambda c, wl: (jnp.tanh(c @ wl), None), x, w)
            return x

        x = jnp.ones((4, 32), jnp.float32)
        co = jax.jit(f).lower(x, w).compile()
        return H.analyze(co.as_text())

    def test_scan_flops_match_unrolled(self):
        a = self._flops(6, unroll=False)
        b = self._flops(6, unroll=True)
        assert abs(a["flops"] - b["flops"]) / b["flops"] < 0.01

    def test_trip_count_scaling(self):
        a = self._flops(2, unroll=False)
        b = self._flops(8, unroll=False)
        assert 3.5 < b["flops"] / a["flops"] < 4.5

    def test_collectives_weighted_by_trip(self):
        import subprocess, sys, textwrap, pathlib
        script = textwrap.dedent("""
            import os
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
            import jax, jax.numpy as jnp
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro.launch import hlo_analysis as H
            mesh = jax.make_mesh((4,), ("model",))
            w = jnp.ones((6, 32, 32))
            def f(x, w):
                def body(c, wl):
                    y = c @ wl
                    return y, None
                x, _ = jax.lax.scan(body, x, w)
                return x
            xs = jax.ShapeDtypeStruct((4, 32), jnp.float32)
            ws = jax.ShapeDtypeStruct((6, 32, 32), jnp.float32)
            co = jax.jit(f, in_shardings=(
                NamedSharding(mesh, P(None, "model")),
                NamedSharding(mesh, P(None, "model", None))),
                out_shardings=NamedSharding(mesh, P(None, "model"))
            ).lower(xs, ws).compile()
            st = H.analyze(co.as_text())
            counts = {k: v["count"] for k, v in st["collectives"].items() if v["count"]}
            total = sum(counts.values())
            assert total >= 6, (counts, "expected >=1 collective x 6 trips")
            print("OK", counts)
        """)
        # inherit the parent env (JAX_PLATFORMS et al.) — a hand-stripped env
        # made jax hang probing platforms under the forced 4-device flag
        res = subprocess.run(
            [sys.executable, "-c", script], capture_output=True, text=True,
            timeout=300,
            env={**os.environ, "PYTHONPATH": "src"},
            cwd=str(pathlib.Path(__file__).resolve().parents[1]))
        assert res.returncode == 0, res.stderr[-1500:]
        assert "OK" in res.stdout
