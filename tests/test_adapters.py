"""Multi-tenant QLoRA adapter serving: registry/cache units, batched
ternary-LoRA kernel vs reference, freeze→serve round-trip, scheduler
adapter-affinity invariants, SRAM-budget churn, and the acceptance bar —
a batch mixing ≥3 distinct adapters (plus None slots) produces per-slot
greedy outputs token-identical to running each request alone, in both
kv='dense' and kv='paged'."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core import qlora, ternary
from repro.kernels.batched_lora.batched_lora import batched_lora_matmul
from repro.kernels.batched_lora.ref import batched_lora_ref
from repro.launch.train import reduce_config
from repro.models.transformer import Model
from repro.serving import PagedKV, RequestSpec, ServeEngine
from repro.serving.adapters import (AdapterCache, AdapterRegistry,
                                    AdapterServing, AdapterSpec,
                                    synthetic_adapter_stacks, target_dims)
from repro.serving.engine import Request
from repro.serving.gateway import Gateway, Scheduler

jax.config.update("jax_enable_x64", False)

SPEC = AdapterSpec(rank=8, alpha=16.0, targets=("q", "v"))


def _kv(name):
    """Map a parametrize id to a fresh KV backend instance."""
    return PagedKV(page=8) if name == "paged" else None


@pytest.fixture(scope="module")
def model_params():
    cfg = reduce_config(get_config("bitnet-2b"), "tiny")
    model = Model(cfg, mode="serve")
    return model, model.init(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def registry(model_params):
    model, _ = model_params
    reg = AdapterRegistry(SPEC)
    rng = np.random.default_rng(7)
    for i in range(4):
        reg.register(f"tenant-{i}",
                     synthetic_adapter_stacks(rng, model.cfg, SPEC,
                                              model.cfg.num_layers, scale=0.05))
    return reg


def make_serving(model, registry, *, budget_adapters=4, max_resident=4):
    nbytes = registry.get("tenant-0").nbytes
    return AdapterServing(model, registry, budget_bytes=nbytes * budget_adapters,
                          max_resident=max_resident)


# ---------------------------------------------------------------------------
# Kernel vs reference (interpreter-mode, per the repo's Pallas test idiom)
# ---------------------------------------------------------------------------


def _stacks(n_adapters, k, r, n, seed=0):
    g = np.random.default_rng(seed)
    a_codes = np.zeros((n_adapters, k // 4, r), np.uint8)
    b_codes = np.zeros((n_adapters, r // 4, n), np.uint8)
    scales = np.zeros((n_adapters,), np.float32)
    for i in range(1, n_adapters):              # slot 0 stays the null adapter
        frozen = qlora.freeze_adapter({
            "a": jnp.asarray(g.normal(size=(k, r)), jnp.float32),
            "b": jnp.asarray(g.normal(size=(r, n)), jnp.float32)})
        a_codes[i] = np.asarray(frozen["a"].packed)
        b_codes[i] = np.asarray(frozen["b"].packed)
        scales[i] = float(frozen["a"].scale) * float(frozen["b"].scale) * 2.0
    return jnp.asarray(a_codes), jnp.asarray(b_codes), jnp.asarray(scales)


class TestBatchedLoraKernel:
    @pytest.mark.parametrize("k,r,n", [(64, 8, 128), (320, 16, 256),
                                       (128, 4, 384)])
    def test_kernel_matches_ref(self, k, r, n):
        a, b, s = _stacks(5, k, r, n, seed=k + n)
        x = jnp.asarray(np.random.default_rng(1).normal(size=(6, k)), jnp.float32)
        idx = jnp.asarray([0, 1, 2, 3, 4, 2], jnp.int32)
        got = batched_lora_matmul(x, a, b, s, idx, interpret=True)
        want = batched_lora_ref(x, a, b, s, idx)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)

    def test_null_adapter_row_is_exactly_zero(self):
        a, b, s = _stacks(3, 64, 8, 128, seed=9)
        x = jnp.asarray(np.random.default_rng(2).normal(size=(3, 64)), jnp.float32)
        got = np.asarray(batched_lora_matmul(x, a, b, s,
                                             jnp.asarray([1, 0, 2], jnp.int32),
                                             interpret=True))
        assert np.all(got[1] == 0.0)
        assert np.any(got[0] != 0.0) and np.any(got[2] != 0.0)

    def test_segmented_rows_are_independent(self):
        """Row b's output depends only on adapter idx[b] — the SGMV contract
        that makes mixed-tenant batches safe."""
        a, b, s = _stacks(4, 64, 8, 128, seed=11)
        x = jnp.asarray(np.random.default_rng(3).normal(size=(4, 64)), jnp.float32)
        mixed = np.asarray(batched_lora_ref(
            x, a, b, s, jnp.asarray([1, 2, 3, 1], jnp.int32)))
        for row, ad in enumerate([1, 2, 3, 1]):
            solo = np.asarray(batched_lora_ref(
                x[row:row + 1], a, b, s, jnp.asarray([ad], jnp.int32)))
            np.testing.assert_array_equal(mixed[row], solo[0])

    def test_ref_3d_prefill_shape(self):
        a, b, s = _stacks(3, 64, 8, 128, seed=13)
        x = jnp.asarray(np.random.default_rng(4).normal(size=(2, 5, 64)),
                        jnp.float32)
        got = batched_lora_ref(x, a, b, s, jnp.asarray([1, 2], jnp.int32))
        assert got.shape == (2, 5, 128)
        flat = batched_lora_ref(x[0], a, b, s, jnp.asarray([1] * 5, jnp.int32))
        np.testing.assert_allclose(np.asarray(got[0]), np.asarray(flat),
                                   rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# Registry: versioning, freeze round-trip, byte accounting
# ---------------------------------------------------------------------------


class TestRegistry:
    def test_versioning(self, model_params):
        model, _ = model_params
        reg = AdapterRegistry(SPEC)
        rng = np.random.default_rng(0)
        v1 = reg.register("t", synthetic_adapter_stacks(rng, model.cfg, SPEC,
                                                        model.cfg.num_layers))
        v2 = reg.register("t", synthetic_adapter_stacks(rng, model.cfg, SPEC,
                                                        model.cfg.num_layers))
        assert (v1.version, v2.version) == (1, 2)
        assert reg.get("t").version == 2            # latest by default
        assert reg.get("t", version=1) is v1        # rollback addressable
        with pytest.raises(KeyError):
            reg.get("unknown")
        with pytest.raises(KeyError):
            reg.get("t", version=3)

    def test_adapter_bytes_matches_packed_sizes(self, registry, model_params):
        """`adapter_bytes` accounting == actual packed codes + f32 scales."""
        model, _ = model_params
        entry = registry.get("tenant-0")
        actual = 0
        for target, pk in entry.packs.items():
            actual += (pk["a_codes"].nbytes + pk["a_scale"].nbytes
                       + pk["b_codes"].nbytes + pk["b_scale"].nbytes)
        formula = sum(
            model.cfg.num_layers
            * qlora.adapter_bytes(*target_dims(model.cfg, t), SPEC.lora_spec)
            for t in SPEC.targets)
        assert entry.nbytes == formula == actual

    def test_freeze_roundtrip_matches_fake_quant_eval(self):
        """Frozen ternary pack → serve path matches the STE fake-quant path
        at eval: same ternary codes, scales applied in a different
        association order only."""
        rng = np.random.default_rng(5)
        k, r, n = 64, 8, 128
        a = jnp.asarray(rng.normal(size=(k, r)), jnp.float32)
        b = jnp.asarray(rng.normal(size=(r, n)), jnp.float32) * 0.1
        spec = qlora.LoRASpec(rank=r, alpha=16.0, ternary=True)
        x = jnp.asarray(rng.normal(size=(3, k)), jnp.float32)
        # eval-mode two-path reference (quantize → dequantize → matmul)
        want = qlora.adapter_path(x, {"a": a, "b": b}, spec, train=False)
        # serve path: freeze to packed codes, combined scale, gathered matmul
        frozen = qlora.freeze_adapter({"a": a, "b": b})
        a_codes = frozen["a"].packed[None]
        b_codes = frozen["b"].packed[None]
        s = (frozen["a"].scale * frozen["b"].scale * spec.scaling)[None]
        got = batched_lora_ref(x[None], a_codes, b_codes, s,
                               jnp.asarray([0], jnp.int32))[0]
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)

    def test_rejects_bad_shapes(self, model_params):
        model, _ = model_params
        reg = AdapterRegistry(SPEC)
        rng = np.random.default_rng(0)
        stacks = synthetic_adapter_stacks(rng, model.cfg, SPEC,
                                          model.cfg.num_layers)
        bad = {t: dict(ab) for t, ab in stacks.items()}
        bad["q"] = {"a": bad["q"]["a"][..., :4], "b": bad["q"]["b"]}
        with pytest.raises(ValueError):
            reg.register("bad", bad)
        with pytest.raises(ValueError):
            reg.register("partial", {"q": stacks["q"]})
        with pytest.raises(ValueError):
            AdapterRegistry(AdapterSpec(rank=6))    # not packable


# ---------------------------------------------------------------------------
# SRAM-budget cache: LRU churn, pinning, byte budget
# ---------------------------------------------------------------------------


class TestAdapterCache:
    def test_lru_eviction_under_byte_budget(self):
        c = AdapterCache(budget_bytes=250, max_entries=8)
        for name in ("a", "b"):
            c.admit(name, 100)
        c.lookup("a")                       # a is now more recent than b
        _, evicted = c.admit("c", 100)      # must evict LRU = b
        assert evicted == ["b"]
        assert c.is_resident("a") and c.is_resident("c") and not c.is_resident("b")
        assert c.bytes_used <= c.budget_bytes
        assert c.evictions == 1

    def test_pinned_never_evicted(self):
        c = AdapterCache(budget_bytes=250, max_entries=8)
        c.admit("a", 100); c.pin("a")
        c.admit("b", 100); c.pin("b")
        assert not c.can_admit("c", 100)    # everything pinned: no room
        with pytest.raises(MemoryError):
            c.admit("c", 100)
        c.unpin("b")
        assert c.can_admit("c", 100)
        _, evicted = c.admit("c", 100)
        assert evicted == ["b"] and c.is_resident("a")

    def test_slot_exhaustion_evicts(self):
        c = AdapterCache(budget_bytes=10_000, max_entries=2)
        c.admit("a", 10); c.admit("b", 10)
        slot_a = c.slot_of("a")
        c.lookup("b")                       # a becomes LRU
        slot_c, evicted = c.admit("c", 10)
        assert evicted == ["a"] and slot_c == slot_a    # slot recycled
        assert sorted(c.resident_ids()) == ["b", "c"]

    def test_oversized_adapter_never_admissible(self):
        c = AdapterCache(budget_bytes=50, max_entries=4)
        assert not c.can_admit("huge", 51)

    def test_stats_shape(self):
        c = AdapterCache(budget_bytes=100, max_entries=2)
        c.admit("a", 10)
        c.lookup("a"); c.lookup("zz")
        st = c.stats()
        assert st["resident"] == 1 and st["hits"] == 1 and st["misses"] == 1
        assert st["hit_rate"] == 0.5 and st["budget_bytes"] == 100


# ---------------------------------------------------------------------------
# Scheduler adapter-affinity: batching help, never a priority/EDF violation
# ---------------------------------------------------------------------------


def _req(uid, prompt_len=4, deadline_s=None, **spec_kw):
    return Request(uid, list(range(prompt_len)), spec=RequestSpec(**spec_kw),
                   deadline_s=deadline_s, t_submit=time.time())


class TestAffinityScheduling:
    def test_warm_preferred_within_class(self):
        s = Scheduler()
        s.push(_req(1, adapter_id="cold"))
        s.push(_req(2, adapter_id="warm"))
        got = s.pop_next(prefer=lambda r: r.adapter_id == "warm")
        assert got.uid == 2                   # later arrival, same class: ok

    def test_priority_never_violated_by_affinity(self):
        """A higher-priority cold-adapter request is never starved by warm
        lower-priority traffic."""
        s = Scheduler()
        s.push(_req(1, priority=0, adapter_id="cold"))
        s.push(_req(2, priority=1, adapter_id="warm"))
        s.push(_req(3, priority=1, adapter_id="warm"))
        got = s.pop_next(prefer=lambda r: r.adapter_id == "warm")
        assert got.uid == 1

    def test_edf_never_violated_by_affinity(self):
        s = Scheduler()
        now = time.time()
        s.push(_req(1, priority=1, deadline_s=now + 1.0, adapter_id="cold"))
        s.push(_req(2, priority=1, deadline_s=now + 9.0, adapter_id="warm"))
        got = s.pop_next(prefer=lambda r: r.adapter_id == "warm")
        assert got.uid == 1                   # earlier deadline wins

    def test_affinity_respects_admission(self):
        s = Scheduler()
        s.push(_req(1, adapter_id="warm"))
        s.push(_req(2, adapter_id="cold"))
        got = s.pop_next(can_admit=lambda r: r.adapter_id != "warm",
                         prefer=lambda r: r.adapter_id == "warm")
        assert got.uid == 2

    def test_engine_affinity_no_priority_starvation(self, model_params,
                                                    registry):
        """End-to-end: with one free slot, a high-priority cold-adapter
        request is admitted ahead of queued warm-adapter traffic."""
        model, params = model_params
        ad = make_serving(model, registry, budget_adapters=1, max_resident=1)
        eng = ServeEngine(model, params, max_slots=1, max_len=64,
                          adapters=ad)
        warm_up = eng.submit([1, 2, 3],
                             RequestSpec(max_new_tokens=2,
                                         adapter_id="tenant-0"))
        eng.run_until_drained()
        assert warm_up.state == "done" and ad.is_resident("tenant-0")
        hi_cold = eng.submit([4, 5],
                             RequestSpec(max_new_tokens=2, priority=0,
                                         adapter_id="tenant-1"))
        lo_warm = eng.submit([6, 7],
                             RequestSpec(max_new_tokens=2, priority=1,
                                         adapter_id="tenant-0"))
        eng.tick()
        assert hi_cold.state == "running"
        assert lo_warm.state == "queued"
        eng.run_until_drained()
        assert hi_cold.state == "done" and lo_warm.state == "done"


# ---------------------------------------------------------------------------
# Engine end-to-end: mixed-tenant batches, budget churn, pin safety
# ---------------------------------------------------------------------------


class TestMultiTenantServing:
    def _solo(self, model, params, registry, kv, prompt, adapter_id):
        ad = make_serving(model, registry)
        eng = ServeEngine(model, params, max_slots=1, max_len=64,
                          kv=_kv(kv), adapters=ad)
        r = eng.submit(prompt, RequestSpec(max_new_tokens=6,
                                           adapter_id=adapter_id))
        eng.run_until_drained()
        assert r.state == "done"
        return r.output

    @pytest.mark.parametrize("kv", ["dense", "paged"])
    def test_mixed_batch_token_identical_to_solo(self, model_params, registry,
                                                 kv):
        """Acceptance: ≥3 distinct adapter_ids + None slots, per-slot greedy
        outputs == unbatched per-request reference, dense and paged."""
        model, params = model_params
        rng = np.random.default_rng(3)
        prompts = [list(rng.integers(0, 100, size=int(rng.integers(4, 12))))
                   for _ in range(5)]
        tenants = [None, "tenant-0", "tenant-1", "tenant-2", None]
        ad = make_serving(model, registry)
        eng = ServeEngine(model, params, max_slots=4, max_len=64, kv=_kv(kv),
                          adapters=ad)
        reqs = [eng.submit(p, RequestSpec(max_new_tokens=6, adapter_id=t))
                for p, t in zip(prompts, tenants)]
        eng.run_until_drained()
        assert all(r.state == "done" for r in reqs)
        for r, p, t in zip(reqs, prompts, tenants):
            assert r.output == self._solo(model, params, registry, kv, p, t), \
                f"slot with adapter {t} diverged from solo reference"

    @pytest.mark.parametrize("kv", ["dense", "paged"])
    def test_none_slots_identical_to_plain_engine(self, model_params, registry,
                                                  kv):
        """adapter_id=None slots must stay token-identical to an engine with
        no adapter subsystem at all."""
        model, params = model_params
        prompt = list(range(20, 29))
        plain = ServeEngine(model, params, max_slots=2, max_len=64,
                            kv=_kv(kv))
        r0 = plain.submit(prompt, RequestSpec(max_new_tokens=6))
        plain.run_until_drained()

        ad = make_serving(model, registry)
        eng = ServeEngine(model, params, max_slots=2, max_len=64, kv=_kv(kv),
                          adapters=ad)
        r1 = eng.submit(prompt, RequestSpec(max_new_tokens=6))    # None slot
        r2 = eng.submit(list(range(5)),
                        RequestSpec(max_new_tokens=6,
                                    adapter_id="tenant-1"))       # neighbour
        eng.run_until_drained()
        assert r1.output == r0.output
        assert r2.state == "done"

    def test_adapter_changes_outputs(self, model_params, registry):
        model, params = model_params
        prompt = list(range(30, 40))
        none_out = self._solo(model, params, registry, "dense", prompt, None)
        tenant_out = self._solo(model, params, registry, "dense", prompt,
                                "tenant-0")
        assert none_out != tenant_out

    def test_budget_churn_and_pinning(self, model_params, registry):
        """Cache respects its byte budget under tenant churn; an adapter with
        an in-flight request is never evicted."""
        model, params = model_params
        ad = make_serving(model, registry, budget_adapters=2, max_resident=2)
        eng = ServeEngine(model, params, max_slots=2, max_len=64, adapters=ad)
        reqs = [eng.submit(list(range(4)),
                           RequestSpec(max_new_tokens=3,
                                       adapter_id=f"tenant-{i}"))
                for i in range(4)]
        budget = ad.cache.budget_bytes
        while any(r.state in ("queued", "running") for r in reqs):
            eng.tick()
            assert ad.cache.bytes_used <= budget
            for slot, r in enumerate(eng.slot_req):
                if r is not None and r.adapter_id is not None:
                    # in-flight ⇒ resident and pinned, idx mapped
                    assert ad.is_resident(r.adapter_id)
                    assert ad.pinned(r.adapter_id)
                    assert eng.slot_adapter[slot] > 0
        assert all(r.state == "done" for r in reqs)
        assert ad.cache.evictions >= 1              # 4 tenants through 2 slots
        assert all(not ad.cache.pinned(i) for i in ad.cache.resident_ids())

    def test_pinned_budget_exhaustion_queues_not_crashes(self, model_params,
                                                         registry):
        """When every budget byte is pinned by running requests, a third
        tenant waits in the queue (admission control), then completes."""
        model, params = model_params
        ad = make_serving(model, registry, budget_adapters=2, max_resident=2)
        eng = ServeEngine(model, params, max_slots=3, max_len=64, adapters=ad)
        a = eng.submit(list(range(6)),
                       RequestSpec(max_new_tokens=8, adapter_id="tenant-0"))
        b = eng.submit(list(range(6)),
                       RequestSpec(max_new_tokens=8, adapter_id="tenant-1"))
        c = eng.submit(list(range(6)),
                       RequestSpec(max_new_tokens=8, adapter_id="tenant-2"))
        eng.tick()
        assert a.state == "running" and b.state == "running"
        assert c.state == "queued"                  # slot free, budget pinned
        eng.run_until_drained()
        assert c.state == "done"

    def test_unknown_or_oversized_adapter_rejected(self, model_params,
                                                   registry):
        model, params = model_params
        ad = make_serving(model, registry)
        eng = ServeEngine(model, params, max_slots=1, max_len=64, adapters=ad)
        assert eng.submit(
            [1, 2], RequestSpec(adapter_id="nope")).state == "rejected"
        no_ad = ServeEngine(model, params, max_slots=1, max_len=64)
        assert no_ad.submit(
            [1, 2], RequestSpec(adapter_id="tenant-0")).state == "rejected"

    def test_preemption_unpins_and_resumes_with_adapter(self, model_params,
                                                        registry):
        """A preempted tenant request unpins its adapter and, once re-
        admitted, reproduces the unpreempted output."""
        model, params = model_params
        solo = self._solo(model, params, registry, "paged",
                          list(range(30, 49)), "tenant-1")
        ad = make_serving(model, registry)
        eng = ServeEngine(model, params, max_slots=2, max_len=64,
                          kv=PagedKV(page=8, n_pages=6), adapters=ad)
        eng.submit(list(range(1, 20)),
                   RequestSpec(max_new_tokens=10, priority=0))
        lo = eng.submit(list(range(30, 49)),
                        RequestSpec(max_new_tokens=10, priority=2,
                                    adapter_id="tenant-1"))
        eng.run_until_drained()
        assert lo.n_preempts >= 1
        assert lo.output[:6] == solo                # same greedy trajectory
        assert not ad.pinned("tenant-1")


# ---------------------------------------------------------------------------
# Batched prefill after a prefix-cache hit (position-offset fix)
# ---------------------------------------------------------------------------


class TestPrefixHitBatchedPrefill:
    def test_no_token_fallback_and_identical_outputs(self, model_params):
        """Regression (ROADMAP item): batched prefill used to fall back to
        token mode after a prefix hit. Now it resumes mid-sequence (position
        offset + attention over cached prefix pages): one prefill tick, same
        tokens as the token-mode path."""
        model, params = model_params
        shared = list(range(10, 26))               # 2 full pages of 8
        tail = [3, 4, 5, 6, 7]
        outs = {}
        for mode in ("token", "batched"):
            eng = ServeEngine(model, params, max_slots=2, max_len=64,
                              kv=PagedKV(page=8), prefix_cache=True,
                              prefill=mode)
            warm = eng.submit(shared + tail, RequestSpec(max_new_tokens=5))
            eng.run_until_drained()                # commits the shared pages
            hit = eng.submit(shared + tail, RequestSpec(max_new_tokens=5))
            eng.run_until_drained()
            assert hit.prefix_hit_tokens == 16
            outs[mode] = (warm.output, hit.output)
            if mode == "batched":
                # the whole remainder ran through one batched prefill call
                assert hit.prefill_ticks == 1
        assert outs["token"] == outs["batched"]

    def test_offset_prefill_positions_match_dense_reference(self, model_params):
        """Model-level check: prefill(pos_offset, prefix_kv) fills the cache
        identically (within fp8 rounding) to one full prefill from zero."""
        model, params = model_params
        toks = np.asarray([list(range(40, 72))], np.int32)
        split = 16
        _, full = model.prefill(params, {"tokens": jnp.asarray(toks)}, 64)
        # first half from zero, second half resumed with the cached prefix
        _, head = model.prefill(params,
                                {"tokens": jnp.asarray(toks[:, :split])}, 64)
        prefix = {"k": head["k"][:, :, :, :split], "v": head["v"][:, :, :, :split]}
        logits2, resumed = model.prefill(
            params, {"tokens": jnp.asarray(toks[:, split:])}, 64,
            pos_offset=split, prefix_kv=prefix)
        got = np.asarray(resumed["k"].astype(jnp.float32))[:, :, :, split:32]
        want = np.asarray(full["k"].astype(jnp.float32))[:, :, :, split:32]
        np.testing.assert_allclose(got, want, rtol=0.2, atol=0.1)  # fp8 cache
        logits1, _ = model.prefill(params, {"tokens": jnp.asarray(toks)}, 64)
        assert int(jnp.argmax(logits1)) == int(jnp.argmax(logits2))


# ---------------------------------------------------------------------------
# Gateway surface: metrics JSON
# ---------------------------------------------------------------------------


@pytest.mark.slow
class TestMultiTenantBenchSmoke:
    def test_bench_multitenant_quick(self, tmp_path):
        """Bench-shaped: drives benchmarks/bench_multitenant end-to-end and
        checks the emitted artifact."""
        import json

        from benchmarks.bench_multitenant import run
        from benchmarks.common import ARTIFACTS
        run(quick=True)
        out = json.loads((ARTIFACTS / "BENCH_multitenant.json").read_text())
        assert set(out) == {"baseline", "single", "multi", "observability",
                            "tiered"}
        assert out["multi"]["completed"] == 8
        tiered = out["tiered"]
        assert tiered["prefix_readmits"] > 0
        assert tiered["kv_spilled_pages"] > 0
        assert tiered["readmit_speedup"] > 0.0
        obs = out["observability"]
        assert obs["phase_breakdown_ms"], obs
        assert obs["energy_per_token_j"] >= 0.0
        assert 0.0 <= obs["gated_bank_fraction"] <= 1.0
        assert 0.0 <= out["multi"]["adapter_hit_rate"] <= 1.0
        assert out["multi"]["adapter_bytes_used"] \
            <= out["multi"]["adapter_budget_bytes"]


class TestGatewayAdapterMetrics:
    def test_metrics_json_reports_adapter_cache(self, model_params, registry):
        model, params = model_params
        ad = make_serving(model, registry, budget_adapters=2, max_resident=2)
        gw = Gateway(ServeEngine(model, params, max_slots=2, max_len=64,
                                 adapters=ad))
        for i in range(3):
            gw.submit(list(range(4)),
                      RequestSpec(max_new_tokens=3, adapter_id=f"tenant-{i}"))
        gw.submit(list(range(4)), RequestSpec(max_new_tokens=3))
        gw.run_until_drained()
        m = gw.metrics_dict()
        g = m["gauges"]
        assert g["adapter_cache_resident"] <= 2
        assert g["adapter_cache_bytes_used"] <= g["adapter_cache_budget_bytes"]
        assert g["adapter_cache_evictions"] >= 1
        assert 0.0 <= g["adapter_cache_hit_rate"] <= 1.0
        assert m["counters"]["adapter_requests_total"] == 3
        assert m["counters"]["adapter_requests__tenant-0"] == 1
