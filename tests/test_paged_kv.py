"""Paged KV pool: allocator invariants + round-trip + attention equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import attention as CA
from repro.serving.paged_kv import PagePool, PagedConfig

jax.config.update("jax_enable_x64", False)


def make_pool(**kw):
    cfg = PagedConfig(n_layers=2, n_kv_heads=2, head_dim=16, page=8,
                      n_pages=16, **kw)
    return PagePool(cfg, max_slots=4), cfg


class TestAllocator:
    def test_reserve_release_roundtrip(self):
        pool, cfg = make_pool()
        assert pool.pages_free == 16
        pool.reserve(0, 20)          # 3 pages of 8
        assert len(pool.tables[0]) == 3 and pool.pages_free == 13
        pool.reserve(0, 24)          # same 3 pages
        assert len(pool.tables[0]) == 3
        pool.release(0)
        assert pool.pages_free == 16

    def test_exhaustion_raises(self):
        pool, cfg = make_pool()
        pool.reserve(0, 16 * 8)
        with pytest.raises(MemoryError):
            pool.reserve(1, 8)

    def test_no_page_shared_between_slots(self):
        pool, _ = make_pool()
        pool.reserve(0, 30)
        pool.reserve(1, 30)
        assert not (set(pool.tables[0]) & set(pool.tables[1]))

    def test_fragmentation_savings(self):
        pool, _ = make_pool()
        s = pool.fragmentation_savings(max_len=64, active_lengths=[8, 16, 8])
        assert 0.7 < s < 0.9  # 4 of 24 reserved pages actually used → 83%


class TestRoundTrip:
    def test_token_write_gather(self):
        pool, cfg = make_pool()
        rng = np.random.default_rng(0)
        toks = [jnp.asarray(rng.normal(size=(2, 2, 16)), jnp.float32)
                for _ in range(10)]
        for pos, t in enumerate(toks):
            pool.write_token(0, pos, t, t * 2)
        k, v = pool.gather_slot(0)
        assert k.shape == (2, 1, 2, 16, 16)  # 2 pages of 8
        for pos, t in enumerate(toks):
            np.testing.assert_allclose(
                np.asarray(k[:, 0, :, pos], np.float32),
                np.asarray(t.astype(cfg.dtype), np.float32))

    def test_span_write_crosses_pages(self):
        pool, cfg = make_pool()
        rng = np.random.default_rng(1)
        span = jnp.asarray(rng.normal(size=(2, 2, 20, 16)), jnp.float32)
        pool.write_span(1, 0, span, span)
        k, _ = pool.gather_slot(1)
        np.testing.assert_allclose(
            np.asarray(k[:, 0, :, :20], np.float32),
            np.asarray(span.astype(cfg.dtype), np.float32))

    def test_attention_over_paged_equals_contiguous(self):
        """Decode attention on a gathered paged cache == on the flat cache."""
        pool, cfg = make_pool()
        rng = np.random.default_rng(2)
        s_used = 19
        ks = jnp.asarray(rng.normal(size=(2, 2, s_used, 16)), jnp.float32)
        vs = jnp.asarray(rng.normal(size=(2, 2, s_used, 16)), jnp.float32)
        pool.write_span(2, 0, ks, vs)
        kp, vp = pool.gather_slot(2)

        q = jnp.asarray(rng.normal(size=(1, 2, 16)), jnp.float32)
        # layer 0, mask padded tail beyond s_used
        s_total = kp.shape[3]
        mask = (jnp.arange(s_total) < s_used)[None]
        out_paged = CA.dense_decode_attention(
            q, kp[0].astype(jnp.float32), vp[0].astype(jnp.float32), mask=mask)
        out_flat = CA.dense_decode_attention(
            q, ks[0:1].astype(cfg.dtype).astype(jnp.float32)[None][0],
            vs[0:1].astype(cfg.dtype).astype(jnp.float32)[None][0])
        np.testing.assert_allclose(np.asarray(out_paged), np.asarray(out_flat),
                                   rtol=1e-5, atol=1e-5)
