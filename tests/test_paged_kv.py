"""Paged KV pool: allocator invariants + round-trip + attention equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import attention as CA
from repro.serving.paged_kv import PagePool, PagedConfig

jax.config.update("jax_enable_x64", False)


def make_pool(**kw):
    cfg = PagedConfig(n_layers=2, n_kv_heads=2, head_dim=16, page=8,
                      n_pages=16, **kw)
    return PagePool(cfg, max_slots=4), cfg


class TestAllocator:
    def test_reserve_release_roundtrip(self):
        pool, cfg = make_pool()
        assert pool.pages_free == 16
        pool.reserve(0, 20)          # 3 pages of 8
        assert len(pool.tables[0]) == 3 and pool.pages_free == 13
        pool.reserve(0, 24)          # same 3 pages
        assert len(pool.tables[0]) == 3
        pool.release(0)
        assert pool.pages_free == 16

    def test_exhaustion_raises(self):
        pool, cfg = make_pool()
        pool.reserve(0, 16 * 8)
        with pytest.raises(MemoryError):
            pool.reserve(1, 8)

    def test_no_page_shared_between_slots(self):
        pool, _ = make_pool()
        pool.reserve(0, 30)
        pool.reserve(1, 30)
        assert not (set(pool.tables[0]) & set(pool.tables[1]))

    def test_fragmentation_savings(self):
        pool, _ = make_pool()
        s = pool.fragmentation_savings(max_len=64, active_lengths=[8, 16, 8])
        assert 0.7 < s < 0.9  # 4 of 24 reserved pages actually used → 83%


class TestRoundTrip:
    def test_token_write_gather(self):
        pool, cfg = make_pool()
        rng = np.random.default_rng(0)
        toks = [jnp.asarray(rng.normal(size=(2, 2, 16)), jnp.float32)
                for _ in range(10)]
        for pos, t in enumerate(toks):
            pool.write_token(0, pos, t, t * 2)
        k, v = pool.gather_slot(0)
        assert k.shape == (2, 1, 2, 16, 16)  # 2 pages of 8
        for pos, t in enumerate(toks):
            np.testing.assert_allclose(
                np.asarray(k[:, 0, :, pos], np.float32),
                np.asarray(t.astype(cfg.dtype), np.float32))

    def test_span_write_crosses_pages(self):
        pool, cfg = make_pool()
        rng = np.random.default_rng(1)
        span = jnp.asarray(rng.normal(size=(2, 2, 20, 16)), jnp.float32)
        pool.write_span(1, 0, span, span)
        k, _ = pool.gather_slot(1)
        np.testing.assert_allclose(
            np.asarray(k[:, 0, :, :20], np.float32),
            np.asarray(span.astype(cfg.dtype), np.float32))

    def test_span_write_unaligned_start_crosses_boundary(self):
        """A span starting mid-page and ending mid-page two pages later must
        land token-exact (the per-page loop splits at both boundaries)."""
        pool, cfg = make_pool()
        rng = np.random.default_rng(3)
        head = jnp.asarray(rng.normal(size=(2, 2, 5, 16)), jnp.float32)
        span = jnp.asarray(rng.normal(size=(2, 2, 14, 16)), jnp.float32)
        pool.write_span(0, 0, head, head)          # positions 0..4
        pool.write_span(0, 5, span, span * 3)      # positions 5..18: 3 pages
        assert len(pool.tables[0]) == 3 and int(pool.lengths[0]) == 19
        k, v = pool.gather_slot(0)
        np.testing.assert_allclose(
            np.asarray(k[:, 0, :, 5:19], np.float32),
            np.asarray(span.astype(cfg.dtype), np.float32))
        np.testing.assert_allclose(
            np.asarray(v[:, 0, :, 5:19], np.float32),
            np.asarray((span * 3).astype(cfg.dtype), np.float32))
        # the head must survive the second write untouched
        np.testing.assert_allclose(
            np.asarray(k[:, 0, :, :5], np.float32),
            np.asarray(head.astype(cfg.dtype), np.float32))


class TestBatchedOps:
    def test_batch_tables_pads_with_scratch(self):
        pool, cfg = make_pool()
        pool.reserve(0, 20)          # 3 pages
        pool.reserve(2, 5)           # 1 page
        t = pool.batch_tables([0, 2], n_pages=4, batch=4)
        assert t.shape == (4, 4)
        assert list(t[0, :3]) == pool.tables[0] and t[0, 3] == pool.scratch_page
        assert t[2, 0] == pool.tables[2][0]
        assert (t[1] == pool.scratch_page).all()  # inactive row

    def test_write_tokens_gather_batch_roundtrip(self):
        pool, cfg = make_pool()
        rng = np.random.default_rng(4)
        pool.reserve(0, 10)
        pool.reserve(1, 3)
        for pos0, pos1 in [(0, 0), (1, 1), (9, 2)]:
            toks = jnp.asarray(rng.normal(size=(2, 4, 2, 16)), jnp.float32)
            page_ids = np.asarray(
                [pool.tables[0][pos0 // cfg.page], pool.tables[1][pos1 // cfg.page],
                 pool.scratch_page, pool.scratch_page], np.int32)
            offs = np.asarray([pos0 % cfg.page, pos1 % cfg.page, 0, 0], np.int32)
            pool.write_tokens(page_ids, offs, toks, toks * 2)
        tables = pool.batch_tables([0, 1], n_pages=2, batch=4)
        k, v = pool.gather_batch(tables)
        assert k.shape == (2, 4, 2, 2 * cfg.page, 16)
        # last written token of slot 0 (pos 9) and slot 1 (pos 2)
        np.testing.assert_allclose(np.asarray(k[:, 0, :, 9], np.float32),
                                   np.asarray(toks[:, 0].astype(cfg.dtype),
                                              np.float32))
        np.testing.assert_allclose(np.asarray(v[:, 1, :, 2], np.float32),
                                   np.asarray((toks[:, 1] * 2).astype(cfg.dtype),
                                              np.float32))

    def test_scratch_page_never_allocated(self):
        pool, cfg = make_pool()
        pool.reserve(0, cfg.n_pages * cfg.page)   # drain the whole pool
        assert pool.scratch_page not in pool.tables[0]

    def test_release_keep_skips_cache_owned_pages(self):
        pool, cfg = make_pool()
        pool.reserve(0, 24)                       # 3 pages
        cached = pool.tables[0][:2]
        pool.release(0, keep=2)
        assert pool.pages_free == cfg.n_pages - 2
        assert not (set(cached) & set(pool.free))
        pool.free_pages(cached)                   # cache eviction path
        assert pool.pages_free == cfg.n_pages


class TestPagedFlashDecode:
    """Block tables threaded into the Pallas kernel's page-shaped context
    loop (scalar prefetch) == contiguous-gather oracle."""

    def _case(self, seed, dtype):
        from repro.kernels.flash_decode.ops import paged_decode_attention
        from repro.kernels.flash_decode.paged import paged_flash_decode_ref
        rng = np.random.default_rng(seed)
        b, hq, hkv, d, page, n_pages, n_p = 3, 8, 2, 32, 16, 10, 4
        q = jnp.asarray(rng.normal(size=(b, hq, d)), jnp.float32)
        kp = jnp.asarray(rng.normal(size=(n_pages + 1, hkv, page, d)),
                         jnp.float32).astype(dtype)
        vp = jnp.asarray(rng.normal(size=(n_pages + 1, hkv, page, d)),
                         jnp.float32).astype(dtype)
        tables = jnp.asarray(rng.integers(0, n_pages, size=(b, n_p)), jnp.int32)
        lengths = jnp.asarray([page * n_p, 17, 1], jnp.int32)
        out = paged_decode_attention(q, kp, vp, tables, lengths, 1.0,
                                     use_kernel=True, interpret=True)
        ref = paged_flash_decode_ref(
            q.reshape(b, hkv, hq // hkv, d), kp.astype(jnp.float32),
            vp.astype(jnp.float32), tables, lengths, 1.0
        ).reshape(b, hq, d)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)

    def test_kernel_matches_gather_oracle_f32(self):
        self._case(0, jnp.float32)

    def test_kernel_matches_gather_oracle_fp8(self):
        self._case(1, jnp.float8_e4m3fn)

    def test_kernel_matches_engine_view_path(self):
        """The kernel over a live PagePool == attention over gather_batch's
        contiguous view (the engine's pure-JAX decode path)."""
        from repro.core import attention as CA
        from repro.kernels.flash_decode.ops import paged_decode_attention
        pool, cfg = make_pool(dtype=jnp.float32)
        rng = np.random.default_rng(5)
        n_tok = 19
        ks = jnp.asarray(rng.normal(size=(2, 2, n_tok, 16)), jnp.float32)
        vs = jnp.asarray(rng.normal(size=(2, 2, n_tok, 16)), jnp.float32)
        pool.write_span(0, 0, ks, vs)
        tables = pool.batch_tables([0], n_pages=3, batch=1)
        kb, vb = pool.gather_batch(tables)          # (L, 1, H, 24, D)
        q = jnp.asarray(rng.normal(size=(1, 2, 16)), jnp.float32)
        out_kernel = paged_decode_attention(
            q, pool.k[0], pool.v[0], jnp.asarray(tables),
            jnp.asarray([n_tok], jnp.int32), 1.0, use_kernel=True,
            interpret=True)
        mask = (jnp.arange(kb.shape[3]) < n_tok)[None]
        out_view = CA.dense_decode_attention(q, kb[0], vb[0], mask=mask)
        np.testing.assert_allclose(np.asarray(out_kernel), np.asarray(out_view),
                                   rtol=1e-5, atol=1e-5)

    def test_attention_over_paged_equals_contiguous(self):
        """Decode attention on a gathered paged cache == on the flat cache."""
        pool, cfg = make_pool()
        rng = np.random.default_rng(2)
        s_used = 19
        ks = jnp.asarray(rng.normal(size=(2, 2, s_used, 16)), jnp.float32)
        vs = jnp.asarray(rng.normal(size=(2, 2, s_used, 16)), jnp.float32)
        pool.write_span(2, 0, ks, vs)
        kp, vp = pool.gather_slot(2)

        q = jnp.asarray(rng.normal(size=(1, 2, 16)), jnp.float32)
        # layer 0, mask padded tail beyond s_used
        s_total = kp.shape[3]
        mask = (jnp.arange(s_total) < s_used)[None]
        out_paged = CA.dense_decode_attention(
            q, kp[0].astype(jnp.float32), vp[0].astype(jnp.float32), mask=mask)
        out_flat = CA.dense_decode_attention(
            q, ks[0:1].astype(cfg.dtype).astype(jnp.float32)[None][0],
            vs[0:1].astype(cfg.dtype).astype(jnp.float32)[None][0])
        np.testing.assert_allclose(np.asarray(out_paged), np.asarray(out_flat),
                                   rtol=1e-5, atol=1e-5)
