"""Observability layer tests: tracer spans/lifecycle/ring, Chrome-trace
validity, Prometheus text exposition, histogram percentile/bucket fixes,
CompileWatch counting, the energy monitor, and the engine integration
(phase breakdown + tick spans end-to-end on a tiny model)."""
import json

import jax
import numpy as np
import pytest

from repro.serving.gateway.metrics import Histogram, Metrics
from repro.serving.obs import (CompileWatch, EnergyMonitor, NULL_TRACER,
                               Tracer, load_trace, validate_trace)
from repro.serving.obs.prom import parse_text, render_text
from repro.serving.obs.tracer import _NULL_SPAN

jax.config.update("jax_enable_x64", False)


class FakeClock:
    """Deterministic monotonic clock for tracer tests."""

    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, s):
        self.t += s


class TestTracer:
    def test_span_nesting_and_ordering(self, tmp_path):
        clk = FakeClock()
        tr = Tracer(clock=clk)
        pid = tr.register("engine[test]")
        with tr.span("tick", pid=pid):
            clk.advance(0.001)
            with tr.span("decode", pid=pid):
                clk.advance(0.002)
            with tr.span("sample", pid=pid):
                clk.advance(0.001)
        events = [e for e in tr.to_events() if e["ph"] == "X"]
        names = [e["name"] for e in events]
        # ts-sorted: the parent tick (earliest start) precedes its children
        assert names == ["tick", "decode", "sample"]
        tick, decode, sample = events
        # children nest inside the parent interval
        assert tick["ts"] <= decode["ts"]
        assert decode["ts"] + decode["dur"] <= tick["ts"] + tick["dur"] + 1e-6
        assert sample["ts"] >= decode["ts"] + decode["dur"] - 1e-6
        assert tick["dur"] == pytest.approx(4000.0)   # 4 ms in µs

    def test_dump_jsonl_valid_and_monotonic(self, tmp_path):
        clk = FakeClock()
        tr = Tracer(clock=clk)
        pid = tr.register("e")
        for _ in range(5):
            with tr.span("tick", pid=pid):
                clk.advance(0.001)
                with tr.span("decode", pid=pid):
                    clk.advance(0.001)
            clk.advance(0.0005)
        path = tmp_path / "trace.jsonl"
        tr.dump(path)
        # every line is a standalone JSON object
        lines = path.read_text().strip().splitlines()
        assert all(isinstance(json.loads(ln), dict) for ln in lines)
        stats = validate_trace(path)
        assert stats["tick_spans"] == 5
        # the non-jsonl flavor is a traceEvents document, same content
        jpath = tmp_path / "trace.json"
        tr.dump(jpath)
        doc = json.loads(jpath.read_text())
        assert len(doc["traceEvents"]) == len(load_trace(path))
        assert validate_trace(jpath)["tick_spans"] == 5

    def test_lifecycle_states_and_preempt(self):
        clk = FakeClock()
        tr = Tracer(clock=clk)
        pid = tr.register("e")
        tr.lifecycle(7, "queued", pid=pid)
        clk.advance(0.01)
        tr.lifecycle(7, "decoding", pid=pid)
        clk.advance(0.02)
        tr.lifecycle(7, "preempt", pid=pid)      # closes decoding → queued
        clk.advance(0.01)
        tr.lifecycle(7, "decoding", pid=pid)
        clk.advance(0.01)
        tr.lifecycle(7, "done", pid=pid)
        evts = [e for e in tr.to_events() if e.get("cat") == "request"]
        spans = [e["name"] for e in evts if e["ph"] == "X"]
        instants = [e["name"] for e in evts if e["ph"] == "i"]
        assert spans == ["queued", "decoding", "queued", "decoding"]
        assert instants == ["preempt", "done"]
        # all on the request's own track (tid = uid)
        assert {e["tid"] for e in evts} == {7}
        # nothing left open → to_events adds no synthetic tail
        assert len([e for e in tr.to_events() if e["ph"] == "X"]) == 4

    def test_open_lifecycle_autoclosed_in_snapshot(self):
        clk = FakeClock()
        tr = Tracer(clock=clk)
        tr.lifecycle(1, "queued")
        clk.advance(0.05)
        evts = tr.to_events()
        (span,) = [e for e in evts if e["ph"] == "X"]
        assert span["name"] == "queued"
        assert span["dur"] == pytest.approx(50_000.0)

    def test_ring_buffer_eviction(self):
        clk = FakeClock()
        tr = Tracer(ring=10, clock=clk)
        pid = tr.register("e")
        for i in range(100):
            tr.instant(f"evt{i}", pid=pid)
            clk.advance(0.001)
        assert len(tr.events) == 10
        names = [e["name"] for e in tr.to_events() if e["ph"] == "i"]
        assert names == [f"evt{i}" for i in range(90, 100)]
        # metadata (track names) survives eviction
        assert any(e["ph"] == "M" for e in tr.to_events())

    def test_disabled_tracer_allocates_nothing(self):
        tr = Tracer(enabled=False)
        s1 = tr.span("tick")
        s2 = tr.span("decode", pid=3, something="else")
        # one shared singleton, no span objects, no events
        assert s1 is s2 is _NULL_SPAN
        with s1:
            pass
        tr.instant("x")
        tr.counter("c", 1.0)
        tr.lifecycle(1, "queued")
        assert len(tr.events) == 0 and tr.to_events() == []

    def test_null_tracer_is_shared_and_inert(self):
        assert not NULL_TRACER.enabled
        assert NULL_TRACER.span("x") is _NULL_SPAN
        assert len(NULL_TRACER.events) == 0


class TestCompileWatch:
    def test_counts_one_compile_per_shape(self):
        import jax.numpy as jnp
        compiled = []
        tr = Tracer(clock=FakeClock())
        fn = jax.jit(lambda x: x * 2)
        w = CompileWatch(fn, "double", tr,
                         on_compile=lambda n, s: compiled.append((n, s)))
        a = jnp.ones((4,))
        b = jnp.ones((8,))
        np.testing.assert_allclose(np.asarray(w(a)), 2.0)
        w(a)                       # cache hit: no new compile
        w(b)                       # new shape bucket: compiles
        w(b)
        assert w.compiles == 2
        assert [n for n, _ in compiled] == ["double", "double"]
        instants = [e for e in tr.to_events() if e["name"] == "jit_compile"]
        assert len(instants) == 2
        assert instants[0]["args"]["fn"] == "double"
        assert "4" in instants[0]["args"]["shapes"]


class TestHistogram:
    def test_percentile_linear_interpolation(self):
        h = Histogram()
        h.observe(1.0)
        h.observe(2.0)
        assert h.percentile(50) == pytest.approx(1.5)
        assert h.percentile(0) == pytest.approx(1.0)
        assert h.percentile(100) == pytest.approx(2.0)
        h2 = Histogram()
        for v in (10.0, 20.0, 30.0, 40.0):
            h2.observe(v)
        assert h2.percentile(50) == pytest.approx(25.0)
        assert h2.percentile(25) == pytest.approx(17.5)

    def test_to_dict_exports_cumulative_buckets(self):
        h = Histogram(buckets=(1.0, 5.0, 10.0))
        for v in (0.5, 0.7, 3.0, 7.0, 100.0):
            h.observe(v)
        d = h.to_dict()
        assert d["buckets"] == {"1": 2, "5": 3, "10": 4, "+Inf": 5}
        # cumulativity: counts never decrease along the edges
        vals = list(d["buckets"].values())
        assert vals == sorted(vals)
        assert vals[-1] == d["count"]

    def test_cumulative_buckets_inf_tail(self):
        h = Histogram(buckets=(1.0,))
        h.observe(0.5)
        h.observe(99.0)
        cb = h.cumulative_buckets()
        assert cb == [(1.0, 1), (float("inf"), 2)]


class TestPromText:
    def _registry(self):
        m = Metrics()
        m.inc("tokens_out", 42)
        m.inc("adapter_requests__tenant-0", 3)
        m.set_gauge("queue_depth", 5)
        for v in (0.5, 3.0, 7.0, 100.0):
            m.observe("ttft_ms", v, buckets=(1.0, 5.0, 10.0))
        return m

    def test_render_parses_and_counters_match(self):
        m = self._registry()
        text = render_text(m)
        parsed = parse_text(text)
        assert parsed["tokens_out"]["type"] == "counter"
        assert parsed["tokens_out"]["samples"]["tokens_out"] == 42.0
        # label-split counter renders as base{id="..."}
        assert 'adapter_requests{id="tenant-0"} 3' in text
        assert parsed["queue_depth"]["samples"]["queue_depth"] == 5.0

    def test_histogram_buckets_cumulative_with_inf(self):
        text = render_text(self._registry())
        parsed = parse_text(text)
        samples = parsed["ttft_ms"]["samples"]
        edges = [k for k in samples if "_bucket" in k]
        counts = [samples[k] for k in edges]
        assert counts == sorted(counts), "buckets must be cumulative"
        assert samples['ttft_ms_bucket{le="+Inf"}'] == 4.0
        assert samples["ttft_ms_count"] == 4.0
        assert samples["ttft_ms_sum"] == pytest.approx(110.5)
        assert parsed["ttft_ms"]["type"] == "histogram"

    def test_type_headers_and_atomic_write(self, tmp_path):
        from repro.serving.obs.prom import write_prom
        text = render_text(self._registry())
        assert "# TYPE tokens_out counter" in text
        assert "# TYPE queue_depth gauge" in text
        assert "# TYPE ttft_ms histogram" in text
        out = tmp_path / "m.prom"
        write_prom(out, text)
        assert out.read_text() == text
        assert not (tmp_path / "m.prom.tmp").exists()

    def test_metrics_to_prom_text_roundtrip(self):
        m = self._registry()
        assert parse_text(m.to_prom_text()) == parse_text(render_text(m))


class TestEnergyMonitor:
    def test_idle_vs_busy(self):
        idle = EnergyMonitor(n_layers=24)
        busy = EnergyMonitor(n_layers=24)
        for _ in range(50):
            idle.observe_tick(wall_s=0.01, busy_s=0.0, tokens=0,
                              sram_utilization=0.0)
            busy.observe_tick(wall_s=0.01, busy_s=0.01, tokens=4,
                              sram_utilization=1.0)
        gi, gb = idle.gauges(), busy.gauges()
        assert gb["chip_power_w"] > gi["chip_power_w"]
        # idle: every ROM bank gated; busy: only active(+prewake) powered
        assert gi["gated_bank_fraction"] == pytest.approx(1.0)
        assert 0.0 < gb["gated_bank_fraction"] < 1.0
        assert gb["energy_per_token_j"] > 0.0
        assert gi["energy_total_j"] > 0.0    # static floor still burns

    def test_energy_integrates_monotonically(self):
        em = EnergyMonitor(n_layers=4)
        last = 0.0
        for _ in range(10):
            em.observe_tick(wall_s=0.005, busy_s=0.003, tokens=1)
            assert em.energy_j > last
            last = em.energy_j

    def test_gating_disabled_draws_more(self):
        on = EnergyMonitor(n_layers=24, gating_enabled=True)
        off = EnergyMonitor(n_layers=24, gating_enabled=False)
        on.observe_tick(wall_s=0.01, busy_s=0.01, tokens=1)
        off.observe_tick(wall_s=0.01, busy_s=0.01, tokens=1)
        assert off.gauges()["chip_power_w"] > on.gauges()["chip_power_w"]
        assert off.gauges()["gated_bank_fraction"] == pytest.approx(0.0)


@pytest.fixture(scope="module")
def model_params():
    from repro.configs.base import get_config
    from repro.launch.train import reduce_config
    from repro.models.transformer import Model
    cfg = reduce_config(get_config("bitnet-2b"), "tiny")
    model = Model(cfg, mode="serve")
    return model, model.init(jax.random.PRNGKey(0))


class TestEngineIntegration:
    def test_traced_engine_end_to_end(self, model_params, tmp_path):
        from repro.serving import PagedKV, RequestSpec, ServeEngine
        from repro.serving.gateway import Gateway
        model, params = model_params
        tr = Tracer()
        eng = ServeEngine(model, params, max_slots=2, max_len=64,
                          kv=PagedKV(page=8, n_pages=24), tracer=tr)
        gw = Gateway(eng)
        reqs = [gw.submit(list(range(3 + i)), RequestSpec(max_new_tokens=4))
                for i in range(3)]
        gw.run_until_drained()
        assert all(q.state == "done" for q in reqs)
        # phase self-times accumulated for the real tick phases
        assert {"schedule", "decode", "sample", "commit",
                "emit"} <= set(eng.stats.phase_ms)
        bd = eng.stats.phase_breakdown_ms()
        assert all(v >= 0 for v in bd.values())
        # every jitted entry rode a CompileWatch: >= decode + sample
        assert eng.stats.jit_compiles >= 2
        # host gaps between dispatches were observed
        assert eng.stats.tick_gaps > 0 and eng.stats.tick_gap_ms_mean > 0
        path = tmp_path / "t.jsonl"
        tr.dump(path)
        stats = validate_trace(path)
        assert stats["tick_spans"] == eng.stats.ticks
        assert stats["request_spans"] > 0
        # each request's track reaches its terminal instant
        done = [e for e in load_trace(path)
                if e.get("cat") == "request" and e["ph"] == "i"]
        assert {e["tid"] for e in done} == {q.uid for q in reqs}
        assert all(e["name"] == "done" for e in done)

    def test_default_engine_has_no_tracer_overhead(self, model_params):
        """Tracer disabled is the default: no span objects, no events, but
        the phase/gap accounting in stats still works."""
        from repro.serving import RequestSpec, ServeEngine
        model, params = model_params
        before = len(NULL_TRACER.events)
        eng = ServeEngine(model, params, max_slots=1, max_len=32)
        assert eng.trace is NULL_TRACER
        eng.submit(list(range(4)), RequestSpec(max_new_tokens=3))
        eng.run_until_drained()
        assert len(NULL_TRACER.events) == before       # recorded nothing
        assert NULL_TRACER.span("x") is _NULL_SPAN     # still the singleton
        assert eng.stats.phase_ms                      # accounting intact

    def test_on_tick_summary_feeds_energy(self, model_params):
        from repro.serving import RequestSpec, ServeEngine
        from repro.serving.gateway import Gateway
        model, params = model_params
        eng = ServeEngine(model, params, max_slots=1, max_len=32)
        gw = Gateway(eng)
        gw.submit(list(range(4)), RequestSpec(max_new_tokens=3))
        gw.run_until_drained()
        assert gw.energy.ticks == eng.stats.ticks
        g = gw.metrics_dict()["gauges"]
        assert g["chip_power_w"] > 0
        assert 0.0 <= g["gated_bank_fraction"] <= 1.0
        assert g["energy_per_token_j"] > 0
        assert "tick_gap_ms" in gw.metrics.histograms
