"""Performance-attribution tests: roofline classification, the profiler's
cost/memory capture and structural-vs-XLA cross-check, SLO latency
attribution golden cases, and the engine/gateway integration (profiled
serving run → validated attribution report + attributed Prom counters)."""
import jax
import jax.numpy as jnp
import pytest

from repro.obs.hardware import CPU_HOST, TPU_V5E, HardwareSpec, detect
from repro.serving.gateway.metrics import Metrics
from repro.serving.obs import (ProfileRegistry, SLOAttribution, SLO_PHASES,
                               attribution_report, classify, validate_report)
from repro.serving.obs.prom import render_text

jax.config.update("jax_enable_x64", False)

HW = HardwareSpec(name="test", peak_flops=100e9, hbm_bw=10e9,
                  ici_link_bw=1e9, hbm_bytes=1 << 30)       # ridge OI = 10


class TestHardwareSpec:
    def test_ridge_and_roof(self):
        assert HW.ridge_intensity == pytest.approx(10.0)
        # below the ridge the roof is bandwidth-sloped, above it flat
        assert HW.roof_flops(1.0) == pytest.approx(10e9)
        assert HW.roof_flops(1000.0) == pytest.approx(100e9)

    def test_detect_never_raises(self):
        hw = detect()
        assert hw in (CPU_HOST, TPU_V5E)
        assert hw.peak_flops > 0 and hw.hbm_bw > 0

    def test_roofline_bench_shares_the_spec(self):
        from benchmarks import roofline
        assert roofline.PEAK_FLOPS == TPU_V5E.peak_flops
        assert roofline.HBM_BW == TPU_V5E.hbm_bw


class TestClassify:
    def test_memory_bound(self):
        # OI = 1 < ridge 10; achieved 5 GB/s of a 10 GB/s roof
        r = classify(1e6, 1e6, 2e-4, HW)
        assert r["bound"] == "memory"
        assert r["intensity"] == pytest.approx(1.0)
        assert r["pct_of_roof"] == pytest.approx(0.5)
        assert r["achieved_gbs"] == pytest.approx(5.0)

    def test_compute_bound(self):
        # OI = 100 > ridge; achieved 50 GFLOP/s of the 100 GFLOP/s peak
        r = classify(1e8, 1e6, 2e-3, HW)
        assert r["bound"] == "compute"
        assert r["pct_of_roof"] == pytest.approx(0.5)
        assert r["achieved_gflops"] == pytest.approx(50.0)

    def test_unknown_without_capture(self):
        r = classify(0.0, 0.0, 1e-3, HW)
        assert r["bound"] == "unknown" and r["pct_of_roof"] == 0.0

    def test_pure_data_movement(self):
        # zero FLOPs: placement degrades to achieved-vs-peak bandwidth
        r = classify(0.0, 1e6, 1e-4, HW)
        assert r["bound"] == "memory"
        assert r["pct_of_roof"] == pytest.approx(1.0)


class TestProfileCapture:
    def test_capture_and_cross_check_band(self):
        """A loop-free jitted matmul: structural and XLA FLOP counts must
        agree (the cross-check band), and cost capture must populate every
        roofline input."""
        prof = ProfileRegistry(hw=CPU_HOST)
        f = jax.jit(lambda a, b: a @ b)
        a = jnp.ones((64, 128), jnp.float32)
        b = jnp.ones((128, 32), jnp.float32)
        jax.block_until_ready(f(a, b))
        prof.observe_call("matmul", f, (a, b), {}, 1e-3)
        rec = prof.records[("matmul", prof_sig := next(iter(prof.records))[1])]
        assert rec.analyzed and rec.capture_error is None
        assert rec.calls == 1 and rec.wall_s == pytest.approx(1e-3)
        assert rec.flops == pytest.approx(2 * 64 * 128 * 32, rel=0.1)
        assert rec.xla_flops > 0
        assert 0.5 <= rec.flops_xla_ratio <= 2.0      # loop-free: ratio ~ 1
        assert rec.bytes > 0
        row = prof.function_rows()[0]
        assert row["bound"] in ("memory", "compute")
        assert row["signature"] == prof_sig

    def test_compile_calls_skip_the_timing_mean(self):
        prof = ProfileRegistry(hw=CPU_HOST, capture=False)
        f = jax.jit(lambda a: a + 1)
        x = jnp.ones((8,), jnp.float32)
        prof.observe_call("add", f, (x,), {}, 2.0, compiled=True)
        prof.observe_call("add", f, (x,), {}, 1e-3)
        rec = next(iter(prof.records.values()))
        assert rec.compiles == 1 and rec.calls == 1
        assert rec.mean_s == pytest.approx(1e-3)
        offs = prof.recompile_offenders()
        assert offs and offs[0]["fn"] == "add" and offs[0]["compiles"] == 1

    def test_report_schema(self):
        prof = ProfileRegistry(hw=CPU_HOST)
        f = jax.jit(lambda a: a * 2)
        x = jnp.ones((4, 4), jnp.float32)
        jax.block_until_ready(f(x))
        prof.observe_call("mul", f, (x,), {}, 1e-4)
        counts = validate_report(prof.report())
        assert counts["functions"] == 1
        with pytest.raises(AssertionError):
            validate_report({"hardware": {}, "functions": []})


class _StubReq:
    """Just the request surface SLOAttribution touches."""

    def __init__(self, uid, t_submit):
        self.uid = uid
        self.t_submit = t_submit
        self.t_admit = None
        self.t_done = None
        self.state = "queued"
        self.stall_s = 0.0


class TestSLOAttribution:
    def test_queued_only_cancel(self):
        """A request cancelled while still queued: its whole wall time is
        queue_wait, and the components sum to the wall exactly."""
        slo = SLOAttribution()
        req = _StubReq(1, 100.0)
        slo.observe_submit(req)
        req.state = "cancelled"
        comp = slo.close(req, now=105.0)
        assert comp["queue_wait"] == pytest.approx(5.0)
        assert sum(comp.values()) == pytest.approx(5.0)
        snap, wall = slo.snapshot(req)
        assert wall == pytest.approx(5.0)
        assert sum(snap.values()) == pytest.approx(wall)

    def test_preempted_golden(self):
        """submit +0 → admit +1 → token +2 → preempt +3 → re-admit +4 (stays
        preempted: replay prefill is preemption cost) → token +5 → done +6.
        Base epoch is nonzero: 0.0 timestamps mean "unset" to the engine."""
        slo = SLOAttribution()
        req = _StubReq(2, 100.0)
        slo.observe_submit(req)
        req.t_admit = 101.0
        slo.observe_admit(req)
        slo.observe_token(req, now=102.0)
        slo.observe_preempt(req, now=103.0)
        req.t_admit = 104.0
        slo.observe_admit(req)                  # must NOT restart prefill
        slo.observe_token(req, now=105.0)
        req.state = "done"
        comp = slo.close(req, now=106.0)
        assert comp["queue_wait"] == pytest.approx(1.0)
        assert comp["prefill"] == pytest.approx(1.0)
        assert comp["preempted"] == pytest.approx(2.0)      # +3 → +5
        assert comp["decode"] == pytest.approx(2.0)         # +2→+3 and +5→+6
        assert sum(comp.values()) == pytest.approx(6.0)

    def test_chunked_prefill_stall_carved(self):
        """Stall wall time is carved out of decode (never other phases) and
        the sum-to-wall identity survives the carve."""
        slo = SLOAttribution()
        req = _StubReq(3, 100.0)
        slo.observe_submit(req)
        req.t_admit = 101.0
        slo.observe_admit(req)
        slo.observe_token(req, now=103.0)
        req.stall_s = 0.5
        req.state = "done"
        comp = slo.close(req, now=105.0)
        assert comp["prefill"] == pytest.approx(2.0)
        assert comp["decode"] == pytest.approx(1.5)
        assert comp["decode_stall"] == pytest.approx(0.5)
        assert sum(comp.values()) == pytest.approx(5.0)

    def test_stall_clamped_to_decode(self):
        # a stall claim larger than the decode interval cannot push any
        # component negative
        slo = SLOAttribution()
        req = _StubReq(4, 100.0)
        slo.observe_submit(req)
        req.t_admit = 101.0
        slo.observe_admit(req)
        slo.observe_token(req, now=102.0)
        req.stall_s = 99.0
        req.state = "expired"
        comp = slo.close(req, now=103.0)
        assert comp["decode"] == 0.0
        assert comp["decode_stall"] == pytest.approx(1.0)
        assert min(comp.values()) >= 0.0
        assert sum(comp.values()) == pytest.approx(3.0)

    def test_close_idempotent_and_violations(self):
        slo = SLOAttribution()
        req = _StubReq(5, 100.0)
        slo.observe_submit(req)
        req.state = "expired"
        first = slo.close(req, now=101.0)
        again = slo.close(req, now=999.0)       # frozen: later close ignored
        assert again == first and slo.closed == 1
        slo.note_violation("queue_wait")
        slo.note_violation("queue_wait")
        assert slo.violations == {"queue_wait": 2}

    def test_prom_renders_attributed_counters(self):
        m = Metrics()
        m.inc("slo_violation__queue_wait")
        m.inc("slo_violation__decode", 2)
        m.observe("slo_phase_ms__decode", 12.5)
        text = render_text(m)
        assert 'slo_violation{id="queue_wait"} 1' in text
        assert 'slo_violation{id="decode"} 2' in text
        assert "slo_phase_ms" in text


@pytest.fixture(scope="module")
def profiled_run():
    """One profiled serving run on the tiny model: profiler + SLO wiring +
    an unmeetable deadline so a violation gets attributed."""
    from repro.configs.base import get_config
    from repro.launch.train import reduce_config
    from repro.models.transformer import Model
    from repro.serving import PagedKV, RequestSpec, ServeEngine
    from repro.serving.gateway import Gateway

    cfg = reduce_config(get_config("bitnet-2b"), "tiny")
    model = Model(cfg, mode="serve")
    params = model.init(jax.random.PRNGKey(0))
    prof = ProfileRegistry()
    eng = ServeEngine(model, params, max_slots=2, max_len=64,
                      kv=PagedKV(page=8, n_pages=24), profiler=prof)
    gw = Gateway(eng)
    reqs = [gw.submit([1, 2, 3, 4], RequestSpec(max_new_tokens=4)),
            gw.submit([5, 6, 7], RequestSpec(max_new_tokens=4)),
            gw.submit([8, 9], RequestSpec(max_new_tokens=3,
                                          deadline_ms=0.01))]
    gw.run_until_drained()
    return gw, prof, reqs


class TestEngineIntegration:
    def test_capture_on_tiny_model(self, profiled_run):
        gw, prof, _ = profiled_run
        rows = prof.function_rows()
        assert rows, "profiler saw no dispatches"
        names = {r["fn"] for r in rows}
        assert any("decode" in n for n in names)
        captured = [r for r in rows if r["capture_error"] is None
                    and r["flops"] > 0]
        assert captured, f"no cost capture succeeded: {rows}"
        # the decode graph scans over layers: the loop-weighted structural
        # count must be >= XLA's once-counted figure
        for r in captured:
            assert r["flops_xla_ratio"] >= 0.9
            assert r["bound"] in ("memory", "compute")
            assert r["calls"] > 0 and r["mean_ms"] > 0

    def test_attribution_report_validates(self, profiled_run):
        gw, prof, _ = profiled_run
        report = attribution_report(gw, prof)
        counts = validate_report(report)
        assert counts["functions"] >= 1
        assert set(report["slo"]["phases"]) == set(SLO_PHASES)
        assert report["host_overhead"]["frac_of_tick"] >= 0.0

    def test_components_sum_to_wall(self, profiled_run):
        """Acceptance invariant: every request's attribution components sum
        to its wall time."""
        gw, _, reqs = profiled_run
        for req in reqs:
            comp, wall = gw.slo.snapshot(req)
            assert wall > 0.0
            assert min(comp.values()) >= 0.0
            assert sum(comp.values()) == pytest.approx(wall, abs=1e-6)

    def test_violation_attributed_and_rendered(self, profiled_run):
        gw, _, reqs = profiled_run
        assert gw.metrics.counter("slo_violations_total") >= 1
        attributed = {n: v for n, v in gw.metrics.counters.items()
                      if n.startswith("slo_violation__")}
        assert attributed, "violation not attributed to any phase"
        assert sum(attributed.values()) == \
            gw.metrics.counter("slo_violations_total")
        text = render_text(gw.metrics)
        assert 'slo_violation{id="' in text
        rep = gw.slo_report()
        assert rep["violations_total"] >= 1
        assert rep["requests_closed"] == len(reqs)


class TestThreadedDispatch:
    """Observability correctness under the async runtime's thread model:
    the compile watch must attribute compiles race-free across threads, and
    the engine's host-gap probe must never count cross-thread wall time."""

    def test_compile_watch_concurrent_single_attribution(self):
        import threading
        from repro.serving.obs import CompileWatch

        calls = []
        fn = jax.jit(lambda x: x * 2)
        watch = CompileWatch(fn, "mul2",
                             on_compile=lambda n, s: calls.append((n, s)))
        xs = [jnp.ones((4,)), jnp.ones((8,)), jnp.ones((16,))]
        barrier = threading.Barrier(8)

        def worker():
            barrier.wait()
            for x in xs * 5:
                watch(x)

        threads = [threading.Thread(target=worker) for _ in range(8)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=60)
        # exactly one compile per distinct shape signature, no matter how
        # the 8 threads interleaved (the old cache-size diff miscounted here)
        assert watch.compiles == len(xs)
        assert len(calls) == len(xs)
        assert len({s for _n, s in calls}) == len(xs)

    def test_dispatch_gap_is_per_thread(self, profiled_run):
        """A dispatch issued from a different thread than the previous one
        must re-arm the gap clock, not record the cross-thread interval."""
        import threading
        import time as _time
        gw, _prof, _reqs = profiled_run
        eng = gw.engine
        eng._t_dev_end = _time.perf_counter() - 10.0   # 10 s ago, main thread
        eng._dispatch_tid = threading.get_ident()
        before_idle = eng.stats.tick_gap_ms_sum
        before_overlap = eng.stats.tick_gap_overlap_ms_sum
        out = {}

        def other_thread():
            out["r"] = eng._dispatch(lambda: 1)
        t = threading.Thread(target=other_thread)
        t.start()
        t.join(timeout=30)
        assert out["r"] == 1
        # the 10 s cross-thread gap is NOT attributed to either ledger
        assert eng.stats.tick_gap_ms_sum == before_idle
        assert eng.stats.tick_gap_overlap_ms_sum == before_overlap
        # …but a same-thread follow-up records a (small) gap again
        def same_thread_twice():
            eng._dispatch(lambda: 1)
            eng._dispatch(lambda: 2)
        t2 = threading.Thread(target=same_thread_twice)
        t2.start()
        t2.join(timeout=30)
        gained = (eng.stats.tick_gaps + eng.stats.tick_gaps_overlap)
        assert gained > 0
