"""Chunked prefill: token identity + SLO isolation.

Acceptance matrix for the chunked-prefill tentpole: splitting a long
prompt's batched prefill into ``prefill_chunk``-token segments (chunk i
resumes at ``pos_offset = i·C`` with the committed chunks as ``prefix_kv``)
must be **token-identical** to monolithic prefill for every
``{DenseKV, PagedKV} × {adapter, none} × chunk size`` combination,
including a prefix-cache hit followed by a chunked resume of the remainder.
Plus the behavioural half: decode slots keep emitting every tick while
another request's prompt streams in chunks, preemption mid-prefill releases
pages and replays cleanly, and the chunk planner follows priority order.
"""
import jax
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.launch.train import reduce_config
from repro.models.transformer import Model
from repro.serving import (DenseKV, PagedKV, RequestSpec, SamplingParams,
                           ServeEngine)
from repro.serving.adapters import (AdapterRegistry, AdapterServing,
                                    AdapterSpec, synthetic_adapter_stacks)
from repro.serving.gateway import Gateway

jax.config.update("jax_enable_x64", False)

ADAPTER_SPEC = AdapterSpec(rank=8, alpha=16.0, targets=("q", "v"))
LONG = 17                      # longest prompt in the identity workload
PAGE = 8


@pytest.fixture(scope="module")
def model_params():
    cfg = reduce_config(get_config("bitnet-2b"), "tiny")
    model = Model(cfg, mode="serve")
    return model, model.init(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def registry(model_params):
    model, _ = model_params
    reg = AdapterRegistry(ADAPTER_SPEC)
    rng = np.random.default_rng(11)
    for i in range(2):
        reg.register(f"tenant-{i}",
                     synthetic_adapter_stacks(rng, model.cfg, ADAPTER_SPEC,
                                              model.cfg.num_layers,
                                              scale=0.05))
    return reg


def _prompts():
    rng = np.random.default_rng(4)
    return [list(rng.integers(0, 100, size=n)) for n in (LONG, 5, 12)]


def _make_engine(model, params, registry, backend, adapter, chunk, **kw):
    make = {"dense": DenseKV, "paged": lambda: PagedKV(page=PAGE)}[backend]
    adapters = None
    if adapter:
        nbytes = registry.get("tenant-0").nbytes
        adapters = AdapterServing(model, registry, budget_bytes=nbytes * 2,
                                  max_resident=2)
    return ServeEngine(model, params, max_slots=3, max_len=64,
                       prefill="batched", prefill_chunk=chunk, kv=make(),
                       seed=7, adapters=adapters, **kw)


_memo = {}


def _outputs(model_params, registry, backend, adapter, chunk):
    """Greedy outputs for the standard workload (memoized: the unchunked
    baseline is shared across every chunk-size case)."""
    key = (backend, adapter, chunk)
    if key not in _memo:
        model, params = model_params
        eng = _make_engine(model, params, registry, backend, adapter, chunk)
        reqs = [eng.submit(p, RequestSpec(max_new_tokens=5,
                                          adapter_id=adapter))
                for p in _prompts()]
        stats = eng.run_until_drained()
        assert stats.completed == len(reqs)
        _memo[key] = ([list(r.output) for r in reqs], stats.prefill_chunks)
    return _memo[key]


class TestTokenIdentityMatrix:
    @pytest.mark.parametrize("backend", ["dense", "paged"])
    @pytest.mark.parametrize("adapter", [None, "tenant-0"])
    @pytest.mark.parametrize("chunk", [1, 4, LONG, LONG + 7])
    def test_chunked_matches_unchunked(self, model_params, registry,
                                       backend, adapter, chunk):
        base, _ = _outputs(model_params, registry, backend, adapter, None)
        got, n_chunks = _outputs(model_params, registry, backend, adapter,
                                 chunk)
        assert got == base, (backend, adapter, chunk)
        if chunk < LONG - 1:
            # the small chunk sizes must actually exercise the chunk path
            assert n_chunks > 0

    def test_chunk_accounting(self, model_params, registry):
        """A C-token chunker spends ceil((len-1)/C) segments on a prompt
        longer than C+1 and transitions to decode with no tokens lost."""
        model, params = model_params
        eng = _make_engine(model, params, registry, "paged", None, 4)
        req = eng.submit(list(range(1, LONG + 1)),
                         RequestSpec(max_new_tokens=3))
        eng.run_until_drained()
        assert req.state == "done" and len(req.output) == 3
        assert req.prefill_chunks == -(-(LONG - 1) // 4)


class TestPrefixCacheThenChunkedResume:
    def test_hit_then_chunked_remainder(self, model_params, registry):
        """A prefix-cache hit resumes *and* the remainder is chunked: the
        slot starts at the shared span, streams the rest in chunks, and the
        output matches the unchunked prefix-cache engine token for token."""
        model, params = model_params
        rng = np.random.default_rng(9)
        shared = list(rng.integers(0, 100, size=2 * PAGE))  # 2 full pages
        tail = list(rng.integers(0, 100, size=13))
        outs = {}
        for chunk in (None, 3):
            eng = _make_engine(model, params, registry, "paged", None, chunk,
                               prefix_cache=True)
            warm = eng.submit(shared + [7, 8], RequestSpec(max_new_tokens=2))
            eng.run_until_drained()
            assert warm.state == "done"
            req = eng.submit(shared + tail, RequestSpec(max_new_tokens=5))
            eng.run_until_drained()
            assert req.state == "done"
            assert req.prefix_hit_tokens == 2 * PAGE
            if chunk:
                assert req.prefill_chunks == -(-(len(tail) - 1) // chunk)
            outs[chunk] = list(req.output)
        assert outs[3] == outs[None]


class TestSLOIsolation:
    def test_decode_keeps_emitting_during_chunked_prefill(self, model_params,
                                                          registry):
        """The SLO-isolation contract: while a long prompt streams in
        chunks, an already-decoding slot emits one token on every tick —
        zero starvation ticks — and the prefill still completes."""
        model, params = model_params
        eng = _make_engine(model, params, registry, "paged", None, 2)
        short = eng.submit([1, 2, 3], RequestSpec(max_new_tokens=30))
        for _ in range(3):
            eng.tick()
        have = len(short.output)
        assert have > 0
        long_req = eng.submit(list(range(1, LONG + 1)),
                              RequestSpec(max_new_tokens=2))
        for i in range(1, 9):
            eng.tick()
            assert len(short.output) == have + i, \
                "decode slot starved during another request's chunked prefill"
        assert long_req.prefill_chunks > 0
        eng.run_until_drained()
        assert long_req.state == "done" and short.state == "done"

    def test_chunk_planner_priority_order(self, model_params, registry):
        """With two prompts mid-chunked-prefill and a decode slot active,
        the interactive (priority 0) prompt's chunks advance first."""
        model, params = model_params
        eng = _make_engine(model, params, registry, "paged", None, 2)
        busy = eng.submit([1, 2], RequestSpec(max_new_tokens=40))
        eng.tick()
        assert len(busy.output) >= 1
        bg = eng.submit(list(range(1, 14)),
                        RequestSpec(max_new_tokens=2, priority=2))
        fg = eng.submit(list(range(2, 15)),
                        RequestSpec(max_new_tokens=2, priority=0))
        eng.tick()                 # both admitted; one chunk budget: fg first
        assert fg.prefill_chunks == 1
        assert bg.prefill_chunks == 0
        eng.run_until_drained()
        assert fg.state == bg.state == "done"
        # fg finished prefill strictly before bg started emitting
        assert fg.t_first <= bg.t_first

    def test_preempt_mid_prefill_releases_and_replays(self, model_params,
                                                      registry):
        """Preemption-safe partial-prefill release: a mid-chunked-prefill
        victim gives its pages back (no leak), requeues, and still produces
        the same tokens as an undisturbed run."""
        model, params = model_params
        # solo reference
        eng = _make_engine(model, params, registry, "paged", None, 3)
        ref = eng.submit(list(range(1, LONG + 1)), RequestSpec(max_new_tokens=4))
        eng.run_until_drained()
        assert ref.state == "done"

        # 7-page (28-token) pool: bg's prefill holds 5 pages, so admitting
        # the priority-0 fg (4 pages) forces a mid-prefill preemption
        eng = ServeEngine(model, params, max_slots=2, max_len=64,
                          prefill="batched", prefill_chunk=3, seed=7,
                          kv=PagedKV(page=4, n_pages=7))
        bg = eng.submit(list(range(1, LONG + 1)),
                        RequestSpec(max_new_tokens=4, priority=2))
        eng.tick()                              # bg starts chunking
        assert eng.slot_prefill_todo[0]
        fg = eng.submit(list(range(1, 16)),
                        RequestSpec(max_new_tokens=4, priority=0))
        eng.tick()
        assert bg.n_preempts == 1 and bg.state in ("preempted", "running")
        assert not bg.output      # it was still mid-prefill when evicted
        eng.run_until_drained()
        assert fg.state == "done" and bg.state == "done"
        assert eng.stats.preemptions >= 1
        assert list(bg.output) == list(ref.output)
        # all pages returned once both slots drained
        assert eng.pool.pages_free == 7
        assert eng.kv.pages_free == 7

    def test_preempt_mid_decode_replays_through_chunks(self, model_params,
                                                       registry):
        """A request preempted *while decoding* replays prompt+output
        through chunked prefill on re-admission — it must stay out of the
        decode batch until the replay commits (feeding it mid-prefill would
        shift its KV positions) and still match an undisturbed run."""
        model, params = model_params
        prompt = list(range(3, 13))                      # 10 tokens
        eng = _make_engine(model, params, registry, "paged", None, 3)
        ref = eng.submit(prompt, RequestSpec(max_new_tokens=6))
        eng.run_until_drained()
        assert ref.state == "done"

        eng = ServeEngine(model, params, max_slots=2, max_len=64,
                          prefill="batched", prefill_chunk=3, seed=7,
                          kv=PagedKV(page=4, n_pages=7))
        bg = eng.submit(prompt, RequestSpec(max_new_tokens=6, priority=2))
        for _ in range(6):       # 3 chunk ticks + ~3 decode ticks
            eng.tick()
        assert len(bg.output) >= 2          # genuinely mid-decode
        fg = eng.submit(list(range(1, 16)),
                        RequestSpec(max_new_tokens=4, priority=0))
        eng.run_until_drained()
        assert fg.state == "done" and bg.state == "done"
        assert bg.n_preempts >= 1
        assert list(bg.output) == list(ref.output)
        assert eng.pool.pages_free == 7


class TestCancelMidPrefill:
    """Satellite regression: cancel() on a slot whose chunked prefill is
    still in flight must release exactly the committed chunk pages, exactly
    once — no double-free against `_release_slot`'s partial-prefill path,
    and prefix-cache-owned lead pages must stay with the trie."""

    def test_cancel_releases_committed_chunks_once(self, model_params,
                                                   registry):
        model, params = model_params
        eng = ServeEngine(model, params, max_slots=2, max_len=64,
                          prefill="batched", prefill_chunk=3,
                          kv=PagedKV(page=4, n_pages=24))
        gw = Gateway(eng)
        req = gw.submit(list(range(2, 32)), RequestSpec(max_new_tokens=4))
        gw.step()
        slot = next(i for i, q in enumerate(eng.slot_req) if q is req)
        assert eng.slot_prefill_todo[slot], "prefill should be mid-flight"
        held = len(eng.pool.tables[slot])
        assert held > 0
        assert gw.cancel(req.uid) and req.state == "cancelled"
        # every page back on the free list, each exactly once
        assert eng.pool.pages_free == 24
        free = list(eng.pool.free)
        assert len(free) == len(set(free))
        assert not eng.slot_prefill_todo[slot]
        assert eng.slot_req[slot] is None
        # double-cancel is a no-op, not a second release
        assert not gw.cancel(req.uid)
        assert eng.pool.pages_free == 24
        # the engine keeps serving afterwards
        ok = gw.submit(list(range(5)), RequestSpec(max_new_tokens=3))
        gw.run_until_drained()
        assert ok.state == "done" and eng.pool.pages_free == 24

    def test_cancel_mid_prefill_after_prefix_hit(self, model_params,
                                                 registry):
        """Cancel during the chunked *remainder* of a prefix-cache hit:
        the shared lead pages stay trie-owned (refcount decremented, not
        freed), only the slot's private chunk pages return to the pool."""
        model, params = model_params
        eng = ServeEngine(model, params, max_slots=2, max_len=64,
                          prefill="batched", prefill_chunk=3,
                          kv=PagedKV(page=4, n_pages=24), prefix_cache=True)
        gw = Gateway(eng)
        shared = list(range(40, 48))                 # 2 full pages
        warm = gw.submit(shared + [1, 2], RequestSpec(max_new_tokens=2))
        gw.run_until_drained()
        assert warm.state == "done"
        trie = {nd.page_id for nd in eng.prefix.nodes.values()}
        assert trie
        req = gw.submit(shared + list(range(60, 80)),
                        RequestSpec(max_new_tokens=2))
        gw.step()
        slot = next(i for i, q in enumerate(eng.slot_req) if q is req)
        assert eng.slot_cached[slot] > 0 and eng.slot_prefill_todo[slot]
        assert gw.cancel(req.uid)
        trie_after = {nd.page_id for nd in eng.prefix.nodes.values()}
        assert trie_after == trie, "cancel must not free trie-owned pages"
        every = list(eng.pool.free) + sorted(trie_after) + [
            p for i, t in enumerate(eng.pool.tables)
            for p in t[eng.slot_cached[i]:]]
        assert len(every) == len(set(every)) == 24, \
            "page owned by more than one of {free, trie, slot} after cancel"

    def test_cancel_from_stream_callback_mid_tick(self, model_params,
                                                  registry):
        """A stream callback cancelling a co-resident request mid-tick must
        not corrupt the tick loop (slots released under it) or double-count
        terminal states."""
        model, params = model_params
        eng = ServeEngine(model, params, max_slots=3, max_len=64,
                          prefill="batched", kv=PagedKV(page=4, n_pages=48),
                          spec_decode=True)
        gw = Gateway(eng)
        reqs = []

        def cb(req, tok):
            for q in reqs:
                if q.uid != req.uid and q.state == "running":
                    gw.cancel(q.uid)
                    return

        for j in range(4):
            reqs.append(gw.submit(
                list(range(3 + j, 9 + j)),
                RequestSpec(max_new_tokens=6,
                            stream_cb=cb if j == 0 else None),
                SamplingParams(spec_k=2 if j % 2 else 0)))
        gw.run_until_drained()
        assert all(q.state in ("done", "cancelled") for q in reqs)
        assert eng.pool.pages_free == 48
        free = list(eng.pool.free)
        assert len(free) == len(set(free))
