"""Sharded multi-replica serving + AOT bucket warmup acceptance tests.

The contract under test (ISSUE: sharded serving with AOT warmup):

  * **zero steady-state recompiles** — after ``ServeEngine.warmup_aot``,
    a request sweep covering every prompt-length bucket, sampling-static
    combo and speculative verify width drives the CompileWatch recompile
    counter to exactly 0; a deliberately unbucketed prompt length is the
    positive control proving the counter still counts;
  * **warmup is semantically free** — a warmed engine's outputs are
    bit-identical to a cold engine's for greedy and seeded sampling
    (warmup must never consume live KV state or advance the sampling key);
  * **router placement** — requests route to the replica with the longest
    prefix-cache hit, falling back to least-loaded (adapter residency
    breaks ties); poisoned replicas are skipped; uid blocks are disjoint;
  * **adapter hot-swap pinning** — a version re-register mid-stream never
    perturbs an in-flight request (it finishes on its pinned version,
    token-identical to a no-swap run) while new submits ride the new one;
  * **sharded == single-device** — under a forced 4-device host platform,
    a 2-replica mesh-sharded router produces token-identical output to one
    unsharded engine across {DenseKV, PagedKV} x {adapter, none} x
    {spec on/off}.
"""
import json
import os
import pathlib
import subprocess
import sys
import textwrap
import types

import jax
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.launch.train import reduce_config
from repro.models.transformer import Model
from repro.serving import (AsyncServeRuntime, DenseKV, PagedKV, ReplicaRouter,
                           RequestSpec, RuntimePoisoned, SamplingParams,
                           ServeEngine)
from repro.serving.adapters import (AdapterRegistry, AdapterServing,
                                    AdapterSpec, synthetic_adapter_stacks)
from repro.serving.gateway import Gateway
from repro.serving.gateway.prefix_cache import PrefixCache
from repro.serving.router import UID_STRIDE

jax.config.update("jax_enable_x64", False)

SPEC = AdapterSpec(rank=4, alpha=8.0, targets=("q", "v"))


@pytest.fixture(scope="module")
def model_params():
    cfg = reduce_config(get_config("bitnet-2b"), "tiny")
    model = Model(cfg, mode="serve")
    return model, model.init(jax.random.PRNGKey(0))


def _registry(model, seed=7, n=2):
    reg = AdapterRegistry(SPEC)
    rng = np.random.default_rng(seed)
    for i in range(n):
        reg.register(f"tenant-{i}",
                     synthetic_adapter_stacks(rng, model.cfg, SPEC,
                                              model.cfg.num_layers,
                                              scale=0.05))
    return reg


def _engine(model_params, *, kv="paged", adapters=None, spec=False,
            prefix_cache=False, slots=2):
    model, params = model_params
    backend = PagedKV(page=8) if kv == "paged" else DenseKV()
    return ServeEngine(model, params, max_slots=slots, max_len=64,
                       prefill="batched", kv=backend, spec_decode=spec,
                       prefix_cache=prefix_cache, adapters=adapters)


def _sweep_workload(*, adapters=False, spec=False):
    """Every fresh-prefill bucket of max_len=64 ({16, 32, 64}), greedy and
    seeded rows (all four sampling-static combos), adapters on alternating
    rows, spec widths 2/4 when enabled."""
    rng = np.random.default_rng(11)
    work = []
    for i, plen in enumerate((3, 9, 14, 20, 30, 44, 57)):
        prompt = list(rng.integers(0, 100, size=plen))
        adapter_id = f"tenant-{i % 2}" if adapters and i % 2 == 0 else None
        spec_k = (2 if i % 3 == 0 else 4) if spec else 0
        sampling = (SamplingParams(spec_k=spec_k) if i % 2 == 0 else
                    SamplingParams(temperature=0.8, top_k=16,
                                   top_p=0.9 if i % 4 == 1 else 1.0,
                                   seed=100 + i, spec_k=spec_k))
        work.append((prompt,
                     RequestSpec(max_new_tokens=5, adapter_id=adapter_id),
                     sampling))
    return work


def _run(eng, work):
    reqs = [eng.submit(p, s, sp) for p, s, sp in work]
    eng.run_until_drained()
    assert all(r.state == "done" for r in reqs)
    return [r.output for r in reqs]


class TestAotWarmup:
    def test_zero_recompiles_after_sweep(self, model_params):
        """The headline warmup contract: AOT bucket warmup + jit pre-trace
        drive steady-state recompiles to exactly zero across the full
        bucket/static/verify-width surface."""
        model, _ = model_params
        reg = _registry(model)
        adapters = AdapterServing(model, reg,
                                  budget_bytes=reg.get("tenant-0").nbytes * 2,
                                  max_resident=2)
        eng = _engine(model_params, kv="paged", adapters=adapters, spec=True)
        info = eng.warmup_aot(max_prompt_len=64)
        assert info["aot_executables"] >= 3      # >= one per pow2 bucket
        assert info["compiles"] > 0
        assert eng.stats.warmup_compiles == info["compiles"]
        assert eng.stats.jit_compiles == 0       # warmup cost reclassified

        _run(eng, _sweep_workload(adapters=True, spec=True))
        assert eng.stats.jit_compiles == 0, \
            "request sweep recompiled after AOT warmup"
        assert eng.stats.aot_fallbacks == 0

    def test_dense_backend_also_zero(self, model_params):
        eng = _engine(model_params, kv="dense", spec=True)
        eng.warmup_aot(max_prompt_len=64)
        _run(eng, _sweep_workload(spec=True))
        assert eng.stats.jit_compiles == 0
        assert eng.stats.aot_fallbacks == 0

    def test_unbucketed_length_is_positive_control(self, model_params):
        """A prompt landing in a bucket warmup never compiled must bump the
        recompile counter — proving the zeros above are measurements, not a
        dead counter."""
        eng = _engine(model_params, kv="paged")
        eng.warmup_aot(max_prompt_len=16)        # only the 16-token bucket
        r = eng.submit(list(range(10)), RequestSpec(max_new_tokens=3))
        eng.run_until_drained()
        assert r.state == "done" and eng.stats.jit_compiles == 0
        r = eng.submit(list(range(24)), RequestSpec(max_new_tokens=3))
        eng.run_until_drained()                  # 24 -> 32 bucket: unwarmed
        assert r.state == "done" and eng.stats.jit_compiles >= 1

    def test_warm_vs_cold_token_identity(self, model_params):
        """Warmup must not perturb outputs: throwaway decode states and a
        throwaway PRNG key mean the warmed engine's stream is bit-identical
        to the cold engine's."""
        work = _sweep_workload(spec=False)
        ref = _run(_engine(model_params, kv="paged"), work)
        warm = _engine(model_params, kv="paged")
        warm.warmup_aot(max_prompt_len=64)
        assert _run(warm, work) == ref
        assert warm.stats.jit_compiles == 0


def _stub_replica(*, page=8, load=0, committed=None, poisoned=False,
                  resident=()):
    """Duck-typed (runtime, engine) pair for placement-policy tests — the
    router only reads prefix/pool/scheduler/slot_req/adapters/_uid."""
    prefix = None
    if committed is not None:
        prefix = PrefixCache(page)
        prefix.commit(list(committed), list(range(len(committed) // page)), 0)
    eng = types.SimpleNamespace(
        _uid=0,
        prefix=prefix,
        pool=types.SimpleNamespace(cfg=types.SimpleNamespace(page=page)),
        scheduler=[object()] * load,
        slot_req=[None, None],
        adapters=types.SimpleNamespace(
            is_resident=lambda aid: aid in resident) if resident else None)
    return types.SimpleNamespace(eng=eng, poisoned=poisoned, exception=None)


class TestRouterPlacement:
    def test_longest_prefix_hit_wins(self):
        prompt = list(range(40))
        router = ReplicaRouter([
            _stub_replica(committed=prompt[:8], load=0),    # 1 page hit
            _stub_replica(committed=prompt[:24], load=5),   # 2 page hit
        ])
        assert router.route(prompt) == (1, "prefix_hit")

    def test_least_loaded_fallback(self):
        router = ReplicaRouter([_stub_replica(load=3), _stub_replica(load=1)])
        idx, reason = router.route(list(range(6)))
        assert (idx, reason) == (1, "least_loaded")

    def test_adapter_affinity_breaks_load_ties(self):
        router = ReplicaRouter([
            _stub_replica(load=2),
            _stub_replica(load=2, resident=("tenant-0",)),
        ])
        assert router.route([1, 2, 3], "tenant-0") == (1, "adapter_affinity")

    def test_poisoned_replicas_skipped(self):
        prompt = list(range(40))
        router = ReplicaRouter([
            _stub_replica(load=9),
            _stub_replica(committed=prompt[:24], poisoned=True),
        ])
        assert router.route(prompt)[0] == 0
        assert router.degraded and not router.poisoned

    def test_all_poisoned_raises(self):
        router = ReplicaRouter([_stub_replica(poisoned=True),
                                _stub_replica(poisoned=True)])
        assert router.poisoned
        with pytest.raises(RuntimePoisoned):
            router.route([1, 2])

    def test_uid_blocks_disjoint(self):
        router = ReplicaRouter([_stub_replica(), _stub_replica()])
        assert [rt.eng._uid for rt in router.runtimes] == [0, UID_STRIDE]
        replaced = router.replace_replica(0, _stub_replica())
        assert router.runtimes[0].eng._uid == 2 * UID_STRIDE
        assert replaced.eng._uid == 0


class TestRoutedFleet:
    def test_two_replicas_token_identical_to_one_engine(self, model_params):
        """Routed fleet output == one unsharded engine: greedy/seeded token
        streams are engine- and placement-independent, so splitting the
        workload over replicas must not change a single token."""
        work = _sweep_workload(spec=False)
        ref = _run(_engine(model_params, kv="paged"), work)

        engs = [_engine(model_params, kv="paged", prefix_cache=True)
                for _ in range(2)]
        router = ReplicaRouter([AsyncServeRuntime(Gateway(e), depth=1)
                                for e in engs])
        with router:
            tickets = [router.submit(p, spec=s, sampling=sp, timeout=120)
                       for p, s, sp in work]
            router.drain(timeout=300)
            out = [t.result() for t in tickets]
        assert out == ref
        m = router.gw.metrics
        assert m.counter("requests_routed") == len(work)
        # both replicas actually served traffic
        assert m.counter("routed__r0") > 0 and m.counter("routed__r1") > 0
        # uids are namespaced per replica block
        owners = {t.uid // UID_STRIDE for t in tickets}
        assert owners == {0, 1}
        # the fleet prom exposition carries per-replica suffixed series
        prom = m.to_prom_text()
        assert "requests_routed" in prom
        assert "tokens_out_r0" in prom and "tokens_out_r1" in prom
        assert "replicas_healthy 2" in prom


class TestAdapterHotSwapRegression:
    """Deterministic mid-stream version-bump regression (the fuzz lane in
    test_serving_fuzz.py drives the same contract randomly)."""

    def _fresh(self, model_params):
        model, _ = model_params
        reg = _registry(model, seed=7, n=1)
        ad = AdapterServing(model, reg,
                            budget_bytes=reg.get("tenant-0").nbytes * 3,
                            max_resident=3)
        return reg, _engine(model_params, kv="paged", adapters=ad, slots=2)

    def test_inflight_pins_old_version_new_submits_see_new(self,
                                                           model_params):
        model, _ = model_params
        prompt = list(range(40, 52))
        spec = RequestSpec(max_new_tokens=8, adapter_id="tenant-0")

        # reference: same request, no swap anywhere near it
        _, ref_eng = self._fresh(model_params)
        ra = ref_eng.submit(prompt, spec)
        ref_eng.run_until_drained()
        assert ra.state == "done"

        reg, eng = self._fresh(model_params)
        a = eng.submit(prompt, spec)
        while not a.output:                      # in flight, >= 1 token out
            eng.tick()
        slot_a = eng.slot_req.index(a)
        assert eng.slot_adapter_key[slot_a] == "tenant-0@v1"

        # hot-swap: re-register the tenant with different weights
        rng = np.random.default_rng(99)
        reg.register("tenant-0",
                     synthetic_adapter_stacks(rng, model.cfg, SPEC,
                                              model.cfg.num_layers,
                                              scale=0.05))
        b = eng.submit(prompt, spec)
        while b.state == "queued":
            eng.tick()
        slot_b = eng.slot_req.index(b)
        # new placement rides v2 while the old request stays pinned on v1 —
        # both versions resident at once
        assert eng.slot_adapter_key[slot_b] == "tenant-0@v2"
        assert eng.slot_adapter_key[slot_a] == "tenant-0@v1"
        assert eng.adapters.cache.is_resident("tenant-0@v1")
        assert eng.adapters.cache.is_resident("tenant-0@v2")
        eng.run_until_drained()
        assert a.state == "done" and b.state == "done"
        # the in-flight request finished on its pinned version: token-
        # identical to the no-swap reference
        assert a.output == ra.output
        assert not eng.adapters.pinned("tenant-0")


@pytest.mark.slow
class TestShardedIdentityMultiDevice:
    """Forced 4-device host platform: 2 mesh-sharded replicas behind the
    router vs one unsharded engine, token-identical across the whole
    {DenseKV, PagedKV} x {adapter, none} x {spec on/off} matrix."""

    def test_sharded_matrix_token_identity(self):
        script = textwrap.dedent("""
            import os
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
            import jax
            import numpy as np
            jax.config.update("jax_enable_x64", False)
            assert len(jax.devices()) == 4, jax.devices()

            from repro.configs.base import get_config
            from repro.launch.train import reduce_config
            from repro.models.transformer import Model
            from repro.serving import (AsyncServeRuntime, DenseKV, PagedKV,
                                       ReplicaRouter, RequestSpec,
                                       SamplingParams, ServeEngine,
                                       replica_meshes, shard_engine)
            from repro.serving.adapters import (AdapterRegistry,
                                                AdapterServing, AdapterSpec,
                                                synthetic_adapter_stacks)
            from repro.serving.gateway import Gateway

            cfg = reduce_config(get_config("bitnet-2b"), "tiny")
            model = Model(cfg, mode="serve")
            params = model.init(jax.random.PRNGKey(0))
            spec_ad = AdapterSpec(rank=4, alpha=8.0, targets=("q", "v"))
            reg = AdapterRegistry(spec_ad)
            rng = np.random.default_rng(7)
            for i in range(2):
                reg.register(f"tenant-{i}",
                             synthetic_adapter_stacks(rng, cfg, spec_ad,
                                                      cfg.num_layers,
                                                      scale=0.05))

            def engine(kv, with_ad, spec_k):
                backend = PagedKV(page=8) if kv == "paged" else DenseKV()
                ad = None
                if with_ad:
                    nb = reg.get("tenant-0").nbytes
                    ad = AdapterServing(model, reg, budget_bytes=nb * 2,
                                        max_resident=2)
                return ServeEngine(model, params, max_slots=2, max_len=64,
                                   prefill="batched", kv=backend,
                                   spec_decode=spec_k > 0, adapters=ad)

            def workload(with_ad, spec_k, n=3):
                wrng = np.random.default_rng(11)
                work = []
                for i in range(n):
                    prompt = list(wrng.integers(
                        0, 100, size=int(wrng.integers(3, 10))))
                    aid = (f"tenant-{i % 2}" if with_ad and i % 2 == 0
                           else None)
                    sampling = (SamplingParams(spec_k=spec_k) if i % 2 == 0
                                else SamplingParams(temperature=0.8, top_k=16,
                                                    seed=100 + i,
                                                    spec_k=spec_k))
                    work.append((prompt,
                                 RequestSpec(max_new_tokens=5,
                                             adapter_id=aid), sampling))
                return work

            meshes = replica_meshes(2, tp=1)
            devs = jax.devices()
            for kv in ("dense", "paged"):
                for with_ad in (False, True):
                    for spec_k in (0, 4):
                        work = workload(with_ad, spec_k)
                        oracle = engine(kv, with_ad, spec_k)
                        reqs = [oracle.submit(p, s, sp) for p, s, sp in work]
                        oracle.run_until_drained()
                        ref = [r.output for r in reqs]
                        assert all(r.state == "done" for r in reqs)

                        engs = [shard_engine(engine(kv, with_ad, spec_k), m)
                                for m in meshes]
                        # replicas really live on distinct devices
                        for r, e in enumerate(engs):
                            leaf = jax.tree.leaves(e.params)[0]
                            assert leaf.devices() == {devs[r]}, \\
                                (r, leaf.devices())
                        router = ReplicaRouter(
                            [AsyncServeRuntime(Gateway(e), depth=1)
                             for e in engs])
                        with router:
                            tickets = [router.submit(p, spec=s, sampling=sp,
                                                     timeout=120)
                                       for p, s, sp in work]
                            router.drain(timeout=600)
                            out = [t.result() for t in tickets]
                        assert out == ref, (kv, with_ad, spec_k, out, ref)
                        print(f"identical kv={kv} adapters={with_ad} "
                              f"spec={spec_k}", flush=True)
            print("MATRIX-OK")
        """)
        # inherit the parent env (JAX_PLATFORMS et al.) — a hand-stripped
        # env made jax hang probing platforms under the forced-device flag
        res = subprocess.run(
            [sys.executable, "-c", script], capture_output=True, text=True,
            timeout=1800,
            env={**os.environ, "PYTHONPATH": "src"},
            cwd=str(pathlib.Path(__file__).resolve().parents[1]))
        assert res.returncode == 0, (res.stdout[-2000:], res.stderr[-3000:])
        assert "MATRIX-OK" in res.stdout
