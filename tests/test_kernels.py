"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:                      # real hypothesis when installed (CI does)
    from hypothesis import given, settings
    import hypothesis.strategies as st
except ImportError:       # deterministic fallback — properties never skip
    from repro.testing.hypothesis_compat import given, settings, st  # noqa: E402

from repro.core import fp8, ternary
from repro.kernels.flash_decode import ops as fd_ops
from repro.kernels.flash_decode.ref import flash_decode_ref
from repro.kernels.ternary_matmul import ops as tm_ops
from repro.kernels.ternary_matmul.ref import ternary_matmul_ref


def rng(seed=0):
    return np.random.default_rng(seed)


def make_ternary(k, n, seed=0, layout="interleaved"):
    w = jnp.asarray(rng(seed).normal(size=(k, n)), jnp.float32)
    t, s = ternary.quantize(w)
    return ternary.pack2(t, layout=layout), s


class TestTernaryMatmulKernel:
    @pytest.mark.parametrize("m", [1, 7, 128])
    @pytest.mark.parametrize("k,n", [(512, 256), (1024, 384), (2048, 512)])
    def test_shapes_sweep(self, m, k, n):
        p, s = make_ternary(k, n, seed=k + n)
        x = jnp.asarray(rng(m).normal(size=(m, k)), jnp.float32)
        got = tm_ops.ternary_matmul(x, p, s)
        want = ternary_matmul_ref(x, p, s)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-4)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtypes(self, dtype):
        p, s = make_ternary(512, 256, seed=3)
        x = jnp.asarray(rng(4).normal(size=(8, 512)), dtype)
        got = tm_ops.ternary_matmul(x, p, s)
        want = ternary_matmul_ref(x.astype(jnp.float32), p, s)
        tol = 1e-5 if dtype == jnp.float32 else 2e-2
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=tol, atol=tol * 10)

    @pytest.mark.parametrize("layout", ["interleaved", "strided"])
    def test_layouts(self, layout):
        p, s = make_ternary(1024, 256, seed=5, layout=layout)
        x = jnp.asarray(rng(6).normal(size=(4, 1024)), jnp.float32)
        got = tm_ops.ternary_matmul(x, p, s, layout=layout)
        want = ternary_matmul_ref(x, p, s, layout=layout)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-4)

    def test_batched_leading_dims(self):
        p, s = make_ternary(512, 128, seed=7)
        x = jnp.asarray(rng(8).normal(size=(2, 3, 512)), jnp.float32)
        got = tm_ops.ternary_matmul(x, p, s)
        want = ternary_matmul_ref(x.reshape(6, 512), p, s).reshape(2, 3, 128)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-4)

    def test_fallback_path_matches(self):
        # K not divisible by 512 → XLA fallback branch
        p, s = make_ternary(256, 128, seed=9)
        x = jnp.asarray(rng(10).normal(size=(4, 256)), jnp.float32)
        got = tm_ops.ternary_matmul(x, p, s)
        want = ternary_matmul_ref(x, p, s)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-4)

    def test_exactness_on_integer_inputs(self):
        # ternary weights × integer activations must be exact in f32
        p, s = make_ternary(512, 128, seed=11)
        x = jnp.asarray(rng(12).integers(-8, 8, size=(4, 512)), jnp.float32)
        got = tm_ops.ternary_matmul(x, p, jnp.float32(1.0))
        want = ternary_matmul_ref(x, p, jnp.float32(1.0))
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    @given(seed=st.integers(0, 2**16))
    @settings(max_examples=10, deadline=None)
    def test_property_random_ternary(self, seed):
        p, s = make_ternary(512, 128, seed=seed)
        x = jnp.asarray(rng(seed + 1).normal(size=(2, 512)), jnp.float32)
        got = tm_ops.ternary_matmul(x, p, s)
        want = ternary_matmul_ref(x, p, s)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-4)


class TestFlashDecodeKernel:
    @pytest.mark.parametrize("hq,hkv", [(8, 8), (8, 4), (16, 2), (4, 1)])
    @pytest.mark.parametrize("s_len", [128, 300, 1024])
    def test_gqa_shapes_sweep(self, hq, hkv, s_len):
        d = 64
        q = jnp.asarray(rng(hq + s_len).normal(size=(2, hq, d)), jnp.float32)
        k = jnp.asarray(rng(1).normal(size=(2, hkv, s_len, d)), jnp.float32)
        v = jnp.asarray(rng(2).normal(size=(2, hkv, s_len, d)), jnp.float32)
        got = fd_ops.decode_attention(q, k, v, jnp.int32(s_len), jnp.float32(1.0))
        want = flash_decode_ref(q.reshape(2, hkv, hq // hkv, d), k, v, s_len)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want).reshape(2, hq, d), rtol=1e-5, atol=1e-5)

    @pytest.mark.parametrize("length", [1, 17, 255, 256])
    def test_length_masking(self, length):
        d, s_len = 64, 256
        q = jnp.asarray(rng(20).normal(size=(1, 4, d)), jnp.float32)
        k = jnp.asarray(rng(21).normal(size=(1, 4, s_len, d)), jnp.float32)
        v = jnp.asarray(rng(22).normal(size=(1, 4, s_len, d)), jnp.float32)
        got = fd_ops.decode_attention(q, k, v, jnp.int32(length), jnp.float32(1.0))
        want = flash_decode_ref(q.reshape(1, 4, 1, d), k, v, length).reshape(1, 4, d)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)

    def test_fp8_kv_cache(self):
        d, s_len = 128, 512
        q = jnp.asarray(rng(30).normal(size=(2, 8, d)), jnp.float32)
        kf = jnp.asarray(rng(31).normal(size=(2, 4, s_len, d)), jnp.float32)
        vf = jnp.asarray(rng(32).normal(size=(2, 4, s_len, d)), jnp.float32)
        k8, ks = fp8.quantize(kf)
        v8, vs = fp8.quantize(vf)
        # common scale for K and V (the paper's per-cache scale)
        sc = jnp.maximum(ks, vs)
        k8 = (kf / sc).astype(jnp.float8_e4m3fn).astype(jnp.float32)
        v8 = (vf / sc).astype(jnp.float8_e4m3fn).astype(jnp.float32)
        got = fd_ops.decode_attention(q, k8, v8, jnp.int32(s_len), sc)
        want = flash_decode_ref(
            q.reshape(2, 4, 2, d), k8 * sc, v8 * sc, s_len).reshape(2, 8, d)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)
        # and fp8 round-trip stays close to the unquantized result
        exact = flash_decode_ref(q.reshape(2, 4, 2, d), kf, vf, s_len).reshape(2, 8, d)
        assert float(jnp.max(jnp.abs(got - exact))) < 0.35  # e4m3 KV error bound

    @pytest.mark.parametrize("d", [64, 128])
    def test_head_dims(self, d):
        q = jnp.asarray(rng(40 + d).normal(size=(1, 4, d)), jnp.float32)
        k = jnp.asarray(rng(41).normal(size=(1, 2, 256, d)), jnp.float32)
        v = jnp.asarray(rng(42).normal(size=(1, 2, 256, d)), jnp.float32)
        got = fd_ops.decode_attention(q, k, v, jnp.int32(256), jnp.float32(1.0))
        want = flash_decode_ref(q.reshape(1, 2, 2, d), k, v, 256).reshape(1, 4, d)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)

    @given(seed=st.integers(0, 2**16), length=st.integers(1, 384))
    @settings(max_examples=8, deadline=None)
    def test_property_online_softmax_invariance(self, seed, length):
        """Online (tiled) softmax must equal materialized softmax for any
        length/tile split — the core flash-decoding invariant."""
        d, s_len = 64, 384
        q = jnp.asarray(rng(seed).normal(size=(1, 2, d)), jnp.float32)
        k = jnp.asarray(rng(seed + 1).normal(size=(1, 2, s_len, d)), jnp.float32)
        v = jnp.asarray(rng(seed + 2).normal(size=(1, 2, s_len, d)), jnp.float32)
        got = fd_ops.decode_attention(q, k, v, jnp.int32(length), jnp.float32(1.0), block_s=128)
        want = flash_decode_ref(q.reshape(1, 2, 1, d), k, v, length).reshape(1, 2, d)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)
