"""C3 equivalence: TOM two-phase decode == stock flash-decode == dense oracle,
single-device and under shard_map over a context-sharded lane axis."""
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:                      # real hypothesis when installed (CI does)
    from hypothesis import given, settings
    import hypothesis.strategies as st
except ImportError:       # deterministic fallback — properties never skip
    from repro.testing.hypothesis_compat import given, settings, st  # noqa: E402

from repro.core import attention as CA

jax.config.update("jax_enable_x64", False)


def _qkv(seed, b=2, h=4, s=128, d=32):
    r = np.random.default_rng(seed)
    q = jnp.asarray(r.normal(size=(b, h, d)), jnp.float32)
    k = jnp.asarray(r.normal(size=(b, h, s, d)), jnp.float32)
    v = jnp.asarray(r.normal(size=(b, h, s, d)), jnp.float32)
    return q, k, v


class TestSingleDeviceEquivalence:
    def test_tom_equals_dense(self):
        q, k, v = _qkv(0)
        ref = CA.dense_decode_attention(q, k, v)
        out = CA.tom_flash_decode(q, k, v, axis_name=None)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)

    def test_stock_equals_dense(self):
        q, k, v = _qkv(1)
        ref = CA.dense_decode_attention(q, k, v)
        out = CA.stock_flash_decode(q, k, v, axis_name=None)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)

    def test_chunked_equals_dense(self):
        q, k, v = _qkv(2, s=96)
        ref = CA.dense_decode_attention(q, k, v)
        out = CA.chunked_flash_decode(q, k, v, chunk=32)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)

    @settings(deadline=None, max_examples=10)
    @given(seed=st.integers(0, 1000))
    def test_property_tom_vs_stock(self, seed):
        q, k, v = _qkv(seed, b=1, h=2, s=64, d=16)
        a = CA.tom_flash_decode(q, k, v, axis_name=None)
        b = CA.stock_flash_decode(q, k, v, axis_name=None)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5)

    def test_masked(self):
        q, k, v = _qkv(3)
        mask = jnp.arange(128)[None, :] < 77
        mask = jnp.broadcast_to(mask, (2, 128))
        ref = CA.dense_decode_attention(q, k, v, mask=mask)
        out = CA.tom_flash_decode(q, k, v, axis_name=None, mask_local=mask)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)

    def test_gqa_wrapper(self):
        r = np.random.default_rng(4)
        q = jnp.asarray(r.normal(size=(2, 8, 16)), jnp.float32)   # Hq=8
        k = jnp.asarray(r.normal(size=(2, 2, 64, 16)), jnp.float32)  # Hkv=2
        v = jnp.asarray(r.normal(size=(2, 2, 64, 16)), jnp.float32)
        out = CA.gqa_decode(q, k, v, axis_name=None, variant="tom")
        # oracle: expand kv heads
        ke = jnp.repeat(k, 4, axis=1)
        ve = jnp.repeat(v, 4, axis=1)
        ref = CA.dense_decode_attention(q, ke, ve)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)


_SHARDMAP_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from functools import partial
    from jax.sharding import Mesh, PartitionSpec as P
    from jax.experimental.shard_map import shard_map
    from repro.core import attention as CA

    mesh = jax.make_mesh((8,), ("model",))
    r = np.random.default_rng(0)
    b, h, s, d = 2, 4, 128, 32
    q = jnp.asarray(r.normal(size=(b, h, d)), jnp.float32)
    k = jnp.asarray(r.normal(size=(b, h, s, d)), jnp.float32)
    v = jnp.asarray(r.normal(size=(b, h, s, d)), jnp.float32)
    ref = CA.dense_decode_attention(q, k, v)

    for variant, fn in (("tom", CA.tom_flash_decode),
                        ("stock", CA.stock_flash_decode)):
        sharded = shard_map(
            partial(fn, axis_name="model"),
            mesh=mesh,
            in_specs=(P(), P(None, None, "model", None), P(None, None, "model", None)),
            out_specs=P(),
        )
        out = jax.jit(sharded)(q, k, v)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)
        print(variant, "OK")
""")


class TestShardMapLanes:
    @pytest.mark.slow
    def test_two_phase_over_8_lanes(self):
        """The paper's dataflow with the KV cache context-sharded across 8
        lanes; the reduction tree is psum/pmax. Runs in a subprocess so the
        8-device XLA flag doesn't leak into this process."""
        res = subprocess.run(
            [sys.executable, "-c", _SHARDMAP_SCRIPT],
            capture_output=True, text=True, timeout=600,
            env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                 "HOME": "/root"},
            cwd=str(__import__("pathlib").Path(__file__).resolve().parents[1]))
        assert res.returncode == 0, res.stderr[-2000:]
        assert "tom OK" in res.stdout and "stock OK" in res.stdout
