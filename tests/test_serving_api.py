"""Unified serving API: RequestSpec/SamplingParams + KVBackend protocol.

Covers the PR-3 acceptance bar: deprecation shims for the old kwarg/string
interfaces (with the deadline-unit fix), the top-p sampler (bit-identical to
the old sampler at top_p=1.0), per-request seeded sampling streams, a
dense↔paged token-identity matrix over {greedy, top-k, top-p} × {adapter,
no adapter} through the KVBackend API, and an interpret-mode proof that
block tables reach the Pallas `paged_flash_decode` kernel from
`Model.decode_step`."""
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.launch.train import reduce_config
from repro.models.transformer import Model
from repro.serving import (DenseKV, PagedKV, RequestSpec, SamplingParams,
                           ServeEngine)
from repro.serving.adapters import (AdapterRegistry, AdapterServing,
                                    AdapterSpec, synthetic_adapter_stacks)
from repro.serving.gateway import Gateway

jax.config.update("jax_enable_x64", False)

NEG_INF = -1e30
ADAPTER_SPEC = AdapterSpec(rank=8, alpha=16.0, targets=("q", "v"))


@pytest.fixture(scope="module")
def model_params():
    cfg = reduce_config(get_config("bitnet-2b"), "tiny")
    model = Model(cfg, mode="serve")
    return model, model.init(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def registry(model_params):
    model, _ = model_params
    reg = AdapterRegistry(ADAPTER_SPEC)
    rng = np.random.default_rng(11)
    for i in range(2):
        reg.register(f"tenant-{i}",
                     synthetic_adapter_stacks(rng, model.cfg, ADAPTER_SPEC,
                                              model.cfg.num_layers, scale=0.05))
    return reg


def _adapters(model, registry):
    nbytes = registry.get("tenant-0").nbytes
    return AdapterServing(model, registry, budget_bytes=nbytes * 2,
                          max_resident=2)


# ---------------------------------------------------------------------------
# Deprecation shims + the deadline-unit fix
# ---------------------------------------------------------------------------


class TestDeprecationShims:
    def test_engine_legacy_kwargs_warn_and_work(self, model_params):
        model, params = model_params
        eng = ServeEngine(model, params, max_slots=1, max_len=64)
        with pytest.warns(DeprecationWarning):
            r = eng.submit([1, 2, 3], max_new_tokens=4, temperature=0.5,
                           top_k=7, priority=2)
        assert (r.max_new_tokens, r.temperature, r.top_k, r.priority) \
            == (4, 0.5, 7, 2)
        eng.run_until_drained()
        assert r.state == "done" and len(r.output) == 4

    def test_gateway_legacy_kwargs_warn(self, model_params):
        model, params = model_params
        gw = Gateway(ServeEngine(model, params, max_slots=1, max_len=64))
        with pytest.warns(DeprecationWarning):
            r = gw.submit([1, 2], max_new_tokens=3, deadline_ms=60_000.0)
        assert r.deadline_s == pytest.approx(time.time() + 60.0, abs=1.0)
        gw.run_until_drained()
        assert r.state == "done"

    def test_kv_string_warns_and_matches_backend(self, model_params):
        model, params = model_params
        with pytest.warns(DeprecationWarning):
            legacy = ServeEngine(model, params, max_slots=2, max_len=64,
                                 kv="paged", page=8)
        new = ServeEngine(model, params, max_slots=2, max_len=64,
                          kv=PagedKV(page=8))
        outs = []
        for eng in (legacy, new):
            r = eng.submit([3, 4, 5], RequestSpec(max_new_tokens=5))
            eng.run_until_drained()
            outs.append(r.output)
        assert outs[0] == outs[1]
        assert legacy.kv_mode == new.kv_mode == "paged"

    def test_new_api_does_not_warn(self, model_params, recwarn):
        model, params = model_params
        eng = ServeEngine(model, params, max_slots=1, max_len=64)
        eng.submit([1, 2], RequestSpec(max_new_tokens=2),
                   SamplingParams(temperature=0.3))
        assert not [w for w in recwarn.list
                    if issubclass(w.category, DeprecationWarning)]

    def test_mixing_spec_and_legacy_rejected(self, model_params):
        model, params = model_params
        eng = ServeEngine(model, params, max_slots=1, max_len=64)
        with pytest.raises(TypeError):
            eng.submit([1], RequestSpec(), max_new_tokens=4)
        with pytest.raises(TypeError):
            eng.submit([1], bogus_kwarg=1)

    def test_deadline_units_unified(self, model_params):
        """The historical Gateway(deadline_ms, relative) vs
        ServeEngine(deadline_s, absolute) split resolves to one field:
        RequestSpec.deadline_ms, relative to submit. All four entry points
        must produce the same absolute scheduler deadline."""
        model, params = model_params
        gw = Gateway(ServeEngine(model, params, max_slots=1, max_len=64))
        eng = ServeEngine(model, params, max_slots=1, max_len=64)
        now = time.time()
        spec = RequestSpec(max_new_tokens=1, deadline_ms=30_000.0)
        reqs = [gw.submit([1], spec), eng.submit([1], spec)]
        with pytest.warns(DeprecationWarning):
            reqs.append(gw.submit([1], max_new_tokens=1, deadline_ms=30_000.0))
        with pytest.warns(DeprecationWarning):
            reqs.append(eng.submit([1], max_new_tokens=1,
                                   deadline_s=now + 30.0))
        for r in reqs:
            assert r.deadline_s == pytest.approx(now + 30.0, abs=1.0)


# ---------------------------------------------------------------------------
# Sampling: top-p golden vs the old sampler, behaviour, seeded streams
# ---------------------------------------------------------------------------


def _old_sample(logits, key, temperature, top_k):
    """The pre-top-p jitted sampler, verbatim (the golden reference)."""
    greedy = jnp.argmax(logits, axis=-1)
    vocab = logits.shape[-1]
    sorted_desc = -jnp.sort(-logits, axis=-1)
    k_idx = jnp.clip(top_k - 1, 0, vocab - 1)
    thresh = jnp.take_along_axis(sorted_desc, k_idx[:, None], axis=-1)
    masked = jnp.where((top_k[:, None] > 0) & (logits < thresh),
                       NEG_INF, logits)
    scaled = masked / jnp.maximum(temperature[:, None], 1e-6)
    sampled = jax.random.categorical(key, scaled, axis=-1)
    use_greedy = temperature <= 0.0
    return jnp.where(use_greedy, greedy, sampled).astype(jnp.int32)


class TestSampling:
    def test_top_p_one_bit_identical_to_old_path(self, model_params):
        """Golden: with top_p=1.0 and no seeds the new sampler's draws are
        bit-identical to the historical temperature/top-k sampler."""
        model, params = model_params
        eng = ServeEngine(model, params, max_slots=4, max_len=64)
        rng = np.random.default_rng(0)
        b, v = 4, 64
        temps = jnp.asarray([0.0, 0.7, 1.3, 5.0], jnp.float32)
        topks = jnp.asarray([0, 3, 0, 10], jnp.int32)
        topps = jnp.ones((b,), jnp.float32)
        seeds = jnp.zeros((b,), jnp.int32)
        has_seed = jnp.zeros((b,), bool)
        steps = jnp.zeros((b,), jnp.int32)
        key = jax.random.PRNGKey(42)
        for _ in range(30):
            key, sub = jax.random.split(key)
            logits = jnp.asarray(rng.normal(size=(b, v)) * 3.0, jnp.float32)
            new = eng._sample(logits, sub, temps, topks, topps, seeds,
                              has_seed, steps)
            old = _old_sample(logits, sub, temps, topks)
            np.testing.assert_array_equal(np.asarray(new), np.asarray(old))

    def test_top_p_restricts_support(self, model_params):
        """With one token holding > top_p of the mass, nucleus sampling must
        always return it; the unrestricted slot keeps sampling freely."""
        model, params = model_params
        eng = ServeEngine(model, params, max_slots=2, max_len=64)
        logits = np.zeros((2, 32), np.float32)
        logits[:, 5] = 6.0                 # softmax(6 vs 0) ≈ 0.93 at T=1
        logits = jnp.asarray(logits)
        temps = jnp.asarray([1.0, 1.0], jnp.float32)
        topks = jnp.zeros((2,), jnp.int32)
        topps = jnp.asarray([0.5, 1.0], jnp.float32)
        aux = (jnp.zeros((2,), jnp.int32), jnp.zeros((2,), bool),
               jnp.zeros((2,), jnp.int32))
        key = jax.random.PRNGKey(0)
        seen0, seen1 = set(), set()
        for _ in range(60):
            key, sub = jax.random.split(key)
            t = np.asarray(eng._sample(logits, sub, temps, topks, topps, *aux))
            seen0.add(int(t[0]))
            seen1.add(int(t[1]))
        assert seen0 == {5}, "top_p=0.5 must pin the dominant token"
        assert len(seen1) > 1, "top_p=1.0 must keep the full support"

    def test_seeded_stream_reproducible_across_batches(self, model_params):
        """A seeded request's sampled tokens depend only on (seed, step):
        identical alone or co-scheduled with other traffic."""
        model, params = model_params
        spec = RequestSpec(max_new_tokens=6)
        sampling = SamplingParams(temperature=0.9, seed=123)
        solo = ServeEngine(model, params, max_slots=3, max_len=64, seed=0)
        a = solo.submit([5, 6, 7], spec, sampling)
        solo.run_until_drained()

        busy = ServeEngine(model, params, max_slots=3, max_len=64, seed=9)
        rng = np.random.default_rng(2)
        for _ in range(2):
            busy.submit(list(rng.integers(0, 100, size=6)),
                        RequestSpec(max_new_tokens=8),
                        SamplingParams(temperature=1.1))
        b = busy.submit([5, 6, 7], spec, sampling)
        busy.run_until_drained()
        assert a.output == b.output

    def test_sampling_params_validation(self):
        with pytest.raises(ValueError):
            SamplingParams(top_p=0.0)
        with pytest.raises(ValueError):
            SamplingParams(top_p=1.5)
        with pytest.raises(ValueError):
            SamplingParams(top_k=-1)
        with pytest.raises(ValueError):
            SamplingParams(seed=2**31)      # must fit the int32 sampler lane
        SamplingParams(seed=-2**31)         # boundary ok


# ---------------------------------------------------------------------------
# Dense ↔ paged token identity through the KVBackend protocol
# ---------------------------------------------------------------------------


SAMPLERS = {
    "greedy": SamplingParams(),
    "topk": SamplingParams(temperature=0.8, top_k=5),
    "topp": SamplingParams(temperature=0.8, top_p=0.7),
}


class TestDensePagedMatrix:
    @pytest.mark.parametrize("sampler", sorted(SAMPLERS))
    @pytest.mark.parametrize("adapter", [None, "tenant-0"])
    def test_token_identity(self, model_params, registry, sampler, adapter):
        """Acceptance: DenseKV and PagedKV produce token-identical outputs
        through the one shared engine tick path, for {greedy, top-k, top-p}
        × {adapter, no adapter}. Sampling runs draw from the same engine key
        stream, so identical logits ⇒ identical tokens."""
        model, params = model_params
        sampling = SAMPLERS[sampler]
        rng = np.random.default_rng(4)
        prompts = [list(rng.integers(0, 100, size=int(rng.integers(3, 12))))
                   for _ in range(5)]
        outs = {}
        for name, make in (("dense", DenseKV), ("paged",
                                                lambda: PagedKV(page=8))):
            ad = _adapters(model, registry) if adapter else None
            eng = ServeEngine(model, params, max_slots=3, max_len=64,
                              kv=make(), seed=7, adapters=ad)
            reqs = [eng.submit(p, RequestSpec(max_new_tokens=6,
                                              adapter_id=adapter), sampling)
                    for p in prompts]
            stats = eng.run_until_drained()
            assert stats.completed == len(prompts)
            outs[name] = [r.output for r in reqs]
        assert outs["dense"] == outs["paged"]


# ---------------------------------------------------------------------------
# Block tables reach paged_flash_decode from Model.decode_step
# ---------------------------------------------------------------------------


class TestPagedKernelPath:
    def _mid_run_state(self, model, params):
        eng = ServeEngine(model, params, max_slots=2, max_len=64,
                          kv=PagedKV(page=8))
        r = eng.submit(list(range(5, 15)), RequestSpec(max_new_tokens=8))
        for _ in range(12):
            eng.tick()
        assert r.state == "running" and r.output
        state = eng.kv.decode_state([0], eng.pos)
        tokens = jnp.asarray(np.asarray([r.output[-1], 0], np.int32))
        return state, tokens, jnp.asarray(eng.pos), eng.pool.scratch_page

    def test_block_tables_reach_kernel(self, model_params, monkeypatch):
        """Interpret-mode acceptance: with paged_attn='kernel',
        Model.decode_step drives `paged_flash_decode` (per layer, block
        tables + live lengths via scalar prefetch) and its logits match the
        XLA gather reference."""
        from repro.kernels.flash_decode import ops as fd_ops
        model, params = model_params
        state, tokens, pos, scratch = self._mid_run_state(model, params)

        calls = []
        real = fd_ops.paged_decode_attention

        def spy(q, k_pool, v_pool, tables, lengths, *a, **kw):
            calls.append({"tables": tables.shape, "kernel": kw.get("use_kernel"),
                          "interpret": kw.get("interpret")})
            return real(q, k_pool, v_pool, tables, lengths, *a, **kw)

        monkeypatch.setattr(fd_ops, "paged_decode_attention", spy)
        logits_gather, new_g = model.decode_step(params, state, tokens, pos)
        assert not calls, "gather reference must not call the paged kernel op"

        kernel_model = dataclasses.replace(model, paged_attn="kernel")
        logits_kernel, new_k = kernel_model.decode_step(params, state, tokens,
                                                        pos)
        assert calls, "block tables never reached paged_decode_attention"
        assert all(c["kernel"] and c["interpret"] for c in calls)
        assert all(c["tables"] == tuple(state.tables.shape) for c in calls)
        # slot 0 is the live request; slot 1 is inactive (its row attends
        # the scratch page — garbage by contract, discarded by the engine)
        np.testing.assert_allclose(np.asarray(logits_kernel)[0],
                                   np.asarray(logits_gather)[0],
                                   rtol=2e-4, atol=2e-4)
        # both paths write the token into the same (non-scratch) pages
        d = jnp.abs(new_g.k_pool.astype(jnp.float32)
                    - new_k.k_pool.astype(jnp.float32))
        per_page = np.asarray(jnp.max(d, axis=(0, 2, 3, 4)))
        assert list(np.nonzero(per_page)[0]) in ([], [scratch])

    def test_engine_runs_forced_kernel_end_to_end(self, model_params):
        """The whole engine tick path works with the kernel dispatch (the
        TPU configuration, interpreted on CPU) and matches the gather path's
        greedy tokens."""
        model, params = model_params
        prompts = [list(range(3, 9)), list(range(40, 44))]
        outs = {}
        for name, m in (("gather", model),
                        ("kernel", dataclasses.replace(model,
                                                       paged_attn="kernel"))):
            eng = ServeEngine(m, params, max_slots=2, max_len=64,
                              kv=PagedKV(page=8))
            reqs = [eng.submit(p, RequestSpec(max_new_tokens=4))
                    for p in prompts]
            eng.run_until_drained()
            outs[name] = [r.output for r in reqs]
        assert outs["gather"] == outs["kernel"]

    def test_dense_backend_never_builds_paged_state(self, model_params):
        """DenseKV hands decode_step the plain dict cache (no block tables,
        no page accounting)."""
        model, params = model_params
        eng = ServeEngine(model, params, max_slots=2, max_len=64, kv=DenseKV())
        state = eng.kv.decode_state([0], eng.pos)
        assert isinstance(state, dict) and set(state) == {"k", "v"}
        assert eng.kv.pages_for(1000) == 0
        assert eng.pool is None
