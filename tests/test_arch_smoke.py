"""Per-architecture smoke tests (deliverable f).

Every assigned architecture instantiates a REDUCED config of its own family
(same topology: MoE routing, MLA latents, SSD recurrence, hybrid pattern,
frontend stubs) and runs:

  * one forward/train step on CPU — finite loss, finite grads;
  * one serve-mode decode step against a KV/state cache — correct logits
    shape, no NaNs.

The FULL configs are exercised via the dry-run only (ShapeDtypeStructs).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCH_IDS, get_config
from repro.launch.train import reduce_config
from repro.models.transformer import Model
from repro.optim import AdamW, constant

jax.config.update("jax_enable_x64", False)

ALL_ARCHS = list(ARCH_IDS) + ["bitnet-2b"]


def _batch_for(cfg, b=2, s=32, seed=0):
    r = np.random.default_rng(seed)
    batch = {"labels": jnp.asarray(r.integers(0, cfg.vocab_size, (b, s)), jnp.int32)}
    if cfg.family in ("vlm", "audio"):
        batch["embeds"] = jnp.asarray(r.normal(size=(b, s, cfg.d_model)),
                                      jnp.bfloat16)
    else:
        batch["tokens"] = jnp.asarray(r.integers(0, cfg.vocab_size, (b, s)),
                                      jnp.int32)
    return batch


@pytest.fixture(scope="module", params=ALL_ARCHS)
def arch(request):
    return request.param


class TestTrainStep:
    def test_forward_loss_finite(self, arch):
        cfg = reduce_config(get_config(arch), "tiny")
        model = Model(cfg, mode="qat", remat=False)
        params = model.init(jax.random.PRNGKey(0))
        loss, aux = jax.jit(model.loss_fn)(params, _batch_for(cfg))
        assert loss.shape == ()
        assert np.isfinite(float(loss)), f"{arch}: non-finite loss"
        assert float(loss) > 0

    def test_one_train_step_updates_and_stays_finite(self, arch):
        cfg = reduce_config(get_config(arch), "tiny")
        model = Model(cfg, mode="qat", remat=False)
        opt = AdamW(schedule=constant(1e-3))
        params = model.init(jax.random.PRNGKey(0))
        state = opt.init(params)
        batch = _batch_for(cfg)

        @jax.jit
        def step(p, st):
            (loss, _), g = jax.value_and_grad(model.loss_fn, has_aux=True)(p, batch)
            p2, st2, m = opt.update(g, st, p)
            return p2, st2, loss, m["grad_norm"]

        p2, st2, loss, gnorm = step(params, state)
        assert np.isfinite(float(loss)) and np.isfinite(float(gnorm))
        assert float(gnorm) > 0, f"{arch}: zero gradient"
        # at least one parameter leaf actually moved
        moved = any(
            not np.array_equal(np.asarray(a), np.asarray(b))
            for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2))
            if hasattr(a, "dtype") and jnp.issubdtype(a.dtype, jnp.floating))
        assert moved, f"{arch}: no parameter moved"


class TestDecodeStep:
    def test_decode_shapes_and_finite(self, arch):
        cfg = reduce_config(get_config(arch), "tiny")
        model = Model(cfg, mode="serve")
        params = model.init(jax.random.PRNGKey(1))
        b, max_len = 2, 16
        cache = model.init_cache(b, max_len)
        if cfg.family in ("vlm", "audio"):
            tok = jnp.zeros((b, cfg.d_model), jnp.bfloat16)
        else:
            tok = jnp.asarray([1, 2], jnp.int32)
        step = jax.jit(model.decode_step)
        for pos in range(3):
            logits, cache = step(params, cache, tok, jnp.asarray(pos, jnp.int32))
            assert logits.shape == (b, cfg.vocab_padded)
            assert np.isfinite(np.asarray(logits)).all(), f"{arch}: NaN logits"
            if cfg.family not in ("vlm", "audio"):
                tok = jnp.argmax(logits, -1).astype(jnp.int32)

    def test_decode_matches_prefill_last_logits(self, arch):
        """Token-by-token decode and batched prefill agree on the final
        next-token distribution (attention archs; SSM prefill fills no state)."""
        cfg = reduce_config(get_config(arch), "tiny")
        if cfg.family in ("ssm", "hybrid", "vlm", "audio"):
            pytest.skip("prefill-vs-decode equivalence is attention/token-only")
        model = Model(cfg, mode="serve")
        params = model.init(jax.random.PRNGKey(2))
        toks = np.asarray([[3, 1, 4, 1, 5, 9, 2, 6]], np.int32)
        max_len = 16

        logits_p, _ = jax.jit(lambda p, b: model.prefill(p, b, max_len))(
            params, {"tokens": jnp.asarray(toks)})

        cache = model.init_cache(1, max_len)
        step = jax.jit(model.decode_step)
        for pos in range(toks.shape[1]):
            logits_d, cache = step(params, cache, jnp.asarray(toks[:, pos]),
                                   jnp.asarray(pos, jnp.int32))
        # prefill attends with pre-quantization K/V while decode reads the
        # fp8 cache → quantization skew compounds with depth; the invariant
        # is strong agreement of the next-token distribution, not equality.
        lp, ld = np.asarray(logits_p), np.asarray(logits_d)
        assert np.isfinite(lp[lp > -1e29]).all() and np.isfinite(ld[ld > -1e29]).all()
        corr = np.corrcoef(lp.ravel(), ld.ravel())[0, 1]
        assert corr > 0.95, f"{arch}: prefill/decode logits corr {corr:.4f}"


class TestQLoRAMode:
    def test_adapters_exist_and_train(self, arch):
        cfg = reduce_config(get_config(arch), "tiny")
        if cfg.lora is None:
            pytest.skip("no lora config")
        from repro.optim import combine, partition, trainable_mask
        model = Model(cfg, mode="qlora", remat=False)
        params = model.init(jax.random.PRNGKey(3))
        mask = trainable_mask(params, "qlora")
        n_train = sum(bool(m) for m in jax.tree.leaves(mask))
        assert n_train > 0, f"{arch}: no adapter leaves"
        tp, fp = partition(params, mask)
        batch = _batch_for(cfg)
        g = jax.jit(jax.grad(
            lambda t: model.loss_fn(combine(t, fp), batch)[0]))(tp)
        gnorm = sum(float(jnp.sum(x.astype(jnp.float32) ** 2))
                    for x in jax.tree.leaves(g)) ** 0.5
        assert np.isfinite(gnorm) and gnorm > 0, f"{arch}: dead adapter grads"
