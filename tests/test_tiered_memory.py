"""Tiered memory hierarchy (device → host → disk) tests.

Covers the `repro.serving.memory.TieredStore` contract (budgets, cost-model
eviction, demote cascade, counters, drain, self-verify), disk crash safety
(a truncated spill file degrades to a miss, never corruption), the
AdapterCache demote/host-hit path, the re-admit identity matrix
``{DenseKV, PagedKV} × {adapter, none}`` — re-admitted prefix KV must be
**bit-identical** to freshly prefilled KV and produce token-identical
output — and the train → freeze → register deployment round trip
(`repro.serving.adapters.from_checkpoint`).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.launch.train import reduce_config
from repro.models.transformer import Model
from repro.serving import (DenseKV, PagedKV, RequestSpec, ServeEngine,
                           TieredStore)
from repro.serving.adapters import (AdapterRegistry, AdapterServing,
                                    AdapterSpec, lora_stacks_from_params,
                                    register_from_checkpoint,
                                    register_from_params,
                                    synthetic_adapter_stacks)
from repro.serving.adapters.registry import TARGET_GROUP
from repro.serving.gateway import Gateway

jax.config.update("jax_enable_x64", False)

SPEC = AdapterSpec(rank=4, alpha=8.0, targets=("q", "v"))
PROMPT = [7, 3, 11, 2, 9, 1, 4, 8, 5, 12, 6, 10, 13, 14, 15, 0, 2, 5, 3]
PAGE = 4


@pytest.fixture(scope="module")
def model_params():
    cfg = reduce_config(get_config("bitnet-2b"), "tiny")
    model = Model(cfg, mode="serve")
    return model, model.init(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def registry(model_params):
    model, _ = model_params
    reg = AdapterRegistry(SPEC)
    rng = np.random.default_rng(11)
    for i in range(2):
        reg.register(f"tenant-{i}",
                     synthetic_adapter_stacks(rng, model.cfg, SPEC,
                                              model.cfg.num_layers,
                                              scale=0.05))
    return reg


def _payload(nbytes, seed=0, dtype=np.uint8):
    rng = np.random.default_rng(seed)
    n = nbytes // np.dtype(dtype).itemsize
    return {"x": rng.integers(0, 200, size=n).astype(dtype)}


def _payload_bytes(p):
    return {k: np.asarray(v).tobytes() for k, v in sorted(p.items())}


class TestTieredStore:
    def test_device_host_round_trip_and_counters(self):
        store = TieredStore(host_budget_bytes=1 << 20)
        pay = {"a": np.arange(16, dtype=np.float32),
               "b": np.ones(8, np.int8)}
        store.note_device("k", 128)
        assert store.tier_of("k") == "device"
        assert store.tier_bytes("device") == 128
        store.demote("k", pay)
        assert store.tier_of("k") == "host"
        assert store.tier_bytes("device") == 0
        got = store.take("k")
        np.testing.assert_array_equal(got["a"], pay["a"])
        np.testing.assert_array_equal(got["b"], pay["b"])
        assert store.tier_of("k") is None
        st = store.stats()
        assert st["demotes"] == 1 and st["promotes"] == 1
        assert st["tier_hits"]["host"] == 1 and st["misses"] == 0
        assert store.get("gone") is None and store.stats()["misses"] == 1
        assert store.verify() == []

    def test_eviction_prefers_stale_cheap_entries(self):
        # score = remat_cost × 1/(1+age) ÷ nbytes; the victim is the
        # minimum — stale entries that are cheap to rebuild go first,
        # recently-touched / expensive entries survive
        store = TieredStore(host_budget_bytes=3 * 1024)
        store.put("cheap-stale", _payload(1024, 1), remat_cost=1.0)
        store.put("pricey", _payload(1024, 2), remat_cost=100.0)
        store.put("cheap-hot", _payload(1024, 3), remat_cost=1.0)
        assert store.get("cheap-hot") is not None      # touch: now hottest
        store.put("new", _payload(1024, 4), remat_cost=1.0)  # forces 1 evict
        assert store.tier_of("cheap-stale") is None    # no disk: evicted
        assert store.tier_of("pricey") == "host"
        assert store.tier_of("cheap-hot") == "host"
        assert store.stats()["evictions"] == 1
        assert store.verify() == []

    def test_demote_cascades_host_to_disk(self, tmp_path):
        store = TieredStore(host_budget_bytes=1024,
                            disk_budget_bytes=2048,
                            disk_dir=str(tmp_path))
        for i in range(3):
            store.put(f"k{i}", _payload(1024, i))
        assert store.tier_of("k2") == "host"           # newest stays up
        assert store.tier_of("k0") == "disk"
        assert store.tier_of("k1") == "disk"
        assert store.tier_bytes("disk") == 2048
        got = store.take("k0")                         # disk read-back
        np.testing.assert_array_equal(got["x"], _payload(1024, 0)["x"])
        assert store.verify() == []
        store.drain()
        assert store.tier_bytes("host") == 0 and store.tier_bytes("disk") == 0
        assert not list(tmp_path.iterdir())

    def test_exotic_dtype_disk_round_trip(self, tmp_path):
        ml_dtypes = pytest.importorskip("ml_dtypes")
        fp8 = np.dtype(ml_dtypes.float8_e4m3fn)
        store = TieredStore(host_budget_bytes=16,      # too small: straight
                            disk_budget_bytes=1 << 20,  # to disk
                            disk_dir=str(tmp_path))
        raw = np.arange(64, dtype=np.uint8).view(fp8)
        store.put("fp8", {"k": raw, "bf16": np.ones(4, ml_dtypes.bfloat16)})
        assert store.tier_of("fp8") == "disk"
        got = store.take("fp8")
        assert got["k"].dtype == fp8
        np.testing.assert_array_equal(got["k"].view(np.uint8),
                                      raw.view(np.uint8))
        assert got["bf16"].dtype == np.dtype(ml_dtypes.bfloat16)

    def test_truncated_disk_file_degrades_to_miss(self, tmp_path):
        store = TieredStore(host_budget_bytes=16,
                            disk_budget_bytes=1 << 20,
                            disk_dir=str(tmp_path))
        store.put("victim", _payload(4096, 9))
        assert store.tier_of("victim") == "disk"
        files = list(tmp_path.iterdir())
        assert len(files) == 1
        files[0].write_bytes(files[0].read_bytes()[:40])   # crash mid-write
        assert store.get("victim") is None                 # miss, no raise
        assert store.stats()["disk_corrupt"] == 1
        assert "victim" not in store
        assert store.verify() == []

    def test_corrupted_disk_payload_fails_crc(self, tmp_path):
        store = TieredStore(host_budget_bytes=16,
                            disk_budget_bytes=1 << 20,
                            disk_dir=str(tmp_path))
        store.put("victim", _payload(4096, 9))
        f = list(tmp_path.iterdir())[0]
        blob = bytearray(f.read_bytes())
        blob[-1] ^= 0xFF                                   # flip a data byte
        f.write_bytes(bytes(blob))
        assert store.get("victim") is None
        assert store.stats()["disk_corrupt"] == 1


class TestAdapterCacheTiering:
    def test_evicted_adapter_demotes_and_readmits_from_host(
            self, model_params, registry):
        model, _ = model_params
        nbytes = registry.get("tenant-0").nbytes
        adapters = AdapterServing(model, registry, budget_bytes=nbytes,
                                  max_resident=1)
        store = TieredStore(host_budget_bytes=8 << 20)
        adapters.attach_tiered(store)
        _, key0 = adapters.acquire_versioned("tenant-0")
        adapters.release_key(key0)
        _, key1 = adapters.acquire_versioned("tenant-1")   # evicts tenant-0
        assert store.tier_of("adapter:" + key0) == "host"
        adapters.release_key(key1)
        slot2, key2 = adapters.acquire_versioned("tenant-0")  # host hit
        assert key2 == key0
        assert store.stats()["promotes"] == 1
        assert store.tier_of("adapter:" + key0) == "device"
        # the re-uploaded device stacks are the registry's packs, bit-exact
        ent = registry.get("tenant-0")
        for t in SPEC.targets:
            pk = ent.packs[t]
            np.testing.assert_array_equal(
                np.asarray(adapters.pack[t]["a"][:, slot2]), pk["a_codes"])
            np.testing.assert_array_equal(
                np.asarray(adapters.pack[t]["b"][:, slot2]), pk["b_codes"])
            np.testing.assert_allclose(
                np.asarray(adapters.pack[t]["s"][:, slot2]),
                pk["a_scale"] * pk["b_scale"] * np.float32(SPEC.scaling),
                rtol=1e-6)
        adapters.release_key(key2)


def _run(gw, prompt, adapter_id=None, max_new=4):
    req = gw.submit(list(prompt), RequestSpec(max_new_tokens=max_new,
                                              adapter_id=adapter_id))
    gw.run_until_drained()
    assert req.state == "done", req.state
    return list(req.output)


def _trie_bytes(eng):
    """{trie key: raw page bytes} — the bit-identity ground truth."""
    out = {}
    for key, node in eng.prefix.nodes.items():
        p = eng.kv.export_page(node.page_id)
        out[key] = (np.asarray(p["k"]).tobytes(), np.asarray(p["v"]).tobytes())
    return out


class TestReadmitIdentity:
    """{DenseKV, PagedKV} × {adapter, none}: spill → re-admit must be
    bit-identical to freshly prefilled KV and token-identical in output."""

    @pytest.mark.parametrize("adapter", [None, "tenant-0"])
    def test_paged_readmit_bit_identical(self, model_params, registry,
                                         adapter):
        model, params = model_params
        nbytes = registry.get("tenant-0").nbytes

        def mk(tiered):
            adapters = None
            if adapter is not None:
                adapters = AdapterServing(model, registry,
                                          budget_bytes=2 * nbytes,
                                          max_resident=2)
            return ServeEngine(model, params, max_slots=2, max_len=64,
                               prefill="batched",
                               kv=PagedKV(page=PAGE, n_pages=24),
                               prefix_cache=True, tiered=tiered,
                               adapters=adapters)

        store = TieredStore(host_budget_bytes=32 << 20)
        eng = mk(store)
        gw = Gateway(eng)
        out1 = _run(gw, PROMPT, adapter)
        pages1 = _trie_bytes(eng)
        assert pages1, "first run committed no prefix pages"
        eng._evict_prefix(len(eng.prefix.nodes))       # force full spill
        assert not eng.prefix.nodes
        assert eng.stats.kv_spilled_pages == len(pages1)
        out2 = _run(gw, PROMPT, adapter)               # re-admits from host
        assert eng.stats.prefix_readmits > 0
        assert out2 == out1
        pages2 = _trie_bytes(eng)
        for key, blob in pages1.items():
            assert pages2[key] == blob, f"re-admitted page {key} not " \
                                        "bit-identical to the spilled copy"
        # against an engine that never tiered: same tokens, same page bytes
        eng3 = mk(None)
        out3 = _run(Gateway(eng3), PROMPT, adapter)
        assert out3 == out1
        pages3 = _trie_bytes(eng3)
        for key, blob in pages1.items():
            assert pages3[key] == blob, f"page {key} differs from a fresh " \
                                        "uncached prefill"
        assert store.verify() == []

    @pytest.mark.parametrize("adapter", [None, "tenant-0"])
    def test_dense_readmit_identity(self, model_params, registry, adapter):
        model, params = model_params
        nbytes = registry.get("tenant-0").nbytes

        def mk(tiered, with_adapters=adapter is not None):
            adapters = None
            if with_adapters:
                adapters = AdapterServing(model, registry,
                                          budget_bytes=2 * nbytes,
                                          max_resident=2)
            return ServeEngine(model, params, max_slots=2, max_len=64,
                               prefill="batched", kv=DenseKV(),
                               tiered=tiered, adapters=adapters)

        store = TieredStore(host_budget_bytes=32 << 20)
        eng = mk(store)
        gw = Gateway(eng)
        out1 = _run(gw, PROMPT, adapter)
        assert eng.stats.kv_spilled_pages >= 1         # spilled at release
        out2 = _run(gw, PROMPT, adapter)               # re-admits
        assert eng.stats.prefix_readmits >= 1
        assert eng.stats.prefix_hit_tokens > 0
        assert out2 == out1
        out3 = _run(Gateway(mk(None)), PROMPT, adapter)
        assert out3 == out1
        # bit-identity: a second engine's fresh prefill spills the same
        # bytes for the shared keys
        store2 = TieredStore(host_budget_bytes=32 << 20)
        _run(Gateway(mk(store2)), PROMPT, adapter)
        shared = set(store.keys("host")) & set(store2.keys("host"))
        assert shared, "no shared spilled entries between identical runs"
        for k in shared:
            assert _payload_bytes(store.get(k)) == \
                _payload_bytes(store2.get(k)), \
                f"spilled dense KV for {k} not bit-identical across runs"

    def test_dense_spill_is_tenant_scoped(self, model_params, registry):
        """The dense spill key is namespaced by the slot's pinned adapter
        version: a plain request must never re-admit a tenant's KV (and
        vice versa), since adapter prefill produces different KV bytes."""
        model, params = model_params
        nbytes = registry.get("tenant-0").nbytes
        adapters = AdapterServing(model, registry, budget_bytes=2 * nbytes,
                                  max_resident=2)
        store = TieredStore(host_budget_bytes=32 << 20)
        eng = ServeEngine(model, params, max_slots=2, max_len=64,
                          prefill="batched", kv=DenseKV(),
                          tiered=store, adapters=adapters)
        gw = Gateway(eng)
        out_t = _run(gw, PROMPT, "tenant-0")
        before = eng.stats.prefix_readmits
        out_p = _run(gw, PROMPT, None)                 # plain revisit
        assert eng.stats.prefix_readmits == before, \
            "plain request re-admitted a tenant's spilled KV"
        # the plain output matches an engine that never saw the tenant
        eng_ref = ServeEngine(model, params, max_slots=2, max_len=64,
                              prefill="batched", kv=DenseKV())
        assert out_p == _run(Gateway(eng_ref), PROMPT, None)
        # and the tenant's own revisit does re-admit, token-identically
        assert _run(gw, PROMPT, "tenant-0") == out_t
        assert eng.stats.prefix_readmits > before


class TestTrainFreezeRegister:
    def test_register_from_checkpoint_round_trip(self, tmp_path):
        """train → freeze → register: a qlora-mode checkpoint's LoRA leaves
        deploy into the registry with packs bit-identical to freezing the
        live tree directly."""
        cfg = reduce_config(get_config("bitnet-2b"), "tiny")
        assert cfg.lora is not None, "bitnet-2b lost its LoRA config"
        model = Model(cfg, mode="qlora", remat=False)
        params = model.init(jax.random.PRNGKey(5))
        rng = np.random.default_rng(7)
        for t in cfg.lora.targets:                 # make the freeze non-
            lora = params["layers"][TARGET_GROUP[t]][t]["lora"]   # trivial:
            for leaf in ("a", "b"):                # b inits to zeros
                lora[leaf] = jnp.asarray(
                    rng.normal(size=lora[leaf].shape).astype(np.float32)
                    * 0.1)
        from repro.ckpt import checkpoint as ckpt_mod
        ckpt_mod.save(str(tmp_path / "ck"), 3, {"params": params},
                      async_=False)
        spec = AdapterSpec(rank=cfg.lora.rank, alpha=cfg.lora.alpha,
                           targets=cfg.lora.targets)
        reg_ck, reg_live = AdapterRegistry(spec), AdapterRegistry(spec)
        params_like = jax.tree.map(np.zeros_like, params)
        ent = register_from_checkpoint(reg_ck, str(tmp_path / "ck"),
                                       "tenant-x", params_like)
        ref = register_from_params(reg_live, params, "tenant-x")
        assert ent.version == 1 and ent.nbytes == ref.nbytes
        assert ent.n_layers == cfg.num_layers
        for t in spec.targets:
            for leaf in ("a_codes", "a_scale", "b_codes", "b_scale"):
                np.testing.assert_array_equal(ent.packs[t][leaf],
                                              ref.packs[t][leaf])
        # deployed pack actually serves: loadable through the runtime
        serve_model = Model(cfg, mode="serve")
        serving = AdapterServing(serve_model, reg_ck,
                                 budget_bytes=2 * ent.nbytes, max_resident=1)
        slot, key = serving.acquire_versioned("tenant-x")
        assert key == "tenant-x@v1" and slot >= 1
        serving.release_key(key)

    def test_missing_lora_leaves_fail_loudly(self, model_params):
        _, serve_params = model_params
        spec = AdapterSpec(rank=4, alpha=8.0, targets=("q", "v"))
        with pytest.raises(KeyError, match="no trained LoRA leaves"):
            lora_stacks_from_params(serve_params, spec)

    def test_missing_checkpoint_fails_loudly(self, tmp_path):
        spec = AdapterSpec(rank=4, alpha=8.0, targets=("q",))
        reg = AdapterRegistry(spec)
        with pytest.raises(FileNotFoundError):
            register_from_checkpoint(reg, str(tmp_path / "nope"), "t", {})
