"""Seeded deterministic serving stress harness.

Random request streams — mixed prompt lengths, priorities, deadlines,
adapters, sampling params (including speculative ``spec_k`` on greedy and
seeded rows), cancels (including targeted cancels of slots whose chunked
prefill is mid-flight) — driven tick-by-tick against the full engine stack
(paged KV + prefix cache + chunked prefill + speculative decoding +
multi-tenant adapters + SLO scheduler), with structural invariants asserted
on *every tick*:

  * **no page leaks**: every pool page is owned by exactly one of
    {free list, prefix-cache trie, a slot's private table span}; shared
    lead pages always belong to the trie;
  * **pinned adapters are never evicted** while their request is in flight;
  * **EDF is never inverted within a priority class**: the scheduler hands
    out a request only if no admissible queued entry has a strictly more
    urgent (priority, deadline) key (checked by a wrapping scheduler);
  * **every stream terminates** with eos / budget / cancel / expiry — no
    zombie requests after drain, and no output ever exceeds its budget;
  * **metrics agree with ground truth**: the gateway's tokens_out counter
    equals the tokens actually emitted, the page-occupancy gauge equals the
    pool's own accounting, accept-rate / gated-bank-fraction stay in
    [0, 1] and the energy integral never decreases;
  * **SLO attribution is a ledger**: every tracked request — live,
    preempted, cancelled mid-prefill, expired or done — has non-negative
    phase components (queue_wait/prefill/decode/decode_stall/preempted)
    that sum to its wall time, every tick;
  * **tiered memory is consistent** (when a TieredStore rides along):
    every entry lives in exactly one tier, per-tier byte accounting
    matches the entries and respects budgets, device-tier KV mirrors the
    prefix trie, pinned/in-flight adapters are never demoted, and after
    ``drain()`` the host and disk tiers are empty with no files left.

The stream is generated from ``FUZZ_SEED`` (env, default 0): the fast lane
pins it, a non-blocking CI job rotates it per run. Every assertion message
carries the seed, so a red run reproduces with
``FUZZ_SEED=<n> pytest tests/test_serving_fuzz.py``.
"""
import os
import threading
import time

import jax
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.launch.train import reduce_config
from repro.models.transformer import Model
from repro.serving import (AsyncServeRuntime, DenseKV, PagedKV, RequestSpec,
                           RuntimePoisoned, SamplingParams, ServeEngine,
                           TieredStore)
from repro.serving.adapters import (AdapterRegistry, AdapterServing,
                                    AdapterSpec, synthetic_adapter_stacks)
from repro.serving.gateway import Gateway
from repro.serving.gateway.scheduler import Scheduler
from repro.serving.router import UID_STRIDE, ReplicaRouter

jax.config.update("jax_enable_x64", False)

pytestmark = pytest.mark.fuzz

SEED = int(os.environ.get("FUZZ_SEED", "0"))
TICKS = int(os.environ.get("FUZZ_TICKS", "220"))
PAGE = 4
N_PAGES = 24      # tight: 3 slots' worst case + trie overflows it → pressure
ADAPTER_SPEC = AdapterSpec(rank=4, alpha=8.0, targets=("q", "v"))
TERMINAL = ("done", "cancelled", "expired", "rejected")


def _fail(msg):
    pytest.fail(f"[fuzz seed={SEED}] {msg} — reproduce with "
                f"FUZZ_SEED={SEED} pytest tests/test_serving_fuzz.py")


def check(cond, msg):
    if not cond:
        _fail(msg)


@pytest.fixture(scope="module")
def model_params():
    cfg = reduce_config(get_config("bitnet-2b"), "tiny")
    model = Model(cfg, mode="serve")
    return model, model.init(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def registry(model_params):
    model, _ = model_params
    reg = AdapterRegistry(ADAPTER_SPEC)
    rng = np.random.default_rng(23)
    for i in range(2):
        reg.register(f"tenant-{i}",
                     synthetic_adapter_stacks(rng, model.cfg, ADAPTER_SPEC,
                                              model.cfg.num_layers,
                                              scale=0.05))
    return reg


class EDFCheckingScheduler(Scheduler):
    """Asserts the no-inversion invariant on every hand-out: among entries
    admissible *right now*, the one granted has the minimal
    (priority, deadline) key — ``prefer`` may only break exact-key ties."""

    def pop_next(self, can_admit=lambda r: True, prefer=None):
        admissible = [r for r in self._entries if can_admit(r)]
        got = super().pop_next(can_admit, prefer)
        if got is not None and admissible:
            gk = self._key(got)[:2]
            best = min(self._key(r)[:2] for r in admissible)
            check(gk <= best,
                  f"EDF inversion: granted key {gk} but {best} was "
                  f"admissible and more urgent")
        return got


def _page_invariants(eng):
    """Exactly-once page ownership across free list / trie / slot tables."""
    pool = eng.pool
    free = list(pool.free)
    check(len(free) == len(set(free)), "duplicate page ids in the free list")
    trie = {nd.page_id for nd in eng.prefix.nodes.values()} \
        if eng.prefix is not None else set()
    owned = []
    for slot in range(eng.max_slots):
        shared = pool.tables[slot][: eng.slot_cached[slot]]
        check(set(shared) <= trie,
              f"slot {slot} claims cache-shared pages {shared} the trie "
              f"does not own")
        owned += pool.tables[slot][eng.slot_cached[slot]:]
    check(len(owned) == len(set(owned)),
          "one page privately owned by two slots")
    every = free + sorted(trie) + owned
    check(len(every) == len(set(every)),
          "page owned by more than one of {free, trie, slot}")
    check(len(every) == pool.cfg.n_pages,
          f"page leak: {pool.cfg.n_pages - len(every)} pages unaccounted")


def _adapter_invariants(eng):
    for slot, req in eng._active_pairs():
        if req.adapter_id is not None:
            key = eng.slot_adapter_key[slot]
            check(key is not None,
                  f"in-flight adapter {req.adapter_id} has no pinned key")
            check(key.startswith(f"{req.adapter_id}@v"),
                  f"slot {slot} pinned {key} but serves {req.adapter_id}")
            check(eng.adapters.cache.is_resident(key),
                  f"in-flight adapter version {key} not resident")
            check(eng.adapters.cache.pinned(key),
                  f"in-flight adapter version {key} not pinned")


def _tier_invariants(eng):
    """Tiered-memory structural invariants, asserted every tick: the store's
    own self-check (one tier per entry, byte accounting, budgets, no orphan
    disk files) plus cross-structure consistency — device-tier KV entries
    mirror the prefix trie exactly, and pinned / in-flight adapters are
    never demoted off the device."""
    store = eng.tiered
    problems = store.verify()
    check(not problems, f"tiered store inconsistent: {problems}")
    dev = set(store.keys("device"))
    trie = {eng._kv_key(k) for k in eng.prefix.nodes} \
        if eng.prefix is not None else set()
    dev_kv = {k for k in dev if k.startswith("kv:")}
    check(dev_kv == trie,
          f"device-tier KV entries out of sync with trie: "
          f"only-store={sorted(dev_kv - trie)[:3]} "
          f"only-trie={sorted(trie - dev_kv)[:3]}")
    if eng.adapters is not None:
        cache = eng.adapters.cache
        resident = {f"adapter:{k}" for k in cache.resident_ids()}
        dev_ad = {k for k in dev if k.startswith("adapter:")}
        check(dev_ad == resident,
              f"device-tier adapter entries out of sync with cache: "
              f"store={sorted(dev_ad)} cache={sorted(resident)}")
        for key, pins in cache._pins.items():
            if pins > 0:
                check(store.tier_of(f"adapter:{key}") == "device",
                      f"pinned adapter {key} demoted off device "
                      f"(tier={store.tier_of(f'adapter:{key}')})")
    # entries in exactly one tier is structural (one dict, one tier field);
    # assert the sum anyway so a bookkeeping refactor can't silently split
    n = sum(len(store.keys(t)) for t in ("device", "host", "disk"))
    check(n == len(store.keys()), "entry counted in more than one tier")


def _metrics_invariants(gw, reqs):
    """Metrics consistency, asserted every tick: the registry must agree
    with ground truth — the tokens_out counter with the tokens actually
    emitted (request outputs AND the engine's own counter), the pool gauge
    with the pool's accounting, rates with their domains, the energy
    integrator with physics (non-negative, only growing)."""
    eng = gw.engine
    m = gw.metrics
    emitted = sum(len(q.output) for q in reqs)
    check(m.counter("tokens_out") == emitted,
          f"tokens_out counter {m.counter('tokens_out')} != "
          f"{emitted} tokens in request outputs")
    check(m.counter("tokens_out") == eng.stats.tokens_out,
          f"tokens_out counter {m.counter('tokens_out')} != engine stats "
          f"{eng.stats.tokens_out}")
    if eng.pool is not None:
        check(m.gauges.get("pool_pages_free") == eng.pool.pages_free,
              f"pool_pages_free gauge {m.gauges.get('pool_pages_free')} != "
              f"pool accounting {eng.pool.pages_free}")
    rate = m.gauges.get("spec_accept_rate", 0.0)
    check(0.0 <= rate <= 1.0, f"spec_accept_rate {rate} outside [0, 1]")
    frac = m.gauges.get("gated_bank_fraction", 1.0)
    check(0.0 <= frac <= 1.0, f"gated_bank_fraction {frac} outside [0, 1]")
    check(gw.energy.energy_j >= 0.0, "energy integral went negative")
    check(m.gauges.get("energy_per_token_j", 0.0) >= 0.0,
          "energy_per_token_j gauge negative")


def _slo_invariants(gw, reqs):
    """Attribution ledger, asserted every tick: for every tracked request —
    in flight or terminal (done / cancelled / expired / preempted-and-back)
    — the phase components are non-negative and sum exactly to the
    request's wall time (float-addition tolerance only)."""
    now = time.time()
    for req in reqs:
        snap = gw.slo.snapshot(req, now=now)
        if snap is None:                 # rejected at submit: never tracked
            check(req.state == "rejected",
                  f"request {req.uid} in state {req.state!r} has no SLO track")
            continue
        comp, wall = snap
        for phase, v in comp.items():
            check(v >= 0.0,
                  f"SLO component {phase} negative ({v}) for request "
                  f"{req.uid} in state {req.state!r}")
        total = sum(comp.values())
        check(abs(total - wall) < 1e-6 + 1e-9 * abs(wall),
              f"SLO components sum {total} != wall {wall} for request "
              f"{req.uid} in state {req.state!r} ({comp})")


def _terminal_invariants(reqs):
    for req in reqs:
        check(req.state in TERMINAL,
              f"request {req.uid} stuck in state {req.state!r} after drain")
        check(len(req.output) <= req.max_new_tokens,
              f"request {req.uid} overran its token budget")
        if req.state == "done":
            ended_by_eos = (req.spec.eos_id is not None
                            and req.output[-1] == req.spec.eos_id)
            check(len(req.output) == req.max_new_tokens or ended_by_eos,
                  f"request {req.uid} 'done' without eos or budget "
                  f"({len(req.output)}/{req.max_new_tokens})")


def _random_spec(rng, tick):
    priority = int(rng.integers(0, 3))
    deadline = None
    roll = rng.random()
    if roll < 0.25:
        deadline = float(rng.integers(30_000, 90_000))   # far future: EDF order
    elif roll < 0.30:
        deadline = -1.0                                  # already expired
    adapter = None
    if rng.random() < 0.4:
        adapter = f"tenant-{int(rng.integers(0, 2))}"
    eos = int(rng.integers(0, 50)) if rng.random() < 0.3 else None
    return RequestSpec(max_new_tokens=int(rng.integers(1, 7)),
                       priority=priority, deadline_ms=deadline,
                       adapter_id=adapter, eos_id=eos)


def _random_sampling(rng):
    # spec_k > 0 on greedy/seeded rows exercises the multi-token verify +
    # span-commit path under the same invariants (0 = plain decode)
    spec_k = int(rng.choice([0, 2, 4]))
    if rng.random() < 0.6:
        return SamplingParams(spec_k=spec_k)          # greedy
    return SamplingParams(temperature=0.8, top_k=int(rng.integers(0, 8)),
                          top_p=float(rng.choice([1.0, 0.9])),
                          seed=int(rng.integers(0, 1000)), spec_k=spec_k)


def _random_prompt(rng, prefixes):
    tail = list(rng.integers(0, 50, size=int(rng.integers(1, 12))))
    if rng.random() < 0.5:               # shared system prefix → trie traffic
        return list(prefixes[int(rng.integers(0, len(prefixes)))]) + tail
    return tail


def _drive(eng, gw, rng, ticks, reqs, prefixes, paged):
    live_uids = []
    mid_prefill_cancels = 0
    for t in range(ticks):
        if rng.random() < 0.18 and len(reqs) < 64:
            req = gw.submit(_random_prompt(rng, prefixes),
                            _random_spec(rng, t), _random_sampling(rng))
            reqs.append(req)
            if req.state != "rejected":
                live_uids.append(req.uid)
        if live_uids and rng.random() < 0.04:
            gw.cancel(live_uids.pop(int(rng.integers(0, len(live_uids)))))
        # targeted: cancel a slot whose chunked prefill is mid-flight —
        # committed chunk pages must release exactly once (no double-free
        # against _release_slot's partial-prefill path)
        if rng.random() < 0.08:
            prefilling = [q for i, q in enumerate(eng.slot_req)
                          if q is not None and eng.slot_prefill_todo[i]]
            if prefilling:
                victim = prefilling[int(rng.integers(0, len(prefilling)))]
                if gw.cancel(victim.uid):
                    mid_prefill_cancels += 1
                    if victim.uid in live_uids:
                        live_uids.remove(victim.uid)
        gw.step()
        if paged:
            _page_invariants(eng)
        if eng.adapters is not None:
            _adapter_invariants(eng)
        if eng.tiered is not None:
            _tier_invariants(eng)
        _metrics_invariants(gw, reqs)
        _slo_invariants(gw, reqs)
    return mid_prefill_cancels


class TestServingFuzz:
    def test_paged_full_stack(self, model_params, registry):
        """The headline harness: paged KV + prefix cache + chunked prefill +
        adapters + cancels, >= TICKS seeded ticks, invariants every tick."""
        model, params = model_params
        nbytes = registry.get("tenant-0").nbytes
        adapters = AdapterServing(model, registry, budget_bytes=nbytes * 2,
                                  max_resident=2)
        eng = ServeEngine(model, params, max_slots=3, max_len=64,
                          prefill="batched", prefill_chunk=3,
                          kv=PagedKV(page=PAGE, n_pages=N_PAGES),
                          prefix_cache=True, seed=SEED, spec_decode=True,
                          scheduler=EDFCheckingScheduler(),
                          adapters=adapters)
        gw = Gateway(eng)
        rng = np.random.default_rng(SEED)
        prefixes = [list(rng.integers(0, 50, size=2 * PAGE))
                    for _ in range(2)]
        reqs = []
        mid_cancels = _drive(eng, gw, rng, TICKS, reqs, prefixes, paged=True)
        check(mid_cancels > 0,
              "stream never cancelled a mid-chunked-prefill slot — raise "
              "the targeted-cancel rate or prompt lengths")
        check(len(reqs) >= 10, "stream produced too few requests to stress "
                               "anything — raise the submit rate")
        # drain: no new arrivals, invariants still per tick
        for _ in range(3000):
            if not (len(eng.scheduler)
                    or any(r is not None for r in eng.slot_req)):
                break
            gw.step()
            _page_invariants(eng)
            _adapter_invariants(eng)
            _metrics_invariants(gw, reqs)
            _slo_invariants(gw, reqs)
        _terminal_invariants(reqs)
        _slo_invariants(gw, reqs)
        # after full drain only trie-owned pages may stay out of the pool
        trie = len({nd.page_id for nd in eng.prefix.nodes.values()})
        check(eng.pool.pages_free + trie == N_PAGES,
              "pages missing after full drain")
        check(eng.stats.prefill_chunks > 0,
              "stream never exercised chunked prefill — lengthen prompts")

    def test_tiered_full_stack(self, model_params, registry, tmp_path):
        """The paged harness with the device→host→disk TieredStore riding
        along under a deliberately tiny host budget and a real disk tier,
        so demote cascades, disk spills, re-admits and prefetch all fire
        mid-stream. ``_tier_invariants`` runs every tick (via ``_drive``);
        after drain the host/disk tiers must empty leak-free."""
        model, params = model_params
        nbytes = registry.get("tenant-0").nbytes
        adapters = AdapterServing(model, registry, budget_bytes=nbytes * 2,
                                  max_resident=2)
        # host fits roughly one adapter's worth of spill: excess cascades
        # to the disk tier, so both demote hops run under the invariants
        store = TieredStore(host_budget_bytes=max(nbytes, 1 << 14),
                            disk_budget_bytes=8 << 20,
                            disk_dir=str(tmp_path / "tier"))
        eng = ServeEngine(model, params, max_slots=3, max_len=64,
                          prefill="batched", prefill_chunk=3,
                          kv=PagedKV(page=PAGE, n_pages=N_PAGES),
                          prefix_cache=True, seed=SEED + 7,
                          scheduler=EDFCheckingScheduler(),
                          adapters=adapters, tiered=store, prefetch=True)
        gw = Gateway(eng)
        rng = np.random.default_rng(SEED + 7)
        prefixes = [list(rng.integers(0, 50, size=2 * PAGE))
                    for _ in range(2)]
        reqs = []
        _drive(eng, gw, rng, max(80, TICKS // 2), reqs, prefixes, paged=True)
        for _ in range(3000):
            if not (len(eng.scheduler)
                    or any(r is not None for r in eng.slot_req)):
                break
            gw.step()
            _page_invariants(eng)
            _adapter_invariants(eng)
            _tier_invariants(eng)
            _metrics_invariants(gw, reqs)
            _slo_invariants(gw, reqs)
        _terminal_invariants(reqs)
        # some seeds never hit pool pressure mid-stream; force one demote
        # sweep post-drain so the spill path is covered on every seed
        if eng.stats.kv_spilled_pages == 0 and eng.prefix.nodes:
            eng._evict_prefix(len(eng.prefix.nodes))
            _page_invariants(eng)
            _tier_invariants(eng)
        check(eng.stats.kv_spilled_pages > 0,
              "stream never spilled a prefix page — no committed prefixes "
              "to demote; lengthen the shared prefixes")
        # post-drain leak check: host and disk must empty, files unlinked
        store.drain()
        check(store.verify() == [], f"post-drain verify: {store.verify()}")
        check(store.tier_bytes("host") == 0, "host bytes leaked after drain")
        check(store.tier_bytes("disk") == 0, "disk bytes leaked after drain")
        left = list((tmp_path / "tier").glob("*"))
        check(not left, f"disk files leaked after drain: {left}")

    def test_dense_backend(self, model_params):
        """Same stream shape on DenseKV (no paging/prefix): termination and
        EDF invariants must hold there too."""
        model, params = model_params
        eng = ServeEngine(model, params, max_slots=3, max_len=64,
                          prefill="batched", prefill_chunk=3,
                          kv=DenseKV(), seed=SEED + 1, spec_decode=True,
                          scheduler=EDFCheckingScheduler())
        gw = Gateway(eng)
        rng = np.random.default_rng(SEED + 1)
        prefixes = [list(rng.integers(0, 50, size=6))]
        reqs = []
        _drive(eng, gw, rng, max(60, TICKS // 3), reqs, prefixes,
               paged=False)
        for _ in range(2000):
            if not (len(eng.scheduler)
                    or any(r is not None for r in eng.slot_req)):
                break
            gw.step()
            _slo_invariants(gw, reqs)
        _terminal_invariants(reqs)
        _slo_invariants(gw, reqs)

class TestAsyncServingFuzz:
    """The same invariant battery, driven through the async runtime: client
    threads submit / stream / cancel concurrently against the dispatch
    thread, and the structural invariants are asserted at every quiescent
    point (the engine is owned by the dispatch thread, so checks run after
    ``drain`` — when the pipeline is settled — rather than per tick)."""

    def _stack(self, model_params, registry):
        model, params = model_params
        nbytes = registry.get("tenant-0").nbytes
        adapters = AdapterServing(model, registry, budget_bytes=nbytes * 2,
                                  max_resident=2)
        eng = ServeEngine(model, params, max_slots=3, max_len=64,
                          prefill="batched", prefill_chunk=3,
                          kv=PagedKV(page=PAGE, n_pages=N_PAGES),
                          prefix_cache=True, seed=SEED, spec_decode=True,
                          scheduler=EDFCheckingScheduler(),
                          adapters=adapters)
        return eng, Gateway(eng)

    @staticmethod
    def _no_leaks(eng):
        trie = len({nd.page_id for nd in eng.prefix.nodes.values()}) \
            if eng.prefix is not None else 0
        check(eng.pool.pages_free + trie == N_PAGES,
              f"page leak: free={eng.pool.pages_free} trie={trie} "
              f"!= {N_PAGES}")
        if eng.adapters is not None:
            pins = dict(eng.adapters.cache._pins)
            check(all(v == 0 for v in pins.values()),
                  f"adapter pins leaked after drain: {pins}")

    def test_async_multiclient_stress(self, model_params, registry):
        eng, gw = self._stack(model_params, registry)
        prefixes = [list(np.random.default_rng(SEED).integers(
            0, 50, size=2 * PAGE)) for _ in range(2)]
        all_tickets = []
        streamed = {}     # ticket -> tokens the client thread saw live
        lock = threading.Lock()

        def client(rt, cid, rnd):
            crng = np.random.default_rng(SEED * 1000 + rnd * 10 + cid)
            for _ in range(3):
                try:
                    tk = rt.submit(_random_prompt(crng, prefixes),
                                   _random_spec(crng, 0),
                                   _random_sampling(crng), timeout=60)
                except RuntimePoisoned:
                    return
                with lock:
                    all_tickets.append(tk)
                roll = crng.random()
                if roll < 0.45:
                    got = list(tk.stream(timeout=120))
                    with lock:
                        streamed[id(tk)] = (tk, got)
                elif roll < 0.65 and tk.req is not None:
                    time.sleep(float(crng.random()) * 0.02)
                    rt.cancel(tk.req.uid, timeout=60)
                # else: fire and forget — backlog thread still finishes it

        with AsyncServeRuntime(gw, depth=1) as rt:
            for rnd in range(3):
                threads = [threading.Thread(target=client,
                                            args=(rt, cid, rnd), daemon=True)
                           for cid in range(4)]
                for t in threads:
                    t.start()
                for t in threads:
                    t.join(timeout=300)
                check(not any(t.is_alive() for t in threads),
                      "client thread hung")
                rt.drain(timeout=300)
                # quiescent point: pipeline settled, inbox/backlog empty
                reqs = [t.req for t in all_tickets if t.req is not None]
                _page_invariants(eng)
                _adapter_invariants(eng)
                _metrics_invariants(gw, reqs)
                _slo_invariants(gw, reqs)
        check(len(all_tickets) >= 20, "stream produced too few requests")
        for tk in all_tickets:
            check(tk.terminal, f"ticket for uid "
                  f"{tk.req.uid if tk.req else '?'} not terminal after close")
            check(tk.state in TERMINAL,
                  f"ticket state {tk.state!r} unexpected without poison")
        # a consumed stream saw exactly the tokens the request emitted
        for tk, got in streamed.values():
            check(got == list(tk.req.output),
                  f"stream for uid {tk.req.uid} saw {got} but request "
                  f"recorded {tk.req.output}")
        _terminal_invariants([t.req for t in all_tickets
                              if t.req is not None])
        self._no_leaks(eng)

    def test_async_crash_recovery_no_leaks(self, model_params, registry):
        """Poison the dispatch thread mid-stream: every ticket must reach a
        terminal error state, every page / pin / queue entry must be
        released, and the fault must re-raise in the submit API."""
        eng, gw = self._stack(model_params, registry)
        rt = AsyncServeRuntime(gw, depth=1).start()
        crng = np.random.default_rng(SEED + 7)
        tickets = []
        for i in range(6):
            spec = RequestSpec(max_new_tokens=64,
                               adapter_id=f"tenant-{i % 2}" if i % 2 else None)
            tickets.append(rt.submit(
                list(crng.integers(0, 50, size=5)), spec,
                SamplingParams(), timeout=60))
        deadline = time.monotonic() + 60
        while (not any(t.tokens() for t in tickets)
               and time.monotonic() < deadline):
            time.sleep(0.01)
        fault = RuntimeError("fuzz-injected device fault")
        orig = eng._sampling_vectors

        def boom(*a, **kw):
            raise fault
        eng._sampling_vectors = boom
        deadline = time.monotonic() + 60
        while not rt.poisoned and time.monotonic() < deadline:
            time.sleep(0.01)
        eng._sampling_vectors = orig
        check(rt.poisoned, "runtime never observed the injected fault")
        rt._dispatch_thread.join(timeout=30)
        rt._backlog_thread.join(timeout=30)
        for tk in tickets:
            check(tk.terminal, "ticket left non-terminal after poison")
            check(tk.state in TERMINAL + ("error",),
                  f"unexpected post-poison ticket state {tk.state!r}")
        check(any(tk.state == "error" for tk in tickets),
              "no ticket carries the terminal error state")
        check(all(r is None for r in eng.slot_req), "slot leaked after poison")
        check(len(eng.scheduler) == 0, "queue entry leaked after poison")
        check(len(eng._pending) == 0, "pipeline tick leaked after poison")
        self._no_leaks(eng)
        with pytest.raises(RuntimePoisoned) as ei:
            rt.submit([1, 2, 3])
        check(ei.value.cause is fault, "poison lost the original exception")
        rt.close(raise_on_poison=False)


class TestRouterRecoveryFuzz:
    """Crash-recovery through the replica router: drop one replica's engine
    mid-tick, verify the fleet degrades (not dies), rebuild the replica,
    replay the dead in-flight requests through the router, and re-assert
    the page / pin / EDF invariant battery on every surviving and rebuilt
    engine — zero leaked pages or pins anywhere."""

    def _replica(self, model_params, registry, seed):
        model, params = model_params
        nbytes = registry.get("tenant-0").nbytes
        adapters = AdapterServing(model, registry, budget_bytes=nbytes * 2,
                                  max_resident=2)
        eng = ServeEngine(model, params, max_slots=3, max_len=64,
                          prefill="batched", prefill_chunk=3,
                          kv=PagedKV(page=PAGE, n_pages=N_PAGES),
                          prefix_cache=True, seed=seed, spec_decode=True,
                          scheduler=EDFCheckingScheduler(),
                          adapters=adapters)
        return eng, AsyncServeRuntime(Gateway(eng), depth=1)

    def test_router_crash_recovery_replay(self, model_params, registry):
        engs, rts = zip(*[self._replica(model_params, registry, SEED + i)
                          for i in range(2)])
        engs, rts = list(engs), list(rts)
        router = ReplicaRouter(list(rts)).start()
        old = None
        try:
            crng = np.random.default_rng(SEED + 13)
            payloads, tickets = [], []
            for i in range(10):
                prompt = list(crng.integers(
                    0, 50, size=int(crng.integers(3, 12))))
                spec = RequestSpec(
                    max_new_tokens=24,
                    adapter_id=f"tenant-{i % 2}" if i % 3 == 0 else None)
                sampling = (SamplingParams() if i % 2 == 0 else
                            SamplingParams(temperature=0.8, top_k=8,
                                           seed=int(crng.integers(0, 1000))))
                payloads.append((prompt, spec, sampling))
                tickets.append(router.submit(prompt, spec=spec,
                                             sampling=sampling, timeout=60))
            deadline = time.monotonic() + 60
            while (not any(t.tokens() for t in tickets)
                   and time.monotonic() < deadline):
                time.sleep(0.01)
            # drop the engine of a replica that owns live work, mid-tick
            with router._tickets_lock:
                owners = dict(router._owner)
            victim = next((owners[t.uid] for t in tickets if not t.terminal),
                          0)
            fault = RuntimeError("fuzz-injected replica fault")

            def boom(*a, **kw):
                raise fault
            engs[victim]._sampling_vectors = boom
            # a direct poke guarantees the dispatch thread ticks the fault
            # even if the victim's queue drained in the meantime (in-flight
            # work may trip it first — then the poke itself sees the poison)
            try:
                poked = rts[victim].submit([1, 2, 3],
                                           RequestSpec(max_new_tokens=2),
                                           SamplingParams(), timeout=60)
            except RuntimePoisoned:
                poked = None
            deadline = time.monotonic() + 60
            while not rts[victim].poisoned and time.monotonic() < deadline:
                time.sleep(0.01)
            check(rts[victim].poisoned,
                  "victim runtime never observed the injected fault")
            check(router.degraded and not router.poisoned,
                  "one dead replica must degrade the fleet, not kill it")
            rts[victim]._dispatch_thread.join(timeout=30)
            rts[victim]._backlog_thread.join(timeout=30)
            check(poked is None or poked.terminal,
                  "ticket on dead replica left non-terminal")
            # poison cleanup on the crashed engine: nothing leaked
            check(all(r is None for r in engs[victim].slot_req),
                  "slot leaked on the crashed replica")
            check(len(engs[victim].scheduler) == 0,
                  "queue entry leaked on the crashed replica")
            TestAsyncServingFuzz._no_leaks(engs[victim])
            # the survivor keeps serving through the router meanwhile
            alive = router.submit([7, 8, 9], spec=RequestSpec(max_new_tokens=2),
                                  sampling=SamplingParams(), timeout=60)
            with router._tickets_lock:
                check(router._owner[alive.uid] != victim,
                      "router placed a request on a poisoned replica")

            # rebuild the replica and swap it in under a fresh uid block
            eng_new, rt_new = self._replica(model_params, registry, SEED + 7)
            rt_new.start()
            old = router.replace_replica(victim, rt_new)
            check(old is rts[victim], "replace_replica returned wrong runtime")
            engs[victim] = eng_new
            check(not router.degraded, "fleet still degraded after rebuild")

            # replay every request the crash errored, through the router
            dead = [i for i, t in enumerate(tickets) if t.state == "error"]
            check(dead, "victim owned no in-flight request — injection raced")
            replayed = [router.submit(payloads[i][0], spec=payloads[i][1],
                                      sampling=payloads[i][2], timeout=60)
                        for i in dead]
            router.drain(timeout=300)
            prior = {t.uid for t in tickets} | {alive.uid}
            if poked is not None:
                prior.add(poked.uid)
            for t in replayed:
                check(t.state == "done",
                      f"replayed request ended {t.state!r}, not done")
                check(t.uid not in prior,
                      "replayed request reused a dead request's uid")
            check(alive.state == "done", "survivor request did not finish")
            for t in tickets:
                check(t.terminal, "original ticket left non-terminal")
            # full invariant battery on every live engine, post-recovery
            for e in engs:
                _page_invariants(e)
                _adapter_invariants(e)
                TestAsyncServingFuzz._no_leaks(e)
        finally:
            router.close(raise_on_poison=False)
            if old is not None:
                old.close(raise_on_poison=False)


class TestAdapterHotSwapFuzz:
    """Adapter hot-swap mid-stream: version re-registers land while
    requests are in flight. In-flight placements must finish on their
    pinned version (one cache key per placement epoch), new submits must
    ride the new version, and both versions may be resident at once."""

    def test_hotswap_midstream(self, model_params):
        model, params = model_params
        reg = AdapterRegistry(ADAPTER_SPEC)          # local: versions mutate
        arng = np.random.default_rng(SEED + 29)

        def stacks():
            return synthetic_adapter_stacks(arng, model.cfg, ADAPTER_SPEC,
                                            model.cfg.num_layers, scale=0.05)
        for i in range(2):
            reg.register(f"tenant-{i}", stacks())
        nbytes = reg.get("tenant-0").nbytes
        adapters = AdapterServing(model, reg, budget_bytes=nbytes * 3,
                                  max_resident=3)
        eng = ServeEngine(model, params, max_slots=3, max_len=64,
                          prefill="batched", prefill_chunk=3,
                          kv=PagedKV(page=PAGE, n_pages=N_PAGES),
                          prefix_cache=True, seed=SEED, spec_decode=True,
                          scheduler=EDFCheckingScheduler(),
                          adapters=adapters)
        gw = Gateway(eng)
        rng = np.random.default_rng(SEED + 5)
        reqs = []
        epoch_keys = {}          # (uid, n_preempts) -> pinned keys observed
        stale_pins = 0           # ticks where a slot rode an older version

        def observe():
            nonlocal stale_pins
            for slot, req in eng._active_pairs():
                if req.adapter_id is not None:
                    key = eng.slot_adapter_key[slot]
                    epoch_keys.setdefault(
                        (req.uid, req.n_preempts), set()).add(key)
                    latest = reg.get(req.adapter_id).version
                    if key != f"{req.adapter_id}@v{latest}":
                        stale_pins += 1

        def step():
            gw.step()
            _page_invariants(eng)
            _adapter_invariants(eng)
            _metrics_invariants(gw, reqs)
            observe()

        # deterministic opener: a long tenant-0 stream crosses a swap
        long_req = gw.submit(list(rng.integers(0, 50, size=6)),
                             RequestSpec(max_new_tokens=24,
                                         adapter_id="tenant-0"),
                             SamplingParams())
        reqs.append(long_req)
        while not long_req.output:
            step()
        reg.register("tenant-0", stacks())           # hot-swap to v2
        follower = gw.submit(list(rng.integers(0, 50, size=6)),
                             RequestSpec(max_new_tokens=6,
                                         adapter_id="tenant-0"),
                             SamplingParams())
        reqs.append(follower)
        while follower.state == "queued":
            step()
        check(long_req.state == "running",
              "opener finished before the swap could straddle it")
        slot_old = eng.slot_req.index(long_req)
        slot_new = eng.slot_req.index(follower)
        check(eng.slot_adapter_key[slot_old] == "tenant-0@v1",
              "in-flight request lost its pinned version on hot-swap")
        check(eng.slot_adapter_key[slot_new] == "tenant-0@v2",
              "post-swap submit did not ride the new version")
        check(eng.adapters.cache.is_resident("tenant-0@v1")
              and eng.adapters.cache.is_resident("tenant-0@v2"),
              "old and new versions not co-resident mid-swap")

        # fuzz phase: random adapter'd traffic with random re-registers
        for t in range(max(60, TICKS // 2)):
            if rng.random() < 0.3 and len(reqs) < 48:
                tenant = f"tenant-{int(rng.integers(0, 2))}"
                reqs.append(gw.submit(
                    _random_prompt(rng, [list(range(2 * PAGE))]),
                    RequestSpec(max_new_tokens=int(rng.integers(1, 7)),
                                priority=int(rng.integers(0, 3)),
                                adapter_id=tenant),
                    _random_sampling(rng)))
            if rng.random() < 0.06:
                reg.register(f"tenant-{int(rng.integers(0, 2))}", stacks())
            step()
        for _ in range(3000):
            if not (len(eng.scheduler)
                    or any(r is not None for r in eng.slot_req)):
                break
            step()
        _terminal_invariants(reqs)
        check(reg.get("tenant-0").version >= 2, "no swap ever happened")
        for epoch, keys in epoch_keys.items():
            check(len(keys) == 1,
                  f"request epoch {epoch} switched adapter versions "
                  f"mid-placement: {sorted(keys)}")
        check(stale_pins > 0,
              "no request was ever observed riding a pre-swap version — "
              "the swap/straddle path went unexercised")
        pins = dict(eng.adapters.cache._pins)
        check(all(v == 0 for v in pins.values()),
              f"adapter pins leaked after drain: {pins}")
