"""Speculative decoding: identity, accept/reject planning, sampler golden.

The hard contract: with ``spec_decode=True`` the engine's outputs are
**token-identical** to the non-speculative engine — greedy and seeded alike,
on both KV backends, with and without multi-tenant adapters. The verify step
earns this by running the S positions as a ``lax.scan`` of the exact
``decode_step`` graph (bit-identical logits per position), and the engine
commits only the accepted span (``PagePool.write_span`` / sliced dense
writes), so rejected drafts never touch storage.

Also pins the sampler invariant spec decode leans on: ``temperature=0`` is
exact argmax regardless of top-p/top-k masking (golden-tested over a
combinatorial grid), plus the pure host-side accept/commit planning helpers.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.launch.train import reduce_config
from repro.models.transformer import Model
from repro.serving import (DenseKV, PagedKV, RequestSpec, SamplingParams,
                           ServeEngine)
from repro.serving.gateway import Gateway
from repro.serving.spec import (AdaptiveSpecK, accepted_prefix,
                                cycle_propose, ngram_propose, plan_emit,
                                propose, quantize_width)

jax.config.update("jax_enable_x64", False)

pytestmark = pytest.mark.spec

ADAPTER_SPEC = None  # built lazily (AdapterSpec import kept local)


@pytest.fixture(scope="module")
def model_params():
    cfg = reduce_config(get_config("bitnet-2b"), "tiny")
    model = Model(cfg, mode="serve")
    return model, model.init(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def registry(model_params):
    from repro.serving.adapters import (AdapterRegistry, AdapterSpec,
                                        synthetic_adapter_stacks)
    model, _ = model_params
    spec = AdapterSpec(rank=4, alpha=8.0, targets=("q", "v"))
    reg = AdapterRegistry(spec)
    rng = np.random.default_rng(5)
    for i in range(2):
        reg.register(f"t{i}",
                     synthetic_adapter_stacks(rng, model.cfg, spec,
                                              model.cfg.num_layers,
                                              scale=0.05))
    return reg


PROMPTS = [(7,), (12,), (5,)]


def _prompts(vocab_cap=1000):
    rng = np.random.default_rng(0)
    return [list(rng.integers(0, vocab_cap, size=n)) for (n,) in PROMPTS]


def _run(model, params, kv_factory, spec_k, *, registry=None, seed=None,
         temperature=0.0, max_new=16, eos_id=None, prompts=None):
    adapters = None
    if registry is not None:
        from repro.serving.adapters import AdapterServing
        adapters = AdapterServing(model, registry,
                                  budget_bytes=registry.get("t0").nbytes * 2,
                                  max_resident=2)
    eng = ServeEngine(model, params, max_slots=4, max_len=64,
                      prefill="batched", kv=kv_factory(),
                      spec_decode=spec_k > 0, adapters=adapters)
    reqs = []
    for j, p in enumerate(prompts or _prompts()):
        adapter_id = f"t{j % 2}" if registry is not None else None
        reqs.append(eng.submit(
            p, RequestSpec(max_new_tokens=max_new, adapter_id=adapter_id,
                           eos_id=eos_id),
            SamplingParams(temperature=temperature, seed=seed,
                           spec_k=spec_k)))
    eng.run_until_drained()
    assert all(r.state == "done" for r in reqs)
    return [r.output for r in reqs], eng


class TestIdentityMatrix:
    """{DenseKV, PagedKV} x {adapter, none} x spec_k in {0, 1, 4}: greedy
    outputs must be token-identical to the non-speculative engine."""

    @pytest.mark.parametrize("kv_name", ["dense", "paged"])
    @pytest.mark.parametrize("with_adapter", [False, True])
    def test_greedy_identity(self, model_params, registry, kv_name,
                             with_adapter):
        model, params = model_params
        kv_factory = DenseKV if kv_name == "dense" \
            else (lambda: PagedKV(page=16))
        reg = registry if with_adapter else None
        base, _ = _run(model, params, kv_factory, 0, registry=reg)
        for spec_k in (1, 4):
            outs, eng = _run(model, params, kv_factory, spec_k, registry=reg)
            assert outs == base, (
                f"{kv_name}/adapter={with_adapter}/spec_k={spec_k} diverged "
                f"from the non-speculative engine")
            # spec_k=4 on these prompts must actually speculate (greedy
            # decode cycles quickly) — an identity test that never drafts
            # proves nothing
            if spec_k == 4:
                assert eng.stats.spec_drafted > 0
                assert eng.stats.spec_accepted > 0
                assert eng.stats.tokens_out > eng.stats.ticks  # multi-commit

    def test_spec_k0_request_on_spec_engine(self, model_params):
        """spec_k=0 requests on a spec_decode=True engine ride the plain
        decode path — no drafts, no verify ticks, identical outputs."""
        model, params = model_params
        base, _ = _run(model, params, DenseKV, 0)
        eng = ServeEngine(model, params, max_slots=4, max_len=64,
                          prefill="batched", kv=DenseKV(), spec_decode=True)
        reqs = [eng.submit(p, RequestSpec(max_new_tokens=16),
                           SamplingParams(spec_k=0)) for p in _prompts()]
        eng.run_until_drained()
        assert [r.output for r in reqs] == base
        assert eng.stats.spec_drafted == 0 and eng.stats.spec_ticks == 0

    def test_eos_mid_draft_truncates_identically(self, model_params):
        """An eos landing inside an accepted draft must end the stream at
        exactly the token the sequential engine would have stopped on."""
        model, params = model_params
        base, _ = _run(model, params, lambda: PagedKV(page=16), 0,
                       max_new=16)
        # pick an eos that each stream emits mid-output so truncation is
        # exercised on every slot that reaches it
        eos = base[0][min(4, len(base[0]) - 1)]
        ref, _ = _run(model, params, lambda: PagedKV(page=16), 0,
                      max_new=16, eos_id=eos)
        outs, _ = _run(model, params, lambda: PagedKV(page=16), 4,
                       max_new=16, eos_id=eos)
        assert outs == ref
        for o in outs:
            assert eos not in o[:-1], "tokens emitted past eos"

    def test_seeded_sampling_reproducibility(self, model_params):
        """Seeded temperature>0 requests speculate too (draws depend only on
        (seed, step)); outputs must match the non-speculative engine, and a
        repetitive prompt must actually produce draft traffic."""
        model, params = model_params
        motif = [11, 23, 37]
        prompts = [motif * 4, motif * 3 + [5], list(range(40, 47))]
        base, _ = _run(model, params, lambda: PagedKV(page=16), 0,
                       seed=123, temperature=0.8, prompts=prompts)
        outs, eng = _run(model, params, lambda: PagedKV(page=16), 4,
                         seed=123, temperature=0.8, prompts=prompts)
        assert outs == base
        assert eng.stats.spec_drafted > 0, \
            "repetitive prompts should draft even when sampling rejects"

    def test_unseeded_sampling_never_drafts(self, model_params):
        """Unseeded stochastic requests have no reproducible accept test —
        they must fall back to one token per tick."""
        model, params = model_params
        _, eng = _run(model, params, DenseKV, 4, temperature=0.9)
        assert eng.stats.spec_drafted == 0 and eng.stats.spec_ticks == 0

    def test_kernel_path_identity(self, model_params):
        """paged_attn="kernel" (interpret mode on CPU): drafts land in the
        in-jit pool copy and every verify position runs paged_flash_decode —
        outputs must match the kernel-mode non-speculative engine."""
        model, params = model_params
        mk = Model(model.cfg, mode="serve", paged_attn="kernel")
        prompts = [_prompts()[0]]

        def one(spec_k):
            eng = ServeEngine(mk, params, max_slots=2, max_len=64,
                              prefill="batched", kv=PagedKV(page=16),
                              spec_decode=spec_k > 0)
            req = eng.submit(prompts[0], RequestSpec(max_new_tokens=8),
                             SamplingParams(spec_k=spec_k))
            eng.run_until_drained()
            return req.output, eng.stats

        base, _ = one(0)
        outs, stats = one(2)
        assert outs == base
        assert stats.spec_ticks > 0


class TestSpecAccounting:
    def test_metrics_gauges(self, model_params):
        model, params = model_params
        eng = ServeEngine(model, params, max_slots=2, max_len=64,
                          prefill="batched", kv=PagedKV(page=16),
                          spec_decode=True)
        gw = Gateway(eng)
        req = gw.submit(_prompts()[0], RequestSpec(max_new_tokens=16),
                        SamplingParams(spec_k=4))
        gw.run_until_drained()
        g = gw.metrics_dict()["gauges"]
        assert g["spec_drafted_tokens"] == eng.stats.spec_drafted > 0
        assert g["spec_accepted_tokens"] == eng.stats.spec_accepted
        assert 0.0 <= g["spec_accept_rate"] <= 1.0
        assert req.spec_drafted > 0
        assert req.spec_accepted <= req.spec_drafted

    def test_budget_never_overrun(self, model_params):
        model, params = model_params
        for max_new in (1, 2, 5):
            outs, _ = _run(model, params, lambda: PagedKV(page=16), 4,
                           max_new=max_new)
            assert all(len(o) == max_new for o in outs)

    def test_paged_page_accounting_after_drain(self, model_params):
        """Rejected drafts must not leak reserved pages: after a full drain
        every page is back on the free list."""
        model, params = model_params
        kv = PagedKV(page=4, n_pages=64)
        _, eng = _run(model, params, lambda: kv, 4, max_new=16)
        assert eng.pool.pages_free == 64
        free = list(eng.pool.free)
        assert len(free) == len(set(free))


class TestSamplerGreedyGolden:
    """Satellite: temperature=0 must be exact argmax no matter what top-p /
    top-k masking rides along in the same batch (the spec-decode accept test
    compares drafts against this argmax)."""

    def test_greedy_exact_argmax_grid(self, model_params):
        model, params = model_params
        eng = ServeEngine(model, params, max_slots=4, max_len=32,
                          kv=DenseKV())
        rng = np.random.default_rng(0)
        v = 64
        for trial in range(25):
            scale = float(rng.choice([0.1, 1.0, 30.0]))
            logits = jnp.asarray(
                rng.normal(size=(4, v)).astype(np.float32) * scale)
            temps = jnp.zeros((4,), jnp.float32)
            topk = jnp.asarray(rng.integers(0, 5, size=4), jnp.int32)
            topp = jnp.asarray(rng.choice([0.05, 0.3, 0.9, 1.0], size=4),
                               jnp.float32)
            seeds = jnp.asarray(rng.integers(0, 100, size=4), jnp.int32)
            has_seed = jnp.asarray(rng.random(4) < 0.5)
            steps = jnp.asarray(rng.integers(0, 10, size=4), jnp.int32)
            expected = np.asarray(jnp.argmax(logits, -1))
            for use_topp in (True, False):
                for use_seeds in (True, False):
                    got = np.asarray(eng._sample(
                        logits, jax.random.PRNGKey(trial), temps, topk,
                        topp, seeds, has_seed, steps,
                        use_topp=use_topp, use_seeds=use_seeds))
                    np.testing.assert_array_equal(
                        got, expected,
                        err_msg=f"greedy row not exact argmax (trial "
                                f"{trial}, use_topp={use_topp}, "
                                f"use_seeds={use_seeds})")

    def test_greedy_degenerate_logits(self, model_params):
        """All-equal and all-NEG_INF rows must still return a valid argmax
        (first index), not NaN-propagate into garbage."""
        model, params = model_params
        eng = ServeEngine(model, params, max_slots=4, max_len=32,
                          kv=DenseKV())
        for logits in (jnp.zeros((4, 16)), jnp.full((4, 16), -1e30)):
            got = np.asarray(eng._sample(
                logits, jax.random.PRNGKey(0), jnp.zeros((4,)),
                jnp.asarray([0, 1, 2, 3], jnp.int32),
                jnp.asarray([0.5, 1.0, 0.05, 0.9], jnp.float32),
                jnp.zeros((4,), jnp.int32), jnp.asarray([True] * 4),
                jnp.zeros((4,), jnp.int32), use_topp=True, use_seeds=True))
            np.testing.assert_array_equal(got, np.zeros((4,), np.int32))

    def test_verify_sampler_matches_single_token_sampler(self, model_params):
        """The verify sampler's row (b, j) must reproduce `_sample_fn` at
        step steps0[b]+j exactly — greedy and seeded."""
        model, params = model_params
        eng = ServeEngine(model, params, max_slots=3, max_len=32,
                          kv=DenseKV())
        rng = np.random.default_rng(1)
        b, s, v = 3, 4, 32
        logits = jnp.asarray(rng.normal(size=(b, s, v)).astype(np.float32))
        temps = jnp.asarray([0.0, 0.8, 0.0], jnp.float32)
        topk = jnp.asarray([0, 3, 2], jnp.int32)
        topp = jnp.asarray([1.0, 0.9, 1.0], jnp.float32)
        seeds = jnp.asarray([0, 42, 0], jnp.int32)
        has_seed = jnp.asarray([False, True, False])
        steps0 = jnp.asarray([0, 5, 2], jnp.int32)
        key = jax.random.PRNGKey(9)
        got = np.asarray(eng._verify_sample(logits, key, temps, topk, topp,
                                            seeds, has_seed, steps0,
                                            use_topp=True, use_seeds=True))
        for j in range(s):
            # greedy/seeded rows are key-independent: any key gives the
            # reference draw for those lanes
            ref = np.asarray(eng._sample(logits[:, j], key, temps, topk,
                                         topp, seeds, has_seed, steps0 + j,
                                         use_topp=True, use_seeds=True))
            for row in (0, 1, 2):
                if temps[row] <= 0 or bool(has_seed[row]):
                    assert got[row, j] == ref[row]


class TestSpecHelpers:
    """Pure host-side planning: proposer, accept, emit caps."""

    def test_ngram_propose_prefers_longest_recent_match(self):
        h = [1, 2, 3, 9, 1, 2, 3]
        assert ngram_propose(h, 2) == [9, 1]        # trigram 1,2,3 matched
        assert ngram_propose([5, 6, 7], 4) == []    # no repetition
        assert ngram_propose([4, 4], 3) == []       # unigram: too noisy
        assert ngram_propose([4, 5, 4, 5], 3) == [4]  # bigram match: width 1
        assert ngram_propose([1], 3) == []
        assert ngram_propose(h, 0) == []

    def test_ngram_propose_most_recent_occurrence_wins(self):
        # 8,9 appears twice; the later occurrence's continuation (3) must
        # win over the earlier one's (1)
        h = [8, 9, 1, 8, 9, 3, 8, 9]
        assert ngram_propose(h, 1) == [3]

    def test_accepted_prefix(self):
        assert accepted_prefix([], [5, 6]) == 0
        assert accepted_prefix([5], [5, 6]) == 1
        assert accepted_prefix([5, 6, 7], [5, 6, 9, 8]) == 2
        assert accepted_prefix([4], [5]) == 0

    def test_plan_emit_caps(self):
        ch = [10, 11, 12, 13]
        assert plan_emit(3, ch, budget=10, room=10, eos_id=None) == ch
        assert plan_emit(3, ch, budget=2, room=10, eos_id=None) == [10, 11]
        assert plan_emit(3, ch, budget=10, room=1, eos_id=None) == [10]
        assert plan_emit(3, ch, budget=10, room=10, eos_id=12) == [10, 11, 12]
        assert plan_emit(0, ch, budget=10, room=10, eos_id=None) == [10]

    def test_cycle_propose(self):
        assert cycle_propose([1, 7, 7, 7], 4) == [7, 7, 7, 7]     # p=1
        assert cycle_propose([3, 4, 3, 4, 3, 4], 5) == [3, 4, 3, 4, 3]
        assert cycle_propose([1, 2, 3], 4) == []                  # no cycle
        assert cycle_propose([7, 7], 4) == []                     # < 3 reps
        # period-3 cycle continues in phase
        assert cycle_propose([1, 2, 3] * 3, 4) == [1, 2, 3, 1]

    def test_propose_prefers_cycle_then_ngram(self):
        assert propose([9, 5, 5, 5], 3) == [5, 5, 5]      # cycle wins
        h = [1, 2, 3, 9, 9, 1, 2, 3]
        assert propose(h, 2) == [9, 9]                    # n-gram fallback
        assert propose([10, 20, 30], 3) == []

    def test_quantize_width(self):
        assert [quantize_width(k) for k in range(-1, 9)] == \
            [0, 0, 1, 1, 3, 3, 3, 3, 7, 7]


class TestAdaptiveSpecK:
    """Pinned adaptation curve of the per-slot draft-width controller —
    pure host-side math, no model."""

    def test_optimistic_start_then_narrow_on_rejection(self):
        a = AdaptiveSpecK()                 # alpha=0.3, init_rate=1.0
        assert a.suggest(7) == 7            # first tick risks the ceiling
        # pinned EWMA trajectory under total rejection: rate *= 0.7 per tick
        widths = []
        for _ in range(6):
            a.observe(drafted=7, accepted=0)
            widths.append(a.suggest(7))
        # rate: .7 .49 .343 .240 .168 .118 → k: 5 3 2 2 1 1 → quantized
        assert widths == [3, 3, 1, 1, 1, 1]
        # the floor keeps one probe draft alive even after a long dry run
        for _ in range(50):
            a.observe(drafted=1, accepted=0)
        assert a.suggest(7) == 1

    def test_rewidens_when_acceptance_recovers(self):
        a = AdaptiveSpecK()
        for _ in range(10):
            a.observe(drafted=7, accepted=0)
        assert a.suggest(7) == 1
        widths = []
        for _ in range(8):
            a.observe(drafted=1, accepted=1)   # stream turned repetitive
            widths.append(a.suggest(7))
        # monotone recovery back to the ceiling, through the 1/3/7 buckets
        assert widths == sorted(widths)
        assert widths[-1] == 7

    def test_suggest_clamps_to_request_ceiling(self):
        a = AdaptiveSpecK()
        assert a.suggest(3) == 3
        assert a.suggest(0) == 0            # spec disabled for this request
        a.observe(drafted=4, accepted=2)    # rate 0.85
        assert a.suggest(15) == 7           # round(12.75)=13 → bucket 7
        assert a.suggest(3) == 3

    def test_zero_draft_tick_is_a_noop(self):
        a = AdaptiveSpecK()
        r0 = a.rate
        a.observe(drafted=0, accepted=0)
        assert a.rate == r0 and a.drafted == 0

    def test_engine_clamps_adaptive_width_to_sampling_spec_k(self):
        """The controller can only narrow, never exceed, the request's
        spec_k — the engine takes min(k, suggest(spec_k))."""
        a = AdaptiveSpecK(init_rate=5.0)     # pathological: EWMA above 1
        assert a.suggest(3) <= 3
        assert a.suggest(7) <= 7
