"""Integration tests: full train loop + exact resume, QLoRA immutability,
serving engine invariants, model-level property tests."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.launch.train import TrainConfig, Trainer, reduce_config
from repro.models.transformer import Model
from repro.serving import RequestSpec, ServeEngine

jax.config.update("jax_enable_x64", False)


def _tc(**kw):
    base = dict(arch="qwen3-1.7b", preset="tiny", steps=6, batch=2, seq=64,
                lr=1e-3, warmup=2, log_every=100)
    base.update(kw)
    return TrainConfig(**base)


class TestTrainLoop:
    def test_loss_decreases(self):
        t = Trainer(_tc(steps=25))
        final = t.run()
        assert final["ce_loss"] < np.log(2048) * 1.01

    def test_resume_is_exact(self):
        """Train 6 straight vs preempt-at-3 + resume → identical params.

        Both runs share the same schedule horizon (steps=6); the first is
        stopped early via stop_after (the preemption path)."""
        t_full = Trainer(_tc(steps=6))
        t_full.run()
        full_leaves = jax.tree.leaves(t_full.params)

        with tempfile.TemporaryDirectory() as d2:
            t_a = Trainer(_tc(steps=6, stop_after=3, ckpt_dir=d2, ckpt_every=3))
            t_a.run()
            t_b = Trainer(_tc(steps=6, ckpt_dir=d2, ckpt_every=100))
            assert t_b.step == 3  # resumed
            t_b.run()
            resumed_leaves = jax.tree.leaves(t_b.params)

        for a, b in zip(full_leaves, resumed_leaves):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_metrics_keys(self):
        final = Trainer(_tc()).run()
        for k in ("ce_loss", "grad_norm", "lr"):
            assert k in final


class TestQLoRA:
    def test_base_immutable_loss_falls(self):
        t = Trainer(_tc(mode="qlora", steps=30, lr=2e-3))
        packed_before = [np.asarray(l).copy()
                         for p, l in jax.tree_util.tree_flatten_with_path(t.params)[0]
                         if "packed" in jax.tree_util.keystr(p)]
        final = t.run()
        packed_after = [np.asarray(l)
                        for p, l in jax.tree_util.tree_flatten_with_path(t.params)[0]
                        if "packed" in jax.tree_util.keystr(p)]
        for a, b in zip(packed_before, packed_after):
            np.testing.assert_array_equal(a, b)
        assert final["ce_loss"] < np.log(2048)  # adapters learned something
        assert final["grad_norm"] > 0


class TestServeEngine:
    @pytest.fixture(scope="class")
    def model_params(self):
        cfg = reduce_config(get_config("qwen3-1.7b"), "tiny")
        model = Model(cfg, mode="serve")
        return model, model.init(jax.random.PRNGKey(0))

    def test_continuous_batching_completes_all(self, model_params):
        model, params = model_params
        eng = ServeEngine(model, params, max_slots=3, max_len=64)
        rng = np.random.default_rng(0)
        reqs = [eng.submit(list(rng.integers(0, 100, size=rng.integers(2, 10))),
                           RequestSpec(max_new_tokens=5)) for _ in range(8)]
        stats = eng.run_until_drained()
        assert stats.completed == 8
        assert all(len(r.output) == 5 for r in reqs)

    def test_greedy_independent_of_batch_composition(self, model_params):
        """A request's greedy output must not depend on co-scheduled slots."""
        model, params = model_params
        prompt = [5, 6, 7, 8]
        eng1 = ServeEngine(model, params, max_slots=4, max_len=64)
        alone = eng1.submit(prompt, RequestSpec(max_new_tokens=6))
        eng1.run_until_drained()

        eng2 = ServeEngine(model, params, max_slots=4, max_len=64)
        rng = np.random.default_rng(1)
        others = [eng2.submit(list(rng.integers(0, 100, size=7)),
                              RequestSpec(max_new_tokens=9)) for _ in range(3)]
        together = eng2.submit(prompt, RequestSpec(max_new_tokens=6))
        eng2.run_until_drained()
        assert alone.output == together.output

    def test_eos_stops_early(self, model_params):
        model, params = model_params
        eng = ServeEngine(model, params, max_slots=1, max_len=64)
        # find the greedy first token, then use it as "eos"
        probe = eng.submit([1, 2, 3], RequestSpec(max_new_tokens=2))
        eng.run_until_drained()
        eos = probe.output[0]
        eng2 = ServeEngine(model, params, max_slots=1, max_len=64)
        r = eng2.submit([1, 2, 3], RequestSpec(max_new_tokens=16, eos_id=eos))
        eng2.run_until_drained()
        assert r.output[-1] == eos and len(r.output) < 16

    def test_prompt_longer_than_window_truncates(self, model_params):
        model, params = model_params
        eng = ServeEngine(model, params, max_slots=1, max_len=32)
        r = eng.submit(list(range(60)), RequestSpec(max_new_tokens=4))
        eng.run_until_drained()
        assert len(r.output) == 4


class TestModelInvariants:
    def test_serve_decode_deterministic(self):
        cfg = reduce_config(get_config("starcoder2-7b"), "tiny")
        model = Model(cfg, mode="serve")
        params = model.init(jax.random.PRNGKey(0))
        outs = []
        for _ in range(2):
            cache = model.init_cache(1, 8)
            logits, _ = jax.jit(model.decode_step)(
                params, cache, jnp.asarray([3], jnp.int32),
                jnp.asarray(0, jnp.int32))
            outs.append(np.asarray(logits))
        np.testing.assert_array_equal(outs[0], outs[1])

    def test_vocab_padding_masked(self):
        cfg = reduce_config(get_config("mamba2-1.3b"), "tiny")
        cfg = cfg.replace(vocab_size=1000)  # padded → 1024
        assert cfg.vocab_padded == 1024
        model = Model(cfg, mode="serve")
        params = model.init(jax.random.PRNGKey(0))
        cache = model.init_cache(1, 8)
        logits, _ = model.decode_step(params, cache, jnp.asarray([1], jnp.int32),
                                      jnp.asarray(0, jnp.int32))
        pad_logits = np.asarray(logits)[:, 1000:]
        assert (pad_logits <= -1e29).all(), "pad vocab slots must be -inf"

    def test_batched_pos_decode_matches_scalar(self):
        """Vector positions (continuous batching) == scalar pos when aligned."""
        cfg = reduce_config(get_config("yi-34b"), "tiny")
        model = Model(cfg, mode="serve")
        params = model.init(jax.random.PRNGKey(0))
        tok = jnp.asarray([4, 9], jnp.int32)
        c1 = model.init_cache(2, 8)
        l1, c1 = model.decode_step(params, c1, tok, jnp.asarray(0, jnp.int32))
        c2 = model.init_cache(2, 8)
        l2, c2 = model.decode_step(params, c2, tok, jnp.asarray([0, 0], jnp.int32))
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                                   rtol=1e-5, atol=1e-5)
