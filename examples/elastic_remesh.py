"""Elastic re-mesh: lose half the fleet mid-training, keep going.

    PYTHONPATH=src python examples/elastic_remesh.py

Simulates the 1000-node failure story end-to-end on CPU devices:

  1. train on mesh A = (data=2, model=4) for 20 steps, async checkpoints;
  2. "lose" devices → re-plan onto mesh B = (data=4, model=2)
     (plan_remesh validates divisibility BEFORE touching any state);
  3. restore: every leaf re-shards onto mesh B's partition specs;
  4. continue training — the loss curve continues, no restart-from-scratch.

Run under XLA_FLAGS host-device emulation so both meshes exist.
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import sys

sys.path.insert(0, "src")

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.ckpt import checkpoint as ckpt  # noqa: E402
from repro.configs.base import get_config  # noqa: E402
from repro.launch.train import TrainConfig, Trainer, reduce_config  # noqa: E402
from repro.runtime.elastic import plan_remesh  # noqa: E402


def main() -> int:
    ckpt_dir = "/tmp/elastic_demo_ckpt"
    import shutil
    shutil.rmtree(ckpt_dir, ignore_errors=True)

    print("=== phase 1: mesh A = (data=2, model=4), 20 steps ===")
    t_a = Trainer(TrainConfig(arch="qwen3-1.7b", preset="tiny", steps=40,
                              stop_after=20, batch=4, seq=128,
                              mesh_model=4, ckpt_dir=ckpt_dir, ckpt_every=10,
                              log_every=10))
    t_a.run()
    loss_a = None

    print("\n=== phase 2: 'failure' → re-plan onto mesh B = (data=4, model=2) ===")
    cfg = reduce_config(get_config("qwen3-1.7b"), "tiny")
    plan = plan_remesh(cfg, (4, 2), ("data", "model"), global_batch=4)
    print(f"plan OK: {plan.new_shape}, notes={plan.notes}")
    bad = None
    try:
        plan_remesh(cfg, (3, 3), ("data", "model"))
    except ValueError as e:
        bad = e
    print(f"indivisible mesh correctly rejected: {type(bad).__name__}")

    print("\n=== phase 3: restore on mesh B and continue to step 40 ===")
    t_b = Trainer(TrainConfig(arch="qwen3-1.7b", preset="tiny", steps=40,
                              batch=4, seq=128, mesh_model=2,
                              ckpt_dir=ckpt_dir, ckpt_every=100,
                              log_every=10))
    assert t_b.step == 20, "should have resumed from the mesh-A checkpoint"
    # prove the state actually lives on the new mesh
    leaf = jax.tree.leaves(t_b.params)[1]
    print("restored leaf sharding:", leaf.sharding.spec if hasattr(leaf.sharding, "spec") else leaf.sharding)
    final = t_b.run()
    print(f"\n[elastic] continued to step 40 on the new mesh; "
          f"final loss {final['ce_loss']:.3f}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
