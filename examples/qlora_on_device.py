"""QLoRA on-device tuning (paper C4): adapt an IMMUTABLE packed-ROM base.

    PYTHONPATH=src python examples/qlora_on_device.py

The paper's two-path execution: the ternary base weights live in ROM and can
never change post-fabrication; adaptation happens through ternary LoRA
adapters in SRAM (LoTA-QAF-style), re-using the same Ternary×FP8 compute.

This example:
  1. builds a reduced model in 'qlora' mode (packed base + adapters),
  2. snapshots the packed base bytes,
  3. fine-tunes on the synthetic corpus — ONLY adapter/norm leaves train,
  4. verifies the loss falls AND the packed base is bit-identical after
     training (the ROM-immutability invariant).
"""
import sys

sys.path.insert(0, "src")

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.launch.train import TrainConfig, Trainer  # noqa: E402


def packed_fingerprint(params) -> int:
    h = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
        key = jax.tree_util.keystr(path)
        if "packed" in key:
            h ^= hash(np.asarray(leaf).tobytes()) ^ hash(key)
    return h


def main() -> int:
    tc = TrainConfig(arch="qwen3-1.7b", preset="tiny", mode="qlora",
                     steps=60, batch=4, seq=128, lr=2e-3, warmup=10,
                     log_every=10)
    trainer = Trainer(tc)

    before = packed_fingerprint(trainer.params)
    n_train = sum(
        np.prod(l.shape)
        for p, l in jax.tree_util.tree_flatten_with_path(trainer.params)[0]
        if "lora" in jax.tree_util.keystr(p))
    n_total = sum(np.prod(l.shape) for l in jax.tree.leaves(trainer.params))
    print(f"[qlora] trainable adapter params: {n_train / 1e3:.0f}K "
          f"of {n_total / 1e6:.1f}M total leaves")

    final = trainer.run()
    after = packed_fingerprint(trainer.params)

    loss = final.get("ce_loss", final.get("loss"))
    print(f"[qlora] final loss {loss:.3f} (random = {np.log(2048):.2f})")
    assert before == after, "ROM base mutated — C4 invariant violated!"
    print("[qlora] packed ROM base bit-identical after training ✓ "
          "(the paper's immutable 'knowledge foundation')")
    return 0


if __name__ == "__main__":
    sys.exit(main())
